# FlashOmni reproduction — one-liner entry points.
#
#   make test              tier-1 test suite (ROADMAP verify command)
#   make smoke             fast benchmark smoke (dispatch-plan amortization +
#                          schedule scan + micro rows); writes bench-smoke.json
#                          locally (gitignored — CI publishes it as the
#                          `bench-smoke` workflow artifact, never in-tree)
#   make bench             full paper-figure benchmark suite
#   make bench-strategies  sweep the strategy + schedule registries: density /
#                          pair-sparsity / fidelity table per producer
#   make bench-schedule    single-scan sampler vs the legacy three-jit loop
#                          (compile time + µs/step)
#   make bench-serving     sequential vs stacked vs continuous-batching
#                          serving (req/s + p50/p95 latency, bit parity)
#   make bench-attention   Fig. 6/10 attention table: fraction-of-peak +
#                          grid-slot accounting (uniform CSR grid vs the
#                          occupancy-bucketed layout; asserts the >=2x
#                          slot cut on the bimodal plan)
#   make bench-gemm        Fig. 6/11 sparse-GEMM table: fraction-of-peak +
#                          grid-slot accounting per density point and the
#                          skewed-occupancy GEMM-O rows (asserts the >=2x
#                          slot cut + bit-identity to the uniform kernel)
#   make autotune          measure per-strategy occupancy histograms (and,
#                          on a real TPU, sweep GEMM tile shapes) into
#                          src/repro/kernels/default_calibration.json;
#                          `make autotune-check` validates the table the
#                          way CI does
#   make analyze           engine invariant analyzer (src/repro/analysis):
#                          jaxpr passes (dispatch purity, collective budget,
#                          dtype promotion, executable budget), the four
#                          static cost certifiers (dispatch cost affine in
#                          T_kv + slot-proportional, a2a bytes == the
#                          pair_cap formula, Update amortization, peak-byte
#                          budgets — all on analysis/cost_model, abstract
#                          traces only, zero FLOPs, well under the 2-minute
#                          CI budget), the DispatchPlan structural validator
#                          over every strategy × backend × kv_buckets × mesh
#                          combo, and the repo-rule AST lint; exits non-zero
#                          on any finding (the CLI forces an 8-device host
#                          platform so mesh combos always run); filter with
#                          `python -m repro.analysis --passes 'cost-*'`

PY ?= python

.PHONY: test smoke bench bench-strategies bench-schedule bench-serving \
        bench-attention bench-gemm autotune autotune-check analyze

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

analyze:
	PYTHONPATH=src $(PY) -m repro.analysis

smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke --json bench-smoke.json

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-strategies:
	PYTHONPATH=src $(PY) -m benchmarks.run --only "strategy registry"

bench-schedule:
	PYTHONPATH=src $(PY) -m benchmarks.run --only "schedule scan"

bench-serving:
	PYTHONPATH=src $(PY) -m benchmarks.run --only "serving queue"

bench-attention:
	PYTHONPATH=src $(PY) -m benchmarks.run --only "fig6/fig10 attention"

bench-gemm:
	PYTHONPATH=src $(PY) -m benchmarks.run --only "fig6/fig11 sparse GEMMs"

autotune:
	PYTHONPATH=src:. $(PY) benchmarks/autotune.py --measure

autotune-check:
	PYTHONPATH=src:. $(PY) benchmarks/autotune.py --check
