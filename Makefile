# FlashOmni reproduction — one-liner entry points.
#
#   make test    tier-1 test suite (ROADMAP verify command)
#   make smoke   fast benchmark smoke (dispatch-plan amortization + micro rows)
#   make bench   full paper-figure benchmark suite

PY ?= python

.PHONY: test smoke bench

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run
