# FlashOmni reproduction — one-liner entry points.
#
#   make test              tier-1 test suite (ROADMAP verify command)
#   make smoke             fast benchmark smoke (dispatch-plan amortization + micro rows)
#   make bench             full paper-figure benchmark suite
#   make bench-strategies  sweep the strategy registry: density / pair-sparsity
#                          / fidelity table per registered symbol producer

PY ?= python

.PHONY: test smoke bench bench-strategies

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --smoke

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-strategies:
	PYTHONPATH=src $(PY) -m benchmarks.run --only "strategy registry"
