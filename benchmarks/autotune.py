"""Plan-calibrated kernel autotuner (ISSUE 8 tentpole, calibration half).

Populates the JSON calibration table consumed by
:mod:`repro.kernels.tuning` — two independent signals:

  * **Per-strategy occupancy histograms** (``strategies`` section): for
    every registered sparsity strategy, build a small engine, run a few
    Update steps and accumulate the plan's ``occ_hist`` (the halving
    width-class histogram of live-row KV occupancy,
    :func:`repro.core.plan.occupancy_histogram`), normalized to
    fractions.  Occupancy is a PLAN property, not a timing — measuring it
    with interpret-mode kernels on CPU is exact, so the checked-in
    default table stays valid for CPU CI (``interpret_safe: true``).

  * **Tile shapes** (``tiles`` section): a ``block_k``/``block_f`` timing
    sweep over the sparse GEMM kernels.  Timings only mean anything on a
    real TPU; off-TPU the sweep is skipped and the hand-picked 512
    defaults are written unchanged.

Usage::

    PYTHONPATH=src:. python benchmarks/autotune.py --measure \
        [--out src/repro/kernels/default_calibration.json] [--steps 6]
    PYTHONPATH=src:. python benchmarks/autotune.py --check [--table PATH]

``--check`` (the CI step) validates the table schema and asserts that
:func:`repro.kernels.tuning.select_kv_buckets` resolves every registered
strategy — calibrated or not — to a member of ``CANDIDATE_BUCKETS``, so a
bad table can never leave the engine without a bucket count.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# --measure: per-strategy occupancy histograms
# ---------------------------------------------------------------------------

def _engine(strategy):
    """Small-but-representative engine (mirrors tests/test_bucketed.py).

    ``N = 1024`` (32 pool blocks) is the floor at which window/phase
    strategies show their real occupancy skew — at toy scale a sliding
    window spans most of the sequence and every row reads as full-width,
    which would mis-calibrate the bucket model toward uniform grids."""
    from repro.core import AttnParams, EngineConfig, init_layer_state
    from repro.core.masks import MaskConfig
    B, H, N, dm, dh = 1, 4, 1024, 64, 32
    cfg = EngineConfig(
        mask=MaskConfig(pool=32, block_q=16, block_kv=16, interval=4,
                        order=1, warmup_steps=1, tau_kv=0.15, tau_q=0.5),
        cap_q_frac=1.0, cap_kv_frac=1.0, cache_dtype=jnp.float32,
        backend="xla", strategy=strategy, kv_buckets=1)
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    p = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh)) * 0.05,
        wk=jax.random.normal(ks[1], (dm, H * dh)) * 0.05,
        wv=jax.random.normal(ks[2], (dm, H * dh)) * 0.05,
        wo=jax.random.normal(ks[3], (H * dh, dm)) * 0.05,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm))
    state = init_layer_state(B, H, N, dm, dh, cfg)
    return cfg, p, x, state, H, N


def measure_strategy(name: str, steps: int = 6) -> dict:
    """Accumulated post-warmup occ_hist fractions for one strategy."""
    from repro.core import update_layer
    cfg, p, x, state, H, N = _engine(name)
    warm = cfg.mask.warmup_steps
    hist = np.zeros((), np.float64)
    rows = 0
    acc = None
    for s in range(steps):
        xs = x + 0.01 * jax.random.normal(jax.random.PRNGKey(10 + s), x.shape)
        _, state = update_layer(p, xs, state, cfg, n_text=64, heads=H,
                                step_idx=jnp.asarray(s, jnp.int32),
                                num_steps=steps)
        if s < warm:
            continue   # warmup plans are all-live by construction
        h = np.asarray(state.plan.occ_hist, np.float64).sum(axis=0)
        acc = h if acc is None else acc + h
    total = float(acc.sum()) if acc is not None else 0.0
    frac = (acc / total).tolist() if total > 0 else []
    return {"occ_hist": [round(f, 6) for f in frac], "rows": int(total)}


# ---------------------------------------------------------------------------
# --measure: tile sweep (real TPU only; timings are meaningless elsewhere)
# ---------------------------------------------------------------------------

_DEFAULT_TILES = {
    "gemm_q": {"default": {"block_k": 512, "block_f": 512}},
    "gemm_o": {"default": {"block_f": 512}},
    "attention": {"default": {}},
}


def sweep_tiles() -> tuple[dict, bool]:
    """Returns ``(tiles, interpret_safe)``.  Off-TPU: defaults, True."""
    if jax.default_backend() != "tpu":
        return json.loads(json.dumps(_DEFAULT_TILES)), True
    from benchmarks.common import time_fn
    from repro.core.symbols import active_indices
    from repro.kernels.gemm_o import gemm_o_sparse_kernel
    from repro.kernels.gemm_q import gemm_q_sparse_kernel
    tiles = json.loads(json.dumps(_DEFAULT_TILES))
    n, d, f, h, block = 4096, 1024, 1024, 8, 128
    t = n // block
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (n, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, f), jnp.float32)
    mask = jnp.zeros((t,), bool).at[: t // 2].set(True)
    ids, cnt = active_indices(mask, t // 2)
    best, best_t = None, float("inf")
    for bk in (256, 512, 1024):
        for bf in (256, 512, 1024):
            fn = jax.jit(lambda x, w, i, c, bk=bk, bf=bf: gemm_q_sparse_kernel(
                x, w, i, block_rows=block, block_k=bk, block_f=bf, row_cnt=c))
            dt = time_fn(fn, x, w, ids, cnt)
            if dt < best_t:
                best, best_t = {"block_k": bk, "block_f": bf}, dt
    tiles["gemm_q"][str(d)] = best
    tiles["gemm_q"]["default"] = dict(best)
    dh = d // h
    oh = jax.random.normal(ks[2], (h, n, dh), jnp.float32)
    wh = jax.random.normal(ks[3], (h, dh, f), jnp.float32)
    bias = jax.random.normal(ks[4], (n, f), jnp.float32)
    m_ch = jnp.zeros((t, h), bool).at[: t // 2, :].set(True)
    rids, rcnt = active_indices(jnp.any(m_ch, -1), t // 2)
    hids, hcnt = active_indices(jnp.take(m_ch, rids, axis=0), h)
    hcnt = jnp.where(jnp.arange(t // 2) < rcnt, hcnt, 0)
    best, best_t = None, float("inf")
    for bf in (256, 512, 1024):
        fn = jax.jit(lambda o, w, b, i, hi, hc, bf=bf: gemm_o_sparse_kernel(
            o, w, b, i, hi, hc, block_rows=block, block_f=bf))
        dt = time_fn(fn, oh, wh, bias, rids, hids, hcnt)
        if dt < best_t:
            best, best_t = {"block_f": bf}, dt
    tiles["gemm_o"][str(h)] = best
    tiles["gemm_o"]["default"] = dict(best)
    return tiles, False


def measure(out_path: Path, steps: int) -> dict:
    from repro.core.strategy import available_strategies
    from repro.kernels.tuning import select_kv_buckets, validate_table
    tiles, interpret_safe = sweep_tiles()
    strategies = {}
    for name in available_strategies():
        ent = measure_strategy(name, steps=steps)
        strategies[name] = ent
        print(f"# {name}: rows={ent['rows']} occ_hist={ent['occ_hist']}",
              file=sys.stderr)
    table = {
        "version": 1,
        "interpret_safe": interpret_safe,
        "tiles": tiles,
        "bucket_model": {"max_clamp_frac": 0.02},
        "strategies": strategies,
    }
    validate_table(table)
    for name in strategies:
        b = select_kv_buckets(name, table)
        print(f"# {name}: select_kv_buckets -> {b}", file=sys.stderr)
    out_path.write_text(json.dumps(table, indent=2) + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)
    return table


# ---------------------------------------------------------------------------
# --check: schema + selection sanity (the CI step)
# ---------------------------------------------------------------------------

def check(table_path: Path | None) -> int:
    from repro.core.strategy import available_strategies
    from repro.kernels.tuning import (CANDIDATE_BUCKETS, DEFAULT_TABLE_PATH,
                                      select_kv_buckets, validate_table)
    p = table_path or DEFAULT_TABLE_PATH
    try:
        table = json.loads(p.read_text())
        validate_table(table)
    except (OSError, ValueError) as e:
        print(f"FAIL: {p}: {e}", file=sys.stderr)
        return 1
    names = set(available_strategies()) | set(table.get("strategies", {}))
    bad = []
    for name in sorted(names):
        b = select_kv_buckets(name, table)
        calibrated = name in table.get("strategies", {})
        print(f"# {name}: kv_buckets={b}"
              f" ({'calibrated' if calibrated else 'uncalibrated -> uniform'})")
        if b not in CANDIDATE_BUCKETS:
            bad.append((name, b))
    if bad:
        print(f"FAIL: selections outside {CANDIDATE_BUCKETS}: {bad}",
              file=sys.stderr)
        return 1
    print(f"# OK: {p} valid; {len(names)} strategies resolve within "
          f"{CANDIDATE_BUCKETS}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--measure", action="store_true",
                      help="measure histograms (+ TPU tile sweep), write table")
    mode.add_argument("--check", action="store_true",
                      help="validate a table and the bucket selections (CI)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="--measure output path (default: checked-in table)")
    ap.add_argument("--table", default=None, metavar="PATH",
                    help="--check input path (default: checked-in table)")
    ap.add_argument("--steps", type=int, default=6,
                    help="Update steps per strategy in --measure")
    args = ap.parse_args(argv)
    if args.measure:
        from repro.kernels.tuning import DEFAULT_TABLE_PATH
        out = Path(args.out) if args.out else DEFAULT_TABLE_PATH
        measure(out, args.steps)
        return 0
    return check(Path(args.table) if args.table else None)


if __name__ == "__main__":
    sys.exit(main())
