"""Paper Table 3 ablation: interval 𝒩 ∈ {3..7} and order 𝒟 ∈ {0,1,2}.

Expected directions (verified on the reduced pipeline): fidelity decreases
with 𝒩; 𝒟=1 ≥ 𝒟=0 (first-order forecasting beats plain reuse); 𝒟=2 adds
little or regresses (the paper's 'limits of simulation' finding)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import psnr
from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig
from repro.core.masks import MaskConfig
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit


def _ecfg(interval, order, strategy="flashomni"):
    """Registry-named engine config (the ablation sweeps 𝒩/𝒟 over the
    paper's own ``flashomni`` symbol producer)."""
    return EngineConfig(mask=MaskConfig(
        tau_q=0.5, tau_kv=0.15, interval=interval, order=order, degrade=0.0,
        block_q=16, block_kv=16, pool=32, warmup_steps=2),
        strategy=strategy, cache_dtype=jnp.float32)


def run(csv: list, *, steps: int = 14, nv: int = 96):
    cfg = get_smoke("flux-mmdit")
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    x0 = jax.random.normal(key, (1, nv, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    scfg = SamplerConfig(num_steps=steps)
    dense = sample(params, cfg, _ecfg(4, 1), text_emb=text, x0=x0, scfg=scfg,
                   force_dense=True)

    for interval in [3, 4, 5, 6, 7]:
        out = sample(params, cfg, _ecfg(interval, 1), text_emb=text, x0=x0,
                     scfg=scfg)
        csv.append({"name": f"table3_N{interval}_D1", "us_per_call": 0.0,
                    "derived": f"psnr={psnr(out, dense):.2f}"})
    for order in [0, 1, 2]:
        out = sample(params, cfg, _ecfg(5, order), text_emb=text, x0=x0,
                     scfg=scfg)
        csv.append({"name": f"table3_N5_D{order}", "us_per_call": 0.0,
                    "derived": f"psnr={psnr(out, dense):.2f}"})
