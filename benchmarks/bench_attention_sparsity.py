"""Paper Fig. 6 (right) / Fig. 10: attention speedup vs sparsity for the
three configurations — feature caching (FC) only, block-sparse skipping
(BSS) only, and both — with randomly generated sparse symbols, exactly as
in the paper's kernel evaluation.

Three measurements per point:
  * measured wall-clock speedup of the STRUCTURAL sparse path vs dense
    attention (CPU XLA — the structural skipping is machine-independent);
  * the PLAN-LEVEL row: the same computation over a precomputed
    DispatchPlan index set (``sparse_attention_from_plan`` — what a
    Dispatch step actually runs), so kernel-vs-XLA comparisons are
    apples-to-apples with the engine's compile-once path (the mask-level
    wrapper additionally pays per-call index decoding);
  * structural FLOP reduction from compiled cost analysis (the quantity
    that maps 1:1 onto TPU MXU time, where the Pallas CSR kernel skips the
    same work at grid granularity).
Theory line: 1/(1−s).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import flops_of, time_fn
from repro.core.attention import (SparseAttentionSpec, attention_plan_indices,
                                  dense_attention, sparse_attention_from_plan,
                                  sparse_attention_xla)


def run(csv: list, *, n=2048, d=64, bh=4, block=64):
    t = n // block
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (bh, n, d))
    k = jax.random.normal(ks[1], (bh, n, d))
    v = jax.random.normal(ks[2], (bh, n, d))
    o_reuse = jnp.zeros((bh, n, d))

    dense = jax.jit(lambda q, k, v: dense_attention(q, k, v))
    t_dense = time_fn(dense, q, k, v)
    f_dense = flops_of(lambda q, k, v: dense_attention(q, k, v), q, k, v)

    for mode in ["FC", "BSS", "both"]:
        for s_target in [0.2, 0.5, 0.8]:
            if mode == "FC":
                p_c, p_s = 1.0 - s_target, 1.0
            elif mode == "BSS":
                p_c, p_s = 1.0, 1.0 - s_target
            else:
                keep = (1.0 - s_target) ** 0.5
                p_c = p_s = keep
            m_c = jax.random.bernoulli(ks[3], p_c, (bh, t)).at[..., 0].set(True)
            m_s = jax.random.bernoulli(ks[4], p_s, (bh, t, t)).at[..., 0].set(True)
            cap_q = int(m_c.sum(-1).max())
            kv_union = (m_s & m_c[..., None]).any(-2)
            cap_kv = int(kv_union.sum(-1).max())
            spec = SparseAttentionSpec(block, block, cap_q, cap_kv)
            fn = jax.jit(lambda q, k, v, mc, ms, orr: sparse_attention_xla(
                q, k, v, mc, ms, orr, spec))
            t_sparse = time_fn(fn, q, k, v, m_c, m_s, o_reuse)
            # Plan-level row: indices precomputed ONCE (Update time), the
            # timed body is exactly what a Dispatch step traces.
            q_ids, q_cnt, kv_ids, kv_cnt, pair_live = jax.jit(
                lambda mc, ms: attention_plan_indices(mc, ms, spec))(m_c, m_s)
            plan_fn = jax.jit(
                lambda q, k, v, orr, qi, qc, ki, kc, pl_: sparse_attention_from_plan(
                    q, k, v, orr, qi, qc, ki, kc, pl_, spec))
            t_plan = time_fn(plan_fn, q, k, v, o_reuse, q_ids, q_cnt,
                             kv_ids, kv_cnt, pair_live)
            f_sparse = flops_of(lambda q, k, v, mc, ms, orr: sparse_attention_xla(
                q, k, v, mc, ms, orr, spec), q, k, v, m_c, m_s, o_reuse)
            # realized sparsity = fraction of (i, j) tile pairs skipped
            pairs_live = float((m_s & m_c[..., None]).sum()) / (bh * t * t)
            s_real = 1.0 - pairs_live
            # TPU CSR-kernel structural metric: live grid cells = Σ kv_cnt
            # over live rows — the Pallas grid skips everything else, so
            # MXU-time speedup ≈ total/live (validated vs ref in tests).
            from repro.core.symbols import active_indices
            q_ids, q_cnt = active_indices(m_c, cap_q)
            rows = jnp.take_along_axis(m_s, q_ids[..., None], axis=-2)
            slot_live = jnp.arange(cap_q) < q_cnt[..., None]
            cells = float(jnp.sum(jnp.sum(rows, -1) * slot_live))
            csr_speedup = (bh * t * t) / max(cells, 1.0)
            csv.append({
                "name": f"fig6_attention_{mode}_s{s_target}",
                "us_per_call": t_sparse * 1e6,
                "derived": (f"sparsity={s_real:.3f}"
                            f" speedup_time={t_dense / t_sparse:.2f}"
                            f" speedup_flops={f_dense / max(f_sparse, 1):.2f}"
                            f" csr_grid_speedup={csr_speedup:.2f}"
                            f" theory={1 / (1 - s_real):.2f}"),
            })
            csv.append({
                "name": f"fig6_attention_plan_{mode}_s{s_target}",
                "us_per_call": t_plan * 1e6,
                "derived": (f"sparsity={s_real:.3f}"
                            f" speedup_time={t_dense / t_plan:.2f}"
                            f" index_decode_overhead_us="
                            f"{(t_sparse - t_plan) * 1e6:.1f}"),
            })
    csv.append({"name": "fig6_attention_dense_baseline",
                "us_per_call": t_dense * 1e6,
                "derived": f"flops={f_dense:.3g}"})
