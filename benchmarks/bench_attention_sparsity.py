"""Paper Fig. 6 (right) / Fig. 10: attention speedup vs sparsity for the
three configurations — feature caching (FC) only, block-sparse skipping
(BSS) only, and both — with randomly generated sparse symbols, exactly as
in the paper's kernel evaluation.

Measurements per density point:
  * measured wall-clock speedup of the STRUCTURAL sparse path vs dense
    attention (CPU XLA — the structural skipping is machine-independent);
  * the PLAN-LEVEL row: the same computation over a precomputed
    DispatchPlan index set (``sparse_attention_from_plan`` — what a
    Dispatch step actually runs), so kernel-vs-XLA comparisons are
    apples-to-apples with the engine's compile-once path (the mask-level
    wrapper additionally pays per-call index decoding);
  * structural FLOP reduction from compiled cost analysis (the quantity
    that maps 1:1 onto TPU MXU time, where the Pallas CSR kernel skips the
    same work at grid granularity), plus the fraction of roofline peak
    (``benchmarks.roofline.PEAK_FLOPS``) the measured time realises;
  * kernel GRID-SLOT accounting: uniform CSR grid (``BH·Cq·Ckv``) vs the
    occupancy-bucketed layout (``bucket_grid_slots``) — padded slots the
    uniform grid launches on skewed plans are the gap between structural
    FLOP reduction and realised speedup.
Theory line: 1/(1−s).

The bucketed section times the two-level-grid kernel against the uniform
kernel on a bimodal (hunyuan-like) plan — a few full-width rows in one
head, diagonal-only rows everywhere else — and ASSERTS the bucketed
layout cuts grid slots ≥ 2× while staying bit-identical to the uniform
kernel (no truncation on this plan).  CI consumes these rows from the
``--smoke --json`` artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import (check_flops_agreement, flops_of,
                               static_flops_of, time_fn)
from benchmarks.roofline import HBM_BW, PEAK_FLOPS
from repro.core.attention import (SparseAttentionSpec, attention_plan_indices,
                                  dense_attention, sparse_attention_from_plan,
                                  sparse_attention_xla)
from repro.core.plan import bucket_geometry, bucket_grid_slots


def _bucketed_bimodal(csv, *, n=256, d=64, heads=4, block=32, kv_buckets=3):
    """Fig. 10 bucketed-grid row: bimodal occupancy ACROSS heads.

    Head 0 carries a few full-width rows; every other row (all heads) is
    diagonal-only.  The uniform grid pads every row to ``cap_kv``; the
    bucketed layout gives the skinny rows narrow slots.  The wide rows fit
    the wide bucket here, so no truncation occurs and the two kernels must
    agree bit-for-bit (interpret mode — same flash accumulation order).
    """
    from repro.kernels import ops

    t = n // block
    bh = heads
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (bh, n, d))
    k = jax.random.normal(ks[1], (bh, n, d))
    v = jax.random.normal(ks[2], (bh, n, d))
    o_reuse = jnp.zeros((bh, n, d))

    diag = jnp.eye(t, dtype=bool)
    m_s = jnp.broadcast_to(diag, (bh, t, t))
    m_s = m_s.at[0, :3].set(True)            # 3 full-width rows in head 0
    m_s = m_s.at[..., 0].set(True)
    m_c = jnp.ones((bh, t), dtype=bool)

    cap_q, cap_kv = t, t
    geometry = bucket_geometry(cap_q, cap_kv, heads, kv_buckets)
    slots_uniform = bh * cap_q * cap_kv
    slots_bucketed = bucket_grid_slots(geometry)
    # ISSUE 6 acceptance: bucketed layout cuts grid slots >= 2x on a
    # bimodal plan (static: equal-area buckets give B/(2^B - 1) ≈ 0.43).
    assert slots_bucketed * 2 <= slots_uniform, (slots_bucketed, slots_uniform)

    uni = functools.partial(ops.flashomni_attention, block_q=block,
                            block_kv=block, cap_q=cap_q, cap_kv=cap_kv,
                            interpret=True)
    bkt = functools.partial(ops.flashomni_attention, block_q=block,
                            block_kv=block, cap_q=cap_q, cap_kv=cap_kv,
                            interpret=True, kv_buckets=kv_buckets, heads=heads)
    out_uni = uni(q, k, v, m_c, m_s, o_reuse)
    out_bkt = bkt(q, k, v, m_c, m_s, o_reuse)
    bit_identical = bool(jnp.all(out_uni == out_bkt))
    assert bit_identical, float(jnp.max(jnp.abs(out_uni - out_bkt)))
    t_uni = time_fn(uni, q, k, v, m_c, m_s, o_reuse, iters=3, warmup=1)
    t_bkt = time_fn(bkt, q, k, v, m_c, m_s, o_reuse, iters=3, warmup=1)

    # Live work: Σ kv cells · (QKᵀ + PV) MACs per (bq, bk, d) tile pair.
    cells = float(jnp.sum(m_s))
    f_live = 4.0 * cells * block * block * d
    bytes_live = 4.0 * (3 * bh * n * d + bh * n * d)     # f32 q,k,v + out
    geo = "/".join(f"{r}x{w}" for r, w in geometry)
    csv.append({
        "name": "fig10_attention_uniform_bimodal",
        "us_per_call": t_uni * 1e6,
        "derived": (f"grid_slots={slots_uniform}"
                    f" frac_peak={f_live / t_uni / PEAK_FLOPS:.2e}"
                    f" frac_hbm={bytes_live / t_uni / HBM_BW:.2e}"),
    })
    csv.append({
        "name": "fig10_attention_bucketed_bimodal",
        "us_per_call": t_bkt * 1e6,
        "derived": (f"grid_slots={slots_bucketed}"
                    f" grid_slot_cut={slots_uniform / slots_bucketed:.2f}"
                    f" frac_peak={f_live / t_bkt / PEAK_FLOPS:.2e}"
                    f" frac_hbm={bytes_live / t_bkt / HBM_BW:.2e}"
                    f" geometry={geo}"
                    f" bit_identical_to_uniform={int(bit_identical)}"),
    })


def run(csv: list, *, n=2048, d=64, bh=4, block=64, smoke=False):
    if smoke:
        n = 512
    t = n // block
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (bh, n, d))
    k = jax.random.normal(ks[1], (bh, n, d))
    v = jax.random.normal(ks[2], (bh, n, d))
    o_reuse = jnp.zeros((bh, n, d))

    dense = jax.jit(lambda q, k, v: dense_attention(q, k, v))
    t_dense = time_fn(dense, q, k, v)
    f_dense = flops_of(lambda q, k, v: dense_attention(q, k, v), q, k, v)
    # Independent second opinion on the roofline numerator (ISSUE 10):
    # the static cost model must agree with XLA's cost_analysis.
    sf_dense = check_flops_agreement(
        "fig6_attention_dense_baseline", f_dense,
        static_flops_of(lambda q, k, v: dense_attention(q, k, v), q, k, v))

    for mode in ["FC", "BSS", "both"]:
        for s_target in ([0.5] if smoke else [0.2, 0.5, 0.8]):
            if mode == "FC":
                p_c, p_s = 1.0 - s_target, 1.0
            elif mode == "BSS":
                p_c, p_s = 1.0, 1.0 - s_target
            else:
                keep = (1.0 - s_target) ** 0.5
                p_c = p_s = keep
            m_c = jax.random.bernoulli(ks[3], p_c, (bh, t)).at[..., 0].set(True)
            m_s = jax.random.bernoulli(ks[4], p_s, (bh, t, t)).at[..., 0].set(True)
            cap_q = int(m_c.sum(-1).max())
            kv_union = (m_s & m_c[..., None]).any(-2)
            cap_kv = int(kv_union.sum(-1).max())
            spec = SparseAttentionSpec(block, block, cap_q, cap_kv)
            fn = jax.jit(lambda q, k, v, mc, ms, orr: sparse_attention_xla(
                q, k, v, mc, ms, orr, spec))
            t_sparse = time_fn(fn, q, k, v, m_c, m_s, o_reuse)
            # Plan-level row: indices precomputed ONCE (Update time), the
            # timed body is exactly what a Dispatch step traces.
            q_ids, q_cnt, kv_ids, kv_cnt, pair_live = jax.jit(
                lambda mc, ms: attention_plan_indices(mc, ms, spec))(m_c, m_s)
            plan_fn = jax.jit(
                lambda q, k, v, orr, qi, qc, ki, kc, pl_: sparse_attention_from_plan(
                    q, k, v, orr, qi, qc, ki, kc, pl_, spec))
            t_plan = time_fn(plan_fn, q, k, v, o_reuse, q_ids, q_cnt,
                             kv_ids, kv_cnt, pair_live)
            f_sparse = flops_of(lambda q, k, v, mc, ms, orr: sparse_attention_xla(
                q, k, v, mc, ms, orr, spec), q, k, v, m_c, m_s, o_reuse)
            sf_sparse = check_flops_agreement(
                f"fig6_attention_{mode}_s{s_target}", f_sparse,
                static_flops_of(
                    lambda q, k, v, mc, ms, orr: sparse_attention_xla(
                        q, k, v, mc, ms, orr, spec),
                    q, k, v, m_c, m_s, o_reuse))
            # realized sparsity = fraction of (i, j) tile pairs skipped
            pairs_live = float((m_s & m_c[..., None]).sum()) / (bh * t * t)
            s_real = 1.0 - pairs_live
            # TPU CSR-kernel structural metric: live grid cells = Σ kv_cnt
            # over live rows — the Pallas grid skips everything else, so
            # MXU-time speedup ≈ total/live (validated vs ref in tests).
            from repro.core.symbols import active_indices
            q_ids, q_cnt = active_indices(m_c, cap_q)
            rows = jnp.take_along_axis(m_s, q_ids[..., None], axis=-2)
            slot_live = jnp.arange(cap_q) < q_cnt[..., None]
            cells = float(jnp.sum(jnp.sum(rows, -1) * slot_live))
            csr_speedup = (bh * t * t) / max(cells, 1.0)
            # Grid-slot accounting (ISSUE 6): the uniform CSR grid launches
            # BH·Cq·Ckv slots regardless of per-row occupancy; the bucketed
            # layout at B=3 shrinks the launch to its static slot total.
            slots_uniform = bh * cap_q * cap_kv
            slots_bucketed = bucket_grid_slots(
                bucket_geometry(cap_q, cap_kv, bh, 3))
            csv.append({
                "name": f"fig6_attention_{mode}_s{s_target}",
                "us_per_call": t_sparse * 1e6,
                "derived": (f"sparsity={s_real:.3f}"
                            f" speedup_time={t_dense / t_sparse:.2f}"
                            f" speedup_flops={f_dense / max(f_sparse, 1):.2f}"
                            f" csr_grid_speedup={csr_speedup:.2f}"
                            f" grid_slots_uniform={slots_uniform}"
                            f" grid_slots_bucketed={slots_bucketed}"
                            f" frac_peak={f_sparse / t_sparse / PEAK_FLOPS:.2e}"
                            f" static_flops={sf_sparse:.6g}"
                            f" theory={1 / (1 - s_real):.2f}"),
            })
            csv.append({
                "name": f"fig6_attention_plan_{mode}_s{s_target}",
                "us_per_call": t_plan * 1e6,
                "derived": (f"sparsity={s_real:.3f}"
                            f" speedup_time={t_dense / t_plan:.2f}"
                            f" frac_peak={f_sparse / t_plan / PEAK_FLOPS:.2e}"
                            f" index_decode_overhead_us="
                            f"{(t_sparse - t_plan) * 1e6:.1f}"),
            })
    csv.append({"name": "fig6_attention_dense_baseline",
                "us_per_call": t_dense * 1e6,
                "derived": (f"flops={f_dense:.3g}"
                            f" static_flops={sf_dense:.6g}"
                            f" frac_peak={f_dense / t_dense / PEAK_FLOPS:.2e}")})
    _bucketed_bimodal(csv)
