"""Paper Fig. 7: computation density over denoising steps, FlashOmni vs a
SpargeAttn-like static-sparsity arm (whose density stays flat)."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.strategies import strategy_configs
from repro.configs.registry import get_smoke
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit


def run(csv: list, *, steps: int = 12, nv: int = 96):
    cfg = get_smoke("flux-mmdit")
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(11)
    x0 = jax.random.normal(key, (1, nv, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    for name in ["FlashOmni", "SpargeAttn-like"]:
        trace: list = []
        sample(params, cfg, strategy_configs()[name], text_emb=text, x0=x0,
               scfg=SamplerConfig(num_steps=steps), trace=trace)
        dens = [round(t["density"], 3) for t in trace]
        csv.append({"name": f"fig7_density_{name}", "us_per_call": 0.0,
                    "derived": "trace=" + "|".join(map(str, dens))})
