"""Dispatch-plan amortization benchmark (ISSUE 1 tentpole accounting).

Measures the per-step cost of a Dispatch layer step under three regimes:

  * ``plan-reuse``   — the compile-once DispatchPlan path: dispatch
    consumes ``state.plan`` verbatim (what the engine now does);
  * ``plan-rebuild`` — the seed behaviour: unpack symbols → expand masks →
    top-k → active_indices on EVERY dispatch (via ``plan_from_state``);
  * ``update``       — a full Update step (dense attention + symbol +
    plan refresh), for the amortization denominator.

Derived columns report the µs/step of the two Update–Dispatch schedules
the paper compares (interval 𝒩=4: one Update + three Dispatches; 𝒩=1:
all Updates) and the rebuild-vs-reuse dispatch speedup.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import (AttnParams, EngineConfig, MaskConfig, dispatch_layer,
                        init_layer_state, plan_from_state, update_layer)


def _setup(n, dm, heads, dh, pool, blk, dtype=jnp.float32, mesh=(1, 1),
           cap_kv_frac=0.9):
    cfg = EngineConfig(
        mask=MaskConfig(pool=pool, block_q=blk, block_kv=blk, interval=4,
                        order=1, warmup_steps=1, tau_q=0.5, tau_kv=0.1),
        cap_q_frac=0.75, cap_kv_frac=cap_kv_frac, cache_dtype=dtype,
        mesh_dp=mesh[0], mesh_sp=mesh[1])
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    p = AttnParams(
        wq=jax.random.normal(ks[0], (dm, heads * dh), dtype) * 0.05,
        wk=jax.random.normal(ks[1], (dm, heads * dh), dtype) * 0.05,
        wv=jax.random.normal(ks[2], (dm, heads * dh), dtype) * 0.05,
        wo=jax.random.normal(ks[3], (heads * dh, dm), dtype) * 0.05,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (1, n, dm), dtype)
    state = init_layer_state(1, heads, n, dm, dh, cfg)
    _, state = update_layer(p, x, state, cfg, n_text=pool, heads=heads)
    return cfg, p, x, state, heads


def run(csv: list, smoke: bool = False) -> None:
    shapes = [(1024, 256, 4, 64, 128, 64)] if smoke else [
        (1024, 256, 4, 64, 128, 64),
        (4096, 512, 8, 64, 256, 64),
    ]
    for n, dm, heads, dh, pool, blk in shapes:
        cfg, p, x, state, h = _setup(n, dm, heads, dh, pool, blk)
        n_tok = x.shape[1]

        disp_reuse = jax.jit(lambda xx, ss: dispatch_layer(
            p, xx, ss, cfg, n_text=pool, heads=h)[0])
        disp_rebuild = jax.jit(lambda xx, ss: dispatch_layer(
            p, xx, ss, cfg, n_text=pool, heads=h,
            plan=plan_from_state(ss, cfg, n_tok))[0])
        upd = jax.jit(lambda xx, ss: update_layer(
            p, xx, ss, cfg, n_text=pool, heads=h)[0])

        iters = 9 if smoke else 15
        t_reuse = time_fn(disp_reuse, x, state, iters=iters) * 1e6
        t_rebuild = time_fn(disp_rebuild, x, state, iters=iters) * 1e6
        t_update = time_fn(upd, x, state, iters=iters) * 1e6

        # Deterministic witness of the removed work (immune to wall-clock
        # noise on shared hosts): index-decode ops in each dispatch jaxpr,
        # counted by the analyzer's primitive-level walker (recurses into
        # pjit/scan sub-jaxprs — jaxpr-text grep misses those).
        def _index_ops(fn):
            from repro.analysis.jaxpr_walk import index_decode_eqns
            return len(index_decode_eqns(jax.make_jaxpr(fn)(x, state)))

        ops_reuse = _index_ops(disp_reuse)
        ops_rebuild = _index_ops(disp_rebuild)

        # Update–Dispatch schedule cost per step (paper interval ablation).
        step_i4 = (t_update + 3 * t_reuse) / 4.0
        step_i4_rebuild = (t_update + 3 * t_rebuild) / 4.0
        step_i1 = t_update

        tag = f"N{n}dm{dm}h{heads}"
        csv.append({"name": f"dispatch_plan_reuse/{tag}",
                    "us_per_call": t_reuse,
                    "derived": (f"rebuild_speedup={t_rebuild / t_reuse:.3f}x "
                                f"sort_topk_ops={ops_reuse}")})
        csv.append({"name": f"dispatch_plan_rebuild/{tag}",
                    "us_per_call": t_rebuild,
                    "derived": (f"overhead={t_rebuild - t_reuse:.1f}us "
                                f"sort_topk_ops={ops_rebuild}")})
        csv.append({"name": f"schedule_interval4/{tag}",
                    "us_per_call": step_i4,
                    "derived": f"vs_interval1={step_i1 / step_i4:.3f}x"})
        csv.append({"name": f"schedule_interval4_rebuild/{tag}",
                    "us_per_call": step_i4_rebuild,
                    "derived": f"vs_interval1={step_i1 / step_i4_rebuild:.3f}x"})

    # Plan-sharded dispatch row (ISSUE 7): same plan, attention running
    # shard_map'ed over a (1, 4) engine mesh with the plan-aware KV
    # exchange.  Needs >= 4 devices — CI's forced-8-device job runs it;
    # on a single-device host the row is skipped (and said so: a silently
    # missing row reads as covered).
    if jax.device_count() >= 4:
        n, dm, heads, dh, pool, blk = 1024, 256, 4, 64, 128, 64
        # 25% density: the regime where the plan-aware exchange beats the
        # dense all-gather (the --sharded-gate regime, here with timing).
        cfgm, p, x, state, h = _setup(n, dm, heads, dh, pool, blk,
                                      mesh=(1, 4), cap_kv_frac=0.25)
        cfg1 = dataclasses.replace(cfgm, mesh_dp=1, mesh_sp=1)
        disp_mesh = jax.jit(lambda xx, ss: dispatch_layer(
            p, xx, ss, cfgm, n_text=pool, heads=h)[0])
        disp_one = jax.jit(lambda xx, ss: dispatch_layer(
            p, xx, ss, cfg1, n_text=pool, heads=h)[0])
        iters = 9 if smoke else 15
        t_mesh = time_fn(disp_mesh, x, state, iters=iters) * 1e6
        t_one = time_fn(disp_one, x, state, iters=iters) * 1e6
        bit = bool((jnp.asarray(disp_mesh(x, state))
                    == jnp.asarray(disp_one(x, state))).all())

        from repro.distributed.plan_shard import (dense_exchange_blocks,
                                                  exchange_blocks,
                                                  shard_geometry)
        t_kv = cfgm.mask.n_blocks(n) * (pool // blk)
        geom = shard_geometry(cfgm.caps(n), t_kv, t_kv, 4,
                              cfgm.mesh_pair_slack)
        csv.append({"name": f"dispatch_plan_sharded/N{n}sp4",
                    "us_per_call": t_mesh,
                    "derived": (f"bit_identical_to_oracle={int(bit)} "
                                f"exchange_blocks={exchange_blocks(geom)} "
                                f"dense_blocks={dense_exchange_blocks(t_kv)} "
                                f"oracle_us={t_one:.1f}")})
    else:
        print("[bench_dispatch_plan] sharded row skipped: "
              f"{jax.device_count()} device(s) < 4")
