"""Paper Tables 1 & 2: end-to-end fidelity vs sparsity across strategies.

The hardware/checkpoint-independent slice: every strategy samples the SAME
reduced MMDiT from the same noise; fidelity is measured against the
full-attention (dense) oracle — PSNR / relative-L2 (stand-ins for the
paper's PSNR/LPIPS/SSIM columns) — alongside realized mean density and the
attention-work reduction (the TOPS/Sparsity columns)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import psnr, time_fn
from benchmarks.strategies import strategy_configs
from repro.configs.registry import get_smoke
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit


def run(csv: list, *, steps: int = 10, nv: int = 96):
    cfg = get_smoke("flux-mmdit")
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(42)
    x0 = jax.random.normal(key, (1, nv, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    scfg = SamplerConfig(num_steps=steps)

    ecfg0 = strategy_configs()["FlashOmni"]
    dense = sample(params, cfg, ecfg0, text_emb=text, x0=x0, scfg=scfg,
                   force_dense=True)

    for name, ecfg in strategy_configs().items():
        # Each baseline is a registry-named symbol producer behind the
        # same engine (ecfg.strategy), not a threshold simulation.
        trace: list = []
        out = sample(params, cfg, ecfg, text_emb=text, x0=x0, scfg=scfg,
                     trace=trace)
        dens = [t["density"] for t in trace if t["kind"] == "dispatch"]
        pair_s = [t["pair_sparsity"] for t in trace if t["kind"] == "dispatch"]
        mean_density = float(np.mean(dens)) if dens else 1.0
        n_disp = len(dens)
        # paper Sparsity metric = skipped pairs / total, run-averaged
        # (update steps are full attention)
        sparsity = n_disp * float(np.mean(pair_s)) / steps if pair_s else 0.0
        rel = float(jnp.linalg.norm(out - dense) / jnp.linalg.norm(dense))
        csv.append({
            "name": f"table12_{name}",
            "us_per_call": 0.0,
            "derived": (f"strategy={ecfg.strategy}"
                        f" psnr={psnr(out, dense):.2f} rel_l2={rel:.4f}"
                        f" sparsity={sparsity:.3f} density={mean_density:.3f}"),
        })
