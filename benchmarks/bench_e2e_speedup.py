"""Paper Fig. 1: end-to-end acceleration on the HunyuanVideo-family arch.

Wall-clock of the full sampling loop, dense vs FlashOmni, on the reduced
config (CPU) + the attention/GEMM work accounting that scales to the 33K
production cell (where the paper reports ~1.5× at 46% sparsity)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.strategies import strategy_configs
from repro.configs.registry import get_smoke
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit


def run(csv: list, *, steps: int = 10, nv: int = 992):
    cfg = get_smoke("hunyuan-video-dit")
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    x0 = jax.random.normal(key, (1, nv, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    scfg = SamplerConfig(num_steps=steps)
    ecfg = strategy_configs()["FlashOmni-aggressive"]

    # warm both paths, then time
    for force in [True, False]:
        sample(params, cfg, ecfg, text_emb=text, x0=x0,
               scfg=SamplerConfig(num_steps=2), force_dense=force)
    t0 = time.perf_counter()
    dense = sample(params, cfg, ecfg, text_emb=text, x0=x0, scfg=scfg,
                   force_dense=True)
    t_dense = time.perf_counter() - t0
    trace: list = []
    t0 = time.perf_counter()
    out = sample(params, cfg, ecfg, text_emb=text, x0=x0, scfg=scfg, trace=trace)
    t_sparse = time.perf_counter() - t0

    dens = [t["density"] for t in trace if t["kind"] == "dispatch"]
    mean_density = float(np.mean(dens)) if dens else 1.0
    n_disp = len(dens)
    sparsity = n_disp * (1 - mean_density) / steps
    rel = float(jnp.linalg.norm(out - dense) / jnp.linalg.norm(dense))

    # Structural FLOP speedup (TPU-faithful; CPU wall-clock at this scale
    # is dominated by gather/scatter overheads the Pallas index maps avoid)
    from benchmarks.common import flops_of
    t_arr = jnp.full((1,), 0.5, jnp.float32)
    xe = (x0 @ jax.random.normal(jax.random.PRNGKey(7),
                                 (cfg.patch_dim, cfg.d_model)) * 0.2)
    states = dit.init_engine_states(cfg, ecfg, 1, nv + cfg.n_text_tokens)
    f = {}
    for mode in ["dense", "update", "dispatch"]:
        f[mode] = flops_of(
            lambda p, s, xv, te, t: dit.denoise_step(
                p, cfg, ecfg, s, xv, te, t, mode=mode, dtype=jnp.float32),
            params, states, xe, text, t_arr)
    n_upd = steps - n_disp
    f_sparse = n_upd * f["update"] + n_disp * f["dispatch"]
    f_speedup = steps * f["dense"] / f_sparse
    csv.append({
        "name": "fig1_hunyuan_e2e",
        "us_per_call": t_sparse / steps * 1e6,
        "derived": (f"e2e_speedup_flops={f_speedup:.2f}"
                    f" e2e_speedup_time_cpu={t_dense / t_sparse:.2f}"
                    f" sparsity={sparsity:.3f} rel_l2={rel:.4f}"
                    f" dispatch_vs_dense_flops={f['dense'] / f['dispatch']:.2f}"),
    })
