"""Paper Fig. 8 + Appendix A.1.2: GEMM-O aggregate speedup across the cache
interval 𝒩 at 17K-token scale (scaled down for CPU), against the paper's
analytical model  speedup = 𝒩 / (1 + (𝒩−1)(1−s)).

One Update (full GEMM + stage-1 bias build) amortizes over 𝒩−1 Dispatches
(sparse GEMM); we time the actual window and compare with theory — the
paper reports 93.1% / 87.7% / 84.7% of theory for 𝒩 = 4 / 6 / 8 on A100.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import GEMM_O_THEORY, time_fn
from repro.core.sparse_gemm import gemm_o_sparse, gemm_o_update_bias


def run(csv: list, *, n=2048, d=512, f=512, h=8, block=128, s=0.9):
    t = n // block
    dh = d // h
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 5)
    oh = jax.random.normal(ks[0], (1, n, h, dh), jnp.float32)
    wh = jax.random.normal(ks[1], (h, dh, f), jnp.float32)
    keep_rows = max(1, round(t * (1 - s)))
    m_ch = jnp.zeros((1, t, h), bool).at[:, :keep_rows, :].set(True)

    dense = jax.jit(lambda o, w: jnp.einsum("bnhd,hdf->bnf", o, w))
    upd_bias = jax.jit(lambda o, w, m: gemm_o_update_bias(o, w, m, block=block))
    disp = jax.jit(lambda o, w, m, b: gemm_o_sparse(o, w, m, b, block=block,
                                                    cap=keep_rows))
    bias = upd_bias(oh, wh, m_ch)
    t_dense = time_fn(dense, oh, wh)
    t_upd = time_fn(dense, oh, wh) + time_fn(upd_bias, oh, wh, m_ch)
    t_disp = time_fn(disp, oh, wh, m_ch, bias)

    # Structural FLOP accounting (the TPU-faithful metric: on the MXU the
    # sparse GEMM's cost IS its FLOPs; the CPU wall-clock below is dominated
    # by gather/scatter overheads that the TPU kernel's index maps avoid).
    from benchmarks.common import flops_of
    f_dense = flops_of(lambda o, w: jnp.einsum("bnhd,hdf->bnf", o, w), oh, wh)
    f_disp = flops_of(lambda o, w, m, b: gemm_o_sparse(o, w, m, b, block=block,
                                                       cap=keep_rows),
                      oh, wh, m_ch, bias)
    f_upd = f_dense + flops_of(
        lambda o, w, m: gemm_o_update_bias(o, w, m, block=block), oh, wh, m_ch)

    for interval in [4, 6, 8]:
        t_window = t_upd + (interval - 1) * t_disp
        t_base = interval * t_dense
        speedup = t_base / t_window
        f_window = f_upd + (interval - 1) * f_disp
        f_speedup = interval * f_dense / f_window
        theory = GEMM_O_THEORY(interval, s)
        csv.append({
            "name": f"fig8_gemm_o_N{interval}",
            "us_per_call": t_window / interval * 1e6,
            "derived": (f"s={s} speedup_flops={f_speedup:.2f}"
                        f" speedup_time_cpu={speedup:.2f} theory={theory:.2f}"
                        f" pct_of_theory={100 * f_speedup / theory:.1f}%"),
        })
