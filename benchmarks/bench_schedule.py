"""Single-scan sampler vs the legacy three-jit Python step loop (ISSUE 3).

Before the scan-native SparsitySchedule, ``pipeline.sample`` was a Python
loop dispatching per step into one of THREE separately-jitted
``denoise_step`` instantiations (dense / update / dispatch).  Now the
whole denoise loop is one ``lax.scan`` whose body ``lax.switch``es on the
schedule's traced mode array.  This benchmark measures both ends:

  * cold-start: wall-clock of the first full run (compile + execute) —
    the scan pays ONE compile, the legacy loop pays one per mode;
  * steady-state µs/step over repeated runs (same executables);
  * the executable count witness (1 vs 2).

``make bench-schedule`` runs exactly this table.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig, is_update_step
from repro.core.masks import MaskConfig
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit


def _legacy_sample(params, cfg, ecfg, *, text_emb, x0, num_steps,
                   patch_embed, jits=None):
    """The pre-ISSUE-3 sampler: Python step loop over per-mode jits."""
    b = x0.shape[0]
    n_tokens = x0.shape[1] + text_emb.shape[1]
    states = dit.init_engine_states(cfg, ecfg, b, n_tokens)
    if jits is None:
        jits = {m: jax.jit(lambda p, s, xv, te, t, m=m: dit.denoise_step(
            p, cfg, ecfg, s, xv, te, t, mode=m, dtype=jnp.float32))
            for m in ("update", "dispatch")}
    x = x0
    dt = 1.0 / num_steps
    for i in range(num_steps):
        t = jnp.full((b,), i * dt, jnp.float32)
        xe = (x @ patch_embed).astype(jnp.float32)
        mode = "update" if is_update_step(i, ecfg) else "dispatch"
        v, states = jits[mode](params, states, xe, text_emb, t)
        x = x + v.astype(x.dtype) * dt
    return x, jits


def run(csv: list, *, steps: int = 12, nv: int = 96, smoke: bool = False):
    if smoke:
        steps = 8
    cfg = get_smoke("flux-mmdit")
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(33)
    x0 = jax.random.normal(key, (1, nv, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    patch_embed = jax.random.normal(jax.random.PRNGKey(7),
                                    (cfg.patch_dim, cfg.d_model)) * 0.2
    ecfg = EngineConfig(
        mask=MaskConfig(tau_q=0.5, tau_kv=0.15, interval=4, order=1,
                        degrade=0.0, block_q=16, block_kv=16, pool=16,
                        warmup_steps=2),
        cache_dtype=jnp.float32, cap_q_frac=1.0, cap_kv_frac=1.0)
    scfg = SamplerConfig(num_steps=steps)

    # --- cold start (fresh executables) ---
    jax.clear_caches()
    t0 = time.perf_counter()
    stats: dict = {}
    out_scan = jax.block_until_ready(sample(
        params, cfg, ecfg, text_emb=text, x0=x0, scfg=scfg,
        patch_embed=patch_embed, stats=stats))
    cold_scan = time.perf_counter() - t0

    jax.clear_caches()
    t0 = time.perf_counter()
    out_legacy, jits = _legacy_sample(params, cfg, ecfg, text_emb=text,
                                      x0=x0, num_steps=steps,
                                      patch_embed=patch_embed)
    jax.block_until_ready(out_legacy)
    cold_legacy = time.perf_counter() - t0

    rel = float(jnp.linalg.norm(out_scan - out_legacy)
                / jnp.linalg.norm(out_legacy))

    # --- steady state (executables warm) ---
    t_scan = time_fn(lambda: sample(params, cfg, ecfg, text_emb=text, x0=x0,
                                    scfg=scfg, patch_embed=patch_embed),
                     iters=5 if smoke else 9)
    t_legacy = time_fn(lambda: _legacy_sample(params, cfg, ecfg,
                                              text_emb=text, x0=x0,
                                              num_steps=steps,
                                              patch_embed=patch_embed,
                                              jits=jits)[0],
                       iters=5 if smoke else 9)

    csv.append({
        "name": f"schedule_scan_sample/steps{steps}",
        "us_per_call": t_scan / steps * 1e6,
        "derived": (f"cold_start_s={cold_scan:.2f}"
                    f" executables={stats['executables']}"
                    f" rel_l2_vs_legacy={rel:.2e}"),
    })
    csv.append({
        "name": f"schedule_legacy_three_jit/steps{steps}",
        "us_per_call": t_legacy / steps * 1e6,
        "derived": (f"cold_start_s={cold_legacy:.2f}"
                    f" executables={len(jits)}"
                    f" compile_speedup={cold_legacy / max(cold_scan, 1e-9):.2f}"
                    f" step_speedup={t_legacy / max(t_scan, 1e-9):.2f}"),
    })
