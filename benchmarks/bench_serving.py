"""Serving-queue benchmark (ISSUE 4): sequential vs stacked vs continuous.

Workload: a request stream mixing FOUR distinct sampling configurations
(different step counts × different SparsitySchedules — the heterogeneous
traffic the paper's deployment scenario implies).  Three servers drain
the same stream:

  * ``sequential`` — one ``pipeline.sample`` per request (LRU-cached
    samplers; every DISTINCT configuration pays its own compile);
  * ``stacked``    — same-shape/same-schedule requests stack on the batch
    axis into one cached sampler call per group;
  * ``continuous`` — fixed-width lane microbatch; mixed-length schedules
    interleave as traced tables through ONE tick executable.

Each mode reports a COLD row (fresh executables — the "first traffic"
serving reality where the schedule mix decides how many compiles you pay)
and a WARM row (steady state).  Cold is where continuous batching wins:
a fixed ≤ 4 executable budget covers every schedule variant, so req/s
beats sequential (~2× at four configs) — asserted, together with per-lane
BIT parity of every stacked/continuous output against the sequential
oracle (the ISSUE 4 acceptance criteria).

A second, HOMOGENEOUS-schedule workload (every request the same 8-step
schedule — lockstep lanes) measures same-mode lane folding (ISSUE 5):
mode-homogeneous ticks fold the lanes into the model batch axis through
the batched mode-group bodies, so continuous warm req/s must land within
10% of ``stacked`` (asserted) instead of trailing it behind the old
lane-serial scan — while the heterogeneous mix keeps its win over
``sequential`` through the scan fallback.

``make bench-serving`` runs exactly this table.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.diffusion.pipeline as pipeline
from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig
from repro.core.lru import LruCache
from repro.core.masks import MaskConfig
from repro.launch.batching import (ContinuousBatcher, Request,
                                   run_sequential, run_stacked)
from repro.models import dit


def _requests(cfg, n_requests: int, specs):
    reqs = []
    for i in range(n_requests):
        steps, schedule = specs[i % len(specs)]
        kx, kt = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(100), i))
        reqs.append(Request(
            rid=i,
            x0=jax.random.normal(kx, (1, 64, cfg.patch_dim)),
            text_emb=jax.random.normal(
                kt, (1, cfg.n_text_tokens, cfg.d_model)),
            num_steps=steps, schedule=schedule))
    return reqs


def _fresh_executables():
    jax.clear_caches()
    pipeline._SAMPLER_CACHE = LruCache(pipeline._SAMPLER_CACHE_SIZE)


def _lat(results, reqs, pct):
    return float(np.percentile([results[r.rid]["latency"] for r in reqs],
                               pct))


def _parity(results, oracle, reqs) -> bool:
    return all(bool((results[r.rid]["out"] == oracle[r.rid]["out"]).all())
               for r in reqs)


def run(csv: list, *, smoke: bool = False):
    n_requests = 8 if smoke else 12
    specs = [(8, None), (6, "step-ramp"), (7, "hunyuan-1.5x"), (5, None)]
    if smoke:
        specs = specs[:3]
    cfg = get_smoke("flux-mmdit")
    ecfg = EngineConfig(mask=MaskConfig(
        tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.0,
        block_q=16, block_kv=16, pool=16, warmup_steps=2),
        cache_dtype=jnp.float32, cap_q_frac=1.0, cap_kv_frac=1.0)
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _requests(cfg, n_requests, specs)
    max_steps = max(s for s, _ in specs)

    modes = {}

    def bench(label, runner):
        _fresh_executables()
        t0 = time.perf_counter()
        cold_res = runner()
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm_res = runner()
        warm = time.perf_counter() - t0
        modes[label] = dict(cold=cold, warm=warm, cold_res=cold_res,
                            warm_res=warm_res)

    batcher = ContinuousBatcher(params, cfg, ecfg, lanes=4,
                                max_steps=max_steps)

    def continuous_run():
        batcher.submit_all(reqs)
        return batcher.run()

    bench("sequential", lambda: run_sequential(
        params, cfg, ecfg, reqs, collect_traces=False))
    bench("stacked", lambda: run_stacked(params, cfg, ecfg, reqs))
    bench("continuous", continuous_run)

    oracle = modes["sequential"]["warm_res"]
    seq_cold = modes["sequential"]["cold"]
    for label, m in modes.items():
        parity = _parity(m["cold_res"], oracle, reqs)
        derived = (f"req_s={n_requests / m['cold']:.2f}"
                   f" warm_req_s={n_requests / m['warm']:.2f}"
                   f" p50_s={_lat(m['cold_res'], reqs, 50):.2f}"
                   f" p95_s={_lat(m['cold_res'], reqs, 95):.2f}"
                   f" configs={len(specs)}"
                   f" bit_parity={parity}")
        if label == "continuous":
            derived += (f" executables={batcher.stats['executables']}"
                        f" ticks={batcher.stats['ticks']}"
                        f" speedup_vs_sequential="
                        f"{seq_cold / m['cold']:.2f}")
        csv.append({"name": f"serving_{label}/req{n_requests}",
                    "us_per_call": m["cold"] / n_requests * 1e6,
                    "derived": derived})
        # ISSUE 4 acceptance: every mode serves bit-identical per-lane
        # outputs; a silent numeric divergence must fail the benchmark.
        assert parity, f"{label} outputs diverged from the sequential oracle"
    # grouped="auto" keeps the non-lockstep heterogeneous mix on the
    # lane-scan path: still EXACTLY one executable, however lanes churn.
    assert batcher.stats["executables"] == 1, batcher.stats["executables"]
    assert modes["continuous"]["cold"] < seq_cold, (
        "continuous batching should beat sequential serving on a "
        f"heterogeneous schedule mix: {modes['continuous']['cold']:.2f}s "
        f"vs {seq_cold:.2f}s")

    # --- Homogeneous-schedule mix (ISSUE 5: same-mode lane folding). ---
    # Every request runs the SAME schedule, so resident lanes advance in
    # lockstep and every tick is mode-homogeneous: the batcher folds the
    # lanes into the model batch axis (grouped tick bodies) instead of
    # scanning them serially.  Metrics are off on both sides (stacked
    # collects none) for an apples-to-apples throughput comparison.
    h_steps = 8
    h_reqs = _requests(cfg, n_requests, [(h_steps, None)])
    h_lanes = min(n_requests, 8)
    h_batcher = ContinuousBatcher(params, cfg, ecfg, lanes=h_lanes,
                                  max_steps=h_steps, with_metrics=False,
                                  sync_every_tick=False)

    def h_continuous():
        h_batcher.submit_all(h_reqs)
        return h_batcher.run()

    h_modes = {}

    def h_bench(label, runner):
        _fresh_executables()
        t0 = time.perf_counter()
        cold_res = runner()
        cold = time.perf_counter() - t0
        # BEST of 3 warm reps: the 10%-of-stacked criterion is a tight
        # margin at smoke scale, and single-rep wall times on a shared
        # CPU host are noisy in both directions.
        warm = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            warm_res = runner()
            warm = min(warm, time.perf_counter() - t0)
        h_modes[label] = dict(cold=cold, warm=warm, cold_res=cold_res,
                              warm_res=warm_res)

    h_bench("stacked", lambda: run_stacked(params, cfg, ecfg, h_reqs))
    h_bench("continuous", h_continuous)
    h_parity = all(
        bool((h_modes["continuous"]["warm_res"][r.rid]["out"]
              == h_modes["stacked"]["warm_res"][r.rid]["out"]).all())
        for r in h_reqs)
    stk_rps = n_requests / h_modes["stacked"]["warm"]
    cont_rps = n_requests / h_modes["continuous"]["warm"]
    for label, m in h_modes.items():
        derived = (f"req_s={n_requests / m['cold']:.2f}"
                   f" warm_req_s={n_requests / m['warm']:.2f}"
                   f" configs=1 bit_parity={h_parity}")
        if label == "continuous":
            derived += (
                f" executables={h_batcher.stats['executables']}"
                f" grouped_ticks={h_batcher.stats['grouped_ticks']}"
                f" scan_ticks={h_batcher.stats['scan_ticks']}"
                f" warm_frac_of_stacked={cont_rps / stk_rps:.2f}")
        csv.append({"name": f"serving_homogeneous_{label}/req{n_requests}",
                    "us_per_call": m["cold"] / n_requests * 1e6,
                    "derived": derived})
    assert h_parity, "homogeneous continuous outputs diverged from stacked"
    assert h_batcher.stats["scan_ticks"] == 0, h_batcher.stats
    assert h_batcher.stats["executables"] <= 4, h_batcher.stats
    # ISSUE 5 acceptance: same-mode lane folding recovers stacked-level
    # warm throughput on a homogeneous-schedule mix (within 10%).
    assert cont_rps >= 0.9 * stk_rps, (
        "homogeneous continuous warm req/s trails stacked by >10%: "
        f"{cont_rps:.2f} vs {stk_rps:.2f}")
