"""Serving-queue benchmark (ISSUE 4): sequential vs stacked vs continuous.

Workload: a request stream mixing FOUR distinct sampling configurations
(different step counts × different SparsitySchedules — the heterogeneous
traffic the paper's deployment scenario implies).  Three servers drain
the same stream:

  * ``sequential`` — one ``pipeline.sample`` per request (LRU-cached
    samplers; every DISTINCT configuration pays its own compile);
  * ``stacked``    — same-shape/same-schedule requests stack on the batch
    axis into one cached sampler call per group;
  * ``continuous`` — fixed-width lane microbatch; mixed-length schedules
    interleave as traced tables through ONE tick executable.

Each mode reports a COLD row (fresh executables — the "first traffic"
serving reality where the schedule mix decides how many compiles you pay)
and a WARM row (steady state).  Cold is where continuous batching wins:
one executable covers every schedule variant, so req/s beats sequential
(~2× at four configs) — asserted, together with per-lane BIT parity of
every stacked/continuous output against the sequential oracle (the ISSUE
acceptance criteria).  Warm steady-state favours stacking (pure batch
parallelism); the continuous lane scan trades some smoke-scale warm
throughput for schedule generality and per-request latency.

``make bench-serving`` runs exactly this table.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.diffusion.pipeline as pipeline
from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig
from repro.core.lru import LruCache
from repro.core.masks import MaskConfig
from repro.launch.batching import (ContinuousBatcher, Request,
                                   run_sequential, run_stacked)
from repro.models import dit


def _requests(cfg, n_requests: int, specs):
    reqs = []
    for i in range(n_requests):
        steps, schedule = specs[i % len(specs)]
        kx, kt = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(100), i))
        reqs.append(Request(
            rid=i,
            x0=jax.random.normal(kx, (1, 64, cfg.patch_dim)),
            text_emb=jax.random.normal(
                kt, (1, cfg.n_text_tokens, cfg.d_model)),
            num_steps=steps, schedule=schedule))
    return reqs


def _fresh_executables():
    jax.clear_caches()
    pipeline._SAMPLER_CACHE = LruCache(pipeline._SAMPLER_CACHE_SIZE)


def _lat(results, reqs, pct):
    return float(np.percentile([results[r.rid]["latency"] for r in reqs],
                               pct))


def _parity(results, oracle, reqs) -> bool:
    return all(bool((results[r.rid]["out"] == oracle[r.rid]["out"]).all())
               for r in reqs)


def run(csv: list, *, smoke: bool = False):
    n_requests = 8 if smoke else 12
    specs = [(8, None), (6, "step-ramp"), (7, "hunyuan-1.5x"), (5, None)]
    if smoke:
        specs = specs[:3]
    cfg = get_smoke("flux-mmdit")
    ecfg = EngineConfig(mask=MaskConfig(
        tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.0,
        block_q=16, block_kv=16, pool=16, warmup_steps=2),
        cache_dtype=jnp.float32, cap_q_frac=1.0, cap_kv_frac=1.0)
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _requests(cfg, n_requests, specs)
    max_steps = max(s for s, _ in specs)

    modes = {}

    def bench(label, runner):
        _fresh_executables()
        t0 = time.perf_counter()
        cold_res = runner()
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm_res = runner()
        warm = time.perf_counter() - t0
        modes[label] = dict(cold=cold, warm=warm, cold_res=cold_res,
                            warm_res=warm_res)

    batcher = ContinuousBatcher(params, cfg, ecfg, lanes=4,
                                max_steps=max_steps)

    def continuous_run():
        batcher.submit_all(reqs)
        return batcher.run()

    bench("sequential", lambda: run_sequential(
        params, cfg, ecfg, reqs, collect_traces=False))
    bench("stacked", lambda: run_stacked(params, cfg, ecfg, reqs))
    bench("continuous", continuous_run)

    oracle = modes["sequential"]["warm_res"]
    seq_cold = modes["sequential"]["cold"]
    for label, m in modes.items():
        parity = _parity(m["cold_res"], oracle, reqs)
        derived = (f"req_s={n_requests / m['cold']:.2f}"
                   f" warm_req_s={n_requests / m['warm']:.2f}"
                   f" p50_s={_lat(m['cold_res'], reqs, 50):.2f}"
                   f" p95_s={_lat(m['cold_res'], reqs, 95):.2f}"
                   f" configs={len(specs)}"
                   f" bit_parity={parity}")
        if label == "continuous":
            derived += (f" executables={batcher.stats['executables']}"
                        f" ticks={batcher.stats['ticks']}"
                        f" speedup_vs_sequential="
                        f"{seq_cold / m['cold']:.2f}")
        csv.append({"name": f"serving_{label}/req{n_requests}",
                    "us_per_call": m["cold"] / n_requests * 1e6,
                    "derived": derived})
        # ISSUE 4 acceptance: every mode serves bit-identical per-lane
        # outputs; a silent numeric divergence must fail the benchmark.
        assert parity, f"{label} outputs diverged from the sequential oracle"
    assert batcher.stats["executables"] == 1, batcher.stats["executables"]
    assert modes["continuous"]["cold"] < seq_cold, (
        "continuous batching should beat sequential serving on a "
        f"heterogeneous schedule mix: {modes['continuous']['cold']:.2f}s "
        f"vs {seq_cold:.2f}s")
