"""Paper Fig. 6 (left) / Fig. 11: GEMM-Q and GEMM-O speedup vs sparsity.

GEMM-Q sparsity lives on the spatial axis (skip cached row blocks);
GEMM-O on the reduction axis (cached heads arrive via the bias).  Measured
on the structural XLA paths; theory = 1/(1−s) for GEMM-Q and for a single
GEMM-O invocation.

Each point carries a PLAN-LEVEL companion row (``*_plan_*``): the same
GEMM over precomputed DispatchPlan indices (``gemm_q_from_plan`` /
``gemm_o_from_plan`` — what a Dispatch step actually traces), so
kernel-vs-XLA comparisons are apples-to-apples with the engine's
compile-once path.  On real TPUs a ``*_kernel_*`` row times the Pallas
kernel over the same indices (interpret mode timings are meaningless, so
the row is skipped off-TPU).

Roofline accounting (ISSUE 8): every density point reports the fraction
of MXU peak (``benchmarks.roofline.PEAK_FLOPS``) and of HBM bandwidth the
LIVE work realises, plus the kernel GRID-SLOT count — uniform
``Cr·Hc`` reduction slots vs the occupancy-bucketed layout
(``bucket_grid_slots``) for GEMM-O.  The ``*_skewed`` rows exercise the
bucketed kernel on a skewed live-head plan (one all-heads row among
single-head rows), ASSERT the ≥2× grid-slot cut and bit-identity to the
uniform kernel, and are consumed by the CI regression gate from the
``--smoke --json`` artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import (check_flops_agreement, flops_of,
                               static_flops_of, time_fn)
from benchmarks.roofline import HBM_BW, PEAK_FLOPS
from repro.core.plan import bucket_geometry, bucket_grid_slots
from repro.core.sparse_gemm import (gemm_o_from_plan, gemm_o_sparse,
                                    gemm_q_from_plan, gemm_q_sparse)
from repro.core.symbols import active_indices


def _bucketed_skewed(csv, *, n=512, d=512, f=512, h=8, block=64,
                     hc_buckets=3):
    """Fig. 11 bucketed GEMM-O rows: skewed live-head occupancy.

    One row block keeps all ``h`` heads live, every other live row keeps
    exactly one — the per-head sparsity shape behind the paper's GEMM-O
    2.5–3.8×.  The uniform grid pays ``Hc = h`` reduction slots for every
    row; the bucketed layout gives the 1-head rows 1–2-deep slots.  The
    all-heads row fits the widest bucket, so no head list truncates and
    the two kernels must agree BIT-for-bit (interpret mode — identical
    accumulation order).  CI gates on the emitted ``grid_slot_cut`` /
    ``bit_identical_to_uniform`` keys.
    """
    from repro.kernels import ops

    t = n // block
    dh = d // h
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    oh = jax.random.normal(ks[0], (h, n, dh), jnp.float32)
    wh = jax.random.normal(ks[1], (h, dh, f), jnp.float32)
    bias = jax.random.normal(ks[2], (n, f), jnp.float32)

    m_ch = jnp.zeros((t, h), bool)
    m_ch = m_ch.at[0, :].set(True)                     # one all-heads row
    m_ch = m_ch.at[jnp.arange(1, t), jnp.arange(1, t) % h].set(True)

    geometry = bucket_geometry(t, h, 1, hc_buckets)
    slots_uniform = t * h
    slots_bucketed = bucket_grid_slots(geometry)
    # ISSUE 8 acceptance: the bucketed layout cuts GEMM-O grid slots >= 2x
    # on the skewed row (static: equal-area buckets give B/(2^B - 1)).
    assert slots_bucketed * 2 <= slots_uniform, (slots_bucketed, slots_uniform)

    uni = functools.partial(ops.gemm_o, block_rows=block, interpret=True)
    bkt = functools.partial(ops.gemm_o, block_rows=block, interpret=True,
                            hc_buckets=hc_buckets)
    out_uni = uni(oh, wh, bias, m_ch)
    out_bkt = bkt(oh, wh, bias, m_ch)
    bit_identical = bool(jnp.all(out_uni == out_bkt))
    assert bit_identical, float(jnp.max(jnp.abs(out_uni - out_bkt)))
    t_uni = time_fn(uni, oh, wh, bias, m_ch, iters=3, warmup=1)
    t_bkt = time_fn(bkt, oh, wh, bias, m_ch, iters=3, warmup=1)

    # Live work: one (block x dh) @ (dh x f) MAC tile per live (row, head).
    pairs = float(jnp.sum(m_ch))
    f_live = 2.0 * pairs * block * dh * f
    bytes_live = 4.0 * (pairs * block * dh + h * dh * f + 2 * t * block * f)
    geo = "/".join(f"{r}x{w}" for r, w in geometry)
    csv.append({
        "name": "fig11_gemm_o_uniform_skewed",
        "us_per_call": t_uni * 1e6,
        "derived": (f"grid_slots={slots_uniform}"
                    f" frac_peak={f_live / t_uni / PEAK_FLOPS:.2e}"
                    f" frac_hbm={bytes_live / t_uni / HBM_BW:.2e}"),
    })
    csv.append({
        "name": "fig11_gemm_o_bucketed_skewed",
        "us_per_call": t_bkt * 1e6,
        "derived": (f"grid_slots={slots_bucketed}"
                    f" grid_slot_cut={slots_uniform / slots_bucketed:.2f}"
                    f" frac_peak={f_live / t_bkt / PEAK_FLOPS:.2e}"
                    f" frac_hbm={bytes_live / t_bkt / HBM_BW:.2e}"
                    f" geometry={geo}"
                    f" bit_identical_to_uniform={int(bit_identical)}"),
    })


def run(csv: list, *, n=4096, d=1024, f=1024, h=8, block=128, smoke=False):
    if smoke:
        n, d, f = 1024, 512, 512
    t = n // block
    on_tpu = jax.default_backend() == "tpu"
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (1, n, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, f), jnp.float32)

    dense_q = jax.jit(lambda x, w: jnp.einsum("bnd,df->bnf", x, w))
    t_dense = time_fn(dense_q, x, w)

    for s in [0.25, 0.5, 0.75]:
        keep = max(1, round(t * (1 - s)))
        mask = jnp.zeros((1, t), bool).at[:, :keep].set(True)
        gq = lambda x, w, m: gemm_q_sparse(x, w, m, block=block, cap=keep)
        fn = jax.jit(gq)
        t_s = time_fn(fn, x, w, mask)
        s_real = 1 - keep / t
        # Static-vs-XLA cross-check on the roofline row (ISSUE 10).
        sf = check_flops_agreement(
            f"fig6_gemm_q_s{s}", flops_of(gq, x, w, mask),
            static_flops_of(gq, x, w, mask))
        # Live-work roofline: the kernel grid launches exactly ``keep``
        # row-block slots (row_cnt guard skips padding on the MXU).
        f_live = 2.0 * keep * block * d * f
        b_live = 4.0 * (keep * block * d + d * f + keep * block * f)
        csv.append({"name": f"fig6_gemm_q_s{s}", "us_per_call": t_s * 1e6,
                    "derived": (f"sparsity={s_real:.3f}"
                                f" speedup_time={t_dense / t_s:.2f}"
                                f" grid_slots={keep}"
                                f" frac_peak={f_live / t_s / PEAK_FLOPS:.2e}"
                                f" frac_hbm={b_live / t_s / HBM_BW:.2e}"
                                f" static_flops={sf:.6g}"
                                f" theory={1 / max(1 - s_real, 1e-9):.2f}")})
        # Plan-level row: live-row indices precomputed once (Update time).
        ids, cnt = jax.jit(lambda m: active_indices(m, keep))(mask)
        plan_fn = jax.jit(lambda x, w, i, c: gemm_q_from_plan(
            x, w, i, c, block=block))
        t_p = time_fn(plan_fn, x, w, ids, cnt)
        csv.append({"name": f"fig6_gemm_q_plan_s{s}", "us_per_call": t_p * 1e6,
                    "derived": (f"sparsity={s_real:.3f}"
                                f" speedup_time={t_dense / t_p:.2f}"
                                f" index_decode_overhead_us="
                                f"{(t_s - t_p) * 1e6:.1f}")})
        if on_tpu:
            from repro.kernels.gemm_q import gemm_q_sparse_kernel
            kern = jax.jit(lambda x, w, i: gemm_q_sparse_kernel(
                x, w, i, block_rows=block))
            t_k = time_fn(kern, x, w, ids)
            csv.append({"name": f"fig6_gemm_q_kernel_s{s}",
                        "us_per_call": t_k * 1e6,
                        "derived": (f"sparsity={s_real:.3f}"
                                    f" speedup_time={t_dense / t_k:.2f}"
                                    f" vs_plan_xla={t_p / t_k:.2f}")})

    # GEMM-O: reduction-axis (head) sparsity + spatial sparsity of dead rows.
    dh = d // h
    oh = jax.random.normal(ks[2], (1, n, h, dh), jnp.float32)
    wh = jax.random.normal(ks[3], (h, dh, f), jnp.float32)
    bias = jax.random.normal(ks[4], (1, n, f), jnp.float32)
    dense_o = jax.jit(lambda o, w: jnp.einsum("bnhd,hdf->bnf", o, w))
    t_dense_o = time_fn(dense_o, oh, wh)
    for s in [0.25, 0.5, 0.75]:
        keep_rows = max(1, round(t * (1 - s)))
        m_ch = jnp.zeros((1, t, h), bool).at[:, :keep_rows, :].set(True)
        go = lambda o, w, m, b: gemm_o_sparse(o, w, m, b, block=block,
                                              cap=keep_rows)
        fn = jax.jit(go)
        t_s = time_fn(fn, oh, wh, m_ch, bias)
        s_real = 1 - keep_rows / t
        sf = check_flops_agreement(
            f"fig6_gemm_o_s{s}", flops_of(go, oh, wh, m_ch, bias),
            static_flops_of(go, oh, wh, m_ch, bias))
        # Grid-slot accounting (ISSUE 8): uniform GEMM-O pays Cr·Hc
        # reduction slots; the bucketed layout's static total at B = 3.
        slots_uniform = keep_rows * h
        slots_bucketed = bucket_grid_slots(
            bucket_geometry(keep_rows, h, 1, 3))
        f_live = 2.0 * keep_rows * block * d * f
        b_live = 4.0 * (keep_rows * block * d + d * f + 2 * n * f)
        csv.append({"name": f"fig6_gemm_o_s{s}", "us_per_call": t_s * 1e6,
                    "derived": (f"sparsity={s_real:.3f}"
                                f" speedup_time={t_dense_o / t_s:.2f}"
                                f" grid_slots_uniform={slots_uniform}"
                                f" grid_slots_bucketed={slots_bucketed}"
                                f" frac_peak={f_live / t_s / PEAK_FLOPS:.2e}"
                                f" frac_hbm={b_live / t_s / HBM_BW:.2e}"
                                f" static_flops={sf:.6g}"
                                f" theory={1 / max(1 - s_real, 1e-9):.2f}")})
        # Plan-level row: row/head lists precomputed once (Update time).
        ids, cnt = jax.jit(lambda m: active_indices(
            jnp.any(m, -1), keep_rows))(m_ch)
        head_mask = jnp.take_along_axis(m_ch, ids[..., None], axis=-2)
        plan_fn = jax.jit(lambda o, w, hm, i, c, b: gemm_o_from_plan(
            o, w, hm, i, c, b, block=block))
        t_p = time_fn(plan_fn, oh, wh, head_mask, ids, cnt, bias)
        csv.append({"name": f"fig6_gemm_o_plan_s{s}", "us_per_call": t_p * 1e6,
                    "derived": (f"sparsity={s_real:.3f}"
                                f" speedup_time={t_dense_o / t_p:.2f}"
                                f" index_decode_overhead_us="
                                f"{(t_s - t_p) * 1e6:.1f}")})
        if on_tpu:
            from repro.kernels.gemm_o import gemm_o_sparse_kernel
            head_ids, head_cnt = active_indices(head_mask, h)
            head_cnt = jnp.where(jnp.arange(keep_rows) < cnt[..., None],
                                 head_cnt, 0)
            kern = jax.jit(lambda o, w, b, i, hi, hc: gemm_o_sparse_kernel(
                o.transpose(0, 2, 1, 3), w, b, i, hi, hc, block_rows=block))
            t_k = time_fn(kern, oh, wh, bias, ids, head_ids, head_cnt)
            csv.append({"name": f"fig6_gemm_o_kernel_s{s}",
                        "us_per_call": t_k * 1e6,
                        "derived": (f"sparsity={s_real:.3f}"
                                    f" speedup_time={t_dense_o / t_k:.2f}"
                                    f" vs_plan_xla={t_p / t_k:.2f}")})
    csv.append({"name": "fig6_gemm_dense_baselines",
                "us_per_call": t_dense * 1e6,
                "derived": f"gemm_o_dense_us={t_dense_o * 1e6:.1f}"})
    _bucketed_skewed(csv)
