"""Paper Fig. 6 (left) / Fig. 11: GEMM-Q and GEMM-O speedup vs sparsity.

GEMM-Q sparsity lives on the spatial axis (skip cached row blocks);
GEMM-O on the reduction axis (cached heads arrive via the bias).  Measured
on the structural XLA paths; theory = 1/(1−s) for GEMM-Q and for a single
GEMM-O invocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import flops_of, time_fn
from repro.core.sparse_gemm import gemm_o_sparse, gemm_q_sparse


def run(csv: list, *, n=4096, d=1024, f=1024, h=8, block=128):
    t = n // block
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (1, n, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, f), jnp.float32)

    dense_q = jax.jit(lambda x, w: jnp.einsum("bnd,df->bnf", x, w))
    t_dense = time_fn(dense_q, x, w)

    for s in [0.25, 0.5, 0.75]:
        keep = max(1, round(t * (1 - s)))
        mask = jnp.zeros((1, t), bool).at[:, :keep].set(True)
        fn = jax.jit(lambda x, w, m: gemm_q_sparse(x, w, m, block=block, cap=keep))
        t_s = time_fn(fn, x, w, mask)
        s_real = 1 - keep / t
        csv.append({"name": f"fig6_gemm_q_s{s}", "us_per_call": t_s * 1e6,
                    "derived": (f"sparsity={s_real:.3f}"
                                f" speedup_time={t_dense / t_s:.2f}"
                                f" theory={1 / max(1 - s_real, 1e-9):.2f}")})

    # GEMM-O: reduction-axis (head) sparsity + spatial sparsity of dead rows.
    dh = d // h
    oh = jax.random.normal(ks[2], (1, n, h, dh), jnp.float32)
    wh = jax.random.normal(ks[3], (h, dh, f), jnp.float32)
    bias = jax.random.normal(ks[4], (1, n, f), jnp.float32)
    dense_o = jax.jit(lambda o, w: jnp.einsum("bnhd,hdf->bnf", o, w))
    t_dense_o = time_fn(dense_o, oh, wh)
    for s in [0.25, 0.5, 0.75]:
        keep_rows = max(1, round(t * (1 - s)))
        m_ch = jnp.zeros((1, t, h), bool).at[:, :keep_rows, :].set(True)
        fn = jax.jit(lambda o, w, m, b: gemm_o_sparse(o, w, m, b, block=block,
                                                      cap=keep_rows))
        t_s = time_fn(fn, oh, wh, m_ch, bias)
        s_real = 1 - keep_rows / t
        csv.append({"name": f"fig6_gemm_o_s{s}", "us_per_call": t_s * 1e6,
                    "derived": (f"sparsity={s_real:.3f}"
                                f" speedup_time={t_dense_o / t_s:.2f}"
                                f" theory={1 / max(1 - s_real, 1e-9):.2f}")})
    csv.append({"name": "fig6_gemm_dense_baselines",
                "us_per_call": t_dense * 1e6,
                "derived": f"gemm_o_dense_us={t_dense_o * 1e6:.1f}"})
