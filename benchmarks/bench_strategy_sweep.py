"""Strategy-registry + schedule-registry sweep: density / pair-sparsity /
fidelity per producer.

Runs EVERY registered :mod:`repro.core.strategy` entry — and every named
:mod:`repro.core.schedule` preset — through the same reduced MMDiT
sampling loop (one ``EngineConfig`` differing only in ``strategy`` /
``schedule``) and reports the paper's efficiency accounting per row: mean
dispatch density (Fig. 7), run-averaged pair sparsity (Table 1's Sparsity
column) and relative L2 vs the dense oracle.  Every row runs the
SINGLE-SCAN sampler (one compiled executable per config — asserted).
``make bench-strategies`` runs exactly this table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import psnr
from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig
from repro.core.masks import MaskConfig
from repro.core.schedule import available_schedules
from repro.core.strategy import available_strategies
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit


def run(csv: list, *, steps: int = 10, nv: int = 96, smoke: bool = False):
    cfg = get_smoke("flux-mmdit")
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(21)
    x0 = jax.random.normal(key, (1, nv, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    if smoke:
        steps = 6
    scfg = SamplerConfig(num_steps=steps)

    def ecfg(name, schedule=None):
        return EngineConfig(
            mask=MaskConfig(tau_q=0.5, tau_kv=0.15, interval=4, order=1,
                            degrade=0.0, block_q=16, block_kv=16, pool=16,
                            warmup_steps=2),
            strategy=name, schedule=schedule, cache_dtype=jnp.float32,
            cap_q_frac=1.0, cap_kv_frac=1.0)

    dense = sample(params, cfg, ecfg("flashomni"), text_emb=text, x0=x0,
                   scfg=scfg, force_dense=True)

    def row(label, config):
        trace: list = []
        stats: dict = {}
        out = sample(params, cfg, config, text_emb=text, x0=x0,
                     scfg=scfg, trace=trace, stats=stats)
        assert stats["executables"] in (1, -1), (label, stats)
        dens = [t["density"] for t in trace if t["kind"] == "dispatch"]
        pair_s = [t["pair_sparsity"] for t in trace if t["kind"] == "dispatch"]
        mean_density = float(np.mean(dens)) if dens else 1.0
        sparsity = (len(pair_s) * float(np.mean(pair_s)) / steps
                    if pair_s else 0.0)
        rel = float(jnp.linalg.norm(out - dense) / jnp.linalg.norm(dense))
        csv.append({
            "name": label,
            "us_per_call": 0.0,
            "derived": (f"density={mean_density:.3f} sparsity={sparsity:.3f}"
                        f" psnr={psnr(out, dense):.2f} rel_l2={rel:.4f}"),
        })

    for name in available_strategies():
        row(f"registry_{name}", ecfg(name))
    for name in available_schedules():
        row(f"schedule_{name}", ecfg("flashomni", schedule=name))
