"""Paper Fig. 9 (A.1.3): fidelity vs warmup-step count.  FlashOmni's claim:
it degrades gracefully at low warmup where cache-everything (TaylorSeer)
collapses."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import psnr
from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig
from repro.core.masks import MaskConfig
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit


def _ecfg(warmup, tau_q):
    return EngineConfig(mask=MaskConfig(
        tau_q=tau_q, tau_kv=0.1, interval=4, order=1, degrade=0.0,
        block_q=16, block_kv=16, pool=32, warmup_steps=warmup),
        cache_dtype=jnp.float32)


def run(csv: list, *, steps: int = 12, nv: int = 96):
    cfg = get_smoke("flux-mmdit")
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(13)
    x0 = jax.random.normal(key, (1, nv, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    scfg = SamplerConfig(num_steps=steps)
    dense = sample(params, cfg, _ecfg(2, 0.5), text_emb=text, x0=x0, scfg=scfg,
                   force_dense=True)
    for warmup in [1, 2, 3, 4]:
        for name, tq in [("flashomni", 0.5), ("taylorseer", 1.0)]:
            out = sample(params, cfg, _ecfg(warmup, tq), text_emb=text, x0=x0,
                         scfg=scfg)
            csv.append({"name": f"fig9_warmup{warmup}_{name}",
                        "us_per_call": 0.0,
                        "derived": f"psnr={psnr(out, dense):.2f}"})
