"""Benchmark utilities: wall-clock timing of jitted callables + FLOP
accounting helpers shared across the paper-figure benchmarks."""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["time_fn", "psnr", "flops_of", "GEMM_O_THEORY"]


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds of a jitted fn (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def psnr(a, b) -> float:
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    mse = float(np.mean((a - b) ** 2))
    rng = float(np.max(np.abs(b))) or 1.0
    return 10 * np.log10(rng * rng / max(mse, 1e-12))


def flops_of(fn, *args) -> float:
    """Per-device HLO FLOPs of a jitted callable (cost analysis)."""
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, (list, tuple)):   # older jax: one dict per device
        c = c[0] if c else {}
    return float(c.get("flops", 0.0))


def GEMM_O_THEORY(n_interval: int, s: float) -> float:
    """Paper A.1.2: window speedup = 𝒩 / (1 + (𝒩−1)(1−s))."""
    return n_interval / (1.0 + (n_interval - 1) * (1.0 - s))
