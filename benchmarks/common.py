"""Benchmark utilities: wall-clock timing of jitted callables + FLOP
accounting helpers shared across the paper-figure benchmarks."""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["time_fn", "psnr", "flops_of", "static_flops_of",
           "check_flops_agreement", "GEMM_O_THEORY"]


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds of a jitted fn (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def psnr(a, b) -> float:
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    mse = float(np.mean((a - b) ** 2))
    rng = float(np.max(np.abs(b))) or 1.0
    return 10 * np.log10(rng * rng / max(mse, 1e-12))


def flops_of(fn, *args) -> float:
    """Per-device HLO FLOPs of a jitted callable (cost analysis)."""
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, (list, tuple)):   # older jax: one dict per device
        c = c[0] if c else {}
    return float(c.get("flops", 0.0))


def static_flops_of(fn, *args) -> float:
    """FLOPs of ``fn`` from the STATIC cost model — no compilation.

    Counts the traced jaxpr with
    :func:`repro.analysis.cost_model.cost_of_jaxpr` (the interpreter the
    invariant analyzer certifies), giving an XLA-independent second
    opinion on :func:`flops_of` for the roofline rows.
    """
    from repro.analysis.cost_model import cost_of_jaxpr
    return float(cost_of_jaxpr(jax.make_jaxpr(fn)(*args)).flops)


def check_flops_agreement(name: str, measured: float, static: float,
                          rtol: float = 0.15) -> float:
    """Assert the XLA ``cost_analysis()`` FLOPs and the static model agree.

    Returns the static count so callers can record it in a derived row.
    XLA occasionally folds a handful of scalar ops the model counts (and
    vice versa for fused masking), so the tolerance is loose-ish; a real
    drift — a missing primitive handler or an op XLA started billing —
    lands far outside 15%.
    """
    if measured <= 0 or static <= 0:
        raise AssertionError(
            f"{name}: non-positive flops (measured={measured}, "
            f"static={static}) — one of the counters went vacuous")
    rel = abs(measured - static) / measured
    if rel > rtol:
        raise AssertionError(
            f"{name}: static cost model ({static:.3e}) disagrees with "
            f"XLA cost_analysis ({measured:.3e}) by {rel:.1%} (> {rtol:.0%})")
    return static


def GEMM_O_THEORY(n_interval: int, s: float) -> float:
    """Paper A.1.2: window speedup = 𝒩 / (1 + (𝒩−1)(1−s))."""
    return n_interval / (1.0 + (n_interval - 1) * (1.0 - s))
