"""Roofline analysis over the dry-run artifacts (task spec §Roofline).

    compute    = HLO_FLOPs_per_device / 197e12           [s]   (bf16 MXU)
    memory     = HLO_bytes_per_device / 819e9            [s]   (HBM)
    collective = collective_bytes_per_device / 50e9      [s]   (ICI, per link)

The SPMD module is per-device, so cost_analysis FLOPs/bytes and the parsed
collective operand bytes are already per-chip.  MODEL_FLOPS = 6·N·D for
dense training (N params, D tokens), 6·N_active·D for MoE, 2·N·D for
forward-only serving.  ``roofline_fraction`` = time the chip would need for
the pure model math / time the dominant term actually binds — the §Perf
score.

Usage:  PYTHONPATH=src:. python -m benchmarks.roofline [--dir artifacts/dryrun]
writes artifacts/roofline.md + returns rows for EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link

COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")


def model_flops_per_device(rec: dict) -> float:
    n_act = rec["n_active_params"]
    chips = rec["n_devices"]
    if rec["entry"] == "train_step":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n_act * tokens / chips
    if rec["entry"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n_act * tokens / chips
    if rec["entry"].startswith("denoise"):
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n_act * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_act * rec["global_batch"] / chips


def analyse(rec: dict) -> dict:
    flops = rec["flops_per_device"] or 0.0
    byts = rec["bytes_per_device"] or 0.0
    coll = sum(rec["collective_bytes"].get(k, 0) for k in COLL_KINDS)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    # The cell's own roofline lower bound: the chip must at least do the
    # model math AND stream every live argument/output through HBM once.
    mem = rec.get("memory_analysis", {})
    min_bytes = (mem.get("argument_size_in_bytes") or 0) + \
        (mem.get("output_size_in_bytes") or 0)
    ideal = max(mf / PEAK_FLOPS, min_bytes / HBM_BW)
    dom_t = max(terms.values()) or 1e-30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "entry": rec["entry"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_ratio": (mf / flops) if flops else 0.0,
        "ideal_s": ideal,
        "roofline_fraction": ideal / dom_t,
        "step_time_bound_s": dom_t,
        "arg_bytes": mem.get("argument_size_in_bytes"),
    }


def load_all(d: Path, mesh: str = "pod16x16", *, unrolled_only: bool = True
             ) -> list[dict]:
    """Prefer the unrolled (exact-cost) artifact for each cell."""
    recs: dict = {}
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec["mesh"] != mesh or "__update" in p.stem:
            continue
        key = (rec["arch"], rec["shape"])
        if rec.get("unrolled") or key not in recs:
            if unrolled_only and not rec.get("unrolled") and key in recs:
                continue
            recs[key] = rec
    return [analyse(r) for r in recs.values()]


def fmt_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | dom | compute s | memory s | collective s | "
           "MODEL/HLO | roofline frac |\n|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant'][:4]} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    rows = load_all(Path(args.dir), args.mesh)
    print(fmt_table(rows))
    worst = sorted((r for r in rows if r["roofline_fraction"] > 0),
                   key=lambda r: r["roofline_fraction"])[:5]
    coll = sorted(rows, key=lambda r: -r["t_collective_s"])[:5]
    print("\nWorst roofline fraction:")
    for r in worst:
        print(f"  {r['arch']} {r['shape']}: {r['roofline_fraction']:.3f} "
              f"(dom {r['dominant']})")
    print("Most collective-bound:")
    for r in coll:
        print(f"  {r['arch']} {r['shape']}: collective {r['t_collective_s']:.2e}s "
              f"vs dom {r['dominant']}")
    Path("artifacts/roofline.md").write_text(fmt_table(rows))


if __name__ == "__main__":
    main()
