"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (task spec).

``--smoke`` runs a fast subset (the dispatch-plan amortization benchmark
at its smallest shape, the sparse-GEMM micro rows and the single-scan
schedule comparison) so CI and ``make smoke`` get a signal in seconds
rather than minutes.  ``--only SUBSTR`` filters suites by label;
``--json PATH`` additionally writes the rows (plus suite wall-times) as a
JSON document.  The JSON is a build ARTIFACT: CI uploads the smoke run's
``bench-smoke.json`` as the ``bench-smoke`` workflow artifact (download
it from the Actions run page) and a guard step fails the build if a
``bench-*.json`` ever lands in the tree — keep local copies out of
commits (``.gitignore`` covers the default names).

Not a suite here (it writes a tracked table, not CSV rows):
``benchmarks/autotune.py --measure`` calibrates the kernel-tuning
table (``src/repro/kernels/default_calibration.json`` — per-strategy
occupancy histograms + GEMM tile shapes, see ``repro.kernels.tuning``);
``--check`` validates it in CI.  ``make autotune`` / ``make
autotune-check``.
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import time


def _suites():
    from benchmarks import (bench_ablation, bench_attention_sparsity,
                            bench_density, bench_dispatch_plan,
                            bench_e2e_quality, bench_e2e_speedup,
                            bench_gemm_o_interval, bench_schedule,
                            bench_serving, bench_sparse_gemm,
                            bench_strategy_sweep, bench_warmup)

    return [
        ("issue1 dispatch-plan amortization", bench_dispatch_plan.run),
        ("issue2 strategy registry sweep", bench_strategy_sweep.run),
        ("issue3 schedule scan vs three-jit", bench_schedule.run),
        ("issue4 serving queue", bench_serving.run),
        ("fig6/fig10 attention", bench_attention_sparsity.run),
        ("fig6/fig11 sparse GEMMs", bench_sparse_gemm.run),
        ("fig8/A.1.2 GEMM-O interval", bench_gemm_o_interval.run),
        ("table1/2 e2e quality", bench_e2e_quality.run),
        ("table3 ablation", bench_ablation.run),
        ("fig7 density", bench_density.run),
        ("fig1 e2e speedup", bench_e2e_speedup.run),
        ("fig9 warmup", bench_warmup.run),
    ]


# Labels included in --smoke mode (fast, CPU-friendly).
SMOKE_SUITES = ("issue1 dispatch-plan amortization",
                "issue3 schedule scan vs three-jit",
                "issue4 serving queue",
                "fig6/fig11 sparse GEMMs",
                "fig6/fig10 attention")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset with reduced shapes")
    ap.add_argument("--only", default=None,
                    help="substring filter on suite labels")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + suite timings as JSON")
    args = ap.parse_args(argv)

    suites = _suites()
    if args.smoke:
        suites = [(l, f) for l, f in suites if l in SMOKE_SUITES]
    if args.only:
        suites = [(l, f) for l, f in suites if args.only in l]
    if not suites:
        print("# no suites matched", file=sys.stderr)
        return

    csv: list[dict] = []
    timings: list[dict] = []
    print("name,us_per_call,derived")
    for label, fn in suites:
        t0 = time.time()
        start = len(csv)
        if "smoke" in inspect.signature(fn).parameters:
            fn(csv, smoke=args.smoke)
        else:
            fn(csv)
        dt = time.time() - t0
        for row in csv[start:]:
            row["suite"] = label
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        timings.append({"suite": label, "seconds": round(dt, 2),
                        "rows": len(csv) - start})
        print(f"# suite [{label}] done in {dt:.1f}s", file=sys.stderr)

    if args.json:
        doc = {"smoke": args.smoke, "rows": csv, "suites": timings}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {len(csv)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
