"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (task spec).

``--smoke`` runs a fast subset (the dispatch-plan amortization benchmark
at its smallest shape, the sparse-GEMM micro rows and the single-scan
schedule comparison) so CI and ``make smoke`` get a signal in seconds
rather than minutes.  ``--only SUBSTR`` filters suites by label;
``--json PATH`` additionally writes the rows (plus suite wall-times) as a
JSON document.  The JSON is a build ARTIFACT: CI uploads the smoke run's
``bench-smoke.json`` as the ``bench-smoke`` workflow artifact (download
it from the Actions run page) and a guard step fails the build if a
``bench-*.json`` ever lands in the tree — keep local copies out of
commits (``.gitignore`` covers the default names).

``--compare OLD.json NEW.json`` starts the persistent perf trajectory:
it diffs two ``--json`` artifacts row by row, prints a per-row delta
report (wall-clock deltas are informational — CI runners are noisy) and
exits non-zero if a GATED derived key regresses: ``grid_slots*`` may
never increase, ``grid_slot_cut`` never decrease, and
``bit_identical_to_uniform`` never flip 1 → 0.  A gated row that
disappears from the new run also fails (a silently vanished row would
make the gate vacuous).  CI downloads the previous run's
``bench-smoke`` artifact and compares (first run passes trivially —
there is nothing to compare against yet).

Not a suite here (it writes a tracked table, not CSV rows):
``benchmarks/autotune.py --measure`` calibrates the kernel-tuning
table (``src/repro/kernels/default_calibration.json`` — per-strategy
occupancy histograms + GEMM tile shapes, see ``repro.kernels.tuning``);
``--check`` validates it in CI.  ``make autotune`` / ``make
autotune-check``.
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import time


def _suites():
    from benchmarks import (bench_ablation, bench_attention_sparsity,
                            bench_density, bench_dispatch_plan,
                            bench_e2e_quality, bench_e2e_speedup,
                            bench_gemm_o_interval, bench_schedule,
                            bench_serving, bench_sparse_gemm,
                            bench_strategy_sweep, bench_warmup)

    return [
        ("issue1 dispatch-plan amortization", bench_dispatch_plan.run),
        ("issue2 strategy registry sweep", bench_strategy_sweep.run),
        ("issue3 schedule scan vs three-jit", bench_schedule.run),
        ("issue4 serving queue", bench_serving.run),
        ("fig6/fig10 attention", bench_attention_sparsity.run),
        ("fig6/fig11 sparse GEMMs", bench_sparse_gemm.run),
        ("fig8/A.1.2 GEMM-O interval", bench_gemm_o_interval.run),
        ("table1/2 e2e quality", bench_e2e_quality.run),
        ("table3 ablation", bench_ablation.run),
        ("fig7 density", bench_density.run),
        ("fig1 e2e speedup", bench_e2e_speedup.run),
        ("fig9 warmup", bench_warmup.run),
    ]


# Labels included in --smoke mode (fast, CPU-friendly).
SMOKE_SUITES = ("issue1 dispatch-plan amortization",
                "issue3 schedule scan vs three-jit",
                "issue4 serving queue",
                "fig6/fig11 sparse GEMMs",
                "fig6/fig10 attention")

# Derived keys gated by ``--compare``: deterministic structural metrics
# (machine-independent, unlike wall-clock).  "max" keys may not increase
# vs the old run, "min" keys may not decrease, beyond the rel tolerance.
COMPARE_GATES = {
    "grid_slots": ("max", 0.0),
    "grid_slots_uniform": ("max", 0.0),
    "grid_slots_bucketed": ("max", 0.0),
    "grid_slot_cut": ("min", 0.02),
    "bit_identical_to_uniform": ("min", 0.0),
}


def _parse_derived(derived: str) -> dict:
    """'a=1 b=2.5e3 c=foo' -> {'a': 1.0, 'b': 2500.0, 'c': 'foo'}."""
    out: dict = {}
    for part in derived.split():
        key, sep, val = part.partition("=")
        if not sep:
            continue
        try:
            out[key] = float(val)
        except ValueError:
            out[key] = val
    return out


def compare_runs(old_doc: dict, new_doc: dict) -> tuple[list, list]:
    """Row-by-row diff of two ``--json`` documents.

    Returns ``(report_lines, regressions)`` — regressions are the gated
    failures (see :data:`COMPARE_GATES`); wall-clock deltas are reported
    but never gate.
    """
    old_rows = {r["name"]: r for r in old_doc.get("rows", [])}
    new_rows = {r["name"]: r for r in new_doc.get("rows", [])}
    report, regressions = [], []
    for name, old in old_rows.items():
        old_d = _parse_derived(old.get("derived", ""))
        gated = sorted(k for k in old_d if k in COMPARE_GATES)
        new = new_rows.get(name)
        if new is None:
            line = f"{name}: MISSING from new run"
            report.append(line)
            if gated:
                regressions.append(f"{line} (gated keys {gated})")
            continue
        new_d = _parse_derived(new.get("derived", ""))
        dt = new["us_per_call"] - old["us_per_call"]
        rel = dt / old["us_per_call"] if old["us_per_call"] else 0.0
        deltas = [f"us {old['us_per_call']:.1f} -> "
                  f"{new['us_per_call']:.1f} ({rel:+.1%})"]
        for key in gated:
            direction, tol = COMPARE_GATES[key]
            o, n = old_d[key], new_d.get(key)
            if n is None:
                regressions.append(f"{name}: gated key {key} vanished")
                deltas.append(f"{key} {o:g} -> MISSING")
                continue
            bad = (n > o * (1 + tol) if direction == "max"
                   else n < o * (1 - tol))
            deltas.append(f"{key} {o:g} -> {n:g}"
                          + (" REGRESSED" if bad else ""))
            if bad:
                regressions.append(
                    f"{name}: {key} {o:g} -> {n:g} "
                    f"({'increase' if direction == 'max' else 'decrease'} "
                    f"beyond {tol:.0%})")
        for key in sorted(set(old_d) & set(new_d) - set(gated)):
            o, n = old_d[key], new_d[key]
            if isinstance(o, float) and isinstance(n, float) and o \
                    and abs(n - o) / abs(o) > 0.25:
                deltas.append(f"{key} {o:g} -> {n:g}")
        report.append(f"{name}: " + "; ".join(deltas))
    for name in sorted(set(new_rows) - set(old_rows)):
        report.append(f"{name}: NEW row")
    return report, regressions


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset with reduced shapes")
    ap.add_argument("--only", default=None,
                    help="substring filter on suite labels")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + suite timings as JSON")
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"),
                    default=None,
                    help="diff two --json artifacts; exit 1 if a gated "
                         "derived key regressed")
    args = ap.parse_args(argv)

    if args.compare:
        old_path, new_path = args.compare
        with open(old_path) as f:
            old_doc = json.load(f)
        with open(new_path) as f:
            new_doc = json.load(f)
        report, regressions = compare_runs(old_doc, new_doc)
        for line in report:
            print(f"  {line}")
        if regressions:
            print(f"\nbench compare: {len(regressions)} gated "
                  f"regression(s):", file=sys.stderr)
            for r in regressions:
                print(f"  {r}", file=sys.stderr)
            raise SystemExit(1)
        print(f"bench compare OK: {len(report)} row(s), no gated "
              f"regressions")
        return

    suites = _suites()
    if args.smoke:
        suites = [(l, f) for l, f in suites if l in SMOKE_SUITES]
    if args.only:
        suites = [(l, f) for l, f in suites if args.only in l]
    if not suites:
        print("# no suites matched", file=sys.stderr)
        return

    csv: list[dict] = []
    timings: list[dict] = []
    print("name,us_per_call,derived")
    for label, fn in suites:
        t0 = time.time()
        start = len(csv)
        if "smoke" in inspect.signature(fn).parameters:
            fn(csv, smoke=args.smoke)
        else:
            fn(csv)
        dt = time.time() - t0
        for row in csv[start:]:
            row["suite"] = label
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        timings.append({"suite": label, "seconds": round(dt, 2),
                        "rows": len(csv) - start})
        print(f"# suite [{label}] done in {dt:.1f}s", file=sys.stderr)

    if args.json:
        doc = {"smoke": args.smoke, "rows": csv, "suites": timings}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {len(csv)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
