"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (task spec)."""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_ablation, bench_attention_sparsity,
                            bench_density, bench_e2e_quality,
                            bench_e2e_speedup, bench_gemm_o_interval,
                            bench_sparse_gemm, bench_warmup)

    suites = [
        ("fig6/fig10 attention", bench_attention_sparsity.run),
        ("fig6/fig11 sparse GEMMs", bench_sparse_gemm.run),
        ("fig8/A.1.2 GEMM-O interval", bench_gemm_o_interval.run),
        ("table1/2 e2e quality", bench_e2e_quality.run),
        ("table3 ablation", bench_ablation.run),
        ("fig7 density", bench_density.run),
        ("fig1 e2e speedup", bench_e2e_speedup.run),
        ("fig9 warmup", bench_warmup.run),
    ]
    csv: list[dict] = []
    print("name,us_per_call,derived")
    for label, fn in suites:
        t0 = time.time()
        start = len(csv)
        fn(csv)
        for row in csv[start:]:
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        print(f"# suite [{label}] done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
