"""Baseline sparsity strategies (paper §4.1 comparison set), each a REAL
symbol producer from the :mod:`repro.core.strategy` registry riding the
same Update–Dispatch engine — the unification claim in practice:

  FORA            — ``cache-all``, plain reuse (𝒟=0), refresh every 𝒩
  TaylorSeer      — ``cache-all``, order-𝒟 forecast
  ToCa-like       — ``flashomni`` caching arm only (τ_kv=0, looser τ_q)
  SpargeAttn-like — ``skip-only`` block-sparse skipping (no caching)
  DiTFastAttnV2   — ``sliding-window`` static S_s band
  FlashOmni       — ``flashomni``: C∧G caching + BSS + sparse GEMMs
  MultiGranularity— per-head table striping flashomni/sliding-window
  Hunyuan-1.5x    — named ``hunyuan-1.5x`` SparsitySchedule (per-layer
                    deployment table traced through the scanned blocks)
  StepRamp        — named ``step-ramp`` schedule (per-step strategy ramp)

Before ISSUE 2 these baselines were SIMULATED by twiddling ``MaskConfig``
thresholds; now each row names its strategy in ``EngineConfig.strategy``
— or a whole named schedule in ``EngineConfig.schedule`` (ISSUE 3), which
the single-scan sampler resolves into a traced (step × layer) table.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import EngineConfig
from repro.core.masks import MaskConfig

__all__ = ["strategy_configs"]

_BASE = dict(interval=4, block_q=16, block_kv=16, pool=16, warmup_steps=2,
             degrade=0.0)


def strategy_configs(interval: int = 4, order: int = 1) -> dict[str, EngineConfig]:
    base = dict(_BASE, interval=interval)
    # capacity fracs 1.0: let each strategy's OWN selection rule set the
    # sparsity level (the static-capacity clamp is a deployment knob, not
    # part of the algorithm comparison).
    mk = lambda strategy, schedule=None, **kw: EngineConfig(
        mask=MaskConfig(**{**base, **kw}), strategy=strategy,
        schedule=schedule, cache_dtype=jnp.float32,
        cap_q_frac=1.0, cap_kv_frac=1.0)
    return {
        "FORA": mk("cache-all", order=0),
        "TaylorSeer": mk("cache-all", order=order),
        "ToCa-like": mk("flashomni", tau_q=0.6, tau_kv=0.0, order=0),
        "SpargeAttn-like": mk("skip-only", tau_kv=0.2, order=0),
        "DiTFastAttnV2-like": mk("sliding-window", tau_kv=0.0, order=0),
        "FlashOmni": mk("flashomni", tau_q=0.5, tau_kv=0.15, order=order),
        "FlashOmni-aggressive": mk("flashomni", tau_q=0.7, tau_kv=0.25,
                                   order=order),
        "MultiGranularity": mk("multi-granularity", tau_q=0.5, tau_kv=0.15,
                               order=order),
        "Hunyuan-1.5x": mk("flashomni", schedule="hunyuan-1.5x",
                           tau_q=0.5, tau_kv=0.15, order=order),
        "StepRamp": mk("flashomni", schedule="step-ramp",
                       tau_q=0.5, tau_kv=0.15, order=order),
    }
