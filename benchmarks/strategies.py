"""Baseline sparsity strategies (paper §4.1 comparison set), all expressed
through the SAME engine config space — the unification claim in practice:

  FORA          — cache everything, plain reuse (𝒟=0), refresh every 𝒩
  TaylorSeer    — cache everything, order-𝒟 forecast
  ToCa-like     — token-importance caching (column-mass metric only)
  SpargeAttn    — block-sparse skipping only (no caching)
  DiTFastAttnV2 — static sliding-window S_s only
  FlashOmni     — C∧G caching + BSS + sparse GEMMs (the paper's engine)
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.engine import EngineConfig
from repro.core.masks import MaskConfig

__all__ = ["strategy_configs"]

_BASE = dict(interval=4, block_q=16, block_kv=16, pool=16, warmup_steps=2,
             degrade=0.0)


def strategy_configs(interval: int = 4, order: int = 1) -> dict[str, EngineConfig]:
    base = dict(_BASE, interval=interval)
    # capacity fracs 1.0: let each strategy's OWN selection rule set the
    # sparsity level (the static-capacity clamp is a deployment knob, not
    # part of the algorithm comparison).
    mk = lambda **kw: EngineConfig(
        mask=MaskConfig(**{**base, **kw}), cache_dtype=jnp.float32,
        cap_q_frac=1.0, cap_kv_frac=1.0)
    return {
        # cache-everything family: tau_q=1 selects all blocks by mass rule
        "FORA": mk(tau_q=1.0, tau_kv=0.0, order=0),
        "TaylorSeer": mk(tau_q=1.0, tau_kv=0.0, order=order),
        "ToCa-like": mk(tau_q=0.6, tau_kv=0.0, order=0),
        "SpargeAttn-like": mk(tau_q=0.0, tau_kv=0.2, order=0),
        "FlashOmni": mk(tau_q=0.5, tau_kv=0.15, order=order),
        "FlashOmni-aggressive": mk(tau_q=0.7, tau_kv=0.25, order=order),
    }
