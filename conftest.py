"""Repo-root pytest config: make ``repro`` importable without PYTHONPATH."""

import sys
from pathlib import Path

_SRC = str(Path(__file__).resolve().parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
