"""Example: long-context LM decode with FlashOmni block-sparse KV selection.

Shows the LM-serving adaptation of the paper's ``S_s`` symbol: the decode
step gathers only the most-relevant KV-cache blocks (by pooled-key scoring
against the current query), matching full attention closely at a fraction
of the cache reads — the mechanism behind the ``long_500k`` grid cells.

Usage:  PYTHONPATH=src python examples/long_context_lm.py
"""

import jax
import jax.numpy as jnp

from repro.core.attention import sparse_decode_attention
from repro.core.masks import pool_tokens
from repro.core.symbols import active_indices, clamp_mask_topk


def main():
    key = jax.random.PRNGKey(0)
    B, H, S, dh = 2, 4, 8192, 64
    block = 64
    t = S // block
    ks = jax.random.split(key, 4)
    k_cache = jax.random.normal(ks[0], (B * H, S, dh))
    v_cache = jax.random.normal(ks[1], (B * H, S, dh))
    q = jax.random.normal(ks[2], (B * H, 1, dh))
    # Plant realistic structure: trained attention concentrates on a few
    # regions; make ~12% of blocks strongly query-aligned.
    hot = jax.random.bernoulli(ks[3], 0.12, (B * H, S // block))
    hot_tok = jnp.repeat(hot, block, axis=-1)[..., None]
    k_cache = jnp.where(hot_tok, k_cache * 0.3 + q * 1.2, k_cache * 0.3)

    # score KV blocks by pooled-key affinity to the current query
    kp = pool_tokens(k_cache, block)                       # (BH, T, dh)
    scores = jnp.einsum("bnd,btd->bt", q[:, 0:1], kp)      # (BH, T)
    keep_frac = 0.25
    cap = max(int(t * keep_frac), 1)
    keep = clamp_mask_topk(jnp.ones_like(scores, bool), scores, cap)
    kv_ids, kv_cnt = active_indices(keep, cap)

    sparse = sparse_decode_attention(q, k_cache, v_cache, kv_ids, kv_cnt, block)
    s = jnp.einsum("bnd,bsd->bns", q, k_cache) * dh ** -0.5
    dense = jnp.einsum("bns,bsd->bnd", jax.nn.softmax(s, -1), v_cache)
    rel = float(jnp.linalg.norm(sparse - dense) / jnp.linalg.norm(dense))
    print(f"context {S} tokens, reading {keep_frac:.0%} of KV blocks")
    print(f"relative error vs full attention: {rel:.4f}")
    print(f"cache reads reduced {1 / keep_frac:.0f}x "
          f"(decode is HBM-bound -> ~{1 / keep_frac:.0f}x step speedup)")


if __name__ == "__main__":
    main()
