"""FlashOmni quickstart: the Update–Dispatch engine on one attention layer.

Runs on CPU in a few seconds:
  1. builds an MMDiT-style joint attention layer (text + vision tokens);
  2. Update step: full attention, sparse symbols refreshed from Q/K;
  3. Dispatch step: sparse attention guided by the packed uint8 symbols;
  4. shows the packed symbols, realized sparsity, and fidelity vs dense;
  5. cross-checks the Pallas kernel (interpret mode) against the oracle.

Usage:  PYTHONPATH=src python examples/quickstart.py [--strategy NAME]

``--strategy`` swaps the sparse-symbol producer (any registry name —
``flashomni``, ``cache-all``, ``skip-only``, ``sliding-window``,
``multi-granularity``, ``hunyuan-1.5x``) behind the SAME engine.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import (AttnParams, EngineConfig, MaskConfig,
                        available_strategies, dispatch_layer,
                        init_layer_state, update_layer)
from repro.core.strategy import strategy_summaries
from repro.core.symbols import unpack_bits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="flashomni",
                    choices=available_strategies(),
                    help="sparse-symbol producer (see repro.core.strategy)")
    args = ap.parse_args()
    print(f"strategy: {args.strategy} — {strategy_summaries()[args.strategy]}")

    key = jax.random.PRNGKey(0)
    B, H, N, dm, dh, n_text = 1, 4, 512, 128, 32, 128
    cfg = EngineConfig(
        mask=MaskConfig(tau_q=0.5, tau_kv=0.05, interval=5, order=1,
                        block_q=32, block_kv=32, pool=64, warmup_steps=1),
        strategy=args.strategy, cache_dtype=jnp.float32)
    ks = jax.random.split(key, 6)
    params = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh)) * dm ** -0.5,
        wk=jax.random.normal(ks[1], (dm, H * dh)) * dm ** -0.5,
        wv=jax.random.normal(ks[2], (dm, H * dh)) * dm ** -0.5,
        wo=jax.random.normal(ks[3], (H * dh, dm)) * (H * dh) ** -0.5,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm))
    state = init_layer_state(B, H, N, dm, dh, cfg)

    # --- Update: full attention + symbol refresh (paper Fig. 4 left) ---
    out_u, state = update_layer(params, x, state, cfg, n_text=n_text, heads=H)
    t = cfg.mask.n_blocks(N)
    m_c = unpack_bits(state.s_c, t)
    print(f"S_c packed bytes (head 0): {state.s_c[0, 0].tolist()}")
    print(f"caching mask (head 0)    : {m_c[0, 0].astype(int).tolist()} "
          f"(1 = compute, 0 = cache-then-reuse)")
    print(f"live fraction            : {float(m_c.mean()):.2f}")

    # --- Dispatch: sparse execution guided by the frozen symbols ---
    x2 = x + 0.02 * jax.random.normal(ks[5], x.shape)   # next denoising step
    out_d, state = dispatch_layer(params, x2, state, cfg, n_text=n_text, heads=H)
    ref, _ = update_layer(params, x2, init_layer_state(B, H, N, dm, dh, cfg),
                          cfg, n_text=n_text, heads=H)
    rel = float(jnp.linalg.norm(out_d - ref) / jnp.linalg.norm(ref))
    print(f"dispatch vs full-attention relative error: {rel:.4f}")
    print("  (random weights make attention near-uniform, the worst case for")
    print("   sparsity; on trained DiTs the skipped mass is ~0 — see tests/)")

    # --- Pallas kernel vs oracle (interpret mode on CPU) ---
    from repro.kernels import ops, ref as kref
    q = jax.random.normal(ks[0], (H, N, dh))
    k = jax.random.normal(ks[1], (H, N, dh))
    v = jax.random.normal(ks[2], (H, N, dh))
    o_reuse = jnp.zeros((H, N, dh))
    tq = N // 32
    m_c_blk = jax.random.bernoulli(ks[3], 0.6, (H, tq))
    m_s_blk = jax.random.bernoulli(ks[4], 0.8, (H, tq, tq)).at[..., 0].set(True)
    got = ops.flashomni_attention(q, k, v, m_c_blk, m_s_blk, o_reuse,
                                  block_q=32, block_kv=32)
    want = kref.attention_ref(q, k, v, m_c_blk, m_s_blk, o_reuse,
                              block_q=32, block_kv=32)
    print(f"Pallas CSR kernel max |err| vs oracle: "
          f"{float(jnp.max(jnp.abs(got - want))):.2e}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
