"""FlashOmni quickstart: the Update–Dispatch engine on one attention layer.

Runs on CPU in a few seconds:
  1. builds an MMDiT-style joint attention layer (text + vision tokens);
  2. Update step: full attention, sparse symbols refreshed from Q/K;
  3. Dispatch step: sparse attention guided by the packed uint8 symbols;
  4. shows the packed symbols, realized sparsity, and fidelity vs dense;
  5. cross-checks the Pallas kernel (interpret mode) against the oracle.

Usage:  PYTHONPATH=src python examples/quickstart.py [--strategy NAME]
                                                     [--schedule NAME]

``--strategy`` swaps the sparse-symbol producer (any registry name —
``flashomni``, ``cache-all``, ``skip-only``, ``sliding-window``,
``multi-granularity``, ``step-phased``, ``hunyuan-1.5x``) behind the SAME
engine.  ``--schedule`` additionally demos a named SparsitySchedule
(``hunyuan-1.5x``, ``step-ramp``) driving the ONE-compile scanned sampling
loop on a tiny MMDiT.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import (AttnParams, EngineConfig, MaskConfig,
                        available_schedules, available_strategies,
                        dispatch_layer, init_layer_state, update_layer)
from repro.core.schedule import MODE_NAMES, schedule_summaries
from repro.core.strategy import strategy_summaries
from repro.core.symbols import unpack_bits


def demo_schedule(name: str):
    """Named schedule -> one compiled scan over a tiny MMDiT sampler."""
    from repro.configs.registry import get_smoke
    from repro.diffusion.pipeline import SamplerConfig, sample
    from repro.models import dit
    print(f"\nschedule: {name} — {schedule_summaries()[name]}")
    cfg = get_smoke("flux-mmdit")
    ecfg = EngineConfig(
        mask=MaskConfig(tau_q=0.5, tau_kv=0.15, interval=4, order=1,
                        degrade=0.0, block_q=16, block_kv=16, pool=16,
                        warmup_steps=2),
        schedule=name, cache_dtype=jnp.float32,
        cap_q_frac=1.0, cap_kv_frac=1.0)
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    x0 = jax.random.normal(key, (1, 64, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    stats: dict = {}
    out = sample(params, cfg, ecfg, text_emb=text, x0=x0,
                 scfg=SamplerConfig(num_steps=8), stats=stats)
    sched = stats["schedule"]
    print(f"  strategies: {[s.name for s in sched.strategies]}")
    print(f"  mode       : {[MODE_NAMES[int(m)][0].upper() for m in sched.mode]}")
    for i in range(sched.num_steps):
        print(f"  step {i} ids: {sched.strategy_ids[i].tolist()}")
    print(f"  compiled executables: {stats['executables']} (one scan, "
          f"lax.switch on the mode array)")
    print(f"  out {out.shape} finite={bool(jnp.isfinite(out).all())}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="flashomni",
                    choices=available_strategies(),
                    help="sparse-symbol producer (see repro.core.strategy)")
    ap.add_argument("--schedule", default=None,
                    choices=available_schedules(),
                    help="also demo a named SparsitySchedule through the "
                         "single-scan sampling loop")
    args = ap.parse_args()
    print(f"strategy: {args.strategy} — {strategy_summaries()[args.strategy]}")

    key = jax.random.PRNGKey(0)
    B, H, N, dm, dh, n_text = 1, 4, 512, 128, 32, 128
    cfg = EngineConfig(
        mask=MaskConfig(tau_q=0.5, tau_kv=0.05, interval=5, order=1,
                        block_q=32, block_kv=32, pool=64, warmup_steps=1),
        strategy=args.strategy, cache_dtype=jnp.float32)
    ks = jax.random.split(key, 6)
    params = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh)) * dm ** -0.5,
        wk=jax.random.normal(ks[1], (dm, H * dh)) * dm ** -0.5,
        wv=jax.random.normal(ks[2], (dm, H * dh)) * dm ** -0.5,
        wo=jax.random.normal(ks[3], (H * dh, dm)) * (H * dh) ** -0.5,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm))
    state = init_layer_state(B, H, N, dm, dh, cfg)

    # --- Update: full attention + symbol refresh (paper Fig. 4 left) ---
    out_u, state = update_layer(params, x, state, cfg, n_text=n_text, heads=H)
    t = cfg.mask.n_blocks(N)
    m_c = unpack_bits(state.s_c, t)
    print(f"S_c packed bytes (head 0): {state.s_c[0, 0].tolist()}")
    print(f"caching mask (head 0)    : {m_c[0, 0].astype(int).tolist()} "
          f"(1 = compute, 0 = cache-then-reuse)")
    print(f"live fraction            : {float(m_c.mean()):.2f}")

    # --- Dispatch: sparse execution guided by the frozen symbols ---
    x2 = x + 0.02 * jax.random.normal(ks[5], x.shape)   # next denoising step
    out_d, state = dispatch_layer(params, x2, state, cfg, n_text=n_text, heads=H)
    ref, _ = update_layer(params, x2, init_layer_state(B, H, N, dm, dh, cfg),
                          cfg, n_text=n_text, heads=H)
    rel = float(jnp.linalg.norm(out_d - ref) / jnp.linalg.norm(ref))
    print(f"dispatch vs full-attention relative error: {rel:.4f}")
    print("  (random weights make attention near-uniform, the worst case for")
    print("   sparsity; on trained DiTs the skipped mass is ~0 — see tests/)")

    # --- Pallas kernel vs oracle (interpret mode on CPU) ---
    from repro.kernels import ops, ref as kref
    q = jax.random.normal(ks[0], (H, N, dh))
    k = jax.random.normal(ks[1], (H, N, dh))
    v = jax.random.normal(ks[2], (H, N, dh))
    o_reuse = jnp.zeros((H, N, dh))
    tq = N // 32
    m_c_blk = jax.random.bernoulli(ks[3], 0.6, (H, tq))
    m_s_blk = jax.random.bernoulli(ks[4], 0.8, (H, tq, tq)).at[..., 0].set(True)
    got = ops.flashomni_attention(q, k, v, m_c_blk, m_s_blk, o_reuse,
                                  block_q=32, block_kv=32)
    want = kref.attention_ref(q, k, v, m_c_blk, m_s_blk, o_reuse,
                              block_q=32, block_kv=32)
    print(f"Pallas CSR kernel max |err| vs oracle: "
          f"{float(jnp.max(jnp.abs(got - want))):.2e}")

    if args.schedule:
        demo_schedule(args.schedule)
    print("quickstart OK")


if __name__ == "__main__":
    main()
