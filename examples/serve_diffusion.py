"""Example: serve batched text-to-vision requests through the FlashOmni
Update–Dispatch sampler (the paper's deployment scenario).

Requests flow through the :mod:`repro.launch.batching` queue; pick the
serving mode with ``--serving`` (``sequential`` | ``stacked`` |
``continuous`` — the continuous batcher interleaves mixed-length
schedules in a fixed-width lane microbatch without recompiling).

Usage:  PYTHONPATH=src python examples/serve_diffusion.py [--steps 12]
            [--serving continuous --requests 4 --mixed-steps]

Multi-device: ``--mesh dp,sp`` runs plan-sharded dispatch over a
``(data, seq)`` device mesh — Update emits per-shard CSR partitions and
attention exchanges only plan-live KV blocks (bit-identical to the
single-device run; see ``repro/distributed/plan_shard.py``).  Try it on
a CPU host with forced devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_diffusion.py --mesh 2,4
"""

import argparse

from repro.launch.serve import serve_diffusion


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hunyuan-video-dit")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--serving", default="sequential",
                    choices=["sequential", "stacked", "continuous"])
    ap.add_argument("--mixed-steps", action="store_true",
                    help="alternate request step counts (mixed-length "
                         "lane interleaving)")
    ap.add_argument("--mesh", default="1,1", metavar="DP,SP",
                    help="engine mesh: sp>1 shards dispatch over a "
                         "(data, seq) mesh with plan-aware KV collectives")
    args = ap.parse_args()
    dp, sp = (int(x) for x in args.mesh.split(","))
    serve_diffusion(args.arch, smoke=True, num_requests=args.requests,
                    num_steps=args.steps, serving=args.serving,
                    mixed_steps=args.mixed_steps, mesh=(dp, sp))


if __name__ == "__main__":
    main()
