"""Example: serve batched text-to-vision requests through the FlashOmni
Update–Dispatch sampler (the paper's deployment scenario).

Usage:  PYTHONPATH=src python examples/serve_diffusion.py [--steps 12]
"""

import argparse

from repro.launch.serve import serve_diffusion


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hunyuan-video-dit")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--requests", type=int, default=2)
    args = ap.parse_args()
    serve_diffusion(args.arch, smoke=True, num_requests=args.requests,
                    num_steps=args.steps)


if __name__ == "__main__":
    main()
