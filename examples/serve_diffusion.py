"""Example: serve batched text-to-vision requests through the FlashOmni
Update–Dispatch sampler (the paper's deployment scenario).

Requests flow through the :mod:`repro.launch.batching` queue; pick the
serving mode with ``--serving`` (``sequential`` | ``stacked`` |
``continuous`` — the continuous batcher interleaves mixed-length
schedules in a fixed-width lane microbatch without recompiling).

Usage:  PYTHONPATH=src python examples/serve_diffusion.py [--steps 12]
            [--serving continuous --requests 4 --mixed-steps]
"""

import argparse

from repro.launch.serve import serve_diffusion


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hunyuan-video-dit")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--serving", default="sequential",
                    choices=["sequential", "stacked", "continuous"])
    ap.add_argument("--mixed-steps", action="store_true",
                    help="alternate request step counts (mixed-length "
                         "lane interleaving)")
    args = ap.parse_args()
    serve_diffusion(args.arch, smoke=True, num_requests=args.requests,
                    num_steps=args.steps, serving=args.serving,
                    mixed_steps=args.mixed_steps)


if __name__ == "__main__":
    main()
