"""Example: end-to-end training driver — train an MMDiT on the synthetic
flow-matching task for a few hundred steps with the full production loop
(AdamW + cosine schedule, async checkpointing, watchdog, restart-capable).

The default config is ~100M params; on this CPU container use ``--dim 256
--layers 8`` (~13M) for a quick run.  Loss should decrease visibly.

Usage:
  PYTHONPATH=src python examples/train_dit.py --steps 200 --dim 256 --layers 8
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ArchConfig
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.synthetic import DataConfig, make_batch
from repro.models import dit
from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.runtime.fault_tolerance import RestartableLoop, StepWatchdog

import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=768)       # 768x12 ≈ 100M
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-vision", type=int, default=64)
    ap.add_argument("--ckpt", default="artifacts/ckpt/train_dit")
    args = ap.parse_args()

    cfg = ArchConfig(name="dit-train", family="dit", n_layers=args.layers,
                     d_model=args.dim, n_heads=max(args.dim // 64, 1),
                     n_kv_heads=max(args.dim // 64, 1), d_ff=4 * args.dim,
                     vocab=0, head_dim=64, n_text_tokens=16, patch_dim=16,
                     remat=False)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: dit.init_params(cfg, jax.random.PRNGKey(0)))))
    print(f"[train_dit] {n_params/1e6:.1f}M params, {args.steps} steps")

    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    opt_state = adamw_init(params)
    dcfg = DataConfig(seed=0, batch=args.batch, seq_len=args.n_vision)

    @jax.jit
    def _step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: dit.train_loss(p, cfg, batch, dtype=jnp.float32))(params)
        new_p, new_o, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        return new_p, new_o, loss, gnorm

    def step_fn(state, step):
        p, o = state
        batch = make_batch(cfg, dcfg, step)
        p, o, loss, gnorm = _step(p, o, batch)
        if step % 20 == 0:
            print(f"  step {step:4d}  loss {float(loss):.5f}  "
                  f"gnorm {float(gnorm):.3f}")
        return (p, o), {"loss": float(loss)}

    loop = RestartableLoop(Checkpointer(args.ckpt, keep=2), ckpt_every=50)
    state, result = loop.run((params, opt_state), step_fn, args.steps,
                             watchdog=StepWatchdog())
    losses = [m["loss"] for m in result.metrics]
    print(f"[train_dit] loss {losses[0]:.5f} -> {losses[-1]:.5f} "
          f"({result.final_step} steps, restarts={result.restarts})")
    assert losses[-1] < losses[0], "training did not reduce the loss"


if __name__ == "__main__":
    main()
