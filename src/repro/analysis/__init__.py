"""Engine invariant analyzer (ISSUE 9 tentpole + ISSUE 10 cost passes).

Four pass families behind one :class:`AnalysisPass` protocol and one
entry point, :func:`run_analysis` (CLI: ``python -m repro.analysis`` /
``make analyze``):

1. **Jaxpr passes** (:mod:`repro.analysis.passes`) — trace real engine
   entry points abstractly and walk the equation graphs with
   :mod:`repro.analysis.jaxpr_walk`: ``dispatch-purity``,
   ``collective-budget``, ``promotion-check``, ``executable-budget``.
2. **Cost passes** (:mod:`repro.analysis.cost_passes`, on top of the
   symbolic interpreter in :mod:`repro.analysis.cost_model`) — certify
   the engine's COST model statically: ``cost-dispatch-scaling``
   (dispatch FLOPs/bytes affine in ``T_kv`` at fixed plan capacity and
   proportional to live plan slots, per backend × kv_buckets × mesh
   group, bit-identical across strategies), ``cost-collective-bytes``
   (mesh seq-mode a2a payload ≡ the ``pair_cap`` formula, never
   ``O(T_kv)``), ``cost-update-amortization`` (Update ≤ κ× one dense
   step, interval-amortized engine ≤ θ× dense), and
   ``cost-memory-footprint`` (peak live bytes per executable within the
   declared budget table; lane-tick peak affine in lane count).
3. **Plan validator** (:mod:`repro.analysis.plan_check`) — structural
   checks over any concrete :class:`~repro.core.plan.DispatchPlan`;
   also the live opt-in hook behind ``EngineConfig.validate_plans`` /
   ``REPRO_VALIDATE_PLANS=1``.
4. **Source lint** (:mod:`repro.analysis.source_lint`) — repo-rule AST
   checks over ``src/`` (plan-field coverage, unbounded caches,
   ``id()``-keyed caches, jit-under-trace).

Adding a pass
-------------
Write a class with a ``name`` string and a ``run(ctx) -> list[Finding]``
method (``ctx.note(msg)`` records non-failing diagnostics, e.g. a
skipped mesh combo on a 1-device host), then append it to
:data:`ALL_PASSES`.  Passes must trace abstractly (``jax.eval_shape`` /
``jax.make_jaxpr`` on ``ShapeDtypeStruct`` operands) — ``run_analysis``
is a CI gate and must not burn compile time or FLOPs.

The static cost model
---------------------
:func:`repro.analysis.cost_model.cost_of_jaxpr` folds a per-primitive
cost table over a jaxpr and returns a
:class:`~repro.analysis.cost_model.CostEstimate` (FLOPs, HBM bytes,
collective payload/wire bytes by kind); ``peak_bytes_of`` estimates the
peak live-buffer footprint via a last-use liveness scan.  The primitive
table, in brief:

===========================  ================================================
primitive family             cost rule
===========================  ================================================
``dot_general``              FLOPs = 2 · out_elems · K (lhs contracting
                             dims); bytes = operands + result
``conv_general_dilated``     FLOPs = 2 · out_elems · (window · C_in);
                             bytes = operands + result
``gather`` / ``*_take``      FLOPs = out_elems; bytes = 2·result + indices
                             (NOT the operand — a plan gather must never
                             bill the full KV it indexes into)
``scatter*``                 FLOPs = updates; bytes = 2·updates + indices
``dynamic_(update_)slice``   bytes = slice in + out (never the operand)
``sort``                     FLOPs = n·log2(n) per sorted lane
``reduce_*`` / elementwise   FLOPs = in/out elems; bytes = in + out
layout/dtype moves           0 FLOPs, in + out bytes (``reshape``,
                             ``transpose``, ``convert_element_type``, …)
``all_to_all``               payload = result bytes; wire = payload·(P−1)/P
``all_gather``               payload = result bytes (= shard · axis_size);
                             wire = payload·(P−1)/P
``psum`` (all-reduce)        payload = result; wire = 2·payload·(P−1)/P
``reduce_scatter``           payload = result·P; wire = payload·(P−1)
``scan``                     body cost × trip count (``length``)
``while``                    body × 1 trip, marks the estimate ``inexact``
``cond`` / ``switch``        per-resource max over branches
``shard_map`` / ``pjit``     recurse; mesh axis sizes join the env
``pallas_call``              kernel body cost × grid size
===========================  ================================================

Adding a primitive cost
-----------------------
When a new primitive shows up in an engine trace the interpreter falls
back to ``out_elems`` FLOPs + full I/O bytes and keeps going — sound but
crude.  To model it properly call
``repro.analysis.cost_model.register_primitive_cost(name, handler)``
where ``handler(eqn, env) -> CostEstimate`` reads shapes from
``eqn.invars[i].aval`` / ``eqn.outvars[i].aval`` and mesh axis sizes
from ``env.axis_sizes``; pure layout moves belong in
``cost_model.LAYOUT_PRIMS`` instead.  Add a shape-parameterized unit
test next to ``tests/test_analysis.py::test_cost_model_*`` and, if the
primitive can carry ``T_kv``-sized work, an adversarial fixture so the
dispatch-scaling pass provably catches misuse.

Wiring a new DispatchPlan field
-------------------------------
A new field must be threaded through FOUR places, and the analyzer
enforces each one:

* produced in ``build_dispatch_plan`` (or a layout helper it splices
  in) — ``plan-rebuild-coverage`` lint;
* if it is an id list (suffix ``_ids``/``_slots``/``_src``/``_rows``/
  ``_idx``), widened in ``DispatchPlan.widen()`` — the
  ``plan-widen-coverage`` lint statically, and the plan validator's
  no-int16-after-widen check dynamically;
* given a sharding entry in ``models/dit.engine_state_specs`` —
  ``plan-spec-coverage`` lint;
* registered with its trailing (core) rank in
  ``plan_check._CORE_RANK`` so the structural validator can fold away
  stacked lane/layer axes — :func:`plan_check.check_plan` raises on an
  unknown-rank field the first time a stacked plan is validated.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol

__all__ = ["Finding", "AnalysisContext", "AnalysisPass", "ALL_PASSES",
           "run_analysis"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation. ``where`` names the traced entry point or
    source location; ``rule`` is the stable machine-readable rule id."""

    pass_name: str
    rule: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"[{self.pass_name}/{self.rule}] {self.where}: {self.message}"


@dataclasses.dataclass
class AnalysisContext:
    """Shared pass inputs: the source root and a non-failing note sink."""

    src_root: str
    notes: List[str] = dataclasses.field(default_factory=list)

    def note(self, msg: str) -> None:
        self.notes.append(msg)


class AnalysisPass(Protocol):
    name: str

    def run(self, ctx: AnalysisContext) -> List[Finding]: ...


class SourceLint:
    """Adapter exposing :mod:`source_lint` through the pass protocol."""

    name = "source-lint"

    def run(self, ctx: AnalysisContext) -> List[Finding]:
        from repro.analysis.source_lint import lint_sources
        return [Finding(self.name, rule, f"{path}:{line}", msg)
                for path, line, rule, msg in lint_sources(ctx.src_root)]


class PlanValidator:
    """Run :func:`plan_check.check_plan` over real engine plans for every
    registered strategy × backend × kv_buckets × mesh combo."""

    name = "plan-validator"

    def run(self, ctx: AnalysisContext) -> List[Finding]:
        import jax

        from repro.analysis.passes import _params, _engine_cfg, sweep_configs, \
            _B, _H, _N, _DM, _DH
        from repro.analysis.plan_check import check_plan
        from repro.core.engine import init_layer_state, update_layer
        findings = []
        x = jax.random.normal(jax.random.PRNGKey(3), (_B, _N, _DM)) * 0.3
        p = _params()
        for label, cfg, skip in sweep_configs():
            if skip is not None:
                ctx.note(f"{self.name}: skipped {label} ({skip})")
                continue
            if cfg.backend == "pallas":
                # The plan is backend-independent (built before dispatch);
                # validating it once per strategy/bucket/mesh combo is the
                # full matrix — skip the duplicate pallas build.
                continue
            state = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
            _, st = update_layer(p, x, state, cfg, n_text=32, heads=_H,
                                 step_idx=2, num_steps=8)
            for msg in check_plan(st.plan, cfg, _N):
                findings.append(Finding(self.name, "plan-invariant",
                                        f"update_layer[{label}]", msg))
        return findings


def _jaxpr_passes():
    from repro.analysis.passes import JAXPR_PASSES
    return [cls() for cls in JAXPR_PASSES]


def _cost_passes():
    from repro.analysis.cost_passes import COST_PASSES
    return [cls() for cls in COST_PASSES]


def ALL_PASSES() -> list:
    # Jaxpr passes run first so their traces warm the (cfg, n) memo the
    # cost passes re-walk.
    return _jaxpr_passes() + _cost_passes() + [PlanValidator(),
                                               SourceLint()]


def run_analysis(passes: Optional[list] = None,
                 src_root: Optional[str] = None,
                 verbose: bool = True) -> List[Finding]:
    """Run ``passes`` (default: all) and return every finding."""
    import os
    if src_root is None:
        src_root = os.path.join(os.path.dirname(__file__), "..", "..")
        src_root = os.path.normpath(src_root)
    ctx = AnalysisContext(src_root=src_root)
    findings: List[Finding] = []
    for p in (ALL_PASSES() if passes is None else passes):
        got = p.run(ctx)
        findings.extend(got)
        if verbose:
            print(f"  pass {p.name}: "
                  f"{'OK' if not got else f'{len(got)} finding(s)'}")
    if verbose:
        for n in ctx.notes:
            print(f"  note: {n}")
        for f in findings:
            print(f"  {f}")
    return findings
