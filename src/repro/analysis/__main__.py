"""CLI for the engine invariant analyzer: ``python -m repro.analysis``.

Exit code 0 = no findings; 1 = at least one finding (the CI gate).

``--fixture NAME`` runs the owning pass against a deliberately broken
input instead of the repo — the acceptance harness for the analyzer
itself (each fixture MUST produce findings, i.e. exit non-zero):

* ``injected-sort``   — a dispatch-shaped fn with a smuggled ``lax.sort``
* ``bad-plan``        — a real plan hand-mutated to violate fold-back
                        (counts past widths, out-of-range ids)
* ``uncovered-field`` — a plan leaf that ``widen()`` does not cover
                        (survives as int16)
* ``id-cache``        — a module caching by ``id(obj)`` into an
                        unbounded module-level dict
"""

# Mesh passes need multiple devices; force an 8-device host platform
# BEFORE jax is imported anywhere (harmless on real multi-device hosts:
# setdefault never overrides an explicit setting).
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys


def _fixture_findings(name: str):
    import jax
    import jax.numpy as jnp

    from repro.analysis import Finding
    if name == "injected-sort":
        from repro.analysis.jaxpr_walk import index_decode_eqns

        def dispatch_like(x, ids):
            # Pretends to consume a plan but re-derives the order.
            order = jax.lax.sort(ids)
            return jnp.take(x, order, axis=0)

        jx = jax.make_jaxpr(dispatch_like)(
            jnp.ones((8, 4)), jnp.arange(8, dtype=jnp.int32))
        return [Finding("dispatch-purity", "no-index-decode-in-dispatch",
                        "fixture[injected-sort]",
                        f"{eqn.primitive.name} in dispatch jaxpr")
                for _, eqn in index_decode_eqns(jx)]
    if name in ("bad-plan", "uncovered-field"):
        from repro.analysis.passes import (_B, _DH, _DM, _H, _N, _engine_cfg,
                                           _params)
        from repro.analysis.plan_check import check_plan
        from repro.core.engine import init_layer_state, update_layer
        cfg = _engine_cfg(kv_buckets=3)
        x = jax.random.normal(jax.random.PRNGKey(0), (_B, _N, _DM)) * 0.3
        st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
        _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                             step_idx=2, num_steps=8)
        plan = st.plan
        if name == "bad-plan":
            plan = plan._replace(
                # counts past the bucket widths AND ids out of range
                bkt_kv_cnt=plan.bkt_kv_cnt + 7,
                kv_row_ids=jnp.full_like(plan.kv_row_ids, 2 ** 14))
        else:
            # a field widen() does not know about stays int16
            plan = plan._replace(q_cnt=plan.q_cnt.astype(jnp.int16))
        return [Finding("plan-validator", "plan-invariant",
                        f"fixture[{name}]", msg)
                for msg in check_plan(plan, cfg, _N)]
    if name == "id-cache":
        from repro.analysis.source_lint import lint_source
        src = (
            "_PLAN_CACHE = {}\n"
            "def lookup(spec):\n"
            "    key = id(spec)\n"
            "    if key not in _PLAN_CACHE:\n"
            "        _PLAN_CACHE[key] = build(spec)\n"
            "    return _PLAN_CACHE[key]\n")
        return [Finding("source-lint", rule, f"fixture[id-cache]:{line}", msg)
                for _, line, rule, msg in lint_source(src)]
    raise SystemExit(f"unknown fixture {name!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="FlashOmni engine invariant analyzer")
    ap.add_argument("--passes", default=None,
                    help="comma-separated pass names (default: all)")
    ap.add_argument("--fixture", default=None,
                    help="run against an adversarial fixture instead of "
                         "the repo (expected to FAIL)")
    ap.add_argument("--src", default=None, help="source root to lint")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.fixture:
        findings = _fixture_findings(args.fixture)
        for f in findings:
            print(f"  {f}")
        print(f"fixture {args.fixture}: {len(findings)} finding(s)")
        return 1 if findings else 0

    from repro.analysis import ALL_PASSES, run_analysis
    passes = ALL_PASSES()
    if args.passes:
        want = {p.strip() for p in args.passes.split(",")}
        known = {p.name for p in passes}
        bad = want - known
        if bad:
            raise SystemExit(f"unknown pass(es) {sorted(bad)}; "
                             f"known: {sorted(known)}")
        passes = [p for p in passes if p.name in want]
    findings = run_analysis(passes=passes, src_root=args.src,
                            verbose=not args.quiet)
    print(f"invariant analysis: {len(findings)} finding(s) across "
          f"{len(passes)} pass(es)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
