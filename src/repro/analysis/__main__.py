"""CLI for the engine invariant analyzer: ``python -m repro.analysis``.

Exit code 0 = no findings; 1 = at least one finding (the CI gate).

``--fixture NAME`` runs the owning pass against a deliberately broken
input instead of the repo — the acceptance harness for the analyzer
itself (each fixture MUST produce findings, i.e. exit non-zero):

* ``injected-sort``   — a dispatch-shaped fn with a smuggled ``lax.sort``
* ``bad-plan``        — a real plan hand-mutated to violate fold-back
                        (counts past widths, out-of-range ids)
* ``uncovered-field`` — a plan leaf that ``widen()`` does not cover
                        (survives as int16)
* ``id-cache``        — a module caching by ``id(obj)`` into an
                        unbounded module-level dict

Cost-pass fixtures (ISSUE 10):

* ``dense-einsum-dispatch``   — a dispatch body hiding a dense
                                ``T_kv``-wide einsum (cost super-linear
                                in T_kv at fixed plan capacity)
* ``mesh-allgather``          — a mesh body smuggling an ``all_gather``
                                of the FULL KV instead of the pair_cap
                                all-to-all
* ``rebuild-every-dispatch``  — an engine paying Update's plan build on
                                every dispatch step (amortization ≥ 1×
                                dense)
* ``memory-hog``              — an executable whose peak live buffers
                                blow the declared byte budget
"""

# Mesh passes need multiple devices; force an 8-device host platform
# BEFORE jax is imported anywhere (harmless on real multi-device hosts:
# setdefault never overrides an explicit setting).
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys


def _fixture_findings(name: str):
    import jax
    import jax.numpy as jnp

    from repro.analysis import Finding
    if name == "injected-sort":
        from repro.analysis.jaxpr_walk import index_decode_eqns

        def dispatch_like(x, ids):
            # Pretends to consume a plan but re-derives the order.
            order = jax.lax.sort(ids)
            return jnp.take(x, order, axis=0)

        jx = jax.make_jaxpr(dispatch_like)(
            jnp.ones((8, 4)), jnp.arange(8, dtype=jnp.int32))
        return [Finding("dispatch-purity", "no-index-decode-in-dispatch",
                        "fixture[injected-sort]",
                        f"{eqn.primitive.name} in dispatch jaxpr")
                for _, eqn in index_decode_eqns(jx)]
    if name in ("bad-plan", "uncovered-field"):
        from repro.analysis.passes import (_B, _DH, _DM, _H, _N, _engine_cfg,
                                           _params)
        from repro.analysis.plan_check import check_plan
        from repro.core.engine import init_layer_state, update_layer
        cfg = _engine_cfg(kv_buckets=3)
        x = jax.random.normal(jax.random.PRNGKey(0), (_B, _N, _DM)) * 0.3
        st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
        _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                             step_idx=2, num_steps=8)
        plan = st.plan
        if name == "bad-plan":
            plan = plan._replace(
                # counts past the bucket widths AND ids out of range
                bkt_kv_cnt=plan.bkt_kv_cnt + 7,
                kv_row_ids=jnp.full_like(plan.kv_row_ids, 2 ** 14))
        else:
            # a field widen() does not know about stays int16
            plan = plan._replace(q_cnt=plan.q_cnt.astype(jnp.int16))
        return [Finding("plan-validator", "plan-invariant",
                        f"fixture[{name}]", msg)
                for msg in check_plan(plan, cfg, _N)]
    if name == "dense-einsum-dispatch":
        from repro.analysis.cost_model import cost_of_jaxpr
        from repro.analysis.cost_passes import (KAPPA_TOKEN,
                                                KAPPA_TOKEN_BYTES,
                                                _token_reference_slope,
                                                token_scaling_findings)
        cap = 32                       # fixed live plan slots

        def dispatch_like(x, k):
            # legit plan-capacity work: gather `cap` rows…
            live = jnp.take(x, jnp.arange(cap), axis=0)
            # …plus a smuggled dense T_kv × T_kv score matrix.
            scores = jnp.einsum("nd,md->nm", x, k)
            return live.sum() + scores.sum()

        ns = (128, 256, 384)
        costs = [cost_of_jaxpr(jax.make_jaxpr(dispatch_like)(
            jax.ShapeDtypeStruct((n, 16), jnp.float32),
            jax.ShapeDtypeStruct((n, 16), jnp.float32))) for n in ns]
        ref_f, ref_b = _token_reference_slope()
        return token_scaling_findings(
            "cost-dispatch-scaling", "fixture[dense-einsum-dispatch]",
            costs, ns, budget_flops=KAPPA_TOKEN * ref_f,
            budget_bytes=KAPPA_TOKEN_BYTES * ref_b)
    if name == "mesh-allgather":
        import numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P

        from repro.analysis.cost_model import cost_of_jaxpr
        from repro.analysis.cost_passes import (_matched,
                                                collective_findings,
                                                expected_a2a_payload)
        from repro.analysis.passes import _B, _DH, _H, _engine_cfg
        if len(jax.devices()) < 2:
            raise SystemExit("mesh-allgather fixture needs >= 2 devices")
        n = 256
        cfg = _matched(_engine_cfg(backend="xla", mesh_dp=1, mesh_sp=2),
                       2, 2, n)
        mesh = Mesh(np.array(jax.devices()[:2]), ("sp",))

        def body(k, v):
            # ships the FULL KV instead of the plan-live pair_cap blocks
            return (jax.lax.all_gather(k, "sp", axis=0, tiled=True),
                    jax.lax.all_gather(v, "sp", axis=0, tiled=True))

        kv = jax.ShapeDtypeStruct((_B * _H * n, _DH), jnp.float32)
        jx = jax.make_jaxpr(shard_map(body, mesh=mesh,
                                      in_specs=(P("sp"), P("sp")),
                                      out_specs=(P(), P()),
                                      check_rep=False))(kv, kv)
        dense_payload = 2.0 * (_B * _H * n * _DH) * 4
        return collective_findings(
            "cost-collective-bytes", "fixture[mesh-allgather]",
            cost_of_jaxpr(jx), expected_a2a_payload(cfg, n), dense_payload)
    if name == "rebuild-every-dispatch":
        from repro.analysis.cost_passes import (_dense_reference_cost,
                                                _matched, _update_cost,
                                                amortization_findings)
        from repro.analysis.passes import _N, _engine_cfg
        cfg = _matched(_engine_cfg(backend="xla", kv_buckets=1), 2, 2, _N)
        u = _update_cost(cfg, _N)
        # dispatch cost := update cost — the plan is rebuilt every step
        return amortization_findings(
            "cost-update-amortization", "fixture[rebuild-every-dispatch]",
            u, u, _dense_reference_cost(_N), cfg.mask.interval)
    if name == "memory-hog":
        from repro.analysis.cost_model import peak_bytes_of
        from repro.analysis.cost_passes import (PEAK_BUDGETS,
                                                footprint_findings)

        def hog(x):
            big = jnp.zeros((512, 512), jnp.float32)   # 1 MB scratch
            return (x[:, None] * big).sum() + x.sum()

        jx = jax.make_jaxpr(hog)(jax.ShapeDtypeStruct((512,), jnp.float32))
        return footprint_findings(
            "cost-memory-footprint", "fixture[memory-hog]",
            peak_bytes_of(jx), PEAK_BUDGETS["dispatch_layer"])
    if name == "id-cache":
        from repro.analysis.source_lint import lint_source
        src = (
            "_PLAN_CACHE = {}\n"
            "def lookup(spec):\n"
            "    key = id(spec)\n"
            "    if key not in _PLAN_CACHE:\n"
            "        _PLAN_CACHE[key] = build(spec)\n"
            "    return _PLAN_CACHE[key]\n")
        return [Finding("source-lint", rule, f"fixture[id-cache]:{line}", msg)
                for _, line, rule, msg in lint_source(src)]
    raise SystemExit(f"unknown fixture {name!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="FlashOmni engine invariant analyzer")
    ap.add_argument("--passes", default=None,
                    help="comma-separated pass names or fnmatch globs, "
                         "e.g. 'cost-*' (default: all)")
    ap.add_argument("--fixture", default=None,
                    help="run against an adversarial fixture instead of "
                         "the repo (expected to FAIL)")
    ap.add_argument("--src", default=None, help="source root to lint")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.fixture:
        findings = _fixture_findings(args.fixture)
        for f in findings:
            print(f"  {f}")
        print(f"fixture {args.fixture}: {len(findings)} finding(s)")
        return 1 if findings else 0

    from repro.analysis import ALL_PASSES, run_analysis
    passes = ALL_PASSES()
    if args.passes:
        import fnmatch
        pats = [p.strip() for p in args.passes.split(",") if p.strip()]
        known = {p.name for p in passes}
        bad = [pat for pat in pats
               if not any(fnmatch.fnmatch(n, pat) for n in known)]
        if bad:
            raise SystemExit(f"pattern(s) {sorted(bad)} match no pass; "
                             f"known: {sorted(known)}")
        passes = [p for p in passes
                  if any(fnmatch.fnmatch(p.name, pat) for pat in pats)]
    findings = run_analysis(passes=passes, src_root=args.src,
                            verbose=not args.quiet)
    print(f"invariant analysis: {len(findings)} finding(s) across "
          f"{len(passes)} pass(es)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
