"""Symbolic per-primitive cost interpreter over traced jaxprs (ISSUE 10).

Walks an abstract trace (``jax.make_jaxpr`` output — no compilation, no
FLOPs executed) and accounts three resources per equation, recursing
into every sub-jaxpr with the trip-count multiplier of its enclosing
higher-order primitive:

* **flops** — ``dot_general``/``conv`` from the contraction shapes
  (2 flops per MAC, matching XLA ``cost_analysis()``), element-wise and
  reduction primitives at one flop per element, pure layout primitives
  (reshape / transpose / broadcast / convert / slice / pad / concat) at
  zero.
* **hbm bytes** — operand + result bytes per equation, with gather /
  dynamic-slice special-cased to *touched* bytes (result + indices, not
  the whole gathered operand) so a plan-capacity gather over a large KV
  buffer costs what it moves, not what it could address.  This is a
  pre-fusion upper-bound proxy, not an HLO buffer-assignment replay —
  useful for *scaling* certificates (is the byte count a function of
  live slots or of ``T_kv``?), not as an absolute HBM counter.
* **collective bytes** — per collective kind, both the *payload*
  (result bytes, the convention of the dry-run HLO-text parser, so the
  two accountings cross-check 1:1) and the *wire* bytes (what actually
  crosses links: ``(P-1)/P`` of an all-to-all, ``(P-1)/P`` of an
  all-gather result, twice that for a psum).  Axis sizes resolve from
  the enclosing ``shard_map`` mesh params (or the ``axis_sizes``
  argument for traces made under ``jax.pmap``-style outer binders).

Recursion rules: ``scan`` multiplies its body by ``length``;
``while_loop`` by 1 (trip count is dynamic — the estimate is a lower
bound there, recorded in :attr:`CostEstimate.inexact`); ``cond`` /
``switch`` take the per-resource **max** over branches; ``pallas_call``
multiplies its kernel body by the grid size; everything else
(``pjit``, ``custom_jvp/vjp``, ``remat``, ``shard_map``) sums at
multiplier 1.

Peak-live-buffer estimation (:func:`peak_bytes_of`) runs a last-use
liveness scan per jaxpr level: at each program point the live set is
the jaxpr's inputs plus every already-defined value still referenced
later; the peak adds the deepest concurrently-live sub-jaxpr.  Like the
byte count it is a *scaling* estimator (pre-buffer-assignment, no
aliasing/donation), calibrated by the MemoryFootprint pass's budget
table rather than read as absolute HBM.

Entry points: :func:`cost_of_jaxpr` and :func:`peak_bytes_of`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

from repro.analysis.jaxpr_walk import as_jaxpr

__all__ = ["CostEstimate", "cost_of_jaxpr", "peak_bytes_of",
           "aval_bytes", "register_primitive_cost", "LAYOUT_PRIMS"]


# ---------------------------------------------------------------------------
# Cost container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CostEstimate:
    """Additive resource totals for one traced executable."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    # per collective kind ("all_to_all", "all_gather", "psum", ...):
    coll_payload: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_wire: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    inexact: bool = False      # a dynamic-trip-count loop was estimated

    def add(self, other: "CostEstimate", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll_payload.items():
            self.coll_payload[k] = self.coll_payload.get(k, 0.0) + v * mult
        for k, v in other.coll_wire.items():
            self.coll_wire[k] = self.coll_wire.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + int(v * mult)
        self.inexact = self.inexact or other.inexact

    def total_collective_payload(self) -> float:
        return float(sum(self.coll_payload.values()))

    def total_collective_wire(self) -> float:
        return float(sum(self.coll_wire.values()))


def aval_bytes(aval) -> float:
    """Byte size of one abstract value (0 for non-array avals)."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0.0
    return float(math.prod(shape)) * dtype.itemsize


def _out_elems(eqn) -> float:
    return float(sum(math.prod(getattr(v.aval, "shape", ()))
                     for v in eqn.outvars))


def _io_bytes(eqn) -> float:
    return float(sum(aval_bytes(v.aval) for v in eqn.invars) +
                 sum(aval_bytes(v.aval) for v in eqn.outvars))


# ---------------------------------------------------------------------------
# Per-primitive handlers
# ---------------------------------------------------------------------------
#
# A handler takes ``(eqn, axis_sizes)`` and returns a CostEstimate for
# that single equation (sub-jaxpr recursion is the interpreter's job,
# not the handler's).  Unlisted primitives fall back to the default:
# one flop per output element + operand/result bytes — except the pure
# LAYOUT_PRIMS, which cost bytes only.

# Primitives that move/reinterpret data without arithmetic.
LAYOUT_PRIMS = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "squeeze", "slice", "rev", "concatenate", "pad", "copy",
    "stop_gradient", "iota", "split", "device_put", "sharding_constraint",
    "bitcast_convert_type", "expand_dims",
})

# Zero-cost bookkeeping primitives (no data movement either).
FREE_PRIMS = frozenset({
    "axis_index", "program_id", "num_programs", "create_token",
    "debug_callback", "pure_callback",
})


def _dot_general_cost(eqn, axis_sizes) -> CostEstimate:
    (lhs_c, _rhs_c), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    k = math.prod(lhs[d] for d in lhs_c) or 1
    return CostEstimate(flops=2.0 * _out_elems(eqn) * k,
                        hbm_bytes=_io_bytes(eqn))


def _conv_cost(eqn, axis_sizes) -> CostEstimate:
    # out elems × (2 × kernel reduction size); kernel is invars[1] with
    # layout-dependent dims — reduction = all kernel elems / out features.
    rhs = eqn.invars[1].aval.shape
    out_feats = max(1, eqn.outvars[0].aval.shape[1])
    red = math.prod(rhs) / out_feats
    return CostEstimate(flops=2.0 * _out_elems(eqn) * red,
                        hbm_bytes=_io_bytes(eqn))


def _gather_cost(eqn, axis_sizes) -> CostEstimate:
    # Touched bytes: read the gathered slices (≈ result) + the index
    # buffer, write the result.  NOT the whole operand — a cap-bounded
    # plan gather over the KV buffer must not look O(T_kv).
    out_b = sum(aval_bytes(v.aval) for v in eqn.outvars)
    idx_b = aval_bytes(eqn.invars[-1].aval) if len(eqn.invars) > 1 else 0.0
    return CostEstimate(hbm_bytes=2.0 * out_b + idx_b)


def _scatter_cost(eqn, axis_sizes) -> CostEstimate:
    # Read + write the touched window (≈ updates) + indices; the
    # untouched remainder of the operand aliases through.
    upd_b = aval_bytes(eqn.invars[-1].aval)
    idx_b = aval_bytes(eqn.invars[1].aval) if len(eqn.invars) > 2 else 0.0
    flops = float(math.prod(getattr(eqn.invars[-1].aval, "shape", ())))
    return CostEstimate(flops=flops, hbm_bytes=2.0 * upd_b + idx_b)


def _dynamic_slice_cost(eqn, axis_sizes) -> CostEstimate:
    out_b = sum(aval_bytes(v.aval) for v in eqn.outvars)
    return CostEstimate(hbm_bytes=2.0 * out_b)


def _dynamic_update_slice_cost(eqn, axis_sizes) -> CostEstimate:
    upd_b = aval_bytes(eqn.invars[1].aval)
    return CostEstimate(hbm_bytes=2.0 * upd_b)


def _sort_cost(eqn, axis_sizes) -> CostEstimate:
    # comparison-sort proxy: n log2 n per sorted lane
    n = _out_elems(eqn)
    return CostEstimate(flops=n * max(1.0, math.log2(max(n, 2.0))),
                        hbm_bytes=_io_bytes(eqn))


def _axis_size(eqn, axis_sizes, names) -> int:
    if isinstance(names, (str, int)):
        names = (names,)
    p = 1
    for nm in names or ():
        p *= int(axis_sizes.get(nm, 1))
    return max(p, 1)


def _collective_cost(kind: str, payload: float, p: int) -> CostEstimate:
    """payload = HLO-result-comparable bytes; wire = bytes crossing links."""
    wire = {
        "all_to_all": payload * (p - 1) / p,
        "all_gather": payload * (p - 1) / p,     # payload is the result
        "psum": 2.0 * payload * (p - 1) / p,     # reduce-scatter+all-gather
        "psum_scatter": payload * (p - 1),       # payload is the shard
        "reduce_scatter": payload * (p - 1),
        "ppermute": payload,
        "pgather": payload * (p - 1) / p,
    }.get(kind, payload)
    return CostEstimate(coll_payload={kind: payload},
                        coll_wire={kind: wire},
                        coll_count={kind: 1})


def _all_to_all_cost(eqn, axis_sizes) -> CostEstimate:
    p = _axis_size(eqn, axis_sizes, eqn.params.get("axis_name"))
    payload = sum(aval_bytes(v.aval) for v in eqn.outvars)  # == operand
    c = _collective_cost("all_to_all", payload, p)
    c.hbm_bytes = _io_bytes(eqn)
    return c


def _all_gather_cost(eqn, axis_sizes) -> CostEstimate:
    p = int(eqn.params.get("axis_size") or
            _axis_size(eqn, axis_sizes, eqn.params.get("axis_name")))
    payload = sum(aval_bytes(v.aval) for v in eqn.outvars)  # P × operand
    c = _collective_cost("all_gather", payload, max(p, 1))
    c.hbm_bytes = _io_bytes(eqn)
    return c


def _psum_like_cost(kind):
    def handler(eqn, axis_sizes) -> CostEstimate:
        p = _axis_size(eqn, axis_sizes,
                       eqn.params.get("axes") or eqn.params.get("axis_name"))
        payload = sum(aval_bytes(v.aval) for v in eqn.outvars)
        c = _collective_cost(kind, payload, p)
        c.hbm_bytes = _io_bytes(eqn)
        c.flops = _out_elems(eqn)
        return c
    return handler


_HANDLERS: Dict[str, Callable] = {
    "dot_general": _dot_general_cost,
    "conv_general_dilated": _conv_cost,
    "gather": _gather_cost,
    "scatter": _scatter_cost,
    "scatter-add": _scatter_cost,
    "scatter_add": _scatter_cost,
    "scatter_max": _scatter_cost,
    "scatter_min": _scatter_cost,
    "scatter_mul": _scatter_cost,
    "dynamic_slice": _dynamic_slice_cost,
    "dynamic_update_slice": _dynamic_update_slice_cost,
    "sort": _sort_cost,
    "top_k": _sort_cost,
    "approx_top_k": _sort_cost,
    "all_to_all": _all_to_all_cost,
    "all_gather": _all_gather_cost,
    "psum": _psum_like_cost("psum"),
    "psum2": _psum_like_cost("psum"),
    "psum_scatter": _psum_like_cost("psum_scatter"),
    "reduce_scatter": _psum_like_cost("reduce_scatter"),
    "ppermute": _psum_like_cost("ppermute"),
    "pmin": _psum_like_cost("psum"),
    "pmax": _psum_like_cost("psum"),
    "pgather": _psum_like_cost("pgather"),
}


def register_primitive_cost(name: str, handler: Callable) -> None:
    """Install/override the cost handler for primitive ``name``.

    ``handler(eqn, axis_sizes) -> CostEstimate`` accounts ONE equation;
    sub-jaxpr recursion stays with the interpreter.  See the package
    docstring ("adding a primitive cost") for the checklist.
    """
    _HANDLERS[name] = handler


def _default_cost(eqn, axis_sizes) -> CostEstimate:
    name = eqn.primitive.name
    if name in FREE_PRIMS:
        return CostEstimate()
    if name in LAYOUT_PRIMS:
        return CostEstimate(hbm_bytes=_io_bytes(eqn))
    if name.startswith("reduce_"):
        in_elems = float(sum(math.prod(getattr(v.aval, "shape", ()))
                             for v in eqn.invars))
        return CostEstimate(flops=in_elems, hbm_bytes=_io_bytes(eqn))
    # element-wise / everything else: one flop per output element
    return CostEstimate(flops=_out_elems(eqn), hbm_bytes=_io_bytes(eqn))


# ---------------------------------------------------------------------------
# Interpreter
# ---------------------------------------------------------------------------

# Higher-order primitives with their own recursion rule; anything else
# carrying a sub-jaxpr in its params (pjit, custom_jvp_call, remat, ...)
# sums the body at multiplier 1 on top of a zero own-cost.
def _grid_size(eqn) -> float:
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", None) or eqn.params.get("grid") or ()
    return float(math.prod(int(g) for g in grid)) or 1.0


def _sub_jaxprs_of(eqn):
    from repro.analysis.jaxpr_walk import _sub_jaxprs
    return list(_sub_jaxprs(eqn.params))


def cost_of_jaxpr(jaxpr, *, axis_sizes: Optional[dict] = None
                  ) -> CostEstimate:
    """Symbolic resource totals for a traced jaxpr (ClosedJaxpr ok).

    ``axis_sizes`` maps mesh axis names to sizes for collectives traced
    OUTSIDE a ``shard_map`` (inside one, the mesh param wins).
    """
    return _cost(as_jaxpr(jaxpr), dict(axis_sizes or {}))


def _cost(jaxpr, axis_sizes: dict) -> CostEstimate:
    total = CostEstimate()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        subs = _sub_jaxprs_of(eqn)
        if name == "scan" and subs:
            body = _cost(subs[0], axis_sizes)
            total.add(body, float(eqn.params.get("length") or 1))
        elif name in ("while", "while_loop") and subs:
            for sub in subs:                      # cond + body, one trip
                total.add(_cost(sub, axis_sizes))
            total.inexact = True
        elif name == "cond" and subs:
            branches = [_cost(sub, axis_sizes) for sub in subs]
            worst = CostEstimate()
            for b in branches:
                worst.flops = max(worst.flops, b.flops)
                worst.hbm_bytes = max(worst.hbm_bytes, b.hbm_bytes)
                for k, v in b.coll_payload.items():
                    worst.coll_payload[k] = max(
                        worst.coll_payload.get(k, 0.0), v)
                for k, v in b.coll_wire.items():
                    worst.coll_wire[k] = max(worst.coll_wire.get(k, 0.0), v)
                for k, v in b.coll_count.items():
                    worst.coll_count[k] = max(worst.coll_count.get(k, 0), v)
                worst.inexact = worst.inexact or b.inexact
            total.add(worst)
        elif name == "shard_map" and subs:
            inner_axes = dict(axis_sizes)
            mesh = eqn.params.get("mesh")
            if mesh is not None:
                inner_axes.update({k: int(v)
                                   for k, v in dict(mesh.shape).items()})
            for sub in subs:
                total.add(_cost(sub, inner_axes))
        elif name == "pallas_call" and subs:
            mult = _grid_size(eqn)
            for sub in subs:
                total.add(_cost(sub, axis_sizes), mult)
        elif subs:
            # pjit / custom_jvp_call / remat / closed_call / ...
            for sub in subs:
                total.add(_cost(sub, axis_sizes))
        else:
            handler = _HANDLERS.get(name, _default_cost)
            total.add(handler(eqn, axis_sizes))
    return total


# ---------------------------------------------------------------------------
# Peak-live-buffer estimator
# ---------------------------------------------------------------------------

def peak_bytes_of(jaxpr) -> float:
    """Peak concurrently-live bytes via a per-level last-use scan.

    At equation ``i`` the live set is the jaxpr's inputs/consts plus
    every defined value whose last use is at or after ``i``, plus the
    equation's own outputs; a sub-jaxpr contributes its own peak on top
    of the point it runs at.  Scale estimator, not buffer assignment:
    no donation, aliasing, or rematerialisation modelling.
    """
    return _peak(as_jaxpr(jaxpr))


def _var_key(v):
    return id(v)


def _peak(jaxpr) -> float:
    eqns = jaxpr.eqns
    base = {}
    for v in list(jaxpr.invars) + list(jaxpr.constvars):
        base[_var_key(v)] = aval_bytes(v.aval)

    last_use = {}
    n = len(eqns)
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if hasattr(v, "aval") and not _is_literal(v):
                last_use[_var_key(v)] = i
    for v in jaxpr.outvars:
        if hasattr(v, "aval") and not _is_literal(v):
            last_use[_var_key(v)] = n

    live = dict(base)            # var key -> bytes, currently live
    peak = float(sum(live.values()))
    for i, eqn in enumerate(eqns):
        for v in eqn.outvars:
            live[_var_key(v)] = aval_bytes(v.aval)
        here = float(sum(live.values()))
        sub_peak = 0.0
        subs = _sub_jaxprs_of(eqn)
        if subs:
            sub_peak = max(_peak(sub) for sub in subs)
            # the sub-jaxpr's inputs/outputs are already in ``here`` as
            # this eqn's operands/results; only the EXTRA interior
            # footprint stacks on top.
            boundary = sum(aval_bytes(v.aval) for v in eqn.invars
                           if hasattr(v, "aval")) + \
                sum(aval_bytes(v.aval) for v in eqn.outvars)
            sub_peak = max(0.0, sub_peak - boundary)
        peak = max(peak, here + sub_peak)
        # retire values whose last use was this equation
        for v in eqn.invars:
            if not hasattr(v, "aval") or _is_literal(v):
                continue
            k = _var_key(v)
            if last_use.get(k, n) <= i and k in live and k not in base:
                del live[k]
    return peak


def _is_literal(v) -> bool:
    return type(v).__name__ == "Literal"
