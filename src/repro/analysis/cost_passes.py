"""Static cost-certificate passes (ISSUE 10 tentpole).

Four passes built on :mod:`repro.analysis.cost_model` — the executable
restatement of the paper's Figures 10–11 claims, run abstractly (no
compilation, no FLOPs) against the REAL engine entry points:

* :class:`DispatchCostScaling` (``cost-dispatch-scaling``) — for every
  ``(backend, kv_buckets, mesh)`` dispatch group, trace
  ``dispatch_layer`` at three matched-capacity sequence lengths and
  certify the FLOP/byte totals are EXACTLY affine in ``T_kv`` (zero
  second difference — any smuggled dense ``T_kv``-wide einsum is
  super-linear and blows the curvature), with the linear per-token
  coefficient bounded by the dense K/V-projection budget the dispatch
  legitimately pays (traced from the same cost model, ×
  :data:`KAPPA_TOKEN` slack).  At fixed ``n`` three plan densities
  certify the live-slot slope: cost strictly increases with the plan's
  ``q``/pair slot capacities (GEMM-Q against live ``q`` slots, GEMM-O /
  attention against the pair-slot product).  Finally every registered
  strategy's dispatch trace must cost bit-identically to its group
  baseline — ``dispatch_layer`` never consults the strategy, so ANY
  cost difference means strategy content leaked into Dispatch.
* :class:`CollectiveBytesBudget` (``cost-collective-bytes``) — the mesh
  seq-mode dispatch's all-to-all payload must EQUAL the ``pair_cap``
  formula ``2 · B·H·P·pair_cap·block_kv·dh · itemsize`` (one exchange
  per K and V), stay under half the dense KV all-gather baseline at 25%
  density, and bring no other collective kind; head mode spends zero
  collectives.  This subsumes the HLO-text heuristic in
  ``launch/dryrun.collective_bytes`` (now a cross-checked consumer).
* :class:`UpdateAmortization` (``cost-update-amortization``) — Update
  (dense step + symbol emit + plan build) costs at most
  :data:`KAPPA_UPDATE` × one dense reference step, and the
  interval-amortized engine ``(update + (interval−1)·dispatch) /
  interval`` beats :data:`THETA_AMORTIZED` × dense — an engine that
  rebuilds the plan every dispatch pays update-cost every step and
  fails this line.
* :class:`MemoryFootprint` (``cost-memory-footprint``) — the peak-live
  -buffer estimate of every traced executable stays inside
  :data:`PEAK_BUDGETS` (measured on the seed geometry + headroom), and
  the serving lane-scan tick's peak is affine in the lane count: the
  marginal bytes of lanes 2→4 and 4→6 must agree, so a lane-count
  change can never alter per-lane bytes (a ``lanes²`` buffer fails).

All thresholds were calibrated against the engine at the analyzer's
tiny trace geometry and hold with 30–50% headroom; they are meant to
catch order-of-magnitude regressions (dense work on the dispatch path,
full-KV collectives, plan rebuilds per step), not 1% drift.

The ``*_findings`` helpers are pure functions over
:class:`~repro.analysis.cost_model.CostEstimate` values so the
adversarial CLI fixtures (``python -m repro.analysis --fixture
cost-*``) and tests can feed them poisoned traces directly.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.cost_model import (CostEstimate, cost_of_jaxpr,
                                       peak_bytes_of)
from repro.analysis.passes import (_B, _DH, _DM, _H, _N, _engine_cfg,
                                   _params, mesh_capacity, trace_pair)
from repro.core.lru import LruCache

__all__ = ["DispatchCostScaling", "CollectiveBytesBudget",
           "UpdateAmortization", "MemoryFootprint", "COST_PASSES",
           "token_scaling_findings", "collective_findings",
           "amortization_findings", "footprint_findings",
           "expected_a2a_payload", "KAPPA_TOKEN", "KAPPA_UPDATE",
           "THETA_AMORTIZED", "PEAK_BUDGETS"]


# Matched-capacity sequence lengths for the T_kv-independence scan.
_NS = (128, 256, 384)

# Per-token FLOP/byte slack over the dense-projection reference (the
# K/V projections + RMSNorm + reuse/bias buffers dispatch must pay per
# token).  Measured slopes across all 8 groups: 0.85×–1.21× the FLOP
# reference, 1.7×–3.6× the byte reference (mesh groups stage the local
# KV slice per shard).
KAPPA_TOKEN = 2.0
KAPPA_TOKEN_BYTES = 5.0

# Update ≤ KAPPA_UPDATE × dense step (measured 1.10× flops, 1.47×
# bytes); amortized interval ≤ THETA_AMORTIZED × dense (measured 0.62×
# xla / 0.72× pallas at 50% density; a rebuild-every-dispatch engine
# sits at the update ratio ≥ 1.09 and fails).
KAPPA_UPDATE = 1.5
KAPPA_UPDATE_BYTES = 2.5
THETA_AMORTIZED = 0.95

# Peak-live-byte budgets at the analyzer trace geometry (measured max
# across the 8 dispatch groups: update 380 KB, dispatch 530 KB; lane
# tick base 966 KB + 311 KB/lane).  ~35% headroom.
PEAK_BUDGETS = {
    "update_layer": 512_000,
    "dispatch_layer": 720_000,
    "lane_tick_base": 1_400_000,
    "lane_tick_per_lane": 450_000,
}
# Lane marginals must agree to this relative tolerance (measured 0.0).
LANE_MARGINAL_RTOL = 0.02

_COST_CACHE = LruCache(maxsize=256)


def dispatch_groups(kv_buckets=(1, 3), meshes=(False, True)):
    """The strategy-independent dispatch trace grid: ``dispatch_layer``
    never consults ``cfg.strategy``, so one (backend, kv_buckets, mesh)
    cell covers every strategy's dispatch jaxpr."""
    for backend, kvb, mesh in itertools.product(
            ("xla", "pallas"), kv_buckets, meshes):
        label = f"{backend}/kv_buckets={kvb}/{'mesh' if mesh else 'single'}"
        kw = dict(backend=backend, kv_buckets=kvb)
        if backend == "pallas":
            kw["interpret"] = True
        if mesh:
            if mesh_capacity() < 2:
                yield label, None, "needs >= 2 devices"
                continue
            kw.update(mesh_dp=1, mesh_sp=2)
        yield label, _engine_cfg(**kw), None


def _matched(cfg, capq_cmp: int, capkv_cmp: int, n: int):
    """Pin the COMPRESSED-granularity capacities regardless of ``n`` so
    the block caps (and hence the plan's live slots) stay constant while
    ``T_kv`` scales — the knob behind the T_kv-independence scan."""
    t = cfg.mask.n_blocks(n)
    return dataclasses.replace(cfg, cap_q_frac=capq_cmp / t,
                               cap_kv_frac=capkv_cmp / t)


def _dispatch_cost(cfg, n: int) -> CostEstimate:
    key = ("dispatch", cfg, n)
    hit = _COST_CACHE.get(key)
    if hit is not None:
        return hit
    _, disp = trace_pair(cfg, n=n, dispatch_only=True)
    return _COST_CACHE.put(key, cost_of_jaxpr(disp))


def _update_cost(cfg, n: int) -> CostEstimate:
    key = ("update", cfg, n)
    hit = _COST_CACHE.get(key)
    if hit is not None:
        return hit
    upd, _ = trace_pair(cfg, n=n)
    return _COST_CACHE.put(key, cost_of_jaxpr(upd))


def _dense_reference_cost(n: int) -> CostEstimate:
    """One dense attention step (projections + dense attention + output
    GEMM) — the UpdateAmortization yardstick."""
    from repro.core.attention import dense_attention
    from repro.core.engine import _project_heads, _qk
    p = _params()

    def dense_layer(x):
        q, k = _qk(p, x, _H, None)
        v = _project_heads(x, p.wv, _H)
        o = dense_attention(q, k, v)
        wo_h = p.wo.reshape(_H, _DH, _DM)
        return jnp.einsum("bnhd,hdf->bnf", o.transpose(0, 2, 1, 3), wo_h)

    key = ("dense-ref", n)
    hit = _COST_CACHE.get(key)
    if hit is not None:
        return hit
    jx = jax.make_jaxpr(dense_layer)(
        jax.ShapeDtypeStruct((_B, n, _DM), jnp.float32))
    return _COST_CACHE.put(key, cost_of_jaxpr(jx))


def _token_reference_slope() -> tuple:
    """(flops, bytes) per token of the work dispatch legitimately pays
    for EVERY token regardless of the plan: dense K/V projections,
    RMSNorm, and the reuse/bias buffers.  Traced from the cost model
    itself so the budget tracks the engine, not a hand-typed constant."""
    from repro.core.engine import _project_heads, rms_norm
    p = _params()

    def per_token(x):
        k_h = rms_norm(_project_heads(x, p.wk, _H), p.k_scale)
        v_h = _project_heads(x, p.wv, _H)
        o_reuse = jnp.zeros((x.shape[0], _H, x.shape[1], _DH), x.dtype)
        return k_h, v_h, o_reuse, x + jnp.zeros_like(x)

    key = ("token-ref",)
    hit = _COST_CACHE.get(key)
    if hit is not None:
        return hit
    costs = [cost_of_jaxpr(jax.make_jaxpr(per_token)(
        jax.ShapeDtypeStruct((_B, n, _DM), jnp.float32)))
        for n in (_NS[0], _NS[1])]
    dn = _NS[1] - _NS[0]
    return _COST_CACHE.put(key, ((costs[1].flops - costs[0].flops) / dn,
                                 (costs[1].hbm_bytes - costs[0].hbm_bytes)
                                 / dn))


# ---------------------------------------------------------------------------
# Pure finding helpers (shared with the CLI fixtures / tests)
# ---------------------------------------------------------------------------

def token_scaling_findings(pass_name: str, where: str,
                           costs: Sequence[CostEstimate],
                           ns: Sequence[int],
                           budget_flops: float,
                           budget_bytes: float) -> List:
    """Certify ``costs`` over matched-capacity lengths ``ns``: exactly
    affine in n (zero curvature) with slope within the per-token budget."""
    from repro.analysis import Finding
    findings = []
    assert len(costs) == len(ns) == 3 and ns[2] - ns[1] == ns[1] - ns[0]
    dn = ns[1] - ns[0]
    for attr, budget, unit in (("flops", budget_flops, "flops"),
                               ("hbm_bytes", budget_bytes, "bytes")):
        v = [getattr(c, attr) for c in costs]
        d1, d2 = v[1] - v[0], v[2] - v[1]
        curv = abs(d2 - d1) / max(v[1], 1.0)
        if curv > 1e-9:
            findings.append(Finding(
                pass_name, "tkv-superlinear", where,
                f"{unit} not affine in T_kv at fixed plan capacity: "
                f"Δ({ns[0]}->{ns[1]})={d1:.0f} vs Δ({ns[1]}->{ns[2]})="
                f"{d2:.0f} — dense T_kv-dependent work on the dispatch "
                f"path"))
        slope = d1 / dn
        if slope > budget:
            findings.append(Finding(
                pass_name, "token-slope-budget", where,
                f"per-token {unit} slope {slope:.0f} exceeds the dense-"
                f"projection budget {budget:.0f} — dispatch pays more "
                f"than the legitimate per-token work"))
    return findings


def expected_a2a_payload(cfg, n: int) -> float:
    """The pair_cap formula: 2 exchanges (K and V) of
    ``(B/dp, H, P, pair_cap, block_kv, dh)`` f32 blocks."""
    from repro.distributed.plan_shard import shard_geometry
    m = cfg.mask
    spec = cfg.caps(n)
    t_kv = m.n_blocks(n) * (m.pool // m.block_kv)
    geom = shard_geometry(spec, t_kv, t_kv, cfg.mesh_sp,
                          cfg.mesh_pair_slack)
    b_local = max(1, _B // cfg.mesh_dp)
    return 2.0 * (b_local * _H * cfg.mesh_sp * geom.pair_cap
                  * m.block_kv * _DH) * 4


def collective_findings(pass_name: str, where: str, cost: CostEstimate,
                        expected_payload: float,
                        dense_payload: float) -> List:
    """Certify a seq-mode mesh dispatch cost: exactly two all-to-alls
    whose payload equals the ``pair_cap`` formula, under half the dense
    all-gather, and nothing else on the wire."""
    from repro.analysis import Finding
    findings = []
    a2a = cost.coll_payload.get("all_to_all", 0.0)
    if cost.coll_count.get("all_to_all", 0) != 2:
        findings.append(Finding(
            pass_name, "a2a-count", where,
            f"expected exactly 2 all_to_all (one per K and V), found "
            f"{cost.coll_count.get('all_to_all', 0)}"))
    if a2a != expected_payload:
        findings.append(Finding(
            pass_name, "pair-cap-formula", where,
            f"all_to_all payload {a2a:.0f}B != pair_cap formula "
            f"{expected_payload:.0f}B — the exchange is not shipping "
            f"exactly the plan-live KV blocks"))
    extra = {k: v for k, v in cost.coll_payload.items()
             if k != "all_to_all" and v}
    if extra:
        findings.append(Finding(
            pass_name, "no-extra-collectives", where,
            f"unexpected collective bytes {extra} — mesh dispatch must "
            f"ship only the plan-aware a2a payload"))
    if dense_payload and a2a >= 0.5 * dense_payload:
        findings.append(Finding(
            pass_name, "dense-ratio", where,
            f"plan-aware payload {a2a:.0f}B >= 0.5x the dense KV "
            f"all-gather {dense_payload:.0f}B — O(T_kv) communication"))
    return findings


def amortization_findings(pass_name: str, where: str,
                          update_cost: CostEstimate,
                          dispatch_cost: CostEstimate,
                          dense_cost: CostEstimate,
                          interval: int) -> List:
    from repro.analysis import Finding
    findings = []
    if update_cost.flops > KAPPA_UPDATE * dense_cost.flops:
        findings.append(Finding(
            pass_name, "update-cost-bound", where,
            f"Update flops {update_cost.flops:.0f} > {KAPPA_UPDATE}x one "
            f"dense step ({dense_cost.flops:.0f}) — plan construction "
            f"dominates the interval"))
    if update_cost.hbm_bytes > KAPPA_UPDATE_BYTES * dense_cost.hbm_bytes:
        findings.append(Finding(
            pass_name, "update-bytes-bound", where,
            f"Update bytes {update_cost.hbm_bytes:.0f} > "
            f"{KAPPA_UPDATE_BYTES}x one dense step "
            f"({dense_cost.hbm_bytes:.0f})"))
    amort = (update_cost.flops + (interval - 1) * dispatch_cost.flops) \
        / (interval * dense_cost.flops)
    if amort > THETA_AMORTIZED:
        findings.append(Finding(
            pass_name, "interval-amortization", where,
            f"amortized interval cost {amort:.3f}x dense exceeds "
            f"{THETA_AMORTIZED}x — the Update is not amortized over the "
            f"interval (a plan rebuilt every dispatch lands here)"))
    return findings


def footprint_findings(pass_name: str, where: str, peak: float,
                       budget: float) -> List:
    from repro.analysis import Finding
    if peak <= budget:
        return []
    return [Finding(
        pass_name, "peak-bytes-budget", where,
        f"estimated peak live bytes {peak:.0f} exceed the declared "
        f"budget {budget:.0f} — a new executable-sized buffer joined "
        f"this trace")]


# ---------------------------------------------------------------------------
# The passes
# ---------------------------------------------------------------------------

class DispatchCostScaling:
    """Dispatch cost ∝ plan slots, never T_kv (the Fig. 10/11 claim)."""

    name = "cost-dispatch-scaling"

    def run(self, ctx) -> List:
        from repro.analysis import Finding
        from repro.core.strategy import available_strategies
        findings = []
        ref_f, ref_b = _token_reference_slope()
        for label, cfg0, skip in dispatch_groups():
            if skip is not None:
                ctx.note(f"{self.name}: skipped {label} ({skip})")
                continue
            # 1. T_kv-independence: matched caps, three lengths.
            costs = [_dispatch_cost(_matched(cfg0, 2, 2, n), n) for n in _NS]
            findings += token_scaling_findings(
                self.name, f"dispatch_layer[{label}]", costs, _NS,
                budget_flops=KAPPA_TOKEN * ref_f,
                budget_bytes=KAPPA_TOKEN_BYTES * ref_b)
            # 2. Live-slot slope: density scan at fixed n.
            n0 = _NS[0]
            dens = [(1, 1), (2, 2), (3, 4)]
            dcosts = [_dispatch_cost(_matched(cfg0, cq, ck, n0), n0)
                      for cq, ck in dens]
            slots = [cq * ck for cq, ck in dens]
            for i in range(1, len(dcosts)):
                if dcosts[i].flops <= dcosts[i - 1].flops:
                    findings.append(Finding(
                        self.name, "slot-slope", f"dispatch_layer[{label}]",
                        f"dispatch flops not increasing with live plan "
                        f"slots ({slots[i - 1]}->{slots[i]}): "
                        f"{dcosts[i - 1].flops:.0f} -> "
                        f"{dcosts[i].flops:.0f} — cost is not plan-"
                        f"proportional"))
            slope = (dcosts[-1].flops - dcosts[0].flops) / \
                (slots[-1] - slots[0])
            ctx.note(f"{self.name}: {label} slot slope "
                     f"{slope:.0f} flops/pair-slot, token slope "
                     f"{(costs[1].flops - costs[0].flops) / (_NS[1] - _NS[0]):.0f} "
                     f"flops/token (budget {KAPPA_TOKEN * ref_f:.0f})")
        # 3. Strategy leak: every strategy must cost its group baseline.
        base = {}
        for label, cfg0, skip in dispatch_groups():
            if skip is None:
                base[label] = _dispatch_cost(cfg0, _N)
        for strat in available_strategies():
            for label, cfg0, skip in dispatch_groups():
                if skip is not None:
                    continue
                cfg = dataclasses.replace(cfg0, strategy=strat)
                c = _dispatch_cost(cfg, _N)
                b = base[label]
                if (c.flops, c.hbm_bytes) != (b.flops, b.hbm_bytes) or \
                        c.coll_payload != b.coll_payload:
                    findings.append(Finding(
                        self.name, "strategy-leak",
                        f"dispatch_layer[{strat}/{label}]",
                        f"dispatch cost ({c.flops:.0f} flops, "
                        f"{c.hbm_bytes:.0f}B) differs from the group "
                        f"baseline ({b.flops:.0f}, {b.hbm_bytes:.0f}B) — "
                        f"strategy content reached the Dispatch jaxpr"))
        return findings


class CollectiveBytesBudget:
    """Mesh a2a bytes ≡ the pair_cap formula, never O(T_kv)."""

    name = "cost-collective-bytes"
    DENSITY_CMP = 2            # compressed-cap target ≈ 25% at n=256
    N = 256

    def run(self, ctx) -> List:
        from repro.analysis import Finding
        findings = []
        if mesh_capacity() < 2:
            ctx.note(f"{self.name}: skipped (needs >= 2 devices; run via "
                     "`make analyze` / python -m repro.analysis)")
            return findings
        cfg = _matched(_engine_cfg(backend="xla", mesh_dp=1, mesh_sp=2),
                       self.DENSITY_CMP, self.DENSITY_CMP, self.N)
        cost = _dispatch_cost(cfg, self.N)
        expected = expected_a2a_payload(cfg, self.N)
        # dense baseline: all-gather of the full K and V (result bytes
        # per shard — same convention as the dry-run HLO parser).
        dense_payload = 2.0 * (_B * _H * self.N * _DH) * 4
        findings += collective_findings(
            self.name, f"dispatch_layer[mesh seq, n={self.N}, "
            f"cap_cmp={self.DENSITY_CMP}]", cost, expected, dense_payload)
        ctx.note(f"{self.name}: a2a payload {cost.coll_payload.get('all_to_all', 0):.0f}B "
                 f"= pair_cap formula, {cost.coll_payload.get('all_to_all', 0) / dense_payload:.3f}x "
                 f"dense all-gather")
        # head mode: zero collectives of any kind.
        cfg_h = _engine_cfg(backend="xla", mesh_dp=1, mesh_sp=2,
                            mesh_axis="head")
        cost_h = _dispatch_cost(cfg_h, _N)
        if cost_h.coll_payload:
            findings.append(Finding(
                self.name, "head-mode-collectives",
                "dispatch_layer[mesh head]",
                f"head-mode dispatch spends collectives "
                f"{cost_h.coll_payload} — it must spend none"))
        return findings


class UpdateAmortization:
    """Update ≤ κ × dense; interval amortization beats θ × dense."""

    name = "cost-update-amortization"

    def run(self, ctx) -> List:
        findings = []
        dense = _dense_reference_cost(_N)
        for backend in ("xla", "pallas"):
            kw = dict(backend=backend, kv_buckets=1)
            if backend == "pallas":
                kw["interpret"] = True
            cfg = _matched(_engine_cfg(**kw), 2, 2, _N)   # 50% density
            u = _update_cost(cfg, _N)
            d = _dispatch_cost(cfg, _N)
            interval = cfg.mask.interval
            findings += amortization_findings(
                self.name, f"update/dispatch[{backend}]", u, d, dense,
                interval)
            ctx.note(f"{self.name}: {backend} update {u.flops / dense.flops:.2f}x "
                     f"dense, dispatch {d.flops / dense.flops:.2f}x, "
                     f"amortized {(u.flops + (interval - 1) * d.flops) / (interval * dense.flops):.2f}x")
        return findings


class MemoryFootprint:
    """Peak live bytes per executable within the declared budget table."""

    name = "cost-memory-footprint"
    LANES = (2, 4, 6)

    def run(self, ctx) -> List:
        from repro.analysis import Finding
        findings = []
        for label, cfg, skip in dispatch_groups():
            if skip is not None:
                ctx.note(f"{self.name}: skipped {label} ({skip})")
                continue
            upd, disp = trace_pair(cfg, n=_N)
            findings += footprint_findings(
                self.name, f"update_layer[{label}]", peak_bytes_of(upd),
                PEAK_BUDGETS["update_layer"])
            findings += footprint_findings(
                self.name, f"dispatch_layer[{label}]", peak_bytes_of(disp),
                PEAK_BUDGETS["dispatch_layer"])
        # Serving lane-scan tick: peak affine in lane count.
        peaks = self._tick_peaks(ctx)
        if peaks is not None:
            l0, l1, l2 = self.LANES
            m1 = (peaks[l1] - peaks[l0]) / (l1 - l0)
            m2 = (peaks[l2] - peaks[l1]) / (l2 - l1)
            if abs(m2 - m1) > LANE_MARGINAL_RTOL * max(m1, 1.0):
                findings.append(Finding(
                    self.name, "lane-bytes-affinity", "lane tick[scan]",
                    f"per-lane marginal peak bytes changed with the lane "
                    f"count: {m1:.0f}B/lane (lanes {l0}->{l1}) vs "
                    f"{m2:.0f}B/lane (lanes {l1}->{l2}) — a buffer "
                    f"scales super-linearly in lanes"))
            budget = PEAK_BUDGETS["lane_tick_base"] + \
                PEAK_BUDGETS["lane_tick_per_lane"] * max(self.LANES)
            findings += footprint_findings(
                self.name, f"lane tick[scan, lanes={max(self.LANES)}]",
                peaks[max(self.LANES)], budget)
            ctx.note(f"{self.name}: lane tick peak "
                     f"{peaks[max(self.LANES)] / 1e6:.2f}MB at "
                     f"{max(self.LANES)} lanes, marginal {m1:.0f}B/lane")
        return findings

    def _tick_peaks(self, ctx) -> Optional[dict]:
        from repro.analysis.passes import _serving_setup, _tick_avals
        from repro.diffusion.pipeline import make_lane_tick
        cfg, ecfg, scfg, strategies = _serving_setup()
        tick = make_lane_tick(cfg, ecfg, scfg, strategies)
        peaks = {}
        for lanes in self.LANES:
            av = _tick_avals(cfg, ecfg, scfg, lanes=lanes)
            try:
                jx = jax.make_jaxpr(tick)(
                    av["params"], av["patch_embed"], av["x"], av["states"],
                    av["text_emb"], av["step"], av["mode_tab"],
                    av["id_tab"], av["dt"], av["nsteps"], av["active"],
                    av["reset"])
            except Exception as e:      # noqa: BLE001 — reported as note;
                # the trace failure itself is ExecutableBudget's finding.
                ctx.note(f"{self.name}: lane tick trace failed ({e!r})")
                return None
            peaks[lanes] = peak_bytes_of(jx)
        return peaks


COST_PASSES = (DispatchCostScaling, CollectiveBytesBudget,
               UpdateAmortization, MemoryFootprint)
