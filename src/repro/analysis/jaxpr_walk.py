"""Primitive-level jaxpr inspection (the analyzer's shared walker).

Every jaxpr-facing invariant in this repo used to be asserted by
substring-grepping ``str(jax.make_jaxpr(...))`` — fragile against
primitive renames, pretty-printer changes, and (worst) silently vacuous
when the primitive hides inside a ``pjit``/``scan``/``switch`` call whose
body the printer elides.  This module walks the equation graph itself,
recursing into EVERY sub-jaxpr an equation carries in its params
(``scan``'s ``jaxpr``, ``cond``/``switch`` ``branches``, ``pjit``'s
``jaxpr``, ``shard_map``, ``custom_jvp_call``'s ``call_jaxpr``, ... —
discovery is structural, not a primitive-name allowlist, so new
higher-order primitives are covered automatically).

All entry points accept a ``ClosedJaxpr`` (what ``jax.make_jaxpr``
returns), a raw ``Jaxpr``, or anything with a ``.jaxpr`` attribute.
"""

from __future__ import annotations

from collections import Counter
from typing import Iterator, Sequence

__all__ = [
    "as_jaxpr", "iter_eqns", "primitive_counts", "find_primitives",
    "eqn_count", "INDEX_DECODE_PRIMS", "COLLECTIVE_PRIMS",
    "index_decode_eqns", "collective_counts",
]

# Primitives that constitute index-decode work (mask -> plan extraction):
# any of these inside a Dispatch jaxpr means the engine is rebuilding the
# plan instead of consuming it.  ``argsort`` lowers to ``sort`` and
# ``jax.lax.approx_max_k`` to ``approx_top_k``, so the three names cover
# the whole family; ``unpack_bits`` has no named primitive of its own —
# its signature (``shift_right_logical`` on uint8 operands) is matched
# structurally by :func:`index_decode_eqns`.
INDEX_DECODE_PRIMS = frozenset({"sort", "top_k", "approx_top_k"})

# Cross-device collectives the CollectiveBudget pass accounts for.  The
# mesh dispatch contract (distributed/plan_shard.py): seq mode spends
# exactly one all_to_all per K and per V and nothing else; head mode
# spends none at all.
COLLECTIVE_PRIMS = frozenset({
    "all_to_all", "all_gather", "psum", "psum_scatter", "reduce_scatter",
    "ppermute", "pmin", "pmax", "pgather",
})


def as_jaxpr(obj):
    """Unwrap ``ClosedJaxpr``/``make_jaxpr`` results down to a ``Jaxpr``."""
    while hasattr(obj, "jaxpr"):
        obj = obj.jaxpr
    return obj


def _sub_jaxprs(params: dict):
    """Yield every Jaxpr held (possibly in a list/tuple) in eqn params."""
    for val in params.values():
        items = val if isinstance(val, (list, tuple)) else (val,)
        for item in items:
            if hasattr(item, "eqns") or hasattr(item, "jaxpr"):
                yield as_jaxpr(item)


def iter_eqns(jaxpr, *, path: tuple = ()) -> Iterator[tuple]:
    """Depth-first ``(path, eqn)`` over the jaxpr and all sub-jaxprs.

    ``path`` is the tuple of enclosing higher-order primitive names, e.g.
    ``("scan", "pjit")`` for an equation inside a jitted scan body.
    """
    jaxpr = as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield path, eqn
        inner = path + (eqn.primitive.name,)
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, path=inner)


def primitive_counts(jaxpr) -> Counter:
    """Recursive primitive-name histogram."""
    return Counter(eqn.primitive.name for _, eqn in iter_eqns(jaxpr))


def find_primitives(jaxpr, names: Sequence[str]) -> list:
    """All ``(path, eqn)`` whose primitive name is in ``names``."""
    names = frozenset(names)
    return [(p, e) for p, e in iter_eqns(jaxpr)
            if e.primitive.name in names]


def eqn_count(jaxpr, *, recursive: bool = False) -> int:
    """Equation count; top-level only by default (the HLO-size proxy used
    by the depth-independence tests — a scan body counts once however
    many layers it covers), or the full recursive count."""
    if recursive:
        return sum(1 for _ in iter_eqns(jaxpr))
    return len(as_jaxpr(jaxpr).eqns)


def _is_uint8_unpack(eqn) -> bool:
    """Structural signature of ``symbols.unpack_bits``: a bit-shift whose
    operand is the uint8 symbol buffer."""
    if eqn.primitive.name not in ("shift_right_logical", "and"):
        return False
    return any(getattr(getattr(v, "aval", None), "dtype", None) is not None
               and str(v.aval.dtype) == "uint8" for v in eqn.invars)


def index_decode_eqns(jaxpr) -> list:
    """All ``(path, eqn)`` doing index-decode work: sort/top-k family plus
    the uint8 symbol-unpack signature (``shift_right_logical`` on the
    packed symbol buffer)."""
    hits = []
    for path, eqn in iter_eqns(jaxpr):
        if eqn.primitive.name in INDEX_DECODE_PRIMS or _is_uint8_unpack(eqn):
            hits.append((path, eqn))
    return hits


def collective_counts(jaxpr) -> Counter:
    """Histogram restricted to :data:`COLLECTIVE_PRIMS`."""
    counts = primitive_counts(jaxpr)
    return Counter({k: v for k, v in counts.items()
                    if k in COLLECTIVE_PRIMS})
