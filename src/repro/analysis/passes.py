"""The jaxpr-level analysis passes (pass family (a) of the analyzer).

Four passes, each tracing REAL engine entry points (never copies of
them) and walking the resulting jaxprs with
:mod:`repro.analysis.jaxpr_walk`:

* :class:`DispatchPurity` — every registered strategy × backend ×
  ``kv_buckets ∈ {1, 3}`` × {single-device, mesh}: the ``dispatch_layer``
  jaxpr contains no index-decode work (sort / top-k family, uint8 symbol
  unpack).  The matching ``update_layer`` jaxpr is the positive control:
  it MUST contain the decode primitives, or the walker went vacuous.
* :class:`CollectiveBudget` — ``MeshBackend`` seq-mode dispatch spends
  exactly one ``all_to_all`` per K and per V (two total) and no other
  collective; head-mode dispatch spends none at all.
* :class:`PromotionCheck` — the serving lane-tick bodies preserve every
  input dtype (bf16 latents stay bf16 — the PR-4 regression class where
  a weak f32 scalar promoted the latents and forced a recompile every
  tick).
* :class:`ExecutableBudget` — a serving configuration lowers to ≤ 4
  distinct executables per lane shape (3 mode-group bodies + the
  lane-scan fallback), and every body traces with the schedule tables
  ABSTRACT — proof the tables are traced operands, so schedule content
  can never mint a new executable.

Tracing is abstract end to end (``jax.eval_shape`` feeds
``jax.make_jaxpr``): the sweep costs compile-less traces, no FLOPs.
Mesh combos need ≥ 2 devices; in-process runs on one device record a
skip note instead (the ``python -m repro.analysis`` CLI forces an
8-device host platform before importing jax, so ``make analyze`` always
covers them).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_walk import (collective_counts, index_decode_eqns,
                                       primitive_counts)
from repro.core.lru import LruCache

__all__ = ["DispatchPurity", "CollectiveBudget", "PromotionCheck",
           "ExecutableBudget", "JAXPR_PASSES", "trace_pair"]


# Small, fast-to-trace engine geometry shared by the jaxpr sweeps.
_B, _H, _N, _DM, _DH = 1, 2, 128, 32, 16


def _mask_cfg():
    from repro.core.masks import MaskConfig
    return MaskConfig(tau_q=0.5, tau_kv=0.15, interval=4, order=1,
                      degrade=0.0, block_q=16, block_kv=16, pool=32,
                      warmup_steps=1)


def _engine_cfg(**kw):
    from repro.core.engine import EngineConfig
    return EngineConfig(mask=_mask_cfg(), cache_dtype=jnp.float32,
                        cap_q_frac=0.75, cap_kv_frac=0.9, **kw)


def _params(key=0):
    from repro.core.engine import AttnParams
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    f = _H * _DH
    return AttnParams(
        wq=jax.random.normal(ks[0], (_DM, f)) * 0.05,
        wk=jax.random.normal(ks[1], (_DM, f)) * 0.05,
        wv=jax.random.normal(ks[2], (_DM, f)) * 0.05,
        wo=jax.random.normal(ks[3], (f, _DM)) * 0.05,
        q_scale=jnp.ones(_DH), k_scale=jnp.ones(_DH))


def mesh_capacity() -> int:
    """Devices available for mesh combos (mesh_sp=2 needs two)."""
    return len(jax.devices())


def sweep_configs(kv_buckets=(1, 3), meshes=(False, True)):
    """Yield ``(label, cfg, skipped)`` over the full purity sweep grid."""
    from repro.core.strategy import available_strategies
    for strat, backend, kvb, mesh in itertools.product(
            available_strategies(), ("xla", "pallas"), kv_buckets, meshes):
        label = (f"{strat}/{backend}/kv_buckets={kvb}/"
                 f"{'mesh' if mesh else 'single'}")
        kw = dict(strategy=strat, backend=backend, kv_buckets=kvb)
        if backend == "pallas":
            kw["interpret"] = True
        if mesh:
            if mesh_capacity() < 2:
                yield label, None, "needs >= 2 devices"
                continue
            kw.update(mesh_dp=1, mesh_sp=2)
        yield label, _engine_cfg(**kw), None


# Engine traces are pure functions of (cfg, n) at the fixed analyzer
# geometry, and both pass families sweep the same grid — memoize so the
# cost passes re-walk the jaxprs the purity passes already traced.
_TRACE_CACHE = LruCache(maxsize=256)


def trace_pair(cfg, n: int = _N, dispatch_only: bool = False):
    """(update_jaxpr, dispatch_jaxpr) for ``cfg`` — abstract, no FLOPs.

    Memoized per ``(cfg, n)`` (EngineConfig is a frozen dataclass).  With
    ``dispatch_only=True`` the Update jaxpr may be ``None`` — the n-sweep
    cost scans only need the Dispatch side and skip the larger trace.
    """
    from repro.core.engine import (dispatch_layer, init_layer_state,
                                   update_layer)
    upd = _TRACE_CACHE.get(("upd", cfg, n))
    disp = _TRACE_CACHE.get(("disp", cfg, n))
    if disp is not None and (upd is not None or dispatch_only):
        return upd, disp
    p = _params()
    x = jax.ShapeDtypeStruct((_B, n, _DM), jnp.float32)
    state = init_layer_state(_B, _H, n, _DM, _DH, cfg)

    def upd_fn(xx, ss):
        return update_layer(p, xx, ss, cfg, n_text=32, heads=_H,
                            step_idx=2, num_steps=8)

    if upd is None and not dispatch_only:
        upd = _TRACE_CACHE.put(("upd", cfg, n), jax.make_jaxpr(upd_fn)(
            x, state))
    if disp is None:
        _, st_sh = jax.eval_shape(upd_fn, x, state)
        disp = _TRACE_CACHE.put(("disp", cfg, n), jax.make_jaxpr(
            lambda xx, ss: dispatch_layer(p, xx, ss, cfg, n_text=32,
                                          heads=_H))(x, st_sh))
    return upd, disp


def _trace_pair(cfg):
    """Back-compat alias at the default geometry (tests import this)."""
    return trace_pair(cfg)


class DispatchPurity:
    """No index-decode primitive in any Dispatch jaxpr (ISSUE 1/6/7/8)."""

    name = "dispatch-purity"

    def run(self, ctx) -> List:
        from repro.analysis import Finding
        findings = []
        for label, cfg, skip in sweep_configs():
            if skip is not None:
                ctx.note(f"{self.name}: skipped {label} ({skip})")
                continue
            upd, disp = _trace_pair(cfg)
            for path, eqn in index_decode_eqns(disp):
                findings.append(Finding(
                    self.name, "no-index-decode-in-dispatch",
                    f"dispatch_layer[{label}]",
                    f"{eqn.primitive.name} at {'/'.join(path) or '<top>'} — "
                    f"Dispatch is rebuilding plan indices"))
            if not index_decode_eqns(upd):
                findings.append(Finding(
                    self.name, "walker-vacuous",
                    f"update_layer[{label}]",
                    "positive control failed: the Update jaxpr shows no "
                    "sort/top-k — the walker is not seeing the real "
                    "engine trace"))
        return findings


class CollectiveBudget:
    """Mesh dispatch: one all_to_all per K and V (seq), zero in head mode."""

    name = "collective-budget"

    def run(self, ctx) -> List:
        from repro.analysis import Finding
        findings = []
        if mesh_capacity() < 2:
            ctx.note(f"{self.name}: skipped (needs >= 2 devices; "
                     "run via `make analyze` / python -m repro.analysis)")
            return findings
        for mode, want_a2a in (("seq", 2), ("head", 0)):
            cfg = _engine_cfg(backend="xla", mesh_dp=1, mesh_sp=2,
                              mesh_axis=mode)
            _, disp = _trace_pair(cfg)
            cc = collective_counts(disp)
            a2a = cc.pop("all_to_all", 0)
            if a2a != want_a2a:
                findings.append(Finding(
                    self.name, "all-to-all-budget",
                    f"dispatch_layer[mesh_axis={mode}]",
                    f"expected exactly {want_a2a} all_to_all (one per K "
                    f"and V), found {a2a}"))
            if cc:
                findings.append(Finding(
                    self.name, "no-extra-collectives",
                    f"dispatch_layer[mesh_axis={mode}]",
                    f"unexpected collectives {dict(cc)} — mesh dispatch "
                    f"must ship only the plan-live KV blocks"))
        return findings


# --- serving-tick passes ----------------------------------------------------

def _serving_setup():
    """Shared tiny serving configuration for the tick passes."""
    from repro.configs.registry import get_smoke
    from repro.core.engine import resolve_schedule
    cfg = get_smoke("flux-mmdit")
    ecfg = _engine_cfg(kv_buckets=1)
    from repro.diffusion.pipeline import SamplerConfig
    scfg = SamplerConfig(num_steps=8, dtype=jnp.float32)
    strategies = resolve_schedule(ecfg, 8, cfg.n_layers).strategies
    return cfg, ecfg, scfg, strategies


def _tick_avals(cfg, ecfg, scfg, lanes=2, nv=64, latent_dtype=jnp.bfloat16):
    """Abstract tick operands for a ``lanes``-wide microbatch."""
    from repro.core.engine import stack_lane_states
    from repro.models import dit
    s_max = scfg.num_steps
    b, pd, nt, dm = 1, cfg.patch_dim, cfg.n_text_tokens, cfg.d_model
    n_tokens = nv + nt
    sds = jax.ShapeDtypeStruct
    states = jax.eval_shape(
        lambda: stack_lane_states(
            dit.init_engine_states(cfg, ecfg, b, n_tokens), lanes))
    return dict(
        params=jax.eval_shape(lambda: dit.init_params(
            cfg, jax.random.PRNGKey(0))),
        patch_embed=sds((pd, dm), jnp.float32),
        x=sds((lanes, b, nv, pd), latent_dtype),
        states=states,
        text_emb=sds((lanes, b, nt, dm), jnp.float32),
        step=sds((lanes,), jnp.int32),
        mode_tab=sds((lanes, s_max), jnp.int32),
        id_tab=sds((lanes, s_max, cfg.n_layers), jnp.int32),
        id_rows=sds((lanes, cfg.n_layers), jnp.int32),
        dt=sds((lanes,), jnp.float32),
        nsteps=sds((lanes,), jnp.int32),
        active=sds((lanes,), jnp.bool_),
        reset=sds((lanes,), jnp.bool_),
    )


def trace_serving_ticks(latent_dtype=jnp.bfloat16):
    """Abstractly trace every serving tick body.

    Returns ``(tick_outputs, errors)`` where ``tick_outputs`` maps body
    name (``scan`` + the three mode groups) to ``(in_avals, out_avals)``.
    Bodies that fail to trace land in ``errors`` instead — schedule
    tables are abstract here, so a failure means schedule CONTENT leaked
    into trace-time control flow (an executable-budget violation).
    """
    from repro.diffusion.pipeline import (make_grouped_lane_tick,
                                          make_lane_tick)
    cfg, ecfg, scfg, strategies = _serving_setup()
    av = _tick_avals(cfg, ecfg, scfg, latent_dtype=latent_dtype)
    outs, errors = {}, {}
    tick = make_lane_tick(cfg, ecfg, scfg, strategies)
    scan_args = (av["params"], av["patch_embed"], av["x"], av["states"],
                 av["text_emb"], av["step"], av["mode_tab"], av["id_tab"],
                 av["dt"], av["nsteps"], av["active"], av["reset"])
    try:
        outs["scan"] = (av, jax.eval_shape(tick, *scan_args))
    except Exception as e:                        # noqa: BLE001 — reported
        errors["scan"] = repr(e)
    grouped = make_grouped_lane_tick(cfg, ecfg, scfg, strategies)
    grp_args = (av["params"], av["patch_embed"], av["x"], av["states"],
                av["text_emb"], av["step"], av["id_rows"], av["dt"],
                av["nsteps"], av["active"], av["reset"])
    for mode, body in grouped.items():
        try:
            outs[mode] = (av, jax.eval_shape(body, *grp_args))
        except Exception as e:                    # noqa: BLE001 — reported
            errors[mode] = repr(e)
    n_bodies = 1 + len(grouped)
    return outs, errors, n_bodies


class PromotionCheck:
    """Serving tick bodies preserve latent/state dtypes (PR-4 class)."""

    name = "promotion-check"

    def run(self, ctx) -> List:
        from repro.analysis import Finding
        findings = []
        outs, errors, _ = trace_serving_ticks(latent_dtype=jnp.bfloat16)
        for body, err in errors.items():
            findings.append(Finding(
                self.name, "tick-trace-failed", f"lane tick[{body}]", err))
        for body, (av, out) in outs.items():
            x2, st2 = out[0], out[1]
            if x2.dtype != av["x"].dtype:
                findings.append(Finding(
                    self.name, "latent-promotion", f"lane tick[{body}]",
                    f"latents promoted {av['x'].dtype} -> {x2.dtype}: the "
                    f"next tick's operands change dtype and recompile"))
            in_leaves = jax.tree.leaves(av["states"])
            out_leaves = jax.tree.leaves(st2)
            for i, (a, b) in enumerate(zip(in_leaves, out_leaves)):
                if a.dtype != b.dtype:
                    findings.append(Finding(
                        self.name, "state-promotion", f"lane tick[{body}]",
                        f"engine-state leaf {i} promoted {a.dtype} -> "
                        f"{b.dtype}"))
        return findings


class ExecutableBudget:
    """Serving lowers to ≤ 4 executables, schedule content stays traced."""

    name = "executable-budget"
    LIMIT = 4

    def run(self, ctx) -> List:
        from repro.analysis import Finding
        findings = []
        outs, errors, n_bodies = trace_serving_ticks(
            latent_dtype=jnp.float32)
        if n_bodies > self.LIMIT:
            findings.append(Finding(
                self.name, "budget-exceeded", "serving ticks",
                f"{n_bodies} distinct jitted tick bodies per lane shape "
                f"(budget {self.LIMIT})"))
        for body, err in errors.items():
            findings.append(Finding(
                self.name, "schedule-content-leak", f"lane tick[{body}]",
                f"body does not trace with ABSTRACT schedule tables — "
                f"schedule content reached trace-time control flow and "
                f"would mint per-schedule executables: {err}"))
        # The scan fallback must keep its lane loop rolled: one lax.scan
        # over lanes, not a per-lane unroll (budget is per lane SHAPE).
        from repro.diffusion.pipeline import make_lane_tick
        cfg, ecfg, scfg, strategies = _serving_setup()
        av = _tick_avals(cfg, ecfg, scfg, latent_dtype=jnp.float32)
        tick = make_lane_tick(cfg, ecfg, scfg, strategies)
        jx = jax.make_jaxpr(lambda *a: tick(*a))(
            av["params"], av["patch_embed"], av["x"], av["states"],
            av["text_emb"], av["step"], av["mode_tab"], av["id_tab"],
            av["dt"], av["nsteps"], av["active"], av["reset"])
        counts = primitive_counts(jx)
        if counts.get("scan", 0) < 1:
            findings.append(Finding(
                self.name, "lane-scan-unrolled", "lane tick[scan]",
                "the lane-serial fallback contains no lax.scan — lanes "
                "unrolled into the jaxpr scale compile time with width"))
        return findings


JAXPR_PASSES = (DispatchPurity, CollectiveBudget, PromotionCheck,
                ExecutableBudget)
