"""Structural validator for :class:`repro.core.plan.DispatchPlan`.

A pure host-side (numpy) checker over any concrete plan pytree.  One
entry point, :func:`check_plan`, returns a list of human-readable
violation strings (empty = the plan is well-formed); :func:`validate_plan`
raises :class:`PlanInvariantError` on the first non-empty result.

Checked invariant families (the "Invariant catalog" in ROADMAP.md maps
each to its originating PR):

* **CSR well-formedness** — every count within its static capacity, every
  id list in range with a strictly ascending live prefix (the
  ``active_indices`` contract: padding slots repeat the last live id),
  GEMM-O padding rows with EMPTY head lists (``head_cnt == 0`` and an
  all-False ``head_mask`` — the bias-aliased Pallas output re-accumulates
  otherwise), and ``head_cnt`` ≡ ``head_mask`` row sums.
* **Shared-truncation fold-back** — the uniform per-row CSR lists are the
  single source of truth: ``bkt_*`` (PR 6), ``gmo_*`` (PR 8) and
  ``shd_*`` (PR 7) layouts must all re-derive from the SAME truncated
  ``kv_row_cnt``/``head_cnt``.  The checker maps each layout row back to
  its (head, slot) origin and compares counts and id prefixes.
* **``occ_hist`` consistency** — recomputed from the final counts via
  :func:`repro.core.plan.occupancy_histogram` and compared bit-exactly
  (the autotuner's calibration signal must describe the plan that runs).
* **``widen()`` completeness** — no int16 leaf may survive ``widen()``;
  a field that does was forgotten in the round-trip (the exact bug class
  the int16 compaction of PRs 6/8 can reintroduce with every new field).

Plans may carry extra leading axes (layer stacking ``(L, ...)``, serving
lanes ``(W, L, ...)``) — all checks flatten them into the batch axis.

Opt-in live hook: ``EngineConfig.validate_plans=True`` or
``REPRO_VALIDATE_PLANS=1`` makes ``build_dispatch_plan`` schedule this
checker on host (``jax.debug.callback``) after every plan build.
"""

from __future__ import annotations

import os
from typing import List, Optional

import numpy as np

__all__ = ["PlanInvariantError", "check_plan", "validate_plan",
           "validation_enabled"]


class PlanInvariantError(AssertionError):
    """A DispatchPlan violated a structural invariant."""


def validation_enabled(cfg) -> bool:
    """The live-hook gate: config flag OR environment opt-in."""
    if getattr(cfg, "validate_plans", False):
        return True
    return os.environ.get("REPRO_VALIDATE_PLANS", "0") not in ("", "0")


# Trailing (core) rank of every DispatchPlan field; leading axes beyond
# it are lane/layer stacking and get flattened into batch.
_CORE_RANK = {
    "q_ids": 3, "q_cnt": 2, "q_slots": 3, "kv_ids": 3, "kv_cnt": 2,
    "pair_live": 4, "kv_row_ids": 4, "kv_row_cnt": 3,
    "row_ids": 2, "row_cnt": 1, "head_ids": 3, "head_cnt": 2,
    "head_mask": 3, "m_ch": 3, "row_score": 2, "occ_hist": 2,
    "bkt_head": 2, "bkt_q_ids": 2, "bkt_q_src": 2, "bkt_q_slots": 2,
    "bkt_kv_ids": 2, "bkt_kv_cnt": 2,
    "gmo_rows": 2, "gmo_src": 2, "gmo_head_ids": 2, "gmo_head_cnt": 2,
    "shd_q_ids": 4, "shd_q_src": 4, "shd_q_slots": 4, "shd_q_cnt": 3,
    "shd_kv_ids": 4, "shd_kv_cnt": 3, "shd_kv_row_ids": 5,
    "shd_kv_row_cnt": 4, "shd_gather_idx": 4, "shd_send_ids": 5,
    "shd_send_cnt": 4,
}


class _Canon:
    """Numpy view of a plan with extra leading axes folded into batch."""

    def __init__(self, plan):
        extra = np.asarray(plan.q_cnt).ndim - _CORE_RANK["q_cnt"]
        self.extra = extra
        self._plan = plan

    def __getattr__(self, name):
        val = getattr(self._plan, name)
        if val is None:
            return None
        arr = np.asarray(val)
        core = _CORE_RANK[name]
        want = core + self.extra
        if arr.ndim != want:
            raise PlanInvariantError(
                f"plan.{name}: rank {arr.ndim} != expected {want} "
                f"(core {core} + {self.extra} stacked axes)")
        if core == 0:
            return arr.reshape(-1)[0]
        return arr.reshape(-1, *arr.shape[arr.ndim - core + 1:])


def _prefix_valid(ids: np.ndarray, cnt: np.ndarray) -> np.ndarray:
    """(..., C) bool: slot index < count."""
    c = ids.shape[-1]
    return np.arange(c) < cnt[..., None]


def _check_id_list(out: List[str], name: str, ids, cnt, hi: int,
                   ascending: bool = True) -> None:
    """Range + ascending-prefix checks shared by every CSR list."""
    if (cnt < 0).any() or (cnt > ids.shape[-1]).any():
        out.append(f"{name}: count outside [0, {ids.shape[-1]}] "
                   f"(max {int(cnt.max())})")
    if (ids < 0).any() or (ids >= hi).any():
        out.append(f"{name}: id outside [0, {hi}) "
                   f"(range [{int(ids.min())}, {int(ids.max())}])")
        return
    if ascending and ids.shape[-1] > 1:
        valid = _prefix_valid(ids, cnt)
        both = valid[..., 1:] & valid[..., :-1]
        if (both & (ids[..., 1:] <= ids[..., :-1])).any():
            out.append(f"{name}: live prefix not strictly ascending")


def _membership(ids, cnt, hi: int) -> np.ndarray:
    """(..., hi) bool table of the live prefix of an id list."""
    sent = np.where(_prefix_valid(ids, cnt), ids, hi)
    table = np.zeros((*ids.shape[:-1], hi + 1), bool)
    np.put_along_axis(table, sent, True, axis=-1)
    return table[..., :hi]


def _slot_of(ids, cnt, hi: int) -> np.ndarray:
    """(..., hi) int: live id -> its slot in the list, -1 elsewhere."""
    c = ids.shape[-1]
    valid = _prefix_valid(ids, cnt)
    sent = np.where(valid, ids, hi)
    pos = np.full((*ids.shape[:-1], hi + 1), -1, np.int64)
    np.put_along_axis(
        pos, sent, np.where(valid, np.arange(c), -1), axis=-1)
    return pos[..., :hi]


def _occ_hist_np(kv_row_cnt, q_cnt, cap_kv: int) -> np.ndarray:
    """NumPy recompute of :func:`repro.core.plan.occupancy_histogram`.

    Deliberately an independent implementation (the recompute-and-compare
    check would be vacuous against itself), and NumPy so the checker can
    run on the jax.debug.callback thread — see :func:`check_plan`.
    """
    from repro.core.plan import OCC_BINS
    live = (np.arange(kv_row_cnt.shape[-1], dtype=np.int32)
            < q_cnt[..., None])
    ths = np.asarray([-(-cap_kv // (1 << (i + 1)))
                      for i in range(OCC_BINS - 1)], np.int32)
    cls = np.sum(kv_row_cnt[..., None] <= ths, axis=-1)
    onehot = (cls[..., None] == np.arange(OCC_BINS, dtype=cls.dtype)) \
        & live[..., None]
    return np.sum(onehot, axis=(1, 2)).astype(np.int32)


def check_plan(plan, cfg, n_tokens: int) -> List[str]:
    """Return every invariant violation in ``plan`` (empty = valid)."""
    from repro.core.plan import bucket_geometry, bucket_slot_layout

    # Materialize every leaf as NumPy BEFORE touching it: this function
    # also runs on jax.debug.callback's host thread, where dispatching
    # any jax op (even widen()'s astype) deadlocks against the device
    # computation that triggered the callback.  widen() is dtype-generic,
    # so on NumPy leaves the whole checker stays off the jax runtime.
    plan = plan._replace(**{
        f: (None if v is None else np.asarray(v))
        for f, v in zip(plan._fields, plan)})

    out: List[str] = []
    m = cfg.mask
    spec = cfg.caps(n_tokens)
    t_cmp = m.n_blocks(n_tokens)
    t_q = -(-n_tokens // m.block_q)
    t_kv = -(-n_tokens // m.block_kv)
    factor = m.pool // m.block_q

    # --- widen() completeness: no int16 survives, and it is idempotent ---
    wide = plan.widen()
    for fname, leaf in zip(wide._fields, wide):
        if leaf is not None and hasattr(leaf, "dtype") \
                and np.dtype(leaf.dtype) == np.int16:
            out.append(f"widen(): field {fname!r} stayed int16 — add it to "
                       f"DispatchPlan.widen()'s _replace call")
    p = _Canon(wide)

    heads = p.m_ch.shape[-1]

    # --- CSR well-formedness --------------------------------------------
    _check_id_list(out, "q_ids", p.q_ids, p.q_cnt, t_q)
    _check_id_list(out, "kv_ids", p.kv_ids, p.kv_cnt, t_kv)
    _check_id_list(out, "row_ids", p.row_ids, p.row_cnt, t_cmp)
    _check_id_list(out, "kv_row_ids", p.kv_row_ids, p.kv_row_cnt, t_kv)
    _check_id_list(out, "head_ids", p.head_ids, p.head_cnt, heads)
    if (p.kv_row_cnt > p.kv_row_ids.shape[-1]).any():
        out.append("kv_row_cnt exceeds the per-row CSR capacity")
    # q blocks live only inside live (kept) pool rows
    rows_live = _membership(p.row_ids, p.row_cnt, t_cmp)
    qrow = np.clip(p.q_ids // factor, 0, t_cmp - 1)
    qv = _prefix_valid(p.q_ids, p.q_cnt)
    hit = np.take_along_axis(
        np.broadcast_to(rows_live[:, None, :], (*p.q_ids.shape[:-1], t_cmp)),
        qrow, axis=-1)
    if (qv & ~hit).any():
        out.append("q_ids: live q block outside the kept row set "
                   "(capacity truncation not applied before extraction)")
    # Per-row CSR lists subset of the per-(b, h) KV union — scoped the
    # way the engine consumes them: only rows holding a live q block are
    # ever read (a fully-cached head keeps raw mask rows as dead
    # payload), and only when the union clamp was a no-op (kv_cnt below
    # capacity) — under truncation the reduction deliberately runs the
    # per-row lists INSTEAD of the union (attention_plan_indices).
    union = _membership(p.kv_ids, p.kv_cnt, t_kv)          # (B*, H, t_kv)
    rv = _prefix_valid(p.kv_row_ids, p.kv_row_cnt)
    rids = np.clip(p.kv_row_ids, 0, t_kv - 1)
    in_union = np.take_along_axis(
        np.broadcast_to(union[:, :, None, :],
                        (*p.kv_row_ids.shape[:-1], t_kv)), rids, axis=-1)
    n_rows = p.kv_row_ids.shape[-2]
    row_used = np.zeros((*qrow.shape[:-1], n_rows + 1), bool)
    np.put_along_axis(row_used, np.where(qv, np.clip(qrow, 0, n_rows), n_rows),
                      True, axis=-1)
    no_trunc = p.kv_cnt < p.kv_ids.shape[-1]               # clamp was a no-op
    if (rv & ~in_union & row_used[..., :n_rows, None]
            & no_trunc[..., None, None]).any():
        out.append("kv_row_ids: live row's list escapes the untruncated "
                   "KV union")
    # GEMM-O padding-slot convention + head_cnt/head_mask agreement
    row_pad = ~_prefix_valid(p.row_ids, p.row_cnt)
    if (p.head_cnt[row_pad] != 0).any():
        out.append("head_cnt: padding row slot with a non-empty head list "
                   "(bias-aliased GEMM-O would re-accumulate it)")
    if p.head_mask[row_pad].any():
        out.append("head_mask: padding row slot with live heads")
    if (p.head_cnt != p.head_mask.sum(-1)).any():
        out.append("head_cnt != head_mask row sums (fold-back missed one "
                   "of the two GEMM-O views)")

    # --- occ_hist: recompute from the final counts ----------------------
    if p.occ_hist is not None:
        want = _occ_hist_np(p.kv_row_cnt, p.q_cnt, spec.cap_kv)
        if p.occ_hist.shape != want.shape or (p.occ_hist != want).any():
            out.append("occ_hist inconsistent with the truncation-folded "
                       "kv_row_cnt/q_cnt (histogram computed before a "
                       "later clamp?)")

    # --- bkt_* fold-back (PR 6) -----------------------------------------
    if p.bkt_head is not None:
        cq, ck = p.q_ids.shape[-1], p.kv_row_ids.shape[-1]
        geom = bucket_geometry(cq, spec.cap_kv, heads, spec.kv_buckets)
        w_pos = np.concatenate(
            [np.full(r, w, np.int32) for r, w in geom])    # (R,)
        srow, jof, _, _ = bucket_slot_layout(geom)
        live = p.bkt_q_ids < t_q                           # (B*, R)
        if (~live & (p.bkt_kv_cnt != 0)).any():
            out.append("bkt_kv_cnt: dead layout row with live KV slots")
        if (p.bkt_kv_cnt > w_pos).any():
            out.append("bkt_kv_cnt exceeds its bucket width (truncation "
                       "not applied at layout build)")
        slot_q = _slot_of(p.q_ids, p.q_cnt, t_q)           # (B*, H, t_q)
        bi = np.arange(live.shape[0])[:, None]
        s = slot_q[bi, p.bkt_head, np.clip(p.bkt_q_ids, 0, t_q - 1)]
        if (live & (s < 0)).any():
            out.append("bkt layout row maps to no live (head, q-slot) "
                       "origin — bkt_head/bkt_q_ids inconsistent with "
                       "q_ids/q_cnt")
        else:
            sc = np.clip(s, 0, cq - 1)
            back = p.kv_row_cnt[bi, p.bkt_head, sc]
            if (live & (back != p.bkt_kv_cnt)).any():
                out.append("shared-truncation fold-back violated: "
                           "bkt_kv_cnt != kv_row_cnt at the layout row's "
                           "origin (bucket clamp not folded back)")
            # id prefixes agree slot-for-slot with the uniform CSR lists
            src_rows = p.kv_row_ids[bi, p.bkt_head[:, srow],
                                    sc[:, srow]]               # (B*, S, Ck)
            want_ids = np.take_along_axis(
                src_rows, np.minimum(jof, ck - 1)[None, :, None],
                axis=-1)[..., 0]
            jvalid = (jof < p.bkt_kv_cnt[:, srow]) & live[:, srow]
            if (jvalid & (p.bkt_kv_ids != want_ids)).any():
                out.append("bkt_kv_ids prefix diverges from kv_row_ids — "
                           "bucketed and uniform kernels would reduce "
                           "different KV lists")

    # --- gmo_* fold-back (PR 8) -----------------------------------------
    if p.gmo_rows is not None:
        cr = p.row_ids.shape[-1]
        geom_o = bucket_geometry(cr, heads, 1, spec.kv_buckets)
        w_pos = np.concatenate([np.full(r, w, np.int32) for r, w in geom_o])
        srow, jof, _, _ = bucket_slot_layout(geom_o)
        live = p.gmo_rows < t_cmp
        if (~live & (p.gmo_head_cnt != 0)).any():
            out.append("gmo_head_cnt: dead layout row with live heads")
        if (p.gmo_head_cnt > w_pos).any():
            out.append("gmo_head_cnt exceeds its bucket width")
        slot_r = _slot_of(p.row_ids, p.row_cnt, t_cmp)
        bi = np.arange(live.shape[0])[:, None]
        s = slot_r[bi, np.clip(p.gmo_rows, 0, t_cmp - 1)]
        if (live & (s < 0)).any():
            out.append("gmo layout row maps to no live compact row slot")
        else:
            sc = np.clip(s, 0, cr - 1)
            if (live & (p.head_cnt[bi, sc] != p.gmo_head_cnt)).any():
                out.append("shared-truncation fold-back violated: "
                           "gmo_head_cnt != head_cnt at the layout row's "
                           "origin (head clamp not folded back)")
            jvalid = (jof < p.gmo_head_cnt[:, srow]) & live[:, srow]
            src_h = p.head_ids[bi, sc[:, srow]]                # (B*, S, H)
            want_ids = np.take_along_axis(
                src_h, np.minimum(jof, heads - 1)[None, :, None],
                axis=-1)[..., 0]
            if (jvalid & (p.gmo_head_ids != want_ids)).any():
                out.append("gmo_head_ids prefix diverges from head_ids")

    # --- shd_* partition (PR 7) -----------------------------------------
    if p.shd_q_ids is not None:
        mesh_sp = getattr(cfg, "mesh_sp", 1)
        from repro.distributed.plan_shard import shard_geometry
        g = shard_geometry(spec, t_q, t_kv, mesh_sp,
                           getattr(cfg, "mesh_pair_slack", 1.5))
        if (p.shd_q_cnt > g.cap_q).any():
            out.append("shd_q_cnt exceeds the per-shard row capacity")
        if (p.shd_kv_cnt > g.cap_kv).any():
            out.append("shd_kv_cnt exceeds the per-shard union capacity")
        if (p.shd_send_cnt > g.pair_cap).any():
            out.append("shd_send_cnt exceeds pair_cap (the collective "
                       "payload would overflow its run)")
        if (p.shd_gather_idx < 0).any() \
                or (p.shd_gather_idx >= g.buf_blocks).any():
            out.append("shd_gather_idx outside the KV exchange buffer")
        if (p.shd_q_cnt.sum(-1) != p.q_cnt).any():
            out.append("per-shard row partition does not cover q_cnt "
                       "exactly (rows lost or duplicated across shards)")
        # fold-back: per-shard row counts gather the SAME truncated counts
        slot_q = _slot_of(p.q_ids, p.q_cnt, t_q)           # (B*, H, t_q)
        bsz, h_ = p.shd_q_cnt.shape[:2]
        bi = np.arange(bsz)[:, None, None, None]
        hi_ = np.arange(h_)[None, :, None, None]
        sv = _prefix_valid(p.shd_q_src, p.shd_q_cnt)
        s = slot_q[bi, hi_, np.clip(p.shd_q_src, 0, t_q - 1)]
        if (sv & (s < 0)).any():
            out.append("shd_q_src names a q block absent from the live "
                       "q_ids prefix")
        else:
            back = p.kv_row_cnt[bi, hi_,
                                np.clip(s, 0, p.q_ids.shape[-1] - 1)]
            if (sv & (back != p.shd_kv_row_cnt)).any():
                out.append("shared-truncation fold-back violated: "
                           "shd_kv_row_cnt != kv_row_cnt at the shard "
                           "row's origin (partition re-truncated)")
        # remapped row lists index the per-shard union (only live row
        # slots count: a dead shard's gathered rows are masked padding)
        jv = _prefix_valid(p.shd_kv_row_ids, p.shd_kv_row_cnt) \
            & sv[..., None]
        if (jv & ((p.shd_kv_row_ids < 0)
                  | (p.shd_kv_row_ids
                     >= p.shd_kv_cnt[..., None, None]))).any():
            out.append("shd_kv_row_ids: union-slot index outside the "
                       "per-shard union prefix")

    return out


def validate_plan(plan, cfg, n_tokens: int) -> None:
    """Raise :class:`PlanInvariantError` listing every violation."""
    bad = check_plan(plan, cfg, n_tokens)
    if bad:
        raise PlanInvariantError(
            "DispatchPlan invariant violation(s):\n  - "
            + "\n  - ".join(bad))


def hook_validate(plan, cfg, n_tokens: int) -> None:
    """``jax.debug.callback`` target used by ``build_dispatch_plan``.

    Runs on host with concrete arrays; any violation raises (surfacing
    through the callback machinery as an error on the next sync point).
    """
    validate_plan(plan, cfg, n_tokens)
