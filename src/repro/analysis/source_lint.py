"""Repo-rule AST lint (pass family (c) of the analyzer).

Walks the ``src/`` Python ASTs for repo-specific rules that generic
linters cannot know:

* ``plan-widen-coverage`` — every :class:`DispatchPlan` *id* field (by
  the repo's naming convention: suffix ``_ids`` / ``_slots`` / ``_src``
  / ``_rows`` / ``_idx``, plus ``bkt_head``) must appear as a keyword in
  ``widen()``'s ``_replace`` call.  Count/mask/score fields
  (``*_cnt`` / ``*_mask`` / ``*_live`` / ``*_score`` / ``*_hist`` /
  ``m_ch``) are int32/bool/f32 by construction and exempt.
* ``plan-spec-coverage`` — every DispatchPlan field must appear as a
  keyword in ``models/dit.engine_state_specs`` (a plan field without a
  sharding spec silently falls back to replication and ships whole
  buffers to every shard).
* ``plan-rebuild-coverage`` — every field must be produced somewhere on
  the plan build path (``build_dispatch_plan`` and the layout helpers it
  splices in: ``bucket_layout`` / ``gmo_layout`` / ``partition_plan``),
  which is also exactly what ``plan_from_state``'s rebuild replays.
* ``module-dict-cache`` — a module-level ``NAME = {}``/``dict()`` whose
  name contains ``CACHE`` or ``MEMO`` is an unbounded cache; it must be
  a :class:`repro.core.lru.LruCache`.  (Registries — append-only,
  explicit registration — are out of scope by naming convention.)
* ``id-keyed-cache`` — the PR-5 bug class: a cache keyed by ``id(obj)``
  aliases freed addresses and defeats value-dedup.  Flagged when a
  statement both calls the ``id`` builtin and touches a
  ``CACHE``/``MEMO``-named store.
* ``jit-in-traced-body`` — ``jax.jit``/``jax.pmap`` inside a function
  passed to ``lax.scan``/``lax.switch``/``lax.cond``/``shard_map``:
  jit under a trace is at best a no-op retrace and at worst an
  executable-budget leak.

Entry point: :func:`lint_sources` (or :func:`lint_source` for one
in-memory module — what the adversarial fixture tests use).
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Tuple

__all__ = ["lint_sources", "lint_source", "LintHit",
           "ID_FIELD_SUFFIXES", "plan_fields"]

# DispatchPlan id-field naming convention (see DispatchPlan docstring).
ID_FIELD_SUFFIXES = ("_ids", "_slots", "_src", "_rows", "_idx")
ID_FIELD_EXTRAS = frozenset({"bkt_head"})

LintHit = Tuple[str, int, str, str]     # (path, lineno, rule, message)

_TRACED_HOPS = frozenset({"scan", "switch", "cond", "while_loop",
                          "shard_map", "fori_loop", "associated_scan"})


def _call_name(node: ast.AST) -> Optional[str]:
    """Trailing name of a call target: ``jax.lax.scan`` -> ``scan``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        return _dotted(node.value) + "." + node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _is_cache_name(name: str) -> bool:
    up = name.upper()
    return "CACHE" in up or "MEMO" in up


def is_id_field(name: str) -> bool:
    return name.endswith(ID_FIELD_SUFFIXES) or name in ID_FIELD_EXTRAS


# ---------------------------------------------------------------------------
# DispatchPlan structural rules (plan.py / dit.py / plan_shard.py)
# ---------------------------------------------------------------------------

def _find_class(tree: ast.Module, name: str) -> Optional[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def plan_fields(plan_tree: ast.Module) -> List[str]:
    """DispatchPlan field names, in declaration order, from the AST."""
    cls = _find_class(plan_tree, "DispatchPlan")
    if cls is None:
        return []
    return [stmt.target.id for stmt in cls.body
            if isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)]


def _method(cls: ast.ClassDef, name: str) -> Optional[ast.FunctionDef]:
    for stmt in cls.body:
        if isinstance(stmt, ast.FunctionDef) and stmt.name == name:
            return stmt
    return None


def _call_keywords(scope: ast.AST, callee_names) -> set:
    """All keyword names of calls to any of ``callee_names`` in scope."""
    out = set()
    for node in ast.walk(scope):
        if isinstance(node, ast.Call) \
                and _call_name(node.func) in callee_names:
            out.update(kw.arg for kw in node.keywords if kw.arg)
    return out


def _dict_keys_in(scope: ast.AST) -> set:
    """String keys visible in dict literals / dict() calls / subscript
    stores within ``scope`` — how the layout helpers emit their fields."""
    out = set()
    for node in ast.walk(scope):
        if isinstance(node, ast.Dict):
            out.update(k.value for k in node.keys
                       if isinstance(k, ast.Constant)
                       and isinstance(k.value, str))
        elif isinstance(node, ast.Call) and _call_name(node.func) == "dict":
            out.update(kw.arg for kw in node.keywords if kw.arg)
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript) \
                        and isinstance(t.slice, ast.Constant) \
                        and isinstance(t.slice.value, str):
                    out.add(t.slice.value)
    return out


def _lint_plan_coverage(src_root: Path) -> List[LintHit]:
    hits: List[LintHit] = []
    plan_path = src_root / "repro" / "core" / "plan.py"
    dit_path = src_root / "repro" / "models" / "dit.py"
    shard_path = src_root / "repro" / "distributed" / "plan_shard.py"
    plan_tree = ast.parse(plan_path.read_text())
    fields = plan_fields(plan_tree)
    if not fields:
        return [(str(plan_path), 1, "plan-widen-coverage",
                 "DispatchPlan class not found")]
    cls = _find_class(plan_tree, "DispatchPlan")

    # widen() coverage of the id-convention fields
    widen = _method(cls, "widen")
    covered = _call_keywords(widen, {"_replace"}) if widen else set()
    for f in fields:
        if is_id_field(f) and f not in covered:
            hits.append((str(plan_path), cls.lineno, "plan-widen-coverage",
                         f"id field {f!r} missing from widen()'s _replace "
                         f"— it would reach kernels as int16"))

    # engine_state_specs coverage (every field needs a sharding spec)
    dit_tree = ast.parse(dit_path.read_text())
    specs_fn = None
    for node in ast.walk(dit_tree):
        if isinstance(node, ast.FunctionDef) \
                and node.name == "engine_state_specs":
            specs_fn = node
            break
    if specs_fn is None:
        hits.append((str(dit_path), 1, "plan-spec-coverage",
                     "engine_state_specs not found"))
    else:
        spec_kw = _call_keywords(specs_fn, {"DispatchPlan", "_replace"})
        for f in fields:
            if f not in spec_kw:
                hits.append((str(dit_path), specs_fn.lineno,
                             "plan-spec-coverage",
                             f"DispatchPlan field {f!r} has no entry in "
                             f"engine_state_specs — it would silently "
                             f"replicate across the mesh"))

    # build-path coverage (build_dispatch_plan + layout helper emissions,
    # the exact path plan_from_state's rebuild replays)
    build_kw = _call_keywords(plan_tree, {"DispatchPlan"})
    build_kw |= _dict_keys_in(plan_tree)
    build_kw |= _dict_keys_in(ast.parse(shard_path.read_text()))
    for f in fields:
        if f not in build_kw:
            hits.append((str(plan_path), cls.lineno, "plan-rebuild-coverage",
                         f"DispatchPlan field {f!r} is never produced on "
                         f"the build/rebuild path"))
    return hits


# ---------------------------------------------------------------------------
# Generic repo rules (whole src/ tree)
# ---------------------------------------------------------------------------

def _lint_module(path: str, tree: ast.Module) -> List[LintHit]:
    hits: List[LintHit] = []

    # module-dict-cache: module-level CACHE/MEMO dict literals
    for node in tree.body:
        if not isinstance(node, ast.Assign):
            continue
        unbounded = isinstance(node.value, (ast.Dict, ast.DictComp)) or (
            isinstance(node.value, ast.Call)
            and _call_name(node.value.func) == "dict")
        if not unbounded:
            continue
        for t in node.targets:
            if isinstance(t, ast.Name) and _is_cache_name(t.id):
                hits.append((path, node.lineno, "module-dict-cache",
                             f"{t.id} is an unbounded module-level dict — "
                             f"use repro.core.lru.LruCache"))

    # id-keyed-cache: a SIMPLE statement touching a CACHE/MEMO-named
    # store while keying (directly or through a local assigned from
    # ``id(...)``) by object identity.  Compound statements are skipped —
    # a whole function mentioning both independently is not a finding —
    # and taint is per enclosing scope, so a transient local dict keyed
    # by ``id`` over pinned objects (schedule.strategy_table's
    # ``by_spec``) stays legal as long as no cache is involved.
    _simple = (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.Expr,
               ast.Return, ast.Assert, ast.Raise, ast.Delete)

    def _calls_id(node: ast.AST) -> bool:
        return any(isinstance(n, ast.Call)
                   and isinstance(n.func, ast.Name) and n.func.id == "id"
                   for n in ast.walk(node))

    seen = set()
    scopes = [tree] + [n for n in ast.walk(tree)
                       if isinstance(n, ast.FunctionDef)]
    for scope in scopes:
        tainted = {t.id for n in ast.walk(scope)
                   if isinstance(n, ast.Assign) and _calls_id(n.value)
                   for t in n.targets if isinstance(t, ast.Name)}
        for node in ast.walk(scope):
            if not isinstance(node, _simple) or node.lineno in seen:
                continue
            touches_cache = any(
                (isinstance(n, ast.Name) and _is_cache_name(n.id))
                or (isinstance(n, ast.Attribute) and _is_cache_name(n.attr))
                for n in ast.walk(node))
            if not touches_cache:
                continue
            if _calls_id(node) or any(
                    isinstance(n, ast.Name) and n.id in tainted
                    for n in ast.walk(node)):
                seen.add(node.lineno)
                hits.append((path, node.lineno, "id-keyed-cache",
                             "cache access keyed by id(obj) — addresses "
                             "recycle after gc; key by VALUE "
                             "(strategy_key / frozen config)"))

    # jit-in-traced-body: jax.jit inside a fn passed to a traced
    # higher-order primitive
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        local_fns = {n.name: n for n in ast.walk(fn)
                     if isinstance(n, ast.FunctionDef)}
        for call in ast.walk(fn):
            if not (isinstance(call, ast.Call)
                    and _call_name(call.func) in _TRACED_HOPS):
                continue
            passed = []
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                if isinstance(arg, ast.Name) and arg.id in local_fns:
                    passed.append(local_fns[arg.id])
                elif isinstance(arg, (ast.List, ast.Tuple)):
                    passed.extend(local_fns[e.id] for e in arg.elts
                                  if isinstance(e, ast.Name)
                                  and e.id in local_fns)
            for body_fn in passed:
                for n in ast.walk(body_fn):
                    if isinstance(n, ast.Call) and isinstance(
                            n.func, ast.Attribute) \
                            and n.func.attr in ("jit", "pmap") \
                            and _dotted(n.func).startswith("jax"):
                        hits.append((
                            path, n.lineno, "jit-in-traced-body",
                            f"jax.{n.func.attr} inside "
                            f"{body_fn.name!r}, which is traced by "
                            f"{_call_name(call.func)} — jit under a "
                            f"trace re-traces per call"))
    return hits


def lint_source(source: str, path: str = "<memory>") -> List[LintHit]:
    """Lint one in-memory module (generic rules only)."""
    return _lint_module(path, ast.parse(source))


def lint_sources(src_root) -> List[LintHit]:
    """Lint the whole ``src/`` tree: plan coverage + generic rules."""
    src_root = Path(src_root)
    hits = _lint_plan_coverage(src_root)
    for path in sorted(src_root.rglob("*.py")):
        tree = ast.parse(path.read_text())
        hits.extend(_lint_module(str(path), tree))
    return hits
