"""Multi-host sharded checkpointer (no external deps).

Layout per step:
    <dir>/step_<n>.tmp/            — written first
        manifest.json              — tree structure, shapes, dtypes, step
        arr_<i>.npy                — one file per leaf (process-local shards
                                     concatenated via addressable data)
    <dir>/step_<n>/                — atomic rename AFTER all writes land

Guarantees exercised by tests:
  * atomic publish (a crash mid-write never yields a readable-but-corrupt
    checkpoint — readers only look at renamed dirs);
  * async save (background thread; ``wait()`` joins before the next save);
  * restore_latest() returns (step, tree) restored onto the target
    shardings via ``jax.device_put``;
  * retention of the newest K checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["Checkpointer"]


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = False):
        """Snapshot to host memory synchronously, write to disk async."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]     # device->host copy NOW
        treedef_str = str(treedef)

        def _write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "n_leaves": len(host),
                        "treedef": treedef_str,
                        "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                                   for a in host]}
            for i, a in enumerate(host):
                np.save(tmp / f"arr_{i}.npy", a)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)                  # atomic publish
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if not p.name.endswith(".tmp"))

    def restore(self, step: int, target_tree: Any, shardings: Any = None) -> Any:
        path = self.dir / f"step_{step}"
        manifest = json.loads((path / "manifest.json").read_text())
        leaves = [np.load(path / f"arr_{i}.npy")
                  for i in range(manifest["n_leaves"])]
        _, treedef = jax.tree.flatten(target_tree)
        tree = treedef.unflatten(leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree

    def restore_latest(self, target_tree: Any, shardings: Any = None):
        steps = self.steps()
        if not steps:
            return None, None
        s = steps[-1]
        return s, self.restore(s, target_tree, shardings)
