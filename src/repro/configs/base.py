"""Architecture configuration schema + shape grid.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (exact published hyper-parameters) and ``SMOKE`` (a reduced
same-family config for CPU tests).  Shapes follow the task grid:

  train_4k    : seq 4096,   global batch 256  -> train_step
  prefill_32k : seq 32768,  global batch 32   -> serve prefill
  decode_32k  : seq 32768,  global batch 128  -> serve decode (1 new token)
  long_500k   : seq 524288, global batch 1    -> long-context decode
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["MoESpec", "ArchConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | dit
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None         # default d_model // n_heads
    moe: Optional[MoESpec] = None
    # Attention pattern: every `global_every`-th layer is global, the rest
    # use a sliding window of `window` tokens (gemma3 5:1, mixtral SWA...).
    window: Optional[int] = None
    global_every: int = 1                  # 1 => all layers global
    # SSM / hybrid
    ssm_state: int = 0
    recurrent_pattern: int = 0             # recurrentgemma: 2 RG-LRU per attn
    # Enc-dec / multimodal frontends (stub = precomputed embeddings)
    encoder_len: int = 0                   # whisper: 1500 frames
    cross_attn_every: int = 0              # llama-3.2-vision: cross-attn cadence
    num_image_tokens: int = 0
    # DiT (the paper's own family)
    n_text_tokens: int = 0
    patch_dim: int = 0
    # Distribution
    zero_over_pod: bool = False            # shard opt state over pod axis too
    remat: bool = True
    scan_layers: bool = True
    # Shape-grid applicability (DESIGN §4 skips)
    skip_shapes: tuple[str, ...] = ()
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding shards on any
        (fsdp × tp) ≤ 16×16 split — standard Megatron/MaxText practice.
        Logits are sliced back to the published vocab before the loss."""
        return -(-self.vocab // 256) * 256 if self.vocab else 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.moe:
            mlp = self.moe.num_experts * 3 * d * self.moe.d_ff + d * self.moe.num_experts
        else:
            mlp = 3 * d * self.d_ff
        if self.family == "ssm":
            # Mamba2: in_proj (d -> 2*d_inner + 2*groups*state + heads), out_proj
            d_in = 2 * d
            attn, mlp = 0, d * (2 * d_in + 2 * self.ssm_state) + d_in * d
        return emb + self.n_layers * (attn + mlp)

    def n_active_params(self) -> int:
        if not self.moe:
            return self.n_params()
        d = self.d_model
        dense_part = self.n_params() - self.n_layers * self.moe.num_experts * 3 * d * self.moe.d_ff
        return dense_part + self.n_layers * self.moe.top_k * 3 * d * self.moe.d_ff
