"""flux-mmdit (paper arch, FLUX.1-style): single-stream MMDiT simplification,
38 blocks d=3072 24H d_ff=12288; 512 text + 4096 vision tokens (the paper's
FLUX.1 4.5K-token setting).  Full FlashOmni Update-Dispatch applies."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="flux-mmdit", family="dit", n_layers=38, d_model=3072, n_heads=24,
    n_kv_heads=24, d_ff=12288, vocab=0, head_dim=128, n_text_tokens=512,
    patch_dim=64, skip_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SMOKE = ArchConfig(
    name="flux-smoke", family="dit", n_layers=3, d_model=64, n_heads=2,
    n_kv_heads=2, d_ff=128, vocab=0, head_dim=32, n_text_tokens=32,
    patch_dim=16, remat=False,
)
