"""gemma3-12b [hf:google/gemma-3-12b-pt; unverified]: 48L d=3840 16H (kv=8)
d_ff=15360 vocab=262144 — 5:1 local:global, 128k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense", n_layers=48, d_model=3840, n_heads=16,
    n_kv_heads=8, d_ff=15360, vocab=262144, head_dim=256, window=1024,
    global_every=6, tie_embeddings=True, rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="gemma3-12b-smoke", family="dense", n_layers=7, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, head_dim=16, window=32, global_every=3,
    tie_embeddings=True, remat=False,
)
