"""gemma3-1b [hf:google/gemma-3-1b-pt; unverified]: 26L d=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144 — 5:1 local:global sliding window, 128k context."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense", n_layers=26, d_model=1152, n_heads=4,
    n_kv_heads=1, d_ff=6912, vocab=262144, head_dim=256, window=512,
    global_every=6, tie_embeddings=True, rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="gemma3-1b-smoke", family="dense", n_layers=7, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab=512, head_dim=16, window=32, global_every=3,
    tie_embeddings=True, remat=False,
)
