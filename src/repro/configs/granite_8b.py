"""granite-8b [arXiv:2405.04324; hf]: 36L d=4096 32H (kv=8) d_ff=14336
vocab=49152 — llama-arch, code.  Pure full attention -> long_500k skipped."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", family="dense", n_layers=36, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab=49152, skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="granite-8b-smoke", family="dense", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, remat=False,
)
