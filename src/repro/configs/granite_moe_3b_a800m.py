"""granite-moe-3b-a800m [hf:ibm-granite; hf]: 32L d=1536 24H (kv=8)
per-expert d_ff=512 vocab=49155, MoE 40 experts top-8.  Full attention ->
long_500k skipped."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=0, vocab=49155, head_dim=64,
    moe=MoESpec(num_experts=40, top_k=8, d_ff=512), skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="granite-moe-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=0, vocab=512, moe=MoESpec(num_experts=8, top_k=4, d_ff=64),
    remat=False,
)
