"""hunyuan-video-dit (paper arch, HunyuanVideo-style): 48 blocks d=3072 24H
d_ff=12288; 256 text + 32768 vision tokens (the paper's 33K setting, the
1.5x end-to-end target).  Full FlashOmni Update-Dispatch applies."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hunyuan-video-dit", family="dit", n_layers=48, d_model=3072,
    n_heads=24, n_kv_heads=24, d_ff=12288, vocab=0, head_dim=128,
    n_text_tokens=256, patch_dim=64,
    skip_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SMOKE = ArchConfig(
    name="hunyuan-smoke", family="dit", n_layers=3, d_model=64, n_heads=2,
    n_kv_heads=2, d_ff=128, vocab=0, head_dim=32, n_text_tokens=32,
    patch_dim=16, remat=False,
)
