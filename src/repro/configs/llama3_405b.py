"""llama3-405b [arXiv:2407.21783; unverified]: 126L d=16384 128H (kv=8)
d_ff=53248 vocab=128256.  Pure full attention -> long_500k skipped.
ZeRO over the pod axis too (params+opt > single-pod HBM)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256,
    skip_shapes=("long_500k",), zero_over_pod=True, rope_theta=500_000.0,
)

SMOKE = ArchConfig(
    name="llama3-405b-smoke", family="dense", n_layers=3, d_model=64,
    n_heads=8, n_kv_heads=2, d_ff=192, vocab=512, remat=False,
)
