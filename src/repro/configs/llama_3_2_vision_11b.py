"""llama-3.2-vision-11b [hf:meta-llama; unverified]: 40L d=4096 32H (kv=8)
d_ff=14336 vocab=128256 — gated cross-attn image layers every 5th layer;
vision encoder STUB (precomputed patch embeddings).  long_500k skipped."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, cross_attn_every=5,
    num_image_tokens=1600, skip_shapes=("long_500k",), rope_theta=500_000.0,
)

SMOKE = ArchConfig(
    name="llama-vision-smoke", family="vlm", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, cross_attn_every=2, num_image_tokens=16,
    remat=False,
)
