"""mamba2-370m [arXiv:2405.21060; unverified]: 48L d=1024 attn-free,
vocab=50280, ssm_state=128 (SSD).  FlashOmni inapplicable (no attention,
DESIGN §Arch-applicability); long_500k runs (linear-time SSD)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024, n_heads=32,
    n_kv_heads=32, d_ff=0, vocab=50280, ssm_state=128,
)

SMOKE = ArchConfig(
    name="mamba2-370m-smoke", family="ssm", n_layers=2, d_model=64, n_heads=2,
    n_kv_heads=2, d_ff=0, vocab=512, ssm_state=16, remat=False,
)
