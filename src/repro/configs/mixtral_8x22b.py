"""mixtral-8x22b [arXiv:2401.04088; hf]: 56L d=6144 48H (kv=8) per-expert
d_ff=16384 vocab=32768, MoE 8 experts top-2, SWA -> all-local window 4096
(long_500k runs: sliding window is sub-quadratic)."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=0, vocab=32768, moe=MoESpec(num_experts=8, top_k=2, d_ff=16384),
    window=4096, global_every=0,
)

SMOKE = ArchConfig(
    name="mixtral-8x22b-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=0, vocab=512, moe=MoESpec(num_experts=4, top_k=2, d_ff=96),
    window=32, global_every=0, remat=False,
)
