"""recurrentgemma-2b [arXiv:2402.19427; hf]: 26L d=2560 10H (kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention 1:2, window 2048.  long_500k runs
(constant-size recurrent state + ring-buffer local KV)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000, head_dim=256,
    window=2048, recurrent_pattern=2, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke", family="hybrid", n_layers=8, d_model=64,
    n_heads=2, n_kv_heads=1, d_ff=128, vocab=512, head_dim=32, window=32,
    recurrent_pattern=2, tie_embeddings=True, remat=False,
)
