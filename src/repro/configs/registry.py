"""Config registry: ``--arch <id>`` resolution for launchers and tests."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, SHAPES, ShapeSpec

__all__ = ["ARCH_IDS", "get_config", "get_smoke", "arch_shapes"]

ARCH_IDS = [
    "gemma3-1b", "granite-8b", "llama3-405b", "gemma3-12b", "mixtral-8x22b",
    "granite-moe-3b-a800m", "mamba2-370m", "whisper-large-v3",
    "llama-3.2-vision-11b", "recurrentgemma-2b",
    # the paper's own archs
    "flux-mmdit", "hunyuan-video-dit",
]

_MODULES = {
    "gemma3-1b": "gemma3_1b", "granite-8b": "granite_8b",
    "llama3-405b": "llama3_405b", "gemma3-12b": "gemma3_12b",
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mamba2-370m": "mamba2_370m", "whisper-large-v3": "whisper_large_v3",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "flux-mmdit": "flux_mmdit", "hunyuan-video-dit": "hunyuan_video",
}


def _module(arch: str):
    key = arch if arch in _MODULES else arch.replace("_", "-")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[key]}")


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ArchConfig:
    return _module(arch).SMOKE


def arch_shapes(cfg: ArchConfig) -> list[ShapeSpec]:
    """The shape-grid cells this arch runs (after DESIGN §4 skips)."""
    if cfg.family == "dit":
        return [ShapeSpec("dit_serve", cfg.n_text_tokens +
                          (4096 if "flux" in cfg.name else 32768), 1, "dit")]
    return [s for s in SHAPES.values() if s.name not in cfg.skip_shapes]
