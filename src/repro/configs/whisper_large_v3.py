"""whisper-large-v3 [arXiv:2212.04356; unverified]: 32L enc + 32L dec,
d=1280 20H d_ff=5120 vocab=51866; conv frontend STUB (precomputed 1500-frame
embeddings).  long_500k skipped (full attention; 500k target tokens is
architecturally meaningless for Whisper)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866, encoder_len=1500,
    skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, encoder_len=24, remat=False,
)
