"""FlashOmni core: unified sparse symbols, mask generation, TaylorSeer
forecasting, sparse attention/GEMM (XLA structural paths) and the
Update–Dispatch engine (the paper's primary contribution)."""

from repro.core.masks import MaskConfig
from repro.core.engine import (
    AttnParams,
    EngineConfig,
    LayerState,
    dispatch_layer,
    init_layer_state,
    is_update_step,
    plan_from_state,
    update_layer,
)
from repro.core.attention import SparseAttentionSpec
from repro.core.backend import get_backend
from repro.core.plan import DispatchPlan, build_dispatch_plan

__all__ = [
    "MaskConfig",
    "EngineConfig",
    "AttnParams",
    "LayerState",
    "DispatchPlan",
    "SparseAttentionSpec",
    "init_layer_state",
    "is_update_step",
    "update_layer",
    "dispatch_layer",
    "plan_from_state",
    "build_dispatch_plan",
    "get_backend",
]
