"""FlashOmni core: unified sparse symbols, mask generation, TaylorSeer
forecasting, sparse attention/GEMM (XLA structural paths) and the
Update–Dispatch engine (the paper's primary contribution)."""

from repro.core.masks import MaskConfig
from repro.core.engine import (
    AttnParams,
    EngineConfig,
    LayerState,
    dispatch_layer,
    init_layer_state,
    is_update_step,
    update_layer,
)
from repro.core.attention import SparseAttentionSpec

__all__ = [
    "MaskConfig",
    "EngineConfig",
    "AttnParams",
    "LayerState",
    "SparseAttentionSpec",
    "init_layer_state",
    "is_update_step",
    "update_layer",
    "dispatch_layer",
]
