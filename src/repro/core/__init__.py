"""FlashOmni core: unified sparse symbols, mask generation, TaylorSeer
forecasting, sparse attention/GEMM (XLA structural paths) and the
Update–Dispatch engine (the paper's primary contribution)."""

from repro.core.masks import MaskConfig
from repro.core.engine import (
    AttnParams,
    EngineConfig,
    LayerState,
    dispatch_layer,
    init_layer_state,
    is_update_step,
    plan_from_state,
    resolve_schedule,
    update_layer,
)
from repro.core.attention import SparseAttentionSpec
from repro.core.backend import get_backend
from repro.core.plan import DispatchPlan, build_dispatch_plan
from repro.core.schedule import (
    SparsitySchedule,
    available_schedules,
    get_schedule,
    register_schedule,
)
from repro.core.strategy import (
    SparsityStrategy,
    StrategyContext,
    SymbolSet,
    available_strategies,
    emit_switch,
    get_strategy,
    register_strategy,
)

__all__ = [
    "MaskConfig",
    "EngineConfig",
    "AttnParams",
    "LayerState",
    "DispatchPlan",
    "SparseAttentionSpec",
    "SparsitySchedule",
    "SparsityStrategy",
    "StrategyContext",
    "SymbolSet",
    "init_layer_state",
    "is_update_step",
    "resolve_schedule",
    "update_layer",
    "dispatch_layer",
    "plan_from_state",
    "build_dispatch_plan",
    "get_backend",
    "get_strategy",
    "get_schedule",
    "register_strategy",
    "register_schedule",
    "available_strategies",
    "available_schedules",
    "emit_switch",
]
