"""FlashOmni attention — XLA structural-sparse path (DESIGN §2).

Two implementations of the same semantics live in this repo:

  * :mod:`repro.kernels.flashomni_attention` — the Pallas TPU kernel with
    per-(i,j) CSR skipping (the paper's Algorithm 1, adapted to the TPU
    sequential grid).  Used on real TPU hardware.
  * this module — a pjit/XLA path with **structural** sparsity that the
    multi-pod dry-run lowers.  Compute for cached Q blocks is removed by a
    capacity-padded gather on the spatial axis (feature caching, ``S_c``),
    and the KV reduction runs over the capacity-padded **union** of KV
    blocks needed by any live row (``S_s``), with the exact per-(i,j) mask
    applied inside the gathered subset.  FLOPs in the compiled HLO shrink
    with both sparsity ratios, so the roofline analysis sees the win.
    When ``cap_kv`` can truncate the union (``cap_kv < T_kv``) the
    reduction switches to the PER-ROW CSR layout (each live row gathers
    its own KV-block list) so truncation semantics match the Pallas
    kernel exactly — same FLOPs, one extra gather dimension.

Masks follow the repo convention: boolean, True = compute.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.symbols import active_indices, clamp_mask_topk

__all__ = [
    "SparseAttentionSpec",
    "dense_attention",
    "masked_block_attention",
    "attention_plan_indices",
    "sparse_attention_from_plan",
    "sparse_attention_xla",
    "sparse_decode_attention",
]

_NEG_INF = -1e30


class SparseAttentionSpec(NamedTuple):
    """Static capacities for the structural path (part of the jit signature)."""

    block_q: int
    block_kv: int
    cap_q: int       # max live Q blocks per (batch, head)
    cap_kv: int      # max live KV blocks in the per-head union
    kv_buckets: int = 1  # occupancy buckets in the Pallas CSR grid (plan.py)


def dense_attention(q, k, v, *, scale: Optional[float] = None, mask=None):
    """Plain softmax attention oracle.  q,k,v: (..., N, d)."""
    scale = (q.shape[-1] ** -0.5) if scale is None else scale
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v.astype(jnp.float32)).astype(q.dtype)


def _block_mask_to_tokens(m_s: jax.Array, block_q: int, block_kv: int, n_q: int, n_kv: int):
    """(…, T_q, T_kv) block mask -> (…, n_q, n_kv) token mask."""
    m = jnp.repeat(jnp.repeat(m_s, block_q, axis=-2), block_kv, axis=-1)
    return m[..., :n_q, :n_kv]


def masked_block_attention(q, k, v, m_c, m_s, o_reuse, *, block_q, block_kv,
                           scale: Optional[float] = None):
    """Dense oracle with FlashOmni semantics (used by tests/ref):

    rows in blocks with ``m_c == 0`` take ``o_reuse``; live rows attend only
    to KV blocks with ``m_s == 1``.
    """
    n_q, n_kv = q.shape[-2], k.shape[-2]
    tok_mask = _block_mask_to_tokens(m_s, block_q, block_kv, n_q, n_kv)
    out = dense_attention(q, k, v, scale=scale, mask=tok_mask)
    row_live = jnp.repeat(m_c, block_q, axis=-1)[..., :n_q]
    return jnp.where(row_live[..., None], out, o_reuse)


def _gather_blocks(x_blocks: jax.Array, ids: jax.Array) -> jax.Array:
    """Gather block rows: x_blocks (..., T, b, d), ids (..., C) -> (..., C, b, d)."""
    idx = ids[..., None, None]
    idx = jnp.broadcast_to(idx, (*ids.shape, *x_blocks.shape[-2:]))
    return jnp.take_along_axis(x_blocks, idx, axis=-3)


def _gather_row_blocks(x_blocks: jax.Array, ids: jax.Array) -> jax.Array:
    """Per-row block gather: x_blocks (..., T, b, d), ids (..., C, Ck) ->
    (..., C, Ck, b, d) — each row gets its own KV-block list (CSR layout)."""
    flat = _gather_blocks(x_blocks, ids.reshape(*ids.shape[:-2], -1))
    return flat.reshape(*ids.shape, *x_blocks.shape[-2:])


def scatter_blocks(base: jax.Array, ids: jax.Array, cnt: jax.Array,
                   vals: jax.Array) -> jax.Array:
    """Scatter capacity-padded block rows into ``base`` (..., T, b, d).

    Padding slots (slot >= cnt) are masked out, so they can never clobber a
    live block that shares their (duplicated) id.

    §Perf iteration C3: implemented as a ONE-HOT EINSUM rather than an HLO
    scatter — data-dependent scatters on a sequence-sharded axis forced
    GSPMD to all-gather the whole operand (188 GB/step on the 33K HunyuanVideo
    cell); the einsum contracts the capacity axis instead, keeps the token
    axis sharded, and runs on the MXU (~3 TFLOP extra vs 3.8 s of ICI).
    Duplicate padded ids are benign: their mask row is zero.
    """
    t = base.shape[-3]
    slot = jnp.arange(ids.shape[-1], dtype=jnp.int32)
    live = slot < cnt[..., None]                              # (..., C)
    onehot = jax.nn.one_hot(jnp.where(live, ids, t), t + 1,
                            dtype=base.dtype)[..., :t]        # (..., C, T)
    scattered = jnp.einsum("...ct,...cbd->...tbd", onehot,
                           vals.astype(base.dtype))
    written = jnp.einsum("...ct->...t", onehot)               # 0/1 per block
    return jnp.where(written[..., None, None] > 0, scattered, base)


def attention_plan_indices(m_c: jax.Array, m_s: jax.Array,
                           spec: SparseAttentionSpec):
    """Index-decode step of the structural path (runs at Update time only).

    Returns ``(q_ids, q_cnt, kv_ids, kv_cnt, pair_live)`` — the attention
    slice of a :class:`repro.core.plan.DispatchPlan`.  All sort/top-k work
    of the XLA path lives here.
    """
    q_ids, q_cnt = active_indices(m_c, spec.cap_q)                     # (..., Cq)
    # KV-block union over live rows, importance = how many live rows need
    # the block; clamped to the static capacity.  The union layout is only
    # consumed when cap_kv admits the full union (cap_kv == T_kv, so the
    # clamp is a no-op); whenever truncation is possible the reduction
    # runs over the per-row CSR lists instead (shared Pallas semantics).
    need = jnp.sum(m_s & m_c[..., None], axis=-2)                      # (..., T_kv)
    kv_union = clamp_mask_topk(need > 0, need, spec.cap_kv)
    kv_ids, kv_cnt = active_indices(kv_union, spec.cap_kv)             # (..., Ck)
    pair = jnp.take_along_axis(
        jnp.take_along_axis(m_s, q_ids[..., :, None], axis=-2),
        kv_ids[..., None, :], axis=-1,
    )                                                                   # (..., Cq, Ck)
    kv_valid = jnp.arange(spec.cap_kv) < kv_cnt[..., None]             # (..., Ck)
    pair_live = pair & kv_valid[..., None, :]
    return q_ids, q_cnt, kv_ids, kv_cnt, pair_live


def sparse_attention_from_plan(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o_reuse: jax.Array,
    q_ids: jax.Array,
    q_cnt: jax.Array,
    kv_ids: jax.Array,
    kv_cnt: jax.Array,
    pair_live: jax.Array,
    spec: SparseAttentionSpec,
    *,
    scale: Optional[float] = None,
    q_chunk_blocks: int = 16,
    q_src_ids: Optional[jax.Array] = None,
    kv_row_ids: Optional[jax.Array] = None,
    kv_row_cnt: Optional[jax.Array] = None,
    force_per_row: bool = False,
) -> jax.Array:
    """Structurally sparse attention over PRECOMPUTED indices.

    Shapes: q,k,v,o_reuse (..., N, d); index arrays as returned by
    :func:`attention_plan_indices`.  Contains no index decoding — a
    Dispatch step traces only gathers/einsums/softmax from here.

    ``q_src_ids`` optionally re-maps the Q gather to a different (compact)
    block layout while the output scatter keeps the full-layout ``q_ids``
    (GEMM-Q layout fusion).  The gathered live Q blocks are processed in
    chunks of ``q_chunk_blocks`` so peak score memory is
    O(chunk·bq·Ckv·bk) regardless of N (needed for the 33K-token
    HunyuanVideo cells).

    ``kv_row_ids``/``kv_row_cnt`` (the DispatchPlan's per-live-row CSR
    column lists) switch the reduction to the PER-ROW layout whenever
    ``cap_kv`` can truncate the per-head KV union (``cap_kv < T_kv``):
    each live row gathers its own KV-block list, which is exactly the
    Pallas CSR kernel's semantics.  The old union layout dropped whole
    columns globally per head when the union overflowed the capacity —
    the documented XLA-vs-Pallas divergence this path closes.  With
    capacity admitting the full union both layouts are bit-identical and
    the cheaper union gather is used.
    """
    bq, bk = spec.block_q, spec.block_kv
    d = q.shape[-1]
    n_kv = k.shape[-2]
    t_q = o_reuse.shape[-2] // bq
    t_kv = n_kv // bk
    scale = (d ** -0.5) if scale is None else scale
    q_src_ids = q_ids if q_src_ids is None else q_src_ids
    # Per-row layout whenever truncation is possible: cap_kv below the full
    # union, OR occupancy buckets (a narrow bucket can truncate a row even
    # with cap_kv == T_kv; the bucket-truncated counts live in kv_row_cnt),
    # OR the caller forces it (mesh-folded plans: the pair clamp lives in
    # kv_row_cnt, which the union layout would ignore).
    per_row = kv_row_ids is not None and (force_per_row
                                          or spec.cap_kv < t_kv
                                          or spec.kv_buckets > 1)

    qb = q.reshape(*q.shape[:-2], q.shape[-2] // bq, bq, d)
    kb = k.reshape(*k.shape[:-2], t_kv, bk, d)
    vb = v.reshape(*v.shape[:-2], t_kv, bk, d)
    if not per_row:
        kg = _gather_blocks(kb, kv_ids)                                # (..., Ck, bk, d)
        vg = _gather_blocks(vb, kv_ids)

    def q_chunk(ids_c, live_c):
        """One chunk of live q-block ids + its pair mask -> outputs."""
        qg = _gather_blocks(qb, ids_c)                                 # (..., cc, bq, d)
        s = jnp.einsum("...ipd,...jqd->...ipjq", qg, kg).astype(jnp.float32) * scale
        s = jnp.where(live_c[..., :, None, :, None], s, _NEG_INF)
        cc = ids_c.shape[-1]
        sf = s.reshape(*s.shape[:-4], cc, bq, spec.cap_kv * bk)
        p = jax.nn.softmax(sf, axis=-1).reshape(s.shape)
        return jnp.einsum("...ipjq,...jqd->...ipd", p,
                          vg.astype(jnp.float32)).astype(q.dtype)

    def q_chunk_rowcsr(ids_c, rids_c, rcnt_c):
        """One chunk of live q blocks, each with its OWN KV-block list."""
        qg = _gather_blocks(qb, ids_c)                                 # (..., cc, bq, d)
        kg_r = _gather_row_blocks(kb, rids_c)                          # (..., cc, Ck, bk, d)
        vg_r = _gather_row_blocks(vb, rids_c)
        s = jnp.einsum("...ipd,...ijqd->...ipjq", qg,
                       kg_r).astype(jnp.float32) * scale
        live = jnp.arange(rids_c.shape[-1]) < rcnt_c[..., None]        # (..., cc, Ck)
        s = jnp.where(live[..., :, None, :, None], s, _NEG_INF)
        cc = ids_c.shape[-1]
        sf = s.reshape(*s.shape[:-4], cc, bq, spec.cap_kv * bk)
        p = jax.nn.softmax(sf, axis=-1).reshape(s.shape)
        return jnp.einsum("...ipjq,...ijqd->...ipd", p,
                          vg_r.astype(jnp.float32)).astype(q.dtype)

    if spec.cap_q <= q_chunk_blocks or spec.cap_q % q_chunk_blocks != 0:
        og = (q_chunk_rowcsr(q_src_ids, kv_row_ids, kv_row_cnt) if per_row
              else q_chunk(q_src_ids, pair_live))
    elif per_row:
        n_ch = spec.cap_q // q_chunk_blocks
        ids_ch = jnp.moveaxis(
            q_src_ids.reshape(*q_src_ids.shape[:-1], n_ch, q_chunk_blocks), -2, 0)
        rids_ch = jnp.moveaxis(
            kv_row_ids.reshape(*kv_row_ids.shape[:-2], n_ch, q_chunk_blocks,
                               kv_row_ids.shape[-1]), -3, 0)
        rcnt_ch = jnp.moveaxis(
            kv_row_cnt.reshape(*kv_row_cnt.shape[:-1], n_ch, q_chunk_blocks),
            -2, 0)
        og_ch = jax.lax.map(lambda t: q_chunk_rowcsr(*t),
                            (ids_ch, rids_ch, rcnt_ch))
        og = jnp.moveaxis(og_ch, 0, -4)
        og = og.reshape(*og.shape[:-4], spec.cap_q, bq, d)
    else:
        n_ch = spec.cap_q // q_chunk_blocks
        ids_ch = jnp.moveaxis(
            q_src_ids.reshape(*q_src_ids.shape[:-1], n_ch, q_chunk_blocks), -2, 0)
        live_ch = jnp.moveaxis(
            pair_live.reshape(*pair_live.shape[:-2], n_ch, q_chunk_blocks,
                              pair_live.shape[-1]), -3, 0)
        og_ch = jax.lax.map(lambda t: q_chunk(*t), (ids_ch, live_ch))
        og = jnp.moveaxis(og_ch, 0, -4)                                # (..., n_ch, cc, bq, d)
        og = og.reshape(*og.shape[:-4], spec.cap_q, bq, d)

    # Scatter computed blocks over the reuse baseline (padding slots dropped).
    out_blocks = o_reuse.reshape(*o_reuse.shape[:-2], t_q, bq, d)
    out_blocks = scatter_blocks(out_blocks, q_ids, q_cnt, og)
    return out_blocks.reshape(o_reuse.shape)


def sparse_attention_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    m_c: jax.Array,
    m_s: jax.Array,
    o_reuse: jax.Array,
    spec: SparseAttentionSpec,
    *,
    scale: Optional[float] = None,
    q_chunk_blocks: int = 16,
) -> jax.Array:
    """Structurally sparse attention (see module docstring).

    Shapes: q,k,v,o_reuse (..., N, d); m_c (..., T_q); m_s (..., T_q, T_kv).
    Mask-level entry point: decodes indices per call (legacy rebuild path).
    The Update–Dispatch engine instead decodes once via
    :func:`attention_plan_indices` and calls
    :func:`sparse_attention_from_plan` on every Dispatch step.  When
    ``cap_kv`` can truncate the union the per-row CSR lists are decoded
    too, so this path shares the Pallas per-row truncation semantics.
    """
    q_ids, q_cnt, kv_ids, kv_cnt, pair_live = attention_plan_indices(
        m_c, m_s, spec)
    kv_row_ids = kv_row_cnt = None
    if spec.cap_kv < m_s.shape[-1] or spec.kv_buckets > 1:
        rows = jnp.take_along_axis(m_s, q_ids[..., :, None], axis=-2)
        kv_row_ids, kv_row_cnt = active_indices(rows, spec.cap_kv)
    return sparse_attention_from_plan(
        q, k, v, o_reuse, q_ids, q_cnt, kv_ids, kv_cnt, pair_live, spec,
        scale=scale, q_chunk_blocks=q_chunk_blocks,
        kv_row_ids=kv_row_ids, kv_row_cnt=kv_row_cnt)


def sparse_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_ids: jax.Array,
    kv_cnt: jax.Array,
    block_kv: int,
    *,
    scale: Optional[float] = None,
    positions: Optional[jax.Array] = None,
    cache_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Block-sparse decode: one (or few) query tokens against a gathered
    subset of KV-cache blocks (LM serving adaptation of ``S_s``).

    q: (..., n_new, d); caches: (..., S, d); kv_ids/kv_cnt from
    :func:`active_indices` over the per-head KV keep mask.
    """
    d = q.shape[-1]
    s_total = k_cache.shape[-2]
    t_kv = s_total // block_kv
    scale = (d ** -0.5) if scale is None else scale
    kb = k_cache.reshape(*k_cache.shape[:-2], t_kv, block_kv, d)
    vb = v_cache.reshape(*v_cache.shape[:-2], t_kv, block_kv, d)
    kg = _gather_blocks(kb, kv_ids)
    vg = _gather_blocks(vb, kv_ids)
    s = jnp.einsum("...nd,...jqd->...njq", q, kg).astype(jnp.float32) * scale
    valid = jnp.arange(kv_ids.shape[-1]) < kv_cnt[..., None]            # (..., Ck)
    live = valid[..., None, :, None]
    if cache_len is not None:
        tok_pos = kv_ids[..., :, None] * block_kv + jnp.arange(block_kv)
        live = live & (tok_pos < cache_len[..., None, None, None])
    s = jnp.where(live, s, _NEG_INF)
    sf = s.reshape(*s.shape[:-2], -1)
    p = jax.nn.softmax(sf, axis=-1).reshape(s.shape)
    return jnp.einsum("...njq,...jqd->...nd", p, vg.astype(jnp.float32)).astype(q.dtype)
