"""Backend routing for the Update–Dispatch engine (paper Fig. 4 "engine").

One logical Dispatch step = GEMM-Q → sparse attention → GEMM-O, all driven
by a precomputed :class:`~repro.core.plan.DispatchPlan`.  Two
interchangeable implementations sit behind a common interface:

  * :class:`XlaBackend`   — the pjit/XLA structural path (capacity-padded
    gathers + one-hot scatters).  Multi-pod / GSPMD friendly; the dry-run
    and roofline tooling lower this one.
  * :class:`PallasBackend` — the paper-faithful Pallas TPU kernels
    (``flashomni_attention_csr`` + ``gemm_q_sparse_kernel`` +
    ``gemm_o_sparse_kernel``), chained through the COMPACT GEMM-Q layout:
    the ``(Cr·bm, F)`` live-row projection feeds the CSR attention kernel
    directly via ``plan.q_slots`` — no scatter between the two kernels.
    Batch is part of every kernel's GRID (attention folds it into the
    flattened ``B·H`` leading axis; the GEMMs carry a leading batch grid
    dimension over per-sample scalar-prefetched index lists), so one
    ``pallas_call`` covers the whole batch.  Off-TPU the kernels run with
    ``interpret=True`` so tests and CI exercise the exact same code path.

Selection lives on ``EngineConfig.backend``: ``"xla"`` | ``"pallas"`` |
``"auto"`` (Pallas on real TPUs, XLA elsewhere).

Truncation semantics are SHARED: when ``cap_kv`` can truncate a head's
KV-block list (``cap_kv < T_kv``) the XLA path switches from the per-head
union layout to the same per-row CSR lists the Pallas kernel consumes
(``plan.kv_row_ids``/``kv_row_cnt``), so both backends truncate each
row's KV list identically — parity holds under truncation, not just when
the capacity admits the full union (see ``tests/test_backend.py``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sparse_gemm
from repro.core.attention import SparseAttentionSpec, sparse_attention_from_plan
from repro.core.plan import DispatchPlan

__all__ = ["XlaBackend", "PallasBackend", "MeshBackend", "get_backend",
           "available_backends"]


class XlaBackend:
    """Structural-sparse XLA path over precomputed plan indices."""

    name = "xla"
    compact_q = False

    def gemm_q(self, x: jax.Array, w: jax.Array, plan: DispatchPlan, *,
               block: int) -> jax.Array:
        """(B, N, d_in) @ (d_in, F) -> (B, N, F), zeros on cached rows."""
        plan = plan.widen()
        return sparse_gemm.gemm_q_from_plan(
            x, w, plan.row_ids, plan.row_cnt, block=block)

    def attention(self, q, k, v, o_reuse, plan: DispatchPlan,
                  spec: SparseAttentionSpec, *, scale: Optional[float] = None,
                  compact_q: bool = False) -> jax.Array:
        """q (B,H,N_q,dh) [compact when ``compact_q``], k/v/o_reuse full.

        The per-row CSR lists are passed alongside the union layout;
        ``sparse_attention_from_plan`` consumes them whenever ``cap_kv``
        can truncate, matching the Pallas kernel's per-row truncation."""
        plan = plan.widen()
        return sparse_attention_from_plan(
            q, k, v, o_reuse, plan.q_ids, plan.q_cnt, plan.kv_ids,
            plan.kv_cnt, plan.pair_live, spec, scale=scale,
            q_src_ids=plan.q_slots if compact_q else None,
            kv_row_ids=plan.kv_row_ids, kv_row_cnt=plan.kv_row_cnt,
            # Mesh-folded plans carry the pair clamp in kv_row_cnt only;
            # the union layout (which ignores it) must never be taken even
            # when cap_kv admits the full union — this is how the single-
            # device oracle consumes a mesh plan bit-identically.
            force_per_row=plan.shd_q_ids is not None)

    def gemm_o(self, o_tok, w, plan: DispatchPlan, bias: jax.Array, *,
               block: int,
               spec: Optional[SparseAttentionSpec] = None) -> jax.Array:
        """o_tok (B,N,H,dh), w (H,dh,F), bias (B,N,F) -> (B,N,F).

        ``plan.head_mask`` already carries any bucket-induced head clamp
        (folded back at Update time, see ``plan.gmo_layout``), so this
        path needs no bucket awareness to stay bit-consistent with the
        bucketed kernel."""
        plan = plan.widen()
        return sparse_gemm.gemm_o_from_plan(
            o_tok, w, plan.head_mask, plan.row_ids, plan.row_cnt, bias,
            block=block)


class PallasBackend:
    """Pallas kernel path (CSR attention + sparse GEMMs, layout-fused)."""

    name = "pallas"
    compact_q = True

    def __init__(self, interpret: Optional[bool] = None):
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret

    def gemm_q(self, x: jax.Array, w: jax.Array, plan: DispatchPlan, *,
               block: int) -> jax.Array:
        """COMPACT (B, Cr·block, F) projection of the live row blocks.

        Batch is a kernel-grid dimension — ONE ``pallas_call`` covers the
        whole batch (ROADMAP item: no Python unroll over B)."""
        plan = plan.widen()
        from repro.kernels.gemm_q import gemm_q_sparse_kernel
        from repro.kernels.tuning import kernel_tiles
        tiles = kernel_tiles("gemm_q", x.shape[-1])
        return gemm_q_sparse_kernel(x, w, plan.row_ids, block_rows=block,
                                    block_k=tiles.get("block_k", 512),
                                    block_f=tiles.get("block_f", 512),
                                    row_cnt=plan.row_cnt,
                                    interpret=self.interpret)

    def attention(self, q, k, v, o_reuse, plan: DispatchPlan,
                  spec: SparseAttentionSpec, *, scale: Optional[float] = None,
                  compact_q: bool = False) -> jax.Array:
        plan = plan.widen()   # Pallas index maps require int32 scalar ids
        from repro.kernels.flashomni_attention import flashomni_attention_csr
        b, h, n_q, dh = q.shape
        n = o_reuse.shape[-2]
        flat = lambda a: a.reshape(b * h, *a.shape[2:])
        if spec.kv_buckets > 1 and plan.bkt_head is not None:
            # Occupancy-bucketed two-level grid: the layout rows fold the
            # head axis, so the plan's (B, R)/(B, S) fields stay unflattened.
            from repro.core.plan import bucket_geometry
            from repro.kernels.flashomni_attention import (
                flashomni_attention_csr_bucketed,
            )
            geometry = bucket_geometry(spec.cap_q, spec.cap_kv, h,
                                       spec.kv_buckets)
            out = flashomni_attention_csr_bucketed(
                flat(q), flat(k), flat(v), flat(o_reuse),
                plan.bkt_head, plan.bkt_q_ids,
                plan.bkt_q_slots if compact_q else plan.bkt_q_src,
                plan.bkt_kv_ids, plan.bkt_kv_cnt, geometry,
                heads=h, block_q=spec.block_q, block_kv=spec.block_kv,
                scale=scale, interpret=self.interpret)
            # No any_live guard needed: dead layout rows write only to the
            # trash pad; cached rows keep their aliased o_reuse values.
            return out.reshape(b, h, n, dh)
        out = flashomni_attention_csr(
            flat(q), flat(k), flat(v), flat(o_reuse),
            flat(plan.q_ids), flat(plan.kv_row_ids), flat(plan.kv_row_cnt),
            block_q=spec.block_q, block_kv=spec.block_kv, scale=scale,
            interpret=self.interpret,
            q_src_ids=flat(plan.q_slots) if compact_q else None)
        # Degenerate all-cached guard (paper A.1.1 S_q degradation): with
        # zero live rows the kernel writes garbage through the duplicated
        # slot-0 id; keep the pure-reuse tensor for those (b, h).
        any_live = (flat(plan.q_cnt) > 0)[:, None, None]
        out = jnp.where(any_live, out, flat(o_reuse))
        return out.reshape(b, h, n, dh)

    def gemm_o(self, o_tok, w, plan: DispatchPlan, bias: jax.Array, *,
               block: int,
               spec: Optional[SparseAttentionSpec] = None) -> jax.Array:
        """Batched in the kernel grid, like :meth:`gemm_q`.

        With ``spec.kv_buckets > 1`` and a plan carrying the ``gmo_*``
        layout, routes to the occupancy-bucketed two-level grid — the
        geometry is re-derived statically from the spec exactly as the
        plan build derived it, and the plan's ``head_cnt``/``head_mask``
        already fold the bucket clamp, so uniform vs bucketed stays
        bit-identical."""
        plan = plan.widen()
        from repro.kernels.tuning import kernel_tiles
        h = w.shape[0]
        tiles = kernel_tiles("gemm_o", h)
        block_f = tiles.get("block_f", 512)
        if spec is not None and spec.kv_buckets > 1 \
                and plan.gmo_rows is not None:
            from repro.core.plan import bucket_geometry
            from repro.kernels.gemm_o import gemm_o_sparse_bucketed_kernel
            cr = plan.row_ids.shape[-1]
            geometry = bucket_geometry(cr, h, 1, spec.kv_buckets)
            return gemm_o_sparse_bucketed_kernel(
                o_tok.transpose(0, 2, 1, 3), w, bias, plan.gmo_rows,
                plan.gmo_src, plan.gmo_head_ids, plan.gmo_head_cnt,
                geometry, block_rows=block, block_f=block_f,
                interpret=self.interpret)
        from repro.kernels.gemm_o import gemm_o_sparse_kernel
        return gemm_o_sparse_kernel(
            o_tok.transpose(0, 2, 1, 3), w, bias, plan.row_ids,
            plan.head_ids, plan.head_cnt, block_rows=block, block_f=block_f,
            interpret=self.interpret)


class MeshBackend:
    """Mesh-sharded dispatch: the inner backend runs per shard under a
    ``shard_map`` over the (data, seq) engine mesh, exchanging only the
    plan-live KV blocks (``distributed/plan_shard.py``).  GEMM-Q/GEMM-O
    delegate to the inner backend unchanged — their sharding is GSPMD's
    job via the state specs; only attention needs explicit collectives."""

    def __init__(self, inner, cfg):
        self.inner = inner
        self.cfg = cfg
        self.name = f"mesh-{inner.name}"
        self.compact_q = inner.compact_q

    def gemm_q(self, x, w, plan, *, block):
        return self.inner.gemm_q(x, w, plan, block=block)

    def attention(self, q, k, v, o_reuse, plan: DispatchPlan,
                  spec: SparseAttentionSpec, *, scale: Optional[float] = None,
                  compact_q: bool = False):
        from repro.distributed.plan_shard import mesh_attention
        return mesh_attention(self.inner, self.cfg, q, k, v, o_reuse, plan,
                              spec, scale=scale, compact_q=compact_q)

    def gemm_o(self, o_tok, w, plan, bias, *, block, spec=None):
        return self.inner.gemm_o(o_tok, w, plan, bias, block=block, spec=spec)


_XLA = XlaBackend()


def available_backends() -> tuple[str, ...]:
    return ("xla", "pallas", "auto")


def get_backend(cfg):
    """Resolve ``EngineConfig.backend`` to a backend instance.

    ``cfg.mesh_sp > 1`` wraps the resolved backend in :class:`MeshBackend`
    — the same Update→Dispatch flow, with attention running sharded."""
    name = cfg.backend
    if name == "auto":
        name = "pallas" if jax.default_backend() == "tpu" else "xla"
    if name == "xla":
        inner = _XLA
    elif name == "pallas":
        inner = PallasBackend(interpret=getattr(cfg, "interpret", None))
    else:
        raise ValueError(
            f"unknown engine backend {cfg.backend!r}; expected one of "
            f"{available_backends()}")
    if getattr(cfg, "mesh_sp", 1) > 1:
        return MeshBackend(inner, cfg)
    return inner
