"""FlashOmni Update–Dispatch engine (paper §3.2, Fig. 4).

The engine owns, per attention layer, the packed sparse symbols, the
TaylorSeer cache state and the GEMM-O cache bias, and exposes two step
functions over a generic attention module:

  * :func:`update_layer`   — full attention; refresh ``S_c``/``S_s`` from the
    fresh Q/K (mask generation of §3.3), refresh the TaylorSeer derivative
    stack and the GEMM-O bias ``B_c`` (stage 1 of §3.5).
  * :func:`dispatch_layer` — sparse execution guided by the frozen symbols:
    GEMM-Q skips cached row blocks, attention runs the structural sparse
    path (or the Pallas kernel on TPU), GEMM-O projects live heads and adds
    the Taylor-forecast bias.

Two cache modes (DESIGN §2.3/§2.4):
  * ``"bias"``    — paper-optimized: cache B_c in output space; cached
    blocks never touch the attention kernel (Eq. 4 makes this exact).
  * ``"o_cache"`` — paper-base: cache per-head attention outputs Õ and let
    the attention kernel's cache-then-reuse branch fill them.

Symbols are stored at the *compressed* granularity (pool = n·b) exactly as
in the paper (decode ``F(S_c, i) = (S_c >> i/n) & 1``), and expanded to
kernel-block granularity on use.

Update→plan→Dispatch dataflow (compile-once DispatchPlan):

    update_layer ──► strategy.emit(q, k, ctx) ──► SymbolSet (S_c, S_s,
                         │                         masks, clamp scores)
                         └─► build_dispatch_plan ──► DispatchPlan
                               (ALL unpack / expand / top-k / argsort
                                index work happens HERE, once per 𝒩 steps)
                         LayerState = (S_c, S_s, taylor, k_since, plan)

The symbol producer is pluggable (``EngineConfig.strategy`` — a
:mod:`repro.core.strategy` registry name, resolved once at trace time):
the paper's §3.3 rule is the ``"flashomni"`` strategy; ``"cache-all"``
(FORA/TaylorSeer), ``"skip-only"`` (SpargeAttn), ``"sliding-window"``
(DiTFastAttnV2), ``"multi-granularity"`` tables and ``"step-phased"``
(per-step re-classification) ride the same engine and kernels unchanged.
:func:`refresh_symbols` keeps the seed §3.3 body verbatim as the
bit-parity oracle for the ``flashomni`` strategy.  Whole (step × layer)
deployment plans are TRACED data: :func:`resolve_schedule` canonicalizes
the config into a :class:`~repro.core.schedule.SparsitySchedule`, and
``update_layer`` accepts a traced ``strategy_id`` over a schedule's
static strategy set (``strategy.emit_switch``) plus traced
``layer_idx``/``step_idx`` context.

    dispatch_layer ──► get_backend(cfg) ──► backend.{gemm_q, attention,
                                                      gemm_o}(…, plan)
                       consumes ``state.plan`` VERBATIM — a Dispatch jaxpr
                       contains no ``unpack_bits``/``clamp_mask_topk``/
                       ``active_indices`` work (see tests/test_backend.py).

Backend routing (``EngineConfig.backend``): ``"xla"`` structural path,
``"pallas"`` CSR kernels with compact GEMM-Q→attention layout fusion, or
``"auto"`` (Pallas on TPU hardware, XLA elsewhere).  The packed symbols
stay in the state as the canonical compressed representation (diagnostics,
resharding, and the paper's symbol-decode fidelity kernels).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import masks as masklib
from repro.core import sparse_gemm, taylorseer
from repro.core.lru import LruCache
from repro.core.attention import SparseAttentionSpec, dense_attention
from repro.core.backend import get_backend
from repro.core.masks import MaskConfig
from repro.core.plan import DispatchPlan, build_dispatch_plan, empty_plan_like
from repro.core.strategy import (SparsityStrategy, StrategyContext,
                                 emit_switch, get_strategy)
from repro.core.symbols import (
    capacity_for,
    clamp_mask_topk,
    pack_bits,
    packed_len,
    unpack_bits,
)

__all__ = [
    "EngineConfig",
    "LayerState",
    "AttnParams",
    "DispatchPlan",
    "init_layer_state",
    "is_update_step",
    "resolve_schedule",
    "schedule_cache_stats",
    "stack_lane_states",
    "gather_lane_states",
    "scatter_lane_states",
    "merge_lane_states",
    "set_lane_state",
    "update_layer",
    "dispatch_layer",
    "plan_from_state",
    "refresh_symbols",
    "rms_norm",
    "apply_rope",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine configuration = paper tuple (τ_q, τ_kv, 𝒩, 𝒟, S_q) + statics."""

    mask: MaskConfig = MaskConfig()
    cache_mode: str = "bias"          # "bias" | "o_cache"
    cap_q_frac: float = 0.75          # static live-Q capacity fraction
    cap_kv_frac: float = 0.9          # static KV-union capacity fraction
    use_gemm_q: bool = True
    use_gemm_o: bool = True
    cache_dtype: jnp.dtype = jnp.bfloat16
    backend: str = "xla"              # "xla" | "pallas" | "auto"
    interpret: Optional[bool] = None  # Pallas interpret mode (None: off-TPU)
    kv_buckets: int = 1               # occupancy buckets in the CSR grid
                                      # (1 = uniform cap_kv reduction;
                                      # 0 = AUTO: pick from the calibrated
                                      # occupancy histogram at schedule-
                                      # resolution time, see
                                      # kernels.tuning.select_kv_buckets;
                                      # see core.plan.bucket_geometry)
    strategy: str = "flashomni"       # sparse-symbol producer (registry name)
    schedule: Optional[str] = None    # named SparsitySchedule preset (overrides
                                      # the strategy/interval mapping in
                                      # resolve_schedule; see core.schedule)
    # Plan-sharded mesh dispatch (distributed/plan_shard.py).  mesh_sp > 1
    # routes attention through a shard_map over the (data, seq) engine
    # mesh; with mesh_axis == "seq" the plan carries per-shard partitions
    # + the plan-aware collective schedule (shd_* fields).  All statics —
    # they key jit caches and the LRU memos like every other field here.
    mesh_dp: int = 1                  # data-parallel shards (batch axis)
    mesh_sp: int = 1                  # sequence/head-parallel shards
    mesh_axis: str = "seq"            # "seq" (token shards + plan-aware
                                      # collectives) | "head" (no collectives)
    mesh_pair_slack: float = 1.5      # per-(src,dst) shipped-block capacity
                                      # slack over cap_kv/P (≥ 1 keeps the
                                      # per-shard union clamp a no-op)
    validate_plans: bool = False      # debug: run the structural plan
                                      # validator (analysis/plan_check.py)
                                      # on host after every plan build;
                                      # REPRO_VALIDATE_PLANS=1 turns it on
                                      # globally without touching configs

    # Capacity bookkeeping.  The single source of truth is the COMPRESSED
    # granularity capacity (symbols live there); block-granularity caps are
    # exact multiples so no live block can overflow the attention gather.
    def cap_q_cmp(self, n_tokens: int) -> int:
        return capacity_for(self.mask.n_blocks(n_tokens), self.cap_q_frac, quantum=1)

    def cap_kv_cmp(self, n_kv: int) -> int:
        return capacity_for(self.mask.n_blocks(n_kv), self.cap_kv_frac, quantum=1)

    def resolved_kv_buckets(self) -> int:
        """``kv_buckets`` with the 0 = "auto" sentinel resolved.

        Auto consults the calibration table's occupancy histogram for
        ``self.strategy`` (:func:`repro.kernels.tuning.select_kv_buckets`)
        — a pure function of the STATIC config, evaluated at schedule /
        spec-resolution time, so every jit cache keyed on this config
        still maps one configuration to one executable and Dispatch
        jaxprs stay sort-free.  Under a mesh the choice is forced to 1:
        the seq-sharded inner spec runs uniform per shard and the head
        mesh rejects buckets outright (distributed/plan_shard.py)."""
        if self.kv_buckets != 0:
            return self.kv_buckets
        if self.mesh_sp > 1:
            return 1
        from repro.kernels.tuning import select_kv_buckets
        return select_kv_buckets(self.strategy)

    def caps(self, n_tokens: int, n_kv: Optional[int] = None) -> SparseAttentionSpec:
        n_kv = n_tokens if n_kv is None else n_kv
        m = self.mask
        t_q = -(-n_tokens // m.block_q)
        t_kv = -(-n_kv // m.block_kv)
        fq, fk = m.pool // m.block_q, m.pool // m.block_kv
        return SparseAttentionSpec(
            block_q=m.block_q,
            block_kv=m.block_kv,
            cap_q=min(self.cap_q_cmp(n_tokens) * fq, t_q),
            cap_kv=min(self.cap_kv_cmp(n_kv) * fk, t_kv),
            kv_buckets=self.resolved_kv_buckets(),
        )


class AttnParams(NamedTuple):
    """Weights of one attention module (MMDiT joint-attention style)."""

    wq: jax.Array            # (dm, H*dh)
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array            # (H*dh, dm)
    q_scale: jax.Array       # (dh,) RMSNorm scales (token-local, Obs. 2)
    k_scale: jax.Array


class LayerState(NamedTuple):
    """Per-layer engine state carried across denoising steps (a pytree)."""

    s_c: jax.Array                 # (B, H, cmp_bytes) uint8 — caching symbol
    s_s: jax.Array                 # (B, H, flat_bytes) uint8 — skipping symbol
    taylor: taylorseer.TaylorState  # over B_c (bias mode) or Õ (o_cache mode)
    k_since: jax.Array             # int32 — dispatch offset since last Update
    plan: DispatchPlan             # compile-once index plan (refreshed at Update)


def init_layer_state(
    batch: int, heads: int, n_tokens: int, d_model: int, head_dim: int, cfg: EngineConfig
) -> LayerState:
    t = cfg.mask.n_blocks(n_tokens)
    cbytes = packed_len(t)
    fbytes = packed_len(t * t)
    if cfg.cache_mode == "bias":
        feat = (batch, n_tokens, d_model)
    else:
        feat = (batch, heads, n_tokens, head_dim)
    return LayerState(
        s_c=jnp.full((batch, heads, cbytes), 255, jnp.uint8),
        s_s=jnp.full((batch, heads, fbytes), 255, jnp.uint8),
        taylor=taylorseer.init_state(feat, cfg.mask.order, cfg.cache_dtype),
        k_since=jnp.zeros((), jnp.int32),
        plan=empty_plan_like(batch, heads, n_tokens, cfg),
    )


def stack_lane_states(states: "LayerState", n_lanes: int) -> "LayerState":
    """Broadcast one request's engine state to ``n_lanes`` microbatch lanes.

    ``states`` is any LayerState pytree (typically the (L, ...)-stacked
    tree from ``models.dit.init_engine_states``); every leaf gains a
    leading ``(n_lanes, ...)`` lane axis.  The continuous batcher carries
    ONE such stacked tree and scans its lane axis per serving tick."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_lanes, *x.shape)), states)


def gather_lane_states(stacked, lane_ids):
    """Gather lanes ``lane_ids`` of a lane-stacked pytree (device-side).

    ``lane_ids`` is any int array (host list or traced); every leaf is
    indexed along its leading lane axis — the general device-side lane
    SELECT the batched serving tick builds on (``jnp.take`` along axis 0,
    so the ids may themselves be traced data inside a jitted tick)."""
    ids = jnp.asarray(lane_ids, jnp.int32)
    return jax.tree.map(lambda s: jnp.take(s, ids, axis=0), stacked)


def scatter_lane_states(stacked, lane_ids, values):
    """Scatter ``values`` into lanes ``lane_ids`` of a lane-stacked pytree.

    ``values`` carries a leading axis of ``len(lane_ids)``; untouched lanes
    keep their state.  ``lane_ids`` must be unique (XLA scatter order is
    otherwise unspecified) and may be TRACED — this is the device-side
    generalization of :func:`set_lane_state` for use INSIDE compiled tick
    bodies, where the scatter lowers once per executable.  On the eager
    host path prefer :func:`set_lane_state`: a static-index update-slice
    dispatches several times faster than an array-index scatter."""
    ids = jnp.asarray(lane_ids, jnp.int32)
    return jax.tree.map(lambda s, v: s.at[ids].set(v.astype(s.dtype)),
                        stacked, values)


def merge_lane_states(old, new, lane_mask):
    """Per-lane select between two lane-stacked pytrees (device-side).

    ``lane_mask`` is a ``(lanes,)`` bool; True lanes take ``new``, False
    lanes keep ``old``.  Used by the batched mode-group tick bodies to
    write back ONLY the lanes that belong to the launched group — the
    fixed-width group body computes every lane (shape-stable executable)
    and this masked scatter discards the rest."""
    mask = jnp.asarray(lane_mask)

    def sel(o, n):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(sel, old, new)


def set_lane_state(stacked, lane: int, fresh):
    """Replace lane ``lane`` of a lane-stacked pytree with ``fresh``.

    The EAGER host-path lane write, used at lane REFILL: a retired lane's
    engine state (and latents / text embeddings) is overwritten with the
    next request's fresh state without touching the other in-flight lanes
    — static-index ``.at[lane].set`` update-slices (cheap to dispatch
    eagerly), no recompilation of the serving tick.  Inside compiled tick
    bodies use :func:`scatter_lane_states` / :func:`gather_lane_states` /
    :func:`merge_lane_states`, the traced-index generalizations."""
    return jax.tree.map(lambda s, f: s.at[lane].set(f), stacked, fresh)


def is_update_step(step: int, cfg: EngineConfig) -> bool:
    """Update/Dispatch phase of one step (warmup + every ``interval``).

    :func:`resolve_schedule` bakes this rule into the per-step ``mode``
    array of a :class:`~repro.core.schedule.SparsitySchedule`, which the
    single-scan sampler switches on; this Python predicate remains for
    host-side schedule construction and diagnostics.
    """
    m = cfg.mask
    if step < m.warmup_steps:
        return True
    return (step - m.warmup_steps) % m.interval == 0


# LRU-bounded (PR 4): a long-running server cycling distinct specs evicts
# the least-recently-resolved schedule instead of growing without limit.
# NOTE the coupling with the pipeline's sampler cache: evicting a schedule
# here means the next request with that spec resolves to a NEW schedule
# object, whose strategy identities miss the sampler cache and recompile —
# so this memo is sized ABOVE the sampler cache, never below.
_SCHEDULE_CACHE_SIZE = 128
_SCHEDULE_CACHE = LruCache(_SCHEDULE_CACHE_SIZE)


def schedule_cache_stats() -> dict:
    """Hit/miss/eviction counters of the schedule-resolution memo."""
    return _SCHEDULE_CACHE.stats()


def resolve_schedule(cfg: EngineConfig, num_steps: int, n_layers: int, *,
                     schedule=None, layer_strategies=None,
                     force_dense: bool = False):
    """Resolve the engine config into a canonical SparsitySchedule.

    ``EngineConfig.strategy`` / ``layer_strategies`` / ``mask.interval`` /
    ``mask.warmup_steps`` (and the ``EngineConfig.schedule`` named preset)
    collapse into one (step × layer) traced table — see
    :mod:`repro.core.schedule`.  An explicit ``schedule`` argument (name or
    prebuilt :class:`SparsitySchedule`) wins over everything.

    Resolution is MEMOIZED (LRU-bounded) for hashable specs (registry
    names + frozen configs) so repeated calls return the SAME schedule
    object — the sampler's jit cache keys on the schedule's strategy
    identities, and a stable resolution means the second request reuses
    the first request's compiled executable instead of re-tracing.

    Bucket-count auto-selection (``cfg.kv_buckets == 0``) happens at this
    resolution boundary too — :meth:`EngineConfig.resolved_kv_buckets`
    consults the calibration table per (strategy, config), so the chosen
    depth is frozen before any trace: one executable per configuration,
    and the serving ≤4-executable budget is unchanged (the candidate set
    {1, 2, 3} never multiplies executables — a config resolves to exactly
    one depth).
    """
    from repro.core.schedule import SparsitySchedule, get_schedule
    try:
        key = (cfg, num_steps, n_layers, schedule,
               None if layer_strategies is None else tuple(layer_strategies),
               force_dense)
        hash(key)
    except TypeError:
        key = None              # unhashable spec (ad-hoc objects): no memo
    if key is not None:
        cached = _SCHEDULE_CACHE.get(key)
        if cached is not None:
            return cached
    if schedule is not None and not force_dense:
        sched = get_schedule(schedule, cfg, num_steps, n_layers)
    else:
        sched = SparsitySchedule.from_config(cfg, num_steps, n_layers,
                                             layer_strategies=layer_strategies,
                                             force_dense=force_dense)
    if key is not None:
        _SCHEDULE_CACHE.put(key, sched)
    return sched


# ---------------------------------------------------------------------------
# Token-local pre-attention ops (Obs. 2: these commute with row skipping).
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def rope_freqs(n: int, dim: int, theta: float = 10000.0) -> jax.Array:
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    t = jnp.arange(n, dtype=jnp.float32)
    return jnp.outer(t, inv)  # (n, dim//2)


def apply_rope(x: jax.Array, freqs: jax.Array) -> jax.Array:
    """x: (..., N, dh); freqs: (N, dh//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos, sin = jnp.cos(freqs), jnp.sin(freqs)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _project_heads(x: jax.Array, w: jax.Array, heads: int) -> jax.Array:
    """(B, N, dm) @ (dm, H*dh) -> (B, H, N, dh)."""
    y = jnp.einsum("bnd,df->bnf", x, w)
    b, n = x.shape[:2]
    return y.reshape(b, n, heads, -1).transpose(0, 2, 1, 3)


def _qk(params: AttnParams, x: jax.Array, heads: int, freqs: Optional[jax.Array]):
    q = rms_norm(_project_heads(x, params.wq, heads), params.q_scale)
    k = rms_norm(_project_heads(x, params.wk, heads), params.k_scale)
    if freqs is not None:
        q, k = apply_rope(q, freqs), apply_rope(k, freqs)
    return q, k


def refresh_symbols(q: jax.Array, k: jax.Array, cfg: EngineConfig, n_text: int,
                    n_tokens: int) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """LEGACY seed §3.3 rule, kept verbatim as the bit-parity oracle.

    ``update_layer`` now calls the pluggable strategy resolved from
    ``cfg.strategy`` instead; ``tests/test_strategy.py`` asserts the
    ``"flashomni"`` strategy reproduces this function's packed symbols
    bit-for-bit.  Returns ``(s_c, s_s, m_c, m_s)`` — packed uint8 symbols
    plus the unpacked compressed-granularity masks (True = compute).
    """
    m = cfg.mask
    m_c = masklib.make_caching_mask(q, k, m, n_text)
    m_c = masklib.apply_degradation(m_c, m.degrade)
    # Static-capacity clamp on live blocks, ranked by total column mass.
    p_map = masklib.compressed_attention_map(q, k, m.pool)
    col_mass = jnp.sum(p_map, axis=-2)
    m_c = clamp_mask_topk(m_c, col_mass, cfg.cap_q_cmp(n_tokens))
    m_s = masklib.make_skip_mask(q, k, m, n_text)
    # Clamp per-row KV keeps to the compressed KV capacity (rank by mass).
    cap_kv = cfg.cap_kv_cmp(n_tokens)
    if cap_kv < m_s.shape[-1]:
        m_s = clamp_mask_topk(m_s, p_map, cap_kv)
    s_c = pack_bits(m_c)
    s_s = pack_bits(m_s.reshape(*m_s.shape[:-2], -1))
    return s_c, s_s, m_c, m_s


def _unpack(state: LayerState, cfg: EngineConfig, n_tokens: int):
    t = cfg.mask.n_blocks(n_tokens)
    m_c = unpack_bits(state.s_c, t)
    m_s = unpack_bits(state.s_s, t * t).reshape(*state.s_s.shape[:-1], t, t)
    return m_c, m_s


def plan_from_state(state: LayerState, cfg: EngineConfig,
                    n_tokens: int) -> DispatchPlan:
    """Legacy rebuild path: re-derive the DispatchPlan from the packed
    symbols (what every Dispatch step used to do).  Kept for the
    plan-reuse invariance tests and the amortization benchmark.  The
    stored ``row_score`` re-ranks the row-capacity truncation so the
    rebuilt plan matches the frozen one exactly."""
    m_c, m_s = _unpack(state, cfg, n_tokens)
    return build_dispatch_plan(m_c, m_s, cfg, n_tokens,
                               row_score=state.plan.row_score)


# ---------------------------------------------------------------------------
# Update / Dispatch step over one attention module.
# ---------------------------------------------------------------------------

def update_layer(
    params: AttnParams,
    x: jax.Array,
    state: LayerState,
    cfg: EngineConfig,
    *,
    n_text: int = 0,
    heads: int,
    freqs: Optional[jax.Array] = None,
    strategy: Optional[str | SparsityStrategy] = None,
    layer_idx: Optional[jax.Array] = None,
    strategy_id: Optional[jax.Array] = None,
    strategies: Optional[tuple] = None,
    step_idx: Optional[jax.Array] = None,
    num_steps: Optional[int | jax.Array] = None,
) -> tuple[jax.Array, LayerState]:
    """Full attention + symbol/cache refresh (paper *Update* phase).

    Two ways to pick the sparse-symbol producer:

      * static — resolved ONCE at trace time from ``cfg.strategy``
        (``strategy`` overrides it per call);
      * scheduled — ``strategies`` (a schedule's static active set) plus a
        TRACED ``strategy_id`` scalar, dispatched via
        :func:`~repro.core.strategy.emit_switch`.  This is how the scanned
        block body threads per-layer deployment tables without unrolling.

    ``layer_idx`` / ``step_idx`` (traced scalars under the model/pipeline
    scans) and ``num_steps`` (a static int, or a traced per-lane scalar
    under the batched serving ticks) reach the strategy's
    :class:`~repro.core.strategy.StrategyContext`.
    """
    b, n, dm = x.shape
    q, k = _qk(params, x, heads, freqs)
    v = _project_heads(x, params.wv, heads)
    o = dense_attention(q, k, v)                               # (B,H,N,dh)
    ctx = StrategyContext(cfg=cfg, n_text=n_text, n_tokens=n,
                          layer_idx=layer_idx, step_idx=step_idx,
                          num_steps=num_steps)
    if strategies is not None:
        sid = jnp.zeros((), jnp.int32) if strategy_id is None else strategy_id
        syms = emit_switch(sid, q, k, ctx, strategies)
    else:
        strat = get_strategy(cfg.strategy if strategy is None else strategy)
        syms = strat.emit(q, k, ctx)
    s_c, s_s, m_c, m_s = syms.s_c, syms.s_s, syms.m_c, syms.m_s

    o_tok = o.transpose(0, 2, 1, 3)                            # (B,N,H,dh)
    dh = o_tok.shape[-1]
    wo_h = params.wo.reshape(heads, dh, dm)
    out = jnp.einsum("bnhd,hdf->bnf", o_tok, wo_h)

    m_ch = jnp.swapaxes(m_c, -1, -2)                           # (B, T, H)
    if cfg.cache_mode == "bias":
        bias = sparse_gemm.gemm_o_update_bias(o_tok, wo_h, m_ch, block=cfg.mask.pool)
        taylor = taylorseer.update(state.taylor, bias.astype(cfg.cache_dtype))
    else:
        taylor = taylorseer.update(state.taylor, o.astype(cfg.cache_dtype))
    # Compile-once index plan: ALL index decoding for the coming Dispatch
    # steps happens here, amortized over the next interval−1 steps.  Rows
    # are ranked for the capacity truncation by the strategy's clamp
    # scores (column mass), summed over the heads where the row is live.
    row_score = jnp.sum(
        jnp.where(m_c, syms.q_scores.astype(jnp.float32), 0.0), axis=-2)
    plan = build_dispatch_plan(m_c, m_s, cfg, n, row_score=row_score)
    new_state = LayerState(s_c=s_c, s_s=s_s, taylor=taylor,
                           k_since=jnp.zeros((), jnp.int32), plan=plan)
    return out, new_state


def dispatch_layer(
    params: AttnParams,
    x: jax.Array,
    state: LayerState,
    cfg: EngineConfig,
    *,
    n_text: int = 0,
    heads: int,
    freqs: Optional[jax.Array] = None,
    plan: Optional[DispatchPlan] = None,
) -> tuple[jax.Array, LayerState]:
    """Sparse execution guided by the frozen DispatchPlan (paper *Dispatch*).

    Consumes ``state.plan`` verbatim — no symbol unpacking, mask expansion
    or top-k/argsort index work happens here; that all ran once inside
    :func:`update_layer`.  ``plan`` overrides the stored plan (used by the
    rebuild-vs-reuse benchmark and invariance tests).  Execution routes
    through :func:`repro.core.backend.get_backend` (XLA structural path or
    Pallas CSR kernels with compact GEMM-Q layout fusion).
    """
    b, n, dm = x.shape
    m = cfg.mask
    plan_stored = state.plan if plan is None else plan
    plan = plan_stored.widen()    # int16 id fields -> int32 for kernels/RoPE
    backend = get_backend(cfg)
    k_since = state.k_since + 1
    spec_c = cfg.caps(n)                                        # block granularity caps

    # --- GEMM-Q: skip row blocks cached in every head (Obs. 2). ---
    if cfg.use_gemm_q:
        q_flat = backend.gemm_q(x, params.wq, plan, block=m.pool)
        compact = backend.compact_q                             # (B, Cr·pool, H·dh)
    else:
        q_flat = jnp.einsum("bnd,df->bnf", x, params.wq)
        compact = False
    n_q = q_flat.shape[1]
    qh = q_flat.reshape(b, n_q, heads, -1).transpose(0, 2, 1, 3)
    qh = rms_norm(qh, params.q_scale)
    k_h = rms_norm(_project_heads(x, params.wk, heads), params.k_scale)
    if freqs is not None:
        q_freqs = freqs
        if compact:
            # Compact rows are gathered: RoPE phases follow the ORIGINAL
            # token positions of the gathered live rows.
            pos = (plan.row_ids[..., :, None] * m.pool
                   + jnp.arange(m.pool)).reshape(b, n_q)        # (B, Cr·pool)
            q_freqs = freqs[pos][:, None]                       # (B,1,n_q,dh/2)
        qh, k_h = apply_rope(qh, q_freqs), apply_rope(k_h, freqs)
    v_h = _project_heads(x, params.wv, heads)

    # --- Attention: backend sparse path over the frozen plan. ---
    dh = qh.shape[-1]
    if cfg.cache_mode == "bias":
        o_reuse = jnp.zeros((b, heads, n, dh), qh.dtype)
    else:
        o_reuse = taylorseer.forecast(state.taylor, k_since, m.interval).astype(qh.dtype)
    o = backend.attention(qh, k_h, v_h, o_reuse, plan, spec_c,
                          compact_q=compact)

    # --- GEMM-O: live heads + forecast bias (Obs. 3, Eq. 4). ---
    o_tok = o.transpose(0, 2, 1, 3)
    wo_h = params.wo.reshape(heads, dh, dm)
    if cfg.cache_mode == "bias":
        bias_f = taylorseer.forecast(state.taylor, k_since, m.interval).astype(x.dtype)
        if cfg.use_gemm_o:
            out = backend.gemm_o(o_tok, wo_h, plan, bias_f, block=m.pool,
                                 spec=spec_c)
        else:
            # Dense GEMM over (zero-filled) cached heads + forecast bias —
            # numerically identical, no FLOP saving (fidelity fallback).
            m_tok = jnp.repeat(plan.m_ch, m.pool, axis=-2)[..., :n, :]
            out = jnp.einsum("bnhd,hdf->bnf",
                             jnp.where(m_tok[..., None], o_tok, 0), wo_h) + bias_f
    else:
        out = jnp.einsum("bnhd,hdf->bnf", o_tok, wo_h)
    new_state = LayerState(s_c=state.s_c, s_s=state.s_s, taylor=state.taylor,
                           k_since=k_since, plan=plan_stored)
    return out, new_state
