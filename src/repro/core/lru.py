"""Tiny bounded LRU cache for host-side compile/resolution memos.

A long-running server cycling through distinct request shapes / schedule
specs would otherwise grow the pipeline's compiled-sampler cache and the
engine's schedule-resolution memo without limit (every distinct key pins
a compiled executable plus its strategy objects alive forever).
:class:`LruCache` bounds them with least-recently-used eviction and
counts hits/misses/evictions so serving stats can expose cache health
(``stats["sampler_cache"]`` in :func:`repro.diffusion.pipeline.sample`).

Not thread-safe by design — the serving loop, like the rest of the JAX
host program, is single-threaded.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable, Optional

__all__ = ["LruCache"]

_MISSING = object()


class LruCache:
    """An ``OrderedDict``-backed LRU with hit/miss/eviction counters."""

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError(f"LruCache needs maxsize >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Hashable, default: Optional[Any] = None) -> Any:
        """Look up ``key``, counting a hit (and refreshing recency) or a
        miss.  Returns ``default`` on miss."""
        val = self._data.get(key, _MISSING)
        if val is _MISSING:
            self.misses += 1
            return default
        self._data.move_to_end(key)
        self.hits += 1
        return val

    def put(self, key: Hashable, value: Any) -> Any:
        """Insert/refresh ``key`` and evict the LRU entry past capacity."""
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.evictions += 1
        return value

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def clear(self) -> None:
        """Drop all entries (counters are kept — they describe lifetime)."""
        self._data.clear()

    def stats(self) -> dict:
        """Counters snapshot: {hits, misses, evictions, size, maxsize}."""
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._data),
                "maxsize": self.maxsize}
