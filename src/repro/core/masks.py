"""Logical mask generation for FlashOmni (paper §3.3, Observation 1, Eq. 1).

Pipeline (all jit-safe, static shapes):

  Q, K (per head, length N)
    └─ mean-pool ``n·b`` consecutive tokens  ->  q̃, k̃       (token gathering)
    └─ compressed map  P̃ = softmax(q̃ k̃ᵀ / √d)               (⌈N/nb_q⌉ × ⌈N/nb_k⌉)
    ├─ caching:  C_{i,v→t} = Σ_j α_{j,i}   (α = P̃[:n_t, n_t:])
    │            G_{i,t→v} = Σ_j β_{j,i}   (β = softmax(P̃[n_t:, :n_t]ᵀ))
    │            cache block i  iff  CumSum↑(C) ≤ τ_q·ΣC  ∧  CumSum↑(G) ≤ τ_q·ΣG
    └─ skipping: per compressed row, skip the smallest-mass KV blocks whose
                 ascending cumulative mass ≤ τ_kv (SpargeAttn-style).

Conventions: masks are boolean with **True = compute** (matches the paper's
1 bits); caching masks never select text blocks (Observation 1 — text rows
must refresh every step) and the skip mask optionally protects the
text↔vision regions.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "MaskConfig",
    "pool_tokens",
    "compressed_attention_map",
    "caching_scores",
    "select_by_cummass",
    "make_caching_mask",
    "make_skip_mask",
    "apply_degradation",
    "expand_block_mask",
]


@dataclasses.dataclass(frozen=True)
class MaskConfig:
    """FlashOmni sparsity configuration ``(τ_q, τ_kv, 𝒩, 𝒟, S_q)`` (paper A.1.1).

    ``pool`` is ``n·b`` — the token-gathering granularity used to build the
    compressed attention map (paper pools ``n`` consecutive b-sized blocks).
    ``block_q``/``block_kv`` are the attention kernel tile sizes ``b_q``/``b_k``.
    """

    tau_q: float = 0.5          # caching cumulative-mass threshold (τ_q)
    tau_kv: float = 0.15        # skipping cumulative-mass threshold (τ_kv)
    interval: int = 5           # 𝒩 — Update every `interval` steps
    order: int = 1              # 𝒟 — TaylorSeer expansion order
    degrade: float = 0.3        # S_q — full-cache degradation threshold
    block_q: int = 64
    block_kv: int = 64
    pool: int = 128             # n·b_q == n·b_kv compressed granularity
    protect_text: bool = True   # never skip t↔t / t↔v / v↔t regions in S_s
    warmup_steps: int = 4       # full attention for the first steps (A.1.3)

    def n_blocks(self, n_tokens: int) -> int:
        return -(-n_tokens // self.pool)


def pool_tokens(x: jax.Array, pool: int) -> jax.Array:
    """Mean-pool groups of ``pool`` consecutive tokens: (..., N, d) -> (..., ⌈N/pool⌉, d)."""
    n = x.shape[-2]
    pad = -(-n // pool) * pool - n
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)])
        # Mean over the true tokens only: scale tail block by pool/(pool-pad).
    xb = x.reshape(*x.shape[:-2], -1, pool, x.shape[-1])
    out = jnp.mean(xb, axis=-2)
    if pad:
        scale = jnp.ones((out.shape[-2],), x.dtype).at[-1].set(pool / (pool - pad))
        out = out * scale[:, None]
    return out


def compressed_attention_map(
    q: jax.Array, k: jax.Array, pool: int, *, scale: Optional[float] = None
) -> jax.Array:
    """P̃ = softmax(q̃ k̃ᵀ / √d) over pooled tokens.  q,k: (..., N, d)."""
    d = q.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    qc = pool_tokens(q.astype(jnp.float32), pool)
    kc = pool_tokens(k.astype(jnp.float32), pool)
    s = jnp.einsum("...id,...jd->...ij", qc, kc) * scale
    return jax.nn.softmax(s, axis=-1)


def caching_scores(p_map: jax.Array, n_text: int) -> tuple[jax.Array, jax.Array]:
    """Vision-to-Text contribution C and Text-to-Vision guidance G.

    ``p_map``: (..., T, T) compressed map with the first ``n_text`` blocks
    being text.  Returns (C, G), each (..., T_vision).
    """
    alpha = p_map[..., :n_text, n_text:]                  # text rows -> vision cols
    contrib = jnp.sum(alpha, axis=-2)                     # C_{i,v→t} = Σ_j α_{j,i}
    beta_raw = jnp.swapaxes(p_map[..., n_text:, :n_text], -1, -2)  # (.., n_t, T_v)
    beta = jax.nn.softmax(beta_raw, axis=-1)              # renormalise across vision
    guidance = jnp.sum(beta, axis=-2)                     # G_{i,t→v} = Σ_j β_{j,i}
    return contrib, guidance


def select_by_cummass(scores: jax.Array, tau: float) -> jax.Array:
    """Eq. 1 selector: True where the block is SPARSIFIED.

    Sort ascending, mark blocks while the cumulative sum stays ≤ τ·total.
    Returns a boolean mask in the original block order.
    """
    order = jnp.argsort(scores, axis=-1)
    sorted_scores = jnp.take_along_axis(scores, order, axis=-1)
    cum = jnp.cumsum(sorted_scores, axis=-1)
    total = jnp.sum(scores, axis=-1, keepdims=True)
    picked_sorted = cum <= tau * total
    # Scatter back through the argsort permutation.
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(picked_sorted, inv, axis=-1)


def make_caching_mask(
    q: jax.Array,
    k: jax.Array,
    cfg: MaskConfig,
    n_text_tokens: int,
    *,
    tau_q: Optional[float] = None,
) -> jax.Array:
    """Per-head caching mask M_c at compressed granularity (True = compute).

    q, k: (..., N, d).  Output: (..., T) with T = ⌈N/pool⌉.  Text blocks are
    always computed (Observation 1).  Vision blocks are cached when selected
    by BOTH the C and G ascending-cummass rules (Eq. 1 conjunction).
    """
    tau = cfg.tau_q if tau_q is None else tau_q
    p_map = compressed_attention_map(q, k, cfg.pool)
    n_t = -(-n_text_tokens // cfg.pool) if n_text_tokens else 0
    t_total = p_map.shape[-1]
    if n_t == 0:
        # Pure-vision DiT (no text stream through this attention): rank by
        # total incoming attention mass per block (column mass).
        col_mass = jnp.sum(p_map, axis=-2)
        cached = select_by_cummass(col_mass, tau)
        return ~cached
    contrib, guidance = caching_scores(p_map, n_t)
    cached_v = select_by_cummass(contrib, tau) & select_by_cummass(guidance, tau)
    text_keep = jnp.ones((*cached_v.shape[:-1], n_t), dtype=jnp.bool_)
    compute_v = ~cached_v
    return jnp.concatenate([text_keep, compute_v], axis=-1)[..., :t_total]


def make_skip_mask(
    q: jax.Array,
    k: jax.Array,
    cfg: MaskConfig,
    n_text_tokens: int,
    *,
    tau_kv: Optional[float] = None,
    static_window: Optional[int] = None,
) -> jax.Array:
    """Per-head skip mask M_s at compressed granularity (True = compute).

    SpargeAttn-style: for each query row of the compressed map, skip the
    smallest-probability KV blocks whose ascending cumulative mass ≤ τ_kv.
    ``static_window`` (in blocks) additionally ANDs a sliding-window static
    pattern — this is how classic local/SWA attention is expressed as an
    ``S_s`` symbol (DESIGN §4: symbol generality).
    """
    tau = cfg.tau_kv if tau_kv is None else tau_kv
    p_map = compressed_attention_map(q, k, cfg.pool)
    skipped = select_by_cummass(p_map, tau)               # rowwise over KV axis
    compute = ~skipped
    t = p_map.shape[-1]
    if static_window is not None:
        idx = jnp.arange(t)
        win = jnp.abs(idx[:, None] - idx[None, :]) < static_window
        compute = compute & win
    # Text protection LAST so a static window can never narrow it (same
    # semantics as strategy.SlidingWindowStrategy's band).
    if cfg.protect_text and n_text_tokens:
        n_t = -(-n_text_tokens // cfg.pool)
        idx = jnp.arange(t)
        is_text_row = (idx < n_t)[:, None]
        is_text_col = (idx < n_t)[None, :]
        compute = compute | is_text_row | is_text_col     # only v↔v may skip
    return compute


def apply_degradation(m_c: jax.Array, degrade: float) -> jax.Array:
    """Paper A.1.1 ``S_q``: if the fraction of blocks requiring computation
    drops below ``degrade``, the whole layer degenerates to full feature
    caching (all-cached) for maximal efficiency."""
    frac = jnp.mean(m_c.astype(jnp.float32), axis=-1, keepdims=True)
    return jnp.where(frac < degrade, jnp.zeros_like(m_c), m_c)


def expand_block_mask(mask: jax.Array, factor: int, n_total: int) -> jax.Array:
    """Broadcast a compressed-granularity mask to kernel-block granularity.

    Each compressed block covers ``factor = pool // block`` kernel blocks;
    the result is truncated to ``n_total = ⌈N/block⌉`` entries.
    """
    out = jnp.repeat(mask, factor, axis=-1)
    return out[..., :n_total]
