"""Compile-once DispatchPlan — precomputed CSR index plan for Dispatch steps.

The paper's Update–Dispatch engine (§3.2) freezes the sparse symbols at an
*Update* step and reuses them for the next ``𝒩−1`` *Dispatch* steps.  The
seed implementation froze only the PACKED symbols and re-derived every
index structure (``unpack_bits`` → block-mask expand → ``clamp_mask_topk``
→ ``active_indices``) on every dispatch of every layer — per-step work that
Sparse VideoGen / Sparse-vDiT show should be off the critical path.

:class:`DispatchPlan` moves all of that to Update time.  It is a plain
pytree carried inside ``LayerState``, so it flows through ``jit``/``scan``
and sharding unchanged, and every backend (XLA structural or Pallas CSR
kernels) consumes it verbatim:

  * ``q_ids``/``q_cnt``       — live q-block ids at kernel-block granularity
    (the attention spatial gather, symbol ``S_c``).
  * ``q_slots``               — the same live q blocks, re-indexed into the
    COMPACT GEMM-Q output layout (``(Cr·pool, F)`` row-major), so the
    Pallas CSR attention kernel can read Q straight out of the compact
    projection without a scatter (layout fusion).
  * ``kv_ids``/``kv_cnt``/``pair_live`` — per-(batch, head) KV-block UNION
    with the exact (i, j) liveness inside the gathered subset (the XLA
    structural path's reduction layout, symbol ``S_s``).
  * ``kv_row_ids``/``kv_row_cnt``       — per-live-row CSR column lists
    (the Pallas kernel's reduction layout).
  * ``row_ids``/``row_cnt``   — pool-granularity row blocks live in ANY
    head (GEMM-Q spatial gather + GEMM-O spatial gather, Obs. 2).
  * ``head_ids``/``head_cnt``/``head_mask`` — per-live-row live-head lists
    (GEMM-O reduction sparsity, Obs. 3) in both CSR (Pallas) and mask
    (XLA) form.
  * ``m_ch``                  — the compressed (row-block, head) compute
    mask, kept for the dense fidelity fallbacks and diagnostics.

All shapes are static functions of ``(EngineConfig, n_tokens, heads)``, so
a Dispatch step's jaxpr contains no sort/top-k/unpack work at all — see
the jaxpr-inspection test in ``tests/test_backend.py``.

Row-capacity truncation ranks by COLUMN MASS: ``row_score`` (the per-row
attention mass the strategy's capacity clamp used, summed over live heads)
decides which live rows survive when ``cap_q_frac`` truncates — the
lowest-mass rows degrade to cache-reuse first.  The score is carried in
the plan so the legacy rebuild path (:func:`~repro.core.engine.
plan_from_state`) reproduces the exact same truncation.

Plan memory (HunyuanVideo 33K-token scale): the two O(H·Cq·Ckv)-ish index
fields — ``kv_row_ids`` and ``row_ids`` — are stored as int16 whenever
every block index fits in 15 bits (33K tokens / 64-token blocks = 516
blocks, far under 2¹⁵) and widened to int32 on use via :meth:`DispatchPlan.
widen`, halving the dominant plan buffers.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import masks as masklib
from repro.core.attention import attention_plan_indices
from repro.core.symbols import active_indices, clamp_mask_topk, slot_positions

__all__ = ["DispatchPlan", "build_dispatch_plan", "empty_plan_like"]


class DispatchPlan(NamedTuple):
    """Precomputed index plan for Dispatch steps (a pytree of int32/bool)."""

    # --- attention, kernel-block granularity, per (B, H) ---
    q_ids: jax.Array       # (B, H, Cq) int32 live q-block ids (full layout)
    q_cnt: jax.Array       # (B, H)     int32
    q_slots: jax.Array     # (B, H, Cq) int32 same blocks, compact layout
    kv_ids: jax.Array      # (B, H, Ck) int32 KV-union ids (XLA path)
    kv_cnt: jax.Array      # (B, H)     int32
    pair_live: jax.Array   # (B, H, Cq, Ck) bool exact (i,j) mask in the union
    kv_row_ids: jax.Array  # (B, H, Cq, Ck) int16/int32 per-row CSR (Pallas)
    kv_row_cnt: jax.Array  # (B, H, Cq) int32
    # --- GEMM-Q / GEMM-O, pool granularity, per B ---
    row_ids: jax.Array     # (B, Cr) int16/int32 row blocks live in any head
    row_cnt: jax.Array     # (B,)    int32
    head_ids: jax.Array    # (B, Cr, H) int32 live heads per live row (CSR)
    head_cnt: jax.Array    # (B, Cr) int32
    head_mask: jax.Array   # (B, Cr, H) bool gathered (row, head) mask
    m_ch: jax.Array        # (B, T, H) bool compressed compute mask
    row_score: jax.Array   # (B, T) f32 column-mass row ranking (truncation)

    def widen(self) -> "DispatchPlan":
        """Return a plan with the compact int16 id fields widened to int32.

        Called once at Dispatch entry (and idempotent): kernels, gathers
        and position arithmetic (RoPE ``row_ids · pool + offset`` can exceed
        int16 at 33K tokens) always see int32 ids, while the stored plan
        keeps the narrow dtype.
        """
        if self.kv_row_ids.dtype == jnp.int32 and self.row_ids.dtype == jnp.int32:
            return self
        return self._replace(kv_row_ids=self.kv_row_ids.astype(jnp.int32),
                             row_ids=self.row_ids.astype(jnp.int32))


def build_dispatch_plan(m_c: jax.Array, m_s: jax.Array, cfg, n_tokens: int,
                        row_score: Optional[jax.Array] = None,
                        compact_ids: bool = True) -> DispatchPlan:
    """Derive the full index plan from fresh compressed-granularity masks.

    ``m_c``: (B, H, T) bool, ``m_s``: (B, H, T, T) bool — True = compute,
    as produced by a :class:`~repro.core.strategy.SparsityStrategy`.  Runs
    ONCE per Update step; every sort/top-k in the engine lives here.

    ``row_score`` (B, T) ranks rows for the capacity truncation (column
    mass from the strategy's ``q_scores``); when ``None`` it falls back to
    the mask-derived live-pair mass (the rebuild path reads the stored
    score instead, so frozen vs rebuilt plans stay identical).
    ``compact_ids=False`` disables the int16 id compaction (round-trip
    reference in tests).
    """
    m = cfg.mask
    spec = cfg.caps(n_tokens)
    factor = m.pool // m.block_q
    t_q = -(-n_tokens // m.block_q)
    t_kv = -(-n_tokens // m.block_kv)
    t_cmp = m_c.shape[-1]

    # Kernel-block granularity masks (transient — not stored).
    # GEMM-Q / GEMM-O spatial gather first (pool granularity, any-head
    # union): attention may only compute q blocks whose pool row survived
    # the row-capacity truncation — the row projection simply does not
    # exist for the others (they degrade to cache-reuse, consistently
    # across backends; the seed XLA path silently attended with q = 0).
    cap_rows = cfg.cap_q_cmp(n_tokens)
    row_live = jnp.any(m_c, axis=-2)                               # (B, T)
    if row_score is None:
        # Mask-derived column-mass proxy: live (head, kv-block) pairs per
        # row — rows doing the least live work are dropped first.
        row_score = jnp.sum(
            jnp.where(m_c, jnp.sum(m_s, axis=-1).astype(jnp.float32), 0.0),
            axis=-2)
    row_score = row_score.astype(jnp.float32)
    # Ranked truncation (ROADMAP item): keep the top-`cap` rows by column
    # mass, not the first `cap` in index order; `active_indices` then
    # restores ascending id order for DMA-friendly gathers.
    row_live = clamp_mask_topk(row_live, row_score, cap_rows)
    row_ids, row_cnt = active_indices(row_live, cap_rows)
    slot = jnp.arange(cap_rows, dtype=jnp.int32)
    sid = jnp.where(slot < row_cnt[..., None], row_ids, t_cmp)
    kept = jnp.zeros((*row_ids.shape[:-1], t_cmp + 1), jnp.bool_)
    kept = jnp.put_along_axis(kept, sid, jnp.ones_like(sid, jnp.bool_),
                              axis=-1, inplace=False)[..., :t_cmp]
    m_c = m_c & kept[..., None, :]                                 # (B, H, T)

    m_c_blk = masklib.expand_block_mask(m_c, factor, t_q)
    m_s_blk = jnp.repeat(jnp.repeat(m_s, factor, axis=-2),
                         m.pool // m.block_kv, axis=-1)[..., :t_q, :t_kv]

    # Attention spatial gather (S_c) + XLA reduction layout (per-(b, h)
    # KV union over live rows) — shared with the mask-level
    # ``sparse_attention_xla`` entry so both paths rank/clamp identically.
    q_ids, q_cnt, kv_ids, kv_cnt, pair_live = attention_plan_indices(
        m_c_blk, m_s_blk, spec)

    # Pallas reduction layout: per-live-row CSR column lists.
    rows = jnp.take_along_axis(m_s_blk, q_ids[..., :, None], axis=-2)
    kv_row_ids, kv_row_cnt = active_indices(rows, spec.cap_kv)

    # GEMM-O reduction sparsity over the kept rows.  Padding slots (slot >=
    # row_cnt) duplicate the last live row id; their head lists MUST be
    # empty — the Pallas GEMM-O output is bias-aliased, so on real TPU a
    # padded duplicate with live heads would re-accumulate that row's
    # contribution once per padded slot (interpret mode hides this).
    m_ch = jnp.swapaxes(m_c, -1, -2)                               # (B, T, H)
    row_valid = slot < row_cnt[..., None]                          # (B, Cr)
    head_mask = jnp.take_along_axis(m_ch, row_ids[..., None], axis=-2)
    head_mask = head_mask & row_valid[..., None]
    heads = m_ch.shape[-1]
    head_ids, head_cnt = active_indices(head_mask, heads)

    # Compact-layout remap: live q block i (block granularity) lives at
    # block index  slot(i // factor)·factor + i % factor  of the compact
    # (Cr·pool, F) GEMM-Q output.  Live q blocks always fall inside live
    # rows (m_c live at (h, i) ⇒ row i live in the any-head union).
    row_slot = slot_positions(row_ids, row_cnt, t_cmp)             # (B, T)
    slot_of = jnp.take_along_axis(
        jnp.broadcast_to(row_slot[:, None, :], (*q_ids.shape[:-1], t_cmp)),
        q_ids // factor, axis=-1)
    q_slots = slot_of * factor + q_ids % factor

    # Plan-memory compaction: the two dominant buffers store block ids that
    # fit in 15 bits at any realistic scale; widen()ed to int32 on use.
    if compact_ids and max(t_cmp, t_q, t_kv) < 2 ** 15:
        kv_row_ids = kv_row_ids.astype(jnp.int16)
        row_ids = row_ids.astype(jnp.int16)

    return DispatchPlan(
        q_ids=q_ids, q_cnt=q_cnt, q_slots=q_slots,
        kv_ids=kv_ids, kv_cnt=kv_cnt, pair_live=pair_live,
        kv_row_ids=kv_row_ids, kv_row_cnt=kv_row_cnt,
        row_ids=row_ids, row_cnt=row_cnt,
        head_ids=head_ids, head_cnt=head_cnt, head_mask=head_mask,
        m_ch=m_ch, row_score=row_score,
    )


def empty_plan_like(batch: int, heads: int, n_tokens: int, cfg) -> DispatchPlan:
    """All-live plan matching the all-ones init symbols (warmup state)."""
    t = cfg.mask.n_blocks(n_tokens)
    m_c = jnp.ones((batch, heads, t), jnp.bool_)
    m_s = jnp.ones((batch, heads, t, t), jnp.bool_)
    return build_dispatch_plan(m_c, m_s, cfg, n_tokens)
