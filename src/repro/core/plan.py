"""Compile-once DispatchPlan — precomputed CSR index plan for Dispatch steps.

The paper's Update–Dispatch engine (§3.2) freezes the sparse symbols at an
*Update* step and reuses them for the next ``𝒩−1`` *Dispatch* steps.  The
seed implementation froze only the PACKED symbols and re-derived every
index structure (``unpack_bits`` → block-mask expand → ``clamp_mask_topk``
→ ``active_indices``) on every dispatch of every layer — per-step work that
Sparse VideoGen / Sparse-vDiT show should be off the critical path.

:class:`DispatchPlan` moves all of that to Update time.  It is a plain
pytree carried inside ``LayerState``, so it flows through ``jit``/``scan``
and sharding unchanged, and every backend (XLA structural or Pallas CSR
kernels) consumes it verbatim:

  * ``q_ids``/``q_cnt``       — live q-block ids at kernel-block granularity
    (the attention spatial gather, symbol ``S_c``).
  * ``q_slots``               — the same live q blocks, re-indexed into the
    COMPACT GEMM-Q output layout (``(Cr·pool, F)`` row-major), so the
    Pallas CSR attention kernel can read Q straight out of the compact
    projection without a scatter (layout fusion).
  * ``kv_ids``/``kv_cnt``/``pair_live`` — per-(batch, head) KV-block UNION
    with the exact (i, j) liveness inside the gathered subset (the XLA
    structural path's reduction layout, symbol ``S_s``).
  * ``kv_row_ids``/``kv_row_cnt``       — per-live-row CSR column lists
    (the Pallas kernel's reduction layout).
  * ``row_ids``/``row_cnt``   — pool-granularity row blocks live in ANY
    head (GEMM-Q spatial gather + GEMM-O spatial gather, Obs. 2).
  * ``head_ids``/``head_cnt``/``head_mask`` — per-live-row live-head lists
    (GEMM-O reduction sparsity, Obs. 3) in both CSR (Pallas) and mask
    (XLA) form.
  * ``m_ch``                  — the compressed (row-block, head) compute
    mask, kept for the dense fidelity fallbacks and diagnostics.

All shapes are static functions of ``(EngineConfig, n_tokens, heads)``, so
a Dispatch step's jaxpr contains no sort/top-k/unpack work at all — see
the jaxpr-inspection test in ``tests/test_backend.py``.

Row-capacity truncation ranks by COLUMN MASS: ``row_score`` (the per-row
attention mass the strategy's capacity clamp used, summed over live heads)
decides which live rows survive when ``cap_q_frac`` truncates — the
lowest-mass rows degrade to cache-reuse first.  The score is carried in
the plan so the legacy rebuild path (:func:`~repro.core.engine.
plan_from_state`) reproduces the exact same truncation.

Plan memory (HunyuanVideo 33K-token scale): every block-id index field —
``kv_row_ids``/``row_ids`` plus ``q_ids``/``q_slots``/``kv_ids`` and the
bucketed ``bkt_*`` id buffers — is stored as int16 whenever every block
index fits in 15 bits (33K tokens / 64-token blocks = 516 blocks, far
under 2¹⁵) and widened to int32 on use via :meth:`DispatchPlan.widen`,
halving the dominant plan buffers.

Occupancy buckets (``EngineConfig.kv_buckets > 1``): the ``bkt_*`` fields
re-sort the H·Cq (head, q-slot) layout rows into a static set of
halving-width KV buckets (:func:`bucket_geometry`) so the Pallas kernel
grid covers live *work* instead of live *rows* — a row with 3 live KV
blocks occupies a ≈3-wide reduction, not a ``cap_kv``-wide one.  Bucket
truncation is scattered back into ``kv_row_cnt`` so the uniform kernel
and the XLA per-row CSR path consume identical truncated lists (the PR-4
shared-truncation invariant, extended to buckets).

GEMM-O buckets (ISSUE 8 tentpole): the same treatment for the OUTPUT
projection's reduction axis.  The ``gmo_*`` fields sort the ``Cr`` compact
row slots by LIVE-HEAD count into :func:`bucket_geometry` buckets over the
head axis (``bucket_geometry(Cr, H, 1, kv_buckets)``), so a row with one
live head occupies a 1-deep reduction slot instead of the uniform grid's
``Hc``-deep one — the paper's GEMM-O 2.5–3.8× comes from exactly this
skew.  Any bucket-induced head clamp is folded BACK into
``head_cnt``/``head_mask`` before extraction (:func:`gmo_layout`), so the
bucketed kernel, the uniform kernel, and the XLA masked-einsum path all
consume the same truncated head lists — bit-identical outputs.

``occ_hist`` (always emitted) is the Update-time KV-occupancy histogram
over halving width classes (:func:`occupancy_histogram`) — the signal
``benchmarks/autotune.py`` calibrates and ``kernels/tuning.py``'s cost
model consumes to pick ``kv_buckets`` per (strategy, config) at
schedule-resolution time.  It is a pure function of the plan's final
``kv_row_cnt``, so ``plan_from_state`` rebuilds it bit-exactly.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masklib
from repro.core.attention import attention_plan_indices
from repro.core.symbols import active_indices, clamp_mask_topk, slot_positions

__all__ = [
    "DispatchPlan",
    "build_dispatch_plan",
    "empty_plan_like",
    "bucket_geometry",
    "bucket_slot_layout",
    "bucket_grid_slots",
    "bucket_layout",
    "gmo_layout",
    "occupancy_histogram",
    "OCC_BINS",
]

#: Width classes of the occupancy histogram carried in ``DispatchPlan.
#: occ_hist`` — class ``i`` holds live rows whose KV list fits width
#: ``⌈cap_kv/2^{i+1}⌉`` (class 0 = needs more than half the capacity).
OCC_BINS = 8


def occupancy_histogram(kv_row_cnt: jax.Array, q_cnt: jax.Array,
                        cap_kv: int) -> jax.Array:
    """Per-sample halving-width-class histogram of live-row KV occupancy.

    ``kv_row_cnt`` (B, H, Cq) int32, ``q_cnt`` (B, H) int32 →
    (B, :data:`OCC_BINS`) int32.  A live row lands in class
    ``#{i : cnt ≤ ⌈cap_kv/2^{i+1}⌉}`` — 0 means it needs (more than) the
    full/half capacity, higher classes fit ever-narrower buckets, and the
    last class absorbs the near-empty tail (including count-0 rows).  A
    pure function of the plan's final (truncation-folded) counts, computed
    at Update time — Dispatch never touches it."""
    live = (jnp.arange(kv_row_cnt.shape[-1], dtype=jnp.int32)
            < q_cnt[..., None])                                # (B, H, Cq)
    ths = np.asarray([-(-cap_kv // (1 << (i + 1)))
                      for i in range(OCC_BINS - 1)], np.int32)
    cls = jnp.sum(kv_row_cnt[..., None] <= ths, axis=-1)       # 0..OCC_BINS-1
    onehot = (cls[..., None] == jnp.arange(OCC_BINS, dtype=cls.dtype)) \
        & live[..., None]
    return jnp.sum(onehot, axis=(1, 2)).astype(jnp.int32)      # (B, OCC_BINS)


def bucket_geometry(cap_q: int, cap_kv: int, heads: int,
                    n_buckets: int) -> tuple[tuple[int, int], ...]:
    """Static occupancy-bucket geometry: ``((rows, kv_width), ...)``.

    Buckets are ordered widest first; widths halve per bucket
    (``cap_kv, ⌈cap_kv/2⌉, ⌈cap_kv/4⌉, …``) and row capacities are
    allocated inversely to width (equal slot area per bucket) over the
    ``heads · cap_q`` layout rows — the head axis is folded into the row
    pool, because the skew the buckets exist to absorb (Sparse VideoGen's
    spatial/temporal split, ``hunyuan-1.5x``'s sliding-window heads) is
    ACROSS heads.  Total grid slots shrink from ``R · cap_kv`` (uniform)
    to ``R · cap_kv · B / (2^B − 1)`` — ``3/7 ≈ 0.43×`` at ``B = 3`` —
    a static bound independent of the plan's occupancy draw.
    """
    r_total = heads * cap_q
    n_buckets = max(1, min(n_buckets, r_total, cap_kv))
    if n_buckets == 1:
        return ((r_total, cap_kv),)
    widths = [-(-cap_kv // (1 << i)) for i in range(n_buckets)]
    denom = (1 << n_buckets) - 1
    rows = [max(1, (r_total << i) // denom) for i in range(n_buckets)]
    rows[-1] += r_total - sum(rows)
    # Tiny-R edge: the max(1,·) bumps can overdraw; repay from the
    # narrowest buckets that still have rows to spare.
    for i in range(n_buckets - 1, -1, -1):
        if rows[i] < 1:
            for j in range(n_buckets - 1, -1, -1):
                if rows[j] > 1:
                    take = min(rows[j] - 1, 1 - rows[i])
                    rows[j] -= take
                    rows[i] += take
                    if rows[i] >= 1:
                        break
    assert sum(rows) == r_total and all(r >= 1 for r in rows)
    return tuple(zip(rows, widths))


def bucket_slot_layout(geometry) -> tuple[np.ndarray, np.ndarray,
                                          np.ndarray, np.ndarray]:
    """Flatten a bucket geometry into per-grid-slot static index arrays.

    Returns ``(srow, j_of, soff, slast)`` — all int32 of length
    ``S = Σ rows·width``: the layout row owning each slot, the slot's
    j-position within its row's KV reduction, the slot index where the
    row's reduction starts, and a 0/1 last-slot-of-row flag.  These are
    compile-time constants of the geometry; the kernel scalar-prefetches
    them to drive its two-level (bucket × row × per-bucket-Ckv) grid.
    """
    srow, j_of, soff, slast = [], [], [], []
    r = 0
    s = 0
    for rows, width in geometry:
        for _ in range(rows):
            for j in range(width):
                srow.append(r)
                j_of.append(j)
                soff.append(s)
                slast.append(1 if j == width - 1 else 0)
            r += 1
            s += width
    mk = lambda a: np.asarray(a, np.int32)
    return mk(srow), mk(j_of), mk(soff), mk(slast)


def bucket_grid_slots(geometry) -> int:
    """Total kernel grid slots the bucketed layout occupies."""
    return int(sum(rows * width for rows, width in geometry))


def bucket_layout(q_ids, q_cnt, q_slots, kv_row_ids, kv_row_cnt,
                  row_score_q, geometry, t_q: int):
    """Sort the H·Cq (head, q-slot) layout rows into the bucket geometry.

    All index arrays are (B, H, Cq[, Ck]) int32 as produced by
    :func:`~repro.core.attention.attention_plan_indices` +
    :func:`~repro.core.symbols.active_indices`; ``row_score_q`` is a
    (B, H, Cq) per-q-row ranking score.  Returns ``(bkt, kv_row_cnt')``:
    the ``bkt_*`` field dict of :class:`DispatchPlan` and the per-row
    counts with the bucket truncation folded back in (shared-truncation
    invariant — uniform kernel and XLA path consume the same lists).

    Runs at Update time only (it sorts); a Dispatch step consumes the
    emitted layout verbatim.
    """
    b_, h_, cq = q_ids.shape
    r_tot = h_ * cq
    live = jnp.arange(cq, dtype=jnp.int32) < q_cnt[..., None]      # (B,H,Cq)
    cnt = jnp.where(live, kv_row_cnt, 0)
    flat2 = lambda a: a.reshape(b_, r_tot)
    pid = jnp.broadcast_to(jnp.arange(r_tot, dtype=jnp.int32), (b_, r_tot))
    # Deterministic lexicographic sort: live first, then descending KV
    # count, then descending row mass, pair id as the tie-break — the pid
    # operand doubles as the permutation (plan_from_state must rebuild
    # this layout bit-exactly from the stored row_score).
    *_, order = jax.lax.sort(
        (flat2(~live).astype(jnp.int32), flat2(-cnt),
         flat2(-row_score_q.astype(jnp.float32)), pid), num_keys=4)
    g = lambda a: jnp.take_along_axis(flat2(a), order, axis=-1)
    s_live = g(live.astype(jnp.int32)) > 0                         # (B, R)
    # Per-position bucket widths (static) and the row_score-consistent
    # truncation: among equal counts the higher-mass row lands in the
    # wider slot, so the lowest-mass rows truncate first.
    w_pos = np.concatenate([np.full(r, w, np.int32) for r, w in geometry])
    bkt_kv_cnt = jnp.minimum(g(cnt), w_pos)
    # Scatter the bucket truncation back into the per-row counts so the
    # uniform kernel and the XLA per-row CSR path see the SAME truncated
    # lists — bucketed vs uniform stays bit-identical, no carve-outs.
    new_cnt = jnp.put_along_axis(jnp.zeros_like(flat2(cnt)), order,
                                 bkt_kv_cnt, axis=-1,
                                 inplace=False).reshape(b_, h_, cq)
    last_cnt = jnp.take_along_axis(
        new_cnt, jnp.maximum(q_cnt - 1, 0)[..., None], axis=-1)
    # Padding q slots duplicate the last live row; give them its truncated
    # count too, or their recompute would clobber the live block's output
    # with the untruncated reduction.
    kv_row_cnt = jnp.where(live, new_cnt, last_cnt)
    srow_np, jof_np, _, _ = bucket_slot_layout(geometry)
    ck = kv_row_ids.shape[-1]
    sorted_kv = jnp.take_along_axis(
        kv_row_ids.reshape(b_, r_tot, ck), order[..., None], axis=-2)
    bkt = dict(
        bkt_head=(order // cq).astype(jnp.int32),
        bkt_q_ids=jnp.where(s_live, g(q_ids), t_q),
        bkt_q_src=jnp.where(s_live, g(q_ids), 0),
        bkt_q_slots=jnp.where(s_live, g(q_slots), 0),
        bkt_kv_ids=sorted_kv[:, srow_np, jof_np],                  # (B, S)
        bkt_kv_cnt=bkt_kv_cnt,
    )
    return bkt, kv_row_cnt


def gmo_layout(row_ids, row_cnt, head_ids, head_cnt, row_score_r, geometry,
               t_cmp: int):
    """Sort the ``Cr`` compact row slots into live-head-count buckets.

    The GEMM-O analogue of :func:`bucket_layout`: ``geometry`` comes from
    ``bucket_geometry(Cr, H, 1, kv_buckets)`` (layout rows = compact row
    slots, reduction axis = live heads).  ``row_score_r`` is the (B, Cr)
    row-mass score gathered at ``row_ids`` — among equal head counts the
    higher-mass row lands in the wider slot, mirroring the attention sort.

    Returns ``(gmo, head_cnt', head_mask')`` where the ``gmo_*`` dict
    feeds :class:`DispatchPlan` and the primed lists carry any
    bucket-induced head clamp folded BACK in: ``head_cnt'`` is the clamp
    scattered to slot order and ``head_mask'`` is rebuilt from the clamped
    CSR prefixes, so the uniform kernel (which iterates ``hh <
    head_cnt``) and the XLA masked einsum consume the SAME truncated head
    lists as the bucketed kernel — bit-identical, no carve-outs.  Runs at
    Update time only (it sorts)."""
    b_, cr = row_ids.shape
    h_ = head_ids.shape[-1]
    slot = jnp.arange(cr, dtype=jnp.int32)
    live = slot[None, :] < row_cnt[:, None]                        # (B, Cr)
    cnt = jnp.where(live, head_cnt, 0)
    pid = jnp.broadcast_to(slot, (b_, cr))
    *_, order = jax.lax.sort(
        ((~live).astype(jnp.int32), -cnt,
         -row_score_r.astype(jnp.float32), pid), num_keys=4)
    g = lambda a: jnp.take_along_axis(a, order, axis=-1)
    s_live = g(live.astype(jnp.int32)) > 0                         # (B, R)
    w_pos = np.concatenate([np.full(r, w, np.int32) for r, w in geometry])
    gmo_head_cnt = jnp.minimum(g(cnt), w_pos)
    new_cnt = jnp.put_along_axis(jnp.zeros_like(cnt), order, gmo_head_cnt,
                                 axis=-1, inplace=False)
    # Rebuild head_mask from the clamped CSR prefixes (the ids are exactly
    # the ascending True positions, so an unclamped rebuild is the
    # identity) — XLA's masked einsum then matches the clamp too.
    keep = jnp.arange(h_, dtype=jnp.int32) < new_cnt[..., None]    # (B,Cr,H)
    sid = jnp.where(keep, head_ids, h_)
    new_mask = jnp.put_along_axis(
        jnp.zeros((b_, cr, h_ + 1), jnp.bool_), sid,
        jnp.ones_like(sid, jnp.bool_), axis=-1, inplace=False)[..., :h_]
    srow_np, jof_np, _, _ = bucket_slot_layout(geometry)
    sorted_heads = jnp.take_along_axis(head_ids, order[..., None], axis=-2)
    gmo = dict(
        gmo_rows=jnp.where(s_live, g(row_ids), t_cmp),
        gmo_src=jnp.where(s_live, g(row_ids), 0),
        gmo_head_ids=sorted_heads[:, srow_np, jof_np],             # (B, S)
        gmo_head_cnt=gmo_head_cnt,
    )
    return gmo, new_cnt, new_mask


class DispatchPlan(NamedTuple):
    """Precomputed index plan for Dispatch steps (a pytree of int32/bool)."""

    # --- attention, kernel-block granularity, per (B, H) ---
    q_ids: jax.Array       # (B, H, Cq) int32 live q-block ids (full layout)
    q_cnt: jax.Array       # (B, H)     int32
    q_slots: jax.Array     # (B, H, Cq) int32 same blocks, compact layout
    kv_ids: jax.Array      # (B, H, Ck) int32 KV-union ids (XLA path)
    kv_cnt: jax.Array      # (B, H)     int32
    pair_live: jax.Array   # (B, H, Cq, Ck) bool exact (i,j) mask in the union
    kv_row_ids: jax.Array  # (B, H, Cq, Ck) int16/int32 per-row CSR (Pallas)
    kv_row_cnt: jax.Array  # (B, H, Cq) int32
    # --- GEMM-Q / GEMM-O, pool granularity, per B ---
    row_ids: jax.Array     # (B, Cr) int16/int32 row blocks live in any head
    row_cnt: jax.Array     # (B,)    int32
    head_ids: jax.Array    # (B, Cr, H) int32 live heads per live row (CSR)
    head_cnt: jax.Array    # (B, Cr) int32
    head_mask: jax.Array   # (B, Cr, H) bool gathered (row, head) mask
    m_ch: jax.Array        # (B, T, H) bool compressed compute mask
    row_score: jax.Array   # (B, T) f32 column-mass row ranking (truncation)
    # --- Update-time KV-occupancy histogram (always emitted) ---
    # (B, OCC_BINS) int32 live rows per halving width class; the
    # autotuner's calibration signal (see kernels/tuning.py).
    occ_hist: Optional[jax.Array] = None
    # --- occupancy-bucketed CSR layout (None unless cfg.kv_buckets > 1) ---
    # Layout rows fold the head axis: R = H·Cq (head, q-slot) pairs sorted
    # by (live, kv count, row_score), widest bucket first; see
    # :func:`bucket_geometry`.  S = Σ rows·width grid slots.
    bkt_head: Optional[jax.Array] = None     # (B, R) int32 head of layout row
    bkt_q_ids: Optional[jax.Array] = None    # (B, R) output q block (dead→T_q)
    bkt_q_src: Optional[jax.Array] = None    # (B, R) read q block, full layout
    bkt_q_slots: Optional[jax.Array] = None  # (B, R) read q block, compact
    bkt_kv_ids: Optional[jax.Array] = None   # (B, S) per-slot kv-block id
    bkt_kv_cnt: Optional[jax.Array] = None   # (B, R) bucket-truncated count
    # --- GEMM-O head-count buckets (None unless cfg.kv_buckets > 1) ---
    # Layout rows are the Cr compact row slots sorted by live-head count
    # into bucket_geometry(Cr, H, 1, kv_buckets); S = Σ rows·width grid
    # slots.  See :func:`gmo_layout`.
    gmo_rows: Optional[jax.Array] = None      # (B, Cr) write row id (dead→T)
    gmo_src: Optional[jax.Array] = None       # (B, Cr) read row id (dead→0)
    gmo_head_ids: Optional[jax.Array] = None  # (B, S) per-slot head id
    gmo_head_cnt: Optional[jax.Array] = None  # (B, Cr) clamped live-head cnt
    # --- plan-sharded mesh partition (None unless cfg.mesh_sp > 1 with
    # mesh_axis == "seq"; see distributed/plan_shard.py).  Axis P indexes
    # the destination shard of the (data, seq) mesh; Cqs/Cks/pc are the
    # static per-shard row / union / per-pair capacities of ShardGeometry.
    shd_q_ids: Optional[jax.Array] = None      # (B,H,P,Cqs) shard-LOCAL q blocks
    shd_q_src: Optional[jax.Array] = None      # (B,H,P,Cqs) same, full layout
    shd_q_slots: Optional[jax.Array] = None    # (B,H,P,Cqs) same, compact layout
    shd_q_cnt: Optional[jax.Array] = None      # (B,H,P)
    shd_kv_ids: Optional[jax.Array] = None     # (B,H,P,Cks) union, GLOBAL ids
    shd_kv_cnt: Optional[jax.Array] = None     # (B,H,P)
    shd_kv_row_ids: Optional[jax.Array] = None  # (B,H,P,Cqs,Ck) union-slot CSR
    shd_kv_row_cnt: Optional[jax.Array] = None  # (B,H,P,Cqs)
    shd_gather_idx: Optional[jax.Array] = None  # (B,H,P,Cks) buffer placement
    shd_send_ids: Optional[jax.Array] = None   # (B,H,Psrc,Pdst,pc) local ids
    shd_send_cnt: Optional[jax.Array] = None   # (B,H,Psrc,Pdst)

    def widen(self) -> "DispatchPlan":
        """Return a plan with the compact int16 id fields widened to int32.

        Called once at Dispatch entry (and idempotent): kernels, gathers
        and position arithmetic (RoPE ``row_ids · pool + offset`` can exceed
        int16 at 33K tokens) always see int32 ids, while the stored plan
        keeps the narrow dtype.
        """
        if self.kv_row_ids.dtype == jnp.int32 and self.row_ids.dtype == jnp.int32 \
                and self.q_ids.dtype == jnp.int32:
            return self
        w = lambda a: (a if a is None or a.dtype == jnp.int32
                       else a.astype(jnp.int32))
        return self._replace(
            q_ids=w(self.q_ids), q_slots=w(self.q_slots), kv_ids=w(self.kv_ids),
            kv_row_ids=w(self.kv_row_ids), row_ids=w(self.row_ids),
            head_ids=w(self.head_ids),
            bkt_head=w(self.bkt_head), bkt_q_ids=w(self.bkt_q_ids),
            bkt_q_src=w(self.bkt_q_src), bkt_q_slots=w(self.bkt_q_slots),
            bkt_kv_ids=w(self.bkt_kv_ids),
            gmo_rows=w(self.gmo_rows), gmo_src=w(self.gmo_src),
            gmo_head_ids=w(self.gmo_head_ids),
            shd_q_ids=w(self.shd_q_ids), shd_q_src=w(self.shd_q_src),
            shd_q_slots=w(self.shd_q_slots), shd_kv_ids=w(self.shd_kv_ids),
            shd_kv_row_ids=w(self.shd_kv_row_ids),
            shd_gather_idx=w(self.shd_gather_idx),
            shd_send_ids=w(self.shd_send_ids))


def build_dispatch_plan(m_c: jax.Array, m_s: jax.Array, cfg, n_tokens: int,
                        row_score: Optional[jax.Array] = None,
                        compact_ids: bool = True) -> DispatchPlan:
    """Derive the full index plan from fresh compressed-granularity masks.

    ``m_c``: (B, H, T) bool, ``m_s``: (B, H, T, T) bool — True = compute,
    as produced by a :class:`~repro.core.strategy.SparsityStrategy`.  Runs
    ONCE per Update step; every sort/top-k in the engine lives here.

    ``row_score`` (B, T) ranks rows for the capacity truncation (column
    mass from the strategy's ``q_scores``); when ``None`` it falls back to
    the mask-derived live-pair mass (the rebuild path reads the stored
    score instead, so frozen vs rebuilt plans stay identical).
    ``compact_ids=False`` disables the int16 id compaction (round-trip
    reference in tests).
    """
    m = cfg.mask
    spec = cfg.caps(n_tokens)
    factor = m.pool // m.block_q
    t_q = -(-n_tokens // m.block_q)
    t_kv = -(-n_tokens // m.block_kv)
    t_cmp = m_c.shape[-1]

    # Kernel-block granularity masks (transient — not stored).
    # GEMM-Q / GEMM-O spatial gather first (pool granularity, any-head
    # union): attention may only compute q blocks whose pool row survived
    # the row-capacity truncation — the row projection simply does not
    # exist for the others (they degrade to cache-reuse, consistently
    # across backends; the seed XLA path silently attended with q = 0).
    cap_rows = cfg.cap_q_cmp(n_tokens)
    row_live = jnp.any(m_c, axis=-2)                               # (B, T)
    if row_score is None:
        # Mask-derived column-mass proxy: live (head, kv-block) pairs per
        # row — rows doing the least live work are dropped first.
        row_score = jnp.sum(
            jnp.where(m_c, jnp.sum(m_s, axis=-1).astype(jnp.float32), 0.0),
            axis=-2)
    row_score = row_score.astype(jnp.float32)
    # Ranked truncation (ROADMAP item): keep the top-`cap` rows by column
    # mass, not the first `cap` in index order; `active_indices` then
    # restores ascending id order for DMA-friendly gathers.
    row_live = clamp_mask_topk(row_live, row_score, cap_rows)
    row_ids, row_cnt = active_indices(row_live, cap_rows)
    slot = jnp.arange(cap_rows, dtype=jnp.int32)
    sid = jnp.where(slot < row_cnt[..., None], row_ids, t_cmp)
    kept = jnp.zeros((*row_ids.shape[:-1], t_cmp + 1), jnp.bool_)
    kept = jnp.put_along_axis(kept, sid, jnp.ones_like(sid, jnp.bool_),
                              axis=-1, inplace=False)[..., :t_cmp]
    m_c = m_c & kept[..., None, :]                                 # (B, H, T)

    m_c_blk = masklib.expand_block_mask(m_c, factor, t_q)
    m_s_blk = jnp.repeat(jnp.repeat(m_s, factor, axis=-2),
                         m.pool // m.block_kv, axis=-1)[..., :t_q, :t_kv]

    # Attention spatial gather (S_c) + XLA reduction layout (per-(b, h)
    # KV union over live rows) — shared with the mask-level
    # ``sparse_attention_xla`` entry so both paths rank/clamp identically.
    q_ids, q_cnt, kv_ids, kv_cnt, pair_live = attention_plan_indices(
        m_c_blk, m_s_blk, spec)

    # Pallas reduction layout: per-live-row CSR column lists.
    rows = jnp.take_along_axis(m_s_blk, q_ids[..., :, None], axis=-2)
    # Plan-sharded mesh fold (distributed/plan_shard.py): the per-(src,
    # dst) shipped-block clamp is applied to the ROW MASKS before the
    # lists are extracted — shared truncation, so every backend (sharded
    # or the single-device oracle) consumes identical lists.  With
    # pair_cap at its safe bound this is the identity and the plan below
    # matches the non-mesh build bit-for-bit.
    geom = None
    mesh_sp = getattr(cfg, "mesh_sp", 1)
    if mesh_sp > 1 and getattr(cfg, "mesh_axis", "seq") == "seq":
        from repro.distributed.plan_shard import mesh_keep_rows, shard_geometry
        geom = shard_geometry(spec, t_q, t_kv, mesh_sp,
                              getattr(cfg, "mesh_pair_slack", 1.5))
        rows = mesh_keep_rows(rows, q_ids, q_cnt, geom)
    kv_row_ids, kv_row_cnt = active_indices(rows, spec.cap_kv)

    # Compact-layout remap (needed below by the bucketed layout too): live
    # q block i (block granularity) lives at block index
    # slot(i // factor)·factor + i % factor of the compact (Cr·pool, F)
    # GEMM-Q output.  Live q blocks always fall inside live rows.
    row_slot = slot_positions(row_ids, row_cnt, t_cmp)             # (B, T)
    slot_of = jnp.take_along_axis(
        jnp.broadcast_to(row_slot[:, None, :], (*q_ids.shape[:-1], t_cmp)),
        q_ids // factor, axis=-1)
    q_slots = slot_of * factor + q_ids % factor

    # Occupancy-bucketed layout (ISSUE 6 tentpole): sort the H·Cq
    # (head, q-slot) layout rows by KV occupancy into the static bucket
    # geometry so the kernel grid covers live WORK, not live rows.  The
    # sort runs here — Update time — so Dispatch jaxprs stay sort-free.
    bkt = {}
    if getattr(spec, "kv_buckets", 1) > 1:
        b_, h_, _ = q_ids.shape
        geometry = bucket_geometry(spec.cap_q, spec.cap_kv, h_,
                                   spec.kv_buckets)
        score = jnp.take_along_axis(
            jnp.broadcast_to(row_score[:, None, :], (b_, h_, t_cmp)),
            (q_ids // factor).astype(jnp.int32), axis=-1)
        bkt, kv_row_cnt = bucket_layout(
            q_ids, q_cnt, q_slots, kv_row_ids, kv_row_cnt, score,
            geometry, t_q)

    # Per-shard partition + collective schedule, emitted AFTER every
    # truncation (pair clamp above, bucket layout here) has been folded
    # into kv_row_cnt — the partition consumes final lists and never
    # truncates on its own (see plan_shard.partition_plan).
    shd = {}
    if geom is not None:
        from repro.distributed.plan_shard import partition_plan
        shd = partition_plan(q_ids, q_cnt, q_slots, kv_row_ids, kv_row_cnt,
                             t_kv, geom)

    # GEMM-O reduction sparsity over the kept rows.  Padding slots (slot >=
    # row_cnt) duplicate the last live row id; their head lists MUST be
    # empty — the Pallas GEMM-O output is bias-aliased, so on real TPU a
    # padded duplicate with live heads would re-accumulate that row's
    # contribution once per padded slot (interpret mode hides this).
    m_ch = jnp.swapaxes(m_c, -1, -2)                               # (B, T, H)
    row_valid = slot < row_cnt[..., None]                          # (B, Cr)
    head_mask = jnp.take_along_axis(m_ch, row_ids[..., None], axis=-2)
    head_mask = head_mask & row_valid[..., None]
    heads = m_ch.shape[-1]
    head_ids, head_cnt = active_indices(head_mask, heads)

    # GEMM-O head-count buckets (ISSUE 8 tentpole): sort the Cr compact
    # row slots by live-head count into halving-depth buckets so the
    # output projection's grid covers live head-work, not Cr·Hc worst
    # case.  Any bucket head clamp is folded back into head_cnt/head_mask
    # (shared truncation — uniform kernel and XLA path stay bit-identical
    # to the bucketed kernel).
    gmo = {}
    if getattr(spec, "kv_buckets", 1) > 1:
        geometry_o = bucket_geometry(cap_rows, heads, 1, spec.kv_buckets)
        score_rows = jnp.take_along_axis(row_score, row_ids, axis=-1)
        gmo, head_cnt, head_mask = gmo_layout(
            row_ids, row_cnt, head_ids, head_cnt, score_rows, geometry_o,
            t_cmp)

    # Occupancy histogram — computed from the FINAL (truncation-folded)
    # counts so plan_from_state rebuilds it bit-exactly.
    occ_hist = occupancy_histogram(kv_row_cnt, q_cnt, spec.cap_kv)

    # Plan-memory compaction: every block-id buffer fits in 15 bits at any
    # realistic scale (33K tokens / 64-token blocks = 516 blocks); store
    # int16, widen()ed to int32 on use.  ``q_ids``/``q_slots``/``kv_ids``
    # join ``kv_row_ids``/``row_ids`` (ISSUE 6 satellite); ``head_ids``
    # and the ``gmo_*`` ids join in ISSUE 8 (head ids < H and gmo row ids
    # ≤ t_cmp both clear the same 15-bit gate).
    if compact_ids and max(t_cmp, t_q + 1, t_kv, heads) < 2 ** 15:
        narrow = lambda a: a.astype(jnp.int16)
        kv_row_ids = narrow(kv_row_ids)
        row_ids = narrow(row_ids)
        q_ids = narrow(q_ids)
        q_slots = narrow(q_slots)
        kv_ids = narrow(kv_ids)
        head_ids = narrow(head_ids)
        if bkt:
            for key in ("bkt_head", "bkt_q_ids", "bkt_q_src", "bkt_q_slots",
                        "bkt_kv_ids"):
                bkt[key] = narrow(bkt[key])
        if gmo:
            for key in ("gmo_rows", "gmo_src", "gmo_head_ids"):
                gmo[key] = narrow(gmo[key])
        # shd_gather_idx indexes the KV exchange buffer, which can hold up
        # to buf_blocks > t_kv entries — gate its compaction separately.
        if shd and geom.buf_blocks < 2 ** 15:
            for key in ("shd_q_ids", "shd_q_src", "shd_q_slots", "shd_kv_ids",
                        "shd_kv_row_ids", "shd_gather_idx", "shd_send_ids"):
                shd[key] = narrow(shd[key])

    plan = DispatchPlan(
        q_ids=q_ids, q_cnt=q_cnt, q_slots=q_slots,
        kv_ids=kv_ids, kv_cnt=kv_cnt, pair_live=pair_live,
        kv_row_ids=kv_row_ids, kv_row_cnt=kv_row_cnt,
        row_ids=row_ids, row_cnt=row_cnt,
        head_ids=head_ids, head_cnt=head_cnt, head_mask=head_mask,
        m_ch=m_ch, row_score=row_score, occ_hist=occ_hist,
        **bkt, **gmo, **shd,
    )
    # Opt-in debug hook (EngineConfig.validate_plans / REPRO_VALIDATE_
    # PLANS=1): structurally validate the freshly built plan on host.
    # cfg/n_tokens are statics, so the callback closes over them; the
    # checker tolerates any stacked lane/layer axes vmap may add.
    from repro.analysis.plan_check import validation_enabled
    if validation_enabled(cfg):
        from repro.analysis.plan_check import hook_validate
        jax.debug.callback(
            lambda p: hook_validate(p, cfg, n_tokens), plan)
    return plan


def empty_plan_like(batch: int, heads: int, n_tokens: int, cfg) -> DispatchPlan:
    """All-live plan matching the all-ones init symbols (warmup state)."""
    t = cfg.mask.n_blocks(n_tokens)
    m_c = jnp.ones((batch, heads, t), jnp.bool_)
    m_s = jnp.ones((batch, heads, t, t), jnp.bool_)
    return build_dispatch_plan(m_c, m_s, cfg, n_tokens)
