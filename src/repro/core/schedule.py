"""Scan-native sparsity schedules — the (step × layer) plan as traced data.

FlashOmni's Update–Dispatch schedule (paper §3.2) and its deployment
tables (the HunyuanVideo 1.5× per-layer configuration, Sparse VideoGen's
per-step head re-classification) used to live OUTSIDE the compiled
program: ``pipeline.sample`` was a Python loop juggling three separate
jits, and any per-layer strategy table unrolled the block scan, so the
HLO grew with model depth.  :class:`SparsitySchedule` turns the whole
schedule into a pytree the compiled program scans over:

  * ``mode``          — ``(num_steps,)`` int32 per-step phase array
    (``MODE_DENSE`` / ``MODE_UPDATE`` / ``MODE_DISPATCH``), generalizing
    the Python-level ``is_update_step`` decision into data that a single
    ``lax.switch`` consumes inside one ``lax.scan`` over steps.
  * ``strategy_ids``  — ``(num_steps, n_layers)`` int32 table over
    ``strategies``, the schedule's static active set of sparse-symbol
    producers.  ``models.dit`` threads one traced row per step through the
    scanned block body (``strategy.emit_switch``), so a Hunyuan-depth
    per-layer table keeps a one-block-sized HLO.
  * ``strategies``    — the static tuple of resolved
    :class:`~repro.core.strategy.SparsityStrategy` instances the id table
    indexes (pytree aux data — part of the jit closure, not traced).

Construction: :meth:`SparsitySchedule.from_config` canonicalizes an
:class:`~repro.core.engine.EngineConfig` — ``strategy`` /
``layer_strategies`` / ``interval`` / ``warmup_steps`` — into a schedule.
A ``multi-granularity`` strategy with a ``layer_assign`` table is expanded
into per-layer variants (deduplicated by head template) with the id table
pointing each layer at its variant: the deployment table IS the schedule.

Named schedules (``register_schedule`` / ``get_schedule``) package whole
deployment recipes; built-ins:

  ``hunyuan-1.5x`` — the paper's HunyuanVideo 1.5× table: skip-only
                     boundary layers, flashomni/sliding-window striped
                     heads in the interior, expanded per layer.
  ``step-ramp``    — denoising-phase ramp: conservative ``skip-only``
                     while structure forms, the full ``flashomni`` rule in
                     the middle, ``cache-all`` for the late near-static
                     steps (the direction of the paper's Fig. 7 density
                     trend).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategy import (MultiGranularityStrategy, SparsityStrategy,
                                 get_strategy, strategy_key)

__all__ = [
    "MODE_DENSE",
    "MODE_UPDATE",
    "MODE_DISPATCH",
    "MODE_IDLE",
    "MODE_NAMES",
    "SparsitySchedule",
    "strategy_table",
    "merge_strategies",
    "schedule_lane_rows",
    "stack_schedules",
    "tick_mode_groups",
    "register_schedule",
    "get_schedule",
    "available_schedules",
    "schedule_summaries",
]

MODE_DENSE, MODE_UPDATE, MODE_DISPATCH = 0, 1, 2
# Batched-serving lane tables pad past a schedule's end with MODE_IDLE:
# the lane holds no work at that step (empty or retired), so the serving
# tick's mode switch runs the no-op branch and contributes zero metrics.
# A SparsitySchedule itself never carries it (validate() rejects it).
MODE_IDLE = 3
MODE_NAMES = ("dense", "update", "dispatch", "idle")


def _mode_array(cfg, num_steps: int) -> np.ndarray:
    """Per-step Update/Dispatch phases from the config's warmup/interval."""
    from repro.core.engine import is_update_step
    return np.asarray([MODE_UPDATE if is_update_step(i, cfg) else MODE_DISPATCH
                       for i in range(num_steps)], np.int32)


def _expand_layer_table(spec: Union[str, SparsityStrategy], n_layers: int):
    """Resolve one strategy spec into ``(strategies, per-layer ids)``.

    A ``multi-granularity`` strategy carrying a ``layer_assign`` table is
    expanded into per-layer variants — deduplicated by head template so
    e.g. the ``hunyuan-1.5x`` preset yields two entries (boundary,
    interior) rather than ``n_layers`` — with the id list pointing each
    layer at its variant.  Everything else maps every layer to one entry.
    """
    strat = get_strategy(spec)
    if isinstance(strat, MultiGranularityStrategy) and strat.layer_assign:
        uniq: list = []
        ids: list[int] = []
        by_template: dict = {}
        variants = strat.per_layer(n_layers)
        for i in range(n_layers):
            key = strat._template(i)
            if key not in by_template:
                by_template[key] = len(uniq)
                uniq.append(variants[i])
            ids.append(by_template[key])
        return tuple(uniq), ids
    return (strat,), [0] * n_layers


def strategy_table(layer_strategies: Sequence, cfg, n_layers: int):
    """Canonicalize a per-layer spec table into ``(strategies, id row)``.

    ``layer_strategies`` is a length-``n_layers`` sequence of registry
    names / strategy instances; ``None`` entries fall back to
    ``cfg.strategy``.  Specs are deduplicated (by name for registry
    strings, by identity for instances) so the returned active set stays
    one-entry-per-distinct-producer and the int32 id row indexes it.

    An entry that is itself a ``multi-granularity`` strategy carrying a
    ``layer_assign`` table is pinned to ITS POSITION's template (the list
    position is the layer index), matching what the old unrolled path's
    ``layer_idx`` threading produced — pinned variants are deduplicated by
    template like :func:`_expand_layer_table`.
    """
    if len(layer_strategies) != n_layers:
        raise ValueError(
            f"layer_strategies has {len(layer_strategies)} entries for "
            f"{n_layers} layers")
    uniq: list = []
    ids: list[int] = []
    by_spec: dict = {}
    for i, s in enumerate(layer_strategies):
        spec = cfg.strategy if s is None else s
        strat = get_strategy(spec)
        key = spec if isinstance(spec, str) else id(spec)
        if isinstance(strat, MultiGranularityStrategy) and strat.layer_assign:
            tmpl = strat._template(i)
            key = (key, tmpl)
            if key not in by_spec:
                by_spec[key] = len(uniq)
                uniq.append(MultiGranularityStrategy(
                    children=strat.children, head_assign=tmpl,
                    name=f"{strat.name}[layer {i}]"))
        elif key not in by_spec:
            by_spec[key] = len(uniq)
            uniq.append(strat)
        ids.append(by_spec[key])
    return tuple(uniq), np.asarray(ids, np.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparsitySchedule:
    """The (step × layer) sparsity plan as a traced pytree (see module doc).

    Leaves: ``mode`` (S,) int32 and ``strategy_ids`` (S, L) int32.
    Aux (static): ``strategies``, the tuple the id table indexes.
    """

    mode: jax.Array
    strategy_ids: jax.Array
    strategies: tuple = ()

    # -- pytree protocol (strategies are static aux data) --
    def tree_flatten(self):
        return (self.mode, self.strategy_ids), self.strategies

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(mode=leaves[0], strategy_ids=leaves[1], strategies=aux)

    @property
    def num_steps(self) -> int:
        return self.mode.shape[0]

    @property
    def n_layers(self) -> int:
        return self.strategy_ids.shape[-1]

    def kinds(self) -> list[str]:
        """Host-side per-step phase names (trace/diagnostics)."""
        return [MODE_NAMES[int(m)] for m in np.asarray(self.mode)]

    def validate(self) -> "SparsitySchedule":
        if self.mode.ndim != 1 or self.strategy_ids.ndim != 2:
            raise ValueError(
                f"schedule shapes: mode {self.mode.shape}, strategy_ids "
                f"{self.strategy_ids.shape}; want (S,) and (S, L)")
        if self.strategy_ids.shape[0] != self.num_steps:
            raise ValueError(
                f"strategy_ids covers {self.strategy_ids.shape[0]} steps, "
                f"mode covers {self.num_steps}")
        if not self.strategies:
            raise ValueError("schedule has no strategies")
        ids = np.asarray(self.strategy_ids)
        if ids.min() < 0 or ids.max() >= len(self.strategies):
            raise ValueError(
                f"strategy ids span [{ids.min()}, {ids.max()}] but only "
                f"{len(self.strategies)} strategies are registered in the "
                "schedule")
        mode = np.asarray(self.mode)
        if mode.min() < MODE_DENSE or mode.max() > MODE_DISPATCH:
            raise ValueError(f"mode values outside {MODE_NAMES}: {mode}")
        return self

    # -- constructors -----------------------------------------------------

    @classmethod
    def from_config(cls, cfg, num_steps: int, n_layers: int, *,
                    layer_strategies: Optional[Sequence] = None,
                    force_dense: bool = False) -> "SparsitySchedule":
        """Canonicalize an ``EngineConfig`` into a schedule.

        Resolution order: ``force_dense`` (all-dense baseline) →
        ``layer_strategies`` (explicit per-layer table, ``None`` entries
        fall back to ``cfg.strategy``) → ``cfg.schedule`` (named preset) →
        ``cfg.strategy`` (expanded when it carries a layer table).
        """
        if force_dense:
            return cls(mode=jnp.zeros((num_steps,), jnp.int32),
                       strategy_ids=jnp.zeros((num_steps, n_layers), jnp.int32),
                       strategies=(get_strategy(cfg.strategy),)).validate()
        if layer_strategies is not None:
            uniq, ids = strategy_table(layer_strategies, cfg, n_layers)
            return cls.from_table(cfg, num_steps, uniq, ids)
        named = getattr(cfg, "schedule", None)
        if named is not None:
            return get_schedule(named, cfg, num_steps, n_layers)
        strategies, ids = _expand_layer_table(cfg.strategy, n_layers)
        return cls.from_table(cfg, num_steps, strategies, ids)

    @classmethod
    def from_table(cls, cfg, num_steps: int, strategies: tuple,
                   layer_ids: Sequence[int]) -> "SparsitySchedule":
        """Schedule with a step-constant per-layer id row and the config's
        Update/Dispatch mode pattern."""
        row = np.asarray(layer_ids, np.int32)
        return cls(mode=jnp.asarray(_mode_array(cfg, num_steps)),
                   strategy_ids=jnp.broadcast_to(
                       row[None, :], (num_steps, row.shape[0])).copy(),
                   strategies=tuple(strategies)).validate()


# ---------------------------------------------------------------------------
# Batched serving: pad/stack mixed-length schedules into lane tables
# ---------------------------------------------------------------------------

def merge_strategies(schedules: Sequence[SparsitySchedule]) -> tuple:
    """Union of the schedules' static strategy sets (value-deduplicated).

    Dedup is by :func:`repro.core.strategy.strategy_key`: value-equal
    registry strategies merge even when they are DISTINCT objects — e.g.
    after an LRU eviction makes ``resolve_schedule`` re-resolve a spec
    into fresh instances — so the serving tick's ``emit_switch`` branch
    count (and hence its compiled executable) is a function of the
    distinct producer VALUES in flight, not of allocation history.
    Ad-hoc strategies without a value key dedup by object identity.  The
    merged tuple is the single static active set the serving tick closes
    over — every lane's id row indexes it."""
    uniq: list = []
    seen: dict = {}
    for sched in schedules:
        for s in sched.strategies:
            key = strategy_key(s)
            if key not in seen:
                seen[key] = len(uniq)
                uniq.append(s)
    return tuple(uniq)


def schedule_lane_rows(sched: SparsitySchedule, strategies: tuple,
                       num_steps: int) -> tuple[np.ndarray, np.ndarray]:
    """Remap ONE schedule onto a shared strategy set and pad to a lane.

    Returns host ``(mode_row (num_steps,), id_row (num_steps, L))`` int32
    arrays: the schedule's own steps keep their mode and get their
    strategy ids remapped into ``strategies`` (a :func:`merge_strategies`
    union that must contain every producer this schedule uses — matched by
    :func:`~repro.core.strategy.strategy_key`, so a value-equal resident
    producer satisfies a freshly re-resolved schedule); steps past
    ``sched.num_steps`` pad with :data:`MODE_IDLE` / id 0.  These rows are
    TRACED data — swapping a lane's rows at refill never recompiles."""
    if sched.num_steps > num_steps:
        raise ValueError(
            f"schedule has {sched.num_steps} steps; the lane table holds "
            f"{num_steps} (raise the batcher's max_steps)")
    index: dict = {}
    for i, s in enumerate(strategies):
        index.setdefault(strategy_key(s), i)
    missing = [s.name for s in sched.strategies
               if strategy_key(s) not in index]
    if missing:
        raise ValueError(
            f"schedule strategies {missing} are not in the shared lane "
            f"strategy set {[s.name for s in strategies]}; rebuild the "
            "batcher universe (merge_strategies) over all queued requests")
    remap = np.asarray([index[strategy_key(s)] for s in sched.strategies],
                       np.int32)
    mode_row = np.full((num_steps,), MODE_IDLE, np.int32)
    mode_row[: sched.num_steps] = np.asarray(sched.mode)
    id_row = np.zeros((num_steps, sched.n_layers), np.int32)
    id_row[: sched.num_steps] = remap[np.asarray(sched.strategy_ids)]
    return mode_row, id_row


def stack_schedules(schedules: Sequence[SparsitySchedule],
                    num_steps: Optional[int] = None):
    """Pad/stack mixed-length schedules into batched lane tables.

    Returns ``(mode, strategy_ids, strategies, lengths)`` where ``mode``
    is ``(lanes, num_steps)`` int32, ``strategy_ids`` is ``(lanes,
    num_steps, n_layers)`` int32 (both host numpy — the continuous
    batcher edits single lanes in place at refill), ``strategies`` the
    merged static producer set every id indexes, and ``lengths`` each
    schedule's true step count.  ``num_steps`` pads to a fixed width
    (default: the longest schedule); shorter lanes trail MODE_IDLE."""
    if not schedules:
        raise ValueError("stack_schedules needs at least one schedule")
    n_layers = {s.n_layers for s in schedules}
    if len(n_layers) != 1:
        raise ValueError(f"mixed n_layers across schedules: {n_layers}")
    lengths = [s.num_steps for s in schedules]
    s_max = max(lengths) if num_steps is None else int(num_steps)
    strategies = merge_strategies(schedules)
    rows = [schedule_lane_rows(s, strategies, s_max) for s in schedules]
    mode = np.stack([m for m, _ in rows])
    ids = np.stack([i for _, i in rows])
    return mode, ids, strategies, lengths


def tick_mode_groups(mode_tab: np.ndarray, steps: np.ndarray,
                     active: np.ndarray) -> list[tuple[int, np.ndarray]]:
    """Partition one serving tick's ACTIVE lanes by their current mode.

    The stacked schedule tables are host-visible, so BEFORE launching a
    tick the batcher knows every lane's mode at its own step counter:
    ``mode_tab[w, steps[w]]``.  Returns ``[(mode, lane_mask), ...]``
    (mode-sorted; ``lane_mask`` is a ``(lanes,)`` bool over ALL lanes,
    True only for active lanes currently in that mode).  One group means
    the tick is mode-HOMOGENEOUS and can run a batched mode body
    (:func:`repro.diffusion.pipeline.make_grouped_lane_tick`) — lane
    parallelism on the model batch axis instead of the lane-serial scan;
    several groups is a genuinely mixed tick, which falls back to the
    scan.  Idle (inactive) lanes belong to no group.
    """
    mode_tab = np.asarray(mode_tab)
    steps = np.asarray(steps)
    active = np.asarray(active, bool)
    n_lanes, s_max = mode_tab.shape
    cur = mode_tab[np.arange(n_lanes), np.clip(steps, 0, s_max - 1)]
    return [(int(m), active & (cur == m))
            for m in sorted({int(c) for c, a in zip(cur, active) if a})]


# ---------------------------------------------------------------------------
# Named-schedule registry (deployment recipes)
# ---------------------------------------------------------------------------

ScheduleFactory = Callable[[Any, int, int], SparsitySchedule]

_SCHEDULES: dict[str, ScheduleFactory] = {}
_SUMMARIES: dict[str, str] = {}


def register_schedule(name: str, factory: ScheduleFactory,
                      summary: str = "") -> None:
    """Register ``factory(cfg, num_steps, n_layers) -> SparsitySchedule``."""
    _SCHEDULES[name] = factory
    _SUMMARIES[name] = summary


def available_schedules() -> tuple[str, ...]:
    return tuple(_SCHEDULES)


def schedule_summaries() -> dict[str, str]:
    """name -> one-line description (docs / --help / ROADMAP table)."""
    return dict(_SUMMARIES)


def get_schedule(spec: Union[str, SparsitySchedule], cfg, num_steps: int,
                 n_layers: int) -> SparsitySchedule:
    """Resolve a named schedule (or pass a prebuilt one through)."""
    if isinstance(spec, SparsitySchedule):
        if spec.num_steps != num_steps or spec.n_layers != n_layers:
            raise ValueError(
                f"schedule is ({spec.num_steps} steps, {spec.n_layers} "
                f"layers); the run wants ({num_steps}, {n_layers})")
        return spec.validate()
    try:
        factory = _SCHEDULES[spec]
    except KeyError:
        raise ValueError(
            f"unknown sparsity schedule {spec!r}; registered: "
            f"{available_schedules()}") from None
    return factory(cfg, num_steps, n_layers).validate()


def _hunyuan_schedule(cfg, num_steps: int, n_layers: int) -> SparsitySchedule:
    strategies, ids = _expand_layer_table(get_strategy("hunyuan-1.5x"),
                                          n_layers)
    return SparsitySchedule.from_table(cfg, num_steps, strategies, ids)


def _step_ramp_schedule(cfg, num_steps: int, n_layers: int) -> SparsitySchedule:
    names = ("skip-only", "flashomni", "cache-all")
    strategies = tuple(get_strategy(n) for n in names)
    phase = np.minimum((np.arange(num_steps) * len(names)) // max(num_steps, 1),
                       len(names) - 1).astype(np.int32)
    ids = np.broadcast_to(phase[:, None], (num_steps, n_layers)).copy()
    return SparsitySchedule(mode=jnp.asarray(_mode_array(cfg, num_steps)),
                            strategy_ids=jnp.asarray(ids),
                            strategies=strategies)


register_schedule(
    "hunyuan-1.5x", _hunyuan_schedule,
    "paper HunyuanVideo 1.5× deployment table expanded per layer "
    "(skip-only boundaries, striped flashomni/sliding-window interior)")
register_schedule(
    "step-ramp", _step_ramp_schedule,
    "denoising-phase ramp: skip-only -> flashomni -> cache-all over the "
    "step axis (uniform across layers)")
