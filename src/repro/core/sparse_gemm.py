"""FlashOmni sparse GEMMs — XLA structural path (paper §3.5, Obs. 2/3, Eq. 3-4).

GEMM-Q (query projection, spatial-axis sparsity)
    RMSNorm and RoPE are token-local, so if block ``i``'s attention output
    is cached for every head, its query projection row-block is dead code.
    The structural path gathers the live row blocks (capacity padded),
    projects only those, and scatters into a zero output.

GEMM-O (output projection, reduction-axis sparsity)
    ``Out_i = Σ_h O_i^h W_h``; heads cached for block ``i`` contribute the
    pre-computed bias  B_c[i] = Σ_{h∉H_i} Õ_i^h W_h  (refreshed at Update).
    Because OP_reuse is element-wise linear (TaylorSeer), forecasting
    commutes with the projection (Eq. 4), so at Dispatch the bias is simply
    Taylor-forecast in *output* space and added to the live-head partial
    GEMM.  Rows whose heads are ALL cached skip the GEMM entirely
    (spatial gather, as in GEMM-Q); intra-row head sparsity is masked in
    this XLA path and structurally skipped in the Pallas kernel.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.attention import scatter_blocks
from repro.core.symbols import active_indices

__all__ = [
    "gemm_q_sparse",
    "gemm_q_from_plan",
    "gemm_o_update_bias",
    "gemm_o_sparse",
    "gemm_o_from_plan",
    "rows_any_head_live",
]


def _gather_rows(xb: jax.Array, ids: jax.Array) -> jax.Array:
    idx = jnp.broadcast_to(ids[..., None, None], (*ids.shape, *xb.shape[-2:]))
    return jnp.take_along_axis(xb, idx, axis=-3)


def gemm_q_from_plan(
    x: jax.Array,
    w: jax.Array,
    ids: jax.Array,
    cnt: jax.Array,
    *,
    block: int,
    bias: Optional[jax.Array] = None,
    compact: bool = False,
) -> jax.Array:
    """Row-block-sparse ``x @ w`` over PRECOMPUTED live-row indices.

    ``ids``/``cnt`` from :func:`repro.core.symbols.active_indices` (or a
    :class:`~repro.core.plan.DispatchPlan`).  When ``compact`` the gathered
    projection is returned in slot order, shape (..., cap·block, d_out),
    without the scatter (the Pallas layout-fusion contract); otherwise it
    is scattered to full shape with zeros on cached rows.
    """
    n, d_in = x.shape[-2], x.shape[-1]
    t = n // block
    xb = x.reshape(*x.shape[:-2], t, block, d_in)
    xg = _gather_rows(xb, ids)                                  # (..., cap, block, d_in)
    yg = jnp.einsum("...cbd,df->...cbf", xg, w)
    if bias is not None:
        yg = yg + bias
    if compact:
        return yg.reshape(*x.shape[:-2], ids.shape[-1] * block, w.shape[-1])
    outb = jnp.zeros((*x.shape[:-2], t, block, w.shape[-1]), yg.dtype)
    outb = scatter_blocks(outb, ids, cnt, yg)
    return outb.reshape(*x.shape[:-1], w.shape[-1])


def gemm_q_sparse(
    x: jax.Array,
    w: jax.Array,
    m_rows: jax.Array,
    *,
    block: int,
    cap: int,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Row-block-sparse ``x @ w`` (mask-level entry; decodes indices).

    x: (..., N, d_in); w: (d_in, d_out); m_rows: (..., T) with T = N//block,
    True = row block is live.  Cached row blocks produce zeros (their Q is
    never consumed — their attention output comes from cache).
    """
    ids, cnt = active_indices(m_rows, cap)
    return gemm_q_from_plan(x, w, ids, cnt, block=block, bias=bias)


def rows_any_head_live(m_ch: jax.Array) -> jax.Array:
    """(..., T, H) per-(block, head) compute mask -> (..., T) block-live mask."""
    return jnp.any(m_ch, axis=-1)


def gemm_o_update_bias(
    o_heads: jax.Array,
    w: jax.Array,
    m_ch: jax.Array,
    *,
    block: int,
) -> jax.Array:
    """Update-step stage 1: cache bias ``B_c = Σ_{h∉H_i} O_i^h W_h``.

    o_heads: (..., N, H, dh); w: (H, dh, d_out); m_ch: (..., T, H).
    Returns (..., N, d_out) — zero on rows whose every head is live.
    """
    n = o_heads.shape[-3]
    t = n // block
    cached = ~m_ch                                              # heads NOT recomputed
    per_tok = jnp.repeat(cached, block, axis=-2)[..., :n, :]    # (..., N, H)
    contrib = jnp.einsum("...nhd,hdf->...nhf", o_heads, w)
    return jnp.sum(jnp.where(per_tok[..., None], contrib, 0), axis=-2)


def gemm_o_from_plan(
    o_heads: jax.Array,
    w: jax.Array,
    head_mask: jax.Array,
    ids: jax.Array,
    cnt: jax.Array,
    bias_forecast: jax.Array,
    *,
    block: int,
) -> jax.Array:
    """Dispatch-step GEMM-O over PRECOMPUTED indices.

    o_heads: (..., N, H, dh); w: (H, dh, d_out); ``ids``/``cnt`` are the
    live-row list and ``head_mask`` (..., cap, H) the per-live-row live-head
    mask — both straight from a :class:`~repro.core.plan.DispatchPlan`.

    Under ``kv_buckets > 1`` the plan's ``head_mask`` already carries the
    bucket-induced head clamp (folded back at Update time by
    ``plan.gmo_layout``), so this path consumes the same truncated head
    lists as the bucketed Pallas kernel — the ISSUE-8 no-carve-outs
    bit-consistency invariant needs no bucket awareness here.
    """
    n, h, dh = o_heads.shape[-3], o_heads.shape[-2], o_heads.shape[-1]
    t = n // block
    d_out = w.shape[-1]
    ob = o_heads.reshape(*o_heads.shape[:-3], t, block, h, dh)
    idx = jnp.broadcast_to(ids[..., None, None, None], (*ids.shape, block, h, dh))
    og = jnp.take_along_axis(ob, idx, axis=-4)                  # (..., cap, block, H, dh)
    og = jnp.where(head_mask[..., None, :, None], og, 0)        # mask cached heads
    yg = jnp.einsum("...cbhd,hdf->...cbf", og, w)
    outb = jnp.zeros((*o_heads.shape[:-3], t, block, d_out), yg.dtype)
    outb = scatter_blocks(outb, ids, cnt, yg)
    out = outb.reshape(*o_heads.shape[:-3], n, d_out)
    return out + bias_forecast


def gemm_o_sparse(
    o_heads: jax.Array,
    w: jax.Array,
    m_ch: jax.Array,
    bias_forecast: jax.Array,
    *,
    block: int,
    cap: int,
) -> jax.Array:
    """Dispatch-step GEMM-O (mask-level entry; decodes indices per call).

    o_heads: (..., N, H, dh); w: (H, dh, d_out); m_ch: (..., T, H);
    bias_forecast = OP_reuse(B_c): (..., N, d_out).
    Fully cached row blocks cost zero GEMM FLOPs (spatial gather).
    """
    live_rows = rows_any_head_live(m_ch)                        # (..., T)
    ids, cnt = active_indices(live_rows, cap)
    mh = jnp.take_along_axis(m_ch, ids[..., None], axis=-2)     # (..., cap, H)
    return gemm_o_from_plan(o_heads, w, mh, ids, cnt, bias_forecast,
                            block=block)
