"""Pluggable sparsity strategies — first-class sparse-symbol producers.

FlashOmni's central claim (paper §3.3) is that *flexible sparse symbols
standardize the representation of a wide range of sparsity strategies*:
anything that can emit a caching symbol ``S_c`` and a skipping symbol
``S_s`` rides the same Update–Dispatch engine, DispatchPlan and kernels
unchanged.  This module makes the producer side a real API:

  * :class:`SparsityStrategy` — the protocol.  ``emit(q, k, ctx)`` maps the
    Update-step Q/K (plus a :class:`StrategyContext`) to a
    :class:`SymbolSet`: packed ``s_c``/``s_s``, the post-clamp boolean
    masks, and the ranking scores the static-capacity clamp used (the
    engine reuses them to rank the plan's row-capacity truncation).
  * a string-keyed registry (:func:`register_strategy`,
    :func:`get_strategy`, :func:`available_strategies`) resolved once at
    ``update_layer`` trace time from ``EngineConfig.strategy``.

Built-in strategies and the papers/baselines they reproduce:

  ``flashomni``        — the paper's §3.3 rule (C∧G caching + cummass BSS),
                         extracted VERBATIM from the seed
                         ``engine.refresh_symbols`` (bit-identical symbols).
  ``cache-all``        — FORA / TaylorSeer family: every vision block is
                         cached and forecast; text rows refresh (Obs. 1).
  ``skip-only``        — SpargeAttn-style: no caching, per-row cumulative-
                         mass block skipping only.
  ``sliding-window``   — DiTFastAttnV2-style static ``S_s`` band.
  ``multi-granularity``— per-layer / per-head table of child strategies
                         (Sparse VideoGen's spatial/temporal head classes,
                         Sparse-vDiT's per-head fixed patterns).
  ``step-phased``      — SVG-style per-step re-classification: switches
                         between phase children at traced step boundaries
                         (reads ``StrategyContext.step_idx``).
  ``hunyuan-1.5x``     — the paper's HunyuanVideo 1.5× configuration shape
                         expressed as a multi-granularity table.

Schedules: :func:`emit_switch` dispatches over a SET of strategies through
a TRACED strategy id (``lax.switch`` with a uniform ``(q, k)`` operand
signature), which is what :mod:`repro.core.schedule` scans — per-layer /
per-step deployment tables become data, not trace structure.

All strategies are pure ``jnp`` and jit-safe; the clamp + packing step is
shared (:func:`finalize_symbols`) so every producer honours the TPU
static-capacity adaptation identically.
"""

from __future__ import annotations

from typing import (Any, Callable, Mapping, NamedTuple, Optional, Protocol,
                    Sequence, Union, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masklib
from repro.core.symbols import clamp_mask_topk, pack_bits

__all__ = [
    "StrategyContext",
    "SymbolSet",
    "SparsityStrategy",
    "finalize_symbols",
    "emit_switch",
    "strategy_key",
    "register_strategy",
    "get_strategy",
    "available_strategies",
    "strategy_summaries",
    "FlashOmniStrategy",
    "CacheAllStrategy",
    "SkipOnlyStrategy",
    "SlidingWindowStrategy",
    "MultiGranularityStrategy",
    "StepPhasedStrategy",
]


class StrategyContext(NamedTuple):
    """Per-call context handed to ``emit``.

    ``cfg``, ``n_text`` and ``n_tokens`` are static (part of the jit
    closure).  ``layer_idx`` and ``step_idx`` are TRACED scalars under the
    scan-native schedule (``models.dit`` scans layers,
    ``diffusion.pipeline`` scans steps), so strategies may only use them in
    traced arithmetic (``jnp.where`` / ``lax.switch``), never in Python
    control flow.  Both are ``None`` for direct single-layer calls outside
    a schedule (``examples/quickstart.py`` style).  ``num_steps`` is the
    schedule length: a static Python int under ``pipeline.sample`` (one
    schedule per trace) or a TRACED int32 scalar under the continuous
    batcher's serving ticks (lanes mix step counts, so each lane threads
    its own) — strategies must handle both (``jnp`` arithmetic does).
    """

    cfg: Any
    n_text: int
    n_tokens: int
    layer_idx: Optional[Any] = None    # traced int32 scalar under lax.scan
    step_idx: Optional[Any] = None     # traced int32 scalar under the step scan
    num_steps: Optional[Any] = None    # schedule length: static int, or a
                                       # traced per-lane int32 scalar under
                                       # the batched serving ticks


class SymbolSet(NamedTuple):
    """What a strategy emits: packed symbols + masks + clamp-ranking scores.

    ``s_c``/``s_s`` are the packed uint8 symbols (paper Fig. 5);
    ``m_c`` (B, H, T) / ``m_s`` (B, H, T, T) the post-clamp boolean masks
    (True = compute); ``q_scores`` (B, H, T) / ``kv_scores`` (B, H, T, T)
    the ranking the static-capacity clamp used — the engine reuses
    ``q_scores`` as the column-mass ranking for the DispatchPlan's
    row-capacity truncation.
    """

    s_c: jax.Array
    s_s: jax.Array
    m_c: jax.Array
    m_s: jax.Array
    q_scores: jax.Array
    kv_scores: jax.Array


@runtime_checkable
class SparsityStrategy(Protocol):
    """Anything that can produce packed sparse symbols from Update Q/K."""

    name: str

    def emit(self, q: jax.Array, k: jax.Array,
             ctx: StrategyContext) -> SymbolSet: ...


def finalize_symbols(m_c: jax.Array, m_s: jax.Array, q_scores: jax.Array,
                     kv_scores: jax.Array, ctx: StrategyContext) -> SymbolSet:
    """Shared clamp + packing tail of every strategy.

    Applies the TPU static-capacity clamps (DESIGN §2.5) ranked by the
    strategy-provided scores, then packs to uint8 symbols — the exact
    op order of the seed ``refresh_symbols`` so ``flashomni`` stays
    bit-identical.
    """
    cfg = ctx.cfg
    m_c = clamp_mask_topk(m_c, q_scores, cfg.cap_q_cmp(ctx.n_tokens))
    m_s = clamp_mask_topk(m_s, kv_scores, cfg.cap_kv_cmp(ctx.n_tokens))
    s_c = pack_bits(m_c)
    s_s = pack_bits(m_s.reshape(*m_s.shape[:-2], -1))
    return SymbolSet(s_c=s_c, s_s=s_s, m_c=m_c, m_s=m_s,
                     q_scores=q_scores, kv_scores=kv_scores)


def _full(q: jax.Array, t: int, value: bool = True) -> jax.Array:
    """(B, H, T) constant mask matching q's batch/head dims."""
    b, h = q.shape[0], q.shape[1]
    return jnp.full((b, h, t), value, jnp.bool_)


def emit_switch(strategy_id: jax.Array, q: jax.Array, k: jax.Array,
                ctx: StrategyContext,
                strategies: Sequence[Union[str, "SparsityStrategy"]]) -> SymbolSet:
    """Scan-compatible emitter dispatch: ``lax.switch`` over strategies.

    ``strategy_id`` is a TRACED int32 scalar (an entry of a
    :class:`~repro.core.schedule.SparsitySchedule` strategy-id table);
    ``strategies`` is the schedule's static active set.  Every branch takes
    the same uniform ``(q, k)`` operand signature and every
    :class:`SymbolSet` field has a shape/dtype fixed by ``(B, H, T)`` and
    the config capacities alone, so the switch is well-typed for ANY mix of
    registered producers — this is what lets per-layer deployment tables
    ride a single scanned block body instead of unrolling the model.
    """
    resolved = tuple(get_strategy(s) for s in strategies)
    if len(resolved) == 1:
        return resolved[0].emit(q, k, ctx)
    branches = [lambda q, k, s=s: s.emit(q, k, ctx) for s in resolved]
    return jax.lax.switch(jnp.asarray(strategy_id, jnp.int32), branches, q, k)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], "SparsityStrategy"]] = {}
_SUMMARIES: dict[str, str] = {}


def register_strategy(name: str, factory: Callable[[], "SparsityStrategy"],
                      summary: str = "") -> None:
    """Register a zero-arg factory under ``name`` (EngineConfig.strategy)."""
    _REGISTRY[name] = factory
    _SUMMARIES[name] = summary


def available_strategies() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def strategy_summaries() -> dict[str, str]:
    """name -> one-line description (docs / --help / ROADMAP table)."""
    return dict(_SUMMARIES)


def get_strategy(spec: Union[str, "SparsityStrategy"]) -> "SparsityStrategy":
    """Resolve an ``EngineConfig.strategy`` value to a strategy instance.

    Accepts a registry name or an already-constructed strategy object
    (ad-hoc strategies plug in without registration).
    """
    if not isinstance(spec, str):
        return spec
    try:
        return _REGISTRY[spec]()
    except KeyError:
        raise ValueError(
            f"unknown sparsity strategy {spec!r}; registered: "
            f"{available_strategies()}") from None


def _key_part(v):
    """Hashable value-key for one constructor parameter (see strategy_key)."""
    if v is None or isinstance(v, (str, int, float, bool)):
        return v
    if isinstance(v, (tuple, list)):
        return tuple(_key_part(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _key_part(x)) for k, x in v.items()))
    key = strategy_key(v)
    if key[0] == "id":
        raise TypeError(f"no value key for {v!r}")
    return key


def strategy_key(strategy: "SparsityStrategy"):
    """Value-level dedup key for registry strategies; id() as the fallback.

    Two value-equal instances of a built-in strategy class (same class,
    same ``name``, same constructor parameters — compared recursively
    through child strategies) return the SAME key, so serving-side dedup
    (``schedule.merge_strategies``, the continuous batcher's strategy
    universe, the sampler cache) treats them as one producer.  Without
    this, an LRU eviction in the ``resolve_schedule`` memo makes the next
    re-resolution of an unchanged spec mint fresh — value-equal — strategy
    objects, and identity-keyed dedup would grow the universe and re-trace
    every serving executable for nothing.

    Only the built-in classes are value-keyed (their ``emit`` is a pure
    function of the constructor parameters).  Ad-hoc / user strategies
    fall back to object identity: ``("id", id(strategy))`` — correct but
    never merged.
    """
    cls = type(strategy)
    if cls not in _VALUE_KEYED_CLASSES:
        return ("id", id(strategy))
    try:
        params = tuple(sorted(
            (k, _key_part(v)) for k, v in vars(strategy).items()
            if k != "name"))
    except TypeError:
        return ("id", id(strategy))
    return (cls.__name__, strategy.name, params)


# ---------------------------------------------------------------------------
# Built-in strategies
# ---------------------------------------------------------------------------

class FlashOmniStrategy:
    """Paper §3.3 rule — the seed ``refresh_symbols`` body, verbatim.

    C∧G cumulative-mass caching with S_q degradation (``S_c``) plus
    SpargeAttn-style per-row cumulative-mass skipping (``S_s``), both
    ranked for the capacity clamp by the compressed attention map.
    ``tau_q``/``tau_kv`` default to the ``MaskConfig`` values; explicit
    constructor values override (the ToCa-like / aggressive arms).
    """

    name = "flashomni"

    def __init__(self, tau_q: Optional[float] = None,
                 tau_kv: Optional[float] = None):
        self.tau_q = tau_q
        self.tau_kv = tau_kv

    def emit(self, q, k, ctx: StrategyContext) -> SymbolSet:
        m = ctx.cfg.mask
        m_c = masklib.make_caching_mask(q, k, m, ctx.n_text, tau_q=self.tau_q)
        m_c = masklib.apply_degradation(m_c, m.degrade)
        p_map = masklib.compressed_attention_map(q, k, m.pool)
        col_mass = jnp.sum(p_map, axis=-2)
        m_s = masklib.make_skip_mask(q, k, m, ctx.n_text, tau_kv=self.tau_kv)
        return finalize_symbols(m_c, m_s, col_mass, p_map, ctx)


class CacheAllStrategy:
    """FORA / TaylorSeer family: cache-and-forecast EVERY vision block.

    No block skipping; text rows stay live (Observation 1 — text must
    refresh every step).  The forecast order (plain reuse vs Taylor)
    is the engine's ``MaskConfig.order``, not the strategy's concern.
    """

    name = "cache-all"

    def emit(self, q, k, ctx: StrategyContext) -> SymbolSet:
        m = ctx.cfg.mask
        t = m.n_blocks(ctx.n_tokens)
        n_t = -(-ctx.n_text // m.pool) if ctx.n_text else 0
        text_row = jnp.arange(t) < n_t
        m_c = _full(q, t) & text_row
        m_s = _full(q, t)[..., None, :] & jnp.ones((t, t), jnp.bool_)
        q_scores = m_c.astype(jnp.float32)
        kv_scores = jnp.broadcast_to(jnp.ones((t, t), jnp.float32), m_s.shape)
        return finalize_symbols(m_c, m_s, q_scores, kv_scores, ctx)


class SkipOnlyStrategy:
    """SpargeAttn-style: no feature caching, cumulative-mass BSS only."""

    name = "skip-only"

    def __init__(self, tau_kv: Optional[float] = None):
        self.tau_kv = tau_kv

    def emit(self, q, k, ctx: StrategyContext) -> SymbolSet:
        m = ctx.cfg.mask
        t = m.n_blocks(ctx.n_tokens)
        p_map = masklib.compressed_attention_map(q, k, m.pool)
        m_c = _full(q, t)
        m_s = masklib.make_skip_mask(q, k, m, ctx.n_text, tau_kv=self.tau_kv)
        return finalize_symbols(m_c, m_s, jnp.sum(p_map, axis=-2), p_map, ctx)


class SlidingWindowStrategy:
    """DiTFastAttnV2-style static band: ``S_s`` keeps |i−j| < window blocks.

    Input-independent (the classic local-attention pattern expressed as a
    sparse symbol); text rows/columns stay dense when the config protects
    them.  The clamp ranking prefers the NEAREST diagonals, so a tight
    ``cap_kv`` shrinks the band instead of truncating arbitrarily.
    """

    name = "sliding-window"

    def __init__(self, window: int = 4):
        self.window = int(window)

    def emit(self, q, k, ctx: StrategyContext) -> SymbolSet:
        m = ctx.cfg.mask
        t = m.n_blocks(ctx.n_tokens)
        idx = jnp.arange(t)
        dist = jnp.abs(idx[:, None] - idx[None, :])
        band = dist < self.window
        protect = jnp.zeros((t, t), jnp.bool_)
        if m.protect_text and ctx.n_text:
            # Same text semantics as masklib.make_skip_mask: protection is
            # applied ON TOP of the window, never narrowed by it.
            n_t = -(-ctx.n_text // m.pool)
            is_text = idx < n_t
            protect = is_text[:, None] | is_text[None, :]
            band = band | protect
        m_c = _full(q, t)
        m_s = _full(q, t)[..., None, :] & band
        q_scores = jnp.ones(m_c.shape, jnp.float32)
        # Rank protected text pairs above every band distance so a tight
        # cap_kv shrinks the band from its far edge and never evicts the
        # prompt (Observation 1) out from under vision queries.
        kv_scores = jnp.broadcast_to(
            jnp.where(protect, 1e9, -dist.astype(jnp.float32)), m_s.shape)
        return finalize_symbols(m_c, m_s, q_scores, kv_scores, ctx)


class MultiGranularityStrategy:
    """Compose a per-layer / per-head table of child strategies.

    Sparse VideoGen classifies heads into spatial vs. temporal sparsity
    classes per step; Sparse-vDiT fixes a sparse pattern per head offline.
    Both are tables ``(layer, head) -> strategy`` — exactly what this
    strategy expresses over ANY registered children.

    ``children``     — child strategy names/instances (index space of the
                       tables).  Children must treat heads independently
                       (true of every built-in): each child only ever sees
                       the Q/K of the heads assigned to it.
    ``head_assign``  — length-H (or shorter, tiled) template of child
                       indices; default stripes heads across children.
    ``layer_assign`` — ``{layer_idx: template | child_idx}`` overrides.
                       ``emit`` itself NEVER reads the layer index (layer
                       ids are traced under the scanned block body, useless
                       for Python-side head grouping); the table is instead
                       routed through the :class:`~repro.core.schedule.
                       SparsitySchedule` strategy-id table —
                       ``SparsitySchedule.from_config`` expands the layer
                       table into per-layer variants (one registry entry
                       per distinct template, see :meth:`per_layer`) and
                       points each layer's id at its variant.
    """

    name = "multi-granularity"

    def __init__(self, children: Sequence[Union[str, SparsityStrategy]] = (
                     "flashomni", "sliding-window"),
                 head_assign: Optional[Sequence[int]] = None,
                 layer_assign: Optional[Mapping[int, Any]] = None,
                 name: Optional[str] = None):
        self.children = tuple(get_strategy(c) for c in children)
        self.head_assign = None if head_assign is None else tuple(head_assign)
        self.layer_assign = dict(layer_assign or {})
        if name is not None:
            self.name = name          # registered presets keep their own name

    def _template(self, layer_idx: Optional[int]) -> Optional[tuple[int, ...]]:
        """The head-assignment template for ``layer_idx`` (layer table →
        head template fallback), used by the SCHEDULE-side expansion only —
        ``emit`` is layer-agnostic."""
        a: Any = None
        if layer_idx is not None:
            a = self.layer_assign.get(layer_idx)
        if a is None:
            a = self.head_assign
        if a is None:
            return None
        return (a,) if isinstance(a, int) else tuple(a)

    def _assignment(self, heads: int) -> list[int]:
        a = self._template(None)
        if a is None:
            return [h % len(self.children) for h in range(heads)]
        return [a[h % len(a)] for h in range(heads)]

    def per_layer(self, n_layers: int) -> list["MultiGranularityStrategy"]:
        """Expand the layer table into one pinned-template strategy per
        layer.  ``SparsitySchedule.from_config`` calls this (deduplicated)
        to turn ``layer_assign`` into strategy-id table entries; it is also
        usable directly as a ``denoise_step(..., layer_strategies=...)``
        table."""
        return [MultiGranularityStrategy(children=self.children,
                                         head_assign=self._template(i),
                                         name=f"{self.name}[layer {i}]")
                for i in range(n_layers)]

    def emit(self, q, k, ctx: StrategyContext) -> SymbolSet:
        heads = q.shape[1]
        assign = self._assignment(heads)
        groups: dict[int, list[int]] = {}
        for h, a in enumerate(assign):
            groups.setdefault(a, []).append(h)
        # Each child emits ONLY over its assigned heads (children are
        # per-head independent), so total symbol work stays one-emit-sized
        # regardless of how many children the table mixes.
        parts = {a: self.children[a].emit(q[:, jnp.asarray(hs)],
                                          k[:, jnp.asarray(hs)], ctx)
                 for a, hs in groups.items()}

        def sel(field: str) -> jax.Array:
            cols: list = [None] * heads
            for a, hs in groups.items():
                arr = getattr(parts[a], field)
                for j, h in enumerate(hs):
                    cols[h] = arr[:, j]
            return jnp.stack(cols, axis=1)

        m_c, m_s = sel("m_c"), sel("m_s")
        # Children already clamped + capacity-ranked their own symbols; the
        # per-head reassembly preserves the per-row True-count bounds, so
        # only re-packing is needed here.
        s_c = pack_bits(m_c)
        s_s = pack_bits(m_s.reshape(*m_s.shape[:-2], -1))
        return SymbolSet(s_c=s_c, s_s=s_s, m_c=m_c, m_s=m_s,
                         q_scores=sel("q_scores"), kv_scores=sel("kv_scores"))


class StepPhasedStrategy:
    """Schedule-varying producer: re-classify at step boundaries.

    Sparse VideoGen re-classifies attention heads per denoising step;
    Sparse-vDiT fixes per-head patterns over a step schedule.  Both need
    the CURRENT STEP inside ``emit`` — this strategy reads the traced
    ``ctx.step_idx`` and ``lax.switch``es between its phase children at the
    configured boundaries, so one trace serves the whole step scan.

    ``phases``      — child strategies, one per phase (any registry
                      names/instances; e.g. two ``multi-granularity``
                      tables with swapped head classes = SVG head
                      re-classification).
    ``boundaries``  — phase-change steps, ascending.  Floats are fractions
                      of ``ctx.num_steps`` (requires a schedule-driven call
                      so ``num_steps`` is known — a static int under
                      ``pipeline.sample`` or a traced per-lane scalar under
                      the continuous batcher's ticks; both resolve through
                      the same ``jnp.round`` arithmetic, so batched serving
                      flips phases at the SAME step as a sequential run);
                      ints are absolute step indices.
                      ``len(phases) == len(boundaries) + 1``.

    Outside a schedule (``step_idx is None`` — direct ``update_layer``
    calls) phase 0 is used.
    """

    name = "step-phased"

    def __init__(self, phases: Sequence[Union[str, SparsityStrategy]] = (
                     "flashomni", "cache-all"),
                 boundaries: Sequence[Union[int, float]] = (0.5,),
                 name: Optional[str] = None):
        self.phases = tuple(get_strategy(p) for p in phases)
        self.boundaries = tuple(boundaries)
        if len(self.phases) != len(self.boundaries) + 1:
            raise ValueError(
                f"{len(self.phases)} phases need {len(self.phases) - 1} "
                f"boundaries, got {len(self.boundaries)}")
        if name is not None:
            self.name = name

    def _boundary_steps(self, num_steps) -> list:
        """Resolve boundaries against ``num_steps``.

        With a STATIC ``num_steps`` (or all-absolute boundaries) this is
        host arithmetic and the resolved steps are validated ascending.
        With a TRACED ``num_steps`` (the continuous batcher threads each
        lane's own step count through the tick) fractional boundaries
        resolve via ``jnp.round`` — fractional semantics survive batching
        instead of silently requiring absolute boundaries.  BOTH paths
        round the FLOAT32 product half-to-even (the static path through
        numpy): device arithmetic is f32, and a float64 host resolve can
        land one step away on near-half products (e.g. 0.3·5 is
        1.4999998 in f64 but 1.5000001 in f32), which would break the
        batcher's bit-parity-with-``sample`` guarantee.  Monotone raw
        boundaries stay monotone after the resolve, so the ascending
        guarantee carries over.
        """
        traced = num_steps is not None and not isinstance(num_steps, int)
        steps = []
        for b in self.boundaries:
            if isinstance(b, float):
                if num_steps is None:
                    raise ValueError(
                        f"{self.name}: fractional boundary {b} needs "
                        "StrategyContext.num_steps (run under a "
                        "SparsitySchedule)")
                if traced:
                    b = jnp.round(
                        jnp.float32(b) * jnp.asarray(num_steps, jnp.float32)
                    ).astype(jnp.int32)
                else:
                    b = int(np.round(np.float32(b) * np.float32(num_steps)))
            steps.append(b if traced and not isinstance(b, int) else int(b))
        if not traced and [int(s) for s in steps] != sorted(int(s) for s in steps):
            raise ValueError(f"{self.name}: boundaries must ascend: {steps}")
        return steps

    def emit(self, q, k, ctx: StrategyContext) -> SymbolSet:
        if ctx.step_idx is None or len(self.phases) == 1:
            return self.phases[0].emit(q, k, ctx)
        steps = self._boundary_steps(ctx.num_steps)
        sidx = jnp.asarray(ctx.step_idx, jnp.int32)
        phase = jnp.zeros((), jnp.int32)
        for s in steps:
            phase = phase + (sidx >= s).astype(jnp.int32)
        branches = [lambda q, k, c=c: c.emit(q, k, ctx) for c in self.phases]
        return jax.lax.switch(phase, branches, q, k)


# Built-in classes whose emit is a pure function of the constructor
# parameters: safe to dedup by value (see strategy_key).  Exact types only —
# subclasses may carry extra behaviour and fall back to identity.
_VALUE_KEYED_CLASSES = (FlashOmniStrategy, CacheAllStrategy, SkipOnlyStrategy,
                        SlidingWindowStrategy, MultiGranularityStrategy,
                        StepPhasedStrategy)


register_strategy(
    "flashomni", FlashOmniStrategy,
    "paper §3.3: C∧G cummass caching + cummass BSS (seed rule, bit-exact)")
register_strategy(
    "cache-all", CacheAllStrategy,
    "FORA / TaylorSeer: forecast every vision block, no skipping")
register_strategy(
    "skip-only", SkipOnlyStrategy,
    "SpargeAttn: per-row cummass block skipping, no caching")
register_strategy(
    "sliding-window", SlidingWindowStrategy,
    "DiTFastAttnV2: static |i-j|<w band as S_s, text protected")
register_strategy(
    "multi-granularity", MultiGranularityStrategy,
    "per-layer/per-head table of child strategies (SVG / Sparse-vDiT)")
register_strategy(
    "step-phased", StepPhasedStrategy,
    "SVG-style per-step re-classification: switch phase children at "
    "traced step boundaries")
register_strategy(
    "hunyuan-1.5x",
    lambda: MultiGranularityStrategy(
        children=("flashomni", "skip-only", "sliding-window"),
        # Boundary layers never cache (skip-only); interior layers run the
        # full rule on 2 of 3 heads and a static band on the third — the
        # shape of the paper's HunyuanVideo 1.5× deployment table.
        head_assign=(0, 0, 2),
        layer_assign={0: 1, 1: 1},
        name="hunyuan-1.5x"),
    "paper HunyuanVideo 1.5× table: flashomni/sliding-window striped "
    "heads; skip-only boundary layers via the schedule's per-layer "
    "strategy-id table (SparsitySchedule.from_config expansion)")
