"""FlashOmni unified sparse symbols (paper §3.3).

Logical block-sparse masks are packed into compact uint8 "sparse symbols"
with big-endian bit alignment (paper Fig. 5: mask [1,1,1,0,0] -> 0b11100000
-> uint8 224).  Two symbols exist per attention layer:

  * ``S_c`` — feature-caching symbol, one bit per (head, q-block).
    Bit == 0 -> the block output is cached/forecast (cache-then-reuse);
    bit == 1 -> the block is computed (compute-on-demand).
  * ``S_s`` — block-sparse-skipping symbol, one bit per
    (head, q-block, kv-block).  Bit == 0 -> the `Q_i K_j^T` / `P_ij V_j`
    tile pair is skipped; bit == 1 -> computed.

Decoders follow the paper:

  F(S_c, i)    = (S_c[i // 8] >> (7 - i % 8)) & 1          (spatial axis)
  J(S_s, i, j) = F(S_s_flat, i * T_kv + j)                 (reduction axis)

Everything here is pure ``jnp`` and jit-safe; the Pallas kernels consume
either the packed symbols directly (fidelity path) or the derived
capacity-padded index lists (structural-skip path, see ``active_indices``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "pack_bits",
    "unpack_bits",
    "decode_spatial",
    "decode_reduction",
    "packed_len",
    "active_indices",
    "capacity_for",
    "clamp_mask_topk",
    "slot_positions",
]

# Big-endian bit weights within a byte: bit for in-byte position p sits at
# (7 - p), so weights are [128, 64, 32, 16, 8, 4, 2, 1].
_BIT_WEIGHTS = np.array([128, 64, 32, 16, 8, 4, 2, 1], dtype=np.uint8)


def packed_len(n_bits: int) -> int:
    """Number of uint8 bytes needed to store ``n_bits`` big-endian bits."""
    return -(-n_bits // 8)


def pack_bits(mask: jax.Array) -> jax.Array:
    """Pack a boolean/0-1 mask of shape (..., T) into uint8 (..., ceil(T/8)).

    Big-endian within each byte, zero padded at the tail (paper Fig. 5).
    """
    mask = jnp.asarray(mask)
    t = mask.shape[-1]
    pad = packed_len(t) * 8 - t
    if pad:
        mask = jnp.pad(
            mask, [(0, 0)] * (mask.ndim - 1) + [(0, pad)], constant_values=0
        )
    bits = mask.reshape(*mask.shape[:-1], -1, 8).astype(jnp.uint8)
    return jnp.einsum(
        "...tb,b->...t", bits, jnp.asarray(_BIT_WEIGHTS), preferred_element_type=jnp.uint8
    ).astype(jnp.uint8)


def unpack_bits(sym: jax.Array, n_bits: int) -> jax.Array:
    """Inverse of :func:`pack_bits` -> bool mask of shape (..., n_bits)."""
    sym = jnp.asarray(sym, dtype=jnp.uint8)
    shifts = jnp.arange(7, -1, -1, dtype=jnp.uint8)  # big-endian
    bits = (sym[..., :, None] >> shifts) & jnp.uint8(1)
    bits = bits.reshape(*sym.shape[:-1], -1)
    return bits[..., :n_bits].astype(jnp.bool_)


def decode_spatial(sym: jax.Array, i: jax.Array) -> jax.Array:
    """Paper's spatial decoder ``F(S_c, i)`` -> 0/1 (int32).

    ``sym`` is the packed symbol array whose last dim indexes bytes; ``i``
    is a (q-)block index along the unpacked axis.
    """
    i = jnp.asarray(i, dtype=jnp.int32)
    byte = jnp.take(sym, i // 8, axis=-1).astype(jnp.int32)
    return (byte >> (7 - (i % 8))) & 1


def decode_reduction(sym_flat: jax.Array, i: jax.Array, j: jax.Array, t_kv: int) -> jax.Array:
    """Paper's reduction decoder ``J(S_s, i, j)`` over a row-major packed
    (T_q x T_kv) bit matrix flattened along the last axis."""
    flat = jnp.asarray(i, jnp.int32) * t_kv + jnp.asarray(j, jnp.int32)
    return decode_spatial(sym_flat, flat)


def capacity_for(t: int, fraction: float, quantum: int = 8) -> int:
    """Static capacity (padded active-count) for a sparsity fraction.

    TPU adaptation (DESIGN §2.5): the number of *computed* blocks implied by
    the cumulative-mass thresholds is data dependent; we bound it by a
    static capacity rounded up to ``quantum`` so the compiled kernel shape
    is stable across steps.
    """
    keep = int(np.ceil(t * float(fraction)))
    keep = max(min(keep, t), 1)
    return int(min(-(-keep // quantum) * quantum, t))


def clamp_mask_topk(mask: jax.Array, score: jax.Array, cap: int) -> jax.Array:
    """Bound the True-count of ``mask`` (last axis) by ``cap``, keeping the
    highest-``score`` entries (TPU static-capacity adaptation, DESIGN §2.5)."""
    t = mask.shape[-1]
    if cap >= t:
        return mask
    s = jnp.where(mask, score.astype(jnp.float32), -jnp.inf)
    _, ids = jax.lax.top_k(s, cap)
    keep = jnp.zeros(mask.shape, jnp.bool_)
    keep = jnp.put_along_axis(keep, ids, jnp.ones_like(ids, jnp.bool_), axis=-1,
                              inplace=False)
    return mask & keep


def slot_positions(ids: jax.Array, count: jax.Array, t: int) -> jax.Array:
    """Inverse of :func:`active_indices`: map each of the ``t`` positions to
    its slot in the compacted ``ids`` list (0 for positions never selected).

    ``ids``: (..., C) from ``active_indices``; ``count``: (...,).  Padding
    slots (slot >= count) are routed to a discard column so a duplicated
    padded id can never overwrite a live slot assignment.  Used to chain the
    compact GEMM-Q layout into the CSR attention kernel without a scatter.
    """
    cap = ids.shape[-1]
    slot = jnp.arange(cap, dtype=jnp.int32)
    sid = jnp.where(slot < count[..., None], ids, t)          # discard -> col t
    scat = jnp.zeros((*ids.shape[:-1], t + 1), jnp.int32)
    scat = jnp.put_along_axis(scat, sid, jnp.broadcast_to(slot, sid.shape),
                              axis=-1, inplace=False)
    return scat[..., :t]


def active_indices(mask: jax.Array, capacity: int) -> tuple[jax.Array, jax.Array]:
    """Compacted index list of ``True`` positions, capacity-padded.

    Returns ``(ids, count)`` where ``ids`` has shape (..., capacity) int32.
    Positions beyond ``count`` repeat the last valid id (safe gather) — the
    kernels mask them out with ``@pl.when``.  Selection keeps ascending
    order so gathers stay quasi-sequential in HBM (DMA friendliness).
    """
    mask = jnp.asarray(mask)
    t = mask.shape[-1]
    # Stable "sort by (not active, index)": active positions first, in order.
    key = jnp.where(mask, 0, 1) * t + jnp.arange(t, dtype=jnp.int32)
    order = jnp.argsort(key, axis=-1)[..., :capacity].astype(jnp.int32)
    count = jnp.sum(mask, axis=-1).astype(jnp.int32)
    count = jnp.minimum(count, capacity)
    # Clamp padding slots to the last active id (or 0 when none active).
    slot = jnp.arange(capacity, dtype=jnp.int32)
    last_valid = jnp.take_along_axis(
        order, jnp.maximum(count - 1, 0)[..., None], axis=-1
    )
    ids = jnp.where(slot < count[..., None], order, last_valid)
    return ids, count
