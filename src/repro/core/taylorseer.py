"""TaylorSeer feature forecasting (Liu et al. 2025b), used by FlashOmni for
the cache-then-reuse path (paper §3.3: "For cached blocks, FlashOmni employs
TaylorSeer to forecast future features via Taylor series expansion using
stored features and their derivatives").

At every *Update* step (interval 𝒩) the engine stores the fresh feature and
refreshes backward finite differences up to order 𝒟:

    Δ⁰y_t = y_t,   Δⁱy_t = Δ^{i-1}y_t − Δ^{i-1}y_{t−𝒩}

At a *Dispatch* step ``k ∈ [1, 𝒩−1]`` after the last update, the forecast is

    ŷ(t+k) = Σ_{i=0}^{𝒟}  Δⁱy_t · kⁱ / (i! · 𝒩ⁱ)

𝒟 = 0 degenerates to plain reuse (FORA-style); 𝒟 = 1 is linear
extrapolation (the paper's best-quality setting, Table 3).  Orders that do
not yet have enough history are masked to zero, so warmup behaviour is
exact plain-reuse until 𝒟+1 updates have been observed.

Everything is a pytree-of-arrays ``TaylorState`` so it can live inside
jitted step functions and be carried through ``lax`` control flow.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TaylorState", "init_state", "update", "forecast", "reuse_coefficients"]

# NOTE (beyond-paper): the cited TaylorSeer coefficients ``kⁱ/(i!·𝒩ⁱ)``
# treat Δⁱ/𝒩ⁱ as an unbiased iᵗʰ-derivative estimate, which is exact only
# for polynomials of degree ≤ 1.  Newton's backward-difference form
# ``c_i = Π_{j<i}(x+j)/i!`` (x = k/𝒩) is exact for degree ≤ 𝒟 at zero extra
# cost.  ``mode="newton"`` enables it; tests cover both.


class TaylorState(NamedTuple):
    """Finite-difference stack: ``derivs[i] = Δⁱ y`` at the last update."""

    derivs: jax.Array      # (order+1, *feature_shape)
    n_updates: jax.Array   # scalar int32 — number of updates absorbed


def init_state(feature_shape: tuple[int, ...], order: int, dtype=jnp.float32) -> TaylorState:
    return TaylorState(
        derivs=jnp.zeros((order + 1, *feature_shape), dtype=dtype),
        n_updates=jnp.zeros((), jnp.int32),
    )


def update(state: TaylorState, y: jax.Array) -> TaylorState:
    """Absorb a freshly computed feature at an *Update* step."""
    order = state.derivs.shape[0] - 1
    prev = state.derivs
    new = [y.astype(prev.dtype)]
    for i in range(1, order + 1):
        new.append(new[i - 1] - prev[i - 1])
    derivs = jnp.stack(new, axis=0)
    # Order-i difference is meaningful only once i+1 samples exist; zero
    # the rest so forecasts degrade to lower order during warmup.
    n = state.n_updates + 1
    valid = (jnp.arange(order + 1, dtype=jnp.int32) < n)
    derivs = jnp.where(valid.reshape(-1, *([1] * y.ndim)), derivs, 0)
    return TaylorState(derivs=derivs, n_updates=n)


def reuse_coefficients(order: int, k: jax.Array, interval: int,
                       mode: str = "taylor") -> jax.Array:
    """Reuse coefficients ``c_i`` for offset ``k`` -> f32 vector (order+1,).

    ``"taylor"`` (paper-faithful): ``c_i = kⁱ / (i!·𝒩ⁱ)``.
    ``"newton"`` (beyond-paper): Newton backward-difference extrapolation
    ``c_i = x(x+1)…(x+i−1)/i!`` with ``x = k/𝒩`` — exact for degree ≤ order.
    """
    x = jnp.asarray(k, jnp.float32) / float(interval)
    coeffs = []
    c = jnp.asarray(1.0, jnp.float32)
    for i in range(order + 1):
        coeffs.append(c)
        if mode == "taylor":
            c = c * x / (i + 1)
        elif mode == "newton":
            c = c * (x + i) / (i + 1)
        else:
            raise ValueError(f"unknown reuse mode: {mode}")
    return jnp.stack(coeffs)


def forecast(state: TaylorState, k: jax.Array, interval: int,
             mode: str = "taylor") -> jax.Array:
    """Forecast the feature ``k`` steps after the last update (OP_reuse)."""
    order = state.derivs.shape[0] - 1
    coef = reuse_coefficients(order, k, interval, mode)
    return jnp.tensordot(coef, state.derivs, axes=(0, 0))
