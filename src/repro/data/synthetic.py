"""Deterministic synthetic data pipeline.

Offline-reproducible streams for every model family: token LM batches,
audio-frame stubs, image-patch stubs and diffusion latents.  The stream is
a pure function of (seed, step) so a restarted job resumes bit-identically
from its checkpointed ``data_state`` — the fault-tolerance tests rely on
this property.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

__all__ = ["DataConfig", "DataState", "make_batch", "data_stream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 256


@dataclasses.dataclass
class DataState:
    step: int = 0

    def as_dict(self):
        return {"step": self.step}


def _tok_batch(cfg: ArchConfig, dcfg: DataConfig, step: int) -> dict:
    rng = np.random.default_rng(dcfg.seed * 1_000_003 + step)
    # Markov-ish synthetic text: mixture of ngram repetition + noise gives a
    # learnable signal (loss decreases) without any external data.
    base = rng.integers(0, cfg.vocab, size=(dcfg.batch, dcfg.seq_len + 1))
    period = 1 + (step % 7)
    base[:, period:] = np.where(
        rng.random((dcfg.batch, dcfg.seq_len + 1 - period)) < 0.7,
        base[:, :-period], base[:, period:])
    tokens = jnp.asarray(base[:, :-1], jnp.int32)
    labels = jnp.asarray(base[:, 1:], jnp.int32)
    return {"tokens": tokens, "labels": labels}


def make_batch(cfg: ArchConfig, dcfg: DataConfig, step: int) -> dict:
    """One batch for arch family at ``step`` (pure function of inputs)."""
    rng = np.random.default_rng(dcfg.seed * 7_000_003 + step)
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        return _tok_batch(cfg, dcfg, step)
    if cfg.family == "encdec":
        b = _tok_batch(cfg, dcfg, step)
        b["frames"] = jnp.asarray(
            rng.standard_normal((dcfg.batch, cfg.encoder_len, cfg.d_model)),
            jnp.float32)
        return b
    if cfg.family == "vlm":
        b = _tok_batch(cfg, dcfg, step)
        b["patches"] = jnp.asarray(
            rng.standard_normal((dcfg.batch, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
        return b
    if cfg.family == "dit":
        nv = dcfg.seq_len
        lat = rng.standard_normal((dcfg.batch, nv, cfg.patch_dim))
        noise = rng.standard_normal((dcfg.batch, nv, cfg.patch_dim))
        t = rng.random((dcfg.batch,))
        xt = (1 - t)[:, None, None] * noise + t[:, None, None] * lat
        emb = rng.standard_normal((cfg.patch_dim, cfg.d_model)) * 0.2
        return {
            "latents": jnp.asarray(lat, jnp.float32),
            "noise": jnp.asarray(noise, jnp.float32),
            "patch_emb": jnp.asarray(xt @ emb, jnp.float32),
            "text_emb": jnp.asarray(
                rng.standard_normal((dcfg.batch, max(cfg.n_text_tokens, 1),
                                     cfg.d_model)), jnp.float32),
            "t": jnp.asarray(t, jnp.float32),
        }
    raise ValueError(cfg.family)


def data_stream(cfg: ArchConfig, dcfg: DataConfig,
                start_step: int = 0) -> Iterator[tuple[int, dict]]:
    step = start_step
    while True:
        yield step, make_batch(cfg, dcfg, step)
        step += 1
