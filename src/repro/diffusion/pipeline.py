"""Text-to-vision diffusion pipeline driving the FlashOmni engine.

Rectified-flow Euler sampler: x_{t+dt} = x_t + v_θ(x_t, t)·dt, t: 0 → 1.

The Update–Dispatch schedule (paper §3.2) is TRACED DATA: the engine
config resolves into a :class:`~repro.core.schedule.SparsitySchedule`
(per-step mode array + (step × layer) strategy-id table) and the whole
denoise loop compiles ONCE — a single ``lax.scan`` over steps whose body
``lax.switch``es on the schedule's mode (dense / update / dispatch) and
threads each step's strategy-id row through the scanned DiT blocks.  One
executable per sampling configuration, regardless of step count, schedule
mix, or per-layer deployment tables (enforced by the compile-count test in
``tests/test_schedule.py``).

The pipeline reports the paper's efficiency accounting per step: density
(fraction of live attention work, Fig. 7), sparsity (skip/total, Table 1)
and the attention-FLOP reduction the benchmarks consume.  Metrics
accumulate on device as scan outputs; one host sync after the loop
materializes the whole trace.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.engine import (EngineConfig, merge_lane_states,
                               resolve_schedule, schedule_cache_stats)
from repro.core.lru import LruCache
from repro.core.strategy import strategy_key
from repro.core.symbols import unpack_bits
from repro.models import dit

__all__ = ["SamplerConfig", "sample", "make_lane_tick",
           "make_grouped_lane_tick", "step_density", "pair_sparsity"]


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    num_steps: int = 50
    dtype: Any = jnp.float32


def _density_device(states, ecfg: EngineConfig, n_tokens: int) -> jax.Array:
    """Fig. 7 density as a DEVICE scalar (no host sync)."""
    t = ecfg.mask.n_blocks(n_tokens)
    m_c = unpack_bits(states.s_c, t)             # (L, B, H, T)
    return jnp.mean(m_c.astype(jnp.float32))


def _pair_sparsity_device(states, ecfg: EngineConfig, n_tokens: int) -> jax.Array:
    t = ecfg.mask.n_blocks(n_tokens)
    m_c = unpack_bits(states.s_c, t)
    m_s = unpack_bits(states.s_s, t * t).reshape(*states.s_s.shape[:-1], t, t)
    live = m_s & m_c[..., None]
    return 1.0 - jnp.mean(live.astype(jnp.float32))


def step_density(states, cfg: ArchConfig, ecfg: EngineConfig, n_tokens: int) -> float:
    """Fig. 7 density: fraction of (q-block, head) work still live."""
    return float(_density_device(states, ecfg, n_tokens))


def pair_sparsity(states, cfg: ArchConfig, ecfg: EngineConfig, n_tokens: int) -> float:
    """Paper 'Sparsity' metric: skipped (Q_i K_j, P_ij V_j) pairs / total —
    combines feature caching (dead rows) and block-sparse skipping."""
    return float(_pair_sparsity_device(states, ecfg, n_tokens))


# Compiled single-scan samplers, keyed on every static of the trace (model /
# engine / sampler configs, shapes, metric mode, schedule strategy
# identities — stable across calls because resolve_schedule memoizes).  A
# second request with the same configuration reuses the first one's
# executable.  LRU-BOUNDED: a long-running server cycling through distinct
# request shapes/schedules evicts the least-recently-served sampler (and
# its pinned strategy tuple) instead of growing without limit; hit/miss
# counters surface through ``stats["sampler_cache"]``.
_SAMPLER_CACHE_SIZE = 32
_SAMPLER_CACHE = LruCache(_SAMPLER_CACHE_SIZE)


def sample(params, cfg: ArchConfig, ecfg: EngineConfig, *,
           text_emb: jax.Array, x0: jax.Array, scfg: SamplerConfig = SamplerConfig(),
           patch_embed: Optional[jax.Array] = None,
           trace: Optional[list] = None,
           force_dense: bool = False,
           layer_strategies: Optional[list] = None,
           schedule=None,
           stats: Optional[dict] = None):
    """Run the full sampling loop.  x0: (B, N_v, patch_dim) Gaussian noise.

    The schedule is resolved ONCE on the host
    (:func:`repro.core.engine.resolve_schedule`: ``schedule`` — a named
    preset or prebuilt :class:`~repro.core.schedule.SparsitySchedule` —
    wins over ``layer_strategies`` wins over ``ecfg.schedule`` /
    ``ecfg.strategy``), then the entire denoise loop runs as one jitted
    ``lax.scan`` over ``(step, mode, strategy-id row)``.

    ``patch_embed``: (patch_dim, d_model) stub patchifier.  Returns the
    denoised latents (B, N_v, patch_dim).  ``trace`` (a list) receives one
    ``{step, kind, density, pair_sparsity}`` dict per step; ``stats`` (a
    dict) receives ``executables`` (compiled-executable count for this
    call — exactly 1), ``schedule`` (the resolved schedule) and the
    ``sampler_cache`` / ``schedule_cache`` hit/miss/eviction counters of
    the two LRU-bounded serving memos.
    """
    b, nv, pd = x0.shape
    n_tokens = nv + text_emb.shape[1]
    n_steps = scfg.num_steps
    states = dit.init_engine_states(cfg, ecfg, b, n_tokens)
    if patch_embed is None:
        patch_embed = jax.random.normal(jax.random.PRNGKey(7), (pd, cfg.d_model)) * 0.2

    sched = resolve_schedule(ecfg, n_steps, cfg.n_layers, schedule=schedule,
                             layer_strategies=layer_strategies,
                             force_dense=force_dense)
    with_metrics = trace is not None
    dt = 1.0 / n_steps

    def build():
        def step_fn(mode: str):
            def f(params, states, xe, te, t, row, i):
                kw = {}
                if mode == "update":
                    kw = dict(strategies=sched.strategies, strategy_row=row,
                              step_idx=i, num_steps=n_steps)
                return dit.denoise_step(params, cfg, ecfg, states, xe, te, t,
                                        mode=mode, dtype=scfg.dtype, **kw)
            return f

        branches = [step_fn("dense"), step_fn("update"), step_fn("dispatch")]

        def body(params, patch_embed, text_emb, carry, xs):
            x, states = carry
            i, mode, row = xs
            t = (jnp.full((b,), i, jnp.float32) * dt).astype(scfg.dtype)
            xe = (x @ patch_embed).astype(scfg.dtype)
            v, states = jax.lax.switch(mode, branches, params, states, xe,
                                       text_emb, t, row, i)
            x = x + v.astype(x.dtype) * dt
            ys = ((_density_device(states, ecfg, n_tokens),
                   _pair_sparsity_device(states, ecfg, n_tokens))
                  if with_metrics else None)
            return (x, states), ys

        def run(params, x0, states, text_emb, patch_embed, mode_arr, id_table):
            steps = jnp.arange(n_steps, dtype=jnp.int32)
            (x, states), ys = jax.lax.scan(
                lambda c, xs: body(params, patch_embed, text_emb, c, xs),
                (x0, states), (steps, mode_arr, id_table))
            return x, ys

        return jax.jit(run)

    key = (cfg, ecfg, scfg, n_steps, with_metrics, b, nv, pd,
           text_emb.shape[1], x0.dtype, text_emb.dtype, patch_embed.dtype,
           tuple(strategy_key(s) for s in sched.strategies))
    entry = _SAMPLER_CACHE.get(key)
    if entry is None:
        # Registry strategies key by VALUE (strategy_key), so a schedule
        # re-resolved after an LRU eviction of the resolve_schedule memo
        # still HITS this cache; ad-hoc strategies key by id() and pin
        # their strategies tuple alive next to the compiled fn so the id
        # can never alias a recycled object.
        entry = _SAMPLER_CACHE.put(key, (build(), sched.strategies))
    fn = entry[0]
    x, ys = fn(params, x0, states, text_emb, patch_embed, sched.mode,
               sched.strategy_ids)
    if stats is not None:
        cache_size = getattr(fn, "_cache_size", None)
        stats["executables"] = int(cache_size()) if cache_size else -1
        stats["schedule"] = sched
        stats["sampler_cache"] = _SAMPLER_CACHE.stats()
        stats["schedule_cache"] = schedule_cache_stats()
    if with_metrics:
        kinds = sched.kinds()
        dens, pair_s = jax.device_get(ys)      # ONE host sync for the trace
        for i in range(n_steps):
            trace.append({"step": i, "kind": kinds[i],
                          "density": float(dens[i]),
                          "pair_sparsity": float(pair_s[i])})
    return x


def make_lane_tick(cfg: ArchConfig, ecfg: EngineConfig,
                   scfg: SamplerConfig, strategies: tuple,
                   with_metrics: bool = True):
    """Build the continuous batcher's lane-serial serving tick (fallback).

    One tick advances every lane of a fixed-width microbatch by ONE
    denoising step.  The tick body is a ``lax.scan`` over the LANE axis
    whose body selects each lane's ``(mode, strategy-id row)`` from the
    lane's OWN traced schedule table at the lane's own step counter
    (``SparsitySchedule``s of different lengths pad with ``MODE_IDLE`` —
    see :func:`repro.core.schedule.stack_schedules`), then ``lax.switch``es
    into the same dense/update/dispatch trace bodies as :func:`sample` —
    per-lane numerics are bit-identical to a sequential run of the same
    request (the acceptance criterion of the serving benchmark), because
    each lane body executes exactly the single-request op sequence at the
    single-request shapes.  Mode-HOMOGENEOUS ticks should instead run a
    batched mode body from :func:`make_grouped_lane_tick` (lane
    parallelism on the batch axis); this scan handles the genuinely mixed
    remainders, where the per-lane ``lax.switch`` is unavoidable.

    The returned function is jitted ONCE per lane shape — lanes retire
    and refill by swapping traced data (tables, step counters, state
    slices), never by re-tracing:

        tick(params, patch_embed, x, states, text_emb, step, mode_tab,
             id_tab, dt, nsteps, active, reset) -> (x', states', density,
                                                    pair_sparsity)

    with ``x`` (lanes, B, N_v, patch_dim); ``states`` lane-stacked engine
    states (:func:`repro.core.engine.stack_lane_states`); ``text_emb``
    (lanes, B, N_t, d_model); ``step`` (lanes,) int32 per-lane step
    counters; ``mode_tab`` (lanes, S) / ``id_tab`` (lanes, S, L) the
    stacked schedule tables; ``dt`` (lanes,) f32 per-lane 1/num_steps;
    ``nsteps`` (lanes,) int32 per-lane TOTAL step counts — threaded into
    ``StrategyContext.num_steps`` as a traced scalar so schedule-varying
    producers (``step-phased`` fractional boundaries) behave exactly as
    under ``pipeline.sample``; ``active`` (lanes,) bool; ``reset``
    (lanes,) bool — True for lanes REFILLED since the last tick, whose
    engine state is re-initialized ON DEVICE before stepping (the fresh
    state is a trace constant, so refill costs zero host-side state
    dispatches — only the lane's latent/text buffers are host-written).
    Idle lanes (``active`` false or table padding) run a no-op branch:
    latents/state pass through and their metric outputs are EXACTLY zero.

    ``with_metrics=False`` skips the per-lane density/pair-sparsity
    reductions (the outputs are zeros) — the pure-throughput serving
    configuration; it is a trace-time static, part of the tick key.
    """
    from repro.core.schedule import MODE_IDLE

    def tick(params, patch_embed, x, states, text_emb, step, mode_tab,
             id_tab, dt, nsteps, active, reset):
        b = x.shape[1]
        n_tokens = x.shape[2] + text_emb.shape[2]
        fresh = dit.init_engine_states(cfg, ecfg, b, n_tokens)

        def branch(mode: str):
            def f(x, st, xe, te, t, row, i, dts, ns):
                kw = {}
                if mode == "update":
                    kw = dict(strategies=strategies, strategy_row=row,
                              step_idx=i, num_steps=ns)
                v, st2 = dit.denoise_step(params, cfg, ecfg, st, xe, te, t,
                                          mode=mode, dtype=scfg.dtype, **kw)
                # dts is a STRONG f32 scalar (sample()'s dt is a weak
                # Python float): cast to x.dtype so non-f32 latents are
                # not promoted — the tick's output dtype must equal its
                # input dtype or the next tick recompiles.
                x2 = x + v.astype(x.dtype) * dts.astype(x.dtype)
                if not with_metrics:
                    return (x2, st2, jnp.zeros((), jnp.float32),
                            jnp.zeros((), jnp.float32))
                return (x2, st2, _density_device(st2, ecfg, n_tokens),
                        _pair_sparsity_device(st2, ecfg, n_tokens))
            return f

        def idle(x, st, xe, te, t, row, i, dts, ns):
            return (x, st, jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.float32))

        branches = [branch("dense"), branch("update"), branch("dispatch"),
                    idle]

        def lane(_, xs):
            x, st, te, i, mrow, irow, dts, ns, act, rst = xs
            # Freshly refilled lane: re-initialize its engine state from
            # the trace-constant init tree before stepping.
            st = jax.tree.map(
                lambda s, f: jnp.where(rst, f.astype(s.dtype), s), st, fresh)
            ic = jnp.clip(i, 0, mrow.shape[0] - 1)
            mode = jnp.where(act, mrow[ic], MODE_IDLE)
            t = (jnp.full((b,), i, jnp.float32) * dts).astype(scfg.dtype)
            xe = (x @ patch_embed).astype(scfg.dtype)
            out = jax.lax.switch(mode, branches, x, st, xe, te, t, irow[ic],
                                 i, dts, ns)
            return None, out

        _, (x2, st2, dens, ps) = jax.lax.scan(
            lane, None,
            (x, states, text_emb, step, mode_tab, id_tab, dt, nsteps,
             active, reset))
        return x2, st2, dens, ps

    return jax.jit(tick)


def make_grouped_lane_tick(cfg: ArchConfig, ecfg: EngineConfig,
                           scfg: SamplerConfig, strategies: tuple,
                           with_metrics: bool = True):
    """Build the batched MODE-GROUP serving ticks (same-mode lane folding).

    The continuous batcher's lane tables are host-visible, so before
    launching a tick the host knows every lane's ``(mode, strategy-id
    row)`` (:func:`repro.core.schedule.tick_mode_groups`).  When every
    active lane is in the SAME mode, the lane scan's per-lane
    ``lax.switch`` is pure overhead — the tick is one batched
    dense/update/dispatch step over the lanes folded into the model's
    batch axis.  This factory returns ``{"dense", "update", "dispatch"}``
    → jitted group bodies, each:

        body(params, patch_embed, x, states, text_emb, step, id_rows, dt,
             nsteps, lane_mask, reset) -> (x', states', density,
                                           pair_sparsity)

    Arguments match :func:`make_lane_tick` except the schedule tables are
    replaced by the CURRENT-step slice: ``id_rows`` (lanes, L) int32 — the
    per-lane strategy-id rows at each lane's own step (update body only;
    dense/dispatch ignore them) — and ``lane_mask`` (lanes,) bool selects
    the group.  The body ``jax.vmap``s the single-lane step over the lane
    axis — every per-sample op is the batch-axis fold of the sequential
    op sequence (the stacked-serving bit-parity guarantee), and per-lane
    traced context (step counter, ``dt``, ``num_steps``, TaylorSeer
    ``k_since`` offsets, strategy-id rows) batches with it; per-lane
    outputs stay BIT-identical to sequential runs.  Lanes outside
    ``lane_mask`` are computed (the executable's shape is lane-count
    fixed, never group-sized) and then discarded by a masked lane merge
    (:func:`repro.core.engine.merge_lane_states`): latents/state pass
    through and metrics are EXACTLY zero, the same contract as the scan
    tick's idle branch.

    Each body is jitted ONCE per lane shape; with the scan fallback that
    is a fixed, shape-independent executable budget of ≤ 4 per lane shape
    (dense / update / dispatch / mixed-fallback), regardless of schedule
    variety, group sizes, or how lanes retire and refill.  Strategy-id
    rows are TRACED, so two update groups with different rows are two
    CALLS of one executable; a heterogeneous row mix inside one update
    group is legal too (``emit_switch``'s ``lax.switch`` batches into an
    all-branch select under ``vmap`` — bit-exact, at the cost of running
    every emitter) — the batcher only folds same-mode lanes, which keeps
    the common homogeneous tick on the cheap path.
    """

    def make(mode: str):
        def body(params, patch_embed, x, states, text_emb, step, id_rows,
                 dt, nsteps, lane_mask, reset):
            b = x.shape[1]
            n_tokens = x.shape[2] + text_emb.shape[2]
            lanes = x.shape[0]
            fresh = jax.tree.map(
                lambda f: jnp.broadcast_to(f, (lanes, *f.shape)),
                dit.init_engine_states(cfg, ecfg, b, n_tokens))
            states = merge_lane_states(states, fresh, reset)

            def lane(x_l, st_l, te_l, i, row, dts, ns):
                t = (jnp.full((b,), i, jnp.float32) * dts).astype(scfg.dtype)
                xe = (x_l @ patch_embed).astype(scfg.dtype)
                kw = {}
                if mode == "update":
                    kw = dict(strategies=strategies, strategy_row=row,
                              step_idx=i, num_steps=ns)
                v, st2 = dit.denoise_step(params, cfg, ecfg, st_l, xe, te_l,
                                          t, mode=mode, dtype=scfg.dtype,
                                          **kw)
                x2 = x_l + v.astype(x_l.dtype) * dts.astype(x_l.dtype)
                if not with_metrics:
                    return (x2, st2, jnp.zeros((), jnp.float32),
                            jnp.zeros((), jnp.float32))
                return (x2, st2, _density_device(st2, ecfg, n_tokens),
                        _pair_sparsity_device(st2, ecfg, n_tokens))

            x2, st2, dens, ps = jax.vmap(lane)(x, states, text_emb, step,
                                               id_rows, dt, nsteps)
            x_out = merge_lane_states(x, x2, lane_mask)
            st_out = merge_lane_states(states, st2, lane_mask)
            zero = jnp.zeros((), jnp.float32)
            return (x_out, st_out, jnp.where(lane_mask, dens, zero),
                    jnp.where(lane_mask, ps, zero))

        return jax.jit(body)

    return {"dense": make("dense"), "update": make("update"),
            "dispatch": make("dispatch")}
