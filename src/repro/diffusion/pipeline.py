"""Text-to-vision diffusion pipeline driving the FlashOmni engine.

Rectified-flow Euler sampler: x_{t+dt} = x_t + v_θ(x_t, t)·dt, t: 0 → 1.

The Update–Dispatch schedule (paper §3.2) is TRACED DATA: the engine
config resolves into a :class:`~repro.core.schedule.SparsitySchedule`
(per-step mode array + (step × layer) strategy-id table) and the whole
denoise loop compiles ONCE — a single ``lax.scan`` over steps whose body
``lax.switch``es on the schedule's mode (dense / update / dispatch) and
threads each step's strategy-id row through the scanned DiT blocks.  One
executable per sampling configuration, regardless of step count, schedule
mix, or per-layer deployment tables (enforced by the compile-count test in
``tests/test_schedule.py``).

The pipeline reports the paper's efficiency accounting per step: density
(fraction of live attention work, Fig. 7), sparsity (skip/total, Table 1)
and the attention-FLOP reduction the benchmarks consume.  Metrics
accumulate on device as scan outputs; one host sync after the loop
materializes the whole trace.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.engine import (EngineConfig, resolve_schedule,
                               schedule_cache_stats)
from repro.core.lru import LruCache
from repro.core.symbols import unpack_bits
from repro.models import dit

__all__ = ["SamplerConfig", "sample", "make_lane_tick", "step_density",
           "pair_sparsity"]


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    num_steps: int = 50
    dtype: Any = jnp.float32


def _density_device(states, ecfg: EngineConfig, n_tokens: int) -> jax.Array:
    """Fig. 7 density as a DEVICE scalar (no host sync)."""
    t = ecfg.mask.n_blocks(n_tokens)
    m_c = unpack_bits(states.s_c, t)             # (L, B, H, T)
    return jnp.mean(m_c.astype(jnp.float32))


def _pair_sparsity_device(states, ecfg: EngineConfig, n_tokens: int) -> jax.Array:
    t = ecfg.mask.n_blocks(n_tokens)
    m_c = unpack_bits(states.s_c, t)
    m_s = unpack_bits(states.s_s, t * t).reshape(*states.s_s.shape[:-1], t, t)
    live = m_s & m_c[..., None]
    return 1.0 - jnp.mean(live.astype(jnp.float32))


def step_density(states, cfg: ArchConfig, ecfg: EngineConfig, n_tokens: int) -> float:
    """Fig. 7 density: fraction of (q-block, head) work still live."""
    return float(_density_device(states, ecfg, n_tokens))


def pair_sparsity(states, cfg: ArchConfig, ecfg: EngineConfig, n_tokens: int) -> float:
    """Paper 'Sparsity' metric: skipped (Q_i K_j, P_ij V_j) pairs / total —
    combines feature caching (dead rows) and block-sparse skipping."""
    return float(_pair_sparsity_device(states, ecfg, n_tokens))


# Compiled single-scan samplers, keyed on every static of the trace (model /
# engine / sampler configs, shapes, metric mode, schedule strategy
# identities — stable across calls because resolve_schedule memoizes).  A
# second request with the same configuration reuses the first one's
# executable.  LRU-BOUNDED: a long-running server cycling through distinct
# request shapes/schedules evicts the least-recently-served sampler (and
# its pinned strategy tuple) instead of growing without limit; hit/miss
# counters surface through ``stats["sampler_cache"]``.
_SAMPLER_CACHE_SIZE = 32
_SAMPLER_CACHE = LruCache(_SAMPLER_CACHE_SIZE)


def sample(params, cfg: ArchConfig, ecfg: EngineConfig, *,
           text_emb: jax.Array, x0: jax.Array, scfg: SamplerConfig = SamplerConfig(),
           patch_embed: Optional[jax.Array] = None,
           trace: Optional[list] = None,
           force_dense: bool = False,
           layer_strategies: Optional[list] = None,
           schedule=None,
           stats: Optional[dict] = None):
    """Run the full sampling loop.  x0: (B, N_v, patch_dim) Gaussian noise.

    The schedule is resolved ONCE on the host
    (:func:`repro.core.engine.resolve_schedule`: ``schedule`` — a named
    preset or prebuilt :class:`~repro.core.schedule.SparsitySchedule` —
    wins over ``layer_strategies`` wins over ``ecfg.schedule`` /
    ``ecfg.strategy``), then the entire denoise loop runs as one jitted
    ``lax.scan`` over ``(step, mode, strategy-id row)``.

    ``patch_embed``: (patch_dim, d_model) stub patchifier.  Returns the
    denoised latents (B, N_v, patch_dim).  ``trace`` (a list) receives one
    ``{step, kind, density, pair_sparsity}`` dict per step; ``stats`` (a
    dict) receives ``executables`` (compiled-executable count for this
    call — exactly 1), ``schedule`` (the resolved schedule) and the
    ``sampler_cache`` / ``schedule_cache`` hit/miss/eviction counters of
    the two LRU-bounded serving memos.
    """
    b, nv, pd = x0.shape
    n_tokens = nv + text_emb.shape[1]
    n_steps = scfg.num_steps
    states = dit.init_engine_states(cfg, ecfg, b, n_tokens)
    if patch_embed is None:
        patch_embed = jax.random.normal(jax.random.PRNGKey(7), (pd, cfg.d_model)) * 0.2

    sched = resolve_schedule(ecfg, n_steps, cfg.n_layers, schedule=schedule,
                             layer_strategies=layer_strategies,
                             force_dense=force_dense)
    with_metrics = trace is not None
    dt = 1.0 / n_steps

    def build():
        def step_fn(mode: str):
            def f(params, states, xe, te, t, row, i):
                kw = {}
                if mode == "update":
                    kw = dict(strategies=sched.strategies, strategy_row=row,
                              step_idx=i, num_steps=n_steps)
                return dit.denoise_step(params, cfg, ecfg, states, xe, te, t,
                                        mode=mode, dtype=scfg.dtype, **kw)
            return f

        branches = [step_fn("dense"), step_fn("update"), step_fn("dispatch")]

        def body(params, patch_embed, text_emb, carry, xs):
            x, states = carry
            i, mode, row = xs
            t = (jnp.full((b,), i, jnp.float32) * dt).astype(scfg.dtype)
            xe = (x @ patch_embed).astype(scfg.dtype)
            v, states = jax.lax.switch(mode, branches, params, states, xe,
                                       text_emb, t, row, i)
            x = x + v.astype(x.dtype) * dt
            ys = ((_density_device(states, ecfg, n_tokens),
                   _pair_sparsity_device(states, ecfg, n_tokens))
                  if with_metrics else None)
            return (x, states), ys

        def run(params, x0, states, text_emb, patch_embed, mode_arr, id_table):
            steps = jnp.arange(n_steps, dtype=jnp.int32)
            (x, states), ys = jax.lax.scan(
                lambda c, xs: body(params, patch_embed, text_emb, c, xs),
                (x0, states), (steps, mode_arr, id_table))
            return x, ys

        return jax.jit(run)

    key = (cfg, ecfg, scfg, n_steps, with_metrics, b, nv, pd,
           text_emb.shape[1], x0.dtype, text_emb.dtype, patch_embed.dtype,
           tuple(id(s) for s in sched.strategies))
    entry = _SAMPLER_CACHE.get(key)
    if entry is None:
        # The strategies tuple is pinned alive next to its compiled fn so
        # the id()-based key can never alias a recycled object.
        entry = _SAMPLER_CACHE.put(key, (build(), sched.strategies))
    fn = entry[0]
    x, ys = fn(params, x0, states, text_emb, patch_embed, sched.mode,
               sched.strategy_ids)
    if stats is not None:
        cache_size = getattr(fn, "_cache_size", None)
        stats["executables"] = int(cache_size()) if cache_size else -1
        stats["schedule"] = sched
        stats["sampler_cache"] = _SAMPLER_CACHE.stats()
        stats["schedule_cache"] = schedule_cache_stats()
    if with_metrics:
        kinds = sched.kinds()
        dens, pair_s = jax.device_get(ys)      # ONE host sync for the trace
        for i in range(n_steps):
            trace.append({"step": i, "kind": kinds[i],
                          "density": float(dens[i]),
                          "pair_sparsity": float(pair_s[i])})
    return x


def make_lane_tick(cfg: ArchConfig, ecfg: EngineConfig,
                   scfg: SamplerConfig, strategies: tuple):
    """Build the continuous batcher's compiled serving tick.

    One tick advances every lane of a fixed-width microbatch by ONE
    denoising step.  The tick body is a ``lax.scan`` over the LANE axis
    whose body selects each lane's ``(mode, strategy-id row)`` from the
    lane's OWN traced schedule table at the lane's own step counter
    (``SparsitySchedule``s of different lengths pad with ``MODE_IDLE`` —
    see :func:`repro.core.schedule.stack_schedules`), then ``lax.switch``es
    into the same dense/update/dispatch trace bodies as :func:`sample` —
    per-lane numerics are bit-identical to a sequential run of the same
    request (the acceptance criterion of the serving benchmark), because
    each lane body executes exactly the single-request op sequence at the
    single-request shapes.

    The returned function is jitted ONCE per lane shape — lanes retire
    and refill by swapping traced data (tables, step counters, state
    slices), never by re-tracing:

        tick(params, patch_embed, x, states, text_emb, step, mode_tab,
             id_tab, dt, active) -> (x', states', density, pair_sparsity)

    with ``x`` (lanes, B, N_v, patch_dim); ``states`` lane-stacked engine
    states (:func:`repro.core.engine.stack_lane_states`); ``text_emb``
    (lanes, B, N_t, d_model); ``step`` (lanes,) int32 per-lane step
    counters; ``mode_tab`` (lanes, S) / ``id_tab`` (lanes, S, L) the
    stacked schedule tables; ``dt`` (lanes,) f32 per-lane 1/num_steps;
    ``active`` (lanes,) bool.  Idle lanes (``active`` false or table
    padding) run a no-op branch: latents/state pass through and their
    metric outputs are EXACTLY zero.

    ``StrategyContext.num_steps`` is ``None`` inside the tick (lanes mix
    step counts, so there is no static schedule length): strategies whose
    emit needs it statically — ``step-phased`` with FRACTIONAL boundaries
    — raise at trace time; use absolute step boundaries under the batcher.
    """
    from repro.core.schedule import MODE_IDLE

    def tick(params, patch_embed, x, states, text_emb, step, mode_tab,
             id_tab, dt, active):
        b = x.shape[1]
        n_tokens = x.shape[2] + text_emb.shape[2]

        def branch(mode: str):
            def f(x, st, xe, te, t, row, i, dts):
                kw = {}
                if mode == "update":
                    kw = dict(strategies=strategies, strategy_row=row,
                              step_idx=i, num_steps=None)
                v, st2 = dit.denoise_step(params, cfg, ecfg, st, xe, te, t,
                                          mode=mode, dtype=scfg.dtype, **kw)
                # dts is a STRONG f32 scalar (sample()'s dt is a weak
                # Python float): cast to x.dtype so non-f32 latents are
                # not promoted — the tick's output dtype must equal its
                # input dtype or the next tick recompiles.
                x2 = x + v.astype(x.dtype) * dts.astype(x.dtype)
                return (x2, st2, _density_device(st2, ecfg, n_tokens),
                        _pair_sparsity_device(st2, ecfg, n_tokens))
            return f

        def idle(x, st, xe, te, t, row, i, dts):
            return (x, st, jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.float32))

        branches = [branch("dense"), branch("update"), branch("dispatch"),
                    idle]

        def lane(_, xs):
            x, st, te, i, mrow, irow, dts, act = xs
            ic = jnp.clip(i, 0, mrow.shape[0] - 1)
            mode = jnp.where(act, mrow[ic], MODE_IDLE)
            t = (jnp.full((b,), i, jnp.float32) * dts).astype(scfg.dtype)
            xe = (x @ patch_embed).astype(scfg.dtype)
            out = jax.lax.switch(mode, branches, x, st, xe, te, t, irow[ic],
                                 i, dts)
            return None, out

        _, (x2, st2, dens, ps) = jax.lax.scan(
            lane, None,
            (x, states, text_emb, step, mode_tab, id_tab, dt, active))
        return x2, st2, dens, ps

    return jax.jit(tick)
