"""Text-to-vision diffusion pipeline driving the FlashOmni engine.

Rectified-flow Euler sampler: x_{t+dt} = x_t + v_θ(x_t, t)·dt, t: 0 → 1.
The Update–Dispatch schedule (paper §3.2) is a Python-level decision per
step — Update steps compile once, Dispatch steps compile once; symbols and
TaylorSeer caches flow through the jitted functions as state pytrees.

The pipeline reports the paper's efficiency accounting per step: density
(fraction of live attention work, Fig. 7), sparsity (skip/total, Table 1)
and the attention-FLOP reduction the benchmarks consume.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.engine import EngineConfig, is_update_step
from repro.core.symbols import unpack_bits
from repro.models import dit

__all__ = ["SamplerConfig", "sample", "step_density", "pair_sparsity"]


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    num_steps: int = 50
    dtype: Any = jnp.float32


def _density_device(states, ecfg: EngineConfig, n_tokens: int) -> jax.Array:
    """Fig. 7 density as a DEVICE scalar (no host sync)."""
    t = ecfg.mask.n_blocks(n_tokens)
    m_c = unpack_bits(states.s_c, t)             # (L, B, H, T)
    return jnp.mean(m_c.astype(jnp.float32))


def _pair_sparsity_device(states, ecfg: EngineConfig, n_tokens: int) -> jax.Array:
    t = ecfg.mask.n_blocks(n_tokens)
    m_c = unpack_bits(states.s_c, t)
    m_s = unpack_bits(states.s_s, t * t).reshape(*states.s_s.shape[:-1], t, t)
    live = m_s & m_c[..., None]
    return 1.0 - jnp.mean(live.astype(jnp.float32))


def step_density(states, cfg: ArchConfig, ecfg: EngineConfig, n_tokens: int) -> float:
    """Fig. 7 density: fraction of (q-block, head) work still live."""
    return float(_density_device(states, ecfg, n_tokens))


def pair_sparsity(states, cfg: ArchConfig, ecfg: EngineConfig, n_tokens: int) -> float:
    """Paper 'Sparsity' metric: skipped (Q_i K_j, P_ij V_j) pairs / total —
    combines feature caching (dead rows) and block-sparse skipping."""
    return float(_pair_sparsity_device(states, ecfg, n_tokens))


def sample(params, cfg: ArchConfig, ecfg: EngineConfig, *,
           text_emb: jax.Array, x0: jax.Array, scfg: SamplerConfig = SamplerConfig(),
           patch_embed: Optional[jax.Array] = None,
           trace: Optional[list] = None,
           force_dense: bool = False,
           layer_strategies: Optional[list] = None):
    """Run the full sampling loop.  x0: (B, N_v, patch_dim) Gaussian noise.

    ``patch_embed``: (patch_dim, d_model) stub patchifier.  Returns the
    denoised latents (B, N_v, patch_dim).  ``layer_strategies`` threads a
    per-layer sparse-symbol producer table into every Update step (see
    :func:`repro.models.dit.denoise_step`).
    """
    b, nv, pd = x0.shape
    n_tokens = nv + text_emb.shape[1]
    states = dit.init_engine_states(cfg, ecfg, b, n_tokens)
    if patch_embed is None:
        patch_embed = jax.random.normal(jax.random.PRNGKey(7), (pd, cfg.d_model)) * 0.2

    upd = jax.jit(lambda p, s, xv, te, t: dit.denoise_step(
        p, cfg, ecfg, s, xv, te, t, mode="update", dtype=scfg.dtype,
        layer_strategies=layer_strategies))
    dsp = jax.jit(lambda p, s, xv, te, t: dit.denoise_step(
        p, cfg, ecfg, s, xv, te, t, mode="dispatch", dtype=scfg.dtype,
        layer_strategies=layer_strategies))
    dns = jax.jit(lambda p, s, xv, te, t: dit.denoise_step(
        p, cfg, ecfg, s, xv, te, t, mode="dense", dtype=scfg.dtype))
    # Per-step efficiency metrics stay ON DEVICE during the loop; a single
    # host sync after the last step materializes the whole trace (a
    # per-step ``float(...)`` would serialize the async dispatch pipeline).
    met = jax.jit(lambda s: (_density_device(s, ecfg, n_tokens),
                             _pair_sparsity_device(s, ecfg, n_tokens)))

    x = x0
    dt = 1.0 / scfg.num_steps
    pending: list = []
    for i in range(scfg.num_steps):
        t = jnp.full((b,), i * dt, scfg.dtype)
        xe = (x @ patch_embed).astype(scfg.dtype)
        if force_dense:
            v, states = dns(params, states, xe, text_emb, t)
            kind = "dense"
        elif is_update_step(i, ecfg):
            v, states = upd(params, states, xe, text_emb, t)
            kind = "update"
        else:
            v, states = dsp(params, states, xe, text_emb, t)
            kind = "dispatch"
        if trace is not None:
            pending.append((i, kind, met(states)))
        x = x + v.astype(x.dtype) * dt
    if trace is not None:
        for i, kind, (dens, pair_s) in pending:
            trace.append({"step": i, "kind": kind,
                          "density": float(dens),
                          "pair_sparsity": float(pair_s)})
    return x
