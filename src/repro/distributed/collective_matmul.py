"""Collective matmul: overlap TP all-gather with the MXU (Wang et al.,
"Overlap communication with computation" — the classic 1D bidirectional
ppermute pipeline).

Baseline TP matmul on x sharded along the contraction or feature axis does
    all-gather(x) @ W        (ICI idle while MXU waits, then MXU idle)
This version decomposes the all-gather into P-1 ``ppermute`` steps and
multiplies the resident shard while the next shard is in flight:

    for step in range(P):
        y += x_shard @ W_slice[owner]
        x_shard = ppermute(x_shard)

Used as a §Perf hillclimb lever for the collective-bound cells; the unit
test checks bit-level agreement with the dense product on a host mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ag_matmul_overlapped"]


def ag_matmul_overlapped(x: jax.Array, w: jax.Array, mesh: Mesh, axis: str):
    """y = all_gather(x, axis) @ w, pipelined.

    x: (B, S/P, D) sharded on dim 1 over ``axis``; w: (D, F) replicated.
    Returns y (B, S, F) fully gathered (every device pipelined through all
    P shards, so outputs are replicated) — gather-on-sequence for
    attention-style consumers.
    """
    p = mesh.shape[axis]

    def body(x_shard, w_full):
        idx = jax.lax.axis_index(axis)
        s_loc = x_shard.shape[1]
        out = jnp.zeros((x_shard.shape[0], s_loc * p, w_full.shape[-1]),
                        jnp.promote_types(x_shard.dtype, w_full.dtype))
        perm = [(i, (i + 1) % p) for i in range(p)]

        def step(c, _):
            out, shard, owner = c
            y = jnp.einsum("bsd,df->bsf", shard, w_full)
            out = jax.lax.dynamic_update_slice_in_dim(
                out, y.astype(out.dtype), owner * s_loc, axis=1)
            shard = jax.lax.ppermute(shard, axis, perm)
            owner = (owner - 1) % p
            return (out, shard, owner), None

        (out, _, _), _ = jax.lax.scan(step, (out, x_shard, idx), None, length=p)
        return out

    from jax.experimental.shard_map import shard_map
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis, None), P(None, None)),
        out_specs=P(None, None, None),
        check_rep=False,
    )(x, w)
