"""Gradient compression for data-parallel all-reduce at 1000+ nodes.

Two schemes, both with ERROR FEEDBACK (the residual of the compression is
carried to the next step so the compressed optimizer converges to the same
point — Karimireddy et al. 2019):

  * ``int8``  — per-tensor symmetric quantization: 4× DP traffic reduction,
    unbiased within rounding.
  * ``topk``  — magnitude top-k sparsification (k = fraction of entries):
    10–100× reduction for gradient-sparse regimes.

Usage inside a train step (before the psum that DP inserts):
    comp, state = compress_tree(grads, state, scheme)
    grads = decompress_tree(comp)        # local decompress after all-reduce

The compress→allreduce→decompress pipeline is exercised in tests by
simulating N workers; on a real mesh the all-reduce happens on the
compressed payload via ``shard_map``.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress_int8", "decompress_int8",
           "compress_topk", "decompress_topk", "compress_tree",
           "decompress_tree"]


def init_error_state(tree: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), tree)


class Int8Grad(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # () f32


def compress_int8(g: jax.Array, err: jax.Array) -> tuple[Int8Grad, jax.Array]:
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return Int8Grad(q=q, scale=scale), new_err


def decompress_int8(c: Int8Grad) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


class TopKGrad(NamedTuple):
    values: jax.Array     # (k,) f32
    indices: jax.Array    # (k,) int32
    shape: tuple          # static


def compress_topk(g: jax.Array, err: jax.Array, frac: float = 0.05
                  ) -> tuple[TopKGrad, jax.Array]:
    gf = (g.astype(jnp.float32) + err).reshape(-1)
    k = max(1, int(gf.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(gf), k)
    picked = gf[idx]
    new_err = gf.at[idx].set(0.0).reshape(g.shape)
    return TopKGrad(values=picked, indices=idx.astype(jnp.int32),
                    shape=tuple(g.shape)), new_err


def decompress_topk(c: TopKGrad) -> jax.Array:
    n = 1
    for d in c.shape:
        n *= d
    out = jnp.zeros((n,), jnp.float32).at[c.indices].set(c.values)
    return out.reshape(c.shape)


def compress_tree(grads: Any, err_state: Any, scheme: str = "int8",
                  **kw) -> tuple[Any, Any]:
    """Compress every leaf; returns (compressed_tree, new_error_state)."""
    fn = {"int8": compress_int8,
          "topk": functools.partial(compress_topk, **kw)}[scheme]
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [fn(g, e) for g, e in zip(flat_g, flat_e)]
    comp = tdef.unflatten([o[0] for o in out])
    new_err = tdef.unflatten([o[1] for o in out])
    return comp, new_err


def decompress_tree(comp: Any) -> Any:
    def dec(c):
        if isinstance(c, Int8Grad):
            return decompress_int8(c)
        if isinstance(c, TopKGrad):
            return decompress_topk(c)
        raise TypeError(type(c))
    return jax.tree.map(dec, comp,
                        is_leaf=lambda x: isinstance(x, (Int8Grad, TopKGrad)))
