"""Activation-sharding hint context (§Perf iteration A2).

``with_sharding_constraint`` is the only reliable way to pin GSPMD's
propagation through loop/reshape boundaries — critically, the constraint
also transposes onto the BACKWARD cotangents, which is where the chunked
attention lost its batch sharding (replicated f32[global_batch, ...] temps
in ``transpose(jvp())``).

Model code calls ``constrain(x, "dp", None, ..., "tp")`` with LOGICAL axis
names; the step builders install the active rules here.  Outside a rules
context (unit tests, single-device runs) it is a no-op.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax

from repro.distributed.sharding import ShardingRules, logical_to_physical

_RULES: contextvars.ContextVar[Optional[ShardingRules]] = \
    contextvars.ContextVar("sharding_rules", default=None)

__all__ = ["activation_rules", "constrain"]


@contextlib.contextmanager
def activation_rules(rules: Optional[ShardingRules]):
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    rules = _RULES.get()
    if rules is None:
        return x
    spec = logical_to_physical(logical, rules)
    return jax.lax.with_sharding_constraint(x, spec)
