"""Plan-sharded mesh dispatch: per-shard CSR partitions + a plan-aware
collective schedule (ROADMAP direction 1 tentpole).

The paper's flagship Hunyuan cell (33K tokens) sits at a sequence length
where every production DiT engine goes multi-device (xDiT's USP: Ulysses
head-all-to-all + ring attention).  Torch engines ship DENSE collectives —
each shard all-gathers the full remote K/V regardless of sparsity.  Our
:class:`~repro.core.plan.DispatchPlan` already knows which KV blocks are
live per row, so the collectives here ship **only live blocks**: the
communication volume scales with density, extending the paper's
near-linear sparsity:speedup ratio across the network, not just the FLOPs.

Mesh model
----------
A ``(data, seq)`` mesh (:func:`repro.launch.mesh.make_engine_mesh`).  The
batch axis shards over ``data``.  The second axis runs one of two modes
(``EngineConfig.mesh_axis``):

* ``"head"`` — heads shard over ``seq``.  Attention is embarrassingly
  parallel per head; no collectives.  (Occupancy buckets fold the head
  axis into layout rows, so ``kv_buckets > 1`` is rejected here.)
  Bit parity holds on the Pallas backend (the kernel's flash accumulation
  order per (b, h) grid cell is shape-independent); the XLA backend is
  numerically equal but NOT bitwise — shrinking the head batch lets the
  compiler reassociate its reductions (observed max |Δ| ≈ 2e-8) — so the
  head-mode parity test pins Pallas bitwise and XLA to allclose.
* ``"seq"``  — tokens shard over ``seq``: K/V and the attention output
  live block-contiguously on their owner shard, Q stays replicated (it is
  already density-compacted, so its volume scales with sparsity).  This
  is the interesting mode; everything below describes it.

The plan-aware collective schedule
----------------------------------
All schedule tensors are computed at **Update** time inside
:func:`~repro.core.plan.build_dispatch_plan` (via :func:`partition_plan`)
and carried in the plan's ``shd_*`` fields — a Dispatch step's jaxpr stays
sort-free and consumes them verbatim, exactly like every other plan field.
Per (batch, head, destination shard ``p``):

1. **Row partition** — live q blocks are owned by ``q_id // q_bps``
   (``q_bps = T_q / P`` blocks per shard).  ``shd_q_ids`` / ``shd_q_src``
   / ``shd_q_slots`` / ``shd_q_cnt`` list shard ``p``'s live rows in the
   local / full / compact layouts (capacity ``min(cap_q, q_bps)``; the
   partition of a capacity-clamped set never truncates).
2. **Union + pair clamp** — the union of the rows' (truncation-folded) KV
   lists, split by owner shard ``s``, forms contiguous ascending runs.
   Each remote run is capped at ``pair_cap ≈ ⌈slack · cap_kv / P⌉``
   (``EngineConfig.mesh_pair_slack``); overflow is dropped lowest-need
   first and **folded back into ``kv_row_ids``/``kv_row_cnt``** before
   the bucket layout runs — the PR-4/PR-6 shared-truncation invariant, so
   the single-device oracle consumes the identical lists and sharded
   output stays bit-identical with no carve-outs.  Local blocks never
   ship (``pair_cap`` does not bound the ``s == p`` run).
3. **Exchange step list** — ``shd_send_ids[s, p]`` is the ascending list
   of local block indices shard ``s`` contributes to shard ``p``'s union:
   ONE ``jax.lax.all_to_all`` of ``(P, pair_cap)`` block payloads per
   K and V moves every pair's run (a ring ``ppermute`` schedule would
   move the same bytes in ``P−1`` steps; the single a2a keeps the
   Dispatch jaxpr's collective count static.  On TPU jaxlib ≥ 0.5 the
   ``jax.lax.ragged_all_to_all`` primitive could ship the exact per-pair
   counts with no ``pair_cap`` padding — noted as the upgrade path).
4. **Receive placement** — union slots are ascending, so each source's
   run is contiguous: ``shd_gather_idx`` maps union slot → index into
   ``concat([local K/V blocks, a2a payload])``, a single static gather.
   The gathered union (+ one zero pad block, so the buffer strictly
   exceeds the row-list capacity and the XLA backend takes the per-row
   CSR path) is the shard's KV buffer; ``shd_kv_row_ids`` are the rows'
   lists remapped to buffer slots, order-preserving, so the flash
   accumulation order — and therefore the bits — match the single-device
   kernel.

Communication accounting: the a2a payload is ``P · pair_cap`` blocks per
shard vs ``T_kv`` for the dense all-gather — at 25% density and default
slack the plan-aware exchange moves < 0.5× the dense bytes (CI-gated via
``launch/dryrun.py --sharded-gate``, which counts collective bytes in the
lowered HLO).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.symbols import active_indices, clamp_mask_topk, slot_positions

__all__ = [
    "ShardGeometry",
    "shard_geometry",
    "mesh_keep_rows",
    "partition_plan",
    "exchange_blocks",
    "dense_exchange_blocks",
    "mesh_attention",
]


class ShardGeometry(NamedTuple):
    """Static shapes of the per-shard partition (a function of the spec)."""

    mesh_sp: int    # P — shards on the seq axis
    q_bps: int      # q blocks per shard        (T_q / P)
    kv_bps: int     # kv blocks per shard       (T_kv / P)
    cap_q: int      # per-shard live-row capacity  min(cap_q, q_bps)
    cap_kv: int     # per-shard KV-union capacity  kv_bps + (P−1)·pair_cap
    pair_cap: int   # per-(src, dst) shipped-block capacity

    @property
    def buf_blocks(self) -> int:
        """KV buffer blocks per shard: local slice + full a2a payload."""
        return self.kv_bps + self.mesh_sp * self.pair_cap


def shard_geometry(spec, t_q: int, t_kv: int, mesh_sp: int,
                   pair_slack: float = 1.5) -> ShardGeometry:
    """Derive the static partition geometry; raises on indivisible grids."""
    if mesh_sp < 1:
        raise ValueError(f"mesh_sp must be >= 1, got {mesh_sp}")
    if t_q % mesh_sp or t_kv % mesh_sp:
        raise ValueError(
            f"seq mesh needs the block grid divisible by the shard count: "
            f"T_q={t_q}, T_kv={t_kv}, mesh_sp={mesh_sp}")
    q_bps = t_q // mesh_sp
    kv_bps = t_kv // mesh_sp
    # pair_cap scales with cap_kv (≈ density · T_kv): the wire volume is
    # where the sparsity:communication scaling comes from.  kv_bps is the
    # never-truncates safe bound (a remote slice has only kv_bps blocks).
    pair_cap = min(kv_bps, max(1, math.ceil(pair_slack * spec.cap_kv / mesh_sp)))
    # With slack ≥ 1 the union capacity admits every row list:
    # kv_bps + (P−1)·pair_cap ≥ cap_kv, so active_indices never truncates
    # the per-shard union — the pair clamp is the ONLY mesh truncation.
    cap_kv = min(t_kv, kv_bps + (mesh_sp - 1) * pair_cap)
    return ShardGeometry(mesh_sp=mesh_sp, q_bps=q_bps, kv_bps=kv_bps,
                         cap_q=min(spec.cap_q, q_bps), cap_kv=cap_kv,
                         pair_cap=pair_cap)


def exchange_blocks(geom: ShardGeometry) -> int:
    """a2a payload blocks received per shard (K or V; incl. the unused
    self slot — honest wire accounting, the diagonal pads the payload)."""
    return geom.mesh_sp * geom.pair_cap


def dense_exchange_blocks(t_kv: int) -> int:
    """Dense baseline: all-gather result blocks per shard (K or V)."""
    return t_kv


def _owner(ids: jax.Array, blocks_per_shard: int, mesh_sp: int) -> jax.Array:
    return jnp.clip(ids // blocks_per_shard, 0, mesh_sp - 1)


def mesh_keep_rows(rows: jax.Array, q_ids: jax.Array, q_cnt: jax.Array,
                   geom: ShardGeometry) -> jax.Array:
    """Fold the per-(dst, src) ``pair_cap`` clamp back into the row masks.

    ``rows``: (B, H, Cq, T_kv) bool per-live-row block mask (padding slots
    duplicate the last live row, matching ``active_indices`` semantics).
    For every destination shard, each remote source slice of its KV union
    is capped at ``pair_cap`` blocks, dropping the blocks needed by the
    fewest rows first (the same need-ranked rule as the union clamp in
    :func:`~repro.core.attention.attention_plan_indices`).  The clamp is
    applied to the ROWS — shared truncation: every backend, sharded or
    not, consumes the folded lists.  With ``pair_cap`` at its safe bound
    (``kv_bps``) this is the identity.
    """
    p_ = geom.mesh_sp
    cq = q_ids.shape[-1]
    own = _owner(q_ids, geom.q_bps, p_)                          # (B,H,Cq)
    valid = jnp.arange(cq, dtype=jnp.int32) < q_cnt[..., None]
    ownh = jax.nn.one_hot(jnp.where(valid, own, p_), p_ + 1,
                          dtype=jnp.int32)[..., :p_]             # (B,H,Cq,P)
    need = jnp.einsum("...cp,...ct->...pt", ownh,
                      rows.astype(jnp.int32))                    # (B,H,P,T_kv)
    um = need > 0
    shp = um.shape[:-1]
    um_r = um.reshape(*shp, p_, geom.kv_bps)                     # (...,Pd,Ps,kbps)
    keep_r = clamp_mask_topk(um_r, need.reshape(um_r.shape), geom.pair_cap)
    # The local slice never ships — it is exempt from the pair clamp.
    eye = jnp.eye(p_, dtype=bool)[:, :, None]
    keep_r = jnp.where(eye, um_r, keep_r)
    keep = keep_r.reshape(*shp, p_ * geom.kv_bps)                # (B,H,P,T_kv)
    keep_q = jnp.take_along_axis(
        keep, jnp.broadcast_to(own[..., None], rows.shape), axis=-2)
    return rows & keep_q


def partition_plan(q_ids: jax.Array, q_cnt: jax.Array, q_slots: jax.Array,
                   kv_row_ids: jax.Array, kv_row_cnt: jax.Array,
                   t_kv: int, geom: ShardGeometry) -> dict:
    """Emit the per-shard CSR partition + collective schedule (``shd_*``).

    Inputs are the plan's (truncation-final) attention index fields —
    runs at Update time only, AFTER :func:`mesh_keep_rows` and the bucket
    layout folded their truncations into ``kv_row_cnt``, so every per-pair
    run is already within ``pair_cap`` and nothing here can truncate.
    """
    p_ = geom.mesh_sp
    b_, h_, cq = q_ids.shape
    ck0 = kv_row_ids.shape[-1]
    own = _owner(q_ids, geom.q_bps, p_)
    valid = jnp.arange(cq, dtype=jnp.int32) < q_cnt[..., None]
    # --- row partition: shard p's live rows, in global slot order ---
    pmask = (own[..., None, :] == jnp.arange(p_, dtype=jnp.int32)[:, None]) \
        & valid[..., None, :]                                    # (B,H,P,Cq)
    sel, shd_q_cnt = active_indices(pmask, geom.cap_q)           # (B,H,P,Cqs)
    bc = lambda a: jnp.broadcast_to(a[..., None, :], (b_, h_, p_, cq))
    gsel = lambda a: jnp.take_along_axis(bc(a), sel, axis=-1)
    shd_q_src = gsel(q_ids)
    shd_q_slots = gsel(q_slots)
    shd_q_ids = jnp.clip(
        shd_q_src - jnp.arange(p_, dtype=jnp.int32)[:, None] * geom.q_bps,
        0, geom.q_bps - 1)
    rl = jnp.take_along_axis(
        jnp.broadcast_to(kv_row_ids[..., None, :, :], (b_, h_, p_, cq, ck0)),
        sel[..., None], axis=-2)                                 # (B,H,P,Cqs,Ck0)
    rc = gsel(kv_row_cnt)                                        # (B,H,P,Cqs)
    # --- per-shard KV union (membership scatter; ascending ids) ---
    svalid = jnp.arange(geom.cap_q, dtype=jnp.int32) < shd_q_cnt[..., None]
    jlive = (jnp.arange(ck0, dtype=jnp.int32) < rc[..., None]) \
        & svalid[..., None]
    ids_m = jnp.where(jlive, rl, t_kv).reshape(b_, h_, p_, -1)
    um = jnp.put_along_axis(
        jnp.zeros((b_, h_, p_, t_kv + 1), jnp.int32), ids_m,
        jnp.ones_like(ids_m), axis=-1, inplace=False)[..., :t_kv] > 0
    shd_kv_ids, shd_kv_cnt = active_indices(um, geom.cap_kv)     # (B,H,P,Cks)
    # --- remap row lists to union-buffer slots (order-preserving) ---
    slot_of = slot_positions(shd_kv_ids, shd_kv_cnt, t_kv)       # (B,H,P,t_kv)
    shd_kv_row_ids = jnp.take_along_axis(
        slot_of, rl.reshape(b_, h_, p_, -1), axis=-1).reshape(rl.shape)
    # --- receive placement: union slot -> concat([local, a2a payload]) ---
    sown = _owner(shd_kv_ids, geom.kv_bps, p_)                   # (B,H,P,Cks)
    cvalid = jnp.arange(geom.cap_kv, dtype=jnp.int32) < shd_kv_cnt[..., None]
    ownh = jax.nn.one_hot(jnp.where(cvalid, sown, p_), p_ + 1,
                          dtype=jnp.int32)[..., :p_]             # (B,H,P,Cks,P)
    cnt_src = jnp.einsum("...cs->...s", ownh)                    # (B,H,Pd,Ps)
    starts = jnp.cumsum(cnt_src, axis=-1) - cnt_src              # exclusive
    pos = jnp.arange(geom.cap_kv, dtype=jnp.int32) \
        - jnp.take_along_axis(starts, sown, axis=-1)             # run position
    pself = jnp.arange(p_, dtype=jnp.int32)[:, None]
    shd_gather_idx = jnp.clip(
        jnp.where(sown == pself, shd_kv_ids - pself * geom.kv_bps,
                  geom.kv_bps + sown * geom.pair_cap + pos),
        0, geom.buf_blocks - 1)
    # --- send tables: ascending local ids per (src, dst) pair run ---
    um_r = um.reshape(b_, h_, p_, p_, geom.kv_bps) \
        & ~jnp.eye(p_, dtype=bool)[:, :, None]                   # no self-ship
    send_ids_d, send_cnt_d = active_indices(um_r, geom.pair_cap)
    return dict(
        shd_q_ids=shd_q_ids, shd_q_src=shd_q_src, shd_q_slots=shd_q_slots,
        shd_q_cnt=shd_q_cnt, shd_kv_ids=shd_kv_ids, shd_kv_cnt=shd_kv_cnt,
        shd_kv_row_ids=shd_kv_row_ids, shd_kv_row_cnt=rc,
        shd_gather_idx=shd_gather_idx,
        shd_send_ids=jnp.swapaxes(send_ids_d, 2, 3),             # (B,H,Psrc,Pdst,pc)
        shd_send_cnt=jnp.swapaxes(send_cnt_d, 2, 3))


# ---------------------------------------------------------------------------
# Dispatch-time sharded attention (shard_map over the engine mesh).
# ---------------------------------------------------------------------------

def _dummy_plan_tail(b_l: int, dtype=jnp.int32) -> dict:
    """GEMM-side plan fields the attention backends never read."""
    z = jnp.zeros((b_l, 1), dtype)
    return dict(row_ids=z, row_cnt=jnp.zeros((b_l,), dtype),
                head_ids=jnp.zeros((b_l, 1, 1), dtype),
                head_cnt=z, head_mask=jnp.zeros((b_l, 1, 1), bool),
                m_ch=jnp.zeros((b_l, 1, 1), bool),
                row_score=jnp.zeros((b_l, 1), jnp.float32))


def mesh_attention(inner, cfg, q, k, v, o_reuse, plan, spec, *,
                   scale: Optional[float] = None,
                   compact_q: bool = False) -> jax.Array:
    """shard_map-wrapped sparse attention over the ``(data, seq)`` mesh.

    ``inner`` is the single-device backend (XLA or Pallas) — the SAME
    per-row CSR code path runs inside each shard over the gathered KV
    buffer, with the row lists at their original capacity width, which is
    what makes sharded output bit-identical to the single-device oracle.
    GEMM-Q/GEMM-O stay outside the shard_map (batch-sharded / GSPMD-
    propagated); only attention exchanges KV.
    """
    from repro.core.attention import SparseAttentionSpec
    from repro.core.plan import DispatchPlan
    from repro.launch.mesh import make_engine_mesh

    plan = plan.widen()
    b, h, n_q, dh = q.shape
    n = o_reuse.shape[-2]
    if b % cfg.mesh_dp:
        raise ValueError(f"batch {b} not divisible by mesh_dp={cfg.mesh_dp}")
    if cfg.mesh_axis == "head":
        return _head_sharded(inner, cfg, q, k, v, o_reuse, plan, spec,
                             scale=scale, compact_q=compact_q)
    if plan.shd_q_ids is None:
        raise ValueError("seq-mode mesh dispatch needs a plan built with "
                         "mesh_sp > 1 (shd_* fields missing)")
    mesh = make_engine_mesh(cfg.mesh_dp, cfg.mesh_sp)
    p_ = cfg.mesh_sp
    bk = spec.block_kv
    kv_bps = (n // bk) // p_
    pair_cap = plan.shd_send_ids.shape[-1]
    ck_s = plan.shd_kv_ids.shape[-1]
    cq_s = plan.shd_q_ids.shape[-1]
    ck0 = plan.shd_kv_row_ids.shape[-1]
    # cap_kv keeps the ORIGINAL row-list width ck0 (≤ union capacity by
    # the slack ≥ 1 guarantee), so the inner per-row math — gather widths,
    # live mask, softmax reduction — has the exact shapes of the single-
    # device oracle.  The buffer carries ck_s + 1 blocks (one zero pad),
    # strictly more than ck0, so the XLA path takes the per-row CSR branch.
    inner_spec = SparseAttentionSpec(block_q=spec.block_q, block_kv=bk,
                                     cap_q=cq_s, cap_kv=ck0, kv_buckets=1)

    def body(qf, kl, vl, ol, qi, qs, qc, ri, rc, gi, si):
        b_l = ol.shape[0]
        sq = lambda a: a[:, :, 0]                      # squeeze the P axis
        kb = kl.reshape(b_l, h, kv_bps, bk, dh)
        vb = vl.reshape(b_l, h, kv_bps, bk, dh)
        send = sq(si).reshape(b_l, h, p_ * pair_cap)

        def gather(blocks, ids):
            idx = jnp.broadcast_to(ids[..., None, None], (*ids.shape, bk, dh))
            return jnp.take_along_axis(blocks, idx, axis=2)

        def a2a(x):
            x = x.reshape(b_l, h, p_, pair_cap, bk, dh)
            y = jax.lax.all_to_all(x, "seq", split_axis=2, concat_axis=2)
            return y.reshape(b_l, h, p_ * pair_cap, bk, dh)

        pad = jnp.zeros((b_l, h, 1, bk, dh), kl.dtype)

        def buffer(blocks):
            buf = jnp.concatenate([blocks, a2a(gather(blocks, send))], axis=2)
            union = gather(buf, sq(gi))
            return jnp.concatenate([union, pad], axis=2) \
                .reshape(b_l, h, (ck_s + 1) * bk, dh)

        kx, vx = buffer(kb), buffer(vb)
        pv = DispatchPlan(
            q_ids=sq(qi), q_cnt=sq(qc), q_slots=sq(qs),
            kv_ids=jnp.zeros((b_l, h, 1), jnp.int32),
            kv_cnt=jnp.zeros((b_l, h), jnp.int32),
            pair_live=jnp.zeros((b_l, h, cq_s, 1), bool),
            kv_row_ids=sq(ri), kv_row_cnt=sq(rc), **_dummy_plan_tail(b_l))
        # compact_q=True always: the read layout (full or compact) is baked
        # into q_slots above; q_ids stay the shard-LOCAL output blocks.
        return inner.attention(qf, kx, vx, ol, pv, inner_spec, scale=scale,
                               compact_q=True)

    d, s = "data", "seq"
    f = shard_map(
        body, mesh=mesh,
        in_specs=(P(d, None, None, None),                 # q (replicated on seq)
                  P(d, None, s, None), P(d, None, s, None),
                  P(d, None, s, None),                    # k, v, o_reuse on N
                  P(d, None, s, None), P(d, None, s, None), P(d, None, s),
                  P(d, None, s, None, None), P(d, None, s, None),
                  P(d, None, s, None), P(d, None, s, None, None)),
        out_specs=P(d, None, s, None), check_rep=False)
    src = plan.shd_q_slots if compact_q else plan.shd_q_src
    return f(q, k, v, o_reuse, plan.shd_q_ids, src, plan.shd_q_cnt,
             plan.shd_kv_row_ids, plan.shd_kv_row_cnt, plan.shd_gather_idx,
             plan.shd_send_ids)


def _head_sharded(inner, cfg, q, k, v, o_reuse, plan, spec, *,
                  scale, compact_q):
    """Head-parallel mode: shard H over ``seq``; no collectives at all."""
    from repro.core.plan import DispatchPlan
    from repro.launch.mesh import make_engine_mesh

    h = q.shape[1]
    if h % cfg.mesh_sp:
        raise ValueError(f"heads {h} not divisible by mesh_sp={cfg.mesh_sp}")
    if spec.kv_buckets > 1:
        raise ValueError("mesh_axis='head' cannot shard the bucketed layout "
                         "(bucket rows fold the head axis); use mesh_axis="
                         "'seq' or kv_buckets=1")
    mesh = make_engine_mesh(cfg.mesh_dp, cfg.mesh_sp)

    def body(qh, kh, vh, oh, qi, qc, qs, ki, kc, pl, ri, rc):
        pv = DispatchPlan(q_ids=qi, q_cnt=qc, q_slots=qs, kv_ids=ki,
                          kv_cnt=kc, pair_live=pl, kv_row_ids=ri,
                          kv_row_cnt=rc, **_dummy_plan_tail(qh.shape[0]))
        return inner.attention(qh, kh, vh, oh, pv, spec, scale=scale,
                               compact_q=compact_q)

    d, s = "data", "seq"
    h4 = P(d, s, None, None)
    f = shard_map(
        body, mesh=mesh,
        in_specs=(h4, h4, h4, h4,
                  P(d, s, None), P(d, s), P(d, s, None),
                  P(d, s, None), P(d, s), P(d, s, None, None),
                  P(d, s, None, None), P(d, s, None)),
        out_specs=h4, check_rep=False)
    return f(q, k, v, o_reuse, plan.q_ids, plan.q_cnt, plan.q_slots,
             plan.kv_ids, plan.kv_cnt, plan.pair_live,
             plan.kv_row_ids, plan.kv_row_cnt)
