"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Model code annotates parameters and activations with LOGICAL axis names;
this module maps them onto the physical mesh axes of
``repro.launch.mesh.make_production_mesh``:

  single-pod: (data=16, model=16)          multi-pod: (pod=2, data=16, model=16)

Logical axes:
  * ``dp``    — data parallel (batch dim of activations)
  * ``fsdp``  — weight/optimizer-state sharding (ZeRO-3 over the data axis;
                for ≥100B params the pod axis joins, see configs)
  * ``tp``    — tensor parallel (heads / ff / vocab)
  * ``sp``    — sequence parallel (long-context KV caches, batch=1 cells)
  * ``ep``    — expert parallel (MoE expert dim; only when divisible)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "logical_to_physical", "tree_logical_to_physical",
           "named_sharding_tree", "DEFAULT_RULES", "MULTIPOD_RULES"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis name to a tuple of physical mesh axes."""

    dp: tuple[str, ...] = ("data",)
    fsdp: tuple[str, ...] = ("data",)
    tp: tuple[str, ...] = ("model",)
    sp: tuple[str, ...] = ()
    ep: tuple[str, ...] = ()

    def physical(self, logical: Optional[str]) -> Any:
        if logical is None:
            return None
        axes: tuple[str, ...] = ()
        for part in logical.split("+"):          # e.g. "dp+sp"
            axes = axes + tuple(getattr(self, part))
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]


DEFAULT_RULES = ShardingRules()
MULTIPOD_RULES = ShardingRules(dp=("pod", "data"), fsdp=("data",))
# ZeRO across pods too — used by ≥100B configs (llama3-405b):
MULTIPOD_ZERO_RULES = ShardingRules(dp=("pod", "data"), fsdp=("pod", "data"))
SEQ_RULES = dataclasses.replace(DEFAULT_RULES, sp=("data",))
MULTIPOD_SEQ_RULES = dataclasses.replace(MULTIPOD_RULES, sp=("data",), dp=("pod",))


def logical_to_physical(logical_spec: Sequence[Optional[str]],
                        rules: ShardingRules) -> P:
    """("fsdp", "tp") -> PartitionSpec(("data",), ("model",)) etc."""
    return P(*(rules.physical(ax) for ax in logical_spec))


def tree_logical_to_physical(spec_tree: Any, rules: ShardingRules) -> Any:
    """Map a pytree of logical tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda spec: logical_to_physical(spec, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def named_sharding_tree(spec_tree: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        tree_logical_to_physical(spec_tree, rules),
                        is_leaf=lambda x: isinstance(x, P))
