"""Version shims shared by the Pallas kernel modules."""

from jax.experimental.pallas import tpu as pltpu

# jax<=0.4.x names the TPU compiler-params struct TPUCompilerParams; newer
# releases renamed it CompilerParams.  Resolve whichever exists.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
