"""FlashOmni general sparse attention — Pallas TPU kernels (paper §3.4).

Two variants of the paper's Algorithm 1, adapted to the TPU execution model
(DESIGN §2):

``flashomni_attention_csr``  (default, TPU-native structural skipping)
    The grid covers only LIVE work: ``(BH, Cq, Ckv)`` where ``Cq`` is the
    static capacity of live Q blocks and the KV reduction runs over
    per-row CSR column lists.  Scalar-prefetched index arrays drive the
    BlockSpec index maps, so skipped tiles are never DMA'd and never
    occupy a grid slot — this is what preserves the paper's ~1:1
    speedup:sparsity on a sequential-grid machine.  Cached rows are left
    untouched via input/output aliasing of the ``o_reuse`` tensor (their
    forecast value is produced by the ``taylor_reuse`` element-wise kernel,
    the paper's "alternatively, an elementwise kernel can be invoked").

``flashomni_attention_csr_bucketed``  (occupancy-bucketed two-level grid)
    The uniform CSR grid still pads every live row's reduction to the
    static ``cap_kv`` — mostly-idle slots on the strongly bimodal plans
    the deployment strategies emit (``hunyuan-1.5x`` sliding-window heads
    have tiny per-row KV counts).  The bucketed variant runs a TWO-LEVEL
    grid (bucket × row × per-bucket Ckv, flattened to ``(B, S)`` with
    ``S = Σ rows_b · width_b``): at plan-build time the ``H·Cq`` layout
    rows are sorted by KV occupancy into a static set of halving-width
    buckets (:func:`repro.core.plan.bucket_geometry`), so a row with 3
    live KV blocks occupies a ≈3-wide reduction instead of a
    ``cap_kv``-wide one.  The per-slot (row, j, offset, last) decode is a
    compile-time constant of the geometry, scalar-prefetched like the
    index lists; the uniform kernel is exactly the ``n_buckets = 1``
    degenerate case of this layout.  Bucket truncation is folded back
    into ``kv_row_cnt`` at plan build, so bucketed and uniform outputs
    are BIT-IDENTICAL (same ascending-id flash accumulation order).

``flashomni_attention_symbols``  (paper-faithful predication)
    The grid covers every ``(i, j)`` tile; each program decodes the packed
    uint8 symbols with the paper's bitwise ``F``/``J`` and predicates
    compute with ``@pl.when`` — including the fused cache-then-reuse copy
    branch (Algorithm 1 lines 5–10).  Demonstrates symbol-decode fidelity;
    DMA traffic is NOT reduced (documented GPU→TPU non-transfer).

All validate against :func:`repro.kernels.ref.attention_ref` in
``interpret=True`` mode; on real v5e the CSR variants are the serving path.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = [
    "flashomni_attention_csr",
    "flashomni_attention_csr_bucketed",
    "flashomni_attention_symbols",
]

_NEG_INF = -1e30
_LANES = 128  # TPU vreg lane count: m/l scratch kept (bq, 128)-shaped.


# ---------------------------------------------------------------------------
# CSR variant
# ---------------------------------------------------------------------------

def _csr_kernel(
    # scalar prefetch
    q_ids_ref, q_src_ids_ref, kv_ids_ref, kv_cnt_ref,
    # inputs
    q_ref, k_ref, v_ref, o_reuse_ref,   # o_reuse aliased to output (untouched)
    # outputs
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    ckv: int,
):
    c, j = pl.program_id(1), pl.program_id(2)
    bh = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j < kv_cnt_ref[bh, c])
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        m_prev = m_ref[:, :1]                               # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(j == ckv - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                     # fully-skipped row guard
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flashomni_attention_csr(
    q: jax.Array,             # (BH, N_q, d) — full OR compact (layout fusion)
    k: jax.Array,             # (BH, N_kv, d)
    v: jax.Array,             # (BH, N_kv, d)
    o_reuse: jax.Array,       # (BH, N, d) — cached/forecast baseline (aliased)
    q_ids: jax.Array,         # (BH, Cq) int32 live q-block ids (output layout)
    kv_ids: jax.Array,        # (BH, Cq, Ckv) int32 per-row live kv-block ids
    kv_cnt: jax.Array,        # (BH, Cq) int32
    *,
    block_q: int,
    block_kv: int,
    scale: Optional[float] = None,
    interpret: bool = False,
    q_src_ids: Optional[jax.Array] = None,  # (BH, Cq) q-block ids in Q's layout
) -> jax.Array:
    """CSR sparse attention.  ``q_src_ids`` decouples where live Q blocks
    are READ from where outputs are WRITTEN: pass the compact-slot ids of a
    GEMM-Q ``(Cr·bm, F)`` output to chain the two kernels without a scatter
    (the compact-layout fusion GEMM-Q was designed for).  Defaults to
    ``q_ids`` (full-layout Q)."""
    bhs, n_q, d = q.shape
    n_kv = k.shape[1]
    assert n_q % block_q == 0 and n_kv % block_kv == 0
    assert o_reuse.shape[1] % block_q == 0
    cq, ckv = q_ids.shape[1], kv_ids.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    q_src_ids = q_ids if q_src_ids is None else q_src_ids

    grid = (bhs, cq, ckv)
    kernel = functools.partial(_csr_kernel, scale=scale, ckv=ckv)
    flat_kv = kv_ids.reshape(bhs, cq * ckv)

    def q_map(bh, c, j, q_ids_ref, q_src_ids_ref, kv_ids_ref, kv_cnt_ref):
        return (bh, q_src_ids_ref[bh, c], 0)

    def kv_map(bh, c, j, q_ids_ref, q_src_ids_ref, kv_ids_ref, kv_cnt_ref):
        # Clamp padded slots to the last live column (re-DMA of a resident
        # block — Mosaic elides the copy when the index is unchanged).
        jj = jnp.maximum(jnp.minimum(j, kv_cnt_ref[bh, c] - 1), 0)
        return (bh, kv_ids_ref[bh, c * ckv + jj], 0)

    def o_map(bh, c, j, q_ids_ref, q_src_ids_ref, kv_ids_ref, kv_cnt_ref):
        return (bh, q_ids_ref[bh, c], 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, d), q_map),
                pl.BlockSpec((1, block_kv, d), kv_map),
                pl.BlockSpec((1, block_kv, d), kv_map),
                pl.BlockSpec((1, block_q, d), o_map),       # o_reuse (aliased)
            ],
            out_specs=pl.BlockSpec((1, block_q, d), o_map),
            scratch_shapes=[
                pltpu.VMEM((block_q, d), jnp.float32),
                pltpu.VMEM((block_q, _LANES), jnp.float32),
                pltpu.VMEM((block_q, _LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(o_reuse.shape, o_reuse.dtype),
        # NB: alias indices count the scalar-prefetch operands too.
        input_output_aliases={7: 0},                        # o_reuse -> out
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(q_ids, q_src_ids, flat_kv, kv_cnt, q, k, v, o_reuse)


# ---------------------------------------------------------------------------
# Occupancy-bucketed CSR variant — two-level (bucket × row × Ckv) grid
# ---------------------------------------------------------------------------

def _csr_bucketed_kernel(
    # scalar prefetch: static slot decode + plan layout
    srow_ref, jof_ref, soff_ref, slast_ref,
    head_ref, q_write_ref, q_read_ref, kv_ids_ref, kv_cnt_ref,
    # inputs
    q_ref, k_ref, v_ref, o_reuse_ref,   # o_reuse aliased to output (untouched)
    # outputs
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
):
    b, s = pl.program_id(0), pl.program_id(1)
    r = srow_ref[s]
    jof = jof_ref[s]

    @pl.when(jof == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(jof < kv_cnt_ref[b, r])
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s_ = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        m_prev = m_ref[:, :1]                               # (bq, 1)
        m_cur = jnp.max(s_, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s_ - m_new)
        alpha = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(slast_ref[s] == 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                     # fully-skipped row guard
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flashomni_attention_csr_bucketed(
    q: jax.Array,             # (B·H, N_q, d) — full OR compact (layout fusion)
    k: jax.Array,             # (B·H, N_kv, d)
    v: jax.Array,             # (B·H, N_kv, d)
    o_reuse: jax.Array,       # (B·H, N, d) — cached/forecast baseline (aliased)
    bkt_head: jax.Array,      # (B, R) int32 head of each layout row
    bkt_q_write: jax.Array,   # (B, R) int32 output q-block id (dead rows → T_q)
    bkt_q_read: jax.Array,    # (B, R) int32 q-block id in Q's layout (dead → 0)
    bkt_kv_ids: jax.Array,    # (B, S) int32 per-slot kv-block id
    bkt_kv_cnt: jax.Array,    # (B, R) int32 bucket-truncated live KV count
    geometry,                 # ((rows, width), ...) — bucket_geometry output
    *,
    heads: int,
    block_q: int,
    block_kv: int,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Occupancy-bucketed CSR sparse attention (see module docstring).

    Grid is ``(B, S)`` with ``S = Σ rows_b·width_b`` — the two-level
    bucket × row × per-bucket-Ckv structure flattened so consecutive grid
    steps walk one row's reduction start-to-finish.  The head axis is
    folded into the layout rows (``bh = b·heads + bkt_head[b, r]`` in
    every index map), which is what lets a sliding-window head's short
    rows share narrow buckets while a full head's rows take wide ones.
    Dead layout rows write zeros to a one-block trash pad appended past
    ``N``; live-but-empty rows (zero live KV blocks) write zeros exactly
    like the uniform kernel's fully-skipped-row guard.
    """
    from repro.core.plan import bucket_slot_layout

    bhs, n_q, d = q.shape
    n_kv = k.shape[1]
    n_out = o_reuse.shape[1]
    assert bhs % heads == 0
    assert n_q % block_q == 0 and n_kv % block_kv == 0 and n_out % block_q == 0
    batch = bhs // heads
    srow, jof, soff, slast = bucket_slot_layout(geometry)
    s_total = int(srow.shape[0])
    scale = (d ** -0.5) if scale is None else scale
    kernel = functools.partial(_csr_bucketed_kernel, scale=scale)

    # One trash block per (b, h) past the real tokens: dead layout rows
    # land there (q_write == T_q); sliced off after the call.
    o_pad = jnp.concatenate(
        [o_reuse, jnp.zeros((bhs, block_q, d), o_reuse.dtype)], axis=1)

    def q_map(b, s, srow_r, jof_r, soff_r, slast_r, head_r, qw_r, qr_r,
              kvi_r, kvc_r):
        r = srow_r[s]
        return (b * heads + head_r[b, r], qr_r[b, r], 0)

    def kv_map(b, s, srow_r, jof_r, soff_r, slast_r, head_r, qw_r, qr_r,
               kvi_r, kvc_r):
        r = srow_r[s]
        # Clamp padded slots to the last live column (re-DMA of a resident
        # block — Mosaic elides the copy when the index is unchanged).
        jj = jnp.maximum(jnp.minimum(jof_r[s], kvc_r[b, r] - 1), 0)
        return (b * heads + head_r[b, r], kvi_r[b, soff_r[s] + jj], 0)

    def o_map(b, s, srow_r, jof_r, soff_r, slast_r, head_r, qw_r, qr_r,
              kvi_r, kvc_r):
        r = srow_r[s]
        return (b * heads + head_r[b, r], qw_r[b, r], 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=9,
            grid=(batch, s_total),
            in_specs=[
                pl.BlockSpec((1, block_q, d), q_map),
                pl.BlockSpec((1, block_kv, d), kv_map),
                pl.BlockSpec((1, block_kv, d), kv_map),
                pl.BlockSpec((1, block_q, d), o_map),       # o_reuse (aliased)
            ],
            out_specs=pl.BlockSpec((1, block_q, d), o_map),
            scratch_shapes=[
                pltpu.VMEM((block_q, d), jnp.float32),
                pltpu.VMEM((block_q, _LANES), jnp.float32),
                pltpu.VMEM((block_q, _LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(o_pad.shape, o_pad.dtype),
        # NB: alias indices count the scalar-prefetch operands too.
        input_output_aliases={12: 0},                       # o_pad -> out
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(srow), jnp.asarray(jof), jnp.asarray(soff),
      jnp.asarray(slast), bkt_head, bkt_q_write, bkt_q_read,
      bkt_kv_ids, bkt_kv_cnt, q, k, v, o_pad)
    return out[:, :n_out]


# ---------------------------------------------------------------------------
# Symbols (predication) variant — paper Algorithm 1 verbatim
# ---------------------------------------------------------------------------

def _sym_kernel(
    # scalar prefetch
    s_c_ref, s_s_ref,
    # inputs
    q_ref, k_ref, v_ref, o_reuse_ref,
    # outputs
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    t_kv: int,
):
    bh, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    # F(S_c, i): spatial-axis decode (bitwise, big-endian).
    byte_c = s_c_ref[bh, i // 8].astype(jnp.int32)
    f_live = (byte_c >> (7 - i % 8)) & 1
    # J(S_s, i, j): reduction-axis decode on the row-major flattened matrix.
    flat = i * t_kv + j
    byte_s = s_s_ref[bh, flat // 8].astype(jnp.int32)
    j_live = (byte_s >> (7 - flat % 8)) & 1

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Cache-then-Reuse (Algorithm 1 lines 5-10): fused element-wise copy of
    # the forecast feature, then the CTA-equivalent returns.
    @pl.when((f_live == 0) & (j == t_kv - 1))
    def _reuse():
        o_ref[0] = o_reuse_ref[0]

    # Compute-on-Demand (lines 11-19) with reduction-axis skipping (line 13).
    @pl.when((f_live == 1) & (j_live == 1))
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        m_prev = m_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)

    @pl.when((f_live == 1) & (j == t_kv - 1))
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flashomni_attention_symbols(
    q: jax.Array,             # (BH, N, d)
    k: jax.Array,
    v: jax.Array,
    o_reuse: jax.Array,       # (BH, N, d) forecast features (OP_reuse output)
    s_c: jax.Array,           # (BH, cbytes) uint8 packed caching symbol
    s_s: jax.Array,           # (BH, fbytes) uint8 packed skipping symbol
    *,
    block_q: int,
    block_kv: int,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    bhs, n, d = q.shape
    n_kv = k.shape[1]
    assert n % block_q == 0 and n_kv % block_kv == 0
    t_q, t_kv = n // block_q, n_kv // block_kv
    scale = (d ** -0.5) if scale is None else scale
    kernel = functools.partial(_sym_kernel, scale=scale, t_kv=t_kv)

    def qo_map(bh, i, j, s_c_ref, s_s_ref):
        return (bh, i, 0)

    def kv_map(bh, i, j, s_c_ref, s_s_ref):
        return (bh, j, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(bhs, t_q, t_kv),
            in_specs=[
                pl.BlockSpec((1, block_q, d), qo_map),
                pl.BlockSpec((1, block_kv, d), kv_map),
                pl.BlockSpec((1, block_kv, d), kv_map),
                pl.BlockSpec((1, block_q, d), qo_map),
            ],
            out_specs=pl.BlockSpec((1, block_q, d), qo_map),
            scratch_shapes=[
                pltpu.VMEM((block_q, d), jnp.float32),
                pltpu.VMEM((block_q, _LANES), jnp.float32),
                pltpu.VMEM((block_q, _LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(o_reuse.shape, o_reuse.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(s_c, s_s, q, k, v, o_reuse)
