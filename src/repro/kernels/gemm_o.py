"""FlashOmni GEMM-O — reduction-axis sparse output projection (paper §3.5,
Obs. 3, Eq. 3/4).

``Out_i = Σ_{h∈H_i} O_i^h W_h + OP_reuse(B_c)_i``: per live row block, only
the live heads are reduced; the cached heads' contribution arrives through
the Taylor-forecast bias ``B_c``.  The paper relaunches the kernel for its
two stages on GPU; on TPU both collapse into ONE kernel because the bias is
simply the accumulator's initial value (DESIGN §2.4).

Structure: grid ``(B, Cr, F_tiles, Hc)``, with per-row live-head CSR lists
in scalar memory (flattened over the batch, indexed ``b·Cr + c``) — batch
is a GRID dimension, so one ``pallas_call`` covers every sample (no Python
per-sample relaunch; unbatched inputs still accepted).  The bias tensor is
aliased to the output, so row blocks that are never visited (fully cached
rows) keep their forecast value — Eq. 4's "cache-then-reuse branch
terminates immediately" for free.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["gemm_o_sparse_kernel"]


def _kernel(row_ids_ref, head_ids_ref, head_cnt_ref,
            o_ref, w_ref, bias_ref, out_ref, acc_ref, *, cr: int, hc: int):
    bi, c, hh = pl.program_id(0), pl.program_id(1), pl.program_id(3)
    slot = bi * cr + c

    @pl.when(hh == 0)
    def _init():
        acc_ref[...] = bias_ref[0].astype(jnp.float32)  # B_c as accumulator init

    @pl.when(hh < head_cnt_ref[slot])
    def _accum():
        acc_ref[...] += jax.lax.dot(
            o_ref[0, 0].astype(jnp.float32),
            w_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    # Padding slots (head_cnt == 0) duplicate the last live row id; they
    # must not store: with the bias-aliased output, re-initializing from
    # ``bias_ref`` would erase (interpret) or re-accumulate (TPU re-fetch
    # across f-tiles) the live slot's already-written result.
    @pl.when((hh == hc - 1) & (head_cnt_ref[slot] > 0))
    def _done():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def gemm_o_sparse_kernel(
    o_heads: jax.Array,    # (B, H, N, dh) or (H, N, dh) attention outputs
    w: jax.Array,          # (H, dh, F) output projection, per-head
    bias: jax.Array,       # (B, N, F) or (N, F) OP_reuse(B_c) — aliased to out
    row_ids: jax.Array,    # (B, Cr) or (Cr,) live row-block ids
    head_ids: jax.Array,   # (B, Cr, Hc) or (Cr, Hc) live head ids per row
    head_cnt: jax.Array,   # (B, Cr) or (Cr,)
    *,
    block_rows: int,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    squeeze = o_heads.ndim == 3
    if squeeze:
        o_heads, bias = o_heads[None], bias[None]
        row_ids, head_ids, head_cnt = row_ids[None], head_ids[None], head_cnt[None]
    b, h, n, dh = o_heads.shape
    f = w.shape[-1]
    assert n % block_rows == 0
    block_f = min(block_f, f)
    assert f % block_f == 0
    _, cr, hc = head_ids.shape
    grid = (b, cr, f // block_f, hc)
    flat_rows = row_ids.reshape(-1)
    flat_heads = head_ids.reshape(-1)
    flat_cnt = head_cnt.reshape(-1)

    def o_map(bi, c, fi, hh, rids, hids, hcnt):
        slot = bi * cr + c
        hh_c = jnp.maximum(jnp.minimum(hh, hcnt[slot] - 1), 0)
        return (bi, hids[slot * hc + hh_c], rids[slot], 0)

    def w_map(bi, c, fi, hh, rids, hids, hcnt):
        slot = bi * cr + c
        hh_c = jnp.maximum(jnp.minimum(hh, hcnt[slot] - 1), 0)
        return (hids[slot * hc + hh_c], 0, fi)

    def bias_map(bi, c, fi, hh, rids, hids, hcnt):
        return (bi, rids[bi * cr + c], fi)

    out = pl.pallas_call(
        functools.partial(_kernel, cr=cr, hc=hc),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_rows, dh), o_map),
                pl.BlockSpec((1, dh, block_f), w_map),
                pl.BlockSpec((1, block_rows, block_f), bias_map),
            ],
            out_specs=pl.BlockSpec((1, block_rows, block_f), bias_map),
            scratch_shapes=[pltpu.VMEM((block_rows, block_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct(bias.shape, bias.dtype),
        input_output_aliases={5: 0},                         # bias -> out
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(flat_rows, flat_heads, flat_cnt, o_heads, w, bias)
    return out[0] if squeeze else out
