"""FlashOmni GEMM-O — reduction-axis sparse output projection (paper §3.5,
Obs. 3, Eq. 3/4).

``Out_i = Σ_{h∈H_i} O_i^h W_h + OP_reuse(B_c)_i``: per live row block, only
the live heads are reduced; the cached heads' contribution arrives through
the Taylor-forecast bias ``B_c``.  The paper relaunches the kernel for its
two stages on GPU; on TPU both collapse into ONE kernel because the bias is
simply the accumulator's initial value (DESIGN §2.4).

Structure: grid ``(B, Cr, F_tiles, Hc)``, with per-row live-head CSR lists
in scalar memory (flattened over the batch, indexed ``b·Cr + c``) — batch
is a GRID dimension, so one ``pallas_call`` covers every sample (no Python
per-sample relaunch; unbatched inputs still accepted).  The bias tensor is
aliased to the output, so row blocks that are never visited (fully cached
rows) keep their forecast value — Eq. 4's "cache-then-reuse branch
terminates immediately" for free.

Occupancy-bucketed variant (:func:`gemm_o_sparse_bucketed_kernel`, the
paper's GEMM-O 2.5–3.8× territory): the uniform grid pays ``Hc`` (the max
live-head count) for EVERY row slot even when most rows keep 1–2 live
heads — the common case under per-head sparsity patterns.  The bucketed
grid is ``(B, F_tiles, S)`` with ``S = Σ rows_b·width_b`` over a
halving-depth ``bucket_geometry(Cr, H, 1, kv_buckets)``: row slots are
sorted by live-head count at Update time (``DispatchPlan.gmo_*``,
:func:`repro.core.plan.gmo_layout`) so a 1-head row occupies a 1-deep
reduction slot.  At ``B = 3`` buckets the grid shrinks to
``3/7 ≈ 0.43×`` the uniform slot count — a static bound.  Both variants
preserve the bias-as-accumulator-init trick and the padded-slot no-store
invariant; any bucket-induced head clamp is folded back into the plan's
``head_cnt`` lists, so bucketed and uniform outputs are bit-identical.

Tile shapes (``block_f``, and ``block_k``/``block_f`` for GEMM-Q) come
from the calibration table in :mod:`repro.kernels.tuning` — a JSON file
keyed per kernel kind and per bucket width class, populated by
``benchmarks/autotune.py`` and consulted by :mod:`repro.kernels.ops` /
:class:`repro.core.backend.PallasBackend`.  The checked-in default table
reproduces the hand-picked ``512`` tiles, so behavior without a sweep is
unchanged.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["gemm_o_sparse_kernel", "gemm_o_sparse_bucketed_kernel"]


def _kernel(row_ids_ref, head_ids_ref, head_cnt_ref,
            o_ref, w_ref, bias_ref, out_ref, acc_ref, *, cr: int, hc: int):
    bi, c, hh = pl.program_id(0), pl.program_id(1), pl.program_id(3)
    slot = bi * cr + c

    @pl.when(hh == 0)
    def _init():
        acc_ref[...] = bias_ref[0].astype(jnp.float32)  # B_c as accumulator init

    @pl.when(hh < head_cnt_ref[slot])
    def _accum():
        acc_ref[...] += jax.lax.dot(
            o_ref[0, 0].astype(jnp.float32),
            w_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    # Padding slots (head_cnt == 0) duplicate the last live row id; they
    # must not store: with the bias-aliased output, re-initializing from
    # ``bias_ref`` would erase (interpret) or re-accumulate (TPU re-fetch
    # across f-tiles) the live slot's already-written result.
    @pl.when((hh == hc - 1) & (head_cnt_ref[slot] > 0))
    def _done():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def gemm_o_sparse_kernel(
    o_heads: jax.Array,    # (B, H, N, dh) or (H, N, dh) attention outputs
    w: jax.Array,          # (H, dh, F) output projection, per-head
    bias: jax.Array,       # (B, N, F) or (N, F) OP_reuse(B_c) — aliased to out
    row_ids: jax.Array,    # (B, Cr) or (Cr,) live row-block ids
    head_ids: jax.Array,   # (B, Cr, Hc) or (Cr, Hc) live head ids per row
    head_cnt: jax.Array,   # (B, Cr) or (Cr,)
    *,
    block_rows: int,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    squeeze = o_heads.ndim == 3
    if squeeze:
        o_heads, bias = o_heads[None], bias[None]
        row_ids, head_ids, head_cnt = row_ids[None], head_ids[None], head_cnt[None]
    b, h, n, dh = o_heads.shape
    f = w.shape[-1]
    assert n % block_rows == 0
    block_f = min(block_f, f)
    assert f % block_f == 0
    _, cr, hc = head_ids.shape
    grid = (b, cr, f // block_f, hc)
    flat_rows = row_ids.reshape(-1)
    flat_heads = head_ids.reshape(-1)
    flat_cnt = head_cnt.reshape(-1)

    def o_map(bi, c, fi, hh, rids, hids, hcnt):
        slot = bi * cr + c
        hh_c = jnp.maximum(jnp.minimum(hh, hcnt[slot] - 1), 0)
        return (bi, hids[slot * hc + hh_c], rids[slot], 0)

    def w_map(bi, c, fi, hh, rids, hids, hcnt):
        slot = bi * cr + c
        hh_c = jnp.maximum(jnp.minimum(hh, hcnt[slot] - 1), 0)
        return (hids[slot * hc + hh_c], 0, fi)

    def bias_map(bi, c, fi, hh, rids, hids, hcnt):
        return (bi, rids[bi * cr + c], fi)

    out = pl.pallas_call(
        functools.partial(_kernel, cr=cr, hc=hc),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_rows, dh), o_map),
                pl.BlockSpec((1, dh, block_f), w_map),
                pl.BlockSpec((1, block_rows, block_f), bias_map),
            ],
            out_specs=pl.BlockSpec((1, block_rows, block_f), bias_map),
            scratch_shapes=[pltpu.VMEM((block_rows, block_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct(bias.shape, bias.dtype),
        input_output_aliases={5: 0},                         # bias -> out
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(flat_rows, flat_heads, flat_cnt, o_heads, w, bias)
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# Occupancy-bucketed variant — two-level (bucket × row-slot × Hc_b) grid
# ---------------------------------------------------------------------------

def _bucketed_kernel(srow_ref, jof_ref, soff_ref, slast_ref,
                     rows_ref, src_ref, hid_ref, cnt_ref,
                     o_ref, w_ref, bias_ref, out_ref, acc_ref):
    bi, s = pl.program_id(0), pl.program_id(2)
    r = srow_ref[s]

    @pl.when(jof_ref[s] == 0)
    def _init():
        acc_ref[...] = bias_ref[0].astype(jnp.float32)  # B_c as accumulator init

    @pl.when(jof_ref[s] < cnt_ref[bi, r])
    def _accum():
        acc_ref[...] += jax.lax.dot(
            o_ref[0, 0].astype(jnp.float32),
            w_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    # Store at the LAST slot of the row's bucket width (not at head_cnt-1:
    # the accumulation already finished, trailing slots are no-ops), and
    # only for slots with live heads — dead row slots write nothing, so
    # the bias-aliased output keeps their forecast value (they also map to
    # the trash block, see the wrapper).
    @pl.when((slast_ref[s] == 1) & (cnt_ref[bi, r] > 0))
    def _done():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def gemm_o_sparse_bucketed_kernel(
    o_heads: jax.Array,       # (B, H, N, dh) or (H, N, dh) attention outputs
    w: jax.Array,             # (H, dh, F) output projection, per-head
    bias: jax.Array,          # (B, N, F) or (N, F) OP_reuse(B_c) — aliased
    gmo_rows: jax.Array,      # (B, Cr) or (Cr,) write row id (dead → N//bm)
    gmo_src: jax.Array,       # (B, Cr) or (Cr,) read row id (dead → 0)
    gmo_head_ids: jax.Array,  # (B, S) or (S,) per-slot head id
    gmo_head_cnt: jax.Array,  # (B, Cr) or (Cr,) clamped live-head count
    geometry,                 # ((rows, width), ...) — bucket_geometry output
    *,
    block_rows: int,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Bucketed GEMM-O (see module docstring).

    Grid is ``(B, F_tiles, S)`` with ``S = Σ rows_b·width_b`` — the
    two-level bucket × row-slot × per-bucket-Hc structure flattened so
    consecutive grid steps walk one row's head reduction start-to-finish.
    The plan layout (``gmo_*``, sorted at Update time) is consumed
    verbatim; Dispatch jaxprs stay sort-free.  Dead row slots read row 0 /
    a clamped head (resident-block re-DMA, elided by Mosaic) and store to
    a one-block trash row appended past ``N``, sliced off after the call.
    """
    from repro.core.plan import bucket_slot_layout

    squeeze = o_heads.ndim == 3
    if squeeze:
        o_heads, bias = o_heads[None], bias[None]
        gmo_rows, gmo_src = gmo_rows[None], gmo_src[None]
        gmo_head_ids, gmo_head_cnt = gmo_head_ids[None], gmo_head_cnt[None]
    b, h, n, dh = o_heads.shape
    f = w.shape[-1]
    assert n % block_rows == 0
    block_f = min(block_f, f)
    assert f % block_f == 0
    cr = gmo_rows.shape[-1]
    srow, jof, soff, slast = bucket_slot_layout(geometry)
    s_total = int(srow.shape[0])
    assert int(sum(r for r, _ in geometry)) == cr, (geometry, cr)
    grid = (b, f // block_f, s_total)

    # One trash row block past the real tokens: dead row slots (head_cnt
    # == 0) write nothing, but their out block still flushes whatever the
    # revisited buffer holds — point it at the pad and slice it off.
    pad = jnp.zeros((b, block_rows, f), bias.dtype)
    bias_pad = jnp.concatenate([bias, pad], axis=1)

    def o_map(bi, fi, s, srow_r, jof_r, soff_r, slast_r, rows_r, src_r,
              hid_r, cnt_r):
        r = srow_r[s]
        jj = jnp.maximum(jnp.minimum(jof_r[s], cnt_r[bi, r] - 1), 0)
        return (bi, hid_r[bi, soff_r[s] + jj], src_r[bi, r], 0)

    def w_map(bi, fi, s, srow_r, jof_r, soff_r, slast_r, rows_r, src_r,
              hid_r, cnt_r):
        r = srow_r[s]
        jj = jnp.maximum(jnp.minimum(jof_r[s], cnt_r[bi, r] - 1), 0)
        return (hid_r[bi, soff_r[s] + jj], 0, fi)

    def bias_map(bi, fi, s, srow_r, jof_r, soff_r, slast_r, rows_r, src_r,
                 hid_r, cnt_r):
        return (bi, rows_r[bi, srow_r[s]], fi)

    out = pl.pallas_call(
        _bucketed_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=8,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_rows, dh), o_map),
                pl.BlockSpec((1, dh, block_f), w_map),
                pl.BlockSpec((1, block_rows, block_f), bias_map),
            ],
            out_specs=pl.BlockSpec((1, block_rows, block_f), bias_map),
            scratch_shapes=[pltpu.VMEM((block_rows, block_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct(bias_pad.shape, bias.dtype),
        # NB: alias indices count the scalar-prefetch operands too.
        input_output_aliases={10: 0},                        # bias_pad -> out
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(srow), jnp.asarray(jof), jnp.asarray(soff),
      jnp.asarray(slast), gmo_rows, gmo_src, gmo_head_ids, gmo_head_cnt,
      o_heads, w, bias_pad)
    out = out[:, :n]
    return out[0] if squeeze else out
