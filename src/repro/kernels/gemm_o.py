"""FlashOmni GEMM-O — reduction-axis sparse output projection (paper §3.5,
Obs. 3, Eq. 3/4).

``Out_i = Σ_{h∈H_i} O_i^h W_h + OP_reuse(B_c)_i``: per live row block, only
the live heads are reduced; the cached heads' contribution arrives through
the Taylor-forecast bias ``B_c``.  The paper relaunches the kernel for its
two stages on GPU; on TPU both collapse into ONE kernel because the bias is
simply the accumulator's initial value (DESIGN §2.4).

Structure: grid ``(Cr, F_tiles, Hc)``, with per-row live-head CSR lists in
scalar memory.  The bias tensor is aliased to the output, so row blocks that
are never visited (fully cached rows) keep their forecast value — Eq. 4's
"cache-then-reuse branch terminates immediately" for free.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["gemm_o_sparse_kernel"]


def _kernel(row_ids_ref, head_ids_ref, head_cnt_ref,
            o_ref, w_ref, bias_ref, out_ref, acc_ref, *, hc: int):
    c, hh = pl.program_id(0), pl.program_id(2)

    @pl.when(hh == 0)
    def _init():
        acc_ref[...] = bias_ref[...].astype(jnp.float32)    # B_c as accumulator init

    @pl.when(hh < head_cnt_ref[c])
    def _accum():
        acc_ref[...] += jax.lax.dot(
            o_ref[0].astype(jnp.float32),
            w_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    # Padding slots (head_cnt == 0) duplicate the last live row id; they
    # must not store: with the bias-aliased output, re-initializing from
    # ``bias_ref`` would erase (interpret) or re-accumulate (TPU re-fetch
    # across f-tiles) the live slot's already-written result.
    @pl.when((hh == hc - 1) & (head_cnt_ref[c] > 0))
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def gemm_o_sparse_kernel(
    o_heads: jax.Array,    # (H, N, dh) attention outputs, head-major
    w: jax.Array,          # (H, dh, F) output projection, per-head
    bias: jax.Array,       # (N, F) OP_reuse(B_c) — aliased to the output
    row_ids: jax.Array,    # (Cr,) live row-block ids
    head_ids: jax.Array,   # (Cr, Hc) live head ids per row block
    head_cnt: jax.Array,   # (Cr,)
    *,
    block_rows: int,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    h, n, dh = o_heads.shape
    f = w.shape[-1]
    assert n % block_rows == 0
    block_f = min(block_f, f)
    assert f % block_f == 0
    cr, hc = head_ids.shape
    grid = (cr, f // block_f, hc)
    flat_heads = head_ids.reshape(-1)

    def o_map(c, fi, hh, rids, hids, hcnt):
        hh_c = jnp.maximum(jnp.minimum(hh, hcnt[c] - 1), 0)
        return (hids[c * hc + hh_c], rids[c], 0)

    def w_map(c, fi, hh, rids, hids, hcnt):
        hh_c = jnp.maximum(jnp.minimum(hh, hcnt[c] - 1), 0)
        return (hids[c * hc + hh_c], 0, fi)

    def bias_map(c, fi, hh, rids, hids, hcnt):
        return (rids[c], fi)

    return pl.pallas_call(
        functools.partial(_kernel, hc=hc),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_rows, dh), o_map),
                pl.BlockSpec((1, dh, block_f), w_map),
                pl.BlockSpec((block_rows, block_f), bias_map),
            ],
            out_specs=pl.BlockSpec((block_rows, block_f), bias_map),
            scratch_shapes=[pltpu.VMEM((block_rows, block_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct(bias.shape, bias.dtype),
        input_output_aliases={5: 0},                         # bias -> out
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(row_ids, flat_heads, head_cnt, o_heads, w, bias)
