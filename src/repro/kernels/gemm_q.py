"""FlashOmni GEMM-Q — spatial-axis sparse projection (paper §3.5, Obs. 2).

At *Dispatch* steps, row blocks whose attention output is fully cached never
need their query projection.  The GPU kernel decodes ``S_c`` per CTA and
early-exits; the TPU adaptation gathers the LIVE row blocks through a
scalar-prefetched index map, so dead rows cost neither MXU cycles nor DMA
(DESIGN §2.4).

The output is **compact** ``(Cr·bm, F)`` — live blocks in slot order.  The
FlashOmni attention CSR kernel consumes Q by live-slot index, so the compact
layout chains into attention without a scatter (layout fusion).  Use
:func:`repro.kernels.ops.scatter_rows` when the full-shape tensor is needed.

Batching is part of the KERNEL GRID: pass ``x`` as ``(B, N, K)`` with
``row_ids`` ``(B, Cr)`` and the grid grows a leading batch dimension —
one ``pallas_call`` covers the whole batch (no Python per-sample relaunch;
the scalar-prefetched ids are flattened ``(B·Cr,)`` and indexed by
``b·Cr + c``).  The unbatched ``(N, K)`` / ``(Cr,)`` signature still works.

Occupancy guard (``row_cnt``, ISSUE 8): GEMM-Q has no per-row reduction
occupancy to bucket — its reduction axis is the DENSE model dim ``K``, and
its spatial sparsity is already the compact ``Cr`` capacity (the paper's
1:1 density:speedup line).  What remains is the GPU kernel's ``S_c``
early-exit analogue: capacity-padding slots (``c ≥ row_cnt``) duplicate
the last live row id, and an unguarded kernel pays full MXU work to
compute a duplicate that every consumer masks off.  With ``row_cnt`` the
kernel skips the MXU on padded slots (the input re-DMA of the duplicated
block is elided by Mosaic) and stores deterministic ZEROS there — the
compact tail is defined output, not duplicated garbage.  The GEMM-Q grid
shares the attention kernel's Update-time sort: ``active_indices``
already orders live rows first, which IS the degenerate one-bucket
layout over the dense-``K`` reduction, so no second sort exists anywhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["gemm_q_sparse_kernel"]


def _kernel(row_ids_ref, row_cnt_ref, x_ref, w_ref, o_ref, acc_ref, *,
            n_k: int):
    bi, c, ki = pl.program_id(0), pl.program_id(1), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Padding slots (c >= row_cnt) skip the MXU entirely — their
    # accumulator stays zero, so the compact tail stores deterministic
    # zeros instead of a duplicate of the last live block.
    @pl.when(c < row_cnt_ref[bi])
    def _accum():
        acc_ref[...] += jax.lax.dot(
            x_ref[0].astype(jnp.float32),
            w_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gemm_q_sparse_kernel(
    x: jax.Array,          # (B, N, K) or (N, K)
    w: jax.Array,          # (K, F)
    row_ids: jax.Array,    # (B, Cr) or (Cr,) int32 live row-block ids
    *,
    block_rows: int,       # bm — MUST equal the symbol granularity divisor
    block_k: int = 512,
    block_f: int = 512,
    interpret: bool = False,
    row_cnt: Optional[jax.Array] = None,   # (B,) or () live-slot counts
) -> jax.Array:
    squeeze = x.ndim == 2
    if squeeze:
        x, row_ids = x[None], row_ids[None]
        if row_cnt is not None:
            row_cnt = jnp.asarray(row_cnt).reshape(1)
    b, n, kdim = x.shape
    f = w.shape[1]
    assert n % block_rows == 0
    assert row_ids.shape[0] == b
    block_k = min(block_k, kdim)
    block_f = min(block_f, f)
    assert kdim % block_k == 0 and f % block_f == 0
    cr = row_ids.shape[-1]
    n_k = kdim // block_k
    grid = (b, cr, f // block_f, n_k)
    if row_cnt is None:
        # No occupancy info: treat every slot as live (legacy duplicated-
        # tail behavior would differ — with the guard always on, padded
        # slots compute the duplicate like before the guard existed; all
        # callers in-tree pass the real counts).
        row_cnt = jnp.full((b,), cr, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_rows, block_k),
                             lambda bi, c, fi, ki, ids, cnt: (bi, ids[bi * cr + c], ki)),
                pl.BlockSpec((block_k, block_f),
                             lambda bi, c, fi, ki, ids, cnt: (ki, fi)),
            ],
            out_specs=pl.BlockSpec((1, block_rows, block_f),
                                   lambda bi, c, fi, ki, ids, cnt: (bi, c, fi)),
            scratch_shapes=[pltpu.VMEM((block_rows, block_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, cr * block_rows, f), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(row_ids.reshape(-1), row_cnt.reshape(-1).astype(jnp.int32), x, w)
    return out[0] if squeeze else out
