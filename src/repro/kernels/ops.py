"""Jit'd wrappers for the FlashOmni Pallas kernels.

These translate the engine's logical masks into the scalar-prefetch index
lists the kernels consume, pick interpret mode automatically off-TPU, and
guard the degenerate all-cached case (paper A.1.1 ``S_q`` degradation) where
the kernels would have no live work.

Tile shapes for the sparse GEMMs come from the calibration table in
:mod:`repro.kernels.tuning` (``kernel_tiles``), keyed per kernel kind and
reduction-width class — ``benchmarks/autotune.py`` populates it on real
TPUs; the checked-in default reproduces the hand-picked 512s.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.symbols import active_indices
from repro.kernels.flashomni_attention import (
    flashomni_attention_csr,
    flashomni_attention_symbols,
)
from repro.kernels.gemm_o import (gemm_o_sparse_bucketed_kernel,
                                  gemm_o_sparse_kernel)
from repro.kernels.gemm_q import gemm_q_sparse_kernel
from repro.kernels.taylor_reuse import taylor_reuse_kernel
from repro.kernels.tuning import kernel_tiles

__all__ = [
    "on_tpu",
    "flashomni_attention",
    "gemm_q",
    "gemm_o",
    "taylor_reuse",
    "scatter_rows",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def scatter_rows(compact: jax.Array, row_ids: jax.Array, row_cnt: jax.Array,
                 base: jax.Array, block: int) -> jax.Array:
    """Scatter a compact (Cr·block, F) result back into ``base`` (N, F)."""
    cr = row_ids.shape[0]
    t = base.shape[0] // block
    vals = compact.reshape(cr, block, -1)
    slot = jnp.arange(cr, dtype=jnp.int32)
    sid = jnp.where(slot < row_cnt, row_ids, t)
    padded = jnp.concatenate(
        [base.reshape(t, block, -1), jnp.zeros((1, block, base.shape[-1]), base.dtype)], 0)
    padded = padded.at[sid].set(vals.astype(base.dtype))
    return padded[:t].reshape(base.shape)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "variant",
                                             "cap_q", "cap_kv", "interpret",
                                             "kv_buckets", "heads"))
def flashomni_attention(
    q: jax.Array,            # (BH, N, d)
    k: jax.Array,
    v: jax.Array,
    m_c: jax.Array,          # (BH, T_q) bool, True = compute
    m_s: jax.Array,          # (BH, T_q, T_kv) bool
    o_reuse: jax.Array,      # (BH, N, d)
    *,
    block_q: int,
    block_kv: int,
    variant: str = "csr",
    cap_q: Optional[int] = None,
    cap_kv: Optional[int] = None,
    interpret: Optional[bool] = None,
    kv_buckets: int = 1,
    heads: int = 1,
) -> jax.Array:
    """Unified sparse attention entry (kernel side of paper Fig. 4).

    ``kv_buckets > 1`` routes to the occupancy-bucketed two-level grid:
    the leading axis is interpreted as ``B·heads`` and the bucket layout
    folds the head axis (short sliding-window rows share narrow buckets
    across heads).  NB: buckets may TRUNCATE a row's KV list to its slot
    width — callers compare against a reference fed the same truncated
    counts (see ``tests/test_bucketed.py``).
    """
    interpret = (not on_tpu()) if interpret is None else interpret
    t_q, t_kv = m_c.shape[-1], m_s.shape[-1]
    if variant == "symbols":
        from repro.core.symbols import pack_bits
        s_c = pack_bits(m_c)
        s_s = pack_bits(m_s.reshape(m_s.shape[0], -1))
        return flashomni_attention_symbols(
            q, k, v, o_reuse, s_c, s_s,
            block_q=block_q, block_kv=block_kv, interpret=interpret)
    cap_q = t_q if cap_q is None else cap_q
    cap_kv = t_kv if cap_kv is None else cap_kv
    q_ids, q_cnt = active_indices(m_c, cap_q)
    rows = jnp.take_along_axis(m_s, q_ids[..., None], axis=-2)       # (BH, Cq, Tkv)
    kv_ids, kv_cnt = active_indices(rows, cap_kv)
    if kv_buckets > 1:
        from repro.core.plan import bucket_geometry, bucket_layout
        from repro.kernels.flashomni_attention import (
            flashomni_attention_csr_bucketed,
        )
        bh = m_c.shape[0]
        assert bh % heads == 0, (bh, heads)
        b = bh // heads
        geometry = bucket_geometry(cap_q, cap_kv, heads, kv_buckets)
        shp = lambda a: a.reshape(b, heads, *a.shape[1:])
        score = jnp.sum(rows, axis=-1).astype(jnp.float32)   # live-mass proxy
        bkt, _ = bucket_layout(
            shp(q_ids), shp(q_cnt), shp(q_ids), shp(kv_ids), shp(kv_cnt),
            shp(score), geometry, t_q)
        return flashomni_attention_csr_bucketed(
            q, k, v, o_reuse,
            bkt["bkt_head"], bkt["bkt_q_ids"], bkt["bkt_q_src"],
            bkt["bkt_kv_ids"], bkt["bkt_kv_cnt"], geometry,
            heads=heads, block_q=block_q, block_kv=block_kv,
            interpret=interpret)
    out = flashomni_attention_csr(
        q, k, v, o_reuse, q_ids, kv_ids, kv_cnt,
        block_q=block_q, block_kv=block_kv, interpret=interpret)
    # Degenerate all-cached guard: the kernel writes garbage into the
    # duplicated slot-0 block when q_cnt == 0; select the pure-reuse tensor.
    any_live = (q_cnt > 0)[:, None, None]
    return jnp.where(any_live, out, o_reuse)


@functools.partial(jax.jit, static_argnames=("block_rows", "cap", "compact", "interpret"))
def gemm_q(
    x: jax.Array,            # (N, K)
    w: jax.Array,            # (K, F)
    row_mask: jax.Array,     # (T,) bool, T = N // block_rows
    *,
    block_rows: int,
    cap: Optional[int] = None,
    compact: bool = True,
    interpret: Optional[bool] = None,
):
    """GEMM-Q wrapper.  Returns ``(y, row_ids, row_cnt)``; ``y`` is compact
    (cap·block, F) when ``compact`` else scattered to (N, F) with zeros."""
    interpret = (not on_tpu()) if interpret is None else interpret
    t = row_mask.shape[-1]
    cap = t if cap is None else cap
    row_ids, row_cnt = active_indices(row_mask, cap)
    tiles = kernel_tiles("gemm_q", x.shape[-1])
    y = gemm_q_sparse_kernel(x, w, row_ids, block_rows=block_rows,
                             block_k=tiles.get("block_k", 512),
                             block_f=tiles.get("block_f", 512),
                             row_cnt=row_cnt, interpret=interpret)
    if not compact:
        base = jnp.zeros((x.shape[0], w.shape[-1]), x.dtype)
        y = scatter_rows(y, row_ids, row_cnt, base, block_rows)
    return y, row_ids, row_cnt


@functools.partial(jax.jit, static_argnames=("block_rows", "cap_rows", "cap_heads",
                                             "interpret", "hc_buckets"))
def gemm_o(
    o_heads: jax.Array,      # (H, N, dh)
    w: jax.Array,            # (H, dh, F)
    bias: jax.Array,         # (N, F) forecast OP_reuse(B_c)
    m_ch: jax.Array,         # (T, H) per-(row-block, head) live mask
    *,
    block_rows: int,
    cap_rows: Optional[int] = None,
    cap_heads: Optional[int] = None,
    interpret: Optional[bool] = None,
    hc_buckets: int = 1,
) -> jax.Array:
    """GEMM-O wrapper.  ``hc_buckets > 1`` routes to the occupancy-bucketed
    two-level grid over live-head counts (the GEMM-O analogue of the
    attention entry's ``kv_buckets``).  NB: buckets may TRUNCATE a row's
    head list to its slot width — callers compare against a reference fed
    the same truncated counts (see ``tests/test_bucketed_gemm.py``)."""
    interpret = (not on_tpu()) if interpret is None else interpret
    t, h = m_ch.shape
    cap_rows = t if cap_rows is None else cap_rows
    cap_heads = h if cap_heads is None else cap_heads
    live_rows = jnp.any(m_ch, axis=-1)
    row_ids, row_cnt = active_indices(live_rows, cap_rows)
    rows = jnp.take(m_ch, row_ids, axis=0)                           # (Cr, H)
    head_ids, head_cnt = active_indices(rows, cap_heads)
    # Padding slots duplicate the last live row; empty their head lists so
    # the bias-aliased kernel skips them (see _kernel's _done guard).
    head_cnt = jnp.where(jnp.arange(cap_rows) < row_cnt, head_cnt, 0)
    tiles = kernel_tiles("gemm_o", h)
    block_f = tiles.get("block_f", 512)
    if hc_buckets > 1:
        from repro.core.plan import bucket_geometry, gmo_layout
        geometry = bucket_geometry(cap_rows, cap_heads, 1, hc_buckets)
        # Live-head mass proxy for the sort's tie-break ranking (the plan
        # build uses the strategy's row_score here).
        score = jnp.sum(rows, axis=-1).astype(jnp.float32)
        gmo, _, _ = gmo_layout(row_ids[None], row_cnt.reshape(1),
                               head_ids[None], head_cnt[None], score[None],
                               geometry, t)
        out = gemm_o_sparse_bucketed_kernel(
            o_heads, w, bias, gmo["gmo_rows"][0], gmo["gmo_src"][0],
            gmo["gmo_head_ids"][0], gmo["gmo_head_cnt"][0], geometry,
            block_rows=block_rows, block_f=block_f, interpret=interpret)
        return jnp.where(row_cnt > 0, out, bias)
    out = gemm_o_sparse_kernel(o_heads, w, bias, row_ids, head_ids, head_cnt,
                               block_rows=block_rows, block_f=block_f,
                               interpret=interpret)
    return jnp.where(row_cnt > 0, out, bias)


@functools.partial(jax.jit, static_argnames=("block", "cap", "interpret"))
def taylor_reuse(
    derivs: jax.Array,       # (D+1, BH, N, d)
    coef: jax.Array,         # (D+1,) f32
    base: jax.Array,         # (BH, N, d)
    cached_mask: jax.Array,  # (BH, T) True = cached (forecast these blocks)
    *,
    block: int,
    cap: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = (not on_tpu()) if interpret is None else interpret
    t = cached_mask.shape[-1]
    cap = t if cap is None else cap
    ids, cnt = active_indices(cached_mask, cap)
    out = taylor_reuse_kernel(derivs, coef.reshape(1, -1).astype(jnp.float32),
                              base, ids, block=block, interpret=interpret)
    any_cached = (cnt > 0)[:, None, None]
    return jnp.where(any_cached, out, base)
