"""Pure-jnp oracles for the FlashOmni Pallas kernels.

Every kernel in this package has a reference here with identical semantics
(dense math + masking, no tiling).  Tests sweep shapes/dtypes and
``assert_allclose`` kernel vs oracle.

Mask convention: boolean, True = compute (matches the 1-bits of the paper's
sparse symbols).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref",
    "gemm_q_ref",
    "gemm_o_ref",
    "taylor_reuse_ref",
]

_NEG_INF = -1e30


def attention_ref(
    q: jax.Array,            # (BH, N, d)
    k: jax.Array,            # (BH, N_kv, d)
    v: jax.Array,            # (BH, N_kv, d)
    m_c: jax.Array,          # (BH, T_q)     True = compute
    m_s: jax.Array,          # (BH, T_q, T_kv)
    o_reuse: jax.Array,      # (BH, N, d)    value for cached rows
    *,
    block_q: int,
    block_kv: int,
    scale: Optional[float] = None,
) -> jax.Array:
    """FlashOmni attention oracle (paper Algorithm 1 semantics)."""
    n, d = q.shape[-2], q.shape[-1]
    n_kv = k.shape[-2]
    scale = (d ** -0.5) if scale is None else scale
    tok = jnp.repeat(jnp.repeat(m_s, block_q, axis=-2), block_kv, axis=-1)
    tok = tok[..., :n, :n_kv]
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    s = jnp.where(tok, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("...qk,...kd->...qd", p, v.astype(jnp.float32))
    row_live = jnp.repeat(m_c, block_q, axis=-1)[..., :n]
    return jnp.where(row_live[..., None], out.astype(q.dtype), o_reuse)


def gemm_q_ref(
    x: jax.Array,            # (N, K)
    w: jax.Array,            # (K, F)
    row_ids: jax.Array,      # (Cr,) live row-block ids (ascending, padded)
    row_cnt: jax.Array,      # ()    number of valid ids
    *,
    block: int,
) -> jax.Array:
    """GEMM-Q oracle: compact (Cr*block, F) projection of the gathered live
    row blocks.  Padding slots are ZEROS — the kernel's occupancy guard
    skips their MXU work and stores a deterministic empty tail (ISSUE 8),
    so the compact layout's dead capacity is defined output."""
    xb = x.reshape(-1, block, x.shape[-1])
    xg = jnp.take(xb, row_ids, axis=0)
    y = jnp.einsum("cbk,kf->cbf", xg.astype(jnp.float32), w.astype(jnp.float32))
    live = jnp.arange(row_ids.shape[0]) < row_cnt
    y = jnp.where(live[:, None, None], y, 0.0)
    return y.reshape(-1, w.shape[-1]).astype(x.dtype)


def gemm_o_ref(
    o_heads: jax.Array,      # (H, N, dh)
    w: jax.Array,            # (H, dh, F)
    bias: jax.Array,         # (N, F)  OP_reuse(B_c) forecast bias
    row_ids: jax.Array,      # (Cr,)
    row_cnt: jax.Array,      # ()
    head_ids: jax.Array,     # (Cr, Hc) live head ids per live row block
    head_cnt: jax.Array,     # (Cr,)
    *,
    block: int,
) -> jax.Array:
    """GEMM-O oracle (Eq. 3): ``Out_i = Σ_{h∈H_i} O_i^h W_h + bias_i`` for
    live row blocks; rows never visited keep ``bias`` (Eq. 4)."""
    h, n, dh = o_heads.shape
    f = w.shape[-1]
    t = n // block
    out = bias.astype(jnp.float32).reshape(t, block, f)
    cr, hc = head_ids.shape
    ob = o_heads.reshape(h, t, block, dh)

    def body(c, out):
        rid = row_ids[c]
        valid_row = c < row_cnt
        hmask = jnp.arange(hc) < head_cnt[c]
        og = ob[:, rid]                                     # (H, block, dh)
        sel = jnp.take(og, head_ids[c], axis=0)             # (Hc, block, dh)
        wg = jnp.take(w, head_ids[c], axis=0)               # (Hc, dh, F)
        part = jnp.einsum("cbd,cdf->bf",
                          jnp.where(hmask[:, None, None], sel, 0).astype(jnp.float32),
                          wg.astype(jnp.float32))
        new = bias.astype(jnp.float32).reshape(t, block, f)[rid] + part
        return out.at[rid].set(jnp.where(valid_row, new, out[rid]))

    out = jax.lax.fori_loop(0, cr, body, out)
    return out.reshape(n, f).astype(bias.dtype)


def taylor_reuse_ref(derivs: jax.Array, coefs: jax.Array) -> jax.Array:
    """OP_reuse oracle: ``Σ_d coefs[d] · derivs[d]`` (TaylorSeer forecast)."""
    return jnp.tensordot(coefs.astype(jnp.float32),
                         derivs.astype(jnp.float32), axes=(0, 0)).astype(derivs.dtype)
