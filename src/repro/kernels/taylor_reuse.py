"""OP_reuse element-wise kernel — TaylorSeer forecast over cached blocks.

The paper's cache-then-reuse branch performs "lightweight element-wise
operations (e.g., summation and multiplication in TaylorSeer)".  On TPU we
run it as a standalone VPU kernel over the CACHED blocks only (scalar-
prefetched id list), overlapping with the MXU-bound sparse attention kernel
at the XLA schedule level (DESIGN §2.3).

    out[block b] = Σ_d  coef[d] · derivs[d, block b]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["taylor_reuse_kernel"]


def _kernel(ids_ref, coef_ref, derivs_ref, base_ref, out_ref, *, order1: int):
    acc = coef_ref[0, 0] * derivs_ref[0, 0].astype(jnp.float32)
    for d in range(1, order1):
        acc += coef_ref[0, d] * derivs_ref[d, 0].astype(jnp.float32)
    out_ref[0] = acc.astype(out_ref.dtype)


def taylor_reuse_kernel(
    derivs: jax.Array,      # (D+1, BH, N, d) finite-difference stack
    coef: jax.Array,        # (1, D+1) f32 reuse coefficients (SMEM 2D)
    base: jax.Array,        # (BH, N, d) written-through baseline (aliased)
    ids: jax.Array,         # (BH, Cc) int32 cached block ids
    *,
    block: int,
    interpret: bool = False,
) -> jax.Array:
    order1, bhs, n, d = derivs.shape
    cc = ids.shape[1]
    assert n % block == 0

    def d_map(bh, c, ids_ref, coef_ref):
        return (0, bh, ids_ref[bh, c], 0)

    def o_map(bh, c, ids_ref, coef_ref):
        return (bh, ids_ref[bh, c], 0)

    return pl.pallas_call(
        functools.partial(_kernel, order1=order1),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(bhs, cc),
            in_specs=[
                pl.BlockSpec((order1, 1, block, d), d_map),
                pl.BlockSpec((1, block, d), o_map),
            ],
            out_specs=pl.BlockSpec((1, block, d), o_map),
        ),
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        input_output_aliases={3: 0},
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ids, coef, derivs, base)
