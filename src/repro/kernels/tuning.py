"""Plan-calibrated kernel tuning — calibration table + bucket cost model.

ISSUE 8 closes the loop between the Update-time occupancy histogram the
plan now carries (``DispatchPlan.occ_hist``, see
:func:`repro.core.plan.occupancy_histogram`) and two static decisions the
engine used to hard-code:

  * **Tile shapes** — ``block_k``/``block_f`` for the sparse GEMM kernels
    were fixed at 512.  :func:`kernel_tiles` looks them up per kernel kind
    and per reduction-width class in a JSON calibration table written by
    ``benchmarks/autotune.py`` (a real timing sweep on TPU; schema-only
    defaults elsewhere).
  * **Bucket count** — ``EngineConfig.kv_buckets`` was a static 1-or-3.
    With ``kv_buckets = 0`` (the "auto" sentinel) the engine calls
    :func:`select_kv_buckets` at schedule-resolution time: the calibrated
    per-strategy occupancy histogram feeds a cost model that picks from
    the static candidate set :data:`CANDIDATE_BUCKETS`.  The selection is
    a pure function of ``(strategy, table)`` — NO runtime plan data — so
    one configuration still lowers to exactly one executable and the
    ≤4-executable serving budget is untouched; Dispatch jaxprs stay
    sort-free because the choice happens before any trace.

Cost model: a bucketed grid has ``B/(2^B − 1)`` of the uniform slot count
(static, from :func:`repro.core.plan.bucket_geometry`), but rows whose
occupancy class is wider than the bucket capacity left for them get
CLAMPED — a fidelity cost, not a speed cost.  :func:`bucket_clamp_frac`
estimates the clamped-row fraction from the histogram (demand vs capacity
per width level, the same greedy order as the Update-time sort);
:func:`select_kv_buckets` takes the deepest candidate whose predicted
clamp fraction stays under ``bucket_model.max_clamp_frac``.  An
uncalibrated strategy falls back to 1 bucket (uniform grid) — never a
surprise clamp.

Table schema (version 1, see ``default_calibration.json``)::

    {
      "version": 1,
      "interpret_safe": true,          # written without a TPU timing sweep
      "tiles": {
        "gemm_q":    {"default": {"block_k": 512, "block_f": 512},
                      "<width>": {...}},       # per reduction-width class
        "gemm_o":    {"default": {"block_f": 512}, ...},
        "attention": {"default": {}}   # block_q/block_kv are mask-locked;
      },                               # reserved for future sweeps
      "bucket_model": {"max_clamp_frac": 0.02},
      "strategies": {
        "<strategy name>": {"occ_hist": [..OCC_BINS fractions..],
                            "rows": <live rows measured>}
      }
    }

The checked-in default table is conservative: tiles reproduce the
hand-picked 512s and the built-in strategies' histograms were measured
with interpret-mode kernels on CPU (occupancy is a plan property, not a
timing), so CPU CI and fresh clones never depend on having run a sweep.
``benchmarks/autotune.py --check`` validates the schema in CI.
"""

from __future__ import annotations

import functools
import json
from pathlib import Path
from typing import Optional

__all__ = [
    "CANDIDATE_BUCKETS",
    "DEFAULT_TABLE_PATH",
    "load_table",
    "validate_table",
    "kernel_tiles",
    "bucket_slot_frac",
    "bucket_clamp_frac",
    "select_kv_buckets",
]

#: Static bucket-count candidates — the executable budget math in
#: core/schedule.py assumes the choice set is small and fixed.
CANDIDATE_BUCKETS = (1, 2, 3)

KINDS = ("gemm_q", "gemm_o", "attention")

DEFAULT_TABLE_PATH = Path(__file__).with_name("default_calibration.json")

_FALLBACK_TABLE = {
    "version": 1,
    "interpret_safe": True,
    "tiles": {
        "gemm_q": {"default": {"block_k": 512, "block_f": 512}},
        "gemm_o": {"default": {"block_f": 512}},
        "attention": {"default": {}},
    },
    "bucket_model": {"max_clamp_frac": 0.02},
    "strategies": {},
}


@functools.lru_cache(maxsize=8)
def load_table(path: Optional[str] = None) -> dict:
    """Load (and memoize) a calibration table; schema-validated.

    ``path=None`` loads the checked-in default.  A missing or invalid
    file degrades to the built-in fallback (current kernel defaults, no
    strategy calibration → :func:`select_kv_buckets` returns 1) — tuning
    is an optimization, never a correctness dependency."""
    p = Path(path) if path is not None else DEFAULT_TABLE_PATH
    try:
        table = json.loads(p.read_text())
        validate_table(table)
    except (OSError, ValueError):
        return dict(_FALLBACK_TABLE)
    return table


def validate_table(table: dict) -> None:
    """Raise ``ValueError`` on any schema violation (see module docstring)."""
    if not isinstance(table, dict):
        raise ValueError("calibration table must be a JSON object")
    if table.get("version") != 1:
        raise ValueError(f"unsupported table version {table.get('version')!r}")
    tiles = table.get("tiles")
    if not isinstance(tiles, dict):
        raise ValueError("missing 'tiles' section")
    for kind in KINDS:
        entry = tiles.get(kind)
        if not isinstance(entry, dict) or "default" not in entry:
            raise ValueError(f"tiles[{kind!r}] needs a 'default' entry")
        for wkey, t in entry.items():
            if wkey != "default" and not wkey.isdigit():
                raise ValueError(f"tiles[{kind!r}] key {wkey!r} not a width")
            if not isinstance(t, dict):
                raise ValueError(f"tiles[{kind!r}][{wkey!r}] not an object")
            for name, v in t.items():
                if not (isinstance(v, int) and v > 0 and (v & (v - 1)) == 0):
                    raise ValueError(
                        f"tiles[{kind!r}][{wkey!r}][{name!r}] = {v!r} "
                        f"is not a positive power of two")
    model = table.get("bucket_model", {})
    mcf = model.get("max_clamp_frac", 0.02)
    if not (isinstance(mcf, (int, float)) and 0.0 <= mcf <= 1.0):
        raise ValueError(f"bucket_model.max_clamp_frac = {mcf!r} not in [0,1]")
    for name, ent in table.get("strategies", {}).items():
        hist = ent.get("occ_hist") if isinstance(ent, dict) else None
        if (not isinstance(hist, list) or not hist
                or any(not isinstance(x, (int, float)) or x < 0 for x in hist)):
            raise ValueError(
                f"strategies[{name!r}].occ_hist must be non-negative numbers")


def kernel_tiles(kind: str, width: Optional[int] = None,
                 table: Optional[dict] = None) -> dict:
    """Tile shapes for ``kind`` at reduction-width class ``width``.

    Exact width-class match wins, else the kind's ``default`` entry.  The
    returned dict holds static ints (``block_k``/``block_f``) merged over
    the default — callers keep their own hard defaults for keys the table
    omits."""
    table = load_table() if table is None else table
    entry = table["tiles"].get(kind, {})
    tiles = dict(entry.get("default", {}))
    if width is not None:
        tiles.update(entry.get(str(int(width)), {}))
    return tiles


def bucket_slot_frac(n_buckets: int) -> float:
    """Grid slots of a ``B``-bucket halving layout as a fraction of the
    uniform grid: ``B / (2^B − 1)`` (1.0, ≈0.67, ≈0.43 for B = 1, 2, 3)."""
    return n_buckets / float((1 << n_buckets) - 1)


def bucket_clamp_frac(hist, n_buckets: int) -> float:
    """Predicted clamped-row fraction of a ``B``-bucket layout.

    ``hist`` is the occupancy histogram over halving width classes
    (counts or fractions; class ``i`` = fits width ``⌈cap/2^{i+1}⌉``, so
    class 0 rows need a full-width slot).  The Update-time sort is greedy
    widest-demand-first, so rows of class ``≤ b`` overflow into clamping
    slots exactly when their cumulative demand exceeds the cumulative row
    capacity of buckets ``0..b`` (``2^b/(2^B − 1)`` rows each)."""
    total = float(sum(hist))
    if total <= 0.0 or n_buckets <= 1:
        return 0.0
    frac = [float(h) / total for h in hist]
    denom = float((1 << n_buckets) - 1)
    clamp = demand = cap = 0.0
    for b in range(n_buckets - 1):
        demand += frac[b] if b < len(frac) else 0.0
        cap += (1 << b) / denom
        clamp = max(clamp, demand - cap)
    return max(0.0, clamp)


def select_kv_buckets(strategy: str, table: Optional[dict] = None,
                      candidates=CANDIDATE_BUCKETS) -> int:
    """Pick the bucket count for a strategy from its calibrated histogram.

    Called at schedule-resolution time by
    :meth:`repro.core.engine.EngineConfig.resolved_kv_buckets` when
    ``kv_buckets == 0``.  Deepest candidate whose predicted clamp fraction
    stays under ``bucket_model.max_clamp_frac`` wins (deeper = fewer grid
    slots); an uncalibrated strategy returns 1 (uniform grid, no surprise
    truncation).  Pure in ``(strategy, table)`` — same config, same
    executable."""
    table = load_table() if table is None else table
    ent = table.get("strategies", {}).get(str(strategy))
    if not ent:
        return 1
    hist = ent.get("occ_hist", [])
    max_clamp = table.get("bucket_model", {}).get("max_clamp_frac", 0.02)
    best = 1
    for b in sorted(candidates):
        if b == 1:
            continue
        if bucket_clamp_frac(hist, b) <= max_clamp \
                and bucket_slot_frac(b) < bucket_slot_frac(best):
            best = b
    return int(best)
