"""Continuous-batching request queue over mixed SparsitySchedules.

The paper's deployment scenario is a served Hunyuan-class model under
real traffic.  Single-request serving leaves two wins on the table:

  * **Stacked batching** — requests that share a data shape AND a
    resolved schedule are pure batch parallelism: concatenate them on the
    batch axis and run the cached single-scan sampler once.  Per-lane
    outputs are BIT-IDENTICAL to sequential runs (batch stacking changes
    no per-sample op shapes' reduction axes), test-enforced.
  * **Continuous batching** — requests whose schedules differ (length,
    strategy mix, per-layer tables) cannot stack, but they CAN interleave:
    a fixed-width microbatch of lanes, each holding one request, advances
    every lane by one denoising step per serving tick.  The host reads
    each lane's ``(mode, strategy-id row)`` from the lane's own schedule
    table BEFORE launching the tick: a mode-homogeneous tick folds the
    lanes into the model's batch axis through one batched mode body
    (same-mode lane folding — stacked-level lane parallelism), and only
    genuinely mixed ticks take the lane-serial scan whose body
    ``lax.switch``es per lane.  Either way the tables are TRACED
    (:func:`repro.core.schedule.stack_schedules` pads mixed lengths with
    ``MODE_IDLE``), so lanes retire and refill WITHOUT recompiling — a
    fixed budget of at most FOUR executables per distinct lane shape
    (dense/update/dispatch group bodies + the mixed fallback), regardless
    of how many schedule variants flow through (the xDiT / Sparse-vDiT
    serving observation: keep heterogeneous sparse configs resident in
    one engine).  A sequential server instead pays one compiled sampler
    per distinct configuration.

Module contents:

  * :class:`Request` / :class:`RequestQueue` — arrival-ordered FIFO.
  * :func:`run_sequential`    — baseline: one ``pipeline.sample`` per
    request (shares compiled samplers via the pipeline's LRU cache).
  * :func:`run_stacked`       — group by (shape, schedule), stack on the
    batch axis, one sampler call per group.
  * :class:`ContinuousBatcher` — the lane engine described above.

``benchmarks/bench_serving.py`` measures all three (req/s, p50/p95
latency) and asserts the per-lane bit-parity acceptance criterion.
"""

from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.engine import (EngineConfig, resolve_schedule,
                               stack_lane_states)
from repro.core.schedule import (MODE_IDLE, MODE_NAMES, merge_strategies,
                                 schedule_lane_rows, tick_mode_groups)
from repro.core.strategy import strategy_key
from repro.diffusion.pipeline import (SamplerConfig, make_grouped_lane_tick,
                                      make_lane_tick, sample)
from repro.models import dit

__all__ = ["Request", "RequestQueue", "ContinuousBatcher",
           "run_sequential", "run_stacked", "default_patch_embed"]


def default_patch_embed(cfg: ArchConfig, patch_dim: int) -> jax.Array:
    """The stub patchifier ``pipeline.sample`` defaults to — every serving
    mode must share it or per-lane parity is meaningless."""
    return jax.random.normal(jax.random.PRNGKey(7),
                             (patch_dim, cfg.d_model)) * 0.2


@dataclasses.dataclass
class Request:
    """One text-to-vision serving request.

    ``x0`` (B, N_v, patch_dim) Gaussian latents; ``text_emb`` (B, N_t,
    d_model); ``schedule`` / ``layer_strategies`` feed
    :func:`repro.core.engine.resolve_schedule` against the server's shared
    ``EngineConfig`` (``None`` → the config's own strategy/interval
    mapping).  ``arrival`` is seconds since the serving clock's start.
    """

    rid: Any
    x0: jax.Array
    text_emb: jax.Array
    num_steps: int
    schedule: Any = None
    layer_strategies: Any = None
    arrival: float = 0.0

    def resolve(self, ecfg: EngineConfig, n_layers: int):
        return resolve_schedule(ecfg, self.num_steps, n_layers,
                                schedule=self.schedule,
                                layer_strategies=self.layer_strategies)

    def shape_key(self) -> tuple:
        """Lane-shape key: requests in one microbatch must agree on it."""
        return (self.x0.shape, str(self.x0.dtype),
                self.text_emb.shape, str(self.text_emb.dtype))


class RequestQueue:
    """Arrival-ordered FIFO (stable for equal arrival times)."""

    def __init__(self):
        self._items: list[tuple[float, int, Request]] = []
        self._seq = 0

    def submit(self, req: Request) -> None:
        # The backing list is kept sorted by (arrival, seq) at all times,
        # so one bisect insertion is O(log n) compares + O(n) moves —
        # re-sorting the whole list per insert made submit_all O(n² log n).
        # The monotone ``seq`` tiebreak means the comparison never reaches
        # the (unorderable) Request itself and equal arrivals stay FIFO.
        bisect.insort(self._items, (req.arrival, self._seq, req))
        self._seq += 1

    def submit_all(self, reqs) -> None:
        for r in reqs:
            self.submit(r)

    def __len__(self) -> int:
        return len(self._items)

    def pending(self) -> list[Request]:
        return [r for _, _, r in self._items]

    def next_arrival(self) -> Optional[float]:
        return self._items[0][0] if self._items else None

    def pop_ready(self, now: float) -> Optional[Request]:
        """Pop the earliest request whose arrival time has passed."""
        if self._items and self._items[0][0] <= now:
            return self._items.pop(0)[2]
        return None


# ---------------------------------------------------------------------------
# Sequential + stacked serving (the baselines the batcher must beat)
# ---------------------------------------------------------------------------

def _result(out, trace, arrival, finish):
    return {"out": out, "trace": trace, "finish": finish,
            "latency": finish - arrival}


def run_sequential(params, cfg: ArchConfig, ecfg: EngineConfig, requests,
                   *, scfg_dtype=jnp.float32, patch_embed=None,
                   collect_traces: bool = True) -> dict:
    """Baseline server: requests strictly one after another (arrival
    order), each through its own ``pipeline.sample`` call.  Compiled
    samplers are shared across same-config requests via the pipeline's
    LRU cache; every DISTINCT configuration still pays its own compile."""
    if patch_embed is None and requests:
        patch_embed = default_patch_embed(cfg, requests[0].x0.shape[-1])
    results: dict = {}
    t0 = time.perf_counter()
    for req in sorted(requests, key=lambda r: r.arrival):
        now = time.perf_counter() - t0
        if now < req.arrival:
            time.sleep(req.arrival - now)
        trace: list = [] if collect_traces else None
        out = sample(params, cfg, ecfg, text_emb=req.text_emb, x0=req.x0,
                     scfg=SamplerConfig(num_steps=req.num_steps,
                                        dtype=scfg_dtype),
                     patch_embed=patch_embed, trace=trace,
                     schedule=req.schedule,
                     layer_strategies=req.layer_strategies)
        jax.block_until_ready(out)
        results[req.rid] = _result(np.asarray(out), trace, req.arrival,
                                   time.perf_counter() - t0)
    return results


def run_stacked(params, cfg: ArchConfig, ecfg: EngineConfig, requests,
                *, scfg_dtype=jnp.float32, patch_embed=None) -> dict:
    """Stack same-shape/same-schedule requests into one batch axis.

    Grouping key = (data shapes, resolved-schedule identity): thanks to
    the memoized :func:`resolve_schedule`, equal specs resolve to the SAME
    schedule object, so grouping by ``id(schedule)`` is exact — each group
    VALUE pins its schedule object alive, so an id can never be recycled
    by a different schedule while grouping (the resolution memo is
    LRU-bounded and may drop its own reference).  Each group runs ONE
    cached single-scan sampler call over the concatenated batch; outputs
    split back per request and are bit-identical to sequential runs
    (test-enforced).  A group starts once ALL its members arrived.
    Per-request traces are not recorded — step metrics of a stacked run
    average over the whole stacked batch (use the continuous batcher for
    per-lane metrics).
    """
    if patch_embed is None and requests:
        patch_embed = default_patch_embed(cfg, requests[0].x0.shape[-1])
    groups: dict[tuple, tuple] = {}
    for req in sorted(requests, key=lambda r: r.arrival):
        sched = req.resolve(ecfg, cfg.n_layers)
        groups.setdefault((req.shape_key(), req.num_steps, id(sched)),
                          (sched, []))[1].append(req)
    results: dict = {}
    t0 = time.perf_counter()
    for (_, num_steps, _), (_, members) in groups.items():
        ready = max(r.arrival for r in members)
        now = time.perf_counter() - t0
        if now < ready:
            time.sleep(ready - now)
        x0 = jnp.concatenate([r.x0 for r in members], axis=0)
        text = jnp.concatenate([r.text_emb for r in members], axis=0)
        out = sample(params, cfg, ecfg, text_emb=text, x0=x0,
                     scfg=SamplerConfig(num_steps=num_steps,
                                        dtype=scfg_dtype),
                     patch_embed=patch_embed,
                     schedule=members[0].schedule,
                     layer_strategies=members[0].layer_strategies)
        jax.block_until_ready(out)
        finish = time.perf_counter() - t0
        off = 0
        for r in members:
            b = r.x0.shape[0]
            results[r.rid] = _result(np.asarray(out[off:off + b]), None,
                                     r.arrival, finish)
            off += b
    return results


# ---------------------------------------------------------------------------
# Continuous batcher
# ---------------------------------------------------------------------------

def _lockstep_capable(schedules) -> bool:
    """True when every queued schedule shares one mode table and length.

    The ``grouped="auto"`` policy input: such a mix keeps resident lanes
    mode-homogeneous whenever they fill together, so the batched
    mode-group bodies earn their compiles; any other mix de-synchronizes
    and would mostly pay for executables the scan fallback replaces."""
    ref: Optional[np.ndarray] = None
    for sched in schedules:
        mode = np.asarray(sched.mode)
        if ref is None:
            ref = mode
        elif mode.shape != ref.shape or not np.array_equal(mode, ref):
            return False
    return True

class ContinuousBatcher:
    """Fixed-width microbatch server over mixed SparsitySchedules.

    ``lanes`` requests are resident at once; every serving tick advances
    each active lane by one denoising step.  A lane whose request reaches
    its own ``num_steps`` RETIRES (output captured) and REFILLS from the
    queue as soon as a request's arrival time passes — all by swapping
    traced data, so the ticks never recompile:

      * per-lane ``(mode, strategy-id)`` rows come from the stacked
        schedule tables (``MODE_IDLE``-padded, strategy ids remapped onto
        the merged strategy universe of all queued requests);
      * per-lane engine states re-initialize ON DEVICE via the tick's
        traced ``reset`` mask (the fresh state is a trace constant), so a
        refill host-writes only the lane's latent/text buffers;
      * empty lanes pass through and contribute EXACTLY zero to the
        per-lane metric outputs (test-enforced).

    Tick dispatch (same-mode lane folding): the lane tables are
    host-visible, so each tick partitions the active lanes by current
    mode (:func:`repro.core.schedule.tick_mode_groups`).  A mode-
    HOMOGENEOUS tick — the steady state whenever resident lanes run the
    same schedule phase, e.g. a homogeneous request mix in lockstep —
    runs one batched mode body (:func:`repro.diffusion.pipeline.
    make_grouped_lane_tick`): the lanes fold into the model's batch axis
    and advance in parallel, recovering stacked-serving throughput.
    Genuinely mixed ticks fall back to the lane-serial scan tick.  The
    compiled-executable budget is FIXED and shape-independent: at most 4
    per distinct lane shape (dense / update / dispatch group bodies + the
    mixed fallback; ``stats["executables"]``, test-enforced ≤ 4), and
    per-lane outputs are bit-identical to sequential runs of the same
    requests on either path (the serving benchmark asserts this).

    ``max_steps`` fixes the padded schedule-table width (default: longest
    queued schedule at ``run`` time; a fixed value keeps the lane shape —
    and hence the executables — stable across ``run`` calls).

    ``shape_buckets`` (ISSUE 6 tentpole, the lane-level analogue of the
    kernel's occupancy buckets): a production mix of NEAR-MISS resolutions
    fragments the exact-``shape_key()`` partitioning into many lane
    partitions, each paying its own compile.  Passing a small tuple of
    canonical vision-token counts (e.g. ``(64, 96, 128)``) rounds each
    request's ``N_v`` UP to the smallest bucket that fits at admission —
    the latent is zero-padded into the lane buffer and the output sliced
    back to the request's own length — so near-miss shapes share ONE lane
    executable and the ≤ 4-executable budget holds across the mix.  A
    request larger than every bucket passes through at its own shape.
    Per-request outputs equal a sequential run of the same PADDED request
    sliced identically (bit-parity test-enforced); the mapping actually
    used is reported in ``stats["shape_buckets"]`` (the lane-bucket map
    ``serve.py --serving continuous`` prints).

    ``grouped`` picks the folding policy.  ``"auto"`` (default) enables
    the mode-group bodies for a ``run`` only when every queued request
    resolves to the SAME mode table and length — the lockstep-capable mix
    where folding recovers stacked-level throughput; a heterogeneous mix
    would compile group bodies it can rarely use (every de-synchronized
    tick takes the scan anyway), so auto keeps it on the one-executable
    scan and preserves the cold-serving win over sequential.  ``True``
    folds every mode-homogeneous tick regardless of the queued mix;
    ``False`` disables folding entirely (the safety valve for backends
    whose kernels cannot lower under ``vmap``).  ``with_metrics=False``
    skips the per-tick density / pair-sparsity reductions for
    pure-throughput serving (lane metric stats and per-request trace
    metrics read as zero).
    """

    def __init__(self, params, cfg: ArchConfig, ecfg: EngineConfig, *,
                 lanes: int = 4, max_steps: Optional[int] = None,
                 scfg_dtype=jnp.float32, patch_embed=None,
                 sync_every_tick: bool = True, grouped="auto",
                 with_metrics: bool = True,
                 shape_buckets: Optional[tuple] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.lanes = int(lanes)
        self.max_steps = max_steps
        self.shape_buckets = (tuple(sorted(int(s) for s in shape_buckets))
                              if shape_buckets else ())
        self.scfg = SamplerConfig(num_steps=0, dtype=scfg_dtype)
        self.patch_embed = patch_embed
        self.sync_every_tick = sync_every_tick
        self.grouped = grouped
        self.with_metrics = with_metrics
        if grouped not in ("auto", True, False):
            raise ValueError(f"grouped must be 'auto', True or False, "
                             f"got {grouped!r}")
        self.queue = RequestQueue()
        self.stats: dict = {}
        self._tick = None
        self._grouped_ticks: Optional[dict] = None
        self._use_grouped = False        # per-run policy decision
        self._universe: tuple = ()
        self._retired_executables = 0    # compiled by discarded tick jits

    def submit(self, req: Request) -> None:
        self.queue.submit(req)

    def submit_all(self, reqs) -> None:
        self.queue.submit_all(reqs)

    # -- internals --------------------------------------------------------

    def _bucket_nv(self, nv: int) -> int:
        """Smallest canonical vision length that fits ``nv`` (or ``nv``)."""
        for b in self.shape_buckets:
            if b >= nv:
                return b
        return nv

    def _canon_key(self, req: Request) -> tuple:
        """``shape_key()`` with ``N_v`` rounded up to its shape bucket."""
        b, nv, pd = req.x0.shape
        return ((b, self._bucket_nv(nv), pd), str(req.x0.dtype),
                req.text_emb.shape, str(req.text_emb.dtype))

    def _cache_sizes(self) -> int:
        """Live compiled-executable count across all tick jits."""
        fns = [self._tick] + (list(self._grouped_ticks.values())
                              if self._grouped_ticks else [])
        return sum(int(f._cache_size()) for f in fns if f is not None)

    def _ensure_tick(self, schedules) -> None:
        """(Re)build the jitted ticks when the strategy universe grows.

        The universe is the ticks' STATIC closure; growing it re-traces.
        Requests whose strategies are already resident — by VALUE
        (:func:`repro.core.strategy.strategy_key`), so a re-resolved spec
        whose memo entry was LRU-evicted still counts as resident — never
        do."""
        known = {strategy_key(s) for s in self._universe}
        new: list = []
        for sched in schedules:
            for s in sched.strategies:
                key = strategy_key(s)
                if key not in known:
                    known.add(key)
                    new.append(s)
        if self._tick is None or new:
            if self._tick is not None:
                # A growing universe re-traces EVERYTHING — keep the old
                # ticks' executables in the count so the recompile is
                # visible in stats["executables"].
                self._retired_executables += self._cache_sizes()
            self._universe = self._universe + tuple(new)
            self._tick = make_lane_tick(self.cfg, self.ecfg, self.scfg,
                                        self._universe, self.with_metrics)
            self._grouped_ticks = (
                make_grouped_lane_tick(self.cfg, self.ecfg, self.scfg,
                                       self._universe, self.with_metrics)
                if self.grouped else None)

    def run(self) -> dict:
        """Drain the queue; returns {rid: {out, trace, latency, finish}}.

        Requests are partitioned by lane shape (each partition runs the
        microbatch loop with its own lane buffers; partitions share the
        jitted tick, so ``stats["executables"]`` counts one executable
        per distinct lane shape)."""
        reqs = [self.queue.pop_ready(float("inf"))
                for _ in range(len(self.queue))]
        scheds = {id(r): r.resolve(self.ecfg, self.cfg.n_layers)
                  for r in reqs}
        self._ensure_tick(scheds.values())
        self._use_grouped = self._grouped_ticks is not None and (
            self.grouped is True or _lockstep_capable(scheds.values()))
        s_max = self.max_steps or max((r.num_steps for r in reqs), default=1)
        # Shape-bucketed partitioning: near-miss N_v resolutions fold into
        # one canonical lane shape (see class docstring) instead of each
        # compiling its own partition.
        by_shape: dict[tuple, list[Request]] = {}
        bucket_map: dict[tuple, tuple] = {}
        for r in reqs:
            key = self._canon_key(r)
            bucket_map[r.shape_key()] = key
            by_shape.setdefault(key, []).append(r)
        results: dict = {}
        total_ticks = 0
        grouped_ticks = 0
        lane_density: list[np.ndarray] = []
        lane_pairs: list[np.ndarray] = []
        lane_active: list[np.ndarray] = []
        # ONE serving clock across partitions: latency/finish times and
        # arrival simulation include time spent queued behind an earlier
        # lane-shape partition.
        t0 = time.perf_counter()
        for key, shape_reqs in by_shape.items():
            q = RequestQueue()
            q.submit_all(shape_reqs)
            part, ticks, gticks, dens, ps, act = self._run_partition(
                q, scheds, s_max, t0, nv_lane=key[0][1])
            results.update(part)
            total_ticks += ticks
            grouped_ticks += gticks
            lane_density.append(dens)
            lane_pairs.append(ps)
            lane_active.append(act)
        self.stats = {
            "executables": self._cache_sizes() + self._retired_executables,
            "ticks": total_ticks,
            "grouped_ticks": grouped_ticks,
            "scan_ticks": total_ticks - grouped_ticks,
            "lanes": self.lanes,
            "max_steps": s_max,
            "strategies": [s.name for s in self._universe],
            "lane_density": (np.concatenate(lane_density)
                             if lane_density else np.zeros((0, self.lanes))),
            "lane_pair_sparsity": (np.concatenate(lane_pairs)
                                   if lane_pairs else
                                   np.zeros((0, self.lanes))),
            "lane_active": (np.concatenate(lane_active)
                            if lane_active else
                            np.zeros((0, self.lanes), bool)),
            "shape_buckets": bucket_map,
            "shape_partitions": len(by_shape),
        }
        return results

    def _run_partition(self, q: RequestQueue, scheds: dict, s_max: int,
                       t0: float, nv_lane: Optional[int] = None):
        cfg, ecfg, W = self.cfg, self.ecfg, self.lanes
        probe = q.pending()[0]
        b, nv, pd = probe.x0.shape
        # The partition's canonical (bucketed) vision length; requests
        # shorter than the lane are zero-padded in and sliced back out.
        nv = nv if nv_lane is None else nv_lane
        nt, dm = probe.text_emb.shape[1], cfg.d_model
        n_tokens = nv + nt
        patch_embed = self.patch_embed
        if patch_embed is None:
            patch_embed = default_patch_embed(cfg, pd)

        x = jnp.zeros((W, b, nv, pd), probe.x0.dtype)
        text = jnp.zeros((W, b, nt, dm), probe.text_emb.dtype)
        states = stack_lane_states(
            dit.init_engine_states(cfg, ecfg, b, n_tokens), W)
        mode_tab = np.full((W, s_max), MODE_IDLE, np.int32)
        id_tab = np.zeros((W, s_max, cfg.n_layers), np.int32)
        dt = np.zeros((W,), np.float32)
        nsteps = np.zeros((W,), np.int32)
        steps = np.zeros((W,), np.int32)
        active = np.zeros((W,), bool)
        reset = np.zeros((W,), bool)
        lane_req: list[Optional[Request]] = [None] * W

        results: dict = {}
        pending_out: list = []
        tick_log: list = []
        hist: list = []
        act_log: list = []
        ticks = 0
        grouped_ticks = 0
        while len(q) or active.any():
            now = time.perf_counter() - t0
            for w in range(W):
                if active[w]:
                    continue
                req = q.pop_ready(now)
                if req is None:
                    break
                sched = scheds[id(req)]
                mrow, irow = schedule_lane_rows(sched, self._universe, s_max)
                mode_tab[w], id_tab[w] = mrow, irow
                dt[w] = np.float32(1.0 / req.num_steps)
                nsteps[w] = req.num_steps
                x0w = req.x0
                if x0w.shape[1] < nv:      # shape-bucket zero pad
                    x0w = jnp.pad(
                        x0w, ((0, 0), (0, nv - x0w.shape[1]), (0, 0)))
                x = x.at[w].set(x0w)
                text = text.at[w].set(req.text_emb)
                # Engine state re-initializes ON DEVICE inside the tick
                # (traced `reset` mask -> trace-constant fresh state): a
                # refill costs two latent/text writes, not a whole
                # LayerState pytree of host dispatches.
                reset[w] = True
                steps[w], active[w], lane_req[w] = 0, True, req
            if not active.any():
                # Nothing resident and nothing ready yet: idle until the
                # next arrival instead of burning no-op ticks.
                na = q.next_arrival()
                wait = 0.0 if na is None else na - (time.perf_counter() - t0)
                if wait > 0:
                    time.sleep(wait)
                continue
            groups = tick_mode_groups(mode_tab, steps, active)
            if self._use_grouped and len(groups) == 1:
                # Mode-homogeneous tick: fold the lanes into the model
                # batch axis through the matching mode-group body.
                mode, mask = groups[0]
                id_rows = id_tab[np.arange(W), np.clip(steps, 0, s_max - 1)]
                x, states, dens, ps = self._grouped_ticks[MODE_NAMES[mode]](
                    self.params, patch_embed, x, states, text,
                    jnp.asarray(steps), jnp.asarray(id_rows),
                    jnp.asarray(dt), jnp.asarray(nsteps), jnp.asarray(mask),
                    jnp.asarray(reset))
                grouped_ticks += 1
            else:
                # Genuinely mixed modes: lane-serial scan fallback.
                x, states, dens, ps = self._tick(
                    self.params, patch_embed, x, states, text,
                    jnp.asarray(steps), jnp.asarray(mode_tab),
                    jnp.asarray(id_tab), jnp.asarray(dt),
                    jnp.asarray(nsteps), jnp.asarray(active),
                    jnp.asarray(reset))
            reset[:] = False
            if self.sync_every_tick:
                jax.block_until_ready(x)
            hist.append((dens, ps))
            act_log.append(active.copy())
            log = []
            now = time.perf_counter() - t0
            for w in range(W):
                if not active[w]:
                    continue
                req = lane_req[w]
                kind = MODE_NAMES[int(mode_tab[w, steps[w]])]
                log.append((w, req.rid, int(steps[w]), kind))
                steps[w] += 1
                if steps[w] >= req.num_steps:
                    # Slice the shape-bucket pad back off (no-op when the
                    # request filled its lane).
                    pending_out.append((req.rid, x[w][:, :req.x0.shape[1]]))
                    results[req.rid] = _result(None, [], req.arrival, now)
                    active[w], lane_req[w] = False, None
            tick_log.append(log)
            ticks += 1

        # ONE host sync for outputs + the whole per-lane metric history.
        outs = jax.device_get([o for _, o in pending_out])
        for (rid, _), o in zip(pending_out, outs):
            results[rid]["out"] = np.asarray(o)
        if hist:
            dens_h = np.asarray(jax.device_get(jnp.stack(
                [d for d, _ in hist])))
            ps_h = np.asarray(jax.device_get(jnp.stack(
                [p for _, p in hist])))
        else:
            dens_h = ps_h = np.zeros((0, W), np.float32)
        for t_idx, log in enumerate(tick_log):
            for w, rid, step, kind in log:
                results[rid]["trace"].append({
                    "step": step, "kind": kind,
                    "density": float(dens_h[t_idx, w]),
                    "pair_sparsity": float(ps_h[t_idx, w])})
        act_h = (np.stack(act_log) if act_log
                 else np.zeros((0, W), bool))
        return results, ticks, grouped_ticks, dens_h, ps_h, act_h
