import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks the device count on first
#   init).  The 512 placeholder host devices exist ONLY for the dry-run.
#   ``setdefault`` so CI can pin a smaller forced-device count (the
#   8-device sharded-parity job reuses ``--sharded-gate`` on its mesh).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
record memory/cost/collective analyses for EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, arch_shapes, get_config
from repro.launch.mesh import make_production_mesh, rules_for
from repro.launch import steps as ST

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3": 1, "f8e5m2": 1}

# HLO text: ``%all-reduce.705 = f32[256,4096]{1,0} all-reduce(%x), ...`` —
# operands are bare names; we account the RESULT shape as bytes moved
# (all-gather result = bytes received per device; all-reduce ≈ tensor size;
# reduce-scatter result = shard received; a2a tuple = total moved).
_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9_\[\]{},]+)\s+"
    r"(ragged-all-to-all|all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute|collective-broadcast)"
    r"(?:-start)?\(")
# ``ragged-all-to-all`` must precede ``all-to-all`` in the alternation and
# both must be present: the plan-sharded dispatch exchange lowers to one of
# these, and a gate reading 0 bytes because the op name was missing from
# this list would pass vacuously (see ``--sharded-gate``).
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
                       r"f8e4m3\w*|f8e5m2\w*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    for k, v in _DTYPE_BYTES.items():
        if dtype.startswith(k):
            return n * v
    return n * 4


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind (async `-done` ops excluded)."""
    out: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(1)))
        out[kind] = out.get(kind, 0) + total
        out.setdefault(kind + "_count", 0)
        out[kind + "_count"] += 1
    return out


def sharded_dispatch_report(out_dir: Path, *, mesh_sp: int = 8,
                            density: float = 0.25,
                            pair_slack: float = 1.5) -> dict:
    """Account the plan-sharded dispatch's collective bytes statically.

    Builds a small engine cell at ``cap_kv_frac = density`` and traces the
    mesh-sharded attention (``distributed/plan_shard.mesh_attention``) plus
    a dense baseline that all-gathers the full K/V over the same mesh.  The
    byte totals come from the STATIC cost model
    (:func:`repro.analysis.cost_model.cost_of_jaxpr` over the jaxprs — the
    same interpreter the ``cost-collective-bytes`` analyzer pass certifies
    against the ``pair_cap`` formula), so the numbers are exact by
    construction and independent of HLO lowering details.  The compiled
    HLO is still parsed via :func:`collective_bytes`, but only as a
    CROSS-CHECK recorded in the report: ``--sharded-gate`` asserts the HLO
    parse sees nonzero all-to-all bytes agreeing with the static payload,
    so a stale op regex (the PR-7 whack-a-mole) or a lowering that stops
    matching the model both fail loudly instead of gating vacuously.

    The plan-aware exchange ships only ``mesh_sp · pair_cap`` blocks per
    shard (vs ``T_kv`` for the dense all-gather), so at 25% density and
    default slack the ratio lands at
    ``⌈slack · cap_kv / P⌉ · P / T_kv ≈ 0.375`` — the ``--sharded-gate``
    CI flag asserts it stays below 0.5.
    """
    import jax.numpy as jnp

    from repro.core import engine as E
    from repro.core.backend import get_backend
    from repro.core.engine import (AttnParams, EngineConfig, init_layer_state,
                                   update_layer)
    from repro.core.masks import MaskConfig
    from repro.distributed.plan_shard import (dense_exchange_blocks,
                                              exchange_blocks, shard_geometry)
    from repro.launch.mesh import make_engine_mesh

    b, heads, n, dm, dh = 1, 2, 1024, 32, 16
    m = MaskConfig(tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.3,
                   block_q=16, block_kv=16, pool=16, warmup_steps=2)
    cfg = EngineConfig(mask=m, backend="xla", cap_kv_frac=density,
                       mesh_dp=1, mesh_sp=mesh_sp, mesh_pair_slack=pair_slack)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    params = AttnParams(
        wq=jax.random.normal(ks[0], (dm, heads * dh)) * 0.05,
        wk=jax.random.normal(ks[1], (dm, heads * dh)) * 0.05,
        wv=jax.random.normal(ks[2], (dm, heads * dh)) * 0.05,
        wo=jax.random.normal(ks[3], (heads * dh, dm)) * 0.05,
        q_scale=jnp.ones((dh,)), k_scale=jnp.ones((dh,)))
    x = jax.random.normal(ks[4], (b, n, dm), jnp.float32)
    st0 = init_layer_state(b, heads, n, dm, dh, cfg)
    _, st = update_layer(params, x, st0, cfg, heads=heads)
    plan = st.plan.widen()
    spec = cfg.caps(n)
    q, k = E._qk(params, x, heads, None)
    v = E._project_heads(x, params.wv, heads)
    o_reuse = jnp.zeros((b, heads, n, dh), q.dtype)

    from repro.analysis.cost_model import cost_of_jaxpr

    backend = get_backend(cfg)                       # MeshBackend(xla)

    def attn(q_, k_, v_, o_):
        return backend.attention(q_, k_, v_, o_, plan, spec)

    # Source of truth: the static cost model over the traced jaxpr.
    scost = cost_of_jaxpr(jax.make_jaxpr(attn)(q, k, v, o_reuse))
    plan_bytes = scost.coll_payload.get("all_to_all", 0.0)
    extra_kinds = {k_: v_ for k_, v_ in scost.coll_payload.items()
                   if k_ != "all_to_all" and v_}

    mesh = make_engine_mesh(1, mesh_sp)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    def dense(k_, v_):
        kg = jax.lax.all_gather(k_, "seq", axis=2, tiled=True)
        vg = jax.lax.all_gather(v_, "seq", axis=2, tiled=True)
        return kg, vg

    dfn = shard_map(dense, mesh=mesh,
                    in_specs=(PS(None, None, "seq", None),) * 2,
                    out_specs=(PS(None, None, None, None),) * 2,
                    check_rep=False)
    dcost = cost_of_jaxpr(jax.make_jaxpr(dfn)(k, v))
    dense_bytes = dcost.coll_payload.get("all_gather", 0.0)

    t_q = m.n_blocks(n) * (m.pool // m.block_q)
    t_kv = m.n_blocks(n) * (m.pool // m.block_kv)
    geom = shard_geometry(spec, t_q, t_kv, mesh_sp, pair_slack)
    # Closed-form expectation: one exchange per K and V of
    # (b, heads, P·pair_cap·block_kv, dh) blocks.
    formula_bytes = 2.0 * (b * heads * mesh_sp * geom.pair_cap
                           * m.block_kv * dh) * q.dtype.itemsize

    # Cross-check only: parse the compiled HLO with the legacy regex.
    coll = collective_bytes(
        jax.jit(attn).lower(q, k, v, o_reuse).compile().as_text())
    hlo_plan = sum(v_ for k_, v_ in coll.items()
                   if "all-to-all" in k_ and not k_.endswith("_count"))
    dcoll = collective_bytes(jax.jit(dfn).lower(k, v).compile().as_text())
    hlo_dense = sum(v_ for k_, v_ in dcoll.items()
                    if "all-gather" in k_ and not k_.endswith("_count"))

    rec = {
        "mesh_sp": mesh_sp, "density": density, "pair_slack": pair_slack,
        "plan_collective_bytes": plan_bytes,
        "dense_collective_bytes": dense_bytes,
        "ratio": plan_bytes / dense_bytes if dense_bytes else float("inf"),
        "formula_bytes": formula_bytes,
        "static_extra_collectives": extra_kinds,
        "hlo_plan_collective_bytes": hlo_plan,
        "hlo_dense_collective_bytes": hlo_dense,
        "hlo_crosscheck_rel_err": (abs(hlo_plan - plan_bytes)
                                   / plan_bytes if plan_bytes else
                                   float("inf")),
        "exchange_blocks_per_shard": exchange_blocks(geom),
        "dense_exchange_blocks": dense_exchange_blocks(t_kv),
        "sharded_hlo_collectives": coll,
        "dense_hlo_collectives": dcoll,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"sharded_dispatch__sp{mesh_sp}__d{density}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))
    print(f"[dryrun] sharded dispatch: plan={plan_bytes}B "
          f"dense={dense_bytes}B ratio={rec['ratio']:.3f} -> {path}")
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             *, mode_override: str | None = None, unroll: bool = False) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if unroll:
        # Exact roofline accounting: lower the layer loop explicitly so
        # cost_analysis sees every layer (see models.layers.maybe_scan).
        cfg = dataclasses.replace(cfg, scan_layers=False)
    mesh = make_production_mesh(multi_pod=multi_pod)
    shapes = {s.name: s for s in arch_shapes(cfg)}
    shape = shapes[shape_name]
    rules = rules_for(cfg, shape, multi_pod=multi_pod)

    if cfg.family == "dit":
        mode = mode_override or "dispatch"
        fn, in_shapes, in_sh, out_sh = ST.build_dit_step(cfg, shape, mesh, rules,
                                                         mode=mode)
        entry = f"denoise_{mode}"
    elif shape.kind == "train":
        fn, in_shapes, in_sh, out_sh = ST.build_train_step(cfg, shape, mesh, rules)
        entry = "train_step"
    elif shape.kind == "prefill":
        fn, in_shapes, in_sh, out_sh = ST.build_prefill_step(cfg, shape, mesh, rules)
        entry = "prefill"
    else:
        fn, in_shapes, in_sh, out_sh = ST.build_decode_step(cfg, shape, mesh, rules)
        entry = "decode_step"

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*in_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_rec[attr] = getattr(mem, attr, None)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):       # older jaxlib: one dict per device
        cost = cost[0] if cost else {}
    cost = dict(cost or {})
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    rec = {
        "arch": arch, "shape": shape_name, "entry": entry,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": mesh.devices.size,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes accessed"),
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float)) and
                          ("flops" in k or "bytes" in k or "utilization" in k)},
        "memory_analysis": mem_rec,
        "collective_bytes": coll,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "n_params": get_config(arch).n_params(),
        "n_active_params": get_config(arch).n_active_params(),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{mode_override}" if mode_override else ""
    if unroll:
        rec["unrolled"] = True
        suffix += "__unroll"
    path = out_dir / f"{arch}__{shape_name}__{rec['mesh']}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))
    print(f"[dryrun] OK {arch} {shape_name} {rec['mesh']}{suffix} "
          f"flops/dev={rec['flops_per_device']} compile={t_compile:.1f}s -> {path}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mode", default=None, help="dit: update|dispatch")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer loops for exact cost analysis")
    ap.add_argument("--sharded-gate", action="store_true",
                    help="lower the plan-sharded dispatch at 25%% density "
                         "and assert its collective bytes < 0.5x the dense "
                         "KV all-gather over the same mesh")
    ap.add_argument("--mesh-sp", type=int, default=8,
                    help="seq-shard count for --sharded-gate")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.sharded_gate:
        rec = sharded_dispatch_report(out_dir, mesh_sp=args.mesh_sp)
        if not rec["plan_collective_bytes"]:
            raise SystemExit("[dryrun] sharded gate: static model sees 0 "
                             "all_to_all bytes in the sharded dispatch — "
                             "the exchange vanished from the trace")
        if rec["plan_collective_bytes"] != rec["formula_bytes"]:
            raise SystemExit(
                f"[dryrun] sharded gate FAIL: static a2a payload "
                f"{rec['plan_collective_bytes']:.0f}B != pair_cap formula "
                f"{rec['formula_bytes']:.0f}B")
        if rec["static_extra_collectives"]:
            raise SystemExit(
                f"[dryrun] sharded gate FAIL: unexpected collectives "
                f"{rec['static_extra_collectives']} in the sharded dispatch")
        if rec["ratio"] >= 0.5:
            raise SystemExit(f"[dryrun] sharded gate FAIL: plan-aware "
                             f"exchange at {rec['ratio']:.3f}x dense (>= 0.5)")
        # Cross-check: the legacy HLO-text parse must still see the same
        # exchange, or the regex went stale / the lowering diverged.
        if not rec["hlo_plan_collective_bytes"]:
            raise SystemExit("[dryrun] sharded gate: 0 collective bytes read "
                             "from the sharded HLO — op regex is stale")
        if rec["hlo_crosscheck_rel_err"] > 0.25:
            raise SystemExit(
                f"[dryrun] sharded gate FAIL: HLO parse "
                f"({rec['hlo_plan_collective_bytes']:.0f}B) disagrees with "
                f"the static model ({rec['plan_collective_bytes']:.0f}B) by "
                f"{rec['hlo_crosscheck_rel_err']:.1%} (> 25%)")
        print(f"[dryrun] sharded gate OK: {rec['ratio']:.3f}x dense "
              f"(static == pair_cap formula; HLO cross-check "
              f"{rec['hlo_crosscheck_rel_err']:.1%})")
        return

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sh in arch_shapes(get_config(arch)):
                cells.append((arch, sh.name))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, sh in cells:
        for mp in meshes:
            try:
                run_cell(arch, sh, mp, out_dir, mode_override=args.mode,
                         unroll=args.unroll)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((arch, sh, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} {sh} multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nAll {len(cells) * len(meshes)} dry-run cells compiled OK.")


if __name__ == "__main__":
    main()
