import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  The 512 placeholder host devices exist ONLY for the dry-run.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
record memory/cost/collective analyses for EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, arch_shapes, get_config
from repro.launch.mesh import make_production_mesh, rules_for
from repro.launch import steps as ST

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3": 1, "f8e5m2": 1}

# HLO text: ``%all-reduce.705 = f32[256,4096]{1,0} all-reduce(%x), ...`` —
# operands are bare names; we account the RESULT shape as bytes moved
# (all-gather result = bytes received per device; all-reduce ≈ tensor size;
# reduce-scatter result = shard received; a2a tuple = total moved).
_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9_\[\]{},]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
                       r"f8e4m3\w*|f8e5m2\w*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    for k, v in _DTYPE_BYTES.items():
        if dtype.startswith(k):
            return n * v
    return n * 4


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind (async `-done` ops excluded)."""
    out: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(1)))
        out[kind] = out.get(kind, 0) + total
        out.setdefault(kind + "_count", 0)
        out[kind + "_count"] += 1
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             *, mode_override: str | None = None, unroll: bool = False) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if unroll:
        # Exact roofline accounting: lower the layer loop explicitly so
        # cost_analysis sees every layer (see models.layers.maybe_scan).
        cfg = dataclasses.replace(cfg, scan_layers=False)
    mesh = make_production_mesh(multi_pod=multi_pod)
    shapes = {s.name: s for s in arch_shapes(cfg)}
    shape = shapes[shape_name]
    rules = rules_for(cfg, shape, multi_pod=multi_pod)

    if cfg.family == "dit":
        mode = mode_override or "dispatch"
        fn, in_shapes, in_sh, out_sh = ST.build_dit_step(cfg, shape, mesh, rules,
                                                         mode=mode)
        entry = f"denoise_{mode}"
    elif shape.kind == "train":
        fn, in_shapes, in_sh, out_sh = ST.build_train_step(cfg, shape, mesh, rules)
        entry = "train_step"
    elif shape.kind == "prefill":
        fn, in_shapes, in_sh, out_sh = ST.build_prefill_step(cfg, shape, mesh, rules)
        entry = "prefill"
    else:
        fn, in_shapes, in_sh, out_sh = ST.build_decode_step(cfg, shape, mesh, rules)
        entry = "decode_step"

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*in_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_rec[attr] = getattr(mem, attr, None)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):       # older jaxlib: one dict per device
        cost = cost[0] if cost else {}
    cost = dict(cost or {})
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    rec = {
        "arch": arch, "shape": shape_name, "entry": entry,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": mesh.devices.size,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes accessed"),
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float)) and
                          ("flops" in k or "bytes" in k or "utilization" in k)},
        "memory_analysis": mem_rec,
        "collective_bytes": coll,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "n_params": get_config(arch).n_params(),
        "n_active_params": get_config(arch).n_active_params(),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{mode_override}" if mode_override else ""
    if unroll:
        rec["unrolled"] = True
        suffix += "__unroll"
    path = out_dir / f"{arch}__{shape_name}__{rec['mesh']}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))
    print(f"[dryrun] OK {arch} {shape_name} {rec['mesh']}{suffix} "
          f"flops/dev={rec['flops_per_device']} compile={t_compile:.1f}s -> {path}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mode", default=None, help="dit: update|dispatch")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer loops for exact cost analysis")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sh in arch_shapes(get_config(arch)):
                cells.append((arch, sh.name))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, sh in cells:
        for mp in meshes:
            try:
                run_cell(arch, sh, mp, out_dir, mode_override=args.mode,
                         unroll=args.unroll)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((arch, sh, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} {sh} multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nAll {len(cells) * len(meshes)} dry-run cells compiled OK.")


if __name__ == "__main__":
    main()
