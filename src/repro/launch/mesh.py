"""Production mesh construction (task spec: function, NOT module constant,
so importing this never touches jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "rules_for"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for(cfg, shape, *, multi_pod: bool):
    """Pick sharding rules for an (arch, shape, mesh) cell.

    * decode cells map the KV-cache sequence axis (``sp``) onto the model
      axis (kv heads are replicated there — GQA kv counts don't divide 16);
    * batch=1 long-context cells replicate the batch and spread the cache
      sequence over BOTH mesh axes;
    * ≥100B configs (``zero_over_pod``) extend fsdp over the pod axis.
    """
    from repro.distributed.sharding import ShardingRules

    if getattr(cfg, "family", "") == "dit":
        # Batch=1 video DiT serving: sequence parallel over data (and pod,
        # when present — 33K tokens over 32 ways), heads/ff over model.
        sp = ("pod", "data") if multi_pod else ("data",)
        return ShardingRules(dp=(), fsdp=("data",), tp=("model",),
                             sp=sp, ep=())
    dp = ("pod", "data") if multi_pod else ("data",)
    fsdp = ("pod", "data") if (multi_pod and cfg.zero_over_pod) else ("data",)
    sp: tuple[str, ...] = ()
    if shape.kind == "decode":
        if shape.global_batch == 1:           # long_500k: batch can't shard
            dp = ()
            sp = ("data", "model")
        else:
            sp = ("model",)
    return ShardingRules(dp=dp, fsdp=fsdp, tp=("model",), sp=sp, ep=())
