"""Production mesh construction (task spec: function, NOT module constant,
so importing this never touches jax device state)."""

from __future__ import annotations

import functools

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_engine_mesh", "mesh_shape_for",
           "rules_for"]


def mesh_shape_for(n_devices: int, cap_shape: tuple[int, ...]) -> tuple[int, ...]:
    """Largest power-of-two mesh ≤ ``cap_shape`` that fits ``n_devices``.

    The production shapes are the CAP, not a requirement: on a host with
    fewer devices (CI's forced-8-device CPU, a dev box with 1) the mesh
    degrades to what is actually there.  Axes fill from the LAST (model)
    axis first — the innermost axis keeps the best locality — and every
    axis stays a power of two so collectives get regular groups.
    """
    if n_devices < 1:
        raise ValueError(f"need at least one device, got {n_devices}")
    total = 1 << (max(n_devices, 1).bit_length() - 1)      # floor pow2
    total = min(total, int(np.prod(cap_shape)))
    shape = []
    for cap in reversed(cap_shape):
        if cap & (cap - 1):
            raise ValueError(f"cap_shape axes must be powers of two: {cap_shape}")
        a = min(cap, total)
        total //= a
        shape.append(a)
    return tuple(reversed(shape))


def make_production_mesh(*, multi_pod: bool = False):
    cap = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devices = jax.devices()
    shape = mesh_shape_for(len(devices), cap)
    n = int(np.prod(shape))
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


@functools.lru_cache(maxsize=16)
def make_engine_mesh(dp: int = 1, sp: int = 1):
    """(data, seq) mesh for plan-sharded dispatch (distributed/plan_shard).

    Cached so every Dispatch trace of a given shape reuses the SAME Mesh
    object — mesh identity keys jit caches, and a fresh mesh per call
    would break the one-executable-per-(mesh shape, plan shape) budget.
    """
    devices = jax.devices()
    if dp * sp > len(devices):
        raise ValueError(
            f"mesh ({dp}, {sp}) needs {dp * sp} devices, have {len(devices)}")
    return jax.sharding.Mesh(
        np.asarray(devices[:dp * sp]).reshape(dp, sp), ("data", "seq"))


def rules_for(cfg, shape, *, multi_pod: bool):
    """Pick sharding rules for an (arch, shape, mesh) cell.

    * decode cells map the KV-cache sequence axis (``sp``) onto the model
      axis (kv heads are replicated there — GQA kv counts don't divide 16);
    * batch=1 long-context cells replicate the batch and spread the cache
      sequence over BOTH mesh axes;
    * ≥100B configs (``zero_over_pod``) extend fsdp over the pod axis.
    """
    from repro.distributed.sharding import ShardingRules

    if getattr(cfg, "family", "") == "dit":
        # Batch=1 video DiT serving: sequence parallel over data (and pod,
        # when present — 33K tokens over 32 ways), heads/ff over model.
        sp = ("pod", "data") if multi_pod else ("data",)
        return ShardingRules(dp=(), fsdp=("data",), tp=("model",),
                             sp=sp, ep=())
    dp = ("pod", "data") if multi_pod else ("data",)
    fsdp = ("pod", "data") if (multi_pod and cfg.zero_over_pod) else ("data",)
    sp: tuple[str, ...] = ()
    if shape.kind == "decode":
        if shape.global_batch == 1:           # long_500k: batch can't shard
            dp = ()
            sp = ("data", "model")
        else:
            sp = ("model",)
    return ShardingRules(dp=dp, fsdp=fsdp, tp=("model",), sp=sp, ep=())
