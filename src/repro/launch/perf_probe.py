import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing probe: lower+compile ONE cell with a perf-option
combination and print its roofline terms (hypothesis → change → re-lower →
re-analyse loop).

  PYTHONPATH=src python -m repro.launch.perf_probe --arch gemma3-1b \
      --shape train_4k [--cast-bf16] [--moment-dtype bfloat16] \
      [--cap-q-frac 0.6] [--mode update|dispatch] [--tag iterN]
"""

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

from repro.configs.registry import arch_shapes, get_config
from repro.launch import steps as ST
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh, rules_for
from repro.optim.optimizer import AdamWConfig

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def probe(arch, shape_name, *, multi_pod=False, unroll=True, cast_bf16=False,
          moment_dtype="float32", mode="dispatch", cap_q_frac=None,
          cap_kv_frac=None, tag="probe", interval=None, out="artifacts/perf"):
    cfg = get_config(arch)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = {s.name: s for s in arch_shapes(cfg)}[shape_name]
    rules = rules_for(cfg, shape, multi_pod=multi_pod)

    if cfg.family == "dit":
        from repro.core.engine import EngineConfig
        from repro.core.masks import MaskConfig
        ecfg = EngineConfig(
            mask=MaskConfig(tau_q=0.5, tau_kv=0.15,
                            interval=interval or 5, order=1, degrade=0.3,
                            block_q=64, block_kv=64, pool=256),
            cap_q_frac=cap_q_frac or 0.6, cap_kv_frac=cap_kv_frac or 0.9)
        fn, in_shapes, in_sh, out_sh = ST.build_dit_step(
            cfg, shape, mesh, rules, mode=mode, ecfg=ecfg)
    elif shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=moment_dtype)
        fn, in_shapes, in_sh, out_sh = ST.build_train_step(
            cfg, shape, mesh, rules, opt_cfg=opt_cfg,
            cast_params_bf16=cast_bf16)
    elif shape.kind == "prefill":
        fn, in_shapes, in_sh, out_sh = ST.build_prefill_step(cfg, shape, mesh, rules)
    else:
        fn, in_shapes, in_sh, out_sh = ST.build_decode_step(cfg, shape, mesh, rules)

    t0 = time.time()
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*in_shapes).compile()
    cost = dict(compiled.cost_analysis() or {})
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    flops = cost.get("flops", 0.0)
    byts = cost.get("bytes accessed", 0.0)
    cbytes = sum(v for k, v in coll.items() if not k.endswith("count"))
    args = getattr(mem, "argument_size_in_bytes", 0)
    rec = {
        "tag": tag, "arch": arch, "shape": shape_name,
        "opts": {"cast_bf16": cast_bf16, "moment_dtype": moment_dtype,
                 "mode": mode, "cap_q_frac": cap_q_frac,
                 "cap_kv_frac": cap_kv_frac, "interval": interval},
        "t_compute_s": flops / PEAK, "t_memory_s": byts / HBM,
        "t_collective_s": cbytes / ICI,
        "flops": flops, "bytes": byts, "coll_bytes": cbytes,
        "collectives": coll, "arg_bytes": args,
        "compile_s": round(time.time() - t0, 1),
    }
    Path(out).mkdir(parents=True, exist_ok=True)
    p = Path(out) / f"{arch}__{shape_name}__{tag}.json"
    p.write_text(json.dumps(rec, indent=1))
    dom = max(("compute", "memory", "collective"),
              key=lambda k: rec[f"t_{k}_s"])
    print(f"[perf] {arch} {shape_name} [{tag}] compute={rec['t_compute_s']:.3f}s "
          f"memory={rec['t_memory_s']:.3f}s collective={rec['t_collective_s']:.3f}s "
          f"dom={dom} args={args/1e9:.2f}GB -> {p}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-unroll", action="store_true")
    ap.add_argument("--cast-bf16", action="store_true")
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--mode", default="dispatch")
    ap.add_argument("--cap-q-frac", type=float, default=None)
    ap.add_argument("--cap-kv-frac", type=float, default=None)
    ap.add_argument("--interval", type=int, default=None)
    ap.add_argument("--tag", default="probe")
    args = ap.parse_args()
    probe(args.arch, args.shape, multi_pod=args.multi_pod,
          unroll=not args.no_unroll, cast_bf16=args.cast_bf16,
          moment_dtype=args.moment_dtype, mode=args.mode,
          cap_q_frac=args.cap_q_frac, cap_kv_frac=args.cap_kv_frac,
          interval=args.interval, tag=args.tag)


if __name__ == "__main__":
    main()
