import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Unrolled single-pod dry-run sweep for the roofline table, smallest cells
first so results stream in early.  (The scanned --all --both-meshes sweep
remains the compile-validation pass; this one feeds §Roofline.)"""

from pathlib import Path

from repro.configs.registry import ARCH_IDS, arch_shapes, get_config
from repro.launch.dryrun import run_cell

_KIND_W = {"decode": 0, "dit": 1, "prefill": 2, "train": 3}


def main():
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sh in arch_shapes(cfg):
            w = (_KIND_W[sh.kind], cfg.n_params() * cfg.n_layers)
            cells.append((w, arch, sh.name))
    cells.sort()
    out = Path("artifacts/dryrun")
    fails = []
    for _, arch, sh in cells:
        try:
            run_cell(arch, sh, False, out, unroll=True)
        except Exception as e:  # noqa: BLE001
            fails.append((arch, sh, repr(e)))
            print(f"[roofline-sweep] FAIL {arch} {sh}: {e}")
    print(f"done, {len(fails)} failures: {fails}")


if __name__ == "__main__":
    main()
