"""Serving launcher.

Two serving kinds, matching the paper's domain and the LM shape grid:

  * ``--kind diffusion`` — batched text-to-vision requests through the
    FlashOmni Update–Dispatch sampler (the paper's deployment scenario).
  * ``--kind lm``        — LM prefill + decode loop with KV caches.

On this container both run smoke configs; the jitted step functions are
the SAME ones the dry-run lowers for the production meshes."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke
from repro.core.engine import EngineConfig
from repro.core.masks import MaskConfig
from repro.core.schedule import available_schedules
from repro.core.strategy import available_strategies
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models.registry import get_model


def serve_diffusion(arch: str, *, smoke: bool = True, num_requests: int = 2,
                    batch: int = 2, n_vision: int = 96, num_steps: int = 12,
                    strategy: str = "flashomni", schedule: str = None):
    """``schedule`` names a registered SparsitySchedule preset (e.g.
    ``hunyuan-1.5x``, ``step-ramp``); it overrides the per-step mapping of
    ``strategy``.  Either way the whole denoise loop is ONE compiled scan
    per request shape — concurrent schedule variants each cost a single
    executable, not three jits × steps."""
    cfg = get_smoke(arch) if smoke else get_config(arch)
    ecfg = EngineConfig(mask=MaskConfig(
        tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.3,
        block_q=16, block_kv=16, pool=32, warmup_steps=2),
        strategy=strategy, schedule=schedule)
    from repro.models import dit as ditmod
    params = ditmod.init_params(cfg, jax.random.PRNGKey(0))
    results = []
    label = schedule or strategy
    for req in range(num_requests):
        key = jax.random.PRNGKey(100 + req)
        x0 = jax.random.normal(key, (batch, n_vision, cfg.patch_dim))
        text = jax.random.normal(key, (batch, cfg.n_text_tokens, cfg.d_model))
        trace: list = []
        stats: dict = {}
        t0 = time.time()
        out = sample(params, cfg, ecfg, text_emb=text, x0=x0,
                     scfg=SamplerConfig(num_steps=num_steps), trace=trace,
                     stats=stats)
        dt = time.time() - t0
        dens = [s["density"] for s in trace if s["kind"] == "dispatch"]
        print(f"[serve] req {req} [{label}]: {num_steps} steps in {dt:.2f}s  "
              f"mean dispatch density {sum(dens)/max(len(dens),1):.3f}  "
              f"executables {stats['executables']}  "
              f"out {out.shape} finite={bool(jnp.isfinite(out).all())}")
        results.append(out)
    return results


def serve_lm(arch: str, *, smoke: bool = True, batch: int = 2,
             prompt_len: int = 32, gen_len: int = 16, max_len: int = 64):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    cache = model.init_cache(batch, max_len, dtype=jnp.float32)
    decode = jax.jit(lambda p, c, tok, pos: model.decode_step(
        p, c, tok, pos, dtype=jnp.float32))

    t0 = time.time()
    # teacher-forced prefill through the decode path (smoke scale), then greedy
    tok = prompt[:, 0]
    for i in range(prompt_len - 1):
        logits, cache = decode(params, cache, prompt[:, i], jnp.int32(i))
    generated = []
    tok = prompt[:, -1]
    for i in range(gen_len):
        logits, cache = decode(params, cache, tok, jnp.int32(prompt_len - 1 + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(generated, axis=1)
    print(f"[serve] {cfg.name}: prefill {prompt_len} + decode {gen_len} "
          f"in {dt:.2f}s -> tokens {gen[0][:8].tolist()}...")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--kind", default="lm", choices=["lm", "diffusion"])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--strategy", default="flashomni",
                    choices=available_strategies(),
                    help="sparse-symbol producer for --kind diffusion")
    ap.add_argument("--schedule", default=None,
                    choices=available_schedules(),
                    help="named SparsitySchedule preset (overrides the "
                         "--strategy per-step mapping)")
    args = ap.parse_args()
    if args.kind == "diffusion":
        serve_diffusion(args.arch, smoke=not args.full,
                        strategy=args.strategy, schedule=args.schedule)
    else:
        serve_lm(args.arch, smoke=not args.full)


if __name__ == "__main__":
    main()
