"""Serving launcher.

Two serving kinds, matching the paper's domain and the LM shape grid:

  * ``--kind diffusion`` — text-to-vision requests through the FlashOmni
    Update–Dispatch sampler (the paper's deployment scenario), driven by
    the :mod:`repro.launch.batching` request queue in one of three modes:

      - ``--serving sequential`` — one request at a time (baseline; the
        pipeline's LRU sampler cache still shares compiled samplers
        across same-config requests);
      - ``--serving stacked``    — same-shape/same-schedule requests
        stack on the batch axis into ONE cached single-scan sampler call
        (bit-identical per-lane outputs);
      - ``--serving continuous`` — mixed-schedule requests interleave in
        a fixed-width microbatch; lanes retire and refill without
        recompiling (a fixed ≤ 4 executable budget per lane shape).
        Mode-homogeneous ticks fold same-mode lanes into the model
        batch axis (``ContinuousBatcher(grouped="auto")``), so a
        homogeneous request mix serves at stacked-level throughput.
        ``--shape-buckets`` rounds near-miss ``N_v`` resolutions up to
        canonical lane sizes so they share one lane executable; the
        resulting lane-bucket map is printed after the run.

    ``--arrival-interval`` simulates request arrivals (seconds between
    requests); latencies are measured against arrival times.
  * ``--kind lm``        — LM prefill + decode loop with KV caches.

On this container both run smoke configs; the jitted step functions are
the SAME ones the dry-run lowers for the production meshes."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke
from repro.core.engine import EngineConfig
from repro.core.masks import MaskConfig
from repro.core.schedule import available_schedules
from repro.core.strategy import available_strategies
from repro.launch.batching import (ContinuousBatcher, Request,
                                   run_sequential, run_stacked)
from repro.models.registry import get_model


def serve_diffusion(arch: str, *, smoke: bool = True, num_requests: int = 2,
                    batch: int = 2, n_vision: int = 96, num_steps: int = 12,
                    strategy: str = "flashomni", schedule: str = None,
                    serving: str = "sequential", lanes: int = 4,
                    arrival_interval: float = 0.0, mixed_steps: bool = False,
                    mixed_shapes: bool = False, shape_buckets=None,
                    mesh: tuple = (1, 1)):
    """Queue-driven diffusion serving (see module docstring for modes).

    ``schedule`` names a registered SparsitySchedule preset (e.g.
    ``hunyuan-1.5x``, ``step-ramp``); it overrides the per-step mapping of
    ``strategy``.  ``mixed_steps`` alternates request step counts
    (``num_steps`` and ``3·num_steps//4``) to exercise the continuous
    batcher's mixed-length lane interleaving.  ``mixed_shapes`` alternates
    request vision lengths (``n_vision`` and ``n_vision − pool``) to
    exercise the continuous batcher's shape-bucketed lane partitioning;
    ``shape_buckets`` passes the canonical N_v bucket sizes through to
    :class:`~repro.launch.batching.ContinuousBatcher` (default when
    ``mixed_shapes``: ``(n_vision,)`` so the near-miss shape folds in).
    ``mesh`` is ``(dp, sp)``: with ``sp > 1`` the engine runs plan-sharded
    dispatch over a ``(data, seq)`` device mesh (``distributed/plan_shard``)
    — the Update step emits per-shard CSR partitions and attention
    exchanges only plan-live KV blocks.  Needs ``dp·sp`` local devices.
    Returns the per-request result dict from :mod:`repro.launch.batching`.
    """
    cfg = get_smoke(arch) if smoke else get_config(arch)
    ecfg = EngineConfig(mask=MaskConfig(
        tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.3,
        block_q=16, block_kv=16, pool=32, warmup_steps=2),
        strategy=strategy, mesh_dp=mesh[0], mesh_sp=mesh[1])
    from repro.models import dit as ditmod
    params = ditmod.init_params(cfg, jax.random.PRNGKey(0))
    label = schedule or strategy

    requests = []
    for req in range(num_requests):
        # One PRNG key per request, SPLIT between noise and text: reusing
        # a single key for both (the old behaviour) correlates the noise
        # latents with the text embeddings sample-for-sample.
        kx, kt = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(100), req))
        nv = n_vision
        if mixed_shapes and req % 2:
            nv = max(n_vision - ecfg.mask.pool, ecfg.mask.pool)
        x0 = jax.random.normal(kx, (batch, nv, cfg.patch_dim))
        text = jax.random.normal(kt, (batch, cfg.n_text_tokens, cfg.d_model))
        steps = num_steps
        if mixed_steps and req % 2:
            steps = max(3 * num_steps // 4, 1)
        requests.append(Request(rid=req, x0=x0, text_emb=text,
                                num_steps=steps, schedule=schedule,
                                arrival=req * arrival_interval))

    t0 = time.time()
    extra = ""
    if serving == "continuous":
        if shape_buckets is None and mixed_shapes:
            shape_buckets = (n_vision,)
        batcher = ContinuousBatcher(params, cfg, ecfg, lanes=lanes,
                                    shape_buckets=shape_buckets)
        batcher.submit_all(requests)
        results = batcher.run()
        extra = (f"  executables {batcher.stats['executables']}"
                 f"  ticks {batcher.stats['ticks']}"
                 f" ({batcher.stats['grouped_ticks']} grouped"
                 f"/{batcher.stats['scan_ticks']} scan)")
        # Lane-bucket map: which admitted shape folded into which lane
        # shape (ISSUE 6 — shape-bucketed serving lanes).
        print(f"[serve] lane shape buckets "
              f"({batcher.stats['shape_partitions']} partition(s)):")
        for orig, canon in sorted(batcher.stats["shape_buckets"].items()):
            fold = "=" if orig == canon else "->"
            print(f"[serve]   x0 {orig[0]} {fold} lane {canon[0]}")
    elif serving == "stacked":
        results = run_stacked(params, cfg, ecfg, requests)
    elif serving == "sequential":
        results = run_sequential(params, cfg, ecfg, requests)
    else:
        raise ValueError(f"unknown serving mode {serving!r}; expected "
                         "sequential | stacked | continuous")
    wall = time.time() - t0

    for req in requests:
        r = results[req.rid]
        dens = [s["density"] for s in (r["trace"] or [])
                if s["kind"] == "dispatch"]
        dtxt = (f"mean dispatch density "
                f"{sum(dens) / len(dens):.3f}  " if dens else "")
        print(f"[serve] req {req.rid} [{label}] ({serving}): "
              f"{req.num_steps} steps, latency {r['latency']:.2f}s  "
              f"{dtxt}out {r['out'].shape} "
              f"finite={bool(jnp.isfinite(r['out']).all())}")
    print(f"[serve] {serving}: {len(requests)} requests in {wall:.2f}s "
          f"({len(requests) / max(wall, 1e-9):.2f} req/s){extra}")
    return results


def serve_lm(arch: str, *, smoke: bool = True, batch: int = 2,
             prompt_len: int = 32, gen_len: int = 16, max_len: int = 64):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    cache = model.init_cache(batch, max_len, dtype=jnp.float32)
    decode = jax.jit(lambda p, c, tok, pos: model.decode_step(
        p, c, tok, pos, dtype=jnp.float32))

    t0 = time.time()
    # teacher-forced prefill through the decode path (smoke scale), then greedy
    tok = prompt[:, 0]
    for i in range(prompt_len - 1):
        logits, cache = decode(params, cache, prompt[:, i], jnp.int32(i))
    generated = []
    tok = prompt[:, -1]
    for i in range(gen_len):
        logits, cache = decode(params, cache, tok, jnp.int32(prompt_len - 1 + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(generated, axis=1)
    print(f"[serve] {cfg.name}: prefill {prompt_len} + decode {gen_len} "
          f"in {dt:.2f}s -> tokens {gen[0][:8].tolist()}...")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--kind", default="lm", choices=["lm", "diffusion"])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--strategy", default="flashomni",
                    choices=available_strategies(),
                    help="sparse-symbol producer for --kind diffusion")
    ap.add_argument("--schedule", default=None,
                    choices=available_schedules(),
                    help="named SparsitySchedule preset (overrides the "
                         "--strategy per-step mapping)")
    ap.add_argument("--serving", default="sequential",
                    choices=["sequential", "stacked", "continuous"],
                    help="diffusion serving mode (see module docstring)")
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--lanes", type=int, default=4,
                    help="continuous-batcher microbatch width")
    ap.add_argument("--arrival-interval", type=float, default=0.0,
                    help="simulated seconds between request arrivals")
    ap.add_argument("--mixed-steps", action="store_true",
                    help="alternate request step counts (exercises "
                         "mixed-length lane interleaving)")
    ap.add_argument("--mixed-shapes", action="store_true",
                    help="alternate request vision lengths (exercises "
                         "shape-bucketed lane partitioning)")
    ap.add_argument("--shape-buckets", type=int, nargs="*", default=None,
                    help="canonical N_v lane bucket sizes for "
                         "--serving continuous (near-miss shapes round up)")
    ap.add_argument("--mesh", default="1,1", metavar="DP,SP",
                    help="engine mesh 'dp,sp' for --kind diffusion: sp>1 "
                         "runs plan-sharded dispatch over a (data, seq) "
                         "mesh, exchanging only plan-live KV blocks "
                         "(needs dp*sp local devices; e.g. --mesh 2,4 "
                         "under XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8)")
    args = ap.parse_args()
    try:
        mesh = tuple(int(p) for p in args.mesh.split(","))
        assert len(mesh) == 2 and mesh[0] >= 1 and mesh[1] >= 1
    except (ValueError, AssertionError):
        ap.error(f"--mesh expects 'dp,sp' positive ints, got {args.mesh!r}")
    if args.kind == "diffusion":
        serve_diffusion(args.arch, smoke=not args.full,
                        strategy=args.strategy, schedule=args.schedule,
                        serving=args.serving, num_requests=args.requests,
                        lanes=args.lanes,
                        arrival_interval=args.arrival_interval,
                        mixed_steps=args.mixed_steps,
                        mixed_shapes=args.mixed_shapes,
                        shape_buckets=(tuple(args.shape_buckets)
                                       if args.shape_buckets else None),
                        mesh=mesh)
    else:
        serve_lm(args.arch, smoke=not args.full)


if __name__ == "__main__":
    main()
