"""ShapeDtypeStruct input specs for every (arch × shape) cell.

These are the weak-type-correct, shardable stand-ins the multi-pod dry-run
lowers against — no device allocation ever happens (task spec step 2).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = ["train_batch", "train_batch_logical", "prefill_batch",
           "prefill_batch_logical", "dit_inputs", "dit_inputs_logical"]

F = jax.ShapeDtypeStruct


def train_batch(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": F((b, s), jnp.int32), "labels": F((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = F((b, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = F((b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "dit":
        nv = s - cfg.n_text_tokens
        batch = {
            "latents": F((b, nv, cfg.patch_dim), jnp.bfloat16),
            "noise": F((b, nv, cfg.patch_dim), jnp.bfloat16),
            "patch_emb": F((b, nv, cfg.d_model), jnp.bfloat16),
            "text_emb": F((b, cfg.n_text_tokens, cfg.d_model), jnp.bfloat16),
            "t": F((b,), jnp.float32),
        }
    return batch


def train_batch_logical(cfg: ArchConfig) -> dict:
    base = {"tokens": ("dp", None), "labels": ("dp", None)}
    if cfg.family == "encdec":
        base["frames"] = ("dp", None, None)
    if cfg.family == "vlm":
        base["patches"] = ("dp", None, None)
    if cfg.family == "dit":
        base = {"latents": ("dp", None, None), "noise": ("dp", None, None),
                "patch_emb": ("dp", None, None), "text_emb": ("dp", None, None),
                "t": ("dp",)}
    return base


def prefill_batch(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": F((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch = {"frames": F((b, cfg.encoder_len, cfg.d_model), jnp.bfloat16),
                 "tokens": F((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = F((b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_batch_logical(cfg: ArchConfig) -> dict:
    base = {"tokens": ("dp", None)}
    if cfg.family == "encdec":
        base["frames"] = ("dp", None, None)
    if cfg.family == "vlm":
        base["patches"] = ("dp", None, None)
    return base


def dit_inputs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b = shape.global_batch
    nv = shape.seq_len - cfg.n_text_tokens
    return {"x_vision": F((b, nv, cfg.d_model), jnp.bfloat16),
            "text_emb": F((b, cfg.n_text_tokens, cfg.d_model), jnp.bfloat16),
            "t": F((b,), jnp.float32)}


def dit_inputs_logical(cfg: ArchConfig) -> dict:
    return {"x_vision": ("dp", "sp", None), "text_emb": ("dp", None, None),
            "t": ("dp",)}
