"""Jitted step builders shared by the real launchers and the dry-run.

Every builder returns ``(fn, in_shapes, in_shardings, out_shardings)`` so
``dryrun.py`` can ``jax.jit(fn, in_shardings=..., out_shardings=...)
.lower(*in_shapes).compile()`` and the launchers can feed real arrays.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.engine import EngineConfig
from repro.distributed.sharding import ShardingRules, named_sharding_tree
from repro.launch import specs as S
from repro.models.registry import get_model
from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_state_specs, adamw_update

__all__ = ["build_train_step", "build_prefill_step", "build_decode_step",
           "build_dit_step", "eval_shape_tree"]


def eval_shape_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def _shardings(tree_specs, mesh: Mesh, rules: ShardingRules):
    return named_sharding_tree(tree_specs, mesh, rules)


def build_train_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                     rules: ShardingRules, *, opt_cfg: AdamWConfig = AdamWConfig(),
                     cast_params_bf16: bool = False):
    """``cast_params_bf16`` (§Perf lever): convert the sharded f32 params to
    bf16 at step entry, BEFORE the FSDP all-gathers — halves both weight
    all-gather traffic and weight HBM reads in fwd/bwd."""
    model = get_model(cfg)
    p_specs = model.param_specs()
    o_specs = adamw_state_specs(p_specs)

    def train_step(params, opt_state, batch):
        from repro.distributed.ctx import activation_rules

        def loss_fn(p):
            if cast_params_bf16:
                p = jax.tree.map(
                    lambda w: w.astype(jnp.bfloat16)
                    if w.dtype == jnp.float32 else w, p)
            return model.train_loss(p, batch)

        with activation_rules(rules):   # activation sharding hints (§Perf A2)
            loss, grads = jax.value_and_grad(loss_fn)(params)
        new_p, new_o, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

    params_shape = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    opt_shape = jax.eval_shape(
        lambda: adamw_init_from_shapes(params_shape, opt_cfg))
    batch_shape = S.train_batch(cfg, shape)

    p_sh = _shardings(p_specs, mesh, rules)
    o_sh = _shardings(o_specs, mesh, rules)
    b_sh = _shardings(S.train_batch_logical(cfg), mesh, rules)
    m_sh = {"loss": NamedSharding(mesh, P()), "grad_norm": NamedSharding(mesh, P())}
    return (train_step, (params_shape, opt_shape, batch_shape),
            (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh))


def adamw_init_from_shapes(params_shape, opt_cfg: AdamWConfig = AdamWConfig()):
    dt = jnp.dtype(opt_cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params_shape),
            "nu": jax.tree.map(zeros, params_shape),
            "step": jnp.zeros((), jnp.int32)}


def _bf16_params_shape(model):
    ps = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype), ps)


def build_prefill_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                       rules: ShardingRules):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    params_shape = _bf16_params_shape(model)
    batch_shape = S.prefill_batch(cfg, shape)
    p_sh = _shardings(model.param_specs(), mesh, rules)
    b_sh = _shardings(S.prefill_batch_logical(cfg), mesh, rules)
    # vocab dim replicated: published vocabs aren't 16-divisible post-slice.
    out_sh = NamedSharding(mesh, P(rules.physical("dp"), None))
    return prefill_step, (params_shape, batch_shape), (p_sh, b_sh), out_sh


def build_decode_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                      rules: ShardingRules):
    model = get_model(cfg)
    b, s = shape.global_batch, shape.seq_len

    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    params_shape = _bf16_params_shape(model)
    cache_shape = jax.eval_shape(lambda: model.init_cache(b, s))
    token_shape = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    p_sh = _shardings(model.param_specs(), mesh, rules)
    c_sh = _shardings(model.cache_specs(), mesh, rules)
    t_sh = NamedSharding(mesh, P(rules.physical("dp")))
    s_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(mesh, P(rules.physical("dp"), None))
    return (decode_step, (params_shape, cache_shape, token_shape, pos_shape),
            (p_sh, c_sh, t_sh, s_sh), (logits_sh, c_sh))


def build_dit_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                   rules: ShardingRules, *, mode: str = "dispatch",
                   ecfg: EngineConfig | None = None):
    """One diffusion denoise step (Update or Dispatch) for the paper archs."""
    from repro.models import dit as ditmod

    if ecfg is None:
        from repro.core.masks import MaskConfig
        ecfg = EngineConfig(
            mask=MaskConfig(tau_q=0.5, tau_kv=0.15, interval=5, order=1,
                            degrade=0.3, block_q=64, block_kv=64, pool=256),
            cap_q_frac=0.6, cap_kv_frac=0.9)

    def step(params, states, inputs):
        from repro.distributed.ctx import activation_rules
        with activation_rules(rules):   # §Perf iteration C1
            v, new_states = ditmod.denoise_step(
                params, cfg, ecfg, states, inputs["x_vision"], inputs["text_emb"],
                inputs["t"], mode=mode)
        return v, new_states

    model_shape = jax.eval_shape(lambda: ditmod.init_params(cfg, jax.random.PRNGKey(0)))
    model_shape = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype), model_shape)
    n_tok = shape.seq_len
    states_shape = jax.eval_shape(
        lambda: ditmod.init_engine_states(cfg, ecfg, shape.global_batch, n_tok))
    in_shape = S.dit_inputs(cfg, shape)

    p_sh = _shardings(ditmod.param_specs(cfg), mesh, rules)
    st_sh = _shardings(ditmod.engine_state_specs(cfg, ecfg), mesh, rules)
    in_sh = _shardings(S.dit_inputs_logical(cfg), mesh, rules)
    v_sh = NamedSharding(mesh, P(rules.physical("dp"), rules.physical("sp"), None))
    return (step, (model_shape, states_shape, in_shape),
            (p_sh, st_sh, in_sh), (v_sh, st_sh))
