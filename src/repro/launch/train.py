"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Production loop = pjit train_step + async checkpointing + watchdog +
restart-on-failure + optional gradient compression.  On this CPU container
it runs the smoke config end-to-end (the same code path the pods run; the
mesh is just (1,1))."""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_config, get_smoke
from repro.data.synthetic import DataConfig, make_batch
from repro.distributed import compression
from repro.distributed.sharding import ShardingRules
from repro.models.registry import get_model
from repro.optim.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.runtime.fault_tolerance import (FailureInjector, RestartableLoop,
                                           StepWatchdog)

log = logging.getLogger("repro.train")


def make_step_fn(model, opt_cfg: AdamWConfig, dcfg: DataConfig, cfg,
                 *, compress: str | None = None, dtype=jnp.float32):
    err_state = {"e": None}

    @jax.jit
    def _step(params, opt_state, batch, err):
        loss, grads = jax.value_and_grad(
            lambda p: model.train_loss(p, batch, dtype=dtype))(params)
        if compress:
            comp, err = compression.compress_tree(grads, err, compress)
            grads = compression.decompress_tree(comp)
        new_p, new_o, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        return new_p, new_o, err, loss, gnorm

    def step_fn(state, step):
        params, opt_state = state
        if err_state["e"] is None:
            err_state["e"] = compression.init_error_state(params)
        batch = make_batch(cfg, dcfg, step)
        params, opt_state, err_state["e"], loss, gnorm = _step(
            params, opt_state, batch, err_state["e"])
        return (params, opt_state), {"loss": float(loss), "grad_norm": float(gnorm)}

    return step_fn


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          ckpt_dir: str = "artifacts/ckpt", batch: int = 4, seq_len: int = 128,
          compress: str | None = None, fail_at: tuple[int, ...] = (),
          ckpt_every: int = 10, log_every: int = 10):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    model = get_model(cfg)
    dcfg = DataConfig(seed=0, batch=batch, seq_len=seq_len)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps)

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    step_fn = make_step_fn(model, opt_cfg, dcfg, cfg, compress=compress)

    ckpt = Checkpointer(f"{ckpt_dir}/{cfg.name}", keep=2)
    loop = RestartableLoop(ckpt, ckpt_every=ckpt_every)
    injector = FailureInjector(fail_at) if fail_at else None
    t0 = time.time()
    state, result = loop.run((params, opt_state), step_fn, steps,
                             injector=injector, watchdog=StepWatchdog())
    dt = time.time() - t0
    losses = [m["loss"] for m in result.metrics]
    print(f"[train] {cfg.name}: {result.final_step} steps in {dt:.1f}s  "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}  "
          f"restarts={result.restarts} stragglers={len(result.stragglers)}")
    return state, result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--compress", default=None, choices=[None, "int8", "topk"])
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    train(args.arch, smoke=not args.full, steps=args.steps, batch=args.batch,
          seq_len=args.seq_len, compress=args.compress)


if __name__ == "__main__":
    main()
