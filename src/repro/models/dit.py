"""MMDiT — the paper's own model family (FLUX / HunyuanVideo style).

Single-stream DiT blocks over the concatenated [text; vision] token
sequence with adaLN-Zero timestep modulation; joint attention runs through
the FlashOmni Update–Dispatch engine (``repro.core.engine``).  The text
encoder and VAE/patchifier are STUBS per the task spec — inputs are
precomputed text embeddings and latent-patch embeddings.

``denoise_step`` traces one engine phase (``mode`` = "update" /
"dispatch" / "dense"); the pipeline's single-scan sampler ``lax.switch``es
between the three trace bodies on a :class:`~repro.core.schedule.
SparsitySchedule` mode array — one compiled executable for the whole loop.

Engine states are stacked (L, ...) and scanned with the blocks, so the HLO
stays one-block-sized at any depth — including per-layer strategy tables,
which ride the scan as a TRACED strategy-id row (``lax.switch`` over the
schedule's active strategy set inside the block body; nothing unrolls).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import engine as E
from repro.core.engine import AttnParams, EngineConfig, LayerState
from repro.models import layers as L

__all__ = ["init_params", "param_specs", "init_engine_states",
           "engine_state_specs", "denoise_step", "timestep_embedding",
           "train_loss"]


def timestep_embedding(t: jax.Array, dim: int, max_period: float = 10000.0):
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def _init_block(cfg: ArchConfig, key, stack: Optional[int]):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 7)
    sh = lambda *dims: dims if stack is None else (stack, *dims)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], sh(d, h * hd)) * s,
        "wk": jax.random.normal(ks[1], sh(d, h * hd)) * s,
        "wv": jax.random.normal(ks[2], sh(d, h * hd)) * s,
        "wo": jax.random.normal(ks[3], sh(h * hd, d)) * s,
        "q_scale": jnp.ones(sh(hd)),
        "k_scale": jnp.ones(sh(hd)),
        "mlp_wi": jax.random.normal(ks[4], sh(d, cfg.d_ff)) * s,
        "mlp_wo": jax.random.normal(ks[5], sh(cfg.d_ff, d)) * (cfg.d_ff ** -0.5),
        "adaln": jax.random.normal(ks[6], sh(d, 6 * d)) * 0.02,
        "adaln_b": jnp.zeros(sh(6 * d)),
    }


def _block_specs():
    n = (None,)
    return {"wq": (*n, "fsdp", "tp"), "wk": (*n, "fsdp", "tp"),
            "wv": (*n, "fsdp", "tp"), "wo": (*n, "tp", "fsdp"),
            "q_scale": (*n, None), "k_scale": (*n, None),
            "mlp_wi": (*n, "fsdp", "tp"), "mlp_wo": (*n, "tp", "fsdp"),
            "adaln": (*n, "fsdp", None), "adaln_b": (*n, None)}


def init_params(cfg: ArchConfig, key) -> Any:
    kb, kt, kf, kp = jax.random.split(key, 4)
    d = cfg.d_model
    blocks = [_init_block(cfg, jax.random.fold_in(kb, i), None)
              for i in range(cfg.n_layers)]
    return {
        "blocks": jax.tree.map(lambda *x: jnp.stack(x), *blocks),
        "t_mlp1": jax.random.normal(kt, (256, d)) * 0.02,
        "t_mlp2": jax.random.normal(jax.random.fold_in(kt, 1), (d, d)) * 0.02,
        "final_mod": jax.random.normal(kf, (d, 2 * d)) * 0.02,
        "final_proj": jax.random.normal(kp, (d, cfg.patch_dim)) * 0.02,
        "final_norm": jnp.ones((d,)),
    }


def param_specs(cfg: ArchConfig) -> Any:
    return {"blocks": _block_specs(),
            "t_mlp1": (None, "fsdp"), "t_mlp2": ("fsdp", "tp"),
            "final_mod": ("fsdp", None), "final_proj": ("fsdp", None),
            "final_norm": (None,)}


def init_engine_states(cfg: ArchConfig, ecfg: EngineConfig, batch: int,
                       n_tokens: int) -> LayerState:
    one = E.init_layer_state(batch, cfg.n_heads, n_tokens, cfg.d_model, cfg.hd, ecfg)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers, *x.shape)), one)


def engine_state_specs(cfg: ArchConfig, ecfg: EngineConfig) -> LayerState:
    if ecfg.cache_mode == "bias":
        taylor_feat = (None, None, "dp", "sp", "tp")   # (L, D+1, B, N, dm)
    else:
        taylor_feat = (None, None, "dp", None, "sp", None)
    from repro.core.plan import DispatchPlan
    from repro.core.taylorseer import TaylorState
    # Packed symbols are tiny (uint8); replicate the head dim (24 heads do
    # not divide the 16-wide model axis).  The DispatchPlan index arrays are
    # likewise small (int32 at block/pool granularity) and capacity-shaped;
    # shard them on batch only so scalar-prefetch gathers stay local.
    plan = DispatchPlan(
        q_ids=(None, "dp", None, None),
        q_cnt=(None, "dp", None),
        q_slots=(None, "dp", None, None),
        kv_ids=(None, "dp", None, None),
        kv_cnt=(None, "dp", None),
        pair_live=(None, "dp", None, None, None),
        kv_row_ids=(None, "dp", None, None, None),
        kv_row_cnt=(None, "dp", None, None),
        row_ids=(None, "dp", None),
        row_cnt=(None, "dp"),
        head_ids=(None, "dp", None, None),
        head_cnt=(None, "dp", None),
        head_mask=(None, "dp", None, None),
        m_ch=(None, "dp", None, None),
        row_score=(None, "dp", None),
        occ_hist=(None, "dp", None),
    )
    if ecfg.resolved_kv_buckets() > 1:
        # Optional bucketed-layout fields become pytree leaves only when
        # the config emits them — the spec tree must match leaf-for-leaf.
        # NB: resolved_kv_buckets, not kv_buckets — the 0 = auto sentinel
        # must resolve to the same depth the plan build sees via caps().
        plan = plan._replace(
            bkt_head=(None, "dp", None), bkt_q_ids=(None, "dp", None),
            bkt_q_src=(None, "dp", None), bkt_q_slots=(None, "dp", None),
            bkt_kv_ids=(None, "dp", None), bkt_kv_cnt=(None, "dp", None),
            gmo_rows=(None, "dp", None), gmo_src=(None, "dp", None),
            gmo_head_ids=(None, "dp", None), gmo_head_cnt=(None, "dp", None))
    if ecfg.mesh_sp > 1 and ecfg.mesh_axis == "seq":
        # Plan-sharded mesh partition (distributed/plan_shard.py): batch-
        # sharded like every other plan field; the destination-shard axis
        # is consumed by the dispatch shard_map, not by GSPMD.
        p3 = (None, "dp", None, None)
        p4 = (None, "dp", None, None, None)
        plan = plan._replace(
            shd_q_ids=p4, shd_q_src=p4, shd_q_slots=p4, shd_q_cnt=p3,
            shd_kv_ids=p4, shd_kv_cnt=p3,
            shd_kv_row_ids=(None, "dp", None, None, None, None),
            shd_kv_row_cnt=p4, shd_gather_idx=p4,
            shd_send_ids=(None, "dp", None, None, None, None),
            shd_send_cnt=p4)
    return LayerState(
        s_c=(None, "dp", None, None),
        s_s=(None, "dp", None, None),
        taylor=TaylorState(derivs=taylor_feat, n_updates=(None,)),
        k_since=(None,),
        plan=plan,
    )


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None]) + shift[:, None]


def _canonicalize_layer_strategies(layer_strategies, ecfg, n_layers):
    """Per-layer spec table -> (static strategy set, traced int32 id row)."""
    from repro.core.schedule import strategy_table
    strategies, ids = strategy_table(layer_strategies, ecfg, n_layers)
    return strategies, jnp.asarray(ids)


def _block(cfg: ArchConfig, ecfg: EngineConfig, p, state, x, t_emb, *, mode: str,
           n_text: int, strategy=None, layer_idx=None, strategy_id=None,
           strategies=None, step_idx=None, num_steps=None):
    dtype = x.dtype
    mod = (jax.nn.silu(t_emb) @ p["adaln"].astype(dtype) + p["adaln_b"].astype(dtype))
    sh_a, sc_a, g_a, sh_m, sc_m, g_m = jnp.split(mod, 6, axis=-1)
    xa = _modulate(L.rms_norm(x, jnp.ones((cfg.d_model,)), cfg.norm_eps), sh_a, sc_a)
    attn_p = AttnParams(wq=p["wq"].astype(dtype), wk=p["wk"].astype(dtype),
                        wv=p["wv"].astype(dtype), wo=p["wo"].astype(dtype),
                        q_scale=p["q_scale"], k_scale=p["k_scale"])
    if mode == "update":
        o, new_state = E.update_layer(attn_p, xa, state, ecfg, n_text=n_text,
                                      heads=cfg.n_heads, strategy=strategy,
                                      layer_idx=layer_idx,
                                      strategy_id=strategy_id,
                                      strategies=strategies,
                                      step_idx=step_idx, num_steps=num_steps)
    elif mode == "dispatch":
        o, new_state = E.dispatch_layer(attn_p, xa, state, ecfg, n_text=n_text,
                                        heads=cfg.n_heads)
    else:  # "dense": engine off (baseline / training)
        q, k = E._qk(attn_p, xa, cfg.n_heads, None)
        v = E._project_heads(xa, attn_p.wv, cfg.n_heads)
        from repro.core.attention import dense_attention
        oh = dense_attention(q, k, v)
        o = oh.transpose(0, 2, 1, 3).reshape(*xa.shape[:2], -1) @ attn_p.wo
        new_state = state
    from repro.distributed.ctx import constrain
    x = constrain(x + g_a[:, None] * o.astype(dtype), "dp", "sp", None)
    xm = _modulate(L.rms_norm(x, jnp.ones((cfg.d_model,)), cfg.norm_eps), sh_m, sc_m)
    y = constrain(jax.nn.gelu(xm @ p["mlp_wi"].astype(dtype)), "dp", "sp", "tp")
    y = constrain(y @ p["mlp_wo"].astype(dtype), "dp", "sp", None)
    return x + g_m[:, None] * y, new_state


def denoise_step(params, cfg: ArchConfig, ecfg: EngineConfig, states: LayerState,
                 x_vision: jax.Array, text_emb: jax.Array, t: jax.Array,
                 *, mode: str, dtype=jnp.bfloat16, layer_strategies=None,
                 strategies=None, strategy_row=None, step_idx=None,
                 num_steps=None):
    """One diffusion step: predicts the velocity field for ``x_vision``.

    x_vision (B, N_v, d_model) latent patch embeddings; text_emb (B, N_t, d);
    t (B,) diffusion time in [0, 1].  Returns (velocity, new_states).

    Per-layer sparse-symbol producers ride the scanned block body as
    TRACED data (no unrolling — the HLO stays one-block-sized at any
    depth):

      * ``strategies`` + ``strategy_row`` — a schedule's static active set
        and one traced ``(n_layers,)`` int32 id row (a
        ``SparsitySchedule.strategy_ids`` step slice); each scanned block
        ``lax.switch``es its emitter on its row entry.
      * ``layer_strategies`` — convenience per-layer table (registry names
        / strategy objects, ``None`` entries fall back to
        ``ecfg.strategy``); canonicalized into the pair above here.

    ``step_idx`` (traced scalar) and ``num_steps`` (a static int under
    ``pipeline.sample``, or a traced per-lane int32 scalar under the
    batched serving ticks — lanes mix step counts) flow into the
    :class:`~repro.core.strategy.StrategyContext` for schedule-varying
    producers; the scanned layer index is always threaded as the traced
    ``ctx.layer_idx``.

    Under the grouped serving tick the whole step body is ``jax.vmap``ed
    over the lane axis, so ``strategy_row`` may arrive BATCHED (one id row
    per lane): the block scan still threads one row entry per layer, and
    ``emit_switch`` lowers the now-batched ``lax.switch`` to an all-branch
    select — bit-exact per lane, whatever mix of rows the group carries.
    """
    b = x_vision.shape[0]
    n_text = text_emb.shape[1]
    from repro.distributed.ctx import constrain
    x = jnp.concatenate([text_emb.astype(dtype), x_vision.astype(dtype)], axis=1)
    x = constrain(x, "dp", "sp", None)
    t_emb = timestep_embedding(t * 1000.0, 256).astype(dtype) @ params["t_mlp1"].astype(dtype)
    t_emb = (jax.nn.silu(t_emb) @ params["t_mlp2"].astype(dtype)).astype(dtype)

    if layer_strategies is not None:
        if strategies is not None or strategy_row is not None:
            raise ValueError(
                "pass either layer_strategies or strategies/strategy_row, "
                "not both")
        strategies, strategy_row = _canonicalize_layer_strategies(
            layer_strategies, ecfg, cfg.n_layers)
    if strategies is not None and strategy_row is None:
        strategy_row = jnp.zeros((cfg.n_layers,), jnp.int32)
    with_row = strategies is not None and mode == "update"

    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)

    def body(x, sl):
        if with_row:
            p, st, li, sid = sl
        else:
            (p, st, li), sid = sl, None
        x, new_st = _block(cfg, ecfg, p, st, x, t_emb, mode=mode,
                           n_text=n_text, layer_idx=li, strategy_id=sid,
                           strategies=strategies if with_row else None,
                           step_idx=step_idx, num_steps=num_steps)
        return x, new_st

    xs = (params["blocks"], states, layer_ids)
    if with_row:
        xs = (*xs, jnp.asarray(strategy_row, jnp.int32))
    from repro.models import layers as L
    x, new_states = L.maybe_scan(body, x, xs, scan=cfg.scan_layers)
    mod = jax.nn.silu(t_emb) @ params["final_mod"].astype(dtype)
    sh, sc = jnp.split(mod, 2, axis=-1)
    x = _modulate(L.rms_norm(x, params["final_norm"], cfg.norm_eps), sh, sc)
    v = x[:, n_text:] @ params["final_proj"].astype(dtype)
    return v, new_states


def train_loss(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    """Flow-matching training loss (rectified flow): v_θ(x_t, t) ≈ x1 − x0.

    batch: {"latents": (B,N_v,patch_dim) clean targets,
            "patch_emb": (B,N_v,d_model) embedded noisy input,
            "text_emb": (B,N_t,d_model), "t": (B,), "noise": like latents}.
    """
    ecfg = EngineConfig()                    # engine off in training (dense)
    states = init_engine_states(cfg, ecfg, batch["patch_emb"].shape[0],
                                batch["text_emb"].shape[1] + batch["patch_emb"].shape[1])
    v, _ = denoise_step(params, cfg, ecfg, states, batch["patch_emb"],
                        batch["text_emb"], batch["t"], mode="dense", dtype=dtype)
    target = batch["latents"] - batch["noise"]
    return jnp.mean(jnp.square(v.astype(jnp.float32) - target.astype(jnp.float32)))
