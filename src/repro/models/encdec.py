"""Whisper-large-v3 backbone (arXiv:2212.04356): encoder-decoder transformer.

The conv audio frontend is a STUB per the task spec — ``input_specs``
provides precomputed mel-frame embeddings (B, 1500, d).  Both stacks are
vanilla pre-LN transformers (LayerNorm + GELU MLP, no gating); the decoder
adds cross-attention to the encoder output.  FlashOmni applicability: S_s
block-skipping on encoder self-attention and decoder cross-attention
(the paper's t↔v metrics map onto text↔audio); S_c inapplicable.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

__all__ = ["init_params", "param_specs", "forward", "train_loss",
           "init_cache", "cache_specs", "prefill", "decode_step"]


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def _init_ln(d, stack=None):
    sh = (d,) if stack is None else (stack, d)
    return {"scale": jnp.ones(sh), "bias": jnp.zeros(sh)}


def _init_vanilla_mlp(key, d, ff, stack=None):
    k1, k2 = jax.random.split(key)
    sh1 = (d, ff) if stack is None else (stack, d, ff)
    sh2 = (ff, d) if stack is None else (stack, ff, d)
    return {"wi": jax.random.normal(k1, sh1) * d ** -0.5,
            "bi": jnp.zeros(sh1[:-2] + (ff,)),
            "wo": jax.random.normal(k2, sh2) * ff ** -0.5,
            "bo": jnp.zeros(sh2[:-2] + (d,))}


def _vanilla_mlp(p, x):
    dtype = x.dtype
    h = jax.nn.gelu(x @ p["wi"].astype(dtype) + p["bi"].astype(dtype))
    return h @ p["wo"].astype(dtype) + p["bo"].astype(dtype)


def _init_block(cfg: ArchConfig, key, stack, cross: bool):
    ks = jax.random.split(key, 3)
    attn, _ = L.init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, stack=stack)
    p = {"attn": attn, "ln1": _init_ln(cfg.d_model, stack),
         "mlp": _init_vanilla_mlp(ks[1], cfg.d_model, cfg.d_ff, stack),
         "ln2": _init_ln(cfg.d_model, stack)}
    if cross:
        xattn, _ = L.init_attention(ks[2], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd, stack=stack)
        p["xattn"] = xattn
        p["lnx"] = _init_ln(cfg.d_model, stack)
    return p


def _block_specs(cross: bool):
    ln = {"scale": (None, None), "bias": (None, None)}
    mlp = {"wi": (None, "fsdp", "tp"), "bi": (None, "tp"),
           "wo": (None, "tp", "fsdp"), "bo": (None, None)}
    s = {"attn": L.attention_specs(True), "ln1": ln, "mlp": mlp, "ln2": ln}
    if cross:
        s["xattn"] = L.attention_specs(True)
        s["lnx"] = ln
    return s


def init_params(cfg: ArchConfig, key) -> Any:
    ke, kd, kte, kpe, kpd, kh = jax.random.split(key, 6)
    n_enc = n_dec = cfg.n_layers
    enc = [_init_block(cfg, jax.random.fold_in(ke, i), None, cross=False)
           for i in range(n_enc)]
    dec = [_init_block(cfg, jax.random.fold_in(kd, i), None, cross=True)
           for i in range(n_dec)]
    return {
        "tok_embed": jax.random.normal(kte, (cfg.vocab_padded, cfg.d_model)) * 0.02,
        "pos_enc": jax.random.normal(kpe, (cfg.encoder_len, cfg.d_model)) * 0.02,
        "pos_dec": jax.random.normal(kpd, (32768, cfg.d_model)) * 0.02,
        "enc": jax.tree.map(lambda *x: jnp.stack(x), *enc),
        "dec": jax.tree.map(lambda *x: jnp.stack(x), *dec),
        "ln_enc": _init_ln(cfg.d_model),
        "ln_dec": _init_ln(cfg.d_model),
    }


def param_specs(cfg: ArchConfig) -> Any:
    ln0 = {"scale": (None,), "bias": (None,)}
    return {"tok_embed": ("tp", "fsdp"), "pos_enc": (None, "fsdp"),
            "pos_dec": (None, "fsdp"),
            "enc": _block_specs(cross=False), "dec": _block_specs(cross=True),
            "ln_enc": ln0, "ln_dec": ln0}


def _mha(p, x, kv_src, cfg, *, causal):
    b, s, _ = x.shape
    dtype = x.dtype
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(dtype)).reshape(b, s, h, hd)
    k = (kv_src @ p["wk"].astype(dtype)).reshape(b, kv_src.shape[1], hkv, hd)
    v = (kv_src @ p["wv"].astype(dtype)).reshape(b, kv_src.shape[1], hkv, hd)
    o = L.gqa_attention(q, k, v, causal=causal)
    return o.reshape(b, s, h * hd) @ p["wo"].astype(dtype)


def encode(params, cfg: ArchConfig, frames, *, dtype=jnp.bfloat16):
    """frames: (B, encoder_len, d_model) — precomputed conv-frontend output."""
    x = frames.astype(dtype) + params["pos_enc"].astype(dtype)

    def body(x, p):
        xa = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        x = x + _mha(p["attn"], xa, xa, cfg, causal=False)
        xm = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        return x + _vanilla_mlp(p["mlp"], xm), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = L.maybe_scan(body, x, params["enc"], scan=cfg.scan_layers)
    return layer_norm(x, params["ln_enc"]["scale"], params["ln_enc"]["bias"])


def decode_train(params, cfg: ArchConfig, tokens, enc_out, *, dtype=jnp.bfloat16):
    b, s = tokens.shape
    x = jnp.take(params["tok_embed"], tokens, axis=0).astype(dtype)
    x = x + params["pos_dec"][:s].astype(dtype)

    def body(x, p):
        xa = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        x = x + _mha(p["attn"], xa, xa, cfg, causal=True)
        xc = layer_norm(x, p["lnx"]["scale"], p["lnx"]["bias"])
        x = x + _mha(p["xattn"], xc, enc_out, cfg, causal=False)
        xm = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        return x + _vanilla_mlp(p["mlp"], xm), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = L.maybe_scan(body, x, params["dec"], scan=cfg.scan_layers)
    x = layer_norm(x, params["ln_dec"]["scale"], params["ln_dec"]["bias"])
    logits = x @ params["tok_embed"].T.astype(dtype)
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits


def forward(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    enc_out = encode(params, cfg, batch["frames"], dtype=dtype)
    logits = decode_train(params, cfg, batch["tokens"], enc_out, dtype=dtype)
    return logits, jnp.zeros((), jnp.float32)


def train_loss(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    logits, _ = forward(params, cfg, batch, dtype=dtype)
    return L.softmax_xent(logits, batch["labels"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    nl = cfg.n_layers
    kv = lambda length: {
        "k": jnp.zeros((nl, batch, length, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((nl, batch, length, cfg.n_kv_heads, cfg.hd), dtype)}
    return {"self": kv(max_len), "cross": kv(cfg.encoder_len),
            "len": jnp.zeros((batch,), jnp.int32)}


def cache_specs(cfg: ArchConfig):
    kv = {"k": (None, "dp", "sp", None, None), "v": (None, "dp", "sp", None, None)}
    # Cross K/V: encoder_len=1500 divides no mesh axis -> batch-sharded only.
    xkv = {"k": (None, "dp", None, None, None), "v": (None, "dp", None, None, None)}
    return {"self": kv, "cross": xkv, "len": ("dp",)}


def decode_step(params, cfg: ArchConfig, cache, token, pos, *, dtype=jnp.bfloat16):
    """One decoder token; cross K/V assumed precomputed in the cache."""
    b = token.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = jnp.take(params["tok_embed"], token[:, None], axis=0).astype(dtype)
    x = x + jax.lax.dynamic_index_in_dim(params["pos_dec"], pos, keepdims=True).astype(dtype)

    def body(x, sl):
        p, kvs, kvx = sl
        xa = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        q = (xa @ p["attn"]["wq"].astype(dtype)).reshape(b, 1, h, hd)
        kq = (xa @ p["attn"]["wk"].astype(dtype)).reshape(b, 1, hkv, hd)
        vq = (xa @ p["attn"]["wv"].astype(dtype)).reshape(b, 1, hkv, hd)
        slot = jnp.minimum(pos, kvs["k"].shape[1] - 1)
        kc = kvs["k"].at[:, slot].set(kq[:, 0].astype(kvs["k"].dtype))
        vc = kvs["v"].at[:, slot].set(vq[:, 0].astype(kvs["v"].dtype))
        cl = jnp.minimum(pos + 1, kc.shape[1]) * jnp.ones((b,), jnp.int32)
        o = L.decode_attention(q, kc, vc, cl)
        x = x + o.reshape(b, 1, h * hd) @ p["attn"]["wo"].astype(dtype)
        xc = layer_norm(x, p["lnx"]["scale"], p["lnx"]["bias"])
        qx = (xc @ p["xattn"]["wq"].astype(dtype)).reshape(b, 1, h, hd)
        el = kvx["k"].shape[1] * jnp.ones((b,), jnp.int32)
        ox = L.decode_attention(qx, kvx["k"], kvx["v"], el)
        x = x + ox.reshape(b, 1, h * hd) @ p["xattn"]["wo"].astype(dtype)
        xm = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        return x + _vanilla_mlp(p["mlp"], xm), {"k": kc, "v": vc}

    x, new_self = L.maybe_scan(body, x, (params["dec"], cache["self"],
                                         cache["cross"]), scan=cfg.scan_layers)
    x = layer_norm(x, params["ln_dec"]["scale"], params["ln_dec"]["bias"])
    logits = (x @ params["tok_embed"].T.astype(dtype))[:, 0]
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits, dict(cache, self=new_self, len=cache["len"] + 1)


def prefill(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    logits, _ = forward(params, cfg, batch, dtype=dtype)
    return logits[:, -1]
