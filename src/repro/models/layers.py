"""Shared model layers (pure-JAX pytrees + logical sharding specs).

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with tuples of LOGICAL axis names (see
``repro.distributed.sharding``).  Layer stacks are initialised with a
leading ``L`` dim (spec ``None``) and applied with ``lax.scan`` so the HLO
stays one-layer-sized regardless of depth.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "init_dense", "init_rmsnorm", "rms_norm", "rope_table", "apply_rope",
    "gqa_attention", "local_attention", "decode_attention",
    "init_attention", "attention_specs", "init_mlp", "mlp",
    "init_moe", "moe_mlp", "softmax_xent", "maybe_scan",
]


def maybe_scan(body, init, xs, *, scan: bool = True):
    """``lax.scan`` or an unrolled python loop (same signature/результат).

    Unrolling exists for the roofline dry-run: XLA's ``cost_analysis``
    counts a while-loop body ONCE regardless of trip count, so scanned
    models under-report FLOPs/bytes by ~n_layers.  ``--unroll`` dry-runs
    lower the loop explicitly to get exact per-device costs (the scanned
    variant remains the compile-validation + production path).
    """
    if scan:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *e: jnp.stack(e), *ys)
    else:
        stacked = None
    return carry, stacked

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param init helpers
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, spec=("fsdp", "tp"), dtype=jnp.float32,
               stack: Optional[int] = None):
    scale = d_in ** -0.5
    shape = (d_in, d_out) if stack is None else (stack, d_in, d_out)
    w = jax.random.normal(key, shape, dtype) * scale
    s = spec if stack is None else (None, *spec)
    return w, s


def init_rmsnorm(d: int, stack: Optional[int] = None):
    shape = (d,) if stack is None else (stack, d)
    spec = (None,) if stack is None else (None, None)
    return jnp.ones(shape, jnp.float32), spec


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_table(positions: jax.Array, dim: int, theta: float = 10000.0):
    """positions (...,) int -> (cos, sin) each (..., dim//2) f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, dh) or (..., S, dh); cos/sin broadcastable (..., S, dh//2)."""
    if x.ndim == cos.ndim + 2:                    # (B,S,H,dh) with (B?,S,dh/2)
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (training / prefill): chunked causal GQA, optional window.
# ---------------------------------------------------------------------------

def _gqa_scores(qc, k):
    """qc (B,Hkv,G,C,dh) x k (B,Hkv,S,dh) -> (B,Hkv,G,C,S)."""
    return jnp.einsum("bhgcd,bhsd->bhgcs", qc, k)


def gqa_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                  window: Optional[int] = None, chunk: int = 512,
                  scale: Optional[float] = None) -> jax.Array:
    """Chunked masked attention.  q (B,S,H,dh); k,v (B,Skv,Hkv,dh).

    Memory is O(chunk·S_kv) per head; XLA fuses the inner softmax.  The
    window mask also enables the gemma/mixtral sliding-window layers (the
    sub-quadratic path for those is :func:`local_attention`).

    §Perf note (EXPERIMENTS iteration A1): the loop slices Q by INDEX from
    the un-transposed operand instead of scanning a transposed stacked
    copy — GSPMD keeps batch/head sharding through dynamic-slice, whereas
    the stacked form lost it (involuntary full rematerialisation:
    replicated f32[global_batch, ...] temps, ~30x memory-term inflation).
    """
    b, s, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = (dh ** -0.5) if scale is None else scale
    from repro.distributed.ctx import constrain
    qh = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, s, dh) * scale
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    qh = constrain(qh, "dp", None, None, None, "tp")
    kh = constrain(kh, "dp", None, None, "tp")
    vh = constrain(vh, "dp", None, None, "tp")
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    kv_pos = jnp.arange(skv)

    def one_chunk(ci):
        qc = jax.lax.dynamic_slice_in_dim(qh, ci * chunk, chunk, axis=3)
        qc = constrain(qc, "dp", None, None, None, "tp")
        sc = jnp.einsum("bhgcd,bhsd->bhgcs", qc, kh).astype(jnp.float32)
        q_pos = ci * chunk + jnp.arange(chunk) + q_offset
        mask = jnp.ones((chunk, skv), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        sc = jnp.where(mask, sc, _NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhgcs,bhsd->bhgcd", p, vh.astype(jnp.float32))
        return constrain(o.astype(q.dtype), "dp", None, None, None, "tp")

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))   # (nc,b,hkv,g,chunk,dh)
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, g, n_chunks * chunk, dh)
    out = out[:, :, :, :s].reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def local_attention(q, k, v, *, window: int, chunk: Optional[int] = None,
                    scale: Optional[float] = None) -> jax.Array:
    """Sub-quadratic sliding-window attention: each q chunk attends to a
    banded KV slice of length chunk+window.  Cost O(S·(chunk+window))."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    chunk = window if chunk is None else chunk
    scale = (dh ** -0.5) if scale is None else scale
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    band = window + chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # Pad KV on the left so every band slice is in range.
    kp = jnp.pad(k, ((0, 0), (band - chunk, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (band - chunk, pad), (0, 0), (0, 0)))
    qch = q.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def one_chunk(ci, qc):
        start = ci * chunk                      # band begins at start in padded kv
        kb = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        qg = qc.reshape(b, chunk, hkv, g, dh) * scale
        sc = jnp.einsum("bchgd,bshd->bhgcs", qg, kb).astype(jnp.float32)
        q_pos = ci * chunk + jnp.arange(chunk)
        kv_pos = start + jnp.arange(band) - (band - chunk)
        mask = (q_pos[:, None] >= kv_pos[None, :]) & \
               (q_pos[:, None] - kv_pos[None, :] < window) & (kv_pos[None, :] >= 0)
        sc = jnp.where(mask, sc, _NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        ob = jnp.einsum("bhgcs,bshd->bchgd", p, vb.astype(jnp.float32))
        return ob.reshape(b, chunk, h, dh)

    out = jax.lax.map(lambda args: one_chunk(*args), (jnp.arange(n_chunks), qch))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, dh)
    return out[:, :s].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: Optional[int] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token decode: q (B,1,H,dh) vs caches (B,S,Hkv,dh)."""
    b, _, h, dh = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = (dh ** -0.5) if scale is None else scale
    qh = q.reshape(b, hkv, g, dh) * scale
    sc = jnp.einsum("bhgd,bshd->bhgs", qh, k_cache).astype(jnp.float32)
    pos = jnp.arange(s)
    mask = pos[None, :] < cache_len[:, None]                  # (B,S)
    if window is not None:
        mask &= pos[None, :] >= cache_len[:, None] - window
    sc = jnp.where(mask[:, None, None, :], sc, _NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention params
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   stack: Optional[int] = None, qk_norm: bool = False):
    ks = jax.random.split(key, 4)
    wq, sq = init_dense(ks[0], d_model, n_heads * head_dim, ("fsdp", "tp"), stack=stack)
    wk, sk = init_dense(ks[1], d_model, n_kv_heads * head_dim, ("fsdp", "tp"), stack=stack)
    wv, sv = init_dense(ks[2], d_model, n_kv_heads * head_dim, ("fsdp", "tp"), stack=stack)
    wo, so = init_dense(ks[3], n_heads * head_dim, d_model, ("tp", "fsdp"), stack=stack)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    s = {"wq": sq, "wk": sk, "wv": sv, "wo": so}
    if qk_norm:
        p["q_norm"], s["q_norm"] = init_rmsnorm(head_dim, stack)
        p["k_norm"], s["k_norm"] = init_rmsnorm(head_dim, stack)
    return p, s


def attention_specs(stack: bool, qk_norm: bool = False):
    base = (None,) if stack else ()
    s = {"wq": (*base, "fsdp", "tp"), "wk": (*base, "fsdp", "tp"),
         "wv": (*base, "fsdp", "tp"), "wo": (*base, "tp", "fsdp")}
    if qk_norm:
        s["q_norm"] = (*base, None)
        s["k_norm"] = (*base, None)
    return s


# ---------------------------------------------------------------------------
# MLP (gated SiLU) & MoE
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, stack: Optional[int] = None):
    ks = jax.random.split(key, 3)
    wi, si = init_dense(ks[0], d_model, d_ff, ("fsdp", "tp"), stack=stack)
    wg, sg = init_dense(ks[1], d_model, d_ff, ("fsdp", "tp"), stack=stack)
    wo, so = init_dense(ks[2], d_ff, d_model, ("tp", "fsdp"), stack=stack)
    return {"wi": wi, "wg": wg, "wo": wo}, {"wi": si, "wg": sg, "wo": so}


def mlp(p, x):
    from repro.distributed.ctx import constrain
    # Constrain the dot OUTPUTS to stay batch-sharded: otherwise GSPMD may
    # pick contraction-sharded partials (fsdp weight dim) and all-reduce
    # global-batch activations (§Perf iteration A4 — 5x collective win).
    h = jax.nn.silu(constrain(x @ p["wg"], "dp", None, "tp")) * \
        constrain(x @ p["wi"], "dp", None, "tp")
    return constrain(h @ p["wo"], "dp", None, None)


def init_moe(key, d_model: int, d_ff: int, num_experts: int,
             stack: Optional[int] = None):
    ks = jax.random.split(key, 4)
    shape = lambda *dims: dims if stack is None else (stack, *dims)
    base = () if stack is None else (None,)
    scale = d_model ** -0.5
    p = {
        "router": jax.random.normal(ks[0], shape(d_model, num_experts)) * scale,
        "wi": jax.random.normal(ks[1], shape(num_experts, d_model, d_ff)) * scale,
        "wg": jax.random.normal(ks[2], shape(num_experts, d_model, d_ff)) * scale,
        "wo": jax.random.normal(ks[3], shape(num_experts, d_ff, d_model)) * (d_ff ** -0.5),
    }
    s = {
        "router": (*base, "fsdp", None),
        "wi": (*base, "ep", "fsdp", "tp"),
        "wg": (*base, "ep", "fsdp", "tp"),
        "wo": (*base, "ep", "tp", "fsdp"),
    }
    return p, s


def moe_mlp(p, x, *, top_k: int, capacity_factor: float = 1.25):
    """Capacity-based top-k MoE (gather-dispatch; FLOPs ≈ k·tokens·expert).

    EP-friendly: expert buffers (E, C, d) shard E over ``ep`` and flow
    through an all-to-all inserted by the partitioner when ep is mapped.
    """
    b, s, d = x.shape
    e = p["router"].shape[-1]
    n = b * s
    xf = x.reshape(n, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)                 # (N,k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    cap = int(capacity_factor * n * top_k / e) + 1
    flat_e = eids.reshape(-1)                                 # (N*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                      # position in expert
    flat_pos = jnp.sum(pos * onehot, axis=-1)                 # (N*k,)
    keep = flat_pos < cap
    tok_ids = jnp.repeat(jnp.arange(n), top_k)

    # Dispatch: (E, C, d) expert buffers.
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, flat_e, e - 1),
                 jnp.where(keep, flat_pos, cap - 1)].add(
        jnp.where(keep[:, None], xf[tok_ids], 0))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    yb = jnp.einsum("ecf,efd->ecd", h, p["wo"])               # (E,C,d)

    # Combine: gather back and weight by gate.
    flat_gate = gates.reshape(-1)
    contrib = yb[flat_e, jnp.minimum(flat_pos, cap - 1)] * \
        (flat_gate * keep.astype(flat_gate.dtype))[:, None]
    y = jnp.zeros((n, d), jnp.float32).at[tok_ids].add(contrib.astype(jnp.float32))
    aux = _load_balance_loss(probs, eids, e)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _load_balance_loss(probs, eids, e):
    """Switch-style load-balancing auxiliary loss."""
    n = probs.shape[0]
    frac_tokens = jnp.mean(jax.nn.one_hot(eids[:, 0], e), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return e * jnp.sum(frac_tokens * frac_probs)


def softmax_xent(logits: jax.Array, labels: jax.Array, z_loss: float = 1e-4):
    """Cross entropy with z-loss; logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll + z_loss * jnp.square(lse)
    return jnp.mean(loss)
