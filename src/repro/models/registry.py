"""Model registry: family -> module with a unified batch-dict API."""

from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import dit, encdec, rglru, ssm, transformer, vision

__all__ = ["get_model", "Model"]


class Model:
    """Thin adapter giving every family the same entry points.

    ``train_loss(params, batch)``, ``prefill(params, batch)``,
    ``decode_step(params, cache, batch)``; batches are dicts produced by
    ``repro.launch.specs.input_specs``.
    """

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.mod = {
            "dense": transformer, "moe": transformer, "vlm": vision,
            "ssm": ssm, "hybrid": rglru, "encdec": encdec, "dit": dit,
        }[cfg.family]

    # -- params -----------------------------------------------------------
    def init_params(self, key):
        return self.mod.init_params(self.cfg, key)

    def param_specs(self):
        return self.mod.param_specs(self.cfg)

    # -- training ---------------------------------------------------------
    def train_loss(self, params, batch, *, dtype=jnp.bfloat16):
        if self.cfg.family in ("dense", "moe", "ssm", "hybrid"):
            return self.mod.train_loss(params, self.cfg, batch, dtype=dtype)
        return self.mod.train_loss(params, self.cfg, batch, dtype=dtype)

    # -- serving ----------------------------------------------------------
    def prefill(self, params, batch, *, dtype=jnp.bfloat16):
        if self.cfg.family in ("dense", "moe", "ssm", "hybrid"):
            return self.mod.prefill(params, self.cfg, batch["tokens"], dtype=dtype)
        return self.mod.prefill(params, self.cfg, batch, dtype=dtype)

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        return self.mod.init_cache(self.cfg, batch_size, max_len, dtype)

    def cache_specs(self):
        return self.mod.cache_specs(self.cfg)

    def decode_step(self, params, cache, token, pos, *, dtype=jnp.bfloat16):
        return self.mod.decode_step(params, self.cfg, cache, token, pos, dtype=dtype)


def get_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
