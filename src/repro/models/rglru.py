"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks +
local attention, pattern 1 attention : 2 recurrent (period 3).

The RG-LRU gate:  r_t = σ(W_a x + b_a),  i_t = σ(W_x x + b_x)
                  log a_t = -c · softplus(Λ) · r_t          (c = 8)
                  h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Sequences use ``jax.lax.associative_scan`` (O(log S) depth — this plus the
local-attention window is why the arch runs the ``long_500k`` cell with a
CONSTANT-size decode state).  FlashOmni applicability: ``S_s`` expresses
the local-attention window as a static symbol pattern on attn layers;
feature caching is inapplicable (no diffusion timesteps).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

__all__ = ["init_params", "param_specs", "forward", "train_loss",
           "init_cache", "cache_specs", "prefill", "decode_step", "rg_lru"]

_C = 8.0
CONV_K = 4


def rg_lru(x, gate_x, gate_a, lam):
    """x, gates (B,S,D); lam (D,). Associative scan over a_t h + b_t."""
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(lam) * r                  # (B,S,D)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = mult * (i * x.astype(jnp.float32))

    def combine(l, r_):
        a1, b1 = l
        a2, b2 = r_
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype)


def rg_lru_step(state, x, gate_x, gate_a, lam):
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    h = a * state + mult * (i * x.astype(jnp.float32))
    return h.astype(x.dtype), h


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _init_rec_block(cfg: ArchConfig, key, stack: Optional[int]):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    sh = lambda *dims: dims if stack is None else (stack, *dims)
    s = d ** -0.5
    p = {
        "ln": jnp.ones(sh(d)),
        "w_in_x": jax.random.normal(ks[0], sh(d, d)) * s,     # recurrent branch
        "w_in_y": jax.random.normal(ks[1], sh(d, d)) * s,     # gelu gate branch
        "conv": jax.random.normal(ks[2], sh(CONV_K, d)) * 0.2,
        "w_gate_x": jax.random.normal(ks[3], sh(d, d)) * s,
        "w_gate_a": jax.random.normal(ks[4], sh(d, d)) * s,
        "lam": jnp.full(sh(d), 0.65),
        "w_out": jax.random.normal(ks[5], sh(d, d)) * s,
    }
    return p


def _rec_specs(stack: bool):
    b = (None,) if stack else ()
    return {"ln": (*b, None), "w_in_x": (*b, "fsdp", "tp"), "w_in_y": (*b, "fsdp", "tp"),
            "conv": (*b, None, "tp"), "w_gate_x": (*b, "fsdp", "tp"),
            "w_gate_a": (*b, "fsdp", "tp"), "lam": (*b, "tp"),
            "w_out": (*b, "tp", "fsdp")}


def _init_attn_block(cfg: ArchConfig, key, stack: Optional[int]):
    ka, km = jax.random.split(key)
    attn, _ = L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, stack=stack, qk_norm=False)
    mlp, _ = L.init_mlp(km, cfg.d_model, cfg.d_ff, stack=stack)
    sh = lambda *dims: dims if stack is None else (stack, *dims)
    return {"attn": attn, "mlp": mlp, "ln1": jnp.ones(sh(cfg.d_model)),
            "ln2": jnp.ones(sh(cfg.d_model))}


def _attn_specs(stack: bool):
    b = (None,) if stack else ()
    return {"attn": L.attention_specs(stack), "ln1": (*b, None), "ln2": (*b, None),
            "mlp": {"wi": (*b, "fsdp", "tp"), "wg": (*b, "fsdp", "tp"),
                    "wo": (*b, "tp", "fsdp")}}


def _init_mlp_block(cfg, key, stack):
    mlp, _ = L.init_mlp(key, cfg.d_model, cfg.d_ff, stack=stack)
    sh = lambda *dims: dims if stack is None else (stack, *dims)
    return {"mlp": mlp, "ln": jnp.ones(sh(cfg.d_model))}


def n_cycles(cfg: ArchConfig) -> int:
    # Pattern period 3: [recurrent, recurrent, local-attn]
    assert cfg.n_layers % 3 == 2 or cfg.n_layers % 3 == 0, cfg.n_layers
    return cfg.n_layers // 3


def init_params(cfg: ArchConfig, key) -> Any:
    ke, kr, ka, kh, kt = jax.random.split(key, 5)
    nc = n_cycles(cfg)
    rec = [ _init_rec_block(cfg, jax.random.fold_in(kr, i), None)
            for i in range(nc * 2) ]
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model)) * 0.02,
        "rec": jax.tree.map(lambda *x: jnp.stack(x).reshape(nc, 2, *x[0].shape), *rec),
        "attn": _init_attn_block(cfg, ka, nc),
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    # trailing layers (26 % 3 == 2): two recurrent blocks
    tail = cfg.n_layers - nc * 3
    if tail:
        t = [_init_rec_block(cfg, jax.random.fold_in(kt, i), None) for i in range(tail)]
        params["tail"] = jax.tree.map(lambda *x: jnp.stack(x), *t)
    return params


def param_specs(cfg: ArchConfig) -> Any:
    nc = n_cycles(cfg)
    specs = {
        "embed": ("tp", "fsdp"),
        "rec": jax.tree.map(lambda s: (None, *s), _rec_specs(True),
                            is_leaf=lambda x: isinstance(x, tuple)),
        "attn": _attn_specs(True),
        "final_norm": (None,),
    }
    if cfg.n_layers - nc * 3:
        specs["tail"] = _rec_specs(True)
    return specs


def _rec_apply(cfg, p, x):
    dtype = x.dtype
    res = x
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    y = jax.nn.gelu(xn @ p["w_in_y"].astype(dtype))
    xr = xn @ p["w_in_x"].astype(dtype)
    xr = _causal_conv(xr, p["conv"].astype(dtype))
    h = rg_lru(xr, xn @ p["w_gate_x"].astype(dtype),
               xn @ p["w_gate_a"].astype(dtype), p["lam"])
    return res + (h * y) @ p["w_out"].astype(dtype)


def _causal_conv(x, w):
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))


def _attn_apply_blk(cfg, p, x, cos, sin):
    dtype = x.dtype
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xa = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (xa @ p["attn"]["wq"].astype(dtype)).reshape(b, s, h, hd)
    k = (xa @ p["attn"]["wk"].astype(dtype)).reshape(b, s, hkv, hd)
    v = (xa @ p["attn"]["wv"].astype(dtype)).reshape(b, s, hkv, hd)
    q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
    if cfg.window and s > 2 * cfg.window:
        o = L.local_attention(q, k, v, window=cfg.window)
    else:
        o = L.gqa_attention(q, k, v, causal=True, window=cfg.window)
    x = x + o.reshape(b, s, h * hd) @ p["attn"]["wo"].astype(dtype)
    xm = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp(jax.tree.map(lambda w: w.astype(dtype), p["mlp"]), xm)


def forward(params, cfg: ArchConfig, tokens, *, dtype=jnp.bfloat16):
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype) * (cfg.d_model ** 0.5)
    cos, sin = L.rope_table(jnp.arange(s), cfg.hd, cfg.rope_theta)
    remat = (lambda f: jax.checkpoint(
        f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)) \
        if cfg.remat else (lambda f: f)

    def cycle(x, sl):
        def rec_body(x, p):
            return _rec_apply(cfg, p, x), None
        x, _ = L.maybe_scan(remat(rec_body), x, sl["rec"], scan=True)
        x = remat(lambda x2, p: _attn_apply_blk(cfg, p, x2, cos, sin))(x, sl["attn"])
        return x, None

    x, _ = L.maybe_scan(cycle, x, {"rec": params["rec"], "attn": params["attn"]},
                        scan=cfg.scan_layers)
    if "tail" in params:
        def rec_body(x, p):
            return _rec_apply(cfg, p, x), None
        x, _ = L.maybe_scan(remat(rec_body), x, params["tail"],
                            scan=cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T.astype(dtype)
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits, jnp.zeros((), jnp.float32)


def train_loss(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    logits, _ = forward(params, cfg, batch["tokens"], dtype=dtype)
    return L.softmax_xent(logits, batch["labels"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    nc = n_cycles(cfg)
    d = cfg.d_model
    w = min(cfg.window or max_len, max_len)
    cache = {
        "rec_h": jnp.zeros((nc, 2, batch, d), jnp.float32),
        "rec_conv": jnp.zeros((nc, 2, batch, CONV_K - 1, d), dtype),
        "attn": {"k": jnp.zeros((nc, batch, w, cfg.n_kv_heads, cfg.hd), dtype),
                 "v": jnp.zeros((nc, batch, w, cfg.n_kv_heads, cfg.hd), dtype)},
        "len": jnp.zeros((batch,), jnp.int32),
    }
    tail = cfg.n_layers - nc * 3
    if tail:
        cache["tail_h"] = jnp.zeros((tail, batch, d), jnp.float32)
        cache["tail_conv"] = jnp.zeros((tail, batch, CONV_K - 1, d), dtype)
    return cache


def cache_specs(cfg: ArchConfig):
    nc = n_cycles(cfg)
    specs = {
        "rec_h": (None, None, "dp", "tp"),
        "rec_conv": (None, None, "dp", None, "tp"),
        "attn": {"k": (None, "dp", "sp", None, None),
                 "v": (None, "dp", "sp", None, None)},
        "len": ("dp",),
    }
    if cfg.n_layers - nc * 3:
        specs["tail_h"] = (None, "dp", "tp")
        specs["tail_conv"] = (None, "dp", None, "tp")
    return specs


def _rec_step(cfg, p, x, h_state, conv_state):
    dtype = x.dtype
    res = x
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    y = jax.nn.gelu(xn @ p["w_in_y"].astype(dtype))
    xr = xn @ p["w_in_x"].astype(dtype)                       # (B,1,D)
    hist = jnp.concatenate([conv_state, xr[:, 0:1]], axis=1)  # (B,K,D)
    xr = jnp.einsum("bkd,kd->bd", hist, p["conv"].astype(dtype))[:, None]
    new_conv = hist[:, 1:]
    h, new_h = rg_lru_step(h_state[:, None], xr,
                           xn @ p["w_gate_x"].astype(dtype),
                           xn @ p["w_gate_a"].astype(dtype), p["lam"])
    out = res + (h * y) @ p["w_out"].astype(dtype)
    return out, new_h[:, 0], new_conv


def decode_step(params, cfg: ArchConfig, cache, token, pos, *, dtype=jnp.bfloat16):
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dtype) * (cfg.d_model ** 0.5)
    cos, sin = L.rope_table(pos[None], cfg.hd, cfg.rope_theta)
    w = cache["attn"]["k"].shape[2]

    def cycle(x, sl):
        p_cyc, rec_h, rec_conv, kv = sl
        def rec_body(carry, sl2):
            x, = carry
            p, h0, c0 = sl2
            x, h1, c1 = _rec_step(cfg, p, x, h0, c0)
            return (x,), (h1, c1)
        (x,), (h_new, c_new) = L.maybe_scan(
            rec_body, (x,), (p_cyc["rec"], rec_h, rec_conv), scan=True)
        # local attention w/ ring buffer
        p = p_cyc["attn"]
        xa = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q = (xa @ p["attn"]["wq"].astype(dtype)).reshape(b, 1, cfg.n_heads, cfg.hd)
        kq = (xa @ p["attn"]["wk"].astype(dtype)).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
        vq = (xa @ p["attn"]["wv"].astype(dtype)).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
        q, kq = L.apply_rope(q, cos, sin), L.apply_rope(kq, cos, sin)
        slot = pos % w
        kc = kv["k"].at[:, slot].set(kq[:, 0].astype(kv["k"].dtype))
        vc = kv["v"].at[:, slot].set(vq[:, 0].astype(kv["v"].dtype))
        cl = jnp.minimum(pos + 1, w) * jnp.ones((b,), jnp.int32)
        o = L.decode_attention(q, kc, vc, cl)
        x = x + o.reshape(b, 1, -1) @ p["attn"]["wo"].astype(dtype)
        xm = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp(jax.tree.map(lambda w2: w2.astype(dtype), p["mlp"]), xm)
        return x, (h_new, c_new, {"k": kc, "v": vc})

    x, (h_new, c_new, kv_new) = L.maybe_scan(
        cycle, x, ({"rec": params["rec"], "attn": params["attn"]},
                   cache["rec_h"], cache["rec_conv"], cache["attn"]),
        scan=cfg.scan_layers)
    new_cache = dict(cache, rec_h=h_new, rec_conv=c_new, attn=kv_new,
                     len=cache["len"] + 1)
    if "tail" in params:
        def rec_body(carry, sl2):
            x, = carry
            p, h0, c0 = sl2
            x, h1, c1 = _rec_step(cfg, p, x, h0, c0)
            return (x,), (h1, c1)
        (x,), (th, tc) = L.maybe_scan(
            rec_body, (x,), (params["tail"], cache["tail_h"], cache["tail_conv"]),
            scan=cfg.scan_layers)
        new_cache["tail_h"], new_cache["tail_conv"] = th, tc
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(dtype))[:, 0]
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits, new_cache


def prefill(params, cfg: ArchConfig, tokens, *, dtype=jnp.bfloat16):
    logits, _ = forward(params, cfg, tokens, dtype=dtype)
    return logits[:, -1]
