"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Attention-free; FlashOmni's technique is inapplicable here (DESIGN
§Arch-applicability) — the arch is still first-class: train/prefill/decode,
scan-over-layers, sharding specs, constant-memory recurrent decode state
(the reason this arch RUNS the ``long_500k`` cell).

Block: in_proj -> [z | x | B | C | dt]; causal depthwise conv on (x,B,C);
chunked SSD; gated RMSNorm; out_proj.  The chunked SSD follows the paper's
block decomposition: intra-chunk (quadratic in chunk), chunk states,
inter-chunk recurrence (scan), off-diagonal contribution.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

__all__ = ["init_params", "param_specs", "forward", "train_loss",
           "init_cache", "cache_specs", "prefill", "decode_step", "ssd_chunked",
           "ssd_recurrent_step"]

HEAD_DIM = 64
CONV_K = 4


def _dims(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // HEAD_DIM
    return d_inner, n_heads, cfg.ssm_state


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, a_log, b, c, *, chunk: int = 128):
    """Chunked SSD.  x (B,S,H,P); dt (B,S,H); a_log (H,) (A = -exp(a_log));
    b, c (B,S,N) single group.  Returns y (B,S,H,P)."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} not divisible by chunk {chunk}"
    a = -jnp.exp(a_log)                                    # (H,)
    xb = (x * dt[..., None]).reshape(bs, nc, chunk, h, p)  # dt-weighted input
    da = (dt * a).reshape(bs, nc, chunk, h)                # per-step log decay
    bb = b.reshape(bs, nc, chunk, n)
    cc = c.reshape(bs, nc, chunk, n)

    cum = jnp.cumsum(da, axis=2)                           # (B,nc,c,H)
    # 1) intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,c,c,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    ldec = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bkin,bkjn->bkij", cc, bb)         # (B,nc,c,c)
    y_diag = jnp.einsum("bkij,bkijh,bkjhp->bkihp", scores, ldec, xb)

    # 2) chunk-final states: sum_j exp(cum_last - cum_j) B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,c,H)
    states = jnp.einsum("bkjn,bkjh,bkjhp->bkhpn", bb, decay_to_end, xb)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)

    def scan_fn(carry, inp):
        st, = carry
        s_k, dk = inp
        new = st * dk[:, :, None, None] + s_k
        return (new,), st                                  # emit state BEFORE chunk

    init = jnp.zeros((bs, h, p, n), jnp.float32)
    (_, ), prev_states = jax.lax.scan(
        scan_fn, (init,),
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,N)

    # 4) off-diagonal: y_off_i = C_i · (exp(cum_i) ⊙ prev_state)
    in_decay = jnp.exp(cum)                                # (B,nc,c,H)
    y_off = jnp.einsum("bkin,bkih,bkhpn->bkihp", cc, in_decay,
                       prev_states.astype(cc.dtype))
    y = (y_diag + y_off).reshape(bs, s, h, p)
    return y.astype(x.dtype)


def ssd_recurrent_step(state, x_t, dt_t, a_log, b_t, c_t):
    """One-token SSD update.  state (B,H,P,N); x_t (B,H,P); dt_t (B,H);
    b_t, c_t (B,N).  Returns (y_t, new_state)."""
    decay = jnp.exp(dt_t * (-jnp.exp(a_log)))              # (B,H)
    incr = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
    new_state = state * decay[..., None, None] + incr
    y = jnp.einsum("bhpn,bn->bhp", new_state, c_t)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Block / model
# ---------------------------------------------------------------------------

def _init_block(cfg: ArchConfig, key, stack: Optional[int]):
    d_inner, h, n = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * n + h
    ks = jax.random.split(key, 4)
    sh = lambda *dims: dims if stack is None else (stack, *dims)
    return {
        "in_proj": jax.random.normal(ks[0], sh(cfg.d_model, d_in_proj)) * cfg.d_model ** -0.5,
        "conv": jax.random.normal(ks[1], sh(CONV_K, d_inner + 2 * n)) * 0.2,
        "a_log": jnp.zeros(sh(h)),
        "dt_bias": jnp.zeros(sh(h)),
        "d_skip": jnp.ones(sh(h)),
        "norm": jnp.ones(sh(d_inner)),
        "out_proj": jax.random.normal(ks[2], sh(d_inner, cfg.d_model)) * d_inner ** -0.5,
        "ln": jnp.ones(sh(cfg.d_model)),
    }


def _block_specs(stack: bool):
    b = (None,) if stack else ()
    return {"in_proj": (*b, "fsdp", "tp"), "conv": (*b, None, "tp"),
            "a_log": (*b, "tp"), "dt_bias": (*b, "tp"), "d_skip": (*b, "tp"),
            "norm": (*b, "tp"), "out_proj": (*b, "tp", "fsdp"), "ln": (*b, None)}


def init_params(cfg: ArchConfig, key) -> Any:
    ke, kb, kh = jax.random.split(key, 3)
    blocks = [_init_block(cfg, jax.random.fold_in(kb, i), None)
              for i in range(cfg.n_layers)]
    return {
        "embed": jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model)) * 0.02,
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "final_norm": jnp.ones((cfg.d_model,)),
        "lm_head": jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded)) * cfg.d_model ** -0.5,
    }


def param_specs(cfg: ArchConfig) -> Any:
    return {"embed": ("tp", "fsdp"), "blocks": _block_specs(True),
            "final_norm": (None,), "lm_head": ("fsdp", "tp")}


def _causal_conv(x, w):
    """Depthwise causal conv: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out


def _split_proj(cfg, proj):
    d_inner, h, n = _dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * n]
    dt = proj[..., -h:]
    return z, xbc, dt


def _block_apply(cfg: ArchConfig, p, x, *, chunk: int = 128):
    d_inner, h, n = _dims(cfg)
    dtype = x.dtype
    res = x
    x = L.rms_norm(x, p["ln"], cfg.norm_eps)
    proj = x @ p["in_proj"].astype(dtype)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv"].astype(dtype)))
    xs = xbc[..., :d_inner].reshape(*x.shape[:2], h, HEAD_DIM)
    b = xbc[..., d_inner:d_inner + n]
    c = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y = ssd_chunked(xs.astype(jnp.float32), dt, p["a_log"],
                    b.astype(jnp.float32), c.astype(jnp.float32), chunk=chunk)
    y = y + xs.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(*x.shape[:2], d_inner).astype(dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)   # gated norm
    return res + y @ p["out_proj"].astype(dtype)


def forward(params, cfg: ArchConfig, tokens, *, dtype=jnp.bfloat16, chunk: int = 128):
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)

    def body(x, p):
        return _block_apply(cfg, p, x, chunk=chunk), jnp.zeros((), jnp.float32)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = L.maybe_scan(body, x, params["blocks"], scan=cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(dtype)
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits, jnp.zeros((), jnp.float32)


def train_loss(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    logits, _ = forward(params, cfg, batch["tokens"], dtype=dtype)
    return L.softmax_xent(logits, batch["labels"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    d_inner, h, n = _dims(cfg)
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, h, HEAD_DIM, n), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, CONV_K - 1, d_inner + 2 * n), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ArchConfig):
    return {"ssm": (None, "dp", "tp", None, None),
            "conv": (None, "dp", None, "tp"), "len": ("dp",)}


def decode_step(params, cfg: ArchConfig, cache, token, pos, *, dtype=jnp.bfloat16):
    d_inner, h, n = _dims(cfg)
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dtype)

    def body(x, sl):
        p, ssm, conv = sl
        res = x
        xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
        proj = xn @ p["in_proj"].astype(dtype)
        z, xbc, dt = _split_proj(cfg, proj)
        hist = jnp.concatenate([conv, xbc], axis=1)            # (B, K, C)
        xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv"].astype(dtype)))
        new_conv = hist[:, 1:]
        xs = xbc[:, :d_inner].reshape(-1, h, HEAD_DIM)
        bq = xbc[:, d_inner:d_inner + n]
        cq = xbc[:, d_inner + n:]
        dtq = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
        y, new_ssm = ssd_recurrent_step(ssm, xs.astype(jnp.float32), dtq,
                                        p["a_log"], bq.astype(jnp.float32),
                                        cq.astype(jnp.float32))
        y = y + xs.astype(jnp.float32) * p["d_skip"][:, None]
        y = y.reshape(-1, 1, d_inner).astype(dtype)
        y = L.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
        return res + y @ p["out_proj"].astype(dtype), (new_ssm, new_conv)

    x, (new_ssm, new_conv) = L.maybe_scan(
        body, x, (params["blocks"], cache["ssm"], cache["conv"]),
        scan=cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"].astype(dtype))[:, 0]
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits, {"ssm": new_ssm, "conv": new_conv, "len": cache["len"] + 1}


def prefill(params, cfg: ArchConfig, tokens, *, dtype=jnp.bfloat16):
    logits, _ = forward(params, cfg, tokens, dtype=dtype)
    return logits[:, -1]
