"""Decoder-only transformer LM (dense / MoE / local:global patterns).

Covers: gemma3-1b/12b (5:1 local:global GQA), granite-8b, llama3-405b,
mixtral-8x22b (MoE + SWA), granite-moe-3b (MoE top-8), and the backbone of
llama-3.2-vision (cross-attention extension lives in ``models/vision.py``).

Structure notes (production-grade at 1000+ nodes):
  * ``lax.scan`` over layer stacks -> HLO is one-layer-sized; compile time
    is depth-independent (essential for 126-layer llama3-405b).
  * Mixed local/global patterns are *cycle-grouped*: the repeating unit of
    ``global_every`` layers becomes [scan over (global_every-1) local
    layers] + [one global layer], scanned over cycles.  Local layers use
    the banded sub-quadratic kernel and RING-BUFFER KV caches of length
    ``window`` — this is what makes ``long_500k`` feasible.
  * Activation remat (``jax.checkpoint``) on the block body with the
    dots-saveable policy.
  * All params carry logical sharding specs (fsdp/tp), see
    ``repro.distributed.sharding``.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

__all__ = [
    "init_params", "param_specs", "forward", "train_loss",
    "init_cache", "cache_specs", "prefill", "decode_step",
]

Pytree = Any


# ---------------------------------------------------------------------------
# Layer grouping (cycles of local layers + one global layer)
# ---------------------------------------------------------------------------

def layer_groups(cfg: ArchConfig) -> tuple[int, int, int]:
    """Returns (n_cycles, locals_per_cycle, n_tail_local)."""
    if cfg.global_every <= 1:
        if cfg.global_every == 0:      # all-local (pure SWA, e.g. mixtral)
            return 0, 0, cfg.n_layers
        return cfg.n_layers, 0, 0     # all-global
    p = cfg.global_every
    return cfg.n_layers // p, p - 1, cfg.n_layers % p


def _block_init(key, cfg: ArchConfig, stack: Optional[int]):
    ks = jax.random.split(key, 4)
    attn, _ = L.init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, stack=stack, qk_norm=True)
    if cfg.moe:
        mlp, _ = L.init_moe(ks[1], cfg.d_model, cfg.moe.d_ff,
                            cfg.moe.num_experts, stack=stack)
    else:
        mlp, _ = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, stack=stack)
    ln1, _ = L.init_rmsnorm(cfg.d_model, stack)
    ln2, _ = L.init_rmsnorm(cfg.d_model, stack)
    return {"attn": attn, "mlp": mlp, "ln1": ln1, "ln2": ln2}


def _block_specs(cfg: ArchConfig, stack: bool):
    base = (None,) if stack else ()
    attn = L.attention_specs(stack, qk_norm=True)
    if cfg.moe:
        mlp = {"router": (*base, "fsdp", None), "wi": (*base, "ep", "fsdp", "tp"),
               "wg": (*base, "ep", "fsdp", "tp"), "wo": (*base, "ep", "tp", "fsdp")}
    else:
        mlp = {"wi": (*base, "fsdp", "tp"), "wg": (*base, "fsdp", "tp"),
               "wo": (*base, "tp", "fsdp")}
    return {"attn": attn, "mlp": mlp, "ln1": (*base, None), "ln2": (*base, None)}


def _stack2(tree_fn, outer: int, inner: int, key):
    """Init params stacked (outer, inner, ...) — one fold per layer."""
    flat = [tree_fn(jax.random.fold_in(key, i * inner + j))
            for i in range(outer) for j in range(inner)]
    return jax.tree.map(lambda *xs: jnp.stack(xs).reshape(outer, inner, *xs[0].shape),
                        *flat)


def init_params(cfg: ArchConfig, key) -> Pytree:
    kc, kg, kt, ke, kh = jax.random.split(key, 5)
    n_cyc, n_loc, n_tail = layer_groups(cfg)
    params: dict = {}
    params["embed"] = jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model)) * 0.02
    if n_cyc and n_loc:
        params["locals"] = _stack2(lambda k: _block_init(k, cfg, None), n_cyc, n_loc, kc)
        params["globals"] = _block_init(kg, cfg, n_cyc)
    elif n_cyc:
        params["globals"] = _block_init(kg, cfg, n_cyc)
    if n_tail:
        params["tail"] = _block_init(kt, cfg, n_tail)
    params["final_norm"], _ = L.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"], _ = L.init_dense(kh, cfg.d_model, cfg.vocab_padded, ("fsdp", "tp"))
    return params


def param_specs(cfg: ArchConfig) -> Pytree:
    n_cyc, n_loc, n_tail = layer_groups(cfg)
    specs: dict = {"embed": ("tp", "fsdp"), "final_norm": (None,)}
    blk = _block_specs(cfg, stack=True)
    if n_cyc and n_loc:
        specs["locals"] = jax.tree.map(lambda s: (None, *s), blk,
                                       is_leaf=lambda x: isinstance(x, tuple))
        specs["globals"] = blk
    elif n_cyc:
        specs["globals"] = blk
    if n_tail:
        specs["tail"] = blk
    if not cfg.tie_embeddings:
        specs["lm_head"] = ("fsdp", "tp")
    return specs


# ---------------------------------------------------------------------------
# Forward (train / prefill shared body)
# ---------------------------------------------------------------------------

def _attn_apply(p, x, cfg: ArchConfig, *, window, cos, sin, dtype):
    from repro.distributed.ctx import constrain
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = constrain(x @ p["wq"].astype(dtype), "dp", None, "tp").reshape(b, s, h, hd)
    k = constrain(x @ p["wk"].astype(dtype), "dp", None, "tp").reshape(b, s, hkv, hd)
    v = constrain(x @ p["wv"].astype(dtype), "dp", None, "tp").reshape(b, s, hkv, hd)
    q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
    k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    if window is not None and s > 2 * window:
        o = L.local_attention(q, k, v, window=window)
    else:
        o = L.gqa_attention(q, k, v, causal=True, window=window)
    o = constrain(o.reshape(b, s, h * hd), "dp", None, "tp")
    return constrain(o @ p["wo"].astype(dtype), "dp", None, None)


def _block_apply(p, x, cfg: ArchConfig, *, window, cos, sin):
    dtype = x.dtype
    aux = jnp.zeros((), jnp.float32)
    x = x + _attn_apply(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                        window=window, cos=cos, sin=sin, dtype=dtype)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe:
        y, aux = L.moe_mlp(p["mlp"], h, top_k=cfg.moe.top_k)
    else:
        y = L.mlp(jax.tree.map(lambda w: w.astype(dtype), p["mlp"]), h)
    return x + y, aux


def forward(params: Pytree, cfg: ArchConfig, tokens: jax.Array,
            *, dtype=jnp.bfloat16, extra_ctx: Optional[dict] = None) -> tuple[jax.Array, jax.Array]:
    """Full causal forward -> (logits, aux_loss).  tokens (B, S) int32."""
    from repro.distributed.ctx import constrain
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = constrain(x * (cfg.d_model ** 0.5), "dp", None, None)
    cos, sin = L.rope_table(jnp.arange(s), cfg.hd, cfg.rope_theta)
    n_cyc, n_loc, n_tail = layer_groups(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def local_body(x, p):
        y, aux = _block_apply(p, x, cfg, window=cfg.window, cos=cos, sin=sin)
        return y, aux

    def global_body(x, p):
        y, aux = _block_apply(p, x, cfg, window=None, cos=cos, sin=sin)
        return y, aux

    remat = (lambda f: jax.checkpoint(
        f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)) \
        if cfg.remat else (lambda f: f)

    if n_cyc and n_loc:
        def cycle(x, p_cyc):
            x, aux1 = L.maybe_scan(remat(local_body), x, p_cyc["locals"], scan=True)
            x, aux2 = remat(global_body)(x, p_cyc["globals"])
            return x, jnp.sum(aux1) + aux2
        x, auxs = L.maybe_scan(
            cycle, x, {"locals": params["locals"], "globals": params["globals"]},
            scan=cfg.scan_layers)
        aux_total += jnp.sum(auxs)
    elif n_cyc:
        x, auxs = L.maybe_scan(remat(global_body), x, params["globals"],
                               scan=cfg.scan_layers)
        aux_total += jnp.sum(auxs)
    if n_tail:
        x, auxs = L.maybe_scan(remat(local_body), x, params["tail"],
                               scan=cfg.scan_layers)
        aux_total += jnp.sum(auxs)

    from repro.distributed.ctx import constrain
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(x @ head.astype(dtype), "dp", None, "tp")
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits, aux_total


def train_loss(params: Pytree, cfg: ArchConfig, batch: dict,
               *, dtype=jnp.bfloat16) -> jax.Array:
    logits, aux = forward(params, cfg, batch["tokens"], dtype=dtype)
    return L.softmax_xent(logits, batch["labels"]) + 1e-2 * aux


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with ring-buffer local caches
# ---------------------------------------------------------------------------

def _cache_entry(cfg: ArchConfig, batch: int, length: int, stack_dims: tuple[int, ...],
                 dtype):
    shape = (*stack_dims, batch, length, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Pytree:
    n_cyc, n_loc, n_tail = layer_groups(cfg)
    w = min(cfg.window or max_len, max_len)
    cache: dict = {"len": jnp.zeros((batch,), jnp.int32)}
    if n_cyc and n_loc:
        cache["locals"] = _cache_entry(cfg, batch, w, (n_cyc, n_loc), dtype)
        cache["globals"] = _cache_entry(cfg, batch, max_len, (n_cyc,), dtype)
    elif n_cyc:
        cache["globals"] = _cache_entry(cfg, batch, max_len, (n_cyc,), dtype)
    if n_tail:
        cache["tail"] = _cache_entry(cfg, batch, w, (n_tail,), dtype)
    return cache


def cache_specs(cfg: ArchConfig) -> Pytree:
    """Logical specs: batch->dp, kv-heads->tp, sequence->sp (long-context)."""
    n_cyc, n_loc, n_tail = layer_groups(cfg)
    kv = lambda extra: {"k": (*extra, "dp", "sp", None, None),
                        "v": (*extra, "dp", "sp", None, None)}
    specs: dict = {"len": ("dp",)}
    if n_cyc and n_loc:
        specs["locals"] = kv((None, None))
        specs["globals"] = kv((None,))
    elif n_cyc:
        specs["globals"] = kv((None,))
    if n_tail:
        specs["tail"] = kv((None,))
    return specs


def _decode_block(p, x, cache_kv, cfg: ArchConfig, *, window, pos, cos, sin):
    """One-token decode through one block; returns (x, new_cache_kv)."""
    b = x.shape[0]
    dtype = x.dtype
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xa = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (xa @ p["attn"]["wq"].astype(dtype)).reshape(b, 1, h, hd)
    k = (xa @ p["attn"]["wk"].astype(dtype)).reshape(b, 1, hkv, hd)
    v = (xa @ p["attn"]["wv"].astype(dtype)).reshape(b, 1, hkv, hd)
    q = L.rms_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
    k = L.rms_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    length = cache_kv["k"].shape[1]
    if window is not None:
        slot = pos % length                      # ring buffer (local layers)
    else:
        slot = jnp.minimum(pos, length - 1)
    kc = cache_kv["k"].at[:, slot].set(k[:, 0].astype(cache_kv["k"].dtype))
    vc = cache_kv["v"].at[:, slot].set(v[:, 0].astype(cache_kv["v"].dtype))
    cache_len = jnp.minimum(pos + 1, length) * jnp.ones((b,), jnp.int32)
    # Ring-buffer slots are within-window by construction; keys carry their
    # absolute-position RoPE so scores stay relative-correct across wraps.
    o = L.decode_attention(q, kc, vc, cache_len)
    o = o.reshape(b, 1, h * hd) @ p["attn"]["wo"].astype(dtype)
    x = x + o
    hmid = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe:
        y, _ = L.moe_mlp(p["mlp"], hmid, top_k=cfg.moe.top_k)
    else:
        y = L.mlp(jax.tree.map(lambda w: w.astype(dtype), p["mlp"]), hmid)
    return x + y, {"k": kc, "v": vc}


def decode_step(params: Pytree, cfg: ArchConfig, cache: Pytree, token: jax.Array,
                pos: jax.Array, *, dtype=jnp.bfloat16):
    """One new token for the whole batch; pos is the (uniform) write position."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dtype)
    x = x * (cfg.d_model ** 0.5)
    cos, sin = L.rope_table(pos[None], cfg.hd, cfg.rope_theta)
    n_cyc, n_loc, n_tail = layer_groups(cfg)
    new_cache = dict(cache)

    if n_cyc and n_loc:
        def cycle(x, sl):
            p_cyc, c_cyc = sl
            def loc(x, sl2):
                p, c = sl2
                x, nc = _decode_block(p, x, c, cfg, window=cfg.window, pos=pos,
                                      cos=cos, sin=sin)
                return x, nc
            x, nc_loc = L.maybe_scan(loc, x, (p_cyc["locals"], c_cyc["locals"]),
                                     scan=True)
            x, nc_glob = _decode_block(p_cyc["globals"], x, c_cyc["globals"], cfg,
                                       window=None, pos=pos, cos=cos, sin=sin)
            return x, {"locals": nc_loc, "globals": nc_glob}
        x, ncs = L.maybe_scan(
            cycle, x,
            ({"locals": params["locals"], "globals": params["globals"]},
             {"locals": cache["locals"], "globals": cache["globals"]}),
            scan=cfg.scan_layers)
        new_cache["locals"], new_cache["globals"] = ncs["locals"], ncs["globals"]
    elif n_cyc:
        def glob(x, sl):
            p, c = sl
            x, nc = _decode_block(p, x, c, cfg, window=None, pos=pos, cos=cos, sin=sin)
            return x, nc
        x, nc = L.maybe_scan(glob, x, (params["globals"], cache["globals"]),
                             scan=cfg.scan_layers)
        new_cache["globals"] = nc
    if n_tail:
        def tail(x, sl):
            p, c = sl
            x, nc = _decode_block(p, x, c, cfg, window=cfg.window, pos=pos,
                                  cos=cos, sin=sin)
            return x, nc
        x, nc = L.maybe_scan(tail, x, (params["tail"], cache["tail"]),
                             scan=cfg.scan_layers)
        new_cache["tail"] = nc

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(dtype))[:, 0]
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache


def prefill(params: Pytree, cfg: ArchConfig, tokens: jax.Array,
            *, dtype=jnp.bfloat16):
    """Prefill: full forward returning last-token logits (cache population is
    recomputed lazily at decode in this repo's serving loop; the dry-run
    lowers prefill as the compute-bound member of the serve pair)."""
    logits, _ = forward(params, cfg, tokens, dtype=dtype)
    return logits[:, -1]
