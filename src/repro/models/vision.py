"""Llama-3.2-Vision-11B backbone: llama-arch decoder with gated
cross-attention image layers every ``cross_attn_every`` layers.

The vision encoder is a STUB per the task spec — ``input_specs`` provides
precomputed patch embeddings (B, num_image_tokens, d_model).  Pattern is
cycle-grouped like the LM: [cross+self block] + (cross_attn_every − 1)
self blocks per cycle.  FlashOmni applicability: S_s on self-attention;
on cross-attention the paper's C_{v→t}/G_{t→v} metrics apply VERBATIM
(text queries ↔ image keys), so the cross layers use the same mask
generator with image tokens as the "vision" stream.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["init_params", "param_specs", "forward", "train_loss",
           "init_cache", "cache_specs", "prefill", "decode_step"]


def _groups(cfg: ArchConfig) -> tuple[int, int]:
    p = cfg.cross_attn_every
    assert p > 1 and cfg.n_layers % p == 0
    return cfg.n_layers // p, p - 1      # (cycles, self layers per cycle)


def init_params(cfg: ArchConfig, key) -> Any:
    kc, ks, ke, kh, kx = jax.random.split(key, 5)
    n_cyc, n_self = _groups(cfg)
    self_blocks = T._stack2(lambda k: T._block_init(k, cfg, None), n_cyc, n_self, ks)
    cross = []
    for i in range(n_cyc):
        ki = jax.random.fold_in(kx, i)
        xattn, _ = L.init_attention(ki, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd, stack=None, qk_norm=True)
        cross.append({"xattn": xattn, "lnx": jnp.ones((cfg.d_model,)),
                      "gate": jnp.zeros(())})
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model)) * 0.02,
        "selfs": self_blocks,
        "cross": jax.tree.map(lambda *x: jnp.stack(x), *cross),
        "final_norm": jnp.ones((cfg.d_model,)),
        "lm_head": jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded)) * cfg.d_model ** -0.5,
    }
    return params


def param_specs(cfg: ArchConfig) -> Any:
    blk = T._block_specs(cfg, stack=True)
    xspec = L.attention_specs(True, qk_norm=True)
    return {
        "embed": ("tp", "fsdp"),
        "selfs": jax.tree.map(lambda s: (None, *s), blk,
                              is_leaf=lambda x: isinstance(x, tuple)),
        "cross": {"xattn": xspec, "lnx": (None, None), "gate": (None,)},
        "final_norm": (None,),
        "lm_head": ("fsdp", "tp"),
    }


def _cross_apply(p, x, img, cfg: ArchConfig):
    dtype = x.dtype
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xa = L.rms_norm(x, p["lnx"], cfg.norm_eps)
    q = (xa @ p["xattn"]["wq"].astype(dtype)).reshape(b, s, h, hd)
    k = (img @ p["xattn"]["wk"].astype(dtype)).reshape(b, img.shape[1], hkv, hd)
    v = (img @ p["xattn"]["wv"].astype(dtype)).reshape(b, img.shape[1], hkv, hd)
    q = L.rms_norm(q, p["xattn"]["q_norm"], cfg.norm_eps)
    k = L.rms_norm(k, p["xattn"]["k_norm"], cfg.norm_eps)
    o = L.gqa_attention(q, k, v, causal=False)
    o = o.reshape(b, s, h * hd) @ p["xattn"]["wo"].astype(dtype)
    return x + jnp.tanh(p["gate"]).astype(dtype) * o


def forward(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    tokens, img = batch["tokens"], batch["patches"]
    b, s = tokens.shape
    img = img.astype(dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    cos, sin = L.rope_table(jnp.arange(s), cfg.hd, cfg.rope_theta)
    remat = (lambda f: jax.checkpoint(
        f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)) \
        if cfg.remat else (lambda f: f)

    def cycle(x, sl):
        x = remat(lambda x2, p: _cross_apply(p, x2, img, cfg))(x, sl["cross"])
        def body(x2, p):
            y, _ = T._block_apply(p, x2, cfg, window=None, cos=cos, sin=sin)
            return y, None
        x, _ = L.maybe_scan(remat(body), x, sl["selfs"], scan=True)
        return x, None

    x, _ = L.maybe_scan(cycle, x, {"cross": params["cross"], "selfs": params["selfs"]},
                        scan=cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(dtype)
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits, jnp.zeros((), jnp.float32)


def train_loss(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    logits, _ = forward(params, cfg, batch, dtype=dtype)
    return L.softmax_xent(logits, batch["labels"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_cyc, n_self = _groups(cfg)
    kv = lambda *stack: {
        "k": jnp.zeros((*stack, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((*stack, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)}
    xkv = {"k": jnp.zeros((n_cyc, batch, cfg.num_image_tokens, cfg.n_kv_heads, cfg.hd), dtype),
           "v": jnp.zeros((n_cyc, batch, cfg.num_image_tokens, cfg.n_kv_heads, cfg.hd), dtype)}
    return {"selfs": kv(n_cyc, n_self), "cross": xkv,
            "len": jnp.zeros((batch,), jnp.int32)}


def cache_specs(cfg: ArchConfig):
    kv2 = {"k": (None, None, "dp", "sp", None, None),
           "v": (None, None, "dp", "sp", None, None)}
    kv1 = {"k": (None, "dp", None, None, None), "v": (None, "dp", None, None, None)}
    return {"selfs": kv2, "cross": kv1, "len": ("dp",)}


def decode_step(params, cfg: ArchConfig, cache, token, pos, *, dtype=jnp.bfloat16):
    b = token.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dtype)
    cos, sin = L.rope_table(pos[None], cfg.hd, cfg.rope_theta)

    def cycle(x, sl):
        p_c, c_self, c_cross = sl
        # gated cross-attention against precomputed image K/V
        p = p_c["cross"]
        xa = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        q = (xa @ p["xattn"]["wq"].astype(dtype)).reshape(b, 1, h, hd)
        q = L.rms_norm(q, p["xattn"]["q_norm"], cfg.norm_eps)
        il = c_cross["k"].shape[1] * jnp.ones((b,), jnp.int32)
        o = L.decode_attention(q, c_cross["k"], c_cross["v"], il)
        x = x + jnp.tanh(p["gate"]).astype(dtype) * (
            o.reshape(b, 1, h * hd) @ p["xattn"]["wo"].astype(dtype))
        def body(x2, sl2):
            pp, cc = sl2
            y, nc = T._decode_block(pp, x2, cc, cfg, window=None, pos=pos,
                                    cos=cos, sin=sin)
            return y, nc
        x, nc_self = L.maybe_scan(body, x, (p_c["selfs"], c_self), scan=True)
        return x, nc_self

    x, nc = L.maybe_scan(cycle, x, ({"cross": params["cross"], "selfs": params["selfs"]},
                                    cache["selfs"], cache["cross"]),
                         scan=cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"].astype(dtype))[:, 0]
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]
    return logits, dict(cache, selfs=nc, len=cache["len"] + 1)


def prefill(params, cfg: ArchConfig, batch, *, dtype=jnp.bfloat16):
    logits, _ = forward(params, cfg, batch, dtype=dtype)
    return logits[:, -1]
