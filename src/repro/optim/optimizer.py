"""AdamW with global-norm clipping and cosine schedule (pure JAX).

Optimizer state shards exactly like the params (fsdp/tp logical specs) —
ZeRO-1/3 falls out of the sharding rules, not special-case code.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_state_specs", "adamw_update",
           "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    # §Perf lever: bf16 Adam moments cut optimizer-state HBM (and its read/
    # write traffic) in half — 12 B/param -> 8 B/param.  Updates still
    # accumulate in f32 (moments are re-quantized after the f32 math).
    moment_dtype: str = "float32"          # "float32" | "bfloat16"


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def adamw_init(params: Any, cfg: AdamWConfig = AdamWConfig()) -> Any:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_state_specs(param_specs: Any) -> Any:
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    ident = jax.tree.map(lambda s: s, param_specs, is_leaf=is_spec)
    return {"mu": ident, "nu": ident, "step": ()}


def adamw_update(grads: Any, state: Any, params: Any, cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_f = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu_f = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        update = (mu_f / b1c) / (jnp.sqrt(nu_f / b2c) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu_f.astype(mu.dtype), nu_f.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm
