"""Elastic scaling: rebuild the mesh from the surviving device set and
reshard the training state.

Policy (DESIGN §3): the ``data`` axis absorbs capacity changes (it carries
batch DP + ZeRO shards); the ``model`` axis is fixed by the TP layout of the
weights.  On shrink from D to D' data-rows, per-device batch grows by
D/D' and the optimizer shards re-gather — both handled here by re-device_put
onto the new mesh.  Grow-back follows the same path.

On CPU we validate the logic by shrinking a host-device mesh; on real
hardware the surviving-device list comes from the coordinator's heartbeat
service.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import ShardingRules, named_sharding_tree

__all__ = ["shrink_mesh", "reshard_state"]


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def shrink_mesh(mesh: Mesh, surviving: Sequence[int] | None = None,
                *, drop_data_rows: int = 1) -> Mesh:
    """Build a new mesh without the failed data-rows.

    Elastic policy: the surviving data-row count is rounded DOWN to a power
    of two so every sharded dim (batch, fsdp shards — all powers of two in
    this repo) still divides evenly.  ``surviving``: flat device ids that
    are still healthy; defaults to dropping the LAST ``drop_data_rows``
    rows of the data axis.
    """
    devs = mesh.devices             # ndarray [data, model] or [pod, data, model]
    n_model = devs.shape[-1]
    if surviving is not None:
        flat = [d for d in devs.reshape(-1) if d.id in set(surviving)]
        n_rows = _pow2_floor(len(flat) // n_model)
        flat = flat[: n_rows * n_model]
        arr = np.array(flat).reshape(n_rows, n_model)
        return Mesh(arr, mesh.axis_names[-2:])
    if devs.ndim == 2:
        n_rows = _pow2_floor(devs.shape[0] - drop_data_rows)
        return Mesh(devs[:n_rows], mesh.axis_names)
    n_rows = _pow2_floor(devs.shape[1] - drop_data_rows)
    return Mesh(devs[:, :n_rows], mesh.axis_names)


def reshard_state(state: Any, spec_tree: Any, new_mesh: Mesh,
                  rules: ShardingRules) -> Any:
    """Move a pytree onto the (shrunk/grown) mesh with the same logical specs."""
    shardings = named_sharding_tree(spec_tree, new_mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
