"""Fault tolerance for 1000+-node training runs.

Components (all exercised by tests with injected failures):

  * ``StepWatchdog``     — straggler detection: flags steps slower than
    ``factor × p50`` over a rolling window; the runner logs/reshards.
  * ``RestartableLoop``  — the training loop as a restartable state machine
    ``(step, params, opt, data_state)``; on any exception it restores the
    last published checkpoint and resumes (bounded retry budget).
  * ``FailureInjector``  — deterministic chaos-monkey for tests: raises at
    configured steps to simulate preemptions / node loss.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any, Callable, Optional

from repro.checkpoint.checkpointer import Checkpointer

log = logging.getLogger("repro.fault_tolerance")

__all__ = ["StepWatchdog", "FailureInjector", "RestartableLoop", "NodeFailure"]


class NodeFailure(RuntimeError):
    """Simulated node loss / preemption."""


class StepWatchdog:
    def __init__(self, window: int = 32, straggler_factor: float = 3.0):
        self.times: deque[float] = deque(maxlen=window)
        self.factor = straggler_factor
        self.stragglers: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if it is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            p50 = sorted(self.times)[len(self.times) // 2]
            if dt > self.factor * p50:
                is_straggler = True
                self.stragglers.append((step, dt))
                log.warning("straggler step %d: %.3fs (p50 %.3fs)", step, dt, p50)
        self.times.append(dt)
        return is_straggler


class FailureInjector:
    def __init__(self, fail_at_steps: tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise NodeFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class LoopResult:
    final_step: int
    restarts: int
    metrics: list[dict]
    stragglers: list[tuple[int, float]]


class RestartableLoop:
    """Checkpoint/restart training loop.

    ``step_fn(state, step) -> (state, metrics)`` must be a pure update of
    ``state = (params, opt_state)``; the data pipeline is derived from the
    step index (see ``repro.data.synthetic``), so restarts are bit-exact.
    """

    def __init__(self, checkpointer: Checkpointer, *, ckpt_every: int = 10,
                 max_restarts: int = 5):
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts

    def run(self, state: Any, step_fn: Callable, total_steps: int,
            *, injector: Optional[FailureInjector] = None,
            watchdog: Optional[StepWatchdog] = None) -> tuple[Any, LoopResult]:
        watchdog = watchdog or StepWatchdog()
        restarts = 0
        metrics: list[dict] = []
        step = 0
        # resume from the latest checkpoint if one exists
        s0, restored = self.ckpt.restore_latest(state)
        if restored is not None:
            state, step = restored, s0
            log.info("resumed from checkpoint step %d", step)

        while step < total_steps:
            try:
                t0 = time.time()
                if injector is not None:
                    injector.maybe_fail(step)
                state, m = step_fn(state, step)
                watchdog.observe(step, time.time() - t0)
                metrics.append({"step": step, **m})
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except NodeFailure as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                log.warning("restart %d after %r", restarts, e)
                self.ckpt.wait()
                s0, restored = self.ckpt.restore_latest(state)
                if restored is not None:
                    state, step = restored, s0
                else:
                    step = 0  # no checkpoint yet: restart from scratch
        self.ckpt.wait()
        return state, LoopResult(final_step=step, restarts=restarts,
                                 metrics=metrics, stragglers=watchdog.stragglers)
