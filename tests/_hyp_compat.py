"""Hypothesis import shim: property tests degrade to fixed parameterized cases.

``hypothesis`` is an optional test dependency (declared in pyproject.toml /
requirements.txt).  When it is installed, this module re-exports the real
``given``/``settings``/``st`` unchanged.  When it is NOT installed, the
shims below run each ``@given`` test over a small deterministic sample of
the requested strategies instead of failing collection — the suite stays
green either way, just with fixed cases instead of property search.

Only the strategy subset used by this repo's tests is implemented:
``integers``, ``floats``, ``booleans``, ``lists``, ``data``.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _N_CASES = 8  # deterministic draws per @given test

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _DataSentinel:
        """Marks an ``st.data()`` argument (drawn lazily inside the test)."""

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.example(self._rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def data():
            return _DataSentinel()

    st = _St()

    def given(*strategies):
        def decorate(fn):
            # Deliberately NOT functools.wraps: the wrapper must expose a
            # zero-arg signature so pytest does not mistake the strategy
            # parameters for fixtures.
            def wrapper():
                for case in range(_N_CASES):
                    rng = _np.random.default_rng(1000 + case)
                    args = [
                        _Data(rng) if isinstance(s, _DataSentinel)
                        else s.example(rng)
                        for s in strategies
                    ]
                    fn(*args)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return decorate

    class settings:  # noqa: N801 — mirrors the hypothesis API
        def __init__(self, *args, **kwargs):
            pass

        @staticmethod
        def register_profile(name, **kwargs):
            pass

        @staticmethod
        def load_profile(name):
            pass
