"""Engine invariant analyzer tests (ISSUE 9).

Two sides of the acceptance criterion:

* adversarial fixtures every pass MUST flag — an injected ``lax.sort``
  in a dispatch-shaped fn, a hand-mutated plan violating fold-back
  (counts past widths, out-of-range ids), a plan leaf ``widen()`` does
  not cover, an ``id()``-keyed module cache, jit under a traced body;
* green runs on the REAL engine: Dispatch purity for every registered
  strategy × backend, the structural plan validator over real plans
  (uniform + bucketed + mesh-partitioned), the serving-tick promotion
  and executable-budget passes, and the source lint over ``src/``.

Mesh-device-bound combos (CollectiveBudget, mesh DispatchPurity) run in
the forced-8-device CI step via ``python -m repro.analysis``; here they
skip gracefully on one device.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platforms", "cpu")

from repro.analysis import AnalysisContext
from repro.analysis.jaxpr_walk import (eqn_count, index_decode_eqns,
                                       primitive_counts)
from repro.analysis.passes import (_B, _DH, _DM, _H, _N, ExecutableBudget,
                                   PromotionCheck, _engine_cfg, _params,
                                   _trace_pair)
from repro.analysis.plan_check import (PlanInvariantError, check_plan,
                                       validate_plan)
from repro.analysis.source_lint import lint_source, lint_sources
from repro.core.engine import init_layer_state, update_layer
from repro.core.strategy import available_strategies


def _ctx():
    return AnalysisContext(src_root="src")


@pytest.fixture(scope="module")
def real_plan():
    """One concrete bucketed plan off the real Update path."""
    cfg = _engine_cfg(kv_buckets=3)
    x = jax.random.normal(jax.random.PRNGKey(0), (_B, _N, _DM)) * 0.3
    st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
    _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                         step_idx=2, num_steps=8)
    return cfg, st.plan


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------

def test_walker_recurses_into_nested_sub_jaxprs():
    """A sort hidden under jit-inside-scan is invisible to jaxpr-TEXT
    grep at the top level but must be found by the walker."""
    @jax.jit
    def hidden(x):
        def body(c, row):
            return c, jax.lax.sort(row)
        _, ys = jax.lax.scan(body, 0, x)
        return ys

    jx = jax.make_jaxpr(hidden)(jnp.ones((4, 8)))
    hits = index_decode_eqns(jx)
    assert len(hits) == 1
    path, eqn = hits[0]
    assert eqn.primitive.name == "sort"
    assert "scan" in path            # found inside the scan body
    counts = primitive_counts(jx)
    assert counts["sort"] == 1 and counts["scan"] == 1


def test_walker_flags_uint8_unpack_signature():
    """unpack_bits has no named primitive — the walker recognizes its
    uint8 bit-shift signature instead."""
    from repro.core.symbols import unpack_bits
    jx = jax.make_jaxpr(lambda s: unpack_bits(s, 16))(
        jnp.zeros((2, 2), jnp.uint8))
    assert index_decode_eqns(jx), "uint8 unpack signature not detected"


def test_eqn_count_modes():
    def f(x):
        def body(c, v):
            return c + v, v * 2
        return jax.lax.scan(body, 0.0, x)

    jx = jax.make_jaxpr(f)(jnp.ones(8))
    assert eqn_count(jx) == 1                      # the scan itself
    assert eqn_count(jx, recursive=True) > 1       # plus its body


# ---------------------------------------------------------------------------
# adversarial fixtures (each MUST be flagged)
# ---------------------------------------------------------------------------

def test_injected_sort_in_dispatch_fn_is_flagged():
    def dispatch_like(x, ids):
        return jnp.take(x, jax.lax.sort(ids), axis=0)

    jx = jax.make_jaxpr(dispatch_like)(jnp.ones((8, 4)),
                                       jnp.arange(8, dtype=jnp.int32))
    assert {e.primitive.name for _, e in index_decode_eqns(jx)} == {"sort"}


def test_foldback_violating_plan_is_flagged(real_plan):
    cfg, plan = real_plan
    mutated = plan._replace(
        bkt_kv_cnt=plan.bkt_kv_cnt + 7,                # counts > widths
        kv_row_ids=jnp.full_like(plan.kv_row_ids, 2 ** 14))  # ids OOR
    bad = check_plan(mutated, cfg, _N)
    assert any("outside [0" in m for m in bad)
    assert any("fold-back" in m for m in bad)
    with pytest.raises(PlanInvariantError):
        validate_plan(mutated, cfg, _N)


def test_widen_uncovered_field_is_flagged(real_plan):
    cfg, plan = real_plan
    bad = check_plan(plan._replace(q_cnt=plan.q_cnt.astype(jnp.int16)),
                     cfg, _N)
    assert any("stayed int16" in m for m in bad)


def test_occ_hist_mismatch_is_flagged(real_plan):
    cfg, plan = real_plan
    bad = check_plan(
        plan._replace(occ_hist=plan.occ_hist.at[..., 0].add(1)), cfg, _N)
    assert any("occ_hist" in m for m in bad)


def test_id_keyed_cache_is_flagged():
    src = ("_PLAN_CACHE = {}\n"
           "def lookup(spec):\n"
           "    key = id(spec)\n"
           "    if key not in _PLAN_CACHE:\n"
           "        _PLAN_CACHE[key] = spec\n"
           "    return _PLAN_CACHE[key]\n")
    rules = {r for _, _, r, _ in lint_source(src)}
    assert "id-keyed-cache" in rules
    assert "module-dict-cache" in rules   # unbounded dict cache too


def test_transient_local_id_dict_is_not_flagged():
    """schedule.strategy_table's pattern: id() keys into a TRANSIENT
    local dict over pinned objects is legal — no cache involved."""
    src = ("def table(specs):\n"
           "    by_spec = {}\n"
           "    for s in specs:\n"
           "        by_spec[id(s)] = resolve(s)\n"
           "    return by_spec\n")
    assert lint_source(src) == []


def test_jit_in_traced_body_is_flagged():
    src = ("import jax\n"
           "def outer(xs):\n"
           "    def body(c, x):\n"
           "        f = jax.jit(lambda v: v * 2)\n"
           "        return c, f(x)\n"
           "    return jax.lax.scan(body, 0, xs)\n")
    assert {r for _, _, r, _ in lint_source(src)} == {"jit-in-traced-body"}


# ---------------------------------------------------------------------------
# green runs on the real engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("strategy", available_strategies())
def test_dispatch_purity_per_strategy_backend(strategy, backend):
    """Every registered strategy × backend: Dispatch jaxpr decode-free,
    Update jaxpr the positive control (kv_buckets=3 exercises the
    bucketed layouts on both backends)."""
    cfg = _engine_cfg(strategy=strategy, backend=backend, kv_buckets=3,
                      **(dict(interpret=True) if backend == "pallas"
                         else {}))
    upd, disp = _trace_pair(cfg)
    hits = index_decode_eqns(disp)
    assert not hits, (
        f"{strategy}/{backend}: dispatch rebuilds indices: "
        + ", ".join(e.primitive.name for _, e in hits))
    assert index_decode_eqns(upd), \
        f"{strategy}/{backend}: vacuous walker — no decode in Update"


@pytest.mark.parametrize("strategy", available_strategies())
def test_plan_validator_green_per_strategy(strategy):
    """Real plans (bucketed, plus the mesh partition for the default
    strategy) satisfy every structural invariant."""
    cfg = _engine_cfg(strategy=strategy, kv_buckets=3)
    x = jax.random.normal(jax.random.PRNGKey(1), (_B, _N, _DM)) * 0.3
    st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
    _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                         step_idx=2, num_steps=8)
    assert check_plan(st.plan, cfg, _N) == []


def test_plan_validator_green_on_mesh_partition():
    """The shd_* partition checks run on ONE device (partition_plan is
    pure jnp at Update time)."""
    cfg = _engine_cfg(kv_buckets=1, mesh_dp=1, mesh_sp=2)
    x = jax.random.normal(jax.random.PRNGKey(2), (_B, _N, _DM)) * 0.3
    st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
    _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                         step_idx=2, num_steps=8)
    assert st.plan.shd_q_ids is not None
    assert check_plan(st.plan, cfg, _N) == []


def test_plan_validator_tolerates_stacked_axes(real_plan):
    """Layer/lane stacking adds leading axes; the checker folds them."""
    cfg, plan = real_plan
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (2, *a.shape)), plan)
    assert check_plan(stacked, cfg, _N) == []


def test_promotion_and_budget_passes_green():
    ctx = _ctx()
    assert PromotionCheck().run(ctx) == []
    assert ExecutableBudget().run(ctx) == []


def test_source_lint_green_on_repo():
    assert lint_sources("src") == []


def test_sweep_configs_covers_full_matrix():
    """The analyzer's sweep enumerates every registered strategy ×
    backend × kv_buckets ∈ {1,3} × {single, mesh} combo (mesh combos
    carry a skip note on hosts without 2 devices rather than vanishing
    silently)."""
    from repro.analysis.passes import sweep_configs
    combos = list(sweep_configs())
    strategies = set(available_strategies())
    assert len(combos) == len(strategies) * 2 * 2 * 2
    live = [(label, cfg) for label, cfg, skip in combos if skip is None]
    assert {c.strategy for _, c in live} == strategies
    assert {c.backend for _, c in live} == {"xla", "pallas"}
    assert {c.kv_buckets for _, c in live} == {1, 3}
    # skipped combos (mesh on a small host) must say so, never vanish
    for label, cfg, skip in combos:
        if skip is not None:
            assert cfg is None and "mesh" in label and "devices" in skip
    # the single-device half of the grid always runs
    assert len(live) >= len(strategies) * 2 * 2


# ---------------------------------------------------------------------------
# satellite 2: PR 7/8 field coverage regression (widen + specs + rebuild)
# ---------------------------------------------------------------------------

def test_pr78_fields_covered_by_widen_and_specs():
    """Every gmo_*/shd_*/occ_hist field from PRs 7–8 is wired through
    widen() (id fields), engine_state_specs, and the build path — the
    static lint finds zero coverage gaps, and the live widen() of a real
    plan leaves no int16 leaf."""
    import ast
    from pathlib import Path

    from repro.analysis.source_lint import is_id_field, plan_fields
    tree = ast.parse(Path("src/repro/core/plan.py").read_text())
    fields = plan_fields(tree)
    pr78 = [f for f in fields
            if f.startswith(("gmo_", "shd_")) or f == "occ_hist"]
    assert len(pr78) >= 16          # 4 gmo + 11 shd + occ_hist
    hits = [h for h in lint_sources("src") if h[2].startswith("plan-")]
    assert hits == []
    # and the id-field convention actually captures the PR 7/8 id lists
    assert {f for f in pr78 if is_id_field(f)} >= {
        "gmo_rows", "gmo_src", "gmo_head_ids", "shd_q_ids", "shd_q_src",
        "shd_q_slots", "shd_kv_ids", "shd_kv_row_ids", "shd_gather_idx",
        "shd_send_ids"}


def test_widen_roundtrip_complete_on_real_plans(real_plan):
    cfg, plan = real_plan
    wide = plan.widen()
    for name, leaf in zip(wide._fields, wide):
        if leaf is not None and hasattr(leaf, "dtype"):
            assert leaf.dtype != jnp.int16, f"{name} stayed int16"
    # idempotent
    again = wide.widen()
    for a, b in zip(jax.tree.leaves(wide), jax.tree.leaves(again)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# live validation hook
# ---------------------------------------------------------------------------

def test_validate_plans_hook_fires_and_passes(monkeypatch):
    """EngineConfig.validate_plans=True routes every plan build through
    the host-side checker (and real plans pass it)."""
    from repro.analysis import plan_check
    calls = []
    real = plan_check.hook_validate
    monkeypatch.setattr(plan_check, "hook_validate",
                        lambda p, cfg, n: calls.append(1) or real(p, cfg, n))
    cfg = dataclasses.replace(_engine_cfg(kv_buckets=3),
                              validate_plans=True)
    x = jax.random.normal(jax.random.PRNGKey(5), (_B, _N, _DM)) * 0.3
    st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
    _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                         step_idx=2, num_steps=8)
    jax.block_until_ready(st.plan.q_cnt)
    assert calls, "validate_plans=True did not reach the host checker"


def test_validate_plans_env_gate(monkeypatch):
    from repro.analysis.plan_check import validation_enabled
    cfg = _engine_cfg()
    monkeypatch.delenv("REPRO_VALIDATE_PLANS", raising=False)
    assert not validation_enabled(cfg)
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "1")
    assert validation_enabled(cfg)
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "0")
    assert not validation_enabled(cfg)
    assert validation_enabled(dataclasses.replace(cfg,
                                                  validate_plans=True))


def test_collective_budget_green_or_noted_skip():
    """Zero findings either way: on a single-device host the pass
    records a skip note instead of silently vanishing; with >= 2
    devices (CI's forced-8-device step) it verifies the a2a budget."""
    from repro.analysis.passes import CollectiveBudget, mesh_capacity
    ctx = _ctx()
    assert CollectiveBudget().run(ctx) == []
    if mesh_capacity() < 2:
        assert ctx.notes, "1-device skip must leave a note"


# ---------------------------------------------------------------------------
# ISSUE 10: static cost model
# ---------------------------------------------------------------------------

from repro.analysis.cost_model import (CostEstimate, aval_bytes,  # noqa: E402
                                       cost_of_jaxpr, peak_bytes_of)


def test_cost_model_dot_general_exact():
    m, k, n = 48, 96, 32
    jx = jax.make_jaxpr(lambda a, b: a @ b)(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32))
    c = cost_of_jaxpr(jx)
    assert c.flops == 2.0 * m * n * k
    assert c.hbm_bytes == 4.0 * (m * k + k * n + m * n)
    assert not c.inexact and not c.coll_payload


def test_cost_model_matches_xla_on_dense_gemm_and_attention():
    """The headline cross-check: static count vs XLA cost_analysis."""
    from repro.core.attention import dense_attention

    def xla_flops(fn, *args):
        c = jax.jit(fn).lower(*args).compile().cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0] if c else {}
        return float(c.get("flops", 0.0))

    gemm = lambda a, b: jnp.einsum("bnd,df->bnf", a, b)
    a = jnp.ones((1, 128, 64))
    b = jnp.ones((64, 32))
    assert cost_of_jaxpr(jax.make_jaxpr(gemm)(a, b)).flops == \
        xla_flops(gemm, a, b)

    q = jax.random.normal(jax.random.PRNGKey(0), (2, 128, 16))
    att = lambda q: dense_attention(q, q, q)
    static = cost_of_jaxpr(jax.make_jaxpr(att)(q)).flops
    measured = xla_flops(att, q)
    assert abs(static - measured) / measured < 0.05


def test_cost_model_scan_multiplies_by_trip_count():
    def body_cost(xs):
        def step(c, x):
            return c + (x @ x), None
        out, _ = jax.lax.scan(step, jnp.zeros((16, 16)), xs)
        return out

    c8 = cost_of_jaxpr(jax.make_jaxpr(body_cost)(jnp.ones((8, 16, 16))))
    c16 = cost_of_jaxpr(jax.make_jaxpr(body_cost)(jnp.ones((16, 16, 16))))
    # matmul flops dominate and scale exactly with the trip count
    assert c16.flops == pytest.approx(2 * c8.flops, rel=1e-6)


def test_cost_model_gather_bills_touched_bytes_not_operand():
    """A plan-capacity gather over a big KV buffer must cost what it
    moves — the whole point of the T_kv-independence certificate."""
    big = jax.ShapeDtypeStruct((4096, 64), jnp.float32)   # 1 MB operand
    ids = jnp.arange(4, dtype=jnp.int32)
    c = cost_of_jaxpr(jax.make_jaxpr(
        lambda x, i: jnp.take(x, i, axis=0))(big, ids))
    assert c.hbm_bytes < 0.01 * aval_bytes(big)


def test_cost_model_while_marks_inexact():
    def f(x):
        return jax.lax.while_loop(lambda v: v[0] < 10.0,
                                  lambda v: v * 1.5, x)

    assert cost_of_jaxpr(jax.make_jaxpr(f)(jnp.ones(4))).inexact


def test_peak_bytes_sees_liveness_not_total_allocation():
    """A chain of sequential temporaries peaks at a few buffers, far
    below the sum of every intermediate."""
    def chain(x):
        for _ in range(16):
            x = x + 1.0
        return x

    jx = jax.make_jaxpr(chain)(jnp.ones((256, 256)))
    buf = 256 * 256 * 4
    peak = peak_bytes_of(jx)
    assert buf <= peak <= 4 * buf        # not 17 * buf


def test_peak_bytes_counts_concurrently_live_buffers():
    def wide(x):
        a, b, c = x + 1.0, x * 2.0, x - 3.0
        return a + b + c                 # all three live together

    jx = jax.make_jaxpr(wide)(jnp.ones((128, 128)))
    assert peak_bytes_of(jx) >= 3 * 128 * 128 * 4


# ---------------------------------------------------------------------------
# ISSUE 10: cost passes — adversarial fixtures (each MUST be flagged)
# ---------------------------------------------------------------------------

from repro.analysis.cost_passes import (COST_PASSES,  # noqa: E402
                                        CollectiveBytesBudget,
                                        DispatchCostScaling, MemoryFootprint,
                                        PEAK_BUDGETS, UpdateAmortization,
                                        _dense_reference_cost, _matched,
                                        _token_reference_slope, _update_cost,
                                        KAPPA_TOKEN, KAPPA_TOKEN_BYTES,
                                        amortization_findings,
                                        collective_findings,
                                        footprint_findings,
                                        token_scaling_findings)


def test_dense_tkv_einsum_in_dispatch_is_flagged():
    """A dispatch body with an O(T_kv^2) score matrix fails the
    matched-capacity linearity certificate."""
    def dispatch_like(x, k):
        live = jnp.take(x, jnp.arange(32), axis=0)      # plan-capacity work
        return live.sum() + jnp.einsum("nd,md->nm", x, k).sum()

    ns = (128, 256, 384)
    costs = [cost_of_jaxpr(jax.make_jaxpr(dispatch_like)(
        jax.ShapeDtypeStruct((n, 16), jnp.float32),
        jax.ShapeDtypeStruct((n, 16), jnp.float32))) for n in ns]
    ref_f, ref_b = _token_reference_slope()
    findings = token_scaling_findings(
        "cost-dispatch-scaling", "fixture", costs, ns,
        budget_flops=KAPPA_TOKEN * ref_f,
        budget_bytes=KAPPA_TOKEN_BYTES * ref_b)
    assert any(f.rule == "tkv-superlinear" for f in findings)


def test_affine_dispatch_cost_passes_scaling_certificate():
    """The positive control for the fixture above: plan-capacity-only
    work (affine in n under the per-token budget) produces no findings."""
    def clean(x):
        live = jnp.take(x, jnp.arange(32), axis=0)
        return live.sum() + x.sum()

    ns = (128, 256, 384)
    costs = [cost_of_jaxpr(jax.make_jaxpr(clean)(
        jax.ShapeDtypeStruct((n, 16), jnp.float32))) for n in ns]
    ref_f, ref_b = _token_reference_slope()
    assert token_scaling_findings(
        "cost-dispatch-scaling", "clean", costs, ns,
        budget_flops=KAPPA_TOKEN * ref_f,
        budget_bytes=KAPPA_TOKEN_BYTES * ref_b) == []


def test_full_kv_allgather_is_flagged():
    """A mesh dispatch shipping the whole KV (all_gather, no pair_cap
    a2a) violates every line of the collective certificate — built from
    a synthetic estimate so the test runs on one device."""
    smuggled = CostEstimate(coll_payload={"all_gather": 65536.0},
                            coll_count={"all_gather": 2})
    findings = collective_findings("cost-collective-bytes", "fixture",
                                   smuggled, expected_payload=24576.0,
                                   dense_payload=65536.0)
    rules = {f.rule for f in findings}
    assert {"a2a-count", "pair-cap-formula",
            "no-extra-collectives"} <= rules


def test_rebuild_every_dispatch_is_flagged():
    """dispatch cost := update cost models an engine that rebuilds the
    plan every step — the amortization line must fail."""
    cfg = _matched(_engine_cfg(backend="xla", kv_buckets=1), 2, 2, _N)
    u = _update_cost(cfg, _N)
    findings = amortization_findings(
        "cost-update-amortization", "fixture", u, u,
        _dense_reference_cost(_N), cfg.mask.interval)
    assert any(f.rule == "interval-amortization" for f in findings)


def test_memory_hog_is_flagged():
    def hog(x):
        big = jnp.zeros((512, 512), jnp.float32)
        return (x[:, None] * big).sum() + x.sum()

    jx = jax.make_jaxpr(hog)(jax.ShapeDtypeStruct((512,), jnp.float32))
    assert footprint_findings("cost-memory-footprint", "fixture",
                              peak_bytes_of(jx),
                              PEAK_BUDGETS["dispatch_layer"])


# ---------------------------------------------------------------------------
# ISSUE 10: cost passes — green sweep over the real engine
# ---------------------------------------------------------------------------

def test_cost_passes_green_on_real_engine():
    """All four certificates hold on the repo (mesh combos carry a skip
    note on one-device hosts; CI's forced-8-device `make analyze` covers
    them)."""
    ctx = _ctx()
    for cls in COST_PASSES:
        assert cls().run(ctx) == [], f"{cls.name} found regressions"


def test_dispatch_groups_cover_backend_bucket_mesh_grid():
    from repro.analysis.cost_passes import dispatch_groups
    combos = list(dispatch_groups())
    assert len(combos) == 2 * 2 * 2          # backend × kvb × mesh
    live = [(label, cfg) for label, cfg, skip in combos if skip is None]
    assert {c.backend for _, c in live} == {"xla", "pallas"}
    assert {c.kv_buckets for _, c in live} == {1, 3}
    for label, cfg, skip in combos:
        if skip is not None:
            assert cfg is None and "mesh" in label


def test_cli_pass_filter_accepts_globs():
    """`--passes cost-*` selects exactly the four cost passes; a pattern
    matching nothing is an explicit error, not a silent no-op run."""
    from repro.analysis import ALL_PASSES
    import fnmatch
    names = [p.name for p in ALL_PASSES()]
    cost = [n for n in names if fnmatch.fnmatch(n, "cost-*")]
    assert sorted(cost) == ["cost-collective-bytes",
                            "cost-dispatch-scaling",
                            "cost-memory-footprint",
                            "cost-update-amortization"]
    from repro.analysis.__main__ import main
    with pytest.raises(SystemExit, match="match no pass"):
        main(["--passes", "no-such-*", "-q"])


def test_trace_pair_memoizes_per_cfg_and_n():
    from repro.analysis.passes import _TRACE_CACHE, trace_pair
    cfg = _engine_cfg(kv_buckets=1)
    n = 160                               # off-grid: guaranteed cold key
    before = _TRACE_CACHE.misses
    upd1, disp1 = trace_pair(cfg, n=n)
    upd2, disp2 = trace_pair(cfg, n=n)
    assert upd1 is upd2 and disp1 is disp2
    assert _TRACE_CACHE.hits > 0
    # dispatch_only never poisons the full-pair entry
    upd3, _ = trace_pair(cfg, n=n, dispatch_only=False)
    assert upd3 is upd1
    assert _TRACE_CACHE.misses > before   # first call did trace
