"""Engine invariant analyzer tests (ISSUE 9).

Two sides of the acceptance criterion:

* adversarial fixtures every pass MUST flag — an injected ``lax.sort``
  in a dispatch-shaped fn, a hand-mutated plan violating fold-back
  (counts past widths, out-of-range ids), a plan leaf ``widen()`` does
  not cover, an ``id()``-keyed module cache, jit under a traced body;
* green runs on the REAL engine: Dispatch purity for every registered
  strategy × backend, the structural plan validator over real plans
  (uniform + bucketed + mesh-partitioned), the serving-tick promotion
  and executable-budget passes, and the source lint over ``src/``.

Mesh-device-bound combos (CollectiveBudget, mesh DispatchPurity) run in
the forced-8-device CI step via ``python -m repro.analysis``; here they
skip gracefully on one device.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platforms", "cpu")

from repro.analysis import AnalysisContext
from repro.analysis.jaxpr_walk import (eqn_count, index_decode_eqns,
                                       primitive_counts)
from repro.analysis.passes import (_B, _DH, _DM, _H, _N, ExecutableBudget,
                                   PromotionCheck, _engine_cfg, _params,
                                   _trace_pair)
from repro.analysis.plan_check import (PlanInvariantError, check_plan,
                                       validate_plan)
from repro.analysis.source_lint import lint_source, lint_sources
from repro.core.engine import init_layer_state, update_layer
from repro.core.strategy import available_strategies


def _ctx():
    return AnalysisContext(src_root="src")


@pytest.fixture(scope="module")
def real_plan():
    """One concrete bucketed plan off the real Update path."""
    cfg = _engine_cfg(kv_buckets=3)
    x = jax.random.normal(jax.random.PRNGKey(0), (_B, _N, _DM)) * 0.3
    st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
    _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                         step_idx=2, num_steps=8)
    return cfg, st.plan


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------

def test_walker_recurses_into_nested_sub_jaxprs():
    """A sort hidden under jit-inside-scan is invisible to jaxpr-TEXT
    grep at the top level but must be found by the walker."""
    @jax.jit
    def hidden(x):
        def body(c, row):
            return c, jax.lax.sort(row)
        _, ys = jax.lax.scan(body, 0, x)
        return ys

    jx = jax.make_jaxpr(hidden)(jnp.ones((4, 8)))
    hits = index_decode_eqns(jx)
    assert len(hits) == 1
    path, eqn = hits[0]
    assert eqn.primitive.name == "sort"
    assert "scan" in path            # found inside the scan body
    counts = primitive_counts(jx)
    assert counts["sort"] == 1 and counts["scan"] == 1


def test_walker_flags_uint8_unpack_signature():
    """unpack_bits has no named primitive — the walker recognizes its
    uint8 bit-shift signature instead."""
    from repro.core.symbols import unpack_bits
    jx = jax.make_jaxpr(lambda s: unpack_bits(s, 16))(
        jnp.zeros((2, 2), jnp.uint8))
    assert index_decode_eqns(jx), "uint8 unpack signature not detected"


def test_eqn_count_modes():
    def f(x):
        def body(c, v):
            return c + v, v * 2
        return jax.lax.scan(body, 0.0, x)

    jx = jax.make_jaxpr(f)(jnp.ones(8))
    assert eqn_count(jx) == 1                      # the scan itself
    assert eqn_count(jx, recursive=True) > 1       # plus its body


# ---------------------------------------------------------------------------
# adversarial fixtures (each MUST be flagged)
# ---------------------------------------------------------------------------

def test_injected_sort_in_dispatch_fn_is_flagged():
    def dispatch_like(x, ids):
        return jnp.take(x, jax.lax.sort(ids), axis=0)

    jx = jax.make_jaxpr(dispatch_like)(jnp.ones((8, 4)),
                                       jnp.arange(8, dtype=jnp.int32))
    assert {e.primitive.name for _, e in index_decode_eqns(jx)} == {"sort"}


def test_foldback_violating_plan_is_flagged(real_plan):
    cfg, plan = real_plan
    mutated = plan._replace(
        bkt_kv_cnt=plan.bkt_kv_cnt + 7,                # counts > widths
        kv_row_ids=jnp.full_like(plan.kv_row_ids, 2 ** 14))  # ids OOR
    bad = check_plan(mutated, cfg, _N)
    assert any("outside [0" in m for m in bad)
    assert any("fold-back" in m for m in bad)
    with pytest.raises(PlanInvariantError):
        validate_plan(mutated, cfg, _N)


def test_widen_uncovered_field_is_flagged(real_plan):
    cfg, plan = real_plan
    bad = check_plan(plan._replace(q_cnt=plan.q_cnt.astype(jnp.int16)),
                     cfg, _N)
    assert any("stayed int16" in m for m in bad)


def test_occ_hist_mismatch_is_flagged(real_plan):
    cfg, plan = real_plan
    bad = check_plan(
        plan._replace(occ_hist=plan.occ_hist.at[..., 0].add(1)), cfg, _N)
    assert any("occ_hist" in m for m in bad)


def test_id_keyed_cache_is_flagged():
    src = ("_PLAN_CACHE = {}\n"
           "def lookup(spec):\n"
           "    key = id(spec)\n"
           "    if key not in _PLAN_CACHE:\n"
           "        _PLAN_CACHE[key] = spec\n"
           "    return _PLAN_CACHE[key]\n")
    rules = {r for _, _, r, _ in lint_source(src)}
    assert "id-keyed-cache" in rules
    assert "module-dict-cache" in rules   # unbounded dict cache too


def test_transient_local_id_dict_is_not_flagged():
    """schedule.strategy_table's pattern: id() keys into a TRANSIENT
    local dict over pinned objects is legal — no cache involved."""
    src = ("def table(specs):\n"
           "    by_spec = {}\n"
           "    for s in specs:\n"
           "        by_spec[id(s)] = resolve(s)\n"
           "    return by_spec\n")
    assert lint_source(src) == []


def test_jit_in_traced_body_is_flagged():
    src = ("import jax\n"
           "def outer(xs):\n"
           "    def body(c, x):\n"
           "        f = jax.jit(lambda v: v * 2)\n"
           "        return c, f(x)\n"
           "    return jax.lax.scan(body, 0, xs)\n")
    assert {r for _, _, r, _ in lint_source(src)} == {"jit-in-traced-body"}


# ---------------------------------------------------------------------------
# green runs on the real engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("strategy", available_strategies())
def test_dispatch_purity_per_strategy_backend(strategy, backend):
    """Every registered strategy × backend: Dispatch jaxpr decode-free,
    Update jaxpr the positive control (kv_buckets=3 exercises the
    bucketed layouts on both backends)."""
    cfg = _engine_cfg(strategy=strategy, backend=backend, kv_buckets=3,
                      **(dict(interpret=True) if backend == "pallas"
                         else {}))
    upd, disp = _trace_pair(cfg)
    hits = index_decode_eqns(disp)
    assert not hits, (
        f"{strategy}/{backend}: dispatch rebuilds indices: "
        + ", ".join(e.primitive.name for _, e in hits))
    assert index_decode_eqns(upd), \
        f"{strategy}/{backend}: vacuous walker — no decode in Update"


@pytest.mark.parametrize("strategy", available_strategies())
def test_plan_validator_green_per_strategy(strategy):
    """Real plans (bucketed, plus the mesh partition for the default
    strategy) satisfy every structural invariant."""
    cfg = _engine_cfg(strategy=strategy, kv_buckets=3)
    x = jax.random.normal(jax.random.PRNGKey(1), (_B, _N, _DM)) * 0.3
    st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
    _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                         step_idx=2, num_steps=8)
    assert check_plan(st.plan, cfg, _N) == []


def test_plan_validator_green_on_mesh_partition():
    """The shd_* partition checks run on ONE device (partition_plan is
    pure jnp at Update time)."""
    cfg = _engine_cfg(kv_buckets=1, mesh_dp=1, mesh_sp=2)
    x = jax.random.normal(jax.random.PRNGKey(2), (_B, _N, _DM)) * 0.3
    st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
    _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                         step_idx=2, num_steps=8)
    assert st.plan.shd_q_ids is not None
    assert check_plan(st.plan, cfg, _N) == []


def test_plan_validator_tolerates_stacked_axes(real_plan):
    """Layer/lane stacking adds leading axes; the checker folds them."""
    cfg, plan = real_plan
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (2, *a.shape)), plan)
    assert check_plan(stacked, cfg, _N) == []


def test_promotion_and_budget_passes_green():
    ctx = _ctx()
    assert PromotionCheck().run(ctx) == []
    assert ExecutableBudget().run(ctx) == []


def test_source_lint_green_on_repo():
    assert lint_sources("src") == []


def test_sweep_configs_covers_full_matrix():
    """The analyzer's sweep enumerates every registered strategy ×
    backend × kv_buckets ∈ {1,3} × {single, mesh} combo (mesh combos
    carry a skip note on hosts without 2 devices rather than vanishing
    silently)."""
    from repro.analysis.passes import sweep_configs
    combos = list(sweep_configs())
    strategies = set(available_strategies())
    assert len(combos) == len(strategies) * 2 * 2 * 2
    live = [(label, cfg) for label, cfg, skip in combos if skip is None]
    assert {c.strategy for _, c in live} == strategies
    assert {c.backend for _, c in live} == {"xla", "pallas"}
    assert {c.kv_buckets for _, c in live} == {1, 3}
    # skipped combos (mesh on a small host) must say so, never vanish
    for label, cfg, skip in combos:
        if skip is not None:
            assert cfg is None and "mesh" in label and "devices" in skip
    # the single-device half of the grid always runs
    assert len(live) >= len(strategies) * 2 * 2


# ---------------------------------------------------------------------------
# satellite 2: PR 7/8 field coverage regression (widen + specs + rebuild)
# ---------------------------------------------------------------------------

def test_pr78_fields_covered_by_widen_and_specs():
    """Every gmo_*/shd_*/occ_hist field from PRs 7–8 is wired through
    widen() (id fields), engine_state_specs, and the build path — the
    static lint finds zero coverage gaps, and the live widen() of a real
    plan leaves no int16 leaf."""
    import ast
    from pathlib import Path

    from repro.analysis.source_lint import is_id_field, plan_fields
    tree = ast.parse(Path("src/repro/core/plan.py").read_text())
    fields = plan_fields(tree)
    pr78 = [f for f in fields
            if f.startswith(("gmo_", "shd_")) or f == "occ_hist"]
    assert len(pr78) >= 16          # 4 gmo + 11 shd + occ_hist
    hits = [h for h in lint_sources("src") if h[2].startswith("plan-")]
    assert hits == []
    # and the id-field convention actually captures the PR 7/8 id lists
    assert {f for f in pr78 if is_id_field(f)} >= {
        "gmo_rows", "gmo_src", "gmo_head_ids", "shd_q_ids", "shd_q_src",
        "shd_q_slots", "shd_kv_ids", "shd_kv_row_ids", "shd_gather_idx",
        "shd_send_ids"}


def test_widen_roundtrip_complete_on_real_plans(real_plan):
    cfg, plan = real_plan
    wide = plan.widen()
    for name, leaf in zip(wide._fields, wide):
        if leaf is not None and hasattr(leaf, "dtype"):
            assert leaf.dtype != jnp.int16, f"{name} stayed int16"
    # idempotent
    again = wide.widen()
    for a, b in zip(jax.tree.leaves(wide), jax.tree.leaves(again)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# live validation hook
# ---------------------------------------------------------------------------

def test_validate_plans_hook_fires_and_passes(monkeypatch):
    """EngineConfig.validate_plans=True routes every plan build through
    the host-side checker (and real plans pass it)."""
    from repro.analysis import plan_check
    calls = []
    real = plan_check.hook_validate
    monkeypatch.setattr(plan_check, "hook_validate",
                        lambda p, cfg, n: calls.append(1) or real(p, cfg, n))
    cfg = dataclasses.replace(_engine_cfg(kv_buckets=3),
                              validate_plans=True)
    x = jax.random.normal(jax.random.PRNGKey(5), (_B, _N, _DM)) * 0.3
    st0 = init_layer_state(_B, _H, _N, _DM, _DH, cfg)
    _, st = update_layer(_params(), x, st0, cfg, n_text=32, heads=_H,
                         step_idx=2, num_steps=8)
    jax.block_until_ready(st.plan.q_cnt)
    assert calls, "validate_plans=True did not reach the host checker"


def test_validate_plans_env_gate(monkeypatch):
    from repro.analysis.plan_check import validation_enabled
    cfg = _engine_cfg()
    monkeypatch.delenv("REPRO_VALIDATE_PLANS", raising=False)
    assert not validation_enabled(cfg)
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "1")
    assert validation_enabled(cfg)
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "0")
    assert not validation_enabled(cfg)
    assert validation_enabled(dataclasses.replace(cfg,
                                                  validate_plans=True))


def test_collective_budget_green_or_noted_skip():
    """Zero findings either way: on a single-device host the pass
    records a skip note instead of silently vanishing; with >= 2
    devices (CI's forced-8-device step) it verifies the a2a budget."""
    from repro.analysis.passes import CollectiveBudget, mesh_capacity
    ctx = _ctx()
    assert CollectiveBudget().run(ctx) == []
    if mesh_capacity() < 2:
        assert ctx.notes, "1-device skip must leave a note"
