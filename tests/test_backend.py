"""Backend routing + DispatchPlan tests (compile-once Dispatch engine).

Covers the ISSUE-1 acceptance criteria:
  * interpret-mode parity: the Pallas backend (CSR attention + GEMM-Q +
    GEMM-O chained through the compact layout) matches the XLA structural
    path and the ``masked_block_attention`` oracle, for ``"bias"`` and
    ``"o_cache"`` cache modes, ragged kv/head counts and fully-cached rows;
  * plan-reuse invariance: N dispatches with a frozen DispatchPlan equal
    the legacy per-step rebuild path exactly;
  * no index rebuild at Dispatch: the dispatch jaxpr contains no
    sort/top-k work (``unpack_bits``→``clamp_mask_topk``→``active_indices``
    all moved to Update).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AttnParams, EngineConfig, MaskConfig, dispatch_layer,
                        get_backend, init_layer_state, plan_from_state,
                        update_layer)
from repro.core.attention import masked_block_attention
from repro.core.backend import PallasBackend, XlaBackend
from repro.core.plan import build_dispatch_plan


def _engine_setup(mode="bias", backend="xla", tau_kv=0.0, capq=1.0, capkv=1.0,
                  batch=2):
    key = jax.random.PRNGKey(0)
    B, H, N, dm, dh = batch, 2, 256, 64, 32
    cfg = EngineConfig(
        mask=MaskConfig(pool=32, block_q=16, block_kv=16, interval=4,
                        order=1, warmup_steps=1, tau_kv=tau_kv, tau_q=0.5),
        cache_mode=mode, cap_q_frac=capq, cap_kv_frac=capkv,
        cache_dtype=jnp.float32, backend=backend)
    ks = jax.random.split(key, 8)
    p = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh)) * 0.05,
        wk=jax.random.normal(ks[1], (dm, H * dh)) * 0.05,
        wv=jax.random.normal(ks[2], (dm, H * dh)) * 0.05,
        wo=jax.random.normal(ks[3], (H * dh, dm)) * 0.05,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm))
    state = init_layer_state(B, H, N, dm, dh, cfg)
    return cfg, p, x, state, H


# ---------------------------------------------------------------------------
# Backend resolution
# ---------------------------------------------------------------------------

def test_get_backend_routing():
    assert isinstance(get_backend(EngineConfig(backend="xla")), XlaBackend)
    pb = get_backend(EngineConfig(backend="pallas"))
    assert isinstance(pb, PallasBackend)
    assert pb.interpret == (jax.default_backend() != "tpu")
    auto = get_backend(EngineConfig(backend="auto"))
    expect = PallasBackend if jax.default_backend() == "tpu" else XlaBackend
    assert isinstance(auto, expect)
    with pytest.raises(ValueError):
        get_backend(EngineConfig(backend="cuda"))


# ---------------------------------------------------------------------------
# Interpret-mode parity: plan-driven backends vs the dense oracle
# ---------------------------------------------------------------------------

def _plan_inputs(seed, b, h, t, blk, n, d):
    """Random masks with ragged rows, a fully-cached head and a row live in
    only ONE head (ragged head_cnt), plus at least one kv block per row."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (b, h, n, d))
    k = jax.random.normal(ks[1], (b, h, n, d))
    v = jax.random.normal(ks[2], (b, h, n, d))
    o_reuse = jax.random.normal(ks[3], (b, h, n, d))
    m_c = jax.random.bernoulli(ks[4], 0.6, (b, h, t))
    m_c = m_c.at[:, 0, :].set(False)           # head 0: fully cached rows
    m_c = m_c.at[:, 1, 0].set(True)            # row 0 live in one head only
    m_s = jax.random.bernoulli(ks[5], 0.5, (b, h, t, t))
    m_s = m_s.at[..., 0].set(True)             # ragged but never-empty rows
    return q, k, v, o_reuse, m_c, m_s


@pytest.mark.parametrize("seed", [3, 11])
def test_attention_backends_match_oracle(seed):
    b, h, t, blk, d = 2, 3, 8, 16, 32
    n = t * blk
    # pool == block_q == block_kv so compressed == kernel granularity.
    cfg = EngineConfig(mask=MaskConfig(pool=blk, block_q=blk, block_kv=blk),
                       cap_q_frac=1.0, cap_kv_frac=1.0)
    q, k, v, o_reuse, m_c, m_s = _plan_inputs(seed, b, h, t, blk, n, d)
    plan = build_dispatch_plan(m_c, m_s, cfg, n)
    spec = cfg.caps(n)

    want = masked_block_attention(q, k, v, m_c, m_s, o_reuse,
                                  block_q=blk, block_kv=blk)
    got_xla = XlaBackend().attention(q, k, v, o_reuse, plan, spec)
    got_pls = PallasBackend(interpret=True).attention(q, k, v, o_reuse,
                                                      plan, spec)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pls), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("mode", ["bias", "o_cache"])
@pytest.mark.parametrize("tau_kv,capkv", [(0.0, 1.0), (0.15, 1.0),
                                          (0.15, 0.5), (0.15, 0.25)])
def test_dispatch_backend_parity(mode, tau_kv, capkv):
    """Full dispatch step (GEMM-Q → attention → GEMM-O, compact-fused on
    Pallas) agrees across backends in both cache modes — INCLUDING the
    ``cap_kv``-truncated capacities (0.5 / 0.25): the XLA path now
    consumes the same per-row CSR lists as the Pallas kernel, so the old
    "union truncation drops blocks globally per head" divergence is gone
    (these cases used to be excluded as a documented approximation)."""
    cfg_x, p, x, state, H = _engine_setup(mode, "xla", tau_kv=tau_kv,
                                          capkv=capkv)
    cfg_p = dataclasses.replace(cfg_x, backend="pallas", interpret=True)
    _, st = update_layer(p, x, state, cfg_x, n_text=64, heads=H)
    x2 = x + 0.01 * jax.random.normal(jax.random.PRNGKey(5), x.shape)
    out_x, st_x = dispatch_layer(p, x2, st, cfg_x, n_text=64, heads=H)
    out_p, st_p = dispatch_layer(p, x2, st, cfg_p, n_text=64, heads=H)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(out_p),
                               atol=1e-5, rtol=1e-5)
    assert int(st_x.k_since) == int(st_p.k_since) == 1


@pytest.mark.parametrize("seed", [3, 11])
def test_attention_backends_match_oracle_truncated_rows(seed):
    """Per-row KV truncation parity: rows each keep <= cap_kv blocks but
    collectively need MORE than cap_kv distinct columns, so the per-head
    union overflows the static capacity.  Both backends must still match
    the dense oracle exactly — the XLA path may not drop union columns
    globally (the pre-fix behaviour)."""
    b, h, t, blk, d = 2, 2, 8, 16, 32
    n = t * blk
    cfg = EngineConfig(mask=MaskConfig(pool=blk, block_q=blk, block_kv=blk),
                       cap_q_frac=1.0, cap_kv_frac=0.25)   # cap_kv = 2 < t
    spec = cfg.caps(n)
    assert spec.cap_kv == 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, n, d))
    k = jax.random.normal(ks[1], (b, h, n, d))
    v = jax.random.normal(ks[2], (b, h, n, d))
    o_reuse = jax.random.normal(ks[3], (b, h, n, d))
    m_c = jnp.ones((b, h, t), bool)
    # Sliding band of width cap_kv: every row within capacity, union = t.
    idx = jnp.arange(t)
    band = (idx[None, :] - idx[:, None]) % t < spec.cap_kv
    m_s = jnp.broadcast_to(band, (b, h, t, t))
    plan = build_dispatch_plan(m_c, m_s, cfg, n)

    want = masked_block_attention(q, k, v, m_c, m_s, o_reuse,
                                  block_q=blk, block_kv=blk)
    got_xla = XlaBackend().attention(q, k, v, o_reuse, plan, spec)
    got_pls = PallasBackend(interpret=True).attention(q, k, v, o_reuse,
                                                      plan, spec)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pls), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(got_pls),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("mode", ["bias", "o_cache"])
def test_dispatch_backend_parity_with_rope(mode):
    """Compact-layout RoPE uses the ORIGINAL token positions of gathered
    rows — parity must survive capacity-truncated (capq<1) gathers."""
    from repro.core.engine import rope_freqs
    cfg_x, p, x, state, H = _engine_setup(mode, "xla", tau_kv=0.1, capq=0.75)
    cfg_p = dataclasses.replace(cfg_x, backend="pallas", interpret=True)
    freqs = rope_freqs(x.shape[1], 32)
    _, st = update_layer(p, x, state, cfg_x, n_text=64, heads=H, freqs=freqs)
    out_x, _ = dispatch_layer(p, x, st, cfg_x, n_text=64, heads=H, freqs=freqs)
    out_p, _ = dispatch_layer(p, x, st, cfg_p, n_text=64, heads=H, freqs=freqs)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(out_p),
                               atol=1e-5, rtol=1e-5)


def test_gemm_o_backends_with_padded_row_slots():
    """row_cnt < cap ⇒ padding slots duplicate the last live row id.  Their
    head lists must be EMPTY in the plan (bias-aliased Pallas GEMM-O would
    otherwise re-accumulate that row once per padded slot on real TPU) and
    both backends must still match the dense oracle."""
    b, h, t, blk, dh, dm = 2, 3, 8, 16, 32, 48
    n = t * blk
    cfg = EngineConfig(mask=MaskConfig(pool=blk, block_q=blk, block_kv=blk),
                       cap_q_frac=1.0, cap_kv_frac=1.0)
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    m_c = jax.random.bernoulli(ks[0], 0.6, (b, h, t))
    m_c = m_c.at[:, :, 5:].set(False)          # rows 5..7 dead in ALL heads
    m_c = m_c.at[:, 0, 0].set(True)
    m_s = jnp.ones((b, h, t, t), bool)
    plan = build_dispatch_plan(m_c, m_s, cfg, n)
    cap = plan.row_ids.shape[-1]
    assert cap == t and int(plan.row_cnt.max()) < cap   # padding slots exist
    slot = np.arange(cap)[None, :]
    padded = slot >= np.asarray(plan.row_cnt)[:, None]
    assert (np.asarray(plan.head_cnt)[padded] == 0).all()
    assert not np.asarray(plan.head_mask)[padded].any()

    o_tok = jax.random.normal(ks[1], (b, n, h, dh))
    w = jax.random.normal(ks[2], (h, dh, dm))
    bias = jax.random.normal(ks[3], (b, n, dm))
    got_x = XlaBackend().gemm_o(o_tok, w, plan, bias, block=blk)
    got_p = PallasBackend(interpret=True).gemm_o(o_tok, w, plan, bias,
                                                 block=blk)
    m_tok = jnp.repeat(plan.m_ch, blk, axis=-2)[..., :n, :]
    want = jnp.einsum("bnhd,hdf->bnf",
                      jnp.where(m_tok[..., None], o_tok, 0), w) + bias
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Plan lifecycle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_plan_reuse_invariance(backend):
    """N consecutive dispatches with the FROZEN plan produce outputs
    identical to rebuilding the plan from the packed symbols every step
    (the seed implementation's behaviour)."""
    kw = dict(interpret=True) if backend == "pallas" else {}
    cfg, p, x, state, H = _engine_setup("bias", backend, tau_kv=0.1,
                                        capq=0.75, capkv=0.9, batch=1)
    cfg = dataclasses.replace(cfg, **kw)
    _, st = update_layer(p, x, state, cfg, n_text=64, heads=H)
    st_frozen, st_rebuild = st, st
    for k in range(1, 4):
        x = x + 0.01 * jax.random.normal(jax.random.PRNGKey(k), x.shape)
        out_f, st_frozen = dispatch_layer(p, x, st_frozen, cfg,
                                          n_text=64, heads=H)
        rebuilt = plan_from_state(st_rebuild, cfg, x.shape[1])
        out_r, st_rebuild = dispatch_layer(p, x, st_rebuild, cfg,
                                           n_text=64, heads=H, plan=rebuilt)
        np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_r))


def test_update_refreshes_plan():
    cfg, p, x, state, H = _engine_setup("bias", "xla", tau_kv=0.1)
    _, s1 = update_layer(p, x, state, cfg, n_text=64, heads=H)
    _, s2 = dispatch_layer(p, x, s1, cfg, n_text=64, heads=H)
    # Dispatch carries the plan through untouched...
    for a, b in zip(jax.tree.leaves(s1.plan), jax.tree.leaves(s2.plan)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ...and a new Update with different input rebuilds it.
    x2 = x + jax.random.normal(jax.random.PRNGKey(9), x.shape)
    _, s3 = update_layer(p, x2, s2, cfg, n_text=64, heads=H)
    same = all(bool((np.asarray(a) == np.asarray(b)).all())
               for a, b in zip(jax.tree.leaves(s1.plan),
                               jax.tree.leaves(s3.plan)))
    assert not same


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_dispatch_jaxpr_has_no_index_decode(backend):
    """Acceptance criterion: a Dispatch step given a DispatchPlan performs
    no ``unpack_bits``/``clamp_mask_topk``/``active_indices`` work — its
    jaxpr contains no sort/top-k/uint8-unpack equations ANYWHERE,
    including inside pjit/scan sub-jaxprs (the analyzer's primitive-level
    walker, not the old jaxpr-text grep)."""
    from repro.analysis.jaxpr_walk import index_decode_eqns
    kw = dict(interpret=True) if backend == "pallas" else {}
    cfg, p, x, state, H = _engine_setup("bias", backend, tau_kv=0.15,
                                        capq=0.75, capkv=0.9, batch=1)
    cfg = dataclasses.replace(cfg, **kw)
    _, st = update_layer(p, x, state, cfg, n_text=64, heads=H)

    disp = jax.make_jaxpr(
        lambda xx, ss: dispatch_layer(p, xx, ss, cfg, n_text=64, heads=H)
    )(x, st)
    hits = index_decode_eqns(disp)
    assert not hits, (
        "dispatch jaxpr rebuilds indices: "
        + ", ".join(f"{e.primitive.name} at {'/'.join(pth) or '<top>'}"
                    for pth, e in hits))

    # Control: the Update step is where the index decode now lives.
    upd = jax.make_jaxpr(
        lambda xx, ss: update_layer(p, xx, ss, cfg, n_text=64, heads=H)
    )(x, st)
    upd_prims = {e.primitive.name for _, e in index_decode_eqns(upd)}
    assert "sort" in upd_prims and "top_k" in upd_prims
