"""Batched-serving tests (ISSUE 4 + ISSUE 5 acceptance criteria).

  * stacked-vs-sequential BIT parity per lane (batch-axis stacking into
    the cached single-scan sampler changes no per-sample numerics);
  * continuous batcher: mixed-length, mixed-schedule requests interleave
    in a fixed-width microbatch with per-lane outputs bit-identical to
    sequential runs, lanes retiring/refilling WITHOUT recompiling (a
    fixed ≤ 4 executable budget per lane shape, compile-count asserted);
  * same-mode lane folding: mode-homogeneous ticks run the batched
    mode-group bodies (bit parity asserted), mixed ticks exercise the
    lane-scan fallback, and the executable budget is shape-independent;
  * ``step-phased`` FRACTIONAL boundaries behave identically under the
    batcher and under ``pipeline.sample`` (the tick threads per-lane
    traced ``num_steps`` into the StrategyContext);
  * strategy dedup is by VALUE: re-resolving an LRU-evicted spec mints
    fresh strategy objects but must not grow the universe or re-trace;
  * empty-lane padding contributes EXACTLY zero to the per-lane metrics;
  * schedule pad/stack utilities (MODE_IDLE padding, strategy-id
    remapping onto a merged universe);
  * LRU bounds on the sampler cache and the schedule-resolution memo,
    hit/miss counters surfaced through ``stats``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig, resolve_schedule
from repro.core.lru import LruCache
from repro.core.masks import MaskConfig
from repro.core.schedule import (MODE_IDLE, merge_strategies,
                                 schedule_lane_rows, stack_schedules,
                                 tick_mode_groups)
from repro.core.strategy import StepPhasedStrategy, strategy_key
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.launch.batching import (ContinuousBatcher, Request, RequestQueue,
                                   run_sequential, run_stacked)
from repro.models import dit


def _ecfg(**kw):
    base = dict(tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.0,
                block_q=16, block_kv=16, pool=16, warmup_steps=2)
    mask_keys = set(base)
    mask_kw = {k: kw.pop(k) for k in list(kw) if k in mask_keys}
    return EngineConfig(mask=MaskConfig(**{**base, **mask_kw}),
                        cache_dtype=jnp.float32, cap_q_frac=1.0,
                        cap_kv_frac=1.0, **kw)


def _mk_request(cfg, i, steps, schedule=None, layer_strategies=None):
    kx, kt = jax.random.split(
        jax.random.fold_in(jax.random.PRNGKey(100), i))
    return Request(
        rid=i,
        x0=jax.random.normal(kx, (1, 64, cfg.patch_dim)),
        text_emb=jax.random.normal(
            kt, (1, cfg.n_text_tokens, cfg.d_model)),
        num_steps=steps, schedule=schedule,
        layer_strategies=layer_strategies)


@pytest.fixture(scope="module")
def served():
    """Shared model + a mixed request workload + the sequential oracle."""
    cfg = get_smoke("flux-mmdit")
    ecfg = _ecfg()
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    mk = lambda i, steps, schedule=None: _mk_request(cfg, i, steps, schedule)

    # Mixed lengths (8 / 6 / 4 steps) AND mixed schedules: two plain
    # flashomni requests (stackable), two step-ramp, one short straggler.
    reqs = [mk(0, 8), mk(1, 6, "step-ramp"), mk(2, 8),
            mk(3, 6, "step-ramp"), mk(4, 4)]
    seq = run_sequential(params, cfg, ecfg, reqs)
    return cfg, ecfg, params, reqs, seq


def test_stacked_matches_sequential_bitwise(served):
    cfg, ecfg, params, reqs, seq = served
    stk = run_stacked(params, cfg, ecfg, reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            stk[r.rid]["out"], seq[r.rid]["out"],
            err_msg=f"stacked lane {r.rid} diverged from sequential")


def test_continuous_bit_parity_and_executable_budget(served):
    """Lanes retire and refill across mixed-length/mixed-schedule requests
    inside the fixed ≤ 4 executable budget (mode-group bodies + mixed
    fallback), every request's output is bit-identical to its sequential
    run, and the mixed workload exercises BOTH tick paths."""
    cfg, ecfg, params, reqs, seq = served
    # grouped=True (not "auto"): force folding on a non-lockstep mix so
    # this test covers grouped ticks AND the scan fallback side by side.
    bat = ContinuousBatcher(params, cfg, ecfg, lanes=3, max_steps=8,
                            grouped=True)
    bat.submit_all(reqs)
    results = bat.run()
    for r in reqs:
        np.testing.assert_array_equal(
            results[r.rid]["out"], seq[r.rid]["out"],
            err_msg=f"continuous lane {r.rid} diverged from sequential")
    # 5 requests over 3 lanes forces at least one retire->refill cycle;
    # the grouped dense/update/dispatch bodies + the mixed-fallback scan
    # are a FIXED budget: at most 4 executables per lane shape, however
    # lanes churn.
    assert 1 <= bat.stats["executables"] <= 4
    assert bat.stats["ticks"] >= 8      # longest schedule's step count
    # This workload starts lockstep (mode-homogeneous ticks -> grouped
    # bodies) and de-synchronizes when the 4-step straggler refills a
    # lane (mixed modes -> scan fallback): both paths must have run.
    assert bat.stats["grouped_ticks"] > 0
    assert bat.stats["scan_ticks"] > 0
    assert bat.stats["ticks"] == (bat.stats["grouped_ticks"]
                                  + bat.stats["scan_ticks"])
    # Per-lane traces match the sequential sampler's per-step metrics.
    for rid in (0, 1, 4):
        ts, tc = seq[rid]["trace"], results[rid]["trace"]
        assert [t["kind"] for t in ts] == [t["kind"] for t in tc]
        np.testing.assert_allclose(
            [t["density"] for t in tc], [t["density"] for t in ts],
            atol=1e-7, rtol=1e-7)


def test_grouped_tick_homogeneous_bit_parity(served):
    """A homogeneous-schedule mix runs EVERY tick through the batched
    mode-group bodies (no scan fallback), stays inside the executable
    budget, and keeps per-lane outputs bit-identical to sequential."""
    cfg, ecfg, params, _, _ = served
    reqs = [_mk_request(cfg, 20 + i, 6) for i in range(4)]
    seq = run_sequential(params, cfg, ecfg, reqs)
    bat = ContinuousBatcher(params, cfg, ecfg, lanes=4, max_steps=6)
    bat.submit_all(reqs)
    results = bat.run()
    for r in reqs:
        np.testing.assert_array_equal(
            results[r.rid]["out"], seq[r.rid]["out"],
            err_msg=f"grouped lane {r.rid} diverged from sequential")
    assert bat.stats["scan_ticks"] == 0
    assert bat.stats["grouped_ticks"] == bat.stats["ticks"] == 6
    # Only the update + dispatch group bodies compile for this schedule.
    assert bat.stats["executables"] <= 4
    # Per-lane trace metrics flow through the grouped path too.
    for r in reqs:
        ts, tc = seq[r.rid]["trace"], results[r.rid]["trace"]
        assert [t["kind"] for t in ts] == [t["kind"] for t in tc]
        np.testing.assert_allclose(
            [t["density"] for t in tc], [t["density"] for t in ts],
            atol=1e-7, rtol=1e-7)


def test_grouped_disabled_falls_back_to_scan(served):
    """``grouped=False`` (the vmap-incompatible-backend safety valve)
    serves everything through the lane scan, bit-identically."""
    cfg, ecfg, params, _, _ = served
    reqs = [_mk_request(cfg, 30 + i, 4) for i in range(2)]
    seq = run_sequential(params, cfg, ecfg, reqs)
    bat = ContinuousBatcher(params, cfg, ecfg, lanes=2, max_steps=4,
                            grouped=False)
    bat.submit_all(reqs)
    results = bat.run()
    for r in reqs:
        np.testing.assert_array_equal(results[r.rid]["out"],
                                      seq[r.rid]["out"])
    assert bat.stats["grouped_ticks"] == 0
    assert bat.stats["scan_ticks"] == bat.stats["ticks"]
    assert bat.stats["executables"] == 1


@pytest.mark.parametrize("grouped", ["auto", True])
def test_step_phased_fractional_boundaries_under_batcher(served, grouped):
    """`step-phased` with FRACTIONAL boundaries must flip phases at the
    same step under the batcher as under ``pipeline.sample``: the tick
    threads each lane's traced ``num_steps`` into the StrategyContext
    (the old tick passed ``num_steps=None``, so fractional boundaries
    could not run under the batcher at all).  ``auto`` keeps this
    non-lockstep mix on the scan tick; forcing ``grouped=True`` covers
    the vmapped mode-group bodies too (per-lane ``num_steps`` batches)."""
    cfg, _, params, _, _ = served
    ecfg = _ecfg(interval=2)     # updates at 0,1,2,4,6: spans the boundary
    sp = StepPhasedStrategy(phases=("flashomni", "cache-all"),
                            boundaries=(0.5,))
    ls = [sp] * cfg.n_layers
    mk = lambda i, steps: _mk_request(cfg, 40 + i, steps,
                                      layer_strategies=ls)
    # DIFFERENT step counts: the fractional boundary resolves per lane
    # (steps 3 vs 4), which no single absolute boundary can express.
    reqs = [mk(0, 6), mk(1, 8)]
    seq = run_sequential(params, cfg, ecfg, reqs)
    bat = ContinuousBatcher(params, cfg, ecfg, lanes=2, max_steps=8,
                            grouped=grouped)
    bat.submit_all(reqs)
    results = bat.run()
    for r in reqs:
        np.testing.assert_array_equal(
            results[r.rid]["out"], seq[r.rid]["out"],
            err_msg=f"step-phased lane {r.rid} diverged from sequential")


def test_step_phased_boundary_rounding_matches_traced_path():
    """Static and traced fractional-boundary resolves must agree BIT-FOR-BIT
    so batched serving flips phases at the same step as `pipeline.sample`.
    0.3·5 is the canary: 1.4999998 in float64 (rounds to 1) but 1.5000001
    in float32 (rounds to 2) — both paths must take the f32 answer."""
    sp = StepPhasedStrategy(phases=("flashomni", "cache-all", "skip-only"),
                            boundaries=(0.3, 0.9))
    static = sp._boundary_steps(5)
    assert static == [2, 4]               # f32 semantics, not float64's [1, 4]
    traced = [int(jax.jit(lambda n: jnp.stack(sp._boundary_steps(n)))(
        jnp.int32(5))[i]) for i in range(2)]
    assert traced == static


def test_value_dedup_survives_schedule_memo_eviction(served):
    """Re-resolving a spec after its resolve_schedule memo entry is gone
    mints NEW (value-equal) strategy objects; the batcher's value-keyed
    universe must neither grow nor re-trace (stats["executables"] flat)."""
    import repro.core.engine as eng
    cfg, ecfg, params, _, _ = served
    bat = ContinuousBatcher(params, cfg, ecfg, lanes=2, max_steps=4)
    bat.submit_all([_mk_request(cfg, 50 + i, 4) for i in range(2)])
    bat.run()
    before = bat.stats["executables"]
    n_strategies = len(bat.stats["strategies"])
    old_cache = eng._SCHEDULE_CACHE
    eng._SCHEDULE_CACHE = LruCache(128)   # simulate the LRU eviction
    try:
        bat.submit_all([_mk_request(cfg, 60 + i, 4) for i in range(2)])
        bat.run()
    finally:
        eng._SCHEDULE_CACHE = old_cache
    assert bat.stats["executables"] == before
    assert len(bat.stats["strategies"]) == n_strategies


def test_strategy_key_value_semantics():
    from repro.core.strategy import (FlashOmniStrategy,
                                     MultiGranularityStrategy)
    assert strategy_key(FlashOmniStrategy()) == strategy_key(
        FlashOmniStrategy())
    assert strategy_key(FlashOmniStrategy(tau_q=0.3)) != strategy_key(
        FlashOmniStrategy())
    # Recursion through child strategies (and dict-valued layer tables).
    a = MultiGranularityStrategy(children=("flashomni", "sliding-window"),
                                 head_assign=(0, 0, 1),
                                 layer_assign={0: 1})
    b = MultiGranularityStrategy(children=("flashomni", "sliding-window"),
                                 head_assign=(0, 0, 1),
                                 layer_assign={0: 1})
    c = MultiGranularityStrategy(children=("flashomni", "sliding-window"),
                                 head_assign=(0, 1, 1),
                                 layer_assign={0: 1})
    assert strategy_key(a) == strategy_key(b) != strategy_key(c)

    class AdHoc:
        name = "ad-hoc"

        def emit(self, q, k, ctx):   # pragma: no cover - never called
            raise NotImplementedError

    x, y = AdHoc(), AdHoc()
    assert strategy_key(x) != strategy_key(y)      # identity fallback
    assert strategy_key(x) == strategy_key(x)


def test_continuous_empty_lanes_zero_metrics(served):
    """Lanes with no resident request (width > live requests) must run the
    idle branch: zero density / pair-sparsity contribution."""
    cfg, ecfg, params, reqs, seq = served
    bat = ContinuousBatcher(params, cfg, ecfg, lanes=4, max_steps=8)
    bat.submit_all([reqs[0], reqs[4]])   # 2 requests over 4 lanes
    results = bat.run()
    np.testing.assert_array_equal(results[reqs[0].rid]["out"],
                                  seq[reqs[0].rid]["out"])
    act = bat.stats["lane_active"]
    dens = bat.stats["lane_density"]
    ps = bat.stats["lane_pair_sparsity"]
    assert (~act).any()                   # idle lanes existed
    assert float(np.abs(dens[~act]).max(initial=0.0)) == 0.0
    assert float(np.abs(ps[~act]).max(initial=0.0)) == 0.0
    # ...and active lanes did report nonzero metrics.
    assert float(np.abs(dens[act]).max(initial=0.0)) > 0.0


def test_request_queue_arrival_order():
    q = RequestQueue()
    mk = lambda rid, at: Request(rid=rid, x0=jnp.zeros((1, 1, 1)),
                                 text_emb=jnp.zeros((1, 1, 1)),
                                 num_steps=1, arrival=at)
    q.submit(mk("late", 5.0))
    q.submit(mk("a", 0.0))
    q.submit(mk("b", 0.0))
    assert len(q) == 3 and q.next_arrival() == 0.0
    assert q.pop_ready(0.0).rid == "a"    # FIFO within equal arrivals
    assert q.pop_ready(0.0).rid == "b"
    assert q.pop_ready(1.0) is None       # "late" not arrived yet
    assert q.pop_ready(5.0).rid == "late"


def test_request_queue_many_inserts_keep_order():
    """bisect-based submit keeps the (arrival, seq) order over many
    out-of-order inserts — equal arrivals stay FIFO by submission."""
    rng = np.random.default_rng(0)
    q = RequestQueue()
    mk = lambda rid, at: Request(rid=rid, x0=jnp.zeros((1, 1, 1)),
                                 text_emb=jnp.zeros((1, 1, 1)),
                                 num_steps=1, arrival=at)
    arrivals = np.round(rng.uniform(0.0, 4.0, size=200), 1)  # many ties
    for rid, at in enumerate(arrivals):
        q.submit(mk(rid, float(at)))
    want = sorted(range(len(arrivals)), key=lambda r: (arrivals[r], r))
    got = [q.pop_ready(float("inf")).rid for _ in range(len(arrivals))]
    assert got == want and len(q) == 0


# ---------------------------------------------------------------------------
# Schedule pad/stack utilities
# ---------------------------------------------------------------------------

def test_stack_schedules_pads_and_remaps():
    ecfg = _ecfg()
    s_plain = resolve_schedule(ecfg, 4, 3)
    s_ramp = resolve_schedule(ecfg, 6, 3, schedule="step-ramp")
    mode, ids, strategies, lengths = stack_schedules([s_plain, s_ramp])
    assert mode.shape == (2, 6) and ids.shape == (2, 6, 3)
    assert lengths == [4, 6]
    # Lane 0 pads steps 4..5 with MODE_IDLE; lane 1 has none.
    assert (mode[0, 4:] == MODE_IDLE).all() and (mode[0, :4] != MODE_IDLE).all()
    assert (mode[1] != MODE_IDLE).all()
    # Ids remap into the merged universe.  Dedup is by VALUE: step-ramp's
    # own flashomni instance merges with lane 0's value-equal producer,
    # so the union holds 3 distinct producers, not 4 objects.
    uni = merge_strategies([s_plain, s_ramp])
    assert strategies == uni and len(uni) == 3
    assert {s.name for s in uni} == {"flashomni", "skip-only", "cache-all"}
    assert ids[0].max() == 0 and ids[1].max() == 2
    # Remapped rows still select a VALUE-equal strategy per step.
    for step in range(6):
        want = s_ramp.strategies[int(np.asarray(s_ramp.strategy_ids)[step, 0])]
        assert strategy_key(uni[ids[1, step, 0]]) == strategy_key(want)


def test_tick_mode_groups_partitions_active_lanes():
    mode_tab = np.asarray([[1, 2, 2, 2],      # lane 0: update then dispatch
                           [1, 1, 2, 2],      # lane 1
                           [1, 2, 2, 2],      # lane 2 (inactive)
                           [3, 3, 3, 3]],     # lane 3: idle padding
                          np.int32)
    steps = np.asarray([1, 1, 0, 0], np.int32)
    active = np.asarray([True, True, False, False])
    groups = tick_mode_groups(mode_tab, steps, active)
    assert [m for m, _ in groups] == [1, 2]
    np.testing.assert_array_equal(groups[0][1], [False, True, False, False])
    np.testing.assert_array_equal(groups[1][1], [True, False, False, False])
    # Homogeneous tick: one group covering exactly the active lanes.
    groups = tick_mode_groups(mode_tab, np.zeros(4, np.int32), active)
    assert len(groups) == 1 and groups[0][0] == 1
    np.testing.assert_array_equal(groups[0][1], active)
    # No active lanes -> no groups.
    assert tick_mode_groups(mode_tab, steps, np.zeros(4, bool)) == []


def test_schedule_lane_rows_validation():
    ecfg = _ecfg()
    s6 = resolve_schedule(ecfg, 6, 2)
    with pytest.raises(ValueError, match="max_steps"):
        schedule_lane_rows(s6, s6.strategies, 4)
    other = resolve_schedule(ecfg, 6, 2, schedule="step-ramp")
    with pytest.raises(ValueError, match="shared lane strategy set"):
        schedule_lane_rows(other, s6.strategies, 6)
    with pytest.raises(ValueError, match="at least one schedule"):
        stack_schedules([])


def test_lane_state_index_ops_roundtrip():
    """gather/scatter/merge_lane_states are consistent device-side lane
    index ops over arbitrary pytrees (set_lane_state stays the eager
    single-lane special case)."""
    from repro.core.engine import (gather_lane_states, merge_lane_states,
                                   scatter_lane_states, set_lane_state)
    tree = {"a": jnp.arange(12.0).reshape(4, 3),
            "b": jnp.arange(8, dtype=jnp.int32).reshape(4, 2)}
    got = gather_lane_states(tree, [2, 0])
    np.testing.assert_array_equal(got["a"], np.asarray(tree["a"])[[2, 0]])
    fresh = jax.tree.map(lambda s: -jnp.ones_like(s)[0], tree)
    via_set = set_lane_state(tree, 1, fresh)
    via_scatter = scatter_lane_states(
        tree, [1], jax.tree.map(lambda f: f[None], fresh))
    for k in tree:
        np.testing.assert_array_equal(via_set[k], via_scatter[k])
        np.testing.assert_array_equal(via_set[k][0], tree[k][0])
        np.testing.assert_array_equal(via_set[k][1], fresh[k])
    mask = jnp.asarray([False, True, False, True])
    stacked_fresh = jax.tree.map(
        lambda f: jnp.broadcast_to(f, (4, *f.shape)), fresh)
    merged = merge_lane_states(tree, stacked_fresh, mask)
    for k in tree:
        np.testing.assert_array_equal(merged[k][0], tree[k][0])
        np.testing.assert_array_equal(merged[k][1], fresh[k])


# ---------------------------------------------------------------------------
# LRU bounds on the serving memos
# ---------------------------------------------------------------------------

def test_lru_cache_bounds_and_counters():
    c = LruCache(2)
    assert c.get("a") is None and c.misses == 1
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1 and c.hits == 1
    c.put("c", 3)                      # evicts "b" (LRU after the "a" hit)
    assert len(c) == 2 and c.evictions == 1
    assert "b" not in c and "a" in c and "c" in c
    assert c.stats()["maxsize"] == 2
    with pytest.raises(ValueError):
        LruCache(0)


def test_sampler_cache_is_bounded_with_stats(served):
    """Cycling > maxsize distinct sampler configurations must not grow the
    cache past its bound, and stats must expose the hit/miss counters."""
    import repro.diffusion.pipeline as pl
    cfg, ecfg, params, reqs, seq = served
    old = pl._SAMPLER_CACHE
    pl._SAMPLER_CACHE = LruCache(2)
    try:
        x0 = reqs[0].x0
        text = reqs[0].text_emb
        stats: dict = {}
        for steps in (3, 4, 5, 3):     # 3 distinct configs through size 2
            sample(params, cfg, ecfg, text_emb=text, x0=x0,
                   scfg=SamplerConfig(num_steps=steps), stats=stats)
        sc = stats["sampler_cache"]
        assert sc["size"] <= 2 and sc["evictions"] >= 1
        # The repeat of steps=3 was evicted in between: 4 misses, 0 hits.
        assert sc["misses"] == 4 and sc["hits"] == 0
        sample(params, cfg, ecfg, text_emb=text, x0=x0,
               scfg=SamplerConfig(num_steps=3), stats=stats)
        assert stats["sampler_cache"]["hits"] == 1
        assert "schedule_cache" in stats
        assert stats["schedule_cache"]["maxsize"] >= 2
    finally:
        pl._SAMPLER_CACHE = old
