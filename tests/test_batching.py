"""Batched-serving tests (ISSUE 4 acceptance criteria).

  * stacked-vs-sequential BIT parity per lane (batch-axis stacking into
    the cached single-scan sampler changes no per-sample numerics);
  * continuous batcher: mixed-length, mixed-schedule requests interleave
    in a fixed-width microbatch with per-lane outputs bit-identical to
    sequential runs, lanes retiring/refilling WITHOUT recompiling (one
    executable per lane shape, compile-count asserted);
  * empty-lane padding contributes EXACTLY zero to the per-lane metrics;
  * schedule pad/stack utilities (MODE_IDLE padding, strategy-id
    remapping onto a merged universe);
  * LRU bounds on the sampler cache and the schedule-resolution memo,
    hit/miss counters surfaced through ``stats``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig, resolve_schedule
from repro.core.lru import LruCache
from repro.core.masks import MaskConfig
from repro.core.schedule import (MODE_IDLE, merge_strategies,
                                 schedule_lane_rows, stack_schedules)
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.launch.batching import (ContinuousBatcher, Request, RequestQueue,
                                   run_sequential, run_stacked)
from repro.models import dit


def _ecfg(**kw):
    base = dict(tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.0,
                block_q=16, block_kv=16, pool=16, warmup_steps=2)
    mask_keys = set(base)
    mask_kw = {k: kw.pop(k) for k in list(kw) if k in mask_keys}
    return EngineConfig(mask=MaskConfig(**{**base, **mask_kw}),
                        cache_dtype=jnp.float32, cap_q_frac=1.0,
                        cap_kv_frac=1.0, **kw)


@pytest.fixture(scope="module")
def served():
    """Shared model + a mixed request workload + the sequential oracle."""
    cfg = get_smoke("flux-mmdit")
    ecfg = _ecfg()
    params = dit.init_params(cfg, jax.random.PRNGKey(0))

    def mk(i, steps, schedule=None):
        kx, kt = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(100), i))
        return Request(
            rid=i,
            x0=jax.random.normal(kx, (1, 64, cfg.patch_dim)),
            text_emb=jax.random.normal(
                kt, (1, cfg.n_text_tokens, cfg.d_model)),
            num_steps=steps, schedule=schedule)

    # Mixed lengths (8 / 6 / 4 steps) AND mixed schedules: two plain
    # flashomni requests (stackable), two step-ramp, one short straggler.
    reqs = [mk(0, 8), mk(1, 6, "step-ramp"), mk(2, 8),
            mk(3, 6, "step-ramp"), mk(4, 4)]
    seq = run_sequential(params, cfg, ecfg, reqs)
    return cfg, ecfg, params, reqs, seq


def test_stacked_matches_sequential_bitwise(served):
    cfg, ecfg, params, reqs, seq = served
    stk = run_stacked(params, cfg, ecfg, reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            stk[r.rid]["out"], seq[r.rid]["out"],
            err_msg=f"stacked lane {r.rid} diverged from sequential")


def test_continuous_bit_parity_and_single_executable(served):
    """Lanes retire and refill across mixed-length/mixed-schedule requests
    with ONE compiled tick executable, and every request's output is
    bit-identical to its sequential run."""
    cfg, ecfg, params, reqs, seq = served
    bat = ContinuousBatcher(params, cfg, ecfg, lanes=3, max_steps=8)
    bat.submit_all(reqs)
    results = bat.run()
    for r in reqs:
        np.testing.assert_array_equal(
            results[r.rid]["out"], seq[r.rid]["out"],
            err_msg=f"continuous lane {r.rid} diverged from sequential")
    # 5 requests over 3 lanes forces at least one retire->refill cycle;
    # the tick jit must have compiled exactly once (one lane shape).
    assert bat.stats["executables"] == 1
    assert bat.stats["ticks"] >= 8      # longest schedule's step count
    # Per-lane traces match the sequential sampler's per-step metrics.
    for rid in (0, 1, 4):
        ts, tc = seq[rid]["trace"], results[rid]["trace"]
        assert [t["kind"] for t in ts] == [t["kind"] for t in tc]
        np.testing.assert_allclose(
            [t["density"] for t in tc], [t["density"] for t in ts],
            atol=1e-7, rtol=1e-7)


def test_continuous_empty_lanes_zero_metrics(served):
    """Lanes with no resident request (width > live requests) must run the
    idle branch: zero density / pair-sparsity contribution."""
    cfg, ecfg, params, reqs, seq = served
    bat = ContinuousBatcher(params, cfg, ecfg, lanes=4, max_steps=8)
    bat.submit_all([reqs[0], reqs[4]])   # 2 requests over 4 lanes
    results = bat.run()
    np.testing.assert_array_equal(results[reqs[0].rid]["out"],
                                  seq[reqs[0].rid]["out"])
    act = bat.stats["lane_active"]
    dens = bat.stats["lane_density"]
    ps = bat.stats["lane_pair_sparsity"]
    assert (~act).any()                   # idle lanes existed
    assert float(np.abs(dens[~act]).max(initial=0.0)) == 0.0
    assert float(np.abs(ps[~act]).max(initial=0.0)) == 0.0
    # ...and active lanes did report nonzero metrics.
    assert float(np.abs(dens[act]).max(initial=0.0)) > 0.0


def test_request_queue_arrival_order():
    q = RequestQueue()
    mk = lambda rid, at: Request(rid=rid, x0=jnp.zeros((1, 1, 1)),
                                 text_emb=jnp.zeros((1, 1, 1)),
                                 num_steps=1, arrival=at)
    q.submit(mk("late", 5.0))
    q.submit(mk("a", 0.0))
    q.submit(mk("b", 0.0))
    assert len(q) == 3 and q.next_arrival() == 0.0
    assert q.pop_ready(0.0).rid == "a"    # FIFO within equal arrivals
    assert q.pop_ready(0.0).rid == "b"
    assert q.pop_ready(1.0) is None       # "late" not arrived yet
    assert q.pop_ready(5.0).rid == "late"


# ---------------------------------------------------------------------------
# Schedule pad/stack utilities
# ---------------------------------------------------------------------------

def test_stack_schedules_pads_and_remaps():
    ecfg = _ecfg()
    s_plain = resolve_schedule(ecfg, 4, 3)
    s_ramp = resolve_schedule(ecfg, 6, 3, schedule="step-ramp")
    mode, ids, strategies, lengths = stack_schedules([s_plain, s_ramp])
    assert mode.shape == (2, 6) and ids.shape == (2, 6, 3)
    assert lengths == [4, 6]
    # Lane 0 pads steps 4..5 with MODE_IDLE; lane 1 has none.
    assert (mode[0, 4:] == MODE_IDLE).all() and (mode[0, :4] != MODE_IDLE).all()
    assert (mode[1] != MODE_IDLE).all()
    # Ids remap into the merged universe: lane 1's entries address the
    # step-ramp strategies appended after lane 0's single producer.
    uni = merge_strategies([s_plain, s_ramp])
    assert strategies == uni and len(uni) == 4
    assert ids[0].max() == 0 and ids[1].max() == 3
    # Remapped rows still select the SAME strategy objects per step.
    for step in range(6):
        want = s_ramp.strategies[int(np.asarray(s_ramp.strategy_ids)[step, 0])]
        assert uni[ids[1, step, 0]] is want


def test_schedule_lane_rows_validation():
    ecfg = _ecfg()
    s6 = resolve_schedule(ecfg, 6, 2)
    with pytest.raises(ValueError, match="max_steps"):
        schedule_lane_rows(s6, s6.strategies, 4)
    other = resolve_schedule(ecfg, 6, 2, schedule="step-ramp")
    with pytest.raises(ValueError, match="shared lane strategy set"):
        schedule_lane_rows(other, s6.strategies, 6)
    with pytest.raises(ValueError, match="at least one schedule"):
        stack_schedules([])


# ---------------------------------------------------------------------------
# LRU bounds on the serving memos
# ---------------------------------------------------------------------------

def test_lru_cache_bounds_and_counters():
    c = LruCache(2)
    assert c.get("a") is None and c.misses == 1
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1 and c.hits == 1
    c.put("c", 3)                      # evicts "b" (LRU after the "a" hit)
    assert len(c) == 2 and c.evictions == 1
    assert "b" not in c and "a" in c and "c" in c
    assert c.stats()["maxsize"] == 2
    with pytest.raises(ValueError):
        LruCache(0)


def test_sampler_cache_is_bounded_with_stats(served):
    """Cycling > maxsize distinct sampler configurations must not grow the
    cache past its bound, and stats must expose the hit/miss counters."""
    import repro.diffusion.pipeline as pl
    cfg, ecfg, params, reqs, seq = served
    old = pl._SAMPLER_CACHE
    pl._SAMPLER_CACHE = LruCache(2)
    try:
        x0 = reqs[0].x0
        text = reqs[0].text_emb
        stats: dict = {}
        for steps in (3, 4, 5, 3):     # 3 distinct configs through size 2
            sample(params, cfg, ecfg, text_emb=text, x0=x0,
                   scfg=SamplerConfig(num_steps=steps), stats=stats)
        sc = stats["sampler_cache"]
        assert sc["size"] <= 2 and sc["evictions"] >= 1
        # The repeat of steps=3 was evicted in between: 4 misses, 0 hits.
        assert sc["misses"] == 4 and sc["hits"] == 0
        sample(params, cfg, ecfg, text_emb=text, x0=x0,
               scfg=SamplerConfig(num_steps=3), stats=stats)
        assert stats["sampler_cache"]["hits"] == 1
        assert "schedule_cache" in stats
        assert stats["schedule_cache"]["maxsize"] >= 2
    finally:
        pl._SAMPLER_CACHE = old
