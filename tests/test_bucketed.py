"""Occupancy-bucketed CSR attention grid tests (ISSUE 6 acceptance).

  * static bucket geometry: halving widths, equal slot area per bucket,
    rows partition exactly, slot total ≤ 0.5× the uniform grid at B = 3;
  * interpret-mode BIT parity: the bucketed two-level-grid kernel equals
    the uniform CSR kernel fed the same (bucket-truncated) per-row counts
    — the PR-4 shared-truncation invariant extended to buckets, no
    carve-outs — on skewed/bimodal plans including the adversarial one
    full-capacity row among empties;
  * oracle parity: on plans where no bucket truncates, the bucketed
    kernel matches ``masked_block_attention`` within 1e-6;
  * XLA parity: ``XlaBackend`` consumes the bucketed plan's scattered-back
    ``kv_row_cnt`` and agrees with the kernel;
  * strategy emissions (``multi-granularity``, ``hunyuan-1.5x``) run the
    full Update→Dispatch round-trip under ``kv_buckets=3`` on both
    backends, and ``plan_from_state`` rebuilds the bucketed plan fields
    bit-exactly (deterministic Update-time ``lax.sort`` assignment);
  * ``widen()`` round-trips the int16-compacted bucket id fields;
  * serving: two near-miss ``shape_key``s fold into ONE bucketed lane
    partition (≤ 4 executables) with per-request outputs bit-identical to
    sequential runs of the same padded requests, sliced back.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.core import (AttnParams, EngineConfig, MaskConfig, dispatch_layer,
                        init_layer_state, plan_from_state, update_layer)
from repro.core.attention import masked_block_attention
from repro.core.backend import PallasBackend, XlaBackend
from repro.core.masks import MaskConfig
from repro.core.plan import (bucket_geometry, bucket_grid_slots,
                             bucket_slot_layout, build_dispatch_plan)
from repro.launch.batching import ContinuousBatcher, Request, run_sequential
from repro.models import dit

N_TEXT = 64


# ---------------------------------------------------------------------------
# Static geometry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap_q,cap_kv,heads,nb", [
    (8, 8, 4, 3), (8, 16, 4, 3), (16, 32, 8, 3), (5, 7, 3, 3), (8, 16, 4, 2),
])
def test_bucket_geometry_partitions_rows(cap_q, cap_kv, heads, nb):
    geo = bucket_geometry(cap_q, cap_kv, heads, nb)
    rows = [r for r, _ in geo]
    widths = [w for _, w in geo]
    assert sum(rows) == heads * cap_q
    assert all(r >= 1 for r in rows)
    # Halving widths, widest first.
    assert widths == [-(-cap_kv // (1 << i)) for i in range(len(geo))]
    # Per-slot decode arrays cover every slot exactly once, in row order.
    srow, j_of, soff, slast = bucket_slot_layout(geo)
    assert len(srow) == bucket_grid_slots(geo)
    assert int(slast.sum()) == heads * cap_q     # one finalize per row
    np.testing.assert_array_equal(np.sort(np.unique(srow)),
                                  np.arange(heads * cap_q))


def test_bucket_geometry_three_buckets_halve_grid():
    """B = 3 equal-area buckets give a 3/7 ≈ 0.43 slot ratio — the ≥ 2×
    grid-slot cut the ISSUE acceptance requires, by construction."""
    for cap_q, cap_kv, heads in [(8, 8, 4), (8, 16, 4), (16, 64, 8)]:
        geo = bucket_geometry(cap_q, cap_kv, heads, 3)
        assert bucket_grid_slots(geo) * 2 <= heads * cap_q * cap_kv


def test_bucket_geometry_degenerate_single_bucket():
    geo = bucket_geometry(8, 16, 4, 1)
    assert geo == ((32, 16),)
    assert bucket_grid_slots(geo) == 32 * 16


# ---------------------------------------------------------------------------
# Kernel parity on skewed plans
# ---------------------------------------------------------------------------

def _cfgs(kv_buckets=3, **kw):
    mk = dict(pool=32, block_q=16, block_kv=16, interval=4, order=1,
              warmup_steps=1)
    cfg_b = EngineConfig(mask=MaskConfig(**mk), cap_q_frac=1.0,
                         cap_kv_frac=1.0, cache_dtype=jnp.float32,
                         kv_buckets=kv_buckets, **kw)
    cfg_u = dataclasses.replace(cfg_b, kv_buckets=1)
    return cfg_b, cfg_u


def _qkvo(seed, b, h, n, dh):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (jax.random.normal(ks[0], (b, h, n, dh)),
            jax.random.normal(ks[1], (b, h, n, dh)),
            jax.random.normal(ks[2], (b, h, n, dh)),
            jax.random.normal(ks[3], (b, h, n, dh)))


def _parity(m_c, m_s, *, seed=0, n=256, dh=32):
    """Bucketed kernel vs uniform kernel (shared truncated counts, BIT
    equal) vs XLA on the bucketed plan (allclose)."""
    b, h, t = m_c.shape
    cfg_b, cfg_u = _cfgs()
    q, k, v, o_reuse = _qkvo(seed, b, h, n, dh)
    plan_b = build_dispatch_plan(m_c, m_s, cfg_b, n)
    plan_u = build_dispatch_plan(m_c, m_s, cfg_u, n)
    spec_b, spec_u = cfg_b.caps(n), cfg_u.caps(n)
    pb = PallasBackend(interpret=True)
    out_bkt = pb.attention(q, k, v, o_reuse, plan_b, spec_b)
    # Same truncated per-row counts through the UNIFORM kernel: the
    # shared-truncation invariant makes the two layouts bit-identical.
    out_uni = pb.attention(q, k, v, o_reuse,
                           plan_u._replace(kv_row_cnt=plan_b.kv_row_cnt),
                           spec_u)
    np.testing.assert_array_equal(np.asarray(out_bkt), np.asarray(out_uni))
    out_xla = XlaBackend().attention(q, k, v, o_reuse, plan_b, spec_b)
    np.testing.assert_allclose(np.asarray(out_bkt), np.asarray(out_xla),
                               atol=2e-5, rtol=2e-5)
    return out_bkt, plan_b, plan_u


def test_bucketed_bimodal_across_heads_bit_parity():
    """Hunyuan-like skew: two dense heads, two diagonal-only heads."""
    b, h, t = 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    m_c = jax.random.bernoulli(ks[0], 0.7, (b, h, t))
    m_s = jax.random.bernoulli(ks[1], 0.8, (b, h, t, t))
    diag = jnp.eye(t, dtype=bool)
    m_s = m_s.at[:, 2:].set(jnp.broadcast_to(diag, (b, 2, t, t)))
    m_s = m_s.at[..., 0].set(True)
    _parity(m_c, m_s, seed=2)


def test_bucketed_adversarial_one_full_row_oracle():
    """One full-capacity row among (near-)empty rows: the single wide row
    must land in the wide bucket — no truncation — so the bucketed kernel
    matches the dense oracle within 1e-6 on top of the bit parity."""
    b, h, t = 1, 4, 8
    diag = jnp.eye(t, dtype=bool)
    m_s = jnp.broadcast_to(diag, (b, h, t, t))
    m_s = m_s.at[0, 1, 3].set(True)            # the one full-width row
    m_s = m_s.at[..., 0].set(True)
    m_c = jnp.ones((b, h, t), bool)
    m_c = m_c.at[0, 0, 4:].set(False)          # plus some cached rows
    out_bkt, plan_b, plan_u = _parity(m_c, m_s, seed=3)
    q, k, v, o_reuse = _qkvo(3, b, h, t * 32, 32)
    # No bucket truncated: the scattered-back counts equal the uniform
    # plan's (block_kv-granularity) per-row counts.
    np.testing.assert_array_equal(np.asarray(plan_b.kv_row_cnt),
                                  np.asarray(plan_u.kv_row_cnt))
    # The masks are pool-granularity (pool = 32); the oracle consumes them
    # at that block size — identical semantics to the kernel's 16-block
    # expansion of the same cells.
    want = masked_block_attention(q, k, v, m_c, m_s, o_reuse,
                                  block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(out_bkt), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def test_bucketed_truncation_is_shared():
    """Overloaded wide rows DO truncate (more full rows than wide slots);
    the truncated counts are scattered back so uniform-kernel and XLA
    parity still hold bit-for-bit / within tolerance."""
    b, h, t = 1, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(9), 1)
    m_s = jax.random.bernoulli(ks[0], 0.9, (b, h, t, t))
    m_s = m_s.at[..., 0].set(True)             # most rows near-full
    m_c = jnp.ones((b, h, t), bool)
    _, plan_b, plan_u = _parity(m_c, m_s, seed=4)
    assert int(jnp.sum(plan_u.kv_row_cnt - plan_b.kv_row_cnt)) > 0, \
        "plan should truncate on this workload"


# ---------------------------------------------------------------------------
# Strategy emissions under kv_buckets: full engine round-trip + rebuild
# ---------------------------------------------------------------------------

def _engine_setup(strategy, backend, kv_buckets=3):
    key = jax.random.PRNGKey(0)
    B, H, N, dm, dh = 1, 4, 256, 64, 32
    cfg = EngineConfig(
        mask=MaskConfig(pool=32, block_q=16, block_kv=16, interval=4,
                        order=1, warmup_steps=1, tau_kv=0.15, tau_q=0.5),
        cap_q_frac=1.0, cap_kv_frac=1.0, cache_dtype=jnp.float32,
        backend=backend, strategy=strategy, kv_buckets=kv_buckets,
        interpret=True if backend == "pallas" else None)
    ks = jax.random.split(key, 8)
    p = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh)) * 0.05,
        wk=jax.random.normal(ks[1], (dm, H * dh)) * 0.05,
        wv=jax.random.normal(ks[2], (dm, H * dh)) * 0.05,
        wo=jax.random.normal(ks[3], (H * dh, dm)) * 0.05,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm))
    state = init_layer_state(B, H, N, dm, dh, cfg)
    return cfg, p, x, state, H, N


@pytest.mark.parametrize("strategy", ["multi-granularity", "hunyuan-1.5x"])
def test_strategy_emissions_bucketed_roundtrip(strategy):
    cfg, p, x, state, H, N = _engine_setup(strategy, "pallas")
    out_u, st = update_layer(p, x, state, cfg, n_text=N_TEXT, heads=H)
    assert st.plan.bkt_head is not None
    x2 = x + 0.01 * jax.random.normal(jax.random.PRNGKey(5), x.shape)
    out_d, st2 = dispatch_layer(p, x2, st, cfg, n_text=N_TEXT, heads=H)
    assert bool(jnp.isfinite(out_d).all())

    # Same strategy + inputs through the XLA backend: dispatch parity.
    cfg_x, px, xx, sx, _, _ = _engine_setup(strategy, "xla")
    _, st_x = update_layer(px, xx, sx, cfg_x, n_text=N_TEXT, heads=H)
    out_x, _ = dispatch_layer(px, x2, st_x, cfg_x, n_text=N_TEXT, heads=H)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_x),
                               atol=2e-5, rtol=2e-5)

    # plan_from_state rebuilds the bucketed fields bit-exactly (the
    # Update-time lax.sort assignment is deterministic, pid tie-broken).
    rebuilt = plan_from_state(st2, cfg, N)
    for a, b in zip(jax.tree.leaves(rebuilt), jax.tree.leaves(st2.plan)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_widen_covers_bucket_fields():
    b, h, t = 1, 4, 8
    m_c = jnp.ones((b, h, t), bool)
    m_s = jnp.broadcast_to(jnp.eye(t, dtype=bool), (b, h, t, t))
    m_s = m_s.at[..., 0].set(True)
    cfg_b, _ = _cfgs()
    plan = build_dispatch_plan(m_c, m_s, cfg_b, t * 32)
    narrow = ("q_ids", "q_slots", "kv_ids", "kv_row_ids", "row_ids",
              "bkt_head", "bkt_q_ids", "bkt_q_src", "bkt_q_slots",
              "bkt_kv_ids")
    for f in narrow:
        assert getattr(plan, f).dtype == jnp.int16, f
    wide = plan.widen()
    for f in narrow:
        assert getattr(wide, f).dtype == jnp.int32, f
        np.testing.assert_array_equal(np.asarray(getattr(wide, f)),
                                      np.asarray(getattr(plan, f)))
    # Idempotent on an already-wide plan.
    assert wide.widen() is wide


# ---------------------------------------------------------------------------
# Shape-bucketed serving lanes
# ---------------------------------------------------------------------------

def _ecfg():
    return EngineConfig(mask=MaskConfig(
        tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.0,
        block_q=16, block_kv=16, pool=16, warmup_steps=2),
        cache_dtype=jnp.float32, cap_q_frac=1.0, cap_kv_frac=1.0)


def _shape_request(cfg, i, nv, steps=6):
    kx, kt = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(100), i))
    return Request(rid=i, x0=jax.random.normal(kx, (1, nv, cfg.patch_dim)),
                   text_emb=jax.random.normal(
                       kt, (1, cfg.n_text_tokens, cfg.d_model)),
                   num_steps=steps)


def test_shape_buckets_fold_near_miss_lanes():
    """N_v ∈ {64, 48} requests: unbucketed they partition into two lane
    shapes; with ``shape_buckets=(64,)`` they fold into ONE partition
    inside the ≤ 4 executable budget, each request's output bit-identical
    to a sequential run of the same zero-padded request, sliced back."""
    cfg = get_smoke("flux-mmdit")
    ecfg = _ecfg()
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    reqs = [_shape_request(cfg, 0, 64), _shape_request(cfg, 1, 48),
            _shape_request(cfg, 2, 64), _shape_request(cfg, 3, 48)]

    # Baseline: exact shape keys split the queue into two partitions.
    base = ContinuousBatcher(params, cfg, ecfg, lanes=2, max_steps=6)
    base.submit_all(reqs)
    base.run()
    assert base.stats["shape_partitions"] == 2

    bat = ContinuousBatcher(params, cfg, ecfg, lanes=2, max_steps=6,
                            shape_buckets=(64,))
    bat.submit_all(reqs)
    results = bat.run()
    assert bat.stats["shape_partitions"] == 1
    assert 1 <= bat.stats["executables"] <= 4
    # The near-miss key is recorded as folding into the canonical lane.
    folded = {orig[0][1]: canon[0][1]
              for orig, canon in bat.stats["shape_buckets"].items()}
    assert folded == {64: 64, 48: 64}

    # Parity contract: sequential runs of the PADDED requests, sliced
    # back to each request's own N_v.
    padded = [Request(rid=r.rid,
                      x0=jnp.pad(r.x0, ((0, 0), (0, 64 - r.x0.shape[1]),
                                        (0, 0))),
                      text_emb=r.text_emb, num_steps=r.num_steps)
              for r in reqs]
    seq = run_sequential(params, cfg, ecfg, padded)
    for r in reqs:
        np.testing.assert_array_equal(
            np.asarray(results[r.rid]["out"]),
            np.asarray(seq[r.rid]["out"][:, :r.x0.shape[1]]),
            err_msg=f"bucketed lane {r.rid} diverged from padded sequential")
