"""Occupancy-bucketed sparse GEMMs + plan-calibrated autotuner (ISSUE 8).

  * GEMM-O BIT parity: the bucketed two-level-grid kernel equals the
    uniform kernel on the SAME plan — ``gmo_layout`` folds any
    bucket-induced head clamp back into ``head_cnt``/``head_mask`` before
    extraction, so there is nothing left to diverge (no carve-outs) — on
    skewed plans including the adversarial one-full-row-among-empties;
  * padded-slot no-store: fully-cached rows keep their bias-aliased
    forecast value bit-exactly under both grids;
  * XLA parity: ``XlaBackend.gemm_o`` consumes the clamp-folded
    ``head_mask`` and agrees with both kernels within float tolerance;
  * GEMM-Q occupancy guard: the ``row_cnt`` scalar-prefetch guard leaves
    live slots bit-identical to the unguarded kernel and writes
    deterministic zeros into padding slots (the S_c early-exit analogue —
    GEMM-Q has no reduction occupancy to bucket);
  * plan plumbing: ``plan_from_state`` rebuilds ``occ_hist``/``gmo_*``
    bit-exactly; the int16 compaction covers the new id fields and
    ``widen()`` round-trips them;
  * autotuner: schema validation failure modes, the no-calibration → 1
    (uniform) fallback, selection determinism, and the one-executable-
    per-configuration budget (``kv_buckets = 0`` auto resolves purely
    from static config, and a mesh forces uniform).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, plan_from_state, update_layer
from repro.core.backend import PallasBackend, XlaBackend
from repro.core.masks import MaskConfig
from repro.core.plan import (OCC_BINS, build_dispatch_plan,
                             occupancy_histogram)
from repro.kernels import ops
from repro.kernels.tuning import (CANDIDATE_BUCKETS, bucket_clamp_frac,
                                  bucket_slot_frac, kernel_tiles, load_table,
                                  select_kv_buckets, validate_table)

N_TEXT = 64


def _cfgs(kv_buckets=3, **kw):
    mk = dict(pool=32, block_q=16, block_kv=16, interval=4, order=1,
              warmup_steps=1)
    cfg_b = EngineConfig(mask=MaskConfig(**mk), cap_q_frac=1.0,
                         cap_kv_frac=1.0, cache_dtype=jnp.float32,
                         kv_buckets=kv_buckets, **kw)
    cfg_u = dataclasses.replace(cfg_b, kv_buckets=1)
    return cfg_b, cfg_u


def _gemm_o_inputs(seed, b, h, n, dh=32, f=64):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    o_tok = jax.random.normal(ks[0], (b, n, h, dh))
    w = jax.random.normal(ks[1], (h, dh, f))
    bias = jax.random.normal(ks[2], (b, n, f))
    return o_tok, w, bias


def _gemm_o_parity(m_c, m_s, *, seed=0, n=256):
    """Bucketed vs uniform Pallas GEMM-O on the same bucketed plan (BIT
    equal) vs XLA (allclose).  Returns the bucketed output + plan."""
    b, h, t = m_c.shape
    cfg_b, cfg_u = _cfgs()
    plan_b = build_dispatch_plan(m_c, m_s, cfg_b, n)
    plan_u = build_dispatch_plan(m_c, m_s, cfg_u, n)
    spec_b = cfg_b.caps(n)
    assert plan_b.gmo_rows is not None and plan_u.gmo_rows is None
    o_tok, w, bias = _gemm_o_inputs(seed, b, h, n)
    pb = PallasBackend(interpret=True)
    out_bkt = pb.gemm_o(o_tok, w, plan_b, bias, block=cfg_b.mask.pool,
                        spec=spec_b)
    # The SAME plan through the uniform kernel: head_cnt/head_mask already
    # fold the bucket clamp, so the two grids must agree bit-for-bit.
    out_uni = pb.gemm_o(o_tok, w, plan_b, bias, block=cfg_b.mask.pool,
                        spec=None)
    np.testing.assert_array_equal(np.asarray(out_bkt), np.asarray(out_uni))
    out_xla = XlaBackend().gemm_o(o_tok, w, plan_b, bias,
                                  block=cfg_b.mask.pool, spec=spec_b)
    np.testing.assert_allclose(np.asarray(out_bkt), np.asarray(out_xla),
                               atol=2e-5, rtol=2e-5)
    return out_bkt, plan_b, plan_u


def test_gemm_o_bucketed_skewed_bit_parity():
    """One all-heads row among single-head rows — the paper's GEMM-O skew."""
    b, h, t = 2, 4, 8
    m_c = jnp.zeros((b, h, t), bool)
    m_c = m_c.at[:, :, 0].set(True)                      # row 0: all heads
    m_c = m_c.at[:, 0, :].set(True)                      # head 0: all rows
    diag = jnp.eye(t, dtype=bool)
    m_s = jnp.broadcast_to(diag, (b, h, t, t)).at[..., 0].set(True)
    _gemm_o_parity(m_c, m_s, seed=1)


def test_gemm_o_adversarial_one_full_row_among_empties():
    """The single wide row must land in the wide bucket (no clamp), the
    near-empty rest in the narrow ones; clamp-free means the plan's
    head_cnt equals the uniform plan's and all three paths agree."""
    b, h, t = 1, 4, 8
    m_c = jnp.zeros((b, h, t), bool)
    m_c = m_c.at[0, :, 3].set(True)                      # the one full row
    m_c = m_c.at[0, 1, :].set(True)                      # one live head rest
    diag = jnp.eye(t, dtype=bool)
    m_s = jnp.broadcast_to(diag, (b, h, t, t)).at[..., 0].set(True)
    _, plan_b, plan_u = _gemm_o_parity(m_c, m_s, seed=2)
    np.testing.assert_array_equal(np.asarray(plan_b.head_cnt),
                                  np.asarray(plan_u.head_cnt))


def test_gemm_o_clamped_rows_stay_bit_consistent():
    """More full-width rows than wide slots: buckets DO clamp head lists.
    The clamp is folded back into head_cnt/head_mask, so bucketed,
    uniform and XLA still agree (the invariant has no carve-outs)."""
    b, h, t = 1, 4, 8
    m_c = jnp.ones((b, h, t), bool)                      # every row all-heads
    diag = jnp.eye(t, dtype=bool)
    m_s = jnp.broadcast_to(diag, (b, h, t, t)).at[..., 0].set(True)
    _, plan_b, plan_u = _gemm_o_parity(m_c, m_s, seed=3)
    assert int(jnp.sum(plan_u.head_cnt - plan_b.head_cnt)) > 0, \
        "plan should clamp head lists on this workload"


def test_gemm_o_padded_slots_keep_bias():
    """Fully-cached row blocks never store: the bias-aliased output keeps
    their forecast value BIT-exactly under both grids."""
    b, h, t, n = 1, 4, 8, 256
    m_c = jnp.zeros((b, h, t), bool)
    m_c = m_c.at[:, :, :2].set(True)                     # rows 2.. cached
    diag = jnp.eye(t, dtype=bool)
    m_s = jnp.broadcast_to(diag, (b, h, t, t)).at[..., 0].set(True)
    out_bkt, plan_b, _ = _gemm_o_parity(m_c, m_s, seed=4)
    o_tok, w, bias = _gemm_o_inputs(4, b, h, n)
    pool = 32
    dead = np.asarray(out_bkt).reshape(b, t, pool, -1)[:, 2:]
    want = np.asarray(bias).reshape(b, t, pool, -1)[:, 2:]
    np.testing.assert_array_equal(dead, want)


def test_gemm_q_guard_matches_unguarded_live_rows():
    """row_cnt guard: live slots bit-identical to the legacy full-compute
    kernel; padding slots deterministic zeros."""
    from repro.kernels.gemm_q import gemm_q_sparse_kernel
    from repro.core.symbols import active_indices
    n, d, f, block = 256, 64, 64, 32
    t = n // block
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    x = jax.random.normal(ks[0], (n, d))
    w = jax.random.normal(ks[1], (d, f))
    mask = jnp.zeros((t,), bool).at[jnp.asarray([0, 3, 5])].set(True)
    ids, cnt = active_indices(mask, t)                   # cap > live count
    guarded = gemm_q_sparse_kernel(x, w, ids, block_rows=block,
                                   row_cnt=cnt, interpret=True)
    legacy = gemm_q_sparse_kernel(x, w, ids, block_rows=block,
                                  interpret=True)        # row_cnt=None
    live = int(cnt)
    np.testing.assert_array_equal(
        np.asarray(guarded)[: live * block], np.asarray(legacy)[: live * block])
    np.testing.assert_array_equal(
        np.asarray(guarded)[live * block:],
        np.zeros_like(np.asarray(guarded)[live * block:]))


# ---------------------------------------------------------------------------
# Plan plumbing: rebuild, compaction, widen
# ---------------------------------------------------------------------------

def _engine_setup(strategy, backend, kv_buckets=3):
    from repro.core import AttnParams, init_layer_state
    key = jax.random.PRNGKey(0)
    B, H, N, dm, dh = 1, 4, 256, 64, 32
    cfg = EngineConfig(
        mask=MaskConfig(pool=32, block_q=16, block_kv=16, interval=4,
                        order=1, warmup_steps=1, tau_kv=0.15, tau_q=0.5),
        cap_q_frac=1.0, cap_kv_frac=1.0, cache_dtype=jnp.float32,
        backend=backend, strategy=strategy, kv_buckets=kv_buckets,
        interpret=True if backend == "pallas" else None)
    ks = jax.random.split(key, 8)
    p = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh)) * 0.05,
        wk=jax.random.normal(ks[1], (dm, H * dh)) * 0.05,
        wv=jax.random.normal(ks[2], (dm, H * dh)) * 0.05,
        wo=jax.random.normal(ks[3], (H * dh, dm)) * 0.05,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm))
    state = init_layer_state(B, H, N, dm, dh, cfg)
    return cfg, p, x, state, H, N


def test_plan_from_state_rebuilds_gmo_fields_bit_exact():
    cfg, p, x, state, H, N = _engine_setup("hunyuan-1.5x", "pallas")
    _, st = update_layer(p, x, state, cfg, n_text=N_TEXT, heads=H)
    assert st.plan.gmo_rows is not None
    assert st.plan.occ_hist is not None
    rebuilt = plan_from_state(st, cfg, N)
    for f in ("occ_hist", "gmo_rows", "gmo_src", "gmo_head_ids",
              "gmo_head_cnt", "head_ids", "head_cnt", "head_mask"):
        a, b = getattr(rebuilt, f), getattr(st.plan, f)
        assert a.dtype == b.dtype, f
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)


def test_int16_compaction_covers_gmo_and_head_ids():
    b, h, t = 1, 4, 8
    m_c = jnp.ones((b, h, t), bool)
    m_s = jnp.broadcast_to(jnp.eye(t, dtype=bool), (b, h, t, t))
    m_s = m_s.at[..., 0].set(True)
    cfg_b, _ = _cfgs()
    plan = build_dispatch_plan(m_c, m_s, cfg_b, t * 32)
    narrow = ("head_ids", "gmo_rows", "gmo_src", "gmo_head_ids")
    for f in narrow:
        assert getattr(plan, f).dtype == jnp.int16, f
    assert plan.gmo_head_cnt.dtype == jnp.int32       # a count, not an id
    assert plan.occ_hist.dtype == jnp.int32
    wide = plan.widen()
    for f in narrow:
        assert getattr(wide, f).dtype == jnp.int32, f
        np.testing.assert_array_equal(np.asarray(getattr(wide, f)),
                                      np.asarray(getattr(plan, f)))
    assert wide.widen() is wide


def test_occupancy_histogram_semantics():
    """Class i = fits width ceil(cap/2^(i+1)); dead slots excluded; the
    near-empty tail (incl. zero) lands in the last bin."""
    kv_row_cnt = jnp.asarray([[[16, 8, 4, 1, 0, 7]]], jnp.int32)
    q_cnt = jnp.asarray([[5]], jnp.int32)               # slot 5 (cnt 7) dead
    hist = occupancy_histogram(kv_row_cnt, q_cnt, 16)
    assert hist.shape == (1, OCC_BINS)
    want = np.zeros((1, OCC_BINS), np.int32)
    want[0, 0] = 1      # 16 needs full width
    want[0, 1] = 1      # 8 fits width 8 (dead 7 excluded)
    want[0, 2] = 1      # 4 fits width 4
    want[0, OCC_BINS - 1] = 2                           # 1 and 0 → last bin
    np.testing.assert_array_equal(np.asarray(hist), want)
    assert int(hist.sum()) == int(q_cnt.sum())


# ---------------------------------------------------------------------------
# Autotuner: table schema, selection, executable budget
# ---------------------------------------------------------------------------

def test_validate_table_failure_modes():
    ok = load_table()
    validate_table(ok)                                   # checked-in table
    for mutate in [
        lambda t: t.update(version=2),
        lambda t: t.pop("tiles"),
        lambda t: t["tiles"].pop("gemm_q"),
        lambda t: t["tiles"]["gemm_q"].update({"notawidth": {}}),
        lambda t: t["tiles"]["gemm_q"]["default"].update({"block_k": 500}),
        lambda t: t["bucket_model"].update({"max_clamp_frac": 2.0}),
        lambda t: t.update(strategies={"x": {"occ_hist": [-1.0]}}),
    ]:
        bad = {k: ({kk: dict(vv) if isinstance(vv, dict) else vv
                    for kk, vv in v.items()} if isinstance(v, dict) else v)
               for k, v in ok.items()}
        mutate(bad)
        with pytest.raises(ValueError):
            validate_table(bad)


def test_select_kv_buckets_fallback_and_model():
    empty = {"version": 1, "tiles": {k: {"default": {}} for k in
                                     ("gemm_q", "gemm_o", "attention")},
             "bucket_model": {"max_clamp_frac": 0.02}, "strategies": {}}
    # Uncalibrated strategy → uniform grid, never a surprise clamp.
    assert select_kv_buckets("flashomni", empty) == 1
    assert select_kv_buckets("no-such-strategy", empty) == 1
    # All-narrow occupancy → deepest candidate admissible.
    skinny = dict(empty, strategies={"s": {"occ_hist": [0, 0, 0, 1.0]}})
    assert select_kv_buckets("s", skinny) == max(CANDIDATE_BUCKETS)
    # All-wide occupancy → any B > 1 would clamp most rows → uniform.
    wide = dict(empty, strategies={"s": {"occ_hist": [1.0]}})
    assert select_kv_buckets("s", wide) == 1
    # Cost model sanity: slot fraction halves-ish, clamp grows with B.
    assert bucket_slot_frac(1) == 1.0
    assert bucket_slot_frac(3) == pytest.approx(3 / 7)
    assert bucket_clamp_frac([1.0], 3) > bucket_clamp_frac([1.0], 2) > 0
    assert bucket_clamp_frac([0, 0, 1.0], 3) == 0.0


def test_kernel_tiles_defaults_and_width_override():
    table = {"version": 1, "tiles": {
        "gemm_q": {"default": {"block_k": 512, "block_f": 512},
                   "1024": {"block_k": 256}},
        "gemm_o": {"default": {"block_f": 512}},
        "attention": {"default": {}}},
        "bucket_model": {"max_clamp_frac": 0.02}, "strategies": {}}
    assert kernel_tiles("gemm_q", 512, table) == {"block_k": 512,
                                                  "block_f": 512}
    # Width-class override merges over the default.
    assert kernel_tiles("gemm_q", 1024, table) == {"block_k": 256,
                                                   "block_f": 512}
    assert kernel_tiles("attention", None, table) == {}


def test_auto_sentinel_resolves_statically():
    """kv_buckets = 0 resolves from (strategy, table) at spec time: a pure
    function of static config → one configuration, one executable."""
    cfg_a = EngineConfig(mask=MaskConfig(pool=32, block_q=16, block_kv=16),
                         kv_buckets=0, strategy="flashomni")
    b = cfg_a.resolved_kv_buckets()
    assert b in CANDIDATE_BUCKETS
    # Determinism: the same static config resolves to the same spec, so
    # jit caches keyed on the spec stay at one entry per configuration.
    assert cfg_a.caps(256) == cfg_a.caps(256)
    assert cfg_a.caps(256).kv_buckets == b
    # Explicit counts pass through untouched.
    cfg_3 = dataclasses.replace(cfg_a, kv_buckets=3)
    assert cfg_3.resolved_kv_buckets() == 3
    # A mesh forces uniform: seq-sharded dispatch runs per shard.
    cfg_m = dataclasses.replace(cfg_a, mesh_sp=2)
    assert cfg_m.resolved_kv_buckets() == 1
