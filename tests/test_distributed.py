"""Distributed substrate tests: sharding rules, gradient compression
(+error feedback), collective matmul, elastic resharding.

Multi-device cases run in a subprocess with 8 host devices so the main
pytest process keeps the default single CPU device (task spec).  The
collective-matmul subprocess case burns a full interpreter start + 8-device
compile (300 s budget on slow CPU hosts), so it is marked ``slow`` and
skipped unless ``RUN_SLOW_TESTS`` is set."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import compression as C
from repro.distributed.sharding import (DEFAULT_RULES, MULTIPOD_RULES,
                                        ShardingRules, logical_to_physical)
from jax.sharding import PartitionSpec as P


def test_logical_to_physical():
    assert logical_to_physical(("fsdp", "tp"), DEFAULT_RULES) == P("data", "model")
    assert logical_to_physical((None, "tp"), DEFAULT_RULES) == P(None, "model")
    mp = logical_to_physical(("dp", None), MULTIPOD_RULES)
    assert mp == P(("pod", "data"), None)
    r = ShardingRules(sp=("data", "model"))
    assert logical_to_physical(("sp",), r) == P(("data", "model"))


def test_rules_for_cells():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.launch.mesh import rules_for
    cfg = get_config("llama3-405b")
    r = rules_for(cfg, SHAPES["train_4k"], multi_pod=True)
    assert r.fsdp == ("pod", "data")          # ZeRO over pods for 405B
    r2 = rules_for(get_config("gemma3-1b"), SHAPES["long_500k"], multi_pod=False)
    assert r2.dp == () and r2.sp == ("data", "model")
    r3 = rules_for(get_config("hunyuan-video-dit"),
                   SHAPES["decode_32k"], multi_pod=False)
    assert r3.sp == ("data",)                 # DiT sequence parallelism


def test_int8_compression_error_feedback():
    """Error feedback: compressed-SGD averages converge to the true mean."""
    g = jnp.asarray(np.random.default_rng(0).standard_normal((256,)) * 3)
    err = jnp.zeros_like(g)
    total_true, total_comp = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        comp, err = C.compress_int8(g, err)
        total_comp += C.decompress_int8(comp)
        total_true += g
    # with error feedback the ACCUMULATED compressed signal tracks the truth
    rel = float(jnp.linalg.norm(total_comp - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 1e-2, rel


def test_topk_compression_error_feedback():
    g = jnp.asarray(np.random.default_rng(1).standard_normal((512,)))
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(60):
        comp, err = C.compress_topk(g, err, frac=0.1)
        acc += C.decompress_topk(comp)
    rel = float(jnp.linalg.norm(acc / 60 - g) / jnp.linalg.norm(g))
    assert rel < 0.15, rel     # residual bounded by one step's tail mass


def test_compression_payload_sizes():
    g = jnp.zeros((1024,), jnp.float32)
    comp, _ = C.compress_int8(g, jnp.zeros_like(g))
    assert comp.q.dtype == jnp.int8 and comp.q.size == 1024     # 4x smaller
    compk, _ = C.compress_topk(g, jnp.zeros_like(g), frac=0.05)
    assert compk.values.size == 51                              # ~20x smaller


def test_tree_compress_roundtrip_shapes():
    tree = {"a": jnp.ones((8, 4)), "b": jnp.full((16,), 2.0)}
    err = C.init_error_state(tree)
    comp, err = C.compress_tree(tree, err, "int8")
    out = C.decompress_tree(comp)
    assert out["a"].shape == (8, 4) and out["b"].shape == (16,)
    np.testing.assert_allclose(np.asarray(out["b"]), 2.0, rtol=0.02)


_SUBPROC_COLLECTIVE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collective_matmul import ag_matmul_overlapped
    mesh = jax.make_mesh((8,), ("x",))
    B, S, D, F = 2, 32, 16, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (D, F))
    y = ag_matmul_overlapped(x, w, mesh, "x")
    want = jnp.einsum("bsd,df->bsf", x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)
    print("COLLECTIVE_OK")
""")


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("RUN_SLOW_TESTS"),
                    reason="300 s subprocess budget times out slow CPU "
                           "hosts; opt in with RUN_SLOW_TESTS=1")
def test_collective_matmul_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC_COLLECTIVE],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # Host-device simulation: force the CPU
                            # platform so a baked-in libtpu cannot
                            # hang TPU discovery in the clean env.
                            "JAX_PLATFORMS": "cpu"})
    assert "COLLECTIVE_OK" in r.stdout, r.stdout + r.stderr


def _run_sub(code: str, sentinel: str, timeout: int = 600):
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=timeout,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert sentinel in r.stdout, r.stdout + r.stderr


_MESH_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.engine import (EngineConfig, AttnParams, init_layer_state,
                                   update_layer, dispatch_layer)
    from repro.core.masks import MaskConfig
    m = MaskConfig(tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.3,
                   block_q=16, block_kv=16, pool=32, warmup_steps=2)
    B, H, n, dm, dh = 2, 4, 256, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 8)
    params = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H*dh)) * 0.05,
        wk=jax.random.normal(ks[1], (dm, H*dh)) * 0.05,
        wv=jax.random.normal(ks[2], (dm, H*dh)) * 0.05,
        wo=jax.random.normal(ks[3], (H*dh, dm)) * 0.05,
        q_scale=jnp.ones((dh,)), k_scale=jnp.ones((dh,)))
    x = jax.random.normal(ks[4], (B, n, dm), jnp.float32)
""")

# Tentpole acceptance: 8-device sharded dispatch is BIT-identical to the
# single-device oracle (same state, mesh_dp=mesh_sp=1) for every tested
# strategy x kv_buckets combination.  One subprocess per backend keeps
# each under the interpreter+compile budget.
_MESH_PARITY = _MESH_PRELUDE + textwrap.dedent("""
    backend = {backend!r}
    for strat in ("flashomni", "hunyuan-1.5x", "multi-granularity"):
        for kvb in (1, 3):
            cfgm = EngineConfig(mask=m, backend=backend, strategy=strat,
                                kv_buckets=kvb, mesh_dp=2, mesh_sp=4)
            cfg1 = dataclasses.replace(cfgm, mesh_dp=1, mesh_sp=1)
            st0 = init_layer_state(B, H, n, dm, dh, cfgm)
            _, st = update_layer(params, x, st0, cfgm, heads=H)
            om, _ = dispatch_layer(params, x, st, cfgm, heads=H)
            o1, _ = dispatch_layer(params, x, st, cfg1, heads=H)
            assert (np.asarray(om) == np.asarray(o1)).all(), (strat, kvb)
    print("MESH_PARITY_OK")
""")


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_mesh_dispatch_bit_parity_subprocess(backend):
    _run_sub(_MESH_PARITY.format(backend=backend), "MESH_PARITY_OK")


# Head mode: Pallas parity is bitwise (the kernel's flash accumulation
# order per (b, h) grid cell is shape-independent); XLA is allclose only —
# shrinking the head batch lets the compiler reassociate its reductions
# (observed max |delta| ~ 2e-8).  See distributed/plan_shard docstring.
_MESH_HEAD = _MESH_PRELUDE + textwrap.dedent("""
    for backend, bitwise in (("pallas", True), ("xla", False)):
        cfgm = EngineConfig(mask=m, backend=backend, mesh_dp=2, mesh_sp=4,
                            mesh_axis="head")
        cfg1 = dataclasses.replace(cfgm, mesh_dp=1, mesh_sp=1)
        st0 = init_layer_state(B, H, n, dm, dh, cfgm)
        _, st = update_layer(params, x, st0, cfgm, heads=H)
        om, _ = dispatch_layer(params, x, st, cfgm, heads=H)
        o1, _ = dispatch_layer(params, x, st, cfg1, heads=H)
        a, b = np.asarray(om), np.asarray(o1)
        if bitwise:
            assert (a == b).all(), backend
        else:
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    print("MESH_HEAD_OK")
""")


def test_mesh_head_mode_parity_subprocess():
    _run_sub(_MESH_HEAD, "MESH_HEAD_OK")


# Executable budget: repeated Dispatch at one (mesh shape, plan shape)
# reuses ONE executable (make_engine_mesh is cached, so mesh identity is
# stable across traces); a different mesh shape adds exactly one more.
_MESH_BUDGET = _MESH_PRELUDE + textwrap.dedent("""
    import functools
    @functools.partial(jax.jit, static_argnames=("cfg", "heads"))
    def step(params, x, st, cfg, heads):
        o, _ = dispatch_layer(params, x, st, cfg, heads=heads)
        return o
    def run(cfg):
        st0 = init_layer_state(B, H, n, dm, dh, cfg)
        _, st = update_layer(params, x, st0, cfg, heads=H)
        for _ in range(3):
            step(params, x, st, cfg, H).block_until_ready()
    cfg_a = EngineConfig(mask=m, backend="xla", mesh_dp=2, mesh_sp=4)
    run(cfg_a)
    assert step._cache_size() == 1, step._cache_size()
    run(cfg_a)                       # fresh state, same shapes: no retrace
    assert step._cache_size() == 1, step._cache_size()
    run(dataclasses.replace(cfg_a, mesh_dp=1, mesh_sp=2))
    assert step._cache_size() == 2, step._cache_size()
    print("MESH_BUDGET_OK")
""")


def test_mesh_executable_budget_subprocess():
    _run_sub(_MESH_BUDGET, "MESH_BUDGET_OK")


_SUBPROC_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.runtime.elastic import shrink_mesh, reshard_state
    from repro.distributed.sharding import ShardingRules
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = ShardingRules()
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    spec = {"w": ("fsdp", "tp")}
    sharded = reshard_state(state, spec, mesh, rules)
    small = shrink_mesh(mesh, drop_data_rows=1)
    assert small.devices.shape == (2, 2)
    out = reshard_state(sharded, spec, small, rules)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
    print("ELASTIC_OK")
""")


def test_elastic_reshard_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC_ELASTIC],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # Host-device simulation: force the CPU
                            # platform so a baked-in libtpu cannot
                            # hang TPU discovery in the clean env.
                            "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
