"""Distributed substrate tests: sharding rules, gradient compression
(+error feedback), collective matmul, elastic resharding.

Multi-device cases run in a subprocess with 8 host devices so the main
pytest process keeps the default single CPU device (task spec).  The
collective-matmul subprocess case burns a full interpreter start + 8-device
compile (300 s budget on slow CPU hosts), so it is marked ``slow`` and
skipped unless ``RUN_SLOW_TESTS`` is set."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import compression as C
from repro.distributed.sharding import (DEFAULT_RULES, MULTIPOD_RULES,
                                        ShardingRules, logical_to_physical)
from jax.sharding import PartitionSpec as P


def test_logical_to_physical():
    assert logical_to_physical(("fsdp", "tp"), DEFAULT_RULES) == P("data", "model")
    assert logical_to_physical((None, "tp"), DEFAULT_RULES) == P(None, "model")
    mp = logical_to_physical(("dp", None), MULTIPOD_RULES)
    assert mp == P(("pod", "data"), None)
    r = ShardingRules(sp=("data", "model"))
    assert logical_to_physical(("sp",), r) == P(("data", "model"))


def test_rules_for_cells():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.launch.mesh import rules_for
    cfg = get_config("llama3-405b")
    r = rules_for(cfg, SHAPES["train_4k"], multi_pod=True)
    assert r.fsdp == ("pod", "data")          # ZeRO over pods for 405B
    r2 = rules_for(get_config("gemma3-1b"), SHAPES["long_500k"], multi_pod=False)
    assert r2.dp == () and r2.sp == ("data", "model")
    r3 = rules_for(get_config("hunyuan-video-dit"),
                   SHAPES["decode_32k"], multi_pod=False)
    assert r3.sp == ("data",)                 # DiT sequence parallelism


def test_int8_compression_error_feedback():
    """Error feedback: compressed-SGD averages converge to the true mean."""
    g = jnp.asarray(np.random.default_rng(0).standard_normal((256,)) * 3)
    err = jnp.zeros_like(g)
    total_true, total_comp = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        comp, err = C.compress_int8(g, err)
        total_comp += C.decompress_int8(comp)
        total_true += g
    # with error feedback the ACCUMULATED compressed signal tracks the truth
    rel = float(jnp.linalg.norm(total_comp - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 1e-2, rel


def test_topk_compression_error_feedback():
    g = jnp.asarray(np.random.default_rng(1).standard_normal((512,)))
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(60):
        comp, err = C.compress_topk(g, err, frac=0.1)
        acc += C.decompress_topk(comp)
    rel = float(jnp.linalg.norm(acc / 60 - g) / jnp.linalg.norm(g))
    assert rel < 0.15, rel     # residual bounded by one step's tail mass


def test_compression_payload_sizes():
    g = jnp.zeros((1024,), jnp.float32)
    comp, _ = C.compress_int8(g, jnp.zeros_like(g))
    assert comp.q.dtype == jnp.int8 and comp.q.size == 1024     # 4x smaller
    compk, _ = C.compress_topk(g, jnp.zeros_like(g), frac=0.05)
    assert compk.values.size == 51                              # ~20x smaller


def test_tree_compress_roundtrip_shapes():
    tree = {"a": jnp.ones((8, 4)), "b": jnp.full((16,), 2.0)}
    err = C.init_error_state(tree)
    comp, err = C.compress_tree(tree, err, "int8")
    out = C.decompress_tree(comp)
    assert out["a"].shape == (8, 4) and out["b"].shape == (16,)
    np.testing.assert_allclose(np.asarray(out["b"]), 2.0, rtol=0.02)


_SUBPROC_COLLECTIVE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collective_matmul import ag_matmul_overlapped
    mesh = jax.make_mesh((8,), ("x",))
    B, S, D, F = 2, 32, 16, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (D, F))
    y = ag_matmul_overlapped(x, w, mesh, "x")
    want = jnp.einsum("bsd,df->bsf", x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)
    print("COLLECTIVE_OK")
""")


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("RUN_SLOW_TESTS"),
                    reason="300 s subprocess budget times out slow CPU "
                           "hosts; opt in with RUN_SLOW_TESTS=1")
def test_collective_matmul_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC_COLLECTIVE],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # Host-device simulation: force the CPU
                            # platform so a baked-in libtpu cannot
                            # hang TPU discovery in the clean env.
                            "JAX_PLATFORMS": "cpu"})
    assert "COLLECTIVE_OK" in r.stdout, r.stdout + r.stderr


_SUBPROC_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.runtime.elastic import shrink_mesh, reshard_state
    from repro.distributed.sharding import ShardingRules
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = ShardingRules()
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    spec = {"w": ("fsdp", "tp")}
    sharded = reshard_state(state, spec, mesh, rules)
    small = shrink_mesh(mesh, drop_data_rows=1)
    assert small.devices.shape == (2, 2)
    out = reshard_state(sharded, spec, small, rules)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
    print("ELASTIC_OK")
""")


def test_elastic_reshard_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC_ELASTIC],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # Host-device simulation: force the CPU
                            # platform so a baked-in libtpu cannot
                            # hang TPU discovery in the clean env.
                            "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
