"""Update–Dispatch engine invariants (paper §3.2/§3.5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AttnParams, EngineConfig, MaskConfig, dispatch_layer,
                        init_layer_state, is_update_step, update_layer)


def _setup(mode="bias", tau_kv=0.0, capq=1.0, capkv=1.0, order=1, dtype=jnp.float32):
    key = jax.random.PRNGKey(0)
    B, H, N, dm, dh = 1, 2, 256, 64, 32
    cfg = EngineConfig(
        mask=MaskConfig(pool=32, block_q=16, block_kv=16, interval=4,
                        order=order, warmup_steps=1, tau_kv=tau_kv, tau_q=0.5),
        cache_mode=mode, cap_q_frac=capq, cap_kv_frac=capkv,
        cache_dtype=jnp.float32)
    ks = jax.random.split(key, 8)
    p = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh), dtype) * 0.05,
        wk=jax.random.normal(ks[1], (dm, H * dh), dtype) * 0.05,
        wv=jax.random.normal(ks[2], (dm, H * dh), dtype) * 0.05,
        wo=jax.random.normal(ks[3], (H * dh, dm), dtype) * 0.05,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm), dtype)
    state = init_layer_state(B, H, N, dm, dh, cfg)
    return cfg, p, x, state, H


@pytest.mark.parametrize("mode", ["bias", "o_cache"])
def test_dispatch_exact_when_no_skipping(mode):
    """τ_kv=0, full caps, unchanged input -> dispatch == update exactly."""
    cfg, p, x, state, H = _setup(mode)
    out_u, state = update_layer(p, x, state, cfg, n_text=64, heads=H)
    out_d, state = dispatch_layer(p, x, state, cfg, n_text=64, heads=H)
    err = float(jnp.linalg.norm(out_d - out_u) / jnp.linalg.norm(out_u))
    assert err < 1e-5, err


@pytest.mark.parametrize("mode", ["bias", "o_cache"])
def test_dispatch_error_bounded_with_skipping(mode):
    cfg, p, x, state, H = _setup(mode, tau_kv=0.15, capq=0.75, capkv=0.9)
    out_u, state = update_layer(p, x, state, cfg, n_text=64, heads=H)
    out_d, state = dispatch_layer(p, x, state, cfg, n_text=64, heads=H)
    err = float(jnp.linalg.norm(out_d - out_u) / jnp.linalg.norm(out_u))
    assert np.isfinite(err) and err < 0.6


def test_bias_equals_ocache_semantics():
    """Eq. 4: forecasting in projected space == projecting the forecast."""
    cfg_b, p, x, st_b, H = _setup("bias", tau_kv=0.0)
    cfg_o, _, _, st_o, _ = _setup("o_cache", tau_kv=0.0)
    u_b, st_b = update_layer(p, x, st_b, cfg_b, n_text=64, heads=H)
    u_o, st_o = update_layer(p, x, st_o, cfg_o, n_text=64, heads=H)
    d_b, _ = dispatch_layer(p, x, st_b, cfg_b, n_text=64, heads=H)
    d_o, _ = dispatch_layer(p, x, st_o, cfg_o, n_text=64, heads=H)
    np.testing.assert_allclose(np.asarray(d_b), np.asarray(d_o), atol=1e-4)


def test_multi_step_dispatch_chain():
    """N-1 dispatches after an update: k_since increments, outputs finite,
    drift grows smoothly as the input evolves."""
    cfg, p, x, state, H = _setup("bias", tau_kv=0.1, capq=0.9, capkv=1.0)
    out, state = update_layer(p, x, state, cfg, n_text=64, heads=H)
    errs = []
    for k in range(1, 4):
        x = x + 0.01 * jax.random.normal(jax.random.PRNGKey(k), x.shape)
        ref_out, _ = update_layer(p, x, init_layer_state(1, H, 256, 64, 32, cfg),
                                  cfg, n_text=64, heads=H)
        out, state = dispatch_layer(p, x, state, cfg, n_text=64, heads=H)
        assert int(state.k_since) == k
        errs.append(float(jnp.linalg.norm(out - ref_out) /
                          jnp.linalg.norm(ref_out)))
    assert all(np.isfinite(errs))


def test_update_dispatch_schedule():
    cfg = EngineConfig(mask=MaskConfig(interval=5, warmup_steps=3))
    kinds = ["U" if is_update_step(s, cfg) else "D" for s in range(14)]
    assert kinds == list("UUU") + list("UDDDD") * 2 + ["U"]


def test_symbols_refresh_only_on_update():
    cfg, p, x, state, H = _setup("bias", tau_kv=0.1)
    _, s1 = update_layer(p, x, state, cfg, n_text=64, heads=H)
    _, s2 = dispatch_layer(p, x, s1, cfg, n_text=64, heads=H)
    assert (s1.s_c == s2.s_c).all() and (s1.s_s == s2.s_s).all()
    x2 = x + jax.random.normal(jax.random.PRNGKey(9), x.shape)
    _, s3 = update_layer(p, x2, s2, cfg, n_text=64, heads=H)
    assert not bool((s3.s_c == s2.s_c).all())     # new input -> new symbols
