"""Checkpointing + fault tolerance: atomic publish, async save, retention,
injected node failures with bit-exact resume, straggler detection."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import (FailureInjector, NodeFailure,
                                           RestartableLoop, StepWatchdog)


def test_checkpoint_roundtrip(tmp_path):
    ckpt = Checkpointer(tmp_path, keep=2)
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,))}}
    ckpt.save(7, tree, blocking=True)
    step, restored = ckpt.restore_latest(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_retention_and_async(tmp_path):
    ckpt = Checkpointer(tmp_path, keep=2)
    tree = {"x": jnp.zeros((4,))}
    for s in [1, 2, 3, 4]:
        ckpt.save(s, jax.tree.map(lambda a: a + s, tree))
    ckpt.wait()
    assert ckpt.steps() == [3, 4]                      # retention
    _, restored = ckpt.restore_latest(tree)
    np.testing.assert_allclose(np.asarray(restored["x"]), 4.0)


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir (simulated crash mid-write) is never visible to readers."""
    ckpt = Checkpointer(tmp_path, keep=3)
    (tmp_path / "step_9.tmp").mkdir()
    (tmp_path / "step_9.tmp" / "garbage").write_text("crash")
    assert ckpt.steps() == []
    assert ckpt.restore_latest({"x": jnp.zeros(1)}) == (None, None)


def _counter_loop(tmp_path, fail_at=(), total=25, ckpt_every=5):
    """state = counter array; step_fn adds the step index (deterministic)."""
    ckpt = Checkpointer(tmp_path, keep=3)
    loop = RestartableLoop(ckpt, ckpt_every=ckpt_every)

    def step_fn(state, step):
        return state + step, {"v": float(state.sum())}

    injector = FailureInjector(fail_at)
    return loop.run(jnp.zeros((2,)), step_fn, total, injector=injector)


def test_restart_recovers_exact_state(tmp_path):
    state_fail, res_fail = _counter_loop(tmp_path / "a", fail_at=(12, 18))
    state_ok, res_ok = _counter_loop(tmp_path / "b", fail_at=())
    np.testing.assert_array_equal(np.asarray(state_fail), np.asarray(state_ok))
    assert res_fail.restarts == 2
    assert res_fail.final_step == res_ok.final_step == 25


def test_restart_budget_exhausted(tmp_path):
    ckpt = Checkpointer(tmp_path, keep=3)
    loop = RestartableLoop(ckpt, ckpt_every=100, max_restarts=2)
    injector = FailureInjector((3,))
    injector.fired = set()                              # refire every time

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            if step == 3:
                raise NodeFailure("persistent failure")

    with pytest.raises(NodeFailure):
        loop.run(jnp.zeros(1), lambda s, i: (s, {}), 10, injector=AlwaysFail())


def test_straggler_detection():
    wd = StepWatchdog(window=16, straggler_factor=3.0)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 0.5) is True
    assert wd.observe(11, 0.12) is False
    assert wd.stragglers and wd.stragglers[0][0] == 10


def test_train_loop_end_to_end_with_failures(tmp_path):
    """Real model + optimizer through the restartable loop with failures:
    final loss matches the uninterrupted run (deterministic data stream)."""
    from repro.launch.train import train
    _, res_f = train("gemma3-1b", smoke=True, steps=12, batch=2, seq_len=32,
                     ckpt_dir=str(tmp_path / "f"), fail_at=(7,), ckpt_every=4)
    _, res_o = train("gemma3-1b", smoke=True, steps=12, batch=2, seq_len=32,
                     ckpt_dir=str(tmp_path / "o"), ckpt_every=4)
    assert res_f.restarts == 1
    f_loss = [m["loss"] for m in res_f.metrics if m["step"] == 11][-1]
    o_loss = [m["loss"] for m in res_o.metrics if m["step"] == 11][-1]
    np.testing.assert_allclose(f_loss, o_loss, rtol=1e-5)
