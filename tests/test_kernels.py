"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU).

Per task spec: sweep shapes/dtypes per kernel, assert_allclose vs ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.symbols import active_indices
from repro.kernels import ops, ref


def _attn_inputs(key, bh, n, d, bq, bk, p_c=0.6, p_s=0.7, dtype=jnp.float32):
    tq, tkv = n // bq, n // bk
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    q = jax.random.normal(ks[0], (bh, n, d), dtype)
    k = jax.random.normal(ks[1], (bh, n, d), dtype)
    v = jax.random.normal(ks[2], (bh, n, d), dtype)
    o_reuse = jax.random.normal(ks[3], (bh, n, d), dtype)
    m_c = jax.random.bernoulli(ks[4], p_c, (bh, tq))
    m_s = jax.random.bernoulli(ks[5], p_s, (bh, tq, tkv)).at[..., 0].set(True)
    return q, k, v, m_c, m_s, o_reuse


ATTN_SWEEP = [
    # (BH, N, d, bq, bk, dtype, tol)
    (2, 128, 32, 16, 16, jnp.float32, 2e-5),
    (1, 256, 64, 32, 16, jnp.float32, 2e-5),
    (3, 256, 128, 64, 64, jnp.float32, 2e-5),
    (2, 128, 64, 16, 32, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("variant", ["csr", "symbols"])
@pytest.mark.parametrize("bh,n,d,bq,bk,dtype,tol", ATTN_SWEEP)
def test_flashomni_attention_vs_ref(variant, bh, n, d, bq, bk, dtype, tol):
    q, k, v, m_c, m_s, o_reuse = _attn_inputs(bh * n, bh, n, d, bq, bk, dtype=dtype)
    want = ref.attention_ref(q, k, v, m_c, m_s, o_reuse, block_q=bq, block_kv=bk)
    got = ops.flashomni_attention(q, k, v, m_c, m_s, o_reuse,
                                  block_q=bq, block_kv=bk, variant=variant)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("variant", ["csr", "symbols"])
def test_attention_all_cached_and_all_live(variant):
    q, k, v, m_c, m_s, o_reuse = _attn_inputs(7, 2, 128, 32, 16, 16)
    for mc in [jnp.zeros_like(m_c), jnp.ones_like(m_c)]:
        want = ref.attention_ref(q, k, v, mc, m_s, o_reuse, block_q=16, block_kv=16)
        got = ops.flashomni_attention(q, k, v, mc, m_s, o_reuse,
                                      block_q=16, block_kv=16, variant=variant)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_attention_csr_with_capacity():
    q, k, v, m_c, m_s, o_reuse = _attn_inputs(9, 2, 256, 32, 32, 32)
    tq = m_c.shape[-1]
    # capacity == max live count across bh -> still exact
    cap = int(m_c.sum(-1).max())
    want = ref.attention_ref(q, k, v, m_c, m_s, o_reuse, block_q=32, block_kv=32)
    got = ops.flashomni_attention(q, k, v, m_c, m_s, o_reuse, block_q=32,
                                  block_kv=32, cap_q=cap, cap_kv=tq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


GEMM_SWEEP = [
    (128, 64, 128, 16, jnp.float32, 1e-4),
    (256, 128, 256, 32, jnp.float32, 1e-4),
    (128, 256, 512, 64, jnp.float32, 1e-4),
    (128, 64, 128, 16, jnp.bfloat16, 5e-2),
]


@pytest.mark.parametrize("n,k,f,blk,dtype,tol", GEMM_SWEEP)
def test_gemm_q_vs_ref(n, k, f, blk, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(n + k), 3)
    x = jax.random.normal(ks[0], (n, k), dtype)
    w = jax.random.normal(ks[1], (k, f), dtype)
    rm = jax.random.bernoulli(ks[2], 0.5, (n // blk,)).at[0].set(True)
    y, ids, cnt = ops.gemm_q(x, w, rm, block_rows=blk, interpret=True)
    want = ref.gemm_q_ref(x, w, ids, cnt, block=blk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("h,n,dh,f,blk,dtype,tol", [
    (4, 128, 32, 64, 16, jnp.float32, 1e-4),
    (8, 256, 64, 128, 32, jnp.float32, 1e-4),
    (2, 128, 128, 256, 64, jnp.float32, 1e-4),
    (4, 128, 64, 64, 16, jnp.bfloat16, 6e-2),
])
def test_gemm_o_vs_ref(h, n, dh, f, blk, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(h * n), 4)
    oh = jax.random.normal(ks[0], (h, n, dh), dtype)
    w = jax.random.normal(ks[1], (h, dh, f), dtype)
    bias = jax.random.normal(ks[2], (n, f), dtype)
    t = n // blk
    m_ch = jax.random.bernoulli(ks[3], 0.6, (t, h))
    got = ops.gemm_o(oh, w, bias, m_ch, block_rows=blk, interpret=True)
    row_ids, row_cnt = active_indices(jnp.any(m_ch, -1), t)
    head_ids, head_cnt = active_indices(jnp.take(m_ch, row_ids, 0), h)
    want = ref.gemm_o_ref(oh, w, bias, row_ids, row_cnt, head_ids, head_cnt, block=blk)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_gemm_o_eq3_identity():
    """Eq. 3: live-head partial + cached-bias == full dense projection."""
    from repro.core.sparse_gemm import gemm_o_update_bias
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h, n, dh, f, blk = 4, 64, 16, 32, 16
    oh = jax.random.normal(ks[0], (h, n, dh))
    w = jax.random.normal(ks[1], (h, dh, f))
    m_ch = jax.random.bernoulli(ks[2], 0.5, (n // blk, h))
    o_tok = oh.transpose(1, 0, 2)[None]                     # (1,N,H,dh)
    bias = gemm_o_update_bias(o_tok, w, m_ch[None], block=blk)[0]
    got = ops.gemm_o(oh, w, bias, m_ch, block_rows=blk, interpret=True)
    want = jnp.einsum("hnd,hdf->nf", oh, w)                 # full projection
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("d1,bh,n,d,blk", [(2, 2, 128, 32, 16), (4, 1, 64, 64, 16)])
def test_taylor_reuse_vs_ref(d1, bh, n, d, blk):
    ks = jax.random.split(jax.random.PRNGKey(d1), 3)
    derivs = jax.random.normal(ks[0], (d1, bh, n, d))
    coef = jax.random.normal(ks[1], (d1,))
    base = jax.random.normal(ks[2], (bh, n, d))
    cmask = jax.random.bernoulli(ks[0], 0.5, (bh, n // blk))
    got = ops.taylor_reuse(derivs, coef, base, cmask, block=blk, interpret=True)
    want_f = ref.taylor_reuse_ref(derivs, coef)
    live = jnp.repeat(cmask, blk, axis=-1)
    want = jnp.where(live[..., None], want_f, base)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
