"""Mask-generation tests (paper §3.3: compressed map, C/G metrics, Eq. 1)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp_compat import given, settings, st

from repro.core import masks as M

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

CFG = M.MaskConfig(tau_q=0.5, tau_kv=0.15, pool=16, block_q=8, block_kv=8)


def _qk(key, n=128, d=16):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    return (jax.random.normal(k1, (2, n, d)), jax.random.normal(k2, (2, n, d)))


def test_compressed_map_rows_normalised():
    q, k = _qk(0)
    p = M.compressed_attention_map(q, k, 16)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, atol=1e-5)


def test_pool_tokens_mean_with_ragged_tail():
    x = jnp.arange(10, dtype=jnp.float32).reshape(1, 10, 1)
    out = M.pool_tokens(x, 4)
    np.testing.assert_allclose(np.asarray(out[0, :, 0]),
                               [1.5, 5.5, 8.5], atol=1e-6)  # tail mean of (8,9)


@given(st.integers(0, 5), st.floats(0.05, 0.95))
def test_select_by_cummass_respects_threshold(seed, tau):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.random((3, 24)) + 1e-3)
    sel = M.select_by_cummass(scores, tau)
    # cumulative mass of the selected set never exceeds tau * total
    mass = (scores * sel).sum(-1)
    assert (np.asarray(mass) <= tau * np.asarray(scores.sum(-1)) + 1e-5).all()
    # and it is the ASCENDING prefix: anything smaller than a selected score
    # must also be selected
    s, m = np.asarray(scores), np.asarray(sel)
    for b in range(s.shape[0]):
        if m[b].any():
            thr = s[b][m[b]].max()
            assert m[b][s[b] < thr].all()


def test_caching_mask_never_caches_text():
    q, k = _qk(1)
    m_c = M.make_caching_mask(q, k, CFG, n_text_tokens=32)
    n_t = 32 // CFG.pool
    assert bool(m_c[..., :n_t].all())            # Observation 1


def test_caching_mask_pure_vision_path():
    q, k = _qk(2)
    m_c = M.make_caching_mask(q, k, CFG, n_text_tokens=0)
    assert m_c.shape[-1] == 8
    assert bool(m_c.any())                       # something stays live


def test_skip_mask_protects_text_regions():
    q, k = _qk(3)
    m_s = M.make_skip_mask(q, k, CFG, n_text_tokens=32)
    n_t = 2
    assert bool(m_s[..., :n_t, :].all())         # text rows full
    assert bool(m_s[..., :, :n_t].all())         # text cols full


def test_skip_mask_static_window_pattern():
    q, k = _qk(4)
    m_s = M.make_skip_mask(q, k, CFG, n_text_tokens=0, tau_kv=0.0, static_window=2)
    t = m_s.shape[-1]
    i, j = np.meshgrid(np.arange(t), np.arange(t), indexing="ij")
    want = np.abs(i - j) < 2
    np.testing.assert_array_equal(np.asarray(m_s[0]), want)


def test_degradation_threshold():
    m = jnp.array([[True] + [False] * 9])        # 10% live < 30% -> all cached
    out = M.apply_degradation(m, 0.3)
    assert not bool(out.any())
    m2 = jnp.array([[True] * 5 + [False] * 5])   # 50% live stays
    assert (M.apply_degradation(m2, 0.3) == m2).all()


def test_expand_block_mask():
    m = jnp.array([[True, False, True]])
    out = M.expand_block_mask(m, 2, 6)
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  [True, True, False, False, True, True])
