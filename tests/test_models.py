"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, asserting output shapes + finiteness (task spec §f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke
from repro.data.synthetic import DataConfig, make_batch
from repro.models.registry import get_model

LM_ARCHS = [a for a in ARCH_IDS if get_config(a).family in
            ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")]
DIT_ARCHS = [a for a in ARCH_IDS if get_config(a).family == "dit"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, DataConfig(batch=2, seq_len=64), 0)
    loss, grads = jax.value_and_grad(
        lambda p: model.train_loss(p, batch, dtype=jnp.float32))(params)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 32, dtype=jnp.float32)
    if cfg.family == "encdec":
        pass  # cross K/V zeros = attends to zero encoder states; still valid
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, jnp.int32(0),
                                       dtype=jnp.float32)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["len"][0]) == 1


@pytest.mark.parametrize("arch", DIT_ARCHS)
def test_smoke_dit_denoise(arch):
    from repro.core.engine import EngineConfig
    from repro.core.masks import MaskConfig
    from repro.models import dit
    cfg = get_smoke(arch)
    ecfg = EngineConfig(mask=MaskConfig(pool=32, block_q=16, block_kv=16,
                                        interval=4, order=1, warmup_steps=1))
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    B, Nv = 2, 96
    states = dit.init_engine_states(cfg, ecfg, B, Nv + cfg.n_text_tokens)
    xv = jax.random.normal(jax.random.PRNGKey(1), (B, Nv, cfg.d_model))
    te = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.n_text_tokens, cfg.d_model))
    t = jnp.full((B,), 0.5)
    for mode in ["update", "dispatch", "dense"]:
        v, states = dit.denoise_step(params, cfg, ecfg, states, xv, te, t,
                                     mode=mode, dtype=jnp.float32)
        assert v.shape == (B, Nv, cfg.patch_dim)
        assert bool(jnp.isfinite(v).all()), mode


@pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-370m", "recurrentgemma-2b",
                                  "whisper-large-v3"])
def test_decode_matches_forward(arch):
    """Greedy decode chain reproduces teacher-forced forward logits."""
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, DataConfig(batch=2, seq_len=48), 0)
    if cfg.family == "encdec":
        from repro.models import encdec
        logits, _ = encdec.forward(params, cfg, batch, dtype=jnp.float32)
        enc_out = encdec.encode(params, cfg, batch["frames"], dtype=jnp.float32)
        cache = model.init_cache(2, 48, dtype=jnp.float32)
        h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        nl = cfg.n_layers
        xk = jnp.stack([(enc_out @ params["dec"]["xattn"]["wk"][i]).reshape(
            2, -1, hkv, hd) for i in range(nl)])
        xv = jnp.stack([(enc_out @ params["dec"]["xattn"]["wv"][i]).reshape(
            2, -1, hkv, hd) for i in range(nl)])
        cache["cross"] = {"k": xk, "v": xv}
    else:
        from repro.models.registry import Model
        logits, _ = model.mod.forward(params, cfg, batch["tokens"], dtype=jnp.float32)
        cache = model.init_cache(2, 48, dtype=jnp.float32)
    toks = batch["tokens"]
    for i in range(6):
        lg, cache = model.decode_step(params, cache, toks[:, i], jnp.int32(i),
                                      dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, 5]),
                               atol=2e-4, rtol=2e-4)


def test_all_configs_resolve_and_report_params():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.n_params()
        assert n > 0
        if arch == "llama3-405b":
            assert 3.5e11 < n < 4.7e11, n
        if arch == "mamba2-370m":
            assert 2.5e8 < n < 5.5e8, n
        if arch == "mixtral-8x22b":
            assert 1.2e11 < n < 1.6e11, n
            assert cfg.n_active_params() < 0.45 * n
