"""End-to-end diffusion pipeline integration: sparse sampling tracks the
dense oracle (the hardware-independent slice of paper Tables 1–3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.core.engine import EngineConfig
from repro.core.masks import MaskConfig
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit


def _psnr(a, b):
    mse = float(jnp.mean(jnp.square(a - b)))
    rng = float(jnp.max(jnp.abs(b))) or 1.0
    return 10 * np.log10(rng * rng / max(mse, 1e-12))


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("flux-mmdit")
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(42)
    B, Nv = 1, 96
    x0 = jax.random.normal(key, (B, Nv, cfg.patch_dim))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (B, cfg.n_text_tokens, cfg.d_model))
    return cfg, params, x0, text


def _ecfg(**kw):
    base = dict(tau_q=0.5, tau_kv=0.0, interval=4, order=1, degrade=0.0,
                block_q=16, block_kv=16, pool=32, warmup_steps=2)
    base.update(kw)
    return EngineConfig(mask=MaskConfig(**base), cache_dtype=jnp.float32)


def test_sparse_sampling_tracks_dense(setup):
    cfg, params, x0, text = setup
    scfg = SamplerConfig(num_steps=10)
    dense = sample(params, cfg, _ecfg(), text_emb=text, x0=x0, scfg=scfg,
                   force_dense=True)
    trace: list = []
    sparse = sample(params, cfg, _ecfg(), text_emb=text, x0=x0, scfg=scfg,
                    trace=trace)
    assert bool(jnp.isfinite(sparse).all())
    psnr = _psnr(sparse, dense)
    assert psnr > 15.0, psnr                      # visually faithful (smoke scale)
    kinds = [t["kind"] for t in trace]
    assert kinds[:2] == ["update", "update"]      # warmup
    assert "dispatch" in kinds


def test_density_drops_after_warmup(setup):
    """Fig. 7: density starts at 1 (warmup) then falls under sparsity."""
    cfg, params, x0, text = setup
    trace: list = []
    sample(params, cfg, _ecfg(tau_q=0.7), text_emb=text, x0=x0,
           scfg=SamplerConfig(num_steps=8), trace=trace)
    late = [t["density"] for t in trace if t["kind"] == "dispatch"]
    # density measures the PLANNED live fraction for the coming dispatches;
    # with sparsity on it sits strictly below 1 (Fig. 7 shape).
    assert late and min(late) < 1.0


def test_more_aggressive_interval_is_less_faithful(setup):
    """Table 3 ablation direction: larger 𝒩 -> lower fidelity."""
    cfg, params, x0, text = setup
    scfg = SamplerConfig(num_steps=12)
    dense = sample(params, cfg, _ecfg(), text_emb=text, x0=x0, scfg=scfg,
                   force_dense=True)
    psnrs = {}
    for interval in [2, 6]:
        out = sample(params, cfg, _ecfg(interval=interval, tau_q=0.6),
                     text_emb=text, x0=x0, scfg=scfg)
        psnrs[interval] = _psnr(out, dense)
    assert psnrs[2] >= psnrs[6] - 1.0, psnrs      # small slack for noise
