"""Plan-sharded mesh dispatch: partition invariants, single device.

Everything here runs on ONE CPU device — :func:`partition_plan` and
:func:`mesh_keep_rows` are pure jnp and execute at Update time regardless
of the mesh, so the per-shard CSR partition and the collective schedule
tables can be checked without any forced-device subprocess.  The
end-to-end 8-device bit-parity cases live in ``tests/test_distributed.py``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platforms", "cpu")

from repro.core.engine import (AttnParams, EngineConfig, init_layer_state,
                               plan_from_state, update_layer)
from repro.core.masks import MaskConfig
from repro.core.plan import build_dispatch_plan
from repro.distributed.plan_shard import (ShardGeometry, dense_exchange_blocks,
                                          exchange_blocks, mesh_attention,
                                          shard_geometry)

MASK = MaskConfig(tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.3,
                  block_q=16, block_kv=16, pool=32, warmup_steps=2)
B, H, N, DM, DH = 2, 4, 256, 64, 16


def _masks(key=0, b=B, h=H, n=N):
    t = MASK.n_blocks(n)
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    m_c = jax.random.bernoulli(ks[0], 0.7, (b, h, t))
    m_s = jax.random.bernoulli(ks[1], 0.5, (b, h, t, t))
    m_s = m_s.at[..., 0].set(True)      # every row reads block 0 (non-empty)
    m_c = m_c.at[..., 0].set(True)
    return m_c, m_s


def test_shard_geometry_math():
    cfg = EngineConfig(mask=MASK)
    spec = cfg.caps(N)
    g = shard_geometry(spec, 16, 16, 4, pair_slack=1.5)
    assert g.q_bps == 4 and g.kv_bps == 4
    assert g.cap_q == min(spec.cap_q, 4)
    assert g.pair_cap == min(4, max(1, -(-int(1.5 * spec.cap_kv) // 4)))
    assert g.cap_kv == min(16, g.kv_bps + 3 * g.pair_cap)
    assert g.buf_blocks == g.kv_bps + 4 * g.pair_cap
    # slack >= 1 guarantees the per-shard union admits any full row list
    assert g.cap_kv >= min(spec.cap_kv, 16)
    assert exchange_blocks(g) == 4 * g.pair_cap
    assert dense_exchange_blocks(16) == 16
    with pytest.raises(ValueError, match="divisible"):
        shard_geometry(spec, 15, 16, 4)
    with pytest.raises(ValueError, match="mesh_sp"):
        shard_geometry(spec, 16, 16, 0)


def test_identity_fold_is_noop():
    """pair_cap at its safe bound (kv_bps): the mesh fold keeps every
    block, so the base plan fields match the non-mesh plan bit-for-bit."""
    m_c, m_s = _masks()
    cfg0 = EngineConfig(mask=MASK)
    # slack large enough that pair_cap == kv_bps
    cfgm = dataclasses.replace(cfg0, mesh_dp=1, mesh_sp=2,
                               mesh_pair_slack=64.0)
    p0 = build_dispatch_plan(m_c, m_s, cfg0, N)
    pm = build_dispatch_plan(m_c, m_s, cfgm, N)
    g = shard_geometry(cfg0.caps(N), MASK.n_blocks(N) * 2,
                       MASK.n_blocks(N) * 2, 2, 64.0)
    assert g.pair_cap == g.kv_bps
    for f in ("q_ids", "q_cnt", "q_slots", "kv_ids", "kv_cnt", "pair_live",
              "kv_row_ids", "kv_row_cnt", "row_ids", "row_cnt"):
        np.testing.assert_array_equal(
            np.asarray(getattr(p0, f)), np.asarray(getattr(pm, f)), err_msg=f)
    assert p0.shd_q_ids is None and pm.shd_q_ids is not None


def test_partition_invariants():
    """Row partition, union reconstruction from the send/gather tables,
    order-preserving row-list remap, and capacity bounds — all in numpy."""
    sp = 4
    m_c, m_s = _masks()
    cfgm = EngineConfig(mask=MASK, mesh_dp=1, mesh_sp=sp)
    cfg0 = EngineConfig(mask=MASK)
    spec = cfgm.caps(N)
    t = MASK.n_blocks(N) * (MASK.pool // MASK.block_kv)
    g = shard_geometry(spec, t, t, sp, cfgm.mesh_pair_slack)
    pm = build_dispatch_plan(m_c, m_s, cfgm, N).widen()
    p0 = build_dispatch_plan(m_c, m_s, cfg0, N).widen()

    q_ids = np.asarray(pm.q_ids); q_cnt = np.asarray(pm.q_cnt)
    rl = np.asarray(pm.kv_row_ids); rc = np.asarray(pm.kv_row_cnt)
    sq_ids = np.asarray(pm.shd_q_ids); sq_src = np.asarray(pm.shd_q_src)
    sq_cnt = np.asarray(pm.shd_q_cnt)
    skv = np.asarray(pm.shd_kv_ids); skv_cnt = np.asarray(pm.shd_kv_cnt)
    srl = np.asarray(pm.shd_kv_row_ids); src_ = np.asarray(pm.shd_kv_row_cnt)
    gi = np.asarray(pm.shd_gather_idx)
    send = np.asarray(pm.shd_send_ids); send_cnt = np.asarray(pm.shd_send_cnt)

    # capacity bounds
    assert (sq_cnt <= g.cap_q).all() and (skv_cnt <= g.cap_kv).all()
    assert (send_cnt <= g.pair_cap).all()
    # mesh fold only shrinks the row lists (shared truncation)
    assert (rc <= np.asarray(p0.kv_row_cnt)).all()

    for b in range(B):
        for h in range(H):
            live = set(q_ids[b, h, :q_cnt[b, h]].tolist())
            shard_rows = []
            for p in range(sp):
                cnt = sq_cnt[b, h, p]
                rows = sq_src[b, h, p, :cnt].tolist()
                shard_rows += rows
                # local ids are the global ids offset into the shard slice
                np.testing.assert_array_equal(
                    sq_ids[b, h, p, :cnt],
                    sq_src[b, h, p, :cnt] - p * g.q_bps)
                # union reconstruction: gather idx -> global block id
                for c in range(skv_cnt[b, h, p]):
                    gidx = gi[b, h, p, c]
                    if gidx < g.kv_bps:
                        glob = p * g.kv_bps + gidx
                    else:
                        s = (gidx - g.kv_bps) // g.pair_cap
                        j = (gidx - g.kv_bps) % g.pair_cap
                        assert j < send_cnt[b, h, s, p], (b, h, p, c)
                        glob = s * g.kv_bps + send[b, h, s, p, j]
                    assert glob == skv[b, h, p, c], (b, h, p, c)
                # remapped row lists resolve to the folded global lists,
                # order-preserving
                for i in range(cnt):
                    gslot = int(np.where(
                        q_ids[b, h] == sq_src[b, h, p, i])[0][0])
                    nkv = src_[b, h, p, i]
                    assert nkv == rc[b, h, gslot]
                    np.testing.assert_array_equal(
                        skv[b, h, p][srl[b, h, p, i, :nkv]],
                        rl[b, h, gslot, :nkv])
            # row partition covers the live set exactly, no duplicates
            assert sorted(shard_rows) == sorted(live)
            # ascending unions (contiguous per-source runs)
            for p in range(sp):
                u = skv[b, h, p, :skv_cnt[b, h, p]]
                assert (np.diff(u) > 0).all()


def test_plan_from_state_rebuild_bit_exact():
    """ISSUE 7: ``plan_from_state`` rebuilds the shd_* partition fields
    bit-exactly from the packed symbols under a mesh config."""
    cfgm = EngineConfig(mask=MASK, mesh_dp=1, mesh_sp=2)
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    params = AttnParams(
        wq=jax.random.normal(ks[0], (DM, H * DH)) * 0.05,
        wk=jax.random.normal(ks[1], (DM, H * DH)) * 0.05,
        wv=jax.random.normal(ks[2], (DM, H * DH)) * 0.05,
        wo=jax.random.normal(ks[3], (H * DH, DM)) * 0.05,
        q_scale=jnp.ones((DH,)), k_scale=jnp.ones((DH,)))
    x = jax.random.normal(ks[4], (B, N, DM), jnp.float32)
    st0 = init_layer_state(B, H, N, DM, DH, cfgm)
    _, st = update_layer(params, x, st0, cfgm, heads=H)
    rebuilt = plan_from_state(st, cfgm, N)
    assert st.plan.shd_q_ids is not None
    for f in st.plan._fields:
        a, b = getattr(st.plan, f), getattr(rebuilt, f)
        if a is None:
            assert b is None, f
            continue
        assert a.dtype == b.dtype, f
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f)


def test_mesh_attention_validation_errors():
    from repro.core.backend import XlaBackend
    m_c, m_s = _masks()
    cfg0 = EngineConfig(mask=MASK)
    plan = build_dispatch_plan(m_c, m_s, cfg0, N)
    spec = cfg0.caps(N)
    z = jnp.zeros((B, H, N, DH))
    xla = XlaBackend()
    # seq mode rejects a plan built without the shd_* partition
    cfg_seq = dataclasses.replace(cfg0, mesh_dp=1, mesh_sp=2)
    with pytest.raises(ValueError, match="shd_"):
        mesh_attention(xla, cfg_seq, z, z, z, z, plan, spec)
    # head mode rejects indivisible heads and the bucketed layout
    cfg_head = dataclasses.replace(cfg0, mesh_dp=1, mesh_sp=3,
                                   mesh_axis="head")
    with pytest.raises(ValueError, match="heads"):
        mesh_attention(xla, cfg_head, z, z, z, z, plan, spec)
    cfg_head2 = dataclasses.replace(cfg0, mesh_dp=1, mesh_sp=2,
                                    mesh_axis="head")
    spec_b = spec._replace(kv_buckets=3)
    with pytest.raises(ValueError, match="bucketed"):
        mesh_attention(xla, cfg_head2, z, z, z, z, plan, spec_b)
    # batch must divide over the data axis
    cfg_dp = dataclasses.replace(cfg0, mesh_dp=3, mesh_sp=1)
    with pytest.raises(ValueError, match="batch"):
        mesh_attention(xla, cfg_dp, z, z, z, z, plan, spec)


def test_make_engine_mesh_requires_devices():
    from repro.launch.mesh import make_engine_mesh
    with pytest.raises(ValueError, match="devices"):
        make_engine_mesh(1, 8 * len(jax.devices()))


def test_mesh_shape_for_derives_from_device_count():
    from repro.launch.mesh import mesh_shape_for
    assert mesh_shape_for(512, (16, 16)) == (16, 16)     # cap saturates
    assert mesh_shape_for(32, (16, 16)) == (2, 16)       # model axis filled first
    assert mesh_shape_for(1024, (2, 16, 16)) == (2, 16, 16)
    assert mesh_shape_for(8, (16, 16)) == (1, 8)         # model axis first
    assert mesh_shape_for(6, (16, 16)) == (1, 4)         # floor pow2
    assert mesh_shape_for(1, (16, 16)) == (1, 1)
    with pytest.raises(ValueError, match="power"):
        mesh_shape_for(8, (3, 16))
    with pytest.raises(ValueError, match="device"):
        mesh_shape_for(0, (16, 16))


def test_collective_bytes_extended_ops():
    """The dry-run byte counter must know every exchange op the sharded
    dispatch can lower to — a stale list makes the CI gate read 0 bytes."""
    from repro.launch.dryrun import collective_bytes
    hlo = "\n".join([
        "%r = f32[8,16]{1,0} ragged-all-to-all(%a, %b, %c), replica_groups={}",
        "%s = f32[4,4]{1,0} all-to-all(%d), replica_groups={{0,1}}",
        "%t = bf16[32]{0} collective-broadcast(%e)",
        "%u = f32[2,2]{1,0} collective-permute-start(%f)",
    ])
    coll = collective_bytes(hlo)
    assert coll["ragged-all-to-all"] == 8 * 16 * 4
    assert coll["all-to-all"] == 4 * 4 * 4          # not swallowed by ragged
    assert coll["collective-broadcast"] == 32 * 2
    assert coll["collective-permute"] == 2 * 2 * 4  # -start variant counted
    assert coll["ragged-all-to-all_count"] == 1
