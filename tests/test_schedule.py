"""Scan-native SparsitySchedule tests (ISSUE 3 acceptance criteria).

  * schedule resolution: ``EngineConfig`` → mode array + (step × layer)
    strategy-id table, named presets, multi-granularity layer-table
    expansion;
  * scan-vs-unrolled BIT parity for per-layer strategy tables on both
    backends (the traced ``lax.switch`` row reproduces per-layer trace
    bodies exactly);
  * a step-varying strategy (head re-classification flipping at a schedule
    boundary) exercising ``StrategyContext.step_idx``, parity-tested
    Update→Dispatch on both backends;
  * ``sample`` compiles exactly ONE executable for a mixed
    update/dispatch schedule, and its single-scan output matches the
    legacy three-jit Python loop;
  * ``denoise_step`` with a full per-layer table lowers to an HLO whose
    size is independent of ``n_layers`` (the scan never unrolls).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.core import (AttnParams, EngineConfig, MaskConfig, dispatch_layer,
                        init_layer_state, plan_from_state, resolve_schedule,
                        update_layer)
from repro.core.engine import is_update_step
from repro.core.schedule import (MODE_DENSE, MODE_DISPATCH, MODE_UPDATE,
                                 SparsitySchedule, available_schedules,
                                 get_schedule, schedule_summaries)
from repro.core.strategy import (MultiGranularityStrategy, StepPhasedStrategy,
                                 StrategyContext, get_strategy)
from repro.diffusion.pipeline import SamplerConfig, sample
from repro.models import dit

N_TEXT = 32


def _ecfg(**kw):
    base = dict(tau_q=0.5, tau_kv=0.15, interval=4, order=1, degrade=0.0,
                block_q=16, block_kv=16, pool=16, warmup_steps=2)
    mask_keys = set(base)
    mask_kw = {k: kw.pop(k) for k in list(kw) if k in mask_keys}
    return EngineConfig(mask=MaskConfig(**{**base, **mask_kw}),
                        cache_dtype=jnp.float32, cap_q_frac=1.0,
                        cap_kv_frac=1.0, **kw)


def _model(n_layers=None):
    cfg = get_smoke("flux-mmdit")
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    xv = jax.random.normal(key, (1, 64, cfg.d_model))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    return cfg, params, xv, text


# ---------------------------------------------------------------------------
# Schedule resolution
# ---------------------------------------------------------------------------

def test_from_config_modes_follow_update_rule():
    ecfg = _ecfg()
    sched = resolve_schedule(ecfg, 10, 3)
    want = [MODE_UPDATE if is_update_step(i, ecfg) else MODE_DISPATCH
            for i in range(10)]
    assert np.asarray(sched.mode).tolist() == want
    assert sched.strategy_ids.shape == (10, 3)
    assert sched.kinds()[:3] == ["update", "update", "update"]
    assert len(sched.strategies) == 1
    # force_dense: every step dense, single strategy entry.
    dense = resolve_schedule(ecfg, 4, 3, force_dense=True)
    assert np.asarray(dense.mode).tolist() == [MODE_DENSE] * 4


def test_named_schedules_registry():
    for required in ("hunyuan-1.5x", "step-ramp"):
        assert required in available_schedules()
        assert schedule_summaries()[required]
    with pytest.raises(ValueError, match="unknown sparsity schedule"):
        get_schedule("no-such-schedule", _ecfg(), 4, 3)
    # step-ramp: strategy ids ramp over the step axis.
    ramp = get_schedule("step-ramp", _ecfg(), 9, 2)
    ids = np.asarray(ramp.strategy_ids)
    assert ids[0, 0] == 0 and ids[4, 0] == 1 and ids[8, 0] == 2
    assert [s.name for s in ramp.strategies] == \
        ["skip-only", "flashomni", "cache-all"]
    # hunyuan-1.5x: boundary layers point at the skip-only variant.
    hy = get_schedule("hunyuan-1.5x", _ecfg(), 4, 5)
    ids = np.asarray(hy.strategy_ids)
    assert (ids[:, :2] == 0).all() and (ids[:, 2:] == 1).all()
    # A prebuilt schedule passes through but must match the run shape.
    assert get_schedule(hy, _ecfg(), 4, 5) is hy
    with pytest.raises(ValueError, match="schedule is"):
        get_schedule(hy, _ecfg(), 6, 5)


def test_layer_strategies_entry_with_layer_assign_pins_its_position():
    """A layer_strategies ENTRY carrying a layer_assign table is pinned to
    its list position's template — the semantics the deleted unrolled path
    gave via layer_idx threading (regression guard)."""
    from repro.core.schedule import strategy_table
    mg = MultiGranularityStrategy(children=("flashomni", "sliding-window"),
                                  layer_assign={0: 1})
    strategies, ids = strategy_table([mg, mg, mg], _ecfg(), 3)
    # Layer 0 -> the pinned sliding-window variant; layers 1/2 share the
    # head-template variant (deduplicated).
    assert len(strategies) == 2
    assert ids.tolist() == [0, 1, 1]
    assert strategies[0]._template(None) == (1,)
    assert strategies[1]._template(None) is None
    # Registry-name entries resolving to a layer table behave the same.
    strategies2, ids2 = strategy_table(["hunyuan-1.5x"] * 4, _ecfg(), 4)
    assert ids2.tolist() == [0, 0, 1, 1]
    assert len(strategies2) == 2


def test_schedule_validate_rejects_bad_tables():
    ecfg = _ecfg()
    good = resolve_schedule(ecfg, 4, 2)
    bad = SparsitySchedule(mode=good.mode,
                           strategy_ids=good.strategy_ids + 7,
                           strategies=good.strategies)
    with pytest.raises(ValueError, match="strategy ids"):
        bad.validate()
    with pytest.raises(ValueError, match="layer_strategies has"):
        resolve_schedule(ecfg, 4, 3, layer_strategies=["flashomni"])


# ---------------------------------------------------------------------------
# Scan vs unrolled bit parity for per-layer tables (both backends)
# ---------------------------------------------------------------------------

def _assert_tree_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert la.dtype == lb.dtype
        if jnp.issubdtype(la.dtype, jnp.floating):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-6, rtol=1e-6, err_msg=msg)
        else:
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=msg)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_scan_vs_unrolled_bit_parity_per_layer_table(backend):
    """The traced strategy-id row under lax.scan reproduces the unrolled
    per-layer trace bodies exactly (packed symbols + plan, bit for bit)."""
    cfg, params, xv, text = _model()
    ecfg = _ecfg(backend=backend,
                 interpret=True if backend == "pallas" else None)
    t = jnp.full((1,), 0.1)
    n_tokens = 64 + cfg.n_text_tokens
    table = ["flashomni", "cache-all", "sliding-window"][:cfg.n_layers]
    states = dit.init_engine_states(cfg, ecfg, 1, n_tokens)

    v_scan, st_scan = dit.denoise_step(params, cfg, ecfg, states, xv, text, t,
                                       mode="update", dtype=jnp.float32,
                                       layer_strategies=table)
    cfg_unroll = dataclasses.replace(cfg, scan_layers=False)
    v_un, st_un = dit.denoise_step(params, cfg_unroll, ecfg, states, xv, text,
                                   t, mode="update", dtype=jnp.float32,
                                   layer_strategies=table)
    np.testing.assert_array_equal(np.asarray(st_scan.s_c), np.asarray(st_un.s_c))
    np.testing.assert_array_equal(np.asarray(st_scan.s_s), np.asarray(st_un.s_s))
    _assert_tree_equal(st_scan.plan, st_un.plan, msg=backend)
    np.testing.assert_allclose(np.asarray(v_scan), np.asarray(v_un),
                               atol=1e-5, rtol=1e-5)
    # ...and the table really is applied per layer (distinct vision bits).
    t_blocks = ecfg.mask.n_blocks(n_tokens)
    n_t = -(-cfg.n_text_tokens // ecfg.mask.pool)
    from repro.core.symbols import unpack_bits
    m_c = unpack_bits(st_scan.s_c, t_blocks)             # (L, B, H, T)
    assert not bool(m_c[1, ..., n_t:].any())             # cache-all layer
    assert bool(m_c[2, ..., n_t:].all())                 # sliding-window layer


# ---------------------------------------------------------------------------
# Step-varying strategy: head re-classification at a schedule boundary
# ---------------------------------------------------------------------------

def _attn_setup(backend="xla", heads=2):
    key = jax.random.PRNGKey(0)
    B, H, N, dm, dh = 1, heads, 256, 64, 32
    cfg = EngineConfig(
        mask=MaskConfig(pool=32, block_q=16, block_kv=16, interval=4,
                        order=1, warmup_steps=1, tau_kv=0.15, tau_q=0.5),
        cap_q_frac=1.0, cap_kv_frac=1.0, cache_dtype=jnp.float32,
        backend=backend, interpret=True if backend == "pallas" else None)
    ks = jax.random.split(key, 6)
    p = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh)) * 0.05,
        wk=jax.random.normal(ks[1], (dm, H * dh)) * 0.05,
        wv=jax.random.normal(ks[2], (dm, H * dh)) * 0.05,
        wo=jax.random.normal(ks[3], (H * dh, dm)) * 0.05,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm))
    return cfg, p, x, H, N


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_step_phased_head_reclassification(backend):
    """SVG-style re-classification: the head → class table flips at the
    schedule boundary, driven by the TRACED StrategyContext.step_idx."""
    cfg, p, x, H, N = _attn_setup(backend)
    phase_a = MultiGranularityStrategy(children=("cache-all", "skip-only"),
                                       head_assign=(0, 1), name="phase-a")
    phase_b = MultiGranularityStrategy(children=("cache-all", "skip-only"),
                                       head_assign=(1, 0), name="phase-b")
    sp = StepPhasedStrategy(phases=(phase_a, phase_b), boundaries=(2,))
    from repro.core.engine import _qk
    q, k = _qk(p, x, H, None)
    ctx = StrategyContext(cfg=cfg, n_text=N_TEXT, n_tokens=N)
    want_a = phase_a.emit(q, k, ctx)
    want_b = phase_b.emit(q, k, ctx)
    # head 0 caches before the boundary, head 1 after (and vice versa) —
    # and the phased emit matches the phase child exactly on both sides.
    for step, want in [(0, want_a), (1, want_a), (2, want_b), (3, want_b)]:
        got = sp.emit(q, k, ctx._replace(step_idx=jnp.int32(step),
                                         num_steps=4))
        np.testing.assert_array_equal(np.asarray(got.s_c),
                                      np.asarray(want.s_c), err_msg=str(step))
        np.testing.assert_array_equal(np.asarray(got.s_s),
                                      np.asarray(want.s_s))
    assert not np.array_equal(np.asarray(want_a.s_c), np.asarray(want_b.s_c))
    # Without a step context, phase 0 applies (direct update_layer calls).
    got0 = sp.emit(q, k, ctx)
    np.testing.assert_array_equal(np.asarray(got0.s_c), np.asarray(want_a.s_c))

    # Update→Dispatch round-trip ON THE BACKEND across the boundary: the
    # traced step drives update_layer's symbols; dispatch consumes the plan
    # verbatim and the rebuilt plan matches bit for bit.
    for step in (1, 3):
        state = init_layer_state(1, H, N, 64, 32, cfg)
        out_u, st = update_layer(p, x, state, cfg, n_text=N_TEXT, heads=H,
                                 strategy=sp, step_idx=jnp.int32(step),
                                 num_steps=4)
        assert bool(jnp.isfinite(out_u).all())
        want = want_a if step < 2 else want_b
        np.testing.assert_array_equal(np.asarray(st.s_c), np.asarray(want.s_c))
        out_d, st2 = dispatch_layer(p, x, st, cfg, n_text=N_TEXT, heads=H)
        assert bool(jnp.isfinite(out_d).all())
        _assert_tree_equal(plan_from_state(st2, cfg, N), st2.plan,
                           msg=f"{backend} step {step}")


def test_step_phased_validation():
    with pytest.raises(ValueError, match="phases need"):
        StepPhasedStrategy(phases=("flashomni",), boundaries=(0.5,))
    sp = StepPhasedStrategy(phases=("flashomni", "cache-all"),
                            boundaries=(0.5,))
    cfg, p, x, H, N = _attn_setup()
    from repro.core.engine import _qk
    q, k = _qk(p, x, H, None)
    ctx = StrategyContext(cfg=cfg, n_text=N_TEXT, n_tokens=N,
                          step_idx=jnp.int32(1), num_steps=None)
    with pytest.raises(ValueError, match="num_steps"):
        sp.emit(q, k, ctx)


# ---------------------------------------------------------------------------
# One compiled executable for the whole sampling loop
# ---------------------------------------------------------------------------

def test_sample_compiles_exactly_one_executable():
    cfg, params, _, text = _model()
    x0 = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.patch_dim))
    stats: dict = {}
    trace: list = []
    out = sample(params, cfg, _ecfg(), text_emb=text, x0=x0,
                 scfg=SamplerConfig(num_steps=8), trace=trace, stats=stats)
    assert bool(jnp.isfinite(out).all())
    # Mixed schedule (2 warmup updates + interval-4 cadence) through ONE
    # lax.scan with lax.switch: exactly one compiled step executable.
    kinds = [t["kind"] for t in trace]
    assert "update" in kinds and "dispatch" in kinds
    assert stats["executables"] == 1
    # The resolved schedule is surfaced for diagnostics.
    assert stats["schedule"].num_steps == 8


def test_sample_scan_matches_legacy_three_jit_loop():
    """The single-scan sampler reproduces the old Python-loop-of-three-jits
    numerics (same modes, same states threading)."""
    cfg, params, _, text = _model()
    ecfg = _ecfg()
    x0 = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.patch_dim))
    pd = x0.shape[-1]
    patch_embed = jax.random.normal(jax.random.PRNGKey(7), (pd, cfg.d_model)) * 0.2
    steps = 8
    got = sample(params, cfg, ecfg, text_emb=text, x0=x0,
                 scfg=SamplerConfig(num_steps=steps), patch_embed=patch_embed)

    n_tokens = 64 + text.shape[1]
    states = dit.init_engine_states(cfg, ecfg, 1, n_tokens)
    step = {m: jax.jit(lambda p, s, xv, te, t, m=m: dit.denoise_step(
        p, cfg, ecfg, s, xv, te, t, mode=m, dtype=jnp.float32))
        for m in ("update", "dispatch")}
    x = x0
    dt = 1.0 / steps
    for i in range(steps):
        t = jnp.full((1,), i * dt, jnp.float32)
        xe = (x @ patch_embed).astype(jnp.float32)
        mode = "update" if is_update_step(i, ecfg) else "dispatch"
        v, states = step[mode](params, states, xe, text, t)
        x = x + v.astype(x.dtype) * dt
    np.testing.assert_allclose(np.asarray(got), np.asarray(x),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# HLO size independent of n_layers for full per-layer tables
# ---------------------------------------------------------------------------

def test_denoise_step_hlo_size_independent_of_depth():
    """A FULL per-layer strategy table must not unroll the block scan: the
    jaxpr equation count is identical for 3- and 6-layer models."""
    def eqn_count(n_layers):
        cfg, params, xv, text = _model(n_layers=n_layers)
        ecfg = _ecfg()
        n_tokens = 64 + cfg.n_text_tokens
        states = dit.init_engine_states(cfg, ecfg, 1, n_tokens)
        table = (["flashomni", "cache-all", "sliding-window"]
                 * n_layers)[:n_layers]
        t = jnp.full((1,), 0.1)
        jaxpr = jax.make_jaxpr(
            lambda p, s: dit.denoise_step(p, cfg, ecfg, s, xv, text, t,
                                          mode="update", dtype=jnp.float32,
                                          layer_strategies=table))(
            params, states)
        # Top-level equation count via the analyzer's walker: a rolled
        # block scan counts once regardless of depth.
        from repro.analysis.jaxpr_walk import eqn_count as walker_count
        return walker_count(jaxpr)

    assert eqn_count(3) == eqn_count(6)


def test_denoise_step_rejects_conflicting_strategy_args():
    cfg, params, xv, text = _model()
    ecfg = _ecfg()
    states = dit.init_engine_states(cfg, ecfg, 1, 64 + cfg.n_text_tokens)
    t = jnp.full((1,), 0.1)
    with pytest.raises(ValueError, match="not both"):
        dit.denoise_step(params, cfg, ecfg, states, xv, text, t,
                         mode="update", dtype=jnp.float32,
                         layer_strategies=["flashomni"] * cfg.n_layers,
                         strategies=(get_strategy("flashomni"),))
