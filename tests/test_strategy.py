"""SparsityStrategy API tests (ISSUE 2 acceptance criteria).

  * ``flashomni`` reproduces the seed ``refresh_symbols`` packed symbols
    bit-for-bit and the pre-refactor DispatchPlan pytree exactly;
  * every registered strategy runs one Update→Dispatch round-trip on BOTH
    backends (``xla``, ``pallas`` interpret) with finite outputs and an
    exactly-rebuildable plan;
  * plan row-capacity truncation ranks by column mass (ROADMAP item);
  * int16 plan ids round-trip to the int32 reference plan;
  * per-layer strategy tables thread through ``dit.denoise_step``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AttnParams, EngineConfig, MaskConfig,
                        available_strategies, dispatch_layer, get_strategy,
                        init_layer_state, plan_from_state, update_layer)
from repro.core.engine import _qk, refresh_symbols
from repro.core.masks import compressed_attention_map
from repro.core.plan import build_dispatch_plan
from repro.core.strategy import (FlashOmniStrategy, MultiGranularityStrategy,
                                 StrategyContext, strategy_summaries)

N_TEXT = 64


def _setup(strategy="flashomni", backend="xla", capq=1.0, capkv=1.0,
           tau_kv=0.15, heads=3):
    key = jax.random.PRNGKey(0)
    B, H, N, dm, dh = 1, heads, 256, 64, 32
    cfg = EngineConfig(
        mask=MaskConfig(pool=32, block_q=16, block_kv=16, interval=4,
                        order=1, warmup_steps=1, tau_kv=tau_kv, tau_q=0.5),
        cap_q_frac=capq, cap_kv_frac=capkv, cache_dtype=jnp.float32,
        backend=backend, strategy=strategy,
        interpret=True if backend == "pallas" else None)
    ks = jax.random.split(key, 8)
    p = AttnParams(
        wq=jax.random.normal(ks[0], (dm, H * dh)) * 0.05,
        wk=jax.random.normal(ks[1], (dm, H * dh)) * 0.05,
        wv=jax.random.normal(ks[2], (dm, H * dh)) * 0.05,
        wo=jax.random.normal(ks[3], (H * dh, dm)) * 0.05,
        q_scale=jnp.ones(dh), k_scale=jnp.ones(dh))
    x = jax.random.normal(ks[4], (B, N, dm))
    state = init_layer_state(B, H, N, dm, dh, cfg)
    return cfg, p, x, state, H, N


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    names = available_strategies()
    assert len(names) >= 5
    for required in ("flashomni", "cache-all", "skip-only", "sliding-window",
                     "multi-granularity"):
        assert required in names
        assert strategy_summaries()[required]
    with pytest.raises(ValueError, match="unknown sparsity strategy"):
        get_strategy("no-such-strategy")
    # Ad-hoc (unregistered) strategy objects pass through unchanged.
    obj = FlashOmniStrategy(tau_q=0.9)
    assert get_strategy(obj) is obj


# ---------------------------------------------------------------------------
# flashomni == seed refresh_symbols, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capq,capkv", [(1.0, 1.0), (0.75, 0.9)])
def test_flashomni_bit_parity_with_seed_rule(capq, capkv):
    cfg, p, x, _, H, N = _setup(capq=capq, capkv=capkv)
    q, k = _qk(p, x, H, None)
    s_c, s_s, m_c, m_s = refresh_symbols(q, k, cfg, N_TEXT, N)
    syms = get_strategy("flashomni").emit(
        q, k, StrategyContext(cfg=cfg, n_text=N_TEXT, n_tokens=N))
    np.testing.assert_array_equal(np.asarray(s_c), np.asarray(syms.s_c))
    np.testing.assert_array_equal(np.asarray(s_s), np.asarray(syms.s_s))
    np.testing.assert_array_equal(np.asarray(m_c), np.asarray(syms.m_c))
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(syms.m_s))

    # ...and the DispatchPlan built through update_layer matches the plan
    # built from the seed rule's masks with the same column-mass ranking.
    p_map = compressed_attention_map(q, k, cfg.mask.pool)
    col_mass = jnp.sum(p_map, axis=-2)
    row_score = jnp.sum(jnp.where(m_c, col_mass, 0.0), axis=-2)
    want = build_dispatch_plan(m_c, m_s, cfg, N, row_score=row_score)
    _, st = update_layer(p, x, init_layer_state(1, H, N, 64, 32, cfg), cfg,
                         n_text=N_TEXT, heads=H)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(st.plan)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(st.s_c), np.asarray(s_c))
    np.testing.assert_array_equal(np.asarray(st.s_s), np.asarray(s_s))


# ---------------------------------------------------------------------------
# Every registered strategy: Update→Dispatch round-trip on both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("name", available_strategies())
def test_strategy_update_dispatch_roundtrip(name, backend):
    cfg, p, x, state, H, N = _setup(name, backend, capq=0.75, capkv=0.9)
    out_u, st = update_layer(p, x, state, cfg, n_text=N_TEXT, heads=H)
    assert bool(jnp.isfinite(out_u).all())
    x2 = x + 0.01 * jax.random.normal(jax.random.PRNGKey(5), x.shape)
    out_d, st2 = dispatch_layer(p, x2, st, cfg, n_text=N_TEXT, heads=H)
    assert bool(jnp.isfinite(out_d).all())
    assert int(st2.k_since) == 1
    # The plan rebuilt from the packed symbols (+ stored row ranking)
    # reproduces the frozen plan exactly — symbols stay canonical.
    rebuilt = plan_from_state(st2, cfg, N)
    for a, b in zip(jax.tree.leaves(rebuilt), jax.tree.leaves(st2.plan)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_strategy_backend_outputs_match():
    """The same strategy's dispatch agrees across backends (interpret)."""
    for name in available_strategies():
        cfg_x, p, x, state, H, _ = _setup(name, "xla")
        cfg_p = dataclasses.replace(cfg_x, backend="pallas", interpret=True)
        _, st = update_layer(p, x, state, cfg_x, n_text=N_TEXT, heads=H)
        out_x, _ = dispatch_layer(p, x, st, cfg_x, n_text=N_TEXT, heads=H)
        out_p, _ = dispatch_layer(p, x, st, cfg_p, n_text=N_TEXT, heads=H)
        np.testing.assert_allclose(np.asarray(out_x), np.asarray(out_p),
                                   atol=1e-5, rtol=1e-5, err_msg=name)


def test_cache_all_is_pure_forecast():
    """cache-all: every vision block cached ⇒ identical input reproduces
    the Update output exactly (pure reuse of the frozen bias/outputs)."""
    cfg, p, x, state, H, N = _setup("cache-all")
    out_u, st = update_layer(p, x, state, cfg, n_text=N_TEXT, heads=H)
    out_d, _ = dispatch_layer(p, x, st, cfg, n_text=N_TEXT, heads=H)
    err = float(jnp.linalg.norm(out_d - out_u) / jnp.linalg.norm(out_u))
    assert err < 1e-5, err
    # Vision rows carry no live bits; text rows stay live (Observation 1).
    t = cfg.mask.n_blocks(N)
    n_t = N_TEXT // cfg.mask.pool
    from repro.core.symbols import unpack_bits
    m_c = unpack_bits(st.s_c, t)
    assert bool(m_c[..., :n_t].all()) and not bool(m_c[..., n_t:].any())


def test_sliding_window_static_band():
    cfg, p, x, _, H, N = _setup("sliding-window", tau_kv=0.0)
    q, k = _qk(p, x, H, None)
    syms = get_strategy("sliding-window").emit(
        q, k, StrategyContext(cfg=cfg, n_text=0, n_tokens=N))
    t = cfg.mask.n_blocks(N)
    idx = np.arange(t)
    want = np.abs(idx[:, None] - idx[None, :]) < 4
    np.testing.assert_array_equal(
        np.asarray(syms.m_s[0, 0]), want)   # input-independent band
    assert bool(syms.m_c.all())             # no caching


def test_sliding_window_clamp_keeps_protected_text():
    """A tight cap_kv shrinks the band from its far edge; protected text
    columns outrank every band distance and are never evicted."""
    cfg, p, x, _, H, N = _setup("sliding-window", capkv=0.5)  # cap_kv = 4
    q, k = _qk(p, x, H, None)
    syms = get_strategy("sliding-window").emit(
        q, k, StrategyContext(cfg=cfg, n_text=N_TEXT, n_tokens=N))
    t = cfg.mask.n_blocks(N)
    n_t = N_TEXT // cfg.mask.pool
    m_s = np.asarray(syms.m_s)
    assert m_s[..., :n_t].all()          # every row still sees the prompt
    # ...and the band survivors are the NEAREST vision diagonals.
    row = t - 1
    vis_live = np.flatnonzero(m_s[0, 0, row, n_t:]) + n_t
    assert vis_live.tolist() == sorted(range(t - 1, t - 1 - (4 - n_t), -1))


def test_registered_preset_keeps_its_name():
    assert get_strategy("hunyuan-1.5x").name == "hunyuan-1.5x"
    assert get_strategy("multi-granularity").name == "multi-granularity"


def test_multi_granularity_head_table():
    """Striped heads: each head's symbols equal the assigned child's."""
    cfg, p, x, _, H, N = _setup("multi-granularity", heads=4)
    q, k = _qk(p, x, H, None)
    ctx = StrategyContext(cfg=cfg, n_text=N_TEXT, n_tokens=N)
    mg = MultiGranularityStrategy(children=("flashomni", "sliding-window"))
    got = mg.emit(q, k, ctx)
    fo = get_strategy("flashomni").emit(q, k, ctx)
    sw = get_strategy("sliding-window").emit(q, k, ctx)
    for h in range(H):
        child = fo if h % 2 == 0 else sw
        np.testing.assert_array_equal(np.asarray(got.m_c[:, h]),
                                      np.asarray(child.m_c[:, h]))
        np.testing.assert_array_equal(np.asarray(got.m_s[:, h]),
                                      np.asarray(child.m_s[:, h]))
    # layer_assign routes through the SCHEDULE strategy-id table, not emit:
    # per_layer pins each layer's template into its own variant.
    mg2 = MultiGranularityStrategy(children=("flashomni", "sliding-window"),
                                   layer_assign={0: 1})
    expanded = mg2.per_layer(3)
    assert len(expanded) == 3
    e0 = expanded[0].emit(q, k, ctx)
    np.testing.assert_array_equal(np.asarray(e0.m_s), np.asarray(sw.m_s))
    e1 = expanded[1].emit(q, k, ctx)
    np.testing.assert_array_equal(np.asarray(e1.m_s), np.asarray(got.m_s))
    # emit itself is layer-agnostic: layer ids are traced under the scanned
    # block body, so the head template applies regardless of layer_idx (the
    # old warning fallback is gone — the schedule table IS the layer table).
    np.testing.assert_array_equal(
        np.asarray(mg2.emit(q, k, ctx._replace(layer_idx=0)).m_s),
        np.asarray(mg2.emit(q, k, ctx).m_s))
    # SparsitySchedule.from_config expands the table: layer 0 -> the pinned
    # variant, other layers -> the head-template variant (deduplicated).
    from repro.core.schedule import SparsitySchedule
    import dataclasses as _dc
    cfg2 = _dc.replace(cfg, strategy=mg2)
    sched = SparsitySchedule.from_config(cfg2, num_steps=4, n_layers=3)
    assert len(sched.strategies) == 2
    assert sched.strategy_ids.shape == (4, 3)
    assert sched.strategy_ids[0].tolist() == [0, 1, 1]
    s0 = sched.strategies[0].emit(q, k, ctx)
    np.testing.assert_array_equal(np.asarray(s0.m_s), np.asarray(sw.m_s))


# ---------------------------------------------------------------------------
# Plan satellites: mass-ranked row truncation + int16 id round-trip
# ---------------------------------------------------------------------------

def test_row_capacity_truncation_ranks_by_column_mass():
    """cap < live rows ⇒ the LOWEST-mass rows are dropped, not the last
    ones in index order (the seed kept the first `cap` rows)."""
    b, h, t, blk = 1, 2, 8, 16
    n = t * blk
    cfg = EngineConfig(mask=MaskConfig(pool=blk, block_q=blk, block_kv=blk),
                       cap_q_frac=0.5)                     # cap_rows = 4
    m_c = jnp.ones((b, h, t), bool)
    m_s = jnp.ones((b, h, t, t), bool)
    score = jnp.arange(t, dtype=jnp.float32)[None, :]      # mass grows with id
    plan = build_dispatch_plan(m_c, m_s, cfg, n, row_score=score)
    assert sorted(np.asarray(plan.row_ids[0]).tolist()) == [4, 5, 6, 7]
    assert int(plan.row_cnt[0]) == 4
    # Reversed mass keeps the first four rows instead.
    plan2 = build_dispatch_plan(m_c, m_s, cfg, n, row_score=score[..., ::-1])
    assert sorted(np.asarray(plan2.row_ids[0]).tolist()) == [0, 1, 2, 3]
    # Dropped rows degrade to cache-reuse: no compute bits left for them.
    m_ch = np.asarray(plan.m_ch)                            # (B, T, H)
    assert not m_ch[:, :4].any() and m_ch[:, 4:].all()


def test_fallback_row_score_is_mask_mass():
    """Without an explicit score the ranking uses live-pair mass, so rows
    with more live (head, kv) work survive truncation."""
    b, h, t, blk = 1, 2, 8, 16
    cfg = EngineConfig(mask=MaskConfig(pool=blk, block_q=blk, block_kv=blk),
                       cap_q_frac=0.5)
    m_c = jnp.ones((b, h, t), bool)
    m_s = jnp.zeros((b, h, t, t), bool).at[..., :1].set(True)
    # Rows 3..6 attend to every kv block in every head; others to one.
    m_s = m_s.at[..., 3:7, :].set(True)
    plan = build_dispatch_plan(m_c, m_s, cfg, t * blk)
    assert sorted(np.asarray(plan.row_ids[0]).tolist()) == [3, 4, 5, 6]


def test_plan_int16_ids_roundtrip():
    cfg, p, x, state, H, N = _setup(capq=0.75, capkv=0.9)
    q, k = _qk(p, x, H, None)
    syms = get_strategy("flashomni").emit(
        q, k, StrategyContext(cfg=cfg, n_text=N_TEXT, n_tokens=N))
    narrow = build_dispatch_plan(syms.m_c, syms.m_s, cfg, N)
    wide = build_dispatch_plan(syms.m_c, syms.m_s, cfg, N, compact_ids=False)
    assert narrow.row_ids.dtype == jnp.int16
    assert narrow.kv_row_ids.dtype == jnp.int16
    assert wide.row_ids.dtype == jnp.int32
    widened = narrow.widen()
    for a, b in zip(jax.tree.leaves(widened), jax.tree.leaves(wide)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # widen() is idempotent and a no-op on an already-wide plan.
    assert widened.widen() is widened
    assert wide.widen() is wide


# ---------------------------------------------------------------------------
# Per-layer strategy tables through the model
# ---------------------------------------------------------------------------

def test_denoise_step_per_layer_strategies():
    from repro.configs.registry import get_smoke
    from repro.models import dit
    cfg = get_smoke("flux-mmdit")
    ecfg = EngineConfig(
        mask=MaskConfig(tau_q=0.5, tau_kv=0.15, interval=4, order=1,
                        degrade=0.0, block_q=16, block_kv=16, pool=16,
                        warmup_steps=1),
        cache_dtype=jnp.float32, cap_q_frac=1.0, cap_kv_frac=1.0)
    params = dit.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    xv = jax.random.normal(key, (1, 64, cfg.d_model))
    text = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, cfg.n_text_tokens, cfg.d_model))
    t = jnp.zeros((1,))
    n_tokens = 64 + cfg.n_text_tokens
    states = dit.init_engine_states(cfg, ecfg, 1, n_tokens)

    table = ["cache-all"] * cfg.n_layers
    table[0] = "flashomni"
    v, new_states = dit.denoise_step(params, cfg, ecfg, states, xv, text, t,
                                     mode="update", dtype=jnp.float32,
                                     layer_strategies=table)
    assert bool(jnp.isfinite(v).all())
    t_blocks = ecfg.mask.n_blocks(n_tokens)
    n_t = -(-cfg.n_text_tokens // ecfg.mask.pool)
    from repro.core.symbols import unpack_bits
    m_c = unpack_bits(new_states.s_c, t_blocks)            # (L, B, H, T)
    # cache-all layers: no vision bits live; flashomni layer 0: some live.
    assert not bool(m_c[1:, ..., n_t:].any())
    assert bool(m_c[0].any())
    with pytest.raises(ValueError, match="layer_strategies"):
        dit.denoise_step(params, cfg, ecfg, states, xv, text, t,
                         mode="update", dtype=jnp.float32,
                         layer_strategies=["flashomni"])
