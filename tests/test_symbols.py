"""Sparse-symbol unit + property tests (paper §3.3, Fig. 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core import symbols as S

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def test_paper_figure5_example():
    # Paper: mask [1,1,1,0,0] big-end aligned, zero padded -> 0b11100000 = 224
    s = S.pack_bits(jnp.array([1, 1, 1, 0, 0], bool))
    assert int(s[0]) == 224
    # And the two S_s example bytes: 235 = 0b11101011, 197 = 0b11000101
    assert int(S.pack_bits(jnp.array([1,1,1,0,1,0,1,1], bool))[0]) == 235
    assert int(S.pack_bits(jnp.array([1,1,0,0,0,1,0,1], bool))[0]) == 197


@given(st.lists(st.booleans(), min_size=1, max_size=70))
def test_pack_unpack_roundtrip(bits):
    m = jnp.array(bits, bool)
    assert (S.unpack_bits(S.pack_bits(m), len(bits)) == m).all()


@given(st.lists(st.booleans(), min_size=1, max_size=64), st.data())
def test_decode_spatial_matches_mask(bits, data):
    m = jnp.array(bits, bool)
    sym = S.pack_bits(m)
    i = data.draw(st.integers(0, len(bits) - 1))
    assert int(S.decode_spatial(sym, i)) == int(m[i])


@given(st.integers(1, 6), st.integers(1, 6), st.data())
def test_decode_reduction_matches_matrix(tq, tkv, data):
    rng = np.random.default_rng(0)
    m = rng.random((tq, tkv)) < 0.5
    sym = S.pack_bits(jnp.asarray(m.reshape(-1)))
    i = data.draw(st.integers(0, tq - 1))
    j = data.draw(st.integers(0, tkv - 1))
    assert int(S.decode_reduction(sym, i, j, tkv)) == int(m[i, j])


def test_symbol_storage_is_8x_compressed():
    t = 128
    m = jnp.ones((4, t), bool)
    assert S.pack_bits(m).size * 8 == m.size  # uint8 vs 1 bool per bit


@given(st.lists(st.booleans(), min_size=4, max_size=40), st.integers(1, 40))
def test_active_indices_properties(bits, cap):
    m = jnp.array(bits, bool)
    cap = min(cap, len(bits))
    ids, cnt = S.active_indices(m, cap)
    n_active = int(m.sum())
    assert int(cnt) == min(n_active, cap)
    got = np.asarray(ids[: int(cnt)])
    want = np.nonzero(np.asarray(m))[0][:cap]
    np.testing.assert_array_equal(got, want)          # ascending, exact
    if n_active:
        assert (np.asarray(ids) < len(bits)).all()    # padding stays in range


@given(st.integers(1, 64), st.floats(0.01, 1.0))
def test_capacity_for_bounds(t, frac):
    cap = S.capacity_for(t, frac)
    assert 1 <= cap <= t


def test_clamp_mask_topk_keeps_highest():
    m = jnp.array([1, 1, 1, 1, 0, 1], bool)
    score = jnp.array([0.1, 0.9, 0.5, 0.7, 1.0, 0.2])
    out = S.clamp_mask_topk(m, score, 3)
    np.testing.assert_array_equal(np.asarray(out),
                                  [False, True, True, True, False, False])
    assert int(out.sum()) == 3
