"""TaylorSeer forecasting properties (paper §3.3 OP_reuse)."""

import jax.numpy as jnp
import numpy as np
from _hyp_compat import given, settings, st

from repro.core import taylorseer as ts

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _fit_and_forecast(coeffs, order, mode, interval=4, n_updates=4):
    poly = lambda t: sum(c * t ** i for i, c in enumerate(coeffs))
    st_ = ts.init_state((2,), order)
    ts_pts = [interval * i for i in range(n_updates)]
    for t in ts_pts:
        st_ = ts.update(st_, jnp.full((2,), poly(float(t))))
    t_last = ts_pts[-1]
    return st_, poly, t_last


@given(st.lists(st.floats(-2, 2), min_size=2, max_size=2), st.integers(1, 3))
def test_taylor_mode_exact_linear(coeffs, k):
    state, poly, t_last = _fit_and_forecast(coeffs, order=2, mode="taylor")
    pred = ts.forecast(state, k, 4, mode="taylor")
    np.testing.assert_allclose(np.asarray(pred), poly(t_last + k),
                               rtol=1e-4, atol=1e-4)


@given(st.lists(st.floats(-2, 2), min_size=3, max_size=3), st.integers(1, 3))
def test_newton_mode_exact_quadratic(coeffs, k):
    state, poly, t_last = _fit_and_forecast(coeffs, order=2, mode="newton")
    pred = ts.forecast(state, k, 4, mode="newton")
    np.testing.assert_allclose(np.asarray(pred), poly(t_last + k),
                               rtol=1e-3, atol=1e-3)


def test_order0_is_plain_reuse():
    state = ts.init_state((3,), 0)
    state = ts.update(state, jnp.array([1.0, 2.0, 3.0]))
    for k in range(1, 4):
        np.testing.assert_allclose(np.asarray(ts.forecast(state, k, 5)),
                                   [1.0, 2.0, 3.0])


def test_warmup_degrades_to_lower_order():
    # One update only: derivatives are masked, forecast == reuse.
    state = ts.init_state((2,), 2)
    state = ts.update(state, jnp.array([5.0, -1.0]))
    np.testing.assert_allclose(np.asarray(ts.forecast(state, 3, 4)), [5.0, -1.0])


def test_derivative_stack_contents():
    state = ts.init_state((1,), 2)
    for y in [1.0, 3.0, 7.0]:
        state = ts.update(state, jnp.array([y]))
    # Δ0=7, Δ1=7-3=4, Δ2=4-(3-1)=2
    np.testing.assert_allclose(np.asarray(state.derivs[:, 0]), [7.0, 4.0, 2.0])


def test_coefficients_taylor_vs_newton():
    ct = np.asarray(ts.reuse_coefficients(2, 2, 4, "taylor"))
    cn = np.asarray(ts.reuse_coefficients(2, 2, 4, "newton"))
    x = 0.5
    np.testing.assert_allclose(ct, [1, x, x * x / 2], rtol=1e-6)
    np.testing.assert_allclose(cn, [1, x, x * (x + 1) / 2], rtol=1e-6)
